"""Ludwig liquid-crystal simulation: the paper's primary application.

Runs a nematic quench (random Q, gamma = 3 > 2.7 so the nematic phase is
stable) coupled to the LB fluid, printing conservation + free-energy
diagnostics; optionally compares the jnp and pallas engines step-for-step.

    PYTHONPATH=src python examples/ludwig_lc_sim.py [--steps 50] [--check-engines]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import TargetConfig
from repro.apps.ludwig import LudwigConfig, init_state, step
from repro.apps.ludwig.driver import diagnostics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lattice", type=int, nargs=3, default=[16, 16, 16])
    ap.add_argument("--gamma", type=float, default=3.0)
    ap.add_argument("--check-engines", action="store_true")
    args = ap.parse_args()

    cfg = LudwigConfig(lattice=tuple(args.lattice), gamma=args.gamma,
                       target=TargetConfig("jnp"))
    state = init_state(cfg, seed=0, q_amp=2e-2)
    jstep = jax.jit(step, static_argnums=1)

    d0 = diagnostics(state, cfg)
    print(f"step      mass        free_energy     |momentum|")
    t0 = time.perf_counter()
    for i in range(args.steps):
        state = jstep(state, cfg)
        if (i + 1) % max(1, args.steps // 10) == 0:
            d = diagnostics(state, cfg)
            mom = float(np.abs(np.asarray(d["momentum"])).max())
            print(f"{i+1:5d}  {float(d['mass']):12.4f}  "
                  f"{float(d['free_energy']):+.6e}  {mom:.2e}")
    dt = time.perf_counter() - t0
    nsites = int(np.prod(cfg.lattice))
    print(f"\n{args.steps} steps, {dt/args.steps*1e3:.1f} ms/step "
          f"({nsites*args.steps/dt/1e6:.1f} Msite-updates/s on CPU)")
    d = diagnostics(state, cfg)
    assert abs(float(d["mass"]) - float(d0["mass"])) < 1e-2 * float(d0["mass"])
    print("mass conserved; free energy relaxed "
          f"{float(d0['free_energy']):+.3e} -> {float(d['free_energy']):+.3e}")

    if args.check_engines:
        cfgp = LudwigConfig(lattice=tuple(args.lattice), gamma=args.gamma,
                            target=TargetConfig("pallas", vvl=128))
        s_j = step(init_state(cfg, seed=0), cfg)
        s_p = step(init_state(cfgp, seed=0), cfgp)
        np.testing.assert_allclose(s_j.q.to_numpy(), s_p.q.to_numpy(),
                                   rtol=3e-5, atol=1e-7)
        print("jnp and pallas engines agree step-for-step (C1)")


if __name__ == "__main__":
    main()
