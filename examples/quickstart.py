"""Quickstart: the paper's §3 'scale' example, written once, run on both
engines and three layouts — targetDP-JAX in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    AOS, SOA, Field, LaunchGraph, TargetConfig, aosoa, kernel, launch,
    target_sum, copy_to_target, copy_from_target,
)


# __targetEntry__ void scale(double* field): the kernel body is written
# once over canonical (ncomp, VVL) chunks — TLP/ILP/layout are config.
@kernel
def scale(v, a):
    return {"field": a * v["field"]}


@kernel
def shift(v, c):
    return {"field": v["field"] + c}


def fused_chain_demo(field, layout):
    """Fused launch graphs: a chain of kernels whose outputs feed later
    inputs lowers to ONE device kernel per engine — the intermediate
    (2*field) never round-trips through HBM — and the jit-backed launch
    cache means the second launch does not re-trace."""
    g = (LaunchGraph("scale_then_shift")
         .add(scale, {"field": "field"}, {"field": 3},
              params={"a": 2.0}, rename={"field": "scaled"})
         .add(shift, {"field": "scaled"}, {"field": 3},
              params={"c": 1.0}, rename={"field": "out"}))
    for engine in ("jnp", "pallas"):
        out = g.launch({"field": field}, config=TargetConfig(engine, vvl=256),
                       outputs=("out",))["out"]
        want = 2.0 * field.to_numpy() + 1.0
        assert np.allclose(out.to_numpy(), want, rtol=1e-6)
        print(f"fused  layout={layout.name:9s} engine={engine:6s} OK "
              f"(2 kernels, 1 launch)")


def main():
    lattice = (16, 16, 16)
    rng = np.random.default_rng(0)
    host_field = rng.normal(size=(3, *lattice)).astype(np.float32)

    for layout in (SOA, AOS, aosoa(128)):
        # targetMalloc + copyToTarget
        field = Field.from_numpy("field", host_field, lattice, layout)

        for engine in ("jnp", "pallas"):
            cfg = TargetConfig(engine, vvl=256)
            out = launch(scale, {"field": field}, {"field": 3},
                         config=cfg, params={"a": 2.0})["field"]
            # copyFromTarget
            host_out = out.to_numpy()
            assert np.allclose(host_out, 2.0 * host_field, rtol=1e-6)
            total = np.asarray(target_sum(out, cfg))
            print(f"layout={layout.name:9s} engine={engine:6s} "
                  f"sum={total.sum():+.3f}  OK")

        fused_chain_demo(field, layout)

    print("same source, every layout x engine: portable (paper C1/C2)")


if __name__ == "__main__":
    main()
