"""Quickstart: the paper's §3 'scale' example, written once, run on both
engines and three layouts — targetDP-JAX in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --trace quickstart.json
    # then load quickstart.json at https://ui.perfetto.dev — every fused
    # launch is a span tagged with its plan, cache hit/miss, modeled HBM
    # bytes and live roofline placement
"""

import argparse

import numpy as np

from repro.core import AOS, SOA, Field, LaunchGraph, TargetConfig, aosoa, kernel, launch, target_sum, telemetry


# __targetEntry__ void scale(double* field): the kernel body is written
# once over canonical (ncomp, VVL) chunks — TLP/ILP/layout are config.
@kernel
def scale(v, a):
    return {"field": a * v["field"]}


@kernel
def shift(v, c):
    return {"field": v["field"] + c}


def fused_chain_demo(field, layout):
    """Fused launch graphs: a chain of kernels whose outputs feed later
    inputs lowers to ONE device kernel per engine — the intermediate
    (2*field) never round-trips through HBM — and the jit-backed launch
    cache means the second launch does not re-trace."""
    g = (LaunchGraph("scale_then_shift")
         .add(scale, {"field": "field"}, {"field": 3},
              params={"a": 2.0}, rename={"field": "scaled"})
         .add(shift, {"field": "scaled"}, {"field": 3},
              params={"c": 1.0}, rename={"field": "out"}))
    for engine in ("jnp", "pallas"):
        out = g.launch({"field": field}, config=TargetConfig(engine, vvl=256),
                       outputs=("out",))["out"]
        want = 2.0 * field.to_numpy() + 1.0
        assert np.allclose(out.to_numpy(), want, rtol=1e-6)
        print(f"fused  layout={layout.name:9s} engine={engine:6s} OK "
              f"(2 kernels, 1 launch)")


def _poisson_body(v, gather, *, c):
    """A p = (6 + c) p - sum of the 6 face neighbours: a width-1 stencil
    stage body — ``gather(name, disp)`` reads the displaced window straight
    from the VMEM-resident halo'd block."""
    ap = (6.0 + c) * v["p"]
    for d in range(3):
        for s in (1, -1):
            disp = [0, 0, 0]
            disp[d] = s
            ap = ap - gather("p", tuple(disp))
    return {"ap": ap}


def fused_stencil_reduction_demo(lattice=(8, 8, 8), engine="pallas"):
    """The CG residual loop on fused stencil + reduction graphs.

    Two launches per iteration, exactly like the MILC solver (apps/milc/cg):

      op grph   stencil A p  ->  site-local p * Ap  ->  terminal sum <p, Ap>
      upd graph x+alpha p, r-alpha Ap (site-local)  ->  terminal sum |r'|^2

    The stencil gathers neighbours from the halo'd block in VMEM, and both
    inner products accumulate on-chip — neither p*Ap nor r'*r' ever exists
    in HBM.  A = (6 + c) I - 6-point laplacian stencil is SPD, so CG
    converges; the loop below drives it from the two fused launches alone.
    """
    cfg = TargetConfig(engine, vvl=256)
    c = 0.5
    op = (LaunchGraph("poisson_op")
          .add_stencil(_poisson_body, {"p": "p"}, {"ap": 1}, width=1,
                       params={"c": c})
          .add(lambda v: {"prod": v["p"] * v["ap"]},
               {"p": "p", "ap": "ap"}, {"prod": 1})
          .add_reduce("prod", op="sum", name="pap"))
    upd = (LaunchGraph("cg_update")
           .add(lambda v: {"x": v["x"] + v["alpha"] * v["p"]},
                {"x": "x", "p": "p", "alpha": "alpha"}, {"x": 1},
                rename={"x": "x_new"})
           .add(lambda v: {"r": v["r"] - v["alpha"] * v["ap"]},
                {"r": "r", "ap": "ap", "alpha": "alpha"}, {"r": 1},
                rename={"r": "r_new"})
           .add(lambda v: {"sq": v["r"] * v["r"]}, {"r": "r_new"}, {"sq": 1})
           .add_reduce("sq", op="sum", name="rr"))

    rng = np.random.default_rng(1)
    lat = tuple(lattice)
    b = Field.from_numpy("b", rng.normal(size=(1, *lat)), lat, SOA)
    x = Field.from_numpy("x", np.zeros((1, *lat)), lat, SOA)
    r, p = b, b
    rr = float(np.square(b.to_numpy()).sum())
    b2 = rr
    for it in range(50):
        o = op.launch({"p": p}, config=cfg, outputs=("ap", "pap"))
        alpha = rr / float(np.asarray(o["pap"]).sum())
        u = upd.launch({"x": x, "r": r, "p": p, "ap": o["ap"]},
                       scalars={"alpha": alpha}, config=cfg,
                       outputs=("x_new", "r_new", "rr"))
        x, r = u["x_new"], u["r_new"]
        rr_new = float(np.asarray(u["rr"]).sum())
        if rr_new / b2 < 1e-10:
            break
        beta = rr_new / rr
        p = p.with_canonical(r.canonical() + beta * p.canonical())
        rr = rr_new
    assert rr_new / b2 < 1e-8, (it, rr_new / b2)
    print(f"fused stencil+reduction CG: engine={engine:6s} "
          f"converged in {it + 1} iters, |r|^2/|b|^2 = {rr_new / b2:.2e}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry and write a Chrome trace "
                         "(Perfetto-loadable) of every launch to PATH")
    args = ap.parse_args(argv)
    if args.trace:
        telemetry.enable()
        telemetry.configure_logging()
    lattice = (16, 16, 16)
    rng = np.random.default_rng(0)
    host_field = rng.normal(size=(3, *lattice)).astype(np.float32)

    for layout in (SOA, AOS, aosoa(128)):
        # targetMalloc + copyToTarget
        field = Field.from_numpy("field", host_field, lattice, layout)

        for engine in ("jnp", "pallas"):
            cfg = TargetConfig(engine, vvl=256)
            out = launch(scale, {"field": field}, {"field": 3},
                         config=cfg, params={"a": 2.0})["field"]
            # copyFromTarget
            host_out = out.to_numpy()
            assert np.allclose(host_out, 2.0 * host_field, rtol=1e-6)
            total = np.asarray(target_sum(out, cfg))
            print(f"layout={layout.name:9s} engine={engine:6s} "
                  f"sum={total.sum():+.3f}  OK")

        fused_chain_demo(field, layout)

    for engine in ("jnp", "pallas"):
        fused_stencil_reduction_demo(engine=engine)

    print("same source, every layout x engine: portable (paper C1/C2)")
    if args.trace:
        print(telemetry.format_report())
        print(f"chrome trace: {telemetry.export_chrome_trace(args.trace)}")


if __name__ == "__main__":
    main()
