"""Batched LM serving: prefill a batch of prompts, then decode with the
KV/state cache — the serve_step the decode_32k / long_500k dry-run cells
lower, on a CPU-sized config.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b --steps 24
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import init_params
from repro.train.serve_step import build_serve_step, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    if cfg.enc_dec:
        raise SystemExit("use train_lm for enc-dec; serving demo targets "
                         "decoder-only archs")
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"arch={cfg.name} (reduced), batch={args.batch}")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    jit_step = jax.jit(build_serve_step(cfg))
    t0 = time.perf_counter()
    out = generate(params, cfg, prompts, steps=args.steps,
                   s_max=args.prompt_len + args.steps + 8,
                   temperature=args.temperature,
                   rng=jax.random.PRNGKey(1), jit_step=jit_step)
    dt = time.perf_counter() - t0
    toks = np.asarray(out)
    total_new = args.batch * args.steps
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.0f} tok/s on CPU, includes compile)")
    for b in range(min(args.batch, 2)):
        print(f"  seq[{b}]: {toks[b].tolist()}")
    assert toks.shape == (args.batch, args.prompt_len + args.steps)
    print("OK")


if __name__ == "__main__":
    main()
