"""MILC Wilson-Dirac CG inversion: the paper's second application
(UEABS test case).

    PYTHONPATH=src python examples/milc_cg_solve.py [--lattice 8 8 8 8]
"""

import argparse
import time

from repro.apps.milc import MilcConfig, init_problem, solve
from repro.apps.milc.driver import residual_check


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lattice", type=int, nargs=4, default=[8, 8, 8, 8])
    ap.add_argument("--kappa", type=float, default=0.12)
    ap.add_argument("--tol", type=float, default=1e-10)
    ap.add_argument("--hot", type=float, default=0.6)
    args = ap.parse_args()

    cfg = MilcConfig(lattice=tuple(args.lattice), kappa=args.kappa,
                     tol=args.tol, hot=args.hot, max_iter=2000)
    print(f"lattice {cfg.lattice}, kappa={cfg.kappa}, hot={cfg.hot}")
    u, b = init_problem(cfg, seed=0)
    t0 = time.perf_counter()
    res = solve(cfg, u, b)
    dt = time.perf_counter() - t0
    iters = int(res.iterations)
    print(f"CG converged in {iters} iterations "
          f"({dt:.2f}s, {dt/max(iters,1)*1e3:.1f} ms/iter)")
    print(f"normal-equation residual: {float(res.residual):.3e}")
    rc = residual_check(cfg, u, b, res.x)
    print(f"independent |Mx-b|/|b| = {rc:.3e}")
    assert rc < 1e-3
    print("solution verified")


if __name__ == "__main__":
    main()
