"""End-to-end LM training driver: any --arch, fault-tolerant loop with
checkpoints (reduced config on CPU by default; the full configs are for
the TPU meshes via the dry-run/launcher).

    PYTHONPATH=src python examples/train_lm.py --arch granite-3-2b \
        --steps 50 --ckpt-dir /tmp/ckpt
Kill it mid-run and re-run the same command: it resumes from the last
valid checkpoint and reproduces the uninterrupted loss trajectory.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch
from repro.models import init_params
from repro.train.data import DataConfig, TokenStream
from repro.train.fault import LoopConfig, run_loop
from repro.train.optimizer import OptConfig, init_opt
from repro.train.train_step import TrainConfig, build_train_step, init_ef_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--opt", default="adamw",
                    choices=["adamw", "adamw8bit", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--full-config", action="store_true",
                    help="use the FULL architecture (TPU-scale; not for CPU)")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=not args.full_config)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    print(f"arch={cfg.name} params~{cfg.param_count():,}")

    tcfg = TrainConfig(
        opt=OptConfig(kind=args.opt, lr=args.lr),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
    )
    step = jax.jit(build_train_step(cfg, tcfg))
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = {
        "params": params,
        "opt": init_opt(params, tcfg.opt),
        "ef": init_ef_state(params) if args.grad_compression else None,
    }
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=0))

    def make_batch(tokens, labels):
        b = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if cfg.family == "vlm":
            b["image_embeds"] = jnp.zeros((args.batch, 4, cfg.d_model),
                                          jnp.float32)
            b["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None],
                (3, args.batch, args.seq)).astype(jnp.int32)
        if cfg.enc_dec:
            b["frames"] = jnp.zeros((args.batch, args.seq, cfg.d_model),
                                    jnp.float32)
        return b

    def on_step(i, m):
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}  {m['step_time_s']*1e3:.0f} ms")

    run_loop(step, state, stream,
             LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every),
             make_batch=make_batch, on_step=on_step)
    print("done (checkpoints in", args.ckpt_dir + ")")


if __name__ == "__main__":
    main()
