"""The paper's two applications, rebuilt on targetDP-JAX: Ludwig (lattice
Boltzmann + liquid crystal) and MILC (Wilson-Dirac CG)."""
