"""Stencil stages of the Ludwig timestep ("Order Parameter Gradients",
stress divergence, velocity gradients, "Advection" fluxes).

Central second-order differences, matching Ludwig's default finite
differences.  Two forms per op: periodic (rolls, single shard) and halo'd
windows (multi-shard, halos filled by Domain.exchange).  These are jnp-
engine stencils; their bandwidth characteristics are what the paper's
Fig. 4 measures for the corresponding kernels.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import stencil

_SITE_DIMS3 = (1, 2, 3)


def _sh(x, disp):
    """shift_periodic shorthand: result(r) = x(r - disp)."""
    return stencil.shift_periodic(x, disp)


def _e(a: int, s: int):
    d = [0, 0, 0]
    d[a] = s
    return d


def grad_central(x_nd: jnp.ndarray) -> jnp.ndarray:
    """(n, X, Y, Z) -> (3*n, X, Y, Z): [d/dx (n), d/dy (n), d/dz (n)].

    d_a f(r) = (f(r + e_a) - f(r - e_a)) / 2 ; f(r + e_a) = _sh(x, -e_a).
    """
    outs = []
    for a in range(3):
        outs.append(0.5 * (_sh(x_nd, _e(a, -1)) - _sh(x_nd, _e(a, 1))))
    return jnp.concatenate(outs, axis=0)


def laplacian(x_nd: jnp.ndarray) -> jnp.ndarray:
    """Standard 7-point Laplacian, (n, X, Y, Z) -> (n, X, Y, Z)."""
    acc = -6.0 * x_nd
    for a in range(3):
        acc = acc + _sh(x_nd, _e(a, 1)) + _sh(x_nd, _e(a, -1))
    return acc


def divergence(t9_nd: jnp.ndarray) -> jnp.ndarray:
    """Force from stress: (9, X, Y, Z) row-major sigma_ab -> F_a = d_b sigma_ab."""
    outs = []
    for a in range(3):
        acc = 0.0
        for b in range(3):
            s = t9_nd[a * 3 + b : a * 3 + b + 1]
            acc = acc + 0.5 * (_sh(s, _e(b, -1)) - _sh(s, _e(b, 1)))
        outs.append(acc[0])
    return jnp.stack(outs)


def advective_divergence(q_nd: jnp.ndarray, u_nd: jnp.ndarray) -> jnp.ndarray:
    """Ludwig "Advection": finite-volume upwind flux divergence of Q.

    Face flux at (r-1/2 -> r) in dim a uses the upwind Q per the face
    velocity (average of adjacent u).  Returns div(u Q), (5, X, Y, Z).
    """
    out = 0.0
    for a in range(3):
        u_a = u_nd[a : a + 1]
        u_face_lo = 0.5 * (u_a + _sh(u_a, _e(a, 1)))      # face (r-1/2)
        q_up_lo = jnp.where(u_face_lo > 0, _sh(q_nd, _e(a, 1)), q_nd)
        flux_lo = u_face_lo * q_up_lo
        flux_hi = _sh(flux_lo, _e(a, -1))                  # face (r+1/2)
        out = out + (flux_hi - flux_lo)
    return out


# -- halo'd-window variants (inside shard_map; width-2 halos for fluxes) -----

def grad_central_halo(x_halo: jnp.ndarray, width: int) -> jnp.ndarray:
    w = width
    outs = []
    for a in range(3):
        outs.append(
            0.5
            * (
                stencil.shifted_window(x_halo, _e(a, -1), w, _SITE_DIMS3)
                - stencil.shifted_window(x_halo, _e(a, 1), w, _SITE_DIMS3)
            )
        )
    return jnp.concatenate(outs, axis=0)


def laplacian_halo(x_halo: jnp.ndarray, width: int) -> jnp.ndarray:
    w = width
    acc = -6.0 * stencil.shifted_window(x_halo, (0, 0, 0), w, _SITE_DIMS3)
    for a in range(3):
        acc = (
            acc
            + stencil.shifted_window(x_halo, _e(a, 1), w, _SITE_DIMS3)
            + stencil.shifted_window(x_halo, _e(a, -1), w, _SITE_DIMS3)
        )
    return acc
