from .driver import LudwigConfig, LudwigState, init_state, step, step_timed  # noqa: F401
