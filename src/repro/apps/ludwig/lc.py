"""Liquid-crystal (Landau-de Gennes / Beris-Edwards) site-local physics.

The Q order parameter is a symmetric traceless 3x3 tensor stored as a
5-component Field (XX, XY, XZ, YY, YZ; ZZ = -XX-YY).  All functions here
are site-local *chunk* bodies on canonical (ncomp, VVL) arrays: the same
source is traced by the jnp engine and inside pallas kernels (no
array-valued constants — 3x3 algebra is unrolled over Python-int indices,
which is also how Ludwig's C kernels are written).

Physics (one-constant approximation, Ludwig defaults):
  free energy  F = A0/2 (1 - g/3) trQ^2 - A0 g/3 trQ^3 + A0 g/4 (trQ^2)^2
               + kappa/2 (grad Q)^2
  molecular field  H = -A0(1-g/3) Q + A0 g [Q^2 - I trQ^2/3] - A0 g Q trQ^2
                   + kappa lap Q
  Beris-Edwards    dQ/dt + u.grad Q - S(W, Q) = Gamma H
  S(W,Q) = (xi D + Om)(Q + I/3) + (Q + I/3)(xi D - Om) - 2 xi (Q+I/3) tr(QW)
  stress  sigma = -P0 I - xi H(Q+I/3) - xi (Q+I/3)H + 2 xi (Q+I/3) tr(QH)
                + Q H - H Q - kappa (grad_a Q)(grad_b Q)
  force on fluid  F_a = d_b sigma_ab   (the "Chemical Stress" divergence)
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

NQCOMP = 5
_IDX5 = [(0, 0), (0, 1), (0, 2), (1, 1), (1, 2)]


# -- 3x3 algebra on nested Python lists of (VVL,) arrays ---------------------

def q5_to_mat(q) -> List[List[jnp.ndarray]]:
    q0, q1, q2, q3, q4 = (q[i] for i in range(5))
    qzz = -q0 - q3
    return [[q0, q1, q2], [q1, q3, q4], [q2, q4, qzz]]


def mat_to_q5(m) -> jnp.ndarray:
    return jnp.stack([m[a][b] for (a, b) in _IDX5])


def mat_mul(a, b):
    return [
        [sum(a[i][k] * b[k][j] for k in range(3)) for j in range(3)]
        for i in range(3)
    ]


def mat_add(a, b):
    return [[a[i][j] + b[i][j] for j in range(3)] for i in range(3)]


def mat_sub(a, b):
    return [[a[i][j] - b[i][j] for j in range(3)] for i in range(3)]


def mat_scale(a, s):
    return [[a[i][j] * s for j in range(3)] for i in range(3)]


def mat_trace(a):
    return a[0][0] + a[1][1] + a[2][2]


def mat_transpose(a):
    return [[a[j][i] for j in range(3)] for i in range(3)]


def mat_add_diag(a, s):
    """a + s * I (s scalar or (VVL,) array)."""
    out = [[a[i][j] for j in range(3)] for i in range(3)]
    for i in range(3):
        out[i][i] = out[i][i] + s
    return out


def traceless_sym(m):
    """Project to symmetric traceless (numerical hygiene after updates)."""
    sym = [[0.5 * (m[i][j] + m[j][i]) for j in range(3)] for i in range(3)]
    tr3 = mat_trace(sym) / 3.0
    return mat_add_diag(sym, -tr3)


# -- site-local physics chunks ------------------------------------------------

def molecular_field_chunk(q5, lapq5, *, a0: float, gamma: float, kappa: float):
    """H = bulk(Q) + kappa lap Q.  q5/lapq5: (5, VVL) -> (5, VVL)."""
    Q = q5_to_mat(q5)
    QQ = mat_mul(Q, Q)
    trQ2 = mat_trace(QQ)
    # A0 g [Q^2 - I trQ^2/3]
    bulk2 = mat_add_diag(QQ, -trQ2 / 3.0)
    H = mat_add(
        mat_scale(Q, -a0 * (1.0 - gamma / 3.0)),
        mat_scale(bulk2, a0 * gamma),
    )
    H = mat_add(H, mat_scale(Q, -a0 * gamma * trQ2))
    Hel = q5_to_mat(lapq5)
    H = mat_add(H, mat_scale(Hel, kappa))
    return mat_to_q5(traceless_sym(H))


def free_energy_density_chunk(q5, dq15, *, a0: float, gamma: float, kappa: float):
    """Landau-de Gennes free-energy density (1, VVL) — used as the scalar
    diagnostic reduced with target_sum (paper's reduction API)."""
    Q = q5_to_mat(q5)
    QQ = mat_mul(Q, Q)
    trQ2 = mat_trace(QQ)
    trQ3 = mat_trace(mat_mul(QQ, Q))
    bulk = (
        0.5 * a0 * (1.0 - gamma / 3.0) * trQ2
        - (a0 * gamma / 3.0) * trQ3
        + 0.25 * a0 * gamma * trQ2 * trQ2
    )
    # elastic: kappa/2 sum_a sum_ij (d_a Q_ij)^2; dq15 is (3*5, VVL), but the
    # 5-component gradient double counts off-diagonals and misses ZZ — expand.
    el = 0.0
    for a in range(3):
        dQ = q5_to_mat(dq15[a * 5 : (a + 1) * 5])
        for i in range(3):
            for j in range(3):
                el = el + dQ[i][j] * dQ[i][j]
    return (bulk + 0.5 * kappa * el)[None, :]


def stress_chunk(q5, h5, dq15, *, kappa: float, xi: float, p0: float = 0.0):
    """Chemical stress sigma_ab (9, VVL), row-major ab.  dq15 = d_a Q (3*5)."""
    Q = q5_to_mat(q5)
    H = q5_to_mat(h5)
    Qi = mat_add_diag(Q, 1.0 / 3.0)  # Q + I/3
    trQH = mat_trace(mat_mul(Q, H))

    s = mat_scale(mat_add(mat_mul(H, Qi), mat_mul(Qi, H)), -xi)
    s = mat_add(s, mat_scale(Qi, 2.0 * xi * trQH))
    s = mat_add(s, mat_sub(mat_mul(Q, H), mat_mul(H, Q)))  # antisymmetric part

    # elastic distortion stress: - kappa d_a Q_gd d_b Q_gd
    dQ = [q5_to_mat(dq15[a * 5 : (a + 1) * 5]) for a in range(3)]
    for a in range(3):
        for b in range(3):
            grad2 = 0.0
            for g in range(3):
                for d in range(3):
                    grad2 = grad2 + dQ[a][g][d] * dQ[b][g][d]
            s[a][b] = s[a][b] - kappa * grad2
    s = mat_add_diag(s, -p0)
    return jnp.stack([s[a][b] for a in range(3) for b in range(3)])


def beris_edwards_rhs_chunk(q5, h5, w9, *, gamma_rot: float, xi: float):
    """dQ/dt (minus advection) = Gamma H + S(W, Q).  w9 = d_b u_a row-major
    (a, b) -> W[a][b] = du_a/dx_b."""
    Q = q5_to_mat(q5)
    H = q5_to_mat(h5)
    W = [[w9[a * 3 + b] for b in range(3)] for a in range(3)]
    Wt = mat_transpose(W)
    D = mat_scale(mat_add(W, Wt), 0.5)
    Om = mat_scale(mat_sub(W, Wt), 0.5)
    Qi = mat_add_diag(Q, 1.0 / 3.0)

    t1 = mat_mul(mat_add(mat_scale(D, xi), Om), Qi)
    t2 = mat_mul(Qi, mat_sub(mat_scale(D, xi), Om))
    trQW = mat_trace(mat_mul(Q, W))
    t3 = mat_scale(Qi, -2.0 * xi * trQW)
    S = mat_add(mat_add(t1, t2), t3)

    rhs = mat_add(mat_scale(H, gamma_rot), S)
    return mat_to_q5(traceless_sym(rhs))


def q_update_chunk(q5, rhs5, advflux5, *, dt: float):
    """LC Update: Q <- Q + dt (rhs - div adv_flux); advflux5 precomputed
    divergence (5, VVL)."""
    q0 = q5 + dt * (rhs5 - advflux5)
    return mat_to_q5(traceless_sym(q5_to_mat(q0)))
