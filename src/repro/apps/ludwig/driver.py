"""Ludwig liquid-crystal timestep driver (single-shard and sharded).

One timestep reproduces the paper's kernel decomposition (§2.1.1):

  Order Parameter Gradients   stencil   grad Q, lap Q
  (molecular field)           local     H(Q, lap Q)
  Chemical Stress             local     sigma(Q, H, grad Q)
  (force)                     stencil   F = div sigma
  Collision                   local     BGK + Guo forcing   [fused LB step]
  Propagation                 stencil   streaming           [fused LB step]
  Advection (+ Boundaries)    stencil   upwind div(u Q)
  LC Update                   local     Beris-Edwards       [core.launch]

Site-local stages run through core.target.launch so the engine (jnp vs
pallas) and the data layout are pure configuration — the paper's central
claim, which tests/test_ludwig.py asserts by running both engines step-
for-step.  Adjacent site-local stages are *fused* via core.fuse.LaunchGraph
(molecular field + stress; BE rhs + Q update), and the whole LB half of the
step — moments, BGK collision and the streaming *stencil* — is one halo'd
launch graph (`lb_step_graph`): collision is recomputed on the halo ring so
propagation gathers post-collision neighbours from VMEM, and the
post-collision distributions never round-trip through HBM.

The sharded form (`make_sharded_step`) wraps the same stage functions in
jax.shard_map on a Domain: per step it halo-exchanges Q (width 2), the
pre-collision distributions (width 1) and the velocity field (width 1),
then applies the identical periodic-roll stencils on the halo'd local
arrays and crops — the dimension-by-dimension exchange makes the wrapped
reads land in valid halo, the standard MPI decomposition of both papers'
codes.  The fused LB half-step can run under three halo schedules:
``halo="pre"`` (exchange, then one launch — the legacy behavior, default),
``halo="overlap"`` (core.overlap: the exchange is started, the interior
sub-launch runs on locally-owned data with no dependence on it, and thin
boundary slabs run once the halos land — comms hidden behind compute), or
``halo=None`` (the planning layer — ``plan_policy``/tuned table — picks).
`run_steps` drives the step through core.schedule.StepPipeline (donated
double-buffers, pipelined dispatch) for multi-timestep runs.

Shard size is bounded by *tile* size, not lattice size: when a shard's
whole-staged footprint exceeds the VMEM budget (``TargetConfig.vmem_bytes``
or ``$TARGETDP_VMEM_BYTES``), the planning layer tiles the y/z axes of the
fused LB launch (``LoweringPlan.by``/``bz``, double-buffered tile DMA on a
real TPU) — production-size local volumes run with no driver changes here,
and the overlap scheduler's sub-launches inherit the tiles.

Layouts: every Field a step builds carries ``cfg.layout`` (the paper's
per-architecture layout switch), including the halo'd inputs of the fused
LB launch — so a tuned table whose winner is the native-AoSoA stencil
lowering (``LoweringPlan.view == "block"``, core.plan) applies to the
hottest launch of the step with zero driver changes under
``cfg.target.plan_policy="tuned"``.  Every temporary the step builds —
interior stage outputs and halo'd local Fields alike — goes through the
``tileable_layout`` fallback: the lattice keeps ``cfg.layout`` wherever
the site count is SAL-tileable and degrades to SOA otherwise (in practice
only padded local lattices hit the fallback; interior lattices that are
not tileable already fail at ``init_state``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DtypePolicy, Field, LaunchGraph, Layout, SOA, TargetConfig, compat,
    launch, target_sum, tileable_layout,
)
from repro.kernels.lb_collision import ref as lbref
from repro.kernels.lb_collision.ops import collide_kernel
from repro.kernels.lb_propagation import ops as prop_ops
from repro.lattice import Domain
from . import gradients as gr
from . import lc

SITE_DIMS = (1, 2, 3)


@dataclasses.dataclass(frozen=True)
class LudwigConfig:
    lattice: Tuple[int, int, int] = (16, 16, 16)
    tau: float = 0.8            # LB relaxation time; nu = cs2 (tau - 1/2)
    a0: float = 0.01            # Landau-de Gennes bulk scale
    gamma: float = 3.0          # effective temperature (>2.7: nematic)
    kappa: float = 0.01         # elastic constant (one-constant approx.)
    gamma_rot: float = 0.3     # rotational diffusion Gamma
    xi: float = 0.7             # flow-aligning parameter
    dt: float = 1.0
    layout: Layout = SOA
    target: TargetConfig = TargetConfig("jnp", vvl=128)
    # storage dtype for the fused LB half-step's launch ("" = full
    # precision): distributions stream through HBM in this dtype, compute
    # stays fp32 and reductions accumulate wide.  Validated against the
    # full-precision oracle in tests/test_dtype.py.
    storage: str = ""


def _lb_target(cfg: "LudwigConfig") -> TargetConfig:
    """The fused LB launch's config: ``cfg.target`` plus the storage-dtype
    policy when ``cfg.storage`` narrows it."""
    if not cfg.storage:
        return cfg.target
    return dataclasses.replace(
        cfg.target, dtypes=DtypePolicy(storage=cfg.storage,
                                       compute="float32",
                                       accumulate="float64"))


@dataclasses.dataclass
class LudwigState:
    dist: Field   # (19,) distributions
    q: Field      # (5,)  order parameter


jax.tree_util.register_pytree_node(
    LudwigState,
    lambda s: ((s.dist, s.q), None),
    lambda _, ch: LudwigState(dist=ch[0], q=ch[1]),
)


def init_state(cfg: LudwigConfig, seed: int = 0, q_amp: float = 1e-2) -> LudwigState:
    rng = np.random.default_rng(seed)
    nsites = int(np.prod(cfg.lattice))
    rho = jnp.ones((nsites,), jnp.float32)
    u = jnp.zeros((3, nsites), jnp.float32)
    f0 = lbref.equilibrium(rho, u)
    dist = Field.from_canonical("dist", f0, cfg.lattice, cfg.layout)
    q0 = q_amp * rng.normal(size=(5, nsites)).astype(np.float32)
    q = Field.from_canonical("q", jnp.asarray(q0), cfg.lattice, cfg.layout)
    return LudwigState(dist=dist, q=q)


# -- site-local kernel bodies wrapped for core.launch -------------------------

def _mol_field_body(v, *, a0, gamma, kappa):
    return {"h": lc.molecular_field_chunk(v["q"], v["lapq"], a0=a0, gamma=gamma, kappa=kappa)}


def _stress_body(v, *, kappa, xi):
    return {"sigma": lc.stress_chunk(v["q"], v["h"], v["dq"], kappa=kappa, xi=xi)}


def _be_rhs_body(v, *, gamma_rot, xi):
    return {"rhs": lc.beris_edwards_rhs_chunk(v["q"], v["h"], v["w"], gamma_rot=gamma_rot, xi=xi)}


def _q_update_body(v, *, dt):
    return {"q": lc.q_update_chunk(v["q"], v["rhs"], v["adv"], dt=dt)}


def _moments_body(v):
    rho, u = lbref.moments(v["dist"])
    # half-force velocity correction (consistent with Guo forcing)
    u = u + 0.5 * v["force"] / rho[None, :]
    return {"rho": rho[None, :], "u": u}


def _fed_body(v, *, a0, gamma, kappa):
    return {"fed": lc.free_energy_density_chunk(v["q"], v["dq"], a0=a0, gamma=gamma, kappa=kappa)}


def _mkfield(name: str, arr_nd: jnp.ndarray, cfg: LudwigConfig) -> Field:
    lat = tuple(arr_nd.shape[1:])
    return Field.from_canonical(
        name, arr_nd, lat, tileable_layout(cfg.layout, lat))


# -- stage functions (single-shard periodic) ----------------------------------

def stage_gradients(q_nd: jnp.ndarray):
    """Order Parameter Gradients."""
    return gr.grad_central(q_nd), gr.laplacian(q_nd)


# stage stanzas shared by every graph builder below — one definition per
# kernel so the production step and the benchmark/test chains cannot drift
def _add_mol_field(g: LaunchGraph, cfg: LudwigConfig) -> LaunchGraph:
    return g.add(_mol_field_body, {"q": "q", "lapq": "lapq"}, {"h": 5},
                 params=dict(a0=cfg.a0, gamma=cfg.gamma, kappa=cfg.kappa))


def _add_stress(g: LaunchGraph, cfg: LudwigConfig) -> LaunchGraph:
    return g.add(_stress_body, {"q": "q", "h": "h", "dq": "dq"}, {"sigma": 9},
                 params=dict(kappa=cfg.kappa, xi=cfg.xi))


def _add_be_rhs(g: LaunchGraph, cfg: LudwigConfig) -> LaunchGraph:
    return g.add(_be_rhs_body, {"q": "q", "h": "h", "w": "w"}, {"rhs": 5},
                 params=dict(gamma_rot=cfg.gamma_rot, xi=cfg.xi))


def _add_q_update(g: LaunchGraph, cfg: LudwigConfig) -> LaunchGraph:
    return g.add(_q_update_body, {"q": "q", "rhs": "rhs", "adv": "adv"},
                 {"q": 5}, rename={"q": "q_new"}, params=dict(dt=cfg.dt))


def chem_stress_graph(cfg: LudwigConfig) -> LaunchGraph:
    """molecular field -> stress as one fused chain (H also materialized:
    the BE update needs it later in the step)."""
    return _add_stress(_add_mol_field(LaunchGraph("ludwig_chem_stress"), cfg), cfg)


def lc_update_graph(cfg: LudwigConfig) -> LaunchGraph:
    """BE rhs -> Q update as one fused chain; rhs stays in VMEM."""
    return _add_q_update(_add_be_rhs(LaunchGraph("ludwig_lc_update"), cfg), cfg)


def lc_chain_graph(cfg: LudwigConfig) -> LaunchGraph:
    """The 3-kernel LC chain (molecular field -> BE rhs -> Q update) fused
    into one launch — the benchmarks' fused-vs-unfused exhibit; h and rhs
    never touch HBM."""
    g = _add_mol_field(LaunchGraph("ludwig_lc_chain"), cfg)
    return _add_q_update(_add_be_rhs(g, cfg), cfg)


def lb_step_graph(cfg: LudwigConfig) -> LaunchGraph:
    """The whole LB half of a timestep — moments, BGK collision and the
    streaming stencil — as ONE halo'd launch (one pallas_call): dist and
    force stream from HBM once, collision is recomputed on the width-1 halo
    ring, and propagation gathers the post-collision neighbours from the
    VMEM-resident block, so dist1 never materializes in HBM."""
    return (
        LaunchGraph("ludwig_lb_step")
        .add(_moments_body, {"dist": "dist", "force": "force"},
             {"rho": 1, "u": 3})
        .add(collide_kernel, {"dist": "dist", "force": "force"}, {"dist": 19},
             rename={"dist": "dist1"}, params=dict(tau=cfg.tau))
        .add_stencil(prop_ops.propagate_body, {"dist": "dist1"}, {"dist": 19},
                     width=1, rename={"dist": "dist2"})
    )


def stage_chemical_stress(state_q: Field, dq_nd, lapq_nd, cfg: LudwigConfig):
    """molecular field + stress (one fused launch) + force divergence."""
    out = chem_stress_graph(cfg).bind(
        config=cfg.target, outputs=("h", "sigma"),
    )({"q": state_q, "lapq": _mkfield("lapq", lapq_nd, cfg),
       "dq": _mkfield("dq", dq_nd, cfg)})
    force_nd = gr.divergence(out["sigma"].canonical_nd())
    return out["h"], force_nd


def stage_advection(q_nd, u_nd):
    """Advection (+ periodic boundaries: no correction term)."""
    return gr.advective_divergence(q_nd, u_nd)


def stage_lc_update(state_q: Field, h: Field, w_nd, adv_nd, cfg: LudwigConfig) -> Field:
    q_new = lc_update_graph(cfg).bind(
        config=cfg.target, outputs=("q_new",),
    )({"q": state_q, "h": h, "w": _mkfield("w", w_nd, cfg),
       "adv": _mkfield("adv", adv_nd, cfg)})["q_new"]
    # keep the Field name stable across steps (it is pytree aux data)
    return dataclasses.replace(q_new, name=state_q.name)


def _w_tensor(u_nd: jnp.ndarray) -> jnp.ndarray:
    """W_ab = d u_a / d x_b as (9,) row-major from grad_central layout."""
    g = gr.grad_central(u_nd)  # [d/dx u(3), d/dy u(3), d/dz u(3)] => g[b*3+a]
    return jnp.stack([g[b * 3 + a] for a in range(3) for b in range(3)])


def step(state: LudwigState, cfg: LudwigConfig) -> LudwigState:
    """One full LC-LB timestep (single shard, periodic)."""
    q_nd = state.q.canonical_nd()
    dq_nd, lapq_nd = stage_gradients(q_nd)
    h, force_nd = stage_chemical_stress(state.q, dq_nd, lapq_nd, cfg)
    force = _mkfield("force", force_nd, cfg)

    # moments + collision + streaming fused: one halo'd launch, dist and
    # force stream from HBM once, post-collision dist never touches HBM.
    # Under cfg.storage the launch reads/writes storage-dtype bytes; the
    # carried state is cast back so the step's signature stays fixed
    # (quantization to storage precision already happened in the write).
    lb = lb_step_graph(cfg).bind(
        config=_lb_target(cfg), outputs=("dist2", "u"),
    )({"dist": state.dist, "force": force})
    dist2 = dataclasses.replace(
        lb["dist2"].with_data(
            lb["dist2"].data.astype(state.dist.data.dtype)),
        name=state.dist.name)

    u = lb["u"]
    u_nd = u.canonical_nd().astype(q_nd.dtype)
    w_nd = _w_tensor(u_nd)
    adv_nd = stage_advection(q_nd, u_nd)

    q_new = stage_lc_update(state.q, h, w_nd, adv_nd, cfg)
    return LudwigState(dist=dist2, q=q_new)


def step_timed(state: LudwigState, cfg: LudwigConfig) -> Tuple[LudwigState, Dict[str, float]]:
    """Unjitted per-kernel wall timings (benchmarks/fig3)."""
    t: Dict[str, float] = {}

    def timed(name, fn, *a):
        t0 = time.perf_counter()
        out = fn(*a)
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        t[name] = time.perf_counter() - t0
        return out

    q_nd = state.q.canonical_nd()
    dq_nd, lapq_nd = timed("order_parameter_gradients", stage_gradients, q_nd)
    h, force_nd = timed(
        "chemical_stress", stage_chemical_stress, state.q, dq_nd, lapq_nd, cfg
    )
    force = _mkfield("force", force_nd, cfg)
    # time the same fused LB launch production step() runs; the row name
    # matches the LUDWIG_KERNELS["lb_step"] traffic model (dist+force read
    # once, dist''+u written; dist' and rho never touch HBM)
    lb_bound = lb_step_graph(cfg).bind(config=_lb_target(cfg),
                                       outputs=("dist2", "u"))
    lb = timed("lb_step", lambda: lb_bound({"dist": state.dist,
                                            "force": force}))
    dist2 = dataclasses.replace(
        lb["dist2"].with_data(
            lb["dist2"].data.astype(state.dist.data.dtype)),
        name=state.dist.name)
    u_nd = lb["u"].canonical_nd().astype(q_nd.dtype)
    w_nd = _w_tensor(u_nd)
    adv_nd = timed("advection", stage_advection, q_nd, u_nd)
    q_new = timed("lc_update", stage_lc_update, state.q, h, w_nd, adv_nd, cfg)
    return LudwigState(dist=dist2, q=q_new), t


# -- plan autotuning -----------------------------------------------------------

def tune_step_graphs(cfg: LudwigConfig, state: LudwigState, **tune_kw):
    """Autotune every launch graph a timestep runs (chem-stress chain, the
    fused LB half-step, the LC update chain) and persist the winners, so a
    subsequent run with ``cfg.target.plan_policy="tuned"`` — the same driver
    code, zero application changes — picks the swept plans up from the
    table (paper §3.2.2's per-architecture tuning as a layer, not an edit).

    Returns {graph name: (plan, info)} from core.tune.autotune_graph; a
    warm table short-circuits each sweep (info["cached"])."""
    from repro.core import tune

    q_nd = state.q.canonical_nd()
    dq_nd, lapq_nd = stage_gradients(q_nd)
    results = {}
    g = chem_stress_graph(cfg)
    results[g.name] = tune.autotune_graph(
        g,
        {"q": state.q, "lapq": _mkfield("lapq", lapq_nd, cfg),
         "dq": _mkfield("dq", dq_nd, cfg)},
        config=cfg.target, outputs=("h", "sigma"), **tune_kw)
    h, force_nd = stage_chemical_stress(state.q, dq_nd, lapq_nd, cfg)
    force = _mkfield("force", force_nd, cfg)
    g = lb_step_graph(cfg)
    results[g.name] = tune.autotune_graph(
        g, {"dist": state.dist, "force": force},
        config=cfg.target, outputs=("dist2", "u"), **tune_kw)
    lb = g.launch({"dist": state.dist, "force": force},
                  config=cfg.target, outputs=("dist2", "u"))
    u_nd = lb["u"].canonical_nd()
    w_nd = _w_tensor(u_nd)
    adv_nd = stage_advection(q_nd, u_nd)
    g = lc_update_graph(cfg)
    results[g.name] = tune.autotune_graph(
        g,
        {"q": state.q, "h": h, "w": _mkfield("w", w_nd, cfg),
         "adv": _mkfield("adv", adv_nd, cfg)},
        config=cfg.target, outputs=("q_new",), **tune_kw)
    return results


# -- diagnostics ---------------------------------------------------------------

def diagnostics(state: LudwigState, cfg: LudwigConfig) -> Dict[str, jnp.ndarray]:
    """Total mass, momentum, free energy (targetDP reduction API)."""
    mass = target_sum(state.dist, cfg.target).sum()
    q_nd = state.q.canonical_nd()
    dq_nd = gr.grad_central(q_nd)
    dq = _mkfield("dq", dq_nd, cfg)
    fed = launch(
        _fed_body, {"q": state.q, "dq": dq}, {"fed": 1},
        config=cfg.target,
        params=dict(a0=cfg.a0, gamma=cfg.gamma, kappa=cfg.kappa),
    )["fed"]
    free_energy = target_sum(fed, cfg.target)[0]
    rho, u = lbref.moments(state.dist.canonical())
    mom = jnp.sum(rho[None] * u, axis=1)
    return {"mass": mass, "free_energy": free_energy, "momentum": mom}


# -- sharded driver ------------------------------------------------------------

def make_sharded_step(cfg: LudwigConfig, domain: Domain, halo: str = "pre"):
    """Build a jitted shard_map step over canonical-nd global arrays.

    Takes/returns (dist_nd (19, X, Y, Z), q_nd (5, X, Y, Z)) sharded per
    ``domain.spec()``.  Inside: halo exchanges + the identical periodic
    stencils applied to halo'd local arrays (wrap reads land in valid halo
    because exchanges are dimension-ordered), then crops.

    ``halo`` schedules the fused LB half-step's exchange: "pre" (exchange
    then launch, the legacy schedule), "overlap" (interior/boundary split
    launches via core.overlap — the dist/force exchange overlaps the
    interior collision+streaming compute), or None (planned: the tuned
    table may pick overlap per lattice/backend).  All three are
    bit-identical on the jnp engine (asserted in tests/test_distributed).
    """
    if halo not in (None, "pre", "overlap"):
        raise ValueError(f"halo must be None, 'pre' or 'overlap', got {halo!r}")
    mesh = domain.mesh
    spec = domain.spec()
    WQ = 2  # q halo: grad/lap (1) + stress divergence (1)
    dec = domain.decomposed

    def pad(x, w):
        # wrap-pad ALL site dims: for non-decomposed dims the wrap IS the
        # (local-)periodic halo; for decomposed dims exchange overwrites it.
        pads = [(0, 0)] + [(w, w)] * 3
        return jnp.pad(x, pads, mode="wrap")

    def crop(x, w):
        idx = [slice(None)] + [slice(w, s - w) for s in x.shape[1:]]
        return x[tuple(idx)]

    def exchange_w(x, w):
        from repro.core import halo as _halo
        return _halo.exchange(x, dec, width=w)

    tgt = cfg.target
    # bound launches: graph + config + outputs (+ halo) fixed once, reused
    # every sharded call — launch(...) kwargs on a raw graph still work
    chem_step = chem_stress_graph(cfg).bind(config=tgt,
                                            outputs=("h", "sigma"))
    lb_pre_step = lb_step_graph(cfg).bind(config=tgt,
                                          outputs=("dist2", "u"), halo="pre")
    lc_step = lc_update_graph(cfg).bind(config=tgt, outputs=("q_new",))

    def local_step(dist_nd, q_nd):
        # ---- Q stencils on width-2 halo
        qh = exchange_w(pad(q_nd, WQ), WQ)
        dq_h = gr.grad_central(qh)
        lapq_h = gr.laplacian(qh)
        # halo'd local Fields keep cfg.layout whenever the padded lattice
        # stays SAL-tileable (so tuned native-AoSoA plans apply sharded too)
        mk = lambda name, arr: _mkfield(name, arr, cfg)
        qF = mk("q", qh)
        cs = chem_step(
            {"q": qF, "lapq": mk("lapq", lapq_h), "dq": mk("dq", dq_h)})
        h_F = cs["h"]
        force_h = gr.divergence(cs["sigma"].canonical_nd())
        force_nd = crop(force_h, WQ)  # interior: ring-1 div reads ring-2
        # gradients, which wrap locally — so exchange the true force halo

        # ---- fused LB half-step on pre-exchanged halos: the
        # *pre-collision* dist (and the force) is exchanged instead of the
        # seed's post-collision dist, then moments + collision + streaming
        # run as ONE launch — collision recomputed on the neighbour ring
        # from true neighbour dist/force values.  halo="pre" exchanges
        # before the launch; halo="overlap"/None routes through the
        # overlap scheduler (interior sub-launch independent of the
        # exchange, boundary slabs after it — core.overlap).
        if halo == "pre":
            d_h = exchange_w(pad(dist_nd, 1), 1)
            f_h = exchange_w(pad(force_nd, 1), 1)
            lb = lb_pre_step(
                {"dist": mk("dist", d_h), "force": mk("force", f_h)})
        else:
            from repro.core import overlap_launch
            lb = overlap_launch(
                lb_step_graph(cfg),
                {"dist": mk("dist", pad(dist_nd, 1)),
                 "force": mk("force", pad(force_nd, 1))},
                decomposed=dec, config=tgt, outputs=("dist2", "u"),
                halo=halo,
            )
        dist2_nd = lb["dist2"].canonical_nd()

        # ---- hydrodynamics from the pre-collision distributions
        u_nd = lb["u"].canonical_nd()
        uh = exchange_w(pad(u_nd, 1), 1)
        w_h = _w_tensor(uh)
        w_nd = crop(w_h, 1)
        # advection: q +-1 from the wide-halo q, u faces from u halo
        qh1 = crop(qh, WQ - 1)
        adv_h = gr.advective_divergence(qh1, uh)
        adv_nd = crop(adv_h, 1)

        # ---- Beris-Edwards update on interior (fused rhs -> update)
        qiF = mk("qi", q_nd)
        q_new = lc_step(
            {"q": qiF, "h": mk("h", crop(h_F.canonical_nd(), WQ)),
             "w": mk("w", w_nd), "adv": mk("adv", adv_nd)})["q_new"]
        return dist2_nd, q_new.canonical_nd()

    sharded = compat.shard_map(
        local_step, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec)
    )
    return jax.jit(sharded)


def run_steps(
    cfg: LudwigConfig,
    domain: Domain,
    dist_nd: jax.Array,
    q_nd: jax.Array,
    steps: int,
    *,
    halo: str = "pre",
    donate=None,
    block: bool = True,
):
    """Multi-timestep sharded pipeline: one jitted sharded step driven by
    core.schedule.StepPipeline — (dist, q) ping-pong between two donated
    device buffers, dispatch stays ahead of the device, and the per-step
    halo exchange runs under the chosen ``halo`` schedule ("overlap" hides
    it behind the interior compute).  Returns (dist_nd, q_nd) after
    ``steps`` steps.

    With donation enabled (non-CPU backends by default) the caller's input
    arrays are consumed — keep a copy if they are needed again.
    """
    from repro.core.schedule import StepPipeline

    pipe = StepPipeline(make_sharded_step(cfg, domain, halo=halo),
                        donate=donate)
    return pipe.run((dist_nd, q_nd), steps, block=block)
