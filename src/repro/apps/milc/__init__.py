from .driver import MilcConfig, init_problem, solve, solve_sharded  # noqa: F401
