"""MILC field utilities: random SU(3) gauge configurations and spinors.

Storage conventions follow repro.kernels.wilson_dslash.ref: spinors are
24-component Fields ((spin*3+color)*2 + reim), gauge links 72-component
(((mu*3+a)*3+b)*2 + reim), over a 4-D lattice.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def random_su3_gauge(lattice: Tuple[int, int, int, int], seed: int = 0,
                     hot: float = 1.0) -> np.ndarray:
    """(72, X, Y, Z, T) float32: independent SU(3) per site/direction.

    hot=1: fully random ("hot start"); hot=0: unit gauge ("cold start");
    intermediate values interpolate by scaling the anti-hermitian generator.
    """
    rng = np.random.default_rng(seed)
    vol = int(np.prod(lattice))
    # random anti-hermitian traceless generators -> expm -> SU(3)
    a = rng.normal(size=(4 * vol, 3, 3)) + 1j * rng.normal(size=(4 * vol, 3, 3))
    ah = 0.5 * (a - np.conj(np.transpose(a, (0, 2, 1))))
    tr = np.trace(ah, axis1=1, axis2=2) / 3.0
    ah -= tr[:, None, None] * np.eye(3)[None]
    # scale controls disorder
    ah *= hot
    # 3x3 expm via scaling-and-squaring on small matrices
    u = _expm3(ah)
    u = u.reshape((4,) + tuple(lattice) + (3, 3))
    out = np.empty((4, 3, 3, 2) + tuple(lattice), np.float32)
    um = np.moveaxis(u, (-2, -1), (1, 2))  # (4, 3, 3, X,Y,Z,T)
    out[:, :, :, 0] = um.real
    out[:, :, :, 1] = um.imag
    return out.reshape((72,) + tuple(lattice))


def _expm3(a: np.ndarray) -> np.ndarray:
    """expm for a batch of 3x3 matrices (scaling and squaring, Taylor 12)."""
    norm = np.abs(a).sum(axis=(1, 2)).max() + 1e-30
    s = max(0, int(np.ceil(np.log2(norm))) + 1)
    x = a / (2.0 ** s)
    out = np.broadcast_to(np.eye(3, dtype=a.dtype), a.shape).copy()
    term = out.copy()
    for k in range(1, 13):
        term = term @ x / k
        out = out + term
    for _ in range(s):
        out = out @ out
    return out


def random_spinor(lattice, seed: int = 1) -> np.ndarray:
    """(24, X, Y, Z, T) float32 gaussian source."""
    rng = np.random.default_rng(seed)
    return rng.normal(size=(24,) + tuple(lattice)).astype(np.float32)


def unitarity_violation(u72: np.ndarray) -> float:
    """max |U U^dag - I| over sites/directions (gauge sanity check)."""
    lat = u72.shape[1:]
    g = u72.reshape(4, 3, 3, 2, *lat)
    uc = g[:, :, :, 0] + 1j * g[:, :, :, 1]
    uc = np.moveaxis(uc, (1, 2), (-2, -1))  # (4, ..., 3, 3)
    prod = uc @ np.conj(np.swapaxes(uc, -1, -2))
    return float(np.abs(prod - np.eye(3)).max())
