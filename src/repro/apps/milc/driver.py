"""MILC Wilson-CG driver (single-shard and sharded).

Reproduces the UEABS test: invert the Wilson-Dirac operator on a random
SU(3) gauge background with CG on the normal equations.  The sharded form
domain-decomposes the 4-D lattice over mesh axes; each dslash exchanges
the spinor halo (ppermute), the gauge halo is exchanged once per solve —
exactly the MPI structure of the original (the "Shift" kernel is where
MPI lives, §2.1.2).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import Field, Layout, SOA, TargetConfig, compat
from repro.core import halo as halo_mod
from repro.kernels.wilson_dslash.ops import dslash_halo
from repro.lattice import Domain
from . import fields
from .cg import CGResult, cg, make_fused_normal, make_wilson_op


@dataclasses.dataclass(frozen=True)
class MilcConfig:
    lattice: Tuple[int, int, int, int] = (8, 8, 8, 8)
    kappa: float = 0.12
    tol: float = 1e-10
    max_iter: int = 1000
    hot: float = 0.6           # gauge disorder (1 = hot start)
    layout: Layout = SOA
    target: TargetConfig = TargetConfig("jnp", vvl=128)


def init_problem(cfg: MilcConfig, seed: int = 0):
    """Random SU(3) gauge Field (72,) + gaussian source Field (24,)."""
    u_np = fields.random_su3_gauge(cfg.lattice, seed=seed, hot=cfg.hot)
    assert fields.unitarity_violation(u_np) < 1e-5
    b_np = fields.random_spinor(cfg.lattice, seed=seed + 1)
    u = Field.from_numpy("u", u_np, cfg.lattice, cfg.layout)
    b = Field.from_numpy("b", b_np, cfg.lattice, cfg.layout)
    return u, b


def solve(cfg: MilcConfig, u: Field, b: Field) -> CGResult:
    """Single-shard CG solve of M x = b via the normal equations.

    The operator application runs through the fused dslash+axpy+dot graph
    (one pallas_call), the update chain through the fused axpy+residual-norm
    graph (one more): two launches per CG iteration."""
    apply_m, apply_mdag, apply_normal = make_wilson_op(u, cfg.kappa, cfg.target)
    rhs = apply_mdag(b)
    res = cg(apply_normal, rhs, config=cfg.target, tol=cfg.tol,
             max_iter=cfg.max_iter,
             apply_a_dot=make_fused_normal(u, cfg.kappa, cfg.target))
    return res


def tune_solve_graphs(cfg: MilcConfig, u: Field, b: Field, **tune_kw):
    """Autotune the two launch graphs a CG iteration runs — the fused
    normal-operator application (dslash+dslash+xpay/g5 + <p,Ap>) and the
    fused update chain (+ residual norm) — persisting the winners so a
    later ``cfg.target.plan_policy="tuned"`` solve loads them instead of
    re-sweeping.  Returns {graph name: (plan, info)}."""
    from repro.core import tune

    from .cg import cg_update_graph, wilson_normal_graph

    results = {}
    g = wilson_normal_graph(float(cfg.kappa))
    results[g.name] = tune.autotune_graph(
        g, {"p": b, "u": u}, config=cfg.target, outputs=("ap", "pap"),
        **tune_kw)
    g = cg_update_graph(b.ncomp)
    results[g.name] = tune.autotune_graph(
        g, {"x": b, "r": b, "p": b, "ap": b},
        scalars={"alpha": 0.3, "neg_alpha": -0.3},
        config=cfg.target, outputs=("x_new", "r_new", "rr"), **tune_kw)
    return results


def residual_check(cfg: MilcConfig, u: Field, b: Field, x: Field) -> float:
    """|M x - b| / |b| — independent verification of the solve."""
    apply_m, _, _ = make_wilson_op(u, cfg.kappa, cfg.target)
    mx = apply_m(x)
    num = jnp.linalg.norm(mx.canonical() - b.canonical())
    den = jnp.linalg.norm(b.canonical())
    return float(num / den)


# -- sharded solve ---------------------------------------------------------------

def make_domain(cfg: MilcConfig, mesh, dim_axes) -> Domain:
    return Domain(global_shape=cfg.lattice, mesh=mesh, dim_axes=dim_axes, halo=1)


def solve_sharded(cfg: MilcConfig, domain: Domain, u_nd: jax.Array, b_nd: jax.Array):
    """CG under shard_map.  u_nd (72, X,Y,Z,T) and b_nd (24, ...) are global
    canonical-nd arrays (sharded or to-be-sharded per domain.spec()).
    Returns (x_nd, iterations, residual)."""
    mesh = domain.mesh
    spec = domain.spec()
    dec = domain.decomposed
    axes = tuple(ax for _, ax, _ in dec)
    tgt = cfg.target

    def pad(x):
        # wrap-pad all site dims (local periodic); exchange overwrites the
        # decomposed dims' halos with true neighbour data.
        pads = [(0, 0)] + [(1, 1)] * (x.ndim - 1)
        return jnp.pad(x, pads, mode="wrap")

    def exchange(x):
        return halo_mod.exchange(x, dec, width=1)

    def local_solve(u_loc, b_loc):
        lat_loc = u_loc.shape[1:]
        u_h = exchange(pad(u_loc))  # gauge halo once per solve

        def dslash_fn(psi: Field) -> Field:
            psi_h = exchange(pad(psi.canonical_nd()))
            out = dslash_halo(psi_h, u_h, config=tgt, width=1)
            return psi.with_canonical(out.reshape(24, -1))

        bF = Field.from_canonical("b", b_loc, lat_loc, cfg.layout)
        uF = Field.from_canonical("u", u_loc, lat_loc, cfg.layout)
        apply_m, apply_mdag, apply_normal = make_wilson_op(
            uF, cfg.kappa, tgt, dslash_fn=dslash_fn
        )
        rhs = apply_mdag(b_loc_field := bF)
        res = cg(apply_normal, rhs, config=tgt, tol=cfg.tol,
                 max_iter=cfg.max_iter, psum_axes=axes)
        return res.x.canonical_nd(), res.iterations, res.residual

    sharded = compat.shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
    )
    return jax.jit(sharded)(u_nd, b_nd)
