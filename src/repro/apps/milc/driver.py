"""MILC Wilson-CG driver (single-shard and sharded).

Reproduces the UEABS test: invert the Wilson-Dirac operator on a random
SU(3) gauge background with CG on the normal equations.  The sharded form
domain-decomposes the 4-D lattice over mesh axes; each dslash exchanges
the spinor halo (ppermute), the gauge halo is exchanged once per solve —
exactly the MPI structure of the original (the "Shift" kernel is where
MPI lives, §2.1.2).

``solve_sharded`` supports three per-iteration schedules:

* ``halo=None`` — the legacy path: one spinor exchange per dslash, the
  operator unfused (two launches + linear algebra per application).
* ``halo="pre"`` — the fused path: one width-2 spinor exchange, then the
  whole M^dag M application as ONE halo'd launch (wilson_normal_graph).
* ``halo="overlap"`` — the fused path under the comms/compute overlap
  scheduler (core.overlap): the spinor exchange is started, the interior
  of the fused operator runs on locally-owned data with no dependence on
  it, and thin boundary slabs run once the halos land.  Bit-identical to
  ``halo="pre"`` (the CG inner products are computed from the assembled
  Fields through the same producer-independent reduction in both modes),
  asserted under the 8-fake-device harness in tests/test_distributed.py.

The halo'd spinor/gauge Fields keep ``cfg.layout`` whenever the padded
local lattice stays SAL-tileable (falling back to SOA otherwise,
``tileable_layout``), so a tuned native-AoSoA stencil plan
(``LoweringPlan.view == "block"``) reaches the fused per-iteration
operator under ``cfg.target.plan_policy="tuned"`` with no driver edits.
The same goes for tiled plans (``LoweringPlan.by``/``bz``): when a
shard's whole-staged M^dag M footprint exceeds the VMEM budget
(``TargetConfig.vmem_bytes`` / ``$TARGETDP_VMEM_BYTES``), the planning
layer tiles the y/z axes of the fused operator — per-device local volume
is bounded by the tile, not the lattice, which is what lets the paper's
fig 5 lattice sizes fit a device's pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import (
    BatchedField, DtypePolicy, Field, Layout, SOA, TargetConfig, compat,
    overlap_launch, tileable_layout,
)
from repro.core import halo as halo_mod
from repro.kernels.wilson_dslash.ops import dslash_halo
from repro.lattice import Domain
from .cg import (
    BatchedCGResult, CGResult, cg, cg_batched, cg_refined, dot,
    make_fused_normal, make_wilson_op, wilson_normal_graph,
)
from . import fields


@dataclasses.dataclass(frozen=True)
class MilcConfig:
    lattice: Tuple[int, int, int, int] = (8, 8, 8, 8)
    kappa: float = 0.12
    tol: float = 1e-10
    max_iter: int = 1000
    hot: float = 0.6           # gauge disorder (1 = hot start)
    layout: Layout = SOA
    target: TargetConfig = TargetConfig("jnp", vvl=128)
    # mixed precision: storage dtype for the bandwidth-dominant operator
    # launches ("" = full precision), and the iterative-refinement /
    # reliable-update knobs that keep the solve correct under it.
    # refine_k = 0 picks a default (50) whenever storage is narrowed.
    storage: str = ""
    refine_k: int = 0
    reliable: float = 0.0


def _storage_target(cfg: MilcConfig) -> TargetConfig:
    """The operator-launch config: ``cfg.target`` with the storage-dtype
    policy attached when ``cfg.storage`` narrows it (compute stays fp32,
    terminal reductions accumulate in fp64 — compensated fp32 where fp64
    is unavailable)."""
    if not cfg.storage:
        return cfg.target
    return dataclasses.replace(
        cfg.target, dtypes=DtypePolicy(storage=cfg.storage,
                                       compute="float32",
                                       accumulate="float64"))


def _hi_target(cfg: MilcConfig) -> TargetConfig:
    """The reference-operator config for true-residual recomputes: any
    dtype policy stripped and the deterministic default plans, so the
    residual the refinement loop trusts is policy-independent."""
    return dataclasses.replace(cfg.target, plan_policy="default",
                               dtypes=None)


def _refine_k(cfg: MilcConfig) -> int:
    return cfg.refine_k or (50 if cfg.storage else 0)


def init_problem(cfg: MilcConfig, seed: int = 0):
    """Random SU(3) gauge Field (72,) + gaussian source Field (24,)."""
    u_np = fields.random_su3_gauge(cfg.lattice, seed=seed, hot=cfg.hot)
    assert fields.unitarity_violation(u_np) < 1e-5
    b_np = fields.random_spinor(cfg.lattice, seed=seed + 1)
    u = Field.from_numpy("u", u_np, cfg.lattice, cfg.layout)
    b = Field.from_numpy("b", b_np, cfg.lattice, cfg.layout)
    return u, b


def solve(cfg: MilcConfig, u: Field, b: Field) -> CGResult:
    """Single-shard CG solve of M x = b via the normal equations.

    The operator application runs through the fused dslash+axpy+dot graph
    (one pallas_call), the update chain through the fused axpy+residual-norm
    graph (one more): two launches per CG iteration.

    With ``cfg.storage`` narrowed (or ``cfg.refine_k`` set) the solve runs
    :func:`repro.apps.milc.cg.cg_refined`: the per-iteration operator
    launches move storage-dtype bytes while iterative-refinement restarts
    against the policy-free operator recover the working-precision
    tolerance."""
    apply_m, apply_mdag, apply_normal = make_wilson_op(u, cfg.kappa, cfg.target)
    rhs = apply_mdag(b)
    rk = _refine_k(cfg)
    if rk > 0:
        return cg_refined(
            make_fused_normal(u, cfg.kappa, _storage_target(cfg)), rhs,
            config=cfg.target, tol=cfg.tol, max_iter=cfg.max_iter,
            refine_k=rk, reliable=cfg.reliable or 1e-4,
            apply_a_dot_hi=make_fused_normal(u, cfg.kappa, _hi_target(cfg)))
    res = cg(apply_normal, rhs, config=cfg.target, tol=cfg.tol,
             max_iter=cfg.max_iter,
             apply_a_dot=make_fused_normal(u, cfg.kappa,
                                           _storage_target(cfg)))
    return res


def solve_batched(cfg: MilcConfig, u: Field, bs) -> BatchedCGResult:
    """CG-solve a stack of sources against ONE shared gauge field through
    batched launches: per iteration, one fused operator pallas_call and one
    fused masked-update pallas_call cover the whole batch.

    ``bs`` is a sequence of same-lattice source Fields or an already-stacked
    BatchedField.  Each slot's trajectory — rhs, every alpha/beta, the
    iteration count, the final x — is bit-identical to ``solve(cfg, u, b)``
    on that source alone: the rhs is computed per request through the
    single-lattice M^dag path before stacking, and converged slots are
    frozen by select-masking, never arithmetic (see cg._masked_fma_body)."""
    _, apply_mdag, _ = make_wilson_op(u, cfg.kappa, cfg.target)
    if isinstance(bs, BatchedField):
        rhs = BatchedField.stack(
            [apply_mdag(b) for b in bs.unstack()], name="rhs")
    else:
        rhs = BatchedField.stack([apply_mdag(b) for b in bs], name="rhs")
    rk = _refine_k(cfg)
    return cg_batched(
        make_fused_normal(u, cfg.kappa, _storage_target(cfg)), rhs,
        config=cfg.target, tol=cfg.tol, max_iter=cfg.max_iter,
        refine_every=rk,
        apply_a_dot_hi=(make_fused_normal(u, cfg.kappa, _hi_target(cfg))
                        if rk > 0 else None))


def solver_cost_model(cfg: MilcConfig, u: Field, b: Field, *,
                      tol: float = 1e-6, cap: Optional[int] = None):
    """The convergence-aware tuner cost for the fused normal-operator
    graph: a callable mapping a candidate plan to its measured
    iterations-to-tolerance (memoized per plan), so
    :func:`repro.core.tune.autotune_graph` ranks candidates by
    time-per-iteration × iterations — time-to-solution — instead of raw
    launch time.  Dtype-policy candidates are measured through the
    iterative-refinement solve (how they would actually deploy); full
    precision candidates through plain CG."""
    _, apply_mdag, _ = make_wilson_op(u, cfg.kappa, cfg.target)
    rhs = apply_mdag(b)
    cap = cap or cfg.max_iter
    hi_op = make_fused_normal(u, cfg.kappa, _hi_target(cfg))
    cache = {}

    def iterations(plan):
        tgt = dataclasses.replace(cfg.target, plan_policy=plan)
        op = make_fused_normal(u, cfg.kappa, tgt)
        if plan.dtypes:
            res = cg_refined(op, rhs, config=cfg.target, tol=tol,
                             max_iter=cap, refine_k=cfg.refine_k or 50,
                             reliable=cfg.reliable or 1e-4,
                             apply_a_dot_hi=hi_op)
        else:
            res = cg(None, rhs, config=cfg.target, tol=tol, max_iter=cap,
                     apply_a_dot=op)
        return float(max(int(res.iterations), 1))

    def cost(plan):
        if plan not in cache:
            cache[plan] = iterations(plan)
        return cache[plan]

    return cost


def tune_solve_graphs(cfg: MilcConfig, u: Field, b: Field,
                      convergence_cost: bool = False, **tune_kw):
    """Autotune the two launch graphs a CG iteration runs — the fused
    normal-operator application (dslash+dslash+xpay/g5 + <p,Ap>) and the
    fused update chain (+ residual norm) — persisting the winners so a
    later ``cfg.target.plan_policy="tuned"`` solve loads them instead of
    re-sweeping.  Returns {graph name: (plan, info)}.

    ``convergence_cost=True`` ranks the normal-operator candidates by
    measured time-to-solution (:func:`solver_cost_model`) rather than raw
    launch time — required for a fair sweep once dtype-policy twins are in
    the candidate set, since a cheaper-per-iteration plan may need more
    iterations."""
    from repro.core import tune

    from .cg import cg_update_graph, wilson_normal_graph

    results = {}
    g = wilson_normal_graph(float(cfg.kappa))
    op_kw = dict(tune_kw)
    if convergence_cost and "cost_model" not in op_kw:
        op_kw["cost_model"] = solver_cost_model(cfg, u, b)
    results[g.name] = tune.autotune_graph(
        g, {"p": b, "u": u}, config=cfg.target, outputs=("ap", "pap"),
        **op_kw)
    g = cg_update_graph(b.ncomp)
    results[g.name] = tune.autotune_graph(
        g, {"x": b, "r": b, "p": b, "ap": b},
        scalars={"alpha": 0.3, "neg_alpha": -0.3},
        config=cfg.target, outputs=("x_new", "r_new", "rr"), **tune_kw)
    return results


def residual_check(cfg: MilcConfig, u: Field, b: Field, x: Field) -> float:
    """|M x - b| / |b| — independent verification of the solve."""
    apply_m, _, _ = make_wilson_op(u, cfg.kappa, cfg.target)
    mx = apply_m(x)
    num = jnp.linalg.norm(mx.canonical() - b.canonical())
    den = jnp.linalg.norm(b.canonical())
    return float(num / den)


# -- sharded solve ---------------------------------------------------------------

def make_domain(cfg: MilcConfig, mesh, dim_axes) -> Domain:
    return Domain(global_shape=cfg.lattice, mesh=mesh, dim_axes=dim_axes, halo=1)


def make_sharded_solver(
    cfg: MilcConfig, domain: Domain, halo: Optional[str] = None
):
    """Build the jitted sharded CG solver: ``solver(u_nd, b_nd) ->
    (x_nd, iterations, residual)`` over global canonical-nd arrays
    (sharded or to-be-sharded per ``domain.spec()``).

    ``halo`` selects the per-iteration schedule (see the module docstring):
    None (legacy per-dslash exchange, unfused), "pre" (fused normal
    operator on one width-2 pre-exchange) or "overlap" (fused operator
    under the interior/boundary split of core.overlap, hiding the spinor
    exchange behind the interior compute)."""
    if halo not in (None, "pre", "overlap"):
        raise ValueError(f"halo must be None, 'pre' or 'overlap', got {halo!r}")
    mesh = domain.mesh
    spec = domain.spec()
    dec = domain.decomposed
    axes = tuple(ax for _, ax, _ in dec)
    tgt = cfg.target
    WN = 2  # fused normal-operator ring: two width-1 dslash stages

    def pad(x, w=1):
        # wrap-pad all site dims (local periodic); exchange overwrites the
        # decomposed dims' halos with true neighbour data.
        pads = [(0, 0)] + [(w, w)] * (x.ndim - 1)
        return jnp.pad(x, pads, mode="wrap")

    def exchange(x, w=1):
        return halo_mod.exchange(x, dec, width=w)

    def mkF(name, arr):
        lat = tuple(arr.shape[1:])
        return Field.from_canonical(
            name, arr, lat, tileable_layout(cfg.layout, lat))

    def local_solve(u_loc, b_loc):
        lat_loc = u_loc.shape[1:]
        u_h = exchange(pad(u_loc))  # gauge halo once per solve

        def dslash_fn(psi: Field) -> Field:
            psi_h = exchange(pad(psi.canonical_nd()))
            out = dslash_halo(psi_h, u_h, config=tgt, width=1)
            return psi.with_canonical(out.reshape(24, -1))

        bF = mkF("b", b_loc)
        uF = mkF("u", u_loc)
        apply_m, apply_mdag, apply_normal = make_wilson_op(
            uF, cfg.kappa, tgt, dslash_fn=dslash_fn
        )
        rhs = apply_mdag(bF)

        apply_a_dot = None
        if halo is not None:
            # fused M^dag M: dslash+dslash+xpay/g5 as one halo'd graph per
            # iteration.  The gauge halo (ring 2) is exchanged once here.
            graph = wilson_normal_graph(float(cfg.kappa))
            u_h2 = exchange(pad(u_loc, WN), WN)
            uF_h = mkF("u", u_h2)
            # config/outputs/halo bound once; the per-Field output layout
            # is a per-call override (it follows the solve vector)
            normal_pre = graph.bind(config=tgt, outputs=("ap",), halo="pre")

            def apply_a_dot(p: Field):
                p_p = pad(p.canonical_nd(), WN)
                if halo == "pre":
                    p_h = exchange(p_p, WN)
                    pF = mkF("p", p_h)
                    out = normal_pre({"p": pF, "u": uF_h},
                                     out_layouts={"ap": p.layout})
                else:
                    pF = mkF("p", p_p)
                    out = overlap_launch(
                        graph, {"p": pF, "u": uF_h}, decomposed=dec,
                        config=tgt, outputs=("ap",), halo="overlap",
                        exchanged=("u",), out_layouts={"ap": p.layout})
                ap = p.with_data(out["ap"].data)
                # <p, Ap> from the assembled Fields (elementwise product +
                # fold), NOT the graph's fused on-chip reduction: its value
                # is independent of how ap was produced (one launch vs
                # interior/boundary slabs), so the CG trajectory is
                # bit-identical across the "pre" and "overlap" schedules.
                return ap, dot(p, ap, tgt)

        res = cg(apply_normal, rhs, config=tgt, tol=cfg.tol,
                 max_iter=cfg.max_iter, psum_axes=axes,
                 apply_a_dot=apply_a_dot)
        return res.x.canonical_nd(), res.iterations, res.residual

    sharded = compat.shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
    )
    return jax.jit(sharded)


def solve_sharded(
    cfg: MilcConfig,
    domain: Domain,
    u_nd: jax.Array,
    b_nd: jax.Array,
    halo: Optional[str] = None,
):
    """One-shot form of :func:`make_sharded_solver` (builds, jits and runs
    the solver; loops should build the solver once instead)."""
    return make_sharded_solver(cfg, domain, halo)(u_nd, b_nd)
