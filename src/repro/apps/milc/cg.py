"""Conjugate-gradient inversion of the Wilson-Dirac operator (MILC UEABS).

Solves M^dag M x = M^dag b for x (so M x = b), with M = 1 - kappa D and
M^dag = g5 M g5 (gamma5-hermiticity; g5 = diag(1,1,-1,-1) in the DeGrand-
Rossi basis, verified in tests).

The linear algebra is decomposed exactly as the paper's MILC profile
(§2.1.2): "Shift" (neighbour gather, in dslash), "Extract (and Mult)" /
"Insert (and Mult)" (spin projection + SU(3) mult, in dslash), and
"Scalar Mult Add" — the axpy/xpay updates, which run through the
targetDP-JAX launch machinery as site-local kernels so both engines and
all layouts apply (paper C1/C2 for MILC).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import Field, TargetConfig, launch, target_sum
from repro.kernels.wilson_dslash import dslash


# -- site-local linear-algebra kernels (the "Scalar Mult Add" family) ---------

def _axpy_body(v, *, a: float = None):
    return {"out": v["x"] * a + v["y"]}


def axpy(a, x: Field, y: Field, config: TargetConfig) -> Field:
    """a*x + y through the kernel layer (static a)."""
    return launch(_axpy_body, {"x": x, "y": y}, {"out": x.ncomp},
                  config=config, params=dict(a=a))["out"]


def dot(x: Field, y: Field, config: TargetConfig) -> jnp.ndarray:
    """<x, y> as the real inner product over all components/sites.

    (For split re/im spinor fields this equals Re<x|y> of the complex
    inner product.)  Local reduction via the targetDP reduction API; the
    sharded path psums across the mesh.
    """
    prod = launch(lambda v: {"p": v["x"] * v["y"]}, {"x": x, "y": y},
                  {"p": x.ncomp}, config=config)["p"]
    return target_sum(prod, config).sum()


def g5(psi: Field, config: TargetConfig) -> Field:
    """gamma5 psi: flips the sign of spin components 2 and 3."""

    def body(v):
        x = v["psi"]
        return {"out": jnp.concatenate([x[:12], -x[12:]], axis=0)}

    return launch(body, {"psi": psi}, {"out": psi.ncomp}, config=config)["out"]


# -- operator application -------------------------------------------------------

def make_wilson_op(u: Field, kappa: float, config: TargetConfig,
                   dslash_fn: Optional[Callable] = None):
    """Returns apply_m, apply_mdag, apply_normal (M^dag M)."""
    _dslash = dslash_fn or (lambda psi: dslash(psi, u, config=config))

    def apply_m(psi: Field) -> Field:
        d = _dslash(psi)
        return psi.with_canonical(psi.canonical() - kappa * d.canonical())

    def apply_mdag(psi: Field) -> Field:
        return g5(apply_m(g5(psi, config)), config)

    def apply_normal(psi: Field) -> Field:
        return apply_mdag(apply_m(psi))

    return apply_m, apply_mdag, apply_normal


class CGResult(NamedTuple):
    x: Field
    iterations: jnp.ndarray
    residual: jnp.ndarray  # final |r|^2 / |b|^2


def cg(
    apply_a: Callable[[Field], Field],
    b: Field,
    *,
    config: TargetConfig,
    tol: float = 1e-8,
    max_iter: int = 500,
    psum_axes: Tuple[str, ...] = (),
) -> CGResult:
    """Standard CG on a positive-definite operator, jax.lax.while_loop based
    so it jits and shards (dots are psum'd over ``psum_axes`` inside
    shard_map)."""

    def gdot(x: Field, y: Field):
        d = dot(x, y, config)
        for ax in psum_axes:
            d = jax.lax.psum(d, ax)
        return d

    b2 = gdot(b, b)
    x0 = b.with_canonical(jnp.zeros_like(b.canonical()))
    r0 = b
    p0 = b

    def cond(carry):
        x, r, p, rr, it = carry
        return jnp.logical_and(rr / b2 > tol, it < max_iter)

    def body(carry):
        x, r, p, rr, it = carry
        ap = apply_a(p)
        alpha = rr / gdot(p, ap)
        xc = x.canonical() + alpha * p.canonical()
        rc = r.canonical() - alpha * ap.canonical()
        x = x.with_canonical(xc)
        r = r.with_canonical(rc)
        rr_new = gdot(r, r)
        beta = rr_new / rr
        p = p.with_canonical(rc + beta * p.canonical())
        return (x, r, p, rr_new, it + 1)

    rr0 = gdot(r0, r0)
    x, r, p, rr, it = jax.lax.while_loop(cond, body, (x0, r0, p0, rr0, jnp.int32(0)))
    return CGResult(x=x, iterations=it, residual=rr / b2)
