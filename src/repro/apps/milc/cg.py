"""Conjugate-gradient inversion of the Wilson-Dirac operator (MILC UEABS).

Solves M^dag M x = M^dag b for x (so M x = b), with M = 1 - kappa D and
M^dag = g5 M g5 (gamma5-hermiticity; g5 = diag(1,1,-1,-1) in the DeGrand-
Rossi basis, verified in tests).

The linear algebra is decomposed exactly as the paper's MILC profile
(§2.1.2): "Shift" (neighbour gather, in dslash), "Extract (and Mult)" /
"Insert (and Mult)" (spin projection + SU(3) mult, in dslash), and
"Scalar Mult Add" — the axpy/xpay updates, which run through the
targetDP-JAX launch machinery as site-local kernels so both engines and
all layouts apply (paper C1/C2 for MILC).

Two fused launch graphs cover the whole CG iteration (core.fuse):

* ``wilson_normal_graph`` — the operator application M^dag M p with the
  dslash *stencil* stages fused into the xpay/g5 site-local chain and the
  <p, A p> inner product as a terminal reduction: ONE halo'd pallas_call
  per iteration computes ap and its dot with p (neighbour spinors gather
  from the VMEM-resident halo'd block; the dot's per-site products never
  materialize in HBM).
* ``cg_update_graph`` — the "Scalar Mult Add" chain x+alpha*p, r-alpha*ap
  and the residual norm |r_new|^2 as a terminal reduction, again ONE
  launch (p, ap, x, r stream from HBM once; rr_prod never exists in HBM),
  with the traced alpha passed as a runtime scalar so the launch cache
  stays valid across iterations.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import (
    BatchedField, Field, LaunchGraph, TargetConfig, launch, target_sum,
)
from repro.kernels.wilson_dslash import dslash
from repro.kernels.wilson_dslash.ops import dslash_stencil_body


# -- site-local linear-algebra kernels (the "Scalar Mult Add" family) ---------

def _axpy_body(v, *, a: float = None):
    return {"out": v["x"] * a + v["y"]}


def axpy(a, x: Field, y: Field, config: TargetConfig) -> Field:
    """a*x + y through the kernel layer (static a)."""
    return launch(_axpy_body, {"x": x, "y": y}, {"out": x.ncomp},
                  config=config, params=dict(a=a))["out"]


def _fma_body(v):
    """y + a*x with a supplied as a runtime (1, 1) scalar input."""
    return {"out": v["y"] + v["a"] * v["x"]}


def _square_body(v):
    return {"out": v["x"] * v["x"]}


def _mul_body(v):
    return {"out": v["x"] * v["y"]}


def _masked_fma_body(v):
    """y + a*x where the per-request mask is set, y (bitwise) elsewhere.

    The frozen branch must be a *select*, not arithmetic masking: y + 0*x
    flips -0.0 to +0.0 and poisons on non-finite x, so a converged
    request's state would drift from its single-solve bits."""
    return {"out": jnp.where(v["m"] > 0, v["y"] + v["a"] * v["x"], v["y"])}


def _m_g5_body(v, *, kappa):
    """g5 (psi - kappa d): one Wilson matvec + gamma5, site-local."""
    t = v["psi"] - kappa * v["d"]
    return {"out": jnp.concatenate([t[:12], -t[12:]], axis=0)}


def fused_xpay(y: Field, a, x: Field, config: TargetConfig) -> Field:
    """y + a*x with traced a (one cached fused launch); keeps x's pytree
    identity (name/layout) so it can ride a lax.while_loop carry."""
    g = LaunchGraph("cg_xpay").add(
        _fma_body, {"x": "x", "y": "y", "a": "a"}, {"out": x.ncomp}
    )
    out = g.launch({"x": x, "y": y}, scalars={"a": a}, config=config,
                   out_layouts={"out": x.layout})["out"]
    # cast back to the carry dtype: under a storage-dtype policy the launch
    # writes (and so quantizes) the output in storage precision, but the
    # while_loop carry must keep a fixed dtype (no-op without a policy)
    return x.with_data(out.data.astype(x.data.dtype))


def cg_update_graph(ncomp: int) -> LaunchGraph:
    """The CG inner-update chain as a LaunchGraph, ending in the residual
    norm as a terminal reduction (also used by the fused benchmarks for
    bytes-moved accounting): rr_prod never materializes in HBM."""
    return (
        LaunchGraph("cg_update")
        .add(_fma_body, {"x": "p", "y": "x", "a": "alpha"}, {"out": ncomp},
             rename={"out": "x_new"})
        .add(_fma_body, {"x": "ap", "y": "r", "a": "neg_alpha"}, {"out": ncomp},
             rename={"out": "r_new"})
        .add(_square_body, {"x": "r_new"}, {"out": ncomp},
             rename={"out": "rr_prod"})
        .add_reduce("rr_prod", op="sum", name="rr")
    )


def fused_cg_update(x: Field, r: Field, p: Field, ap: Field, alpha,
                    config: TargetConfig):
    """The CG "Scalar Mult Add" chain + residual norm as ONE fused launch:

        x_new = x + alpha p,  r_new = r - alpha ap,  rr = sum (r_new)^2

    Unfused this is three kernels plus a reduction pass (p, ap, x, r and
    three intermediates round-tripping HBM); fused, each operand streams in
    once, only x_new/r_new stream out and the squared residual accumulates
    on-chip.  Returns (x_new, r_new, rr) with x/r pytree identity preserved
    and rr a per-component (ncomp,) partial sum (``rr.sum()`` is |r_new|^2).
    """
    out = cg_update_graph(x.ncomp).launch(
        {"x": x, "r": r, "p": p, "ap": ap},
        scalars={"alpha": alpha, "neg_alpha": -alpha},
        config=config,
        outputs=("x_new", "r_new", "rr"),
        out_layouts={"x_new": x.layout, "r_new": r.layout},
    )
    return (x.with_data(out["x_new"].data.astype(x.data.dtype)),
            r.with_data(out["r_new"].data.astype(r.data.dtype)), out["rr"])


def masked_cg_update_graph(ncomp: int) -> LaunchGraph:
    """The batched-serving variant of :func:`cg_update_graph`: the x/r
    updates select per request on the runtime mask scalar ``m`` (1 while
    the request iterates, 0 once converged), so a frozen slot's state and
    residual are bitwise untouched while live slots update exactly as the
    unmasked chain would."""
    return (
        LaunchGraph("cg_update_masked")
        .add(_masked_fma_body, {"x": "p", "y": "x", "a": "alpha", "m": "m"},
             {"out": ncomp}, rename={"out": "x_new"})
        .add(_masked_fma_body, {"x": "ap", "y": "r", "a": "neg_alpha",
                                "m": "m"},
             {"out": ncomp}, rename={"out": "r_new"})
        .add(_square_body, {"x": "r_new"}, {"out": ncomp},
             rename={"out": "rr_prod"})
        .add_reduce("rr_prod", op="sum", name="rr")
    )


def fused_masked_cg_update(x, r, p, ap, alpha, mask, config: TargetConfig):
    """Per-request-masked CG update chain, one fused launch over the whole
    batch.  ``alpha`` and ``mask`` are per-request ``(batch,)`` scalars."""
    out = masked_cg_update_graph(x.ncomp).launch(
        {"x": x, "r": r, "p": p, "ap": ap},
        scalars={"alpha": alpha, "neg_alpha": -alpha, "m": mask},
        config=config,
        outputs=("x_new", "r_new", "rr"),
        out_layouts={"x_new": x.layout, "r_new": r.layout},
    )
    return (x.with_data(out["x_new"].data.astype(x.data.dtype)),
            r.with_data(out["r_new"].data.astype(r.data.dtype)),
            out["rr"])


def fused_masked_xpay(y, a, x, mask, config: TargetConfig):
    """Masked p-update: r + beta*p where the request is live, p bitwise
    frozen elsewhere (the batched form of :func:`fused_xpay`)."""
    g = LaunchGraph("cg_xpay_masked").add(
        _masked_fma_body, {"x": "x", "y": "y", "a": "a", "m": "m"},
        {"out": x.ncomp}
    )
    out = g.launch({"x": x, "y": y}, scalars={"a": a, "m": mask},
                   config=config, out_layouts={"out": x.layout})["out"]
    return x.with_data(out.data.astype(x.data.dtype))


def dot(x: Field, y: Field, config: TargetConfig) -> jnp.ndarray:
    """<x, y> as the real inner product over all components/sites.

    (For split re/im spinor fields this equals Re<x|y> of the complex
    inner product.)  Local reduction via the targetDP reduction API; the
    sharded path psums across the mesh.
    """
    prod = launch(lambda v: {"p": v["x"] * v["y"]}, {"x": x, "y": y},
                  {"p": x.ncomp}, config=config)["p"]
    return target_sum(prod, config).sum()


def batched_dot(x: BatchedField, y: BatchedField,
                config: TargetConfig) -> jnp.ndarray:
    """Per-request <x, y> over a batch, shape (batch,) — each element
    bitwise :func:`dot` of the corresponding slots: the elementwise product
    is lowering-independent and the batched ``target_sum`` folds each batch
    row in the single-Field accumulation order."""
    g = LaunchGraph("dot_prod").add(_mul_body, {"x": "x", "y": "y"},
                                    {"out": x.ncomp}, rename={"out": "p"})
    prod = g.launch({"x": x, "y": y}, config=config,
                    out_layouts={"p": x.layout})["p"]
    return target_sum(prod, config).sum(axis=-1)


def g5(psi: Field, config: TargetConfig) -> Field:
    """gamma5 psi: flips the sign of spin components 2 and 3."""

    def body(v):
        x = v["psi"]
        return {"out": jnp.concatenate([x[:12], -x[12:]], axis=0)}

    return launch(body, {"psi": psi}, {"out": psi.ncomp}, config=config)["out"]


# -- operator application -------------------------------------------------------

def wilson_normal_graph(kappa: float) -> LaunchGraph:
    """M^dag M p with <p, M^dag M p> as a terminal reduction, fused.

    Both dslash applications run as width-1 *stencil* stages (the "Shift"
    neighbour gathers read the VMEM-resident halo'd block — external inputs
    p and u carry a ring-2 halo, consumed one ring per dslash), the xpay/g5
    "Scalar Mult Add" stages run site-local on the same block, and the
    <p, ap> inner product accumulates on-chip: the whole normal-operator
    application is ONE pallas_call per CG iteration."""
    return (
        LaunchGraph("wilson_normal")
        .add_stencil(dslash_stencil_body, {"psi": "p", "u": "u"}, {"d": 24},
                     width=1, rename={"d": "d1"})
        .add(_m_g5_body, {"psi": "p", "d": "d1"}, {"out": 24},
             rename={"out": "t"}, params=dict(kappa=kappa))
        .add_stencil(dslash_stencil_body, {"psi": "t", "u": "u"}, {"d": 24},
                     width=1, rename={"d": "d2"})
        .add(_m_g5_body, {"psi": "t", "d": "d2"}, {"out": 24},
             rename={"out": "ap"}, params=dict(kappa=kappa))
        .add(_mul_body, {"x": "p", "y": "ap"}, {"out": 24},
             rename={"out": "pap_prod"})
        .add_reduce("pap_prod", op="sum", name="pap")
    )


def make_fused_normal(u: Field, kappa: float, config: TargetConfig):
    """Returns apply(p) -> (A p, <p, A p>) through the fused graph
    (A = M^dag M); ap keeps p's pytree identity for the while_loop carry.
    ``p`` may be a BatchedField (the gauge field is shared across the
    batch): ap comes back batched and the inner product per request,
    shape (batch,)."""
    bound = wilson_normal_graph(float(kappa)).bind(
        config=config, outputs=("ap", "pap"))

    def apply(p):
        out = bound({"p": p, "u": u}, out_layouts={"ap": p.layout})
        # axis=-1 folds the per-component partials: a scalar for a Field,
        # (batch,) for a BatchedField — bitwise the 1-D sum either way
        return p.with_data(out["ap"].data), out["pap"].sum(axis=-1)

    return apply


def make_wilson_op(u: Field, kappa: float, config: TargetConfig,
                   dslash_fn: Optional[Callable] = None):
    """Returns apply_m, apply_mdag, apply_normal (M^dag M)."""
    _dslash = dslash_fn or (lambda psi: dslash(psi, u, config=config))

    def apply_m(psi: Field) -> Field:
        d = _dslash(psi)
        return psi.with_canonical(psi.canonical() - kappa * d.canonical())

    def apply_mdag(psi: Field) -> Field:
        return g5(apply_m(g5(psi, config)), config)

    def apply_normal(psi: Field) -> Field:
        return apply_mdag(apply_m(psi))

    return apply_m, apply_mdag, apply_normal


class CGResult(NamedTuple):
    x: Field
    iterations: jnp.ndarray
    residual: jnp.ndarray  # final |r|^2 / |b|^2


def cg(
    apply_a: Callable[[Field], Field],
    b: Field,
    *,
    config: TargetConfig,
    tol: float = 1e-8,
    max_iter: int = 500,
    psum_axes: Tuple[str, ...] = (),
    apply_a_dot: Optional[Callable[[Field], Tuple[Field, jnp.ndarray]]] = None,
) -> CGResult:
    """Standard CG on a positive-definite operator, jax.lax.while_loop based
    so it jits and shards (dots are psum'd over ``psum_axes`` inside
    shard_map).

    apply_a_dot, when given, computes (A p, <p, A p>) in one fused launch
    (see make_fused_normal) — the iteration then runs TWO pallas_calls:
    operator+dot, and update-chain+residual-norm."""

    def psum(d):
        for ax in psum_axes:
            d = jax.lax.psum(d, ax)
        return d

    def gdot(x: Field, y: Field):
        return psum(dot(x, y, config))

    b2 = gdot(b, b)
    x0 = b.with_canonical(jnp.zeros_like(b.canonical()))
    r0 = b
    p0 = b

    def cond(carry):
        x, r, p, rr, it = carry
        return jnp.logical_and(rr / b2 > tol, it < max_iter)

    def body(carry):
        x, r, p, rr, it = carry
        if apply_a_dot is not None:
            # dslash + axpy chain + <p, ap> reduction: one fused launch
            ap, pap = apply_a_dot(p)
            alpha = rr / psum(pap)
        else:
            ap = apply_a(p)
            alpha = rr / gdot(p, ap)
        # fused "Scalar Mult Add" chain: x/r updates + residual square +
        # its terminal sum in one launch — rr_prod never touches HBM.
        x, r, rr_vec = fused_cg_update(x, r, p, ap, alpha, config)
        rr_new = psum(rr_vec.sum())
        beta = rr_new / rr
        p = fused_xpay(r, beta, p, config)
        return (x, r, p, rr_new, it + 1)

    rr0 = gdot(r0, r0)
    x, r, p, rr, it = jax.lax.while_loop(cond, body, (x0, r0, p0, rr0, jnp.int32(0)))
    return CGResult(x=x, iterations=it, residual=rr / b2)


def cg_refined(
    apply_a_dot,
    b: Field,
    *,
    config: TargetConfig,
    tol: float = 1e-8,
    max_iter: int = 500,
    refine_k: int = 50,
    reliable: float = 1e-4,
    psum_axes: Tuple[str, ...] = (),
    apply_a_dot_hi=None,
) -> CGResult:
    """Iterative-refinement CG: low-precision inner iterations wrapped in
    precision-recovering restarts (the portable-LQCD production recipe).

    The outer loop keeps the solution ``x`` and the *true* residual
    ``r = b - A x`` in working precision.  Each outer step runs an inner CG
    on the correction system ``A d = r`` through ``apply_a_dot`` — whose
    launches may carry a bf16/fp32-storage :class:`DtypePolicy`, so the
    bandwidth-heavy iterations move narrow bytes — capped at ``refine_k``
    iterations or the ``reliable`` relative-residual trigger (the
    reliable-update stop: the inner recurrence residual is not trusted
    below that ratio).  The correction ``x += d`` and the true-residual
    recompute then happen in working precision via ``apply_a_dot_hi``
    (defaults to ``apply_a_dot``; pass the policy-free operator so the
    residual is exact — with an fp64 or compensated-fp32 accumulate where
    fp64 is unavailable).  Converges to the *working*-precision ``tol``
    even though the inner solves are quantized: each restart measures what
    the low-precision pass actually achieved and re-aims the next one.

    ``iterations`` in the result counts the total inner iterations (the
    bandwidth-dominant work), matching :func:`cg`'s accounting.
    """
    hi = apply_a_dot_hi or apply_a_dot

    def psum(d):
        for ax in psum_axes:
            d = jax.lax.psum(d, ax)
        return d

    def norm2(f: Field):
        # working-precision residual norm, independent of any storage
        # policy on `config` (the gate the outer loop trusts)
        c = f.canonical().astype(jnp.float32)
        return psum(jnp.sum(c * c))

    b2 = norm2(b)
    x0 = b.with_canonical(jnp.zeros_like(b.canonical()))

    def true_residual(x):
        ax, _ = hi(x)
        r = b.with_data(b.data - ax.data.astype(b.data.dtype))
        return r, norm2(r)

    def cond(carry):
        _x, _r, rr, it = carry
        return jnp.logical_and(rr / b2 > tol, it < max_iter)

    def body(carry):
        x, r, rr, it = carry
        inner = cg(None, r, config=config, tol=reliable,
                   max_iter=refine_k, psum_axes=psum_axes,
                   apply_a_dot=apply_a_dot)
        # x += d in working precision (never through a storage-dtype write)
        x = x.with_data(x.data + inner.x.data.astype(x.data.dtype))
        r, rr = true_residual(x)
        return (x, r, rr, it + inner.iterations)

    x, _r, rr, it = jax.lax.while_loop(
        cond, body, (x0, b, b2, jnp.int32(0)))
    return CGResult(x=x, iterations=it, residual=rr / b2)


# -- batched CG (multi-simulation serving) --------------------------------------

class BatchedCGState(NamedTuple):
    """Per-slot CG state for a batch of independent same-lattice solves.

    Slot semantics: ``b2 > 0`` and ``rr / b2 > tol`` and ``it < max_iter``
    means the slot is live; an empty slot (all-zero rhs) has ``b2 == 0``
    and is inert (``0/0`` compares False), so a partially filled batch
    runs without special-casing."""

    x: BatchedField
    r: BatchedField
    p: BatchedField
    rr: jnp.ndarray   # (batch,) |r|^2 per slot
    b2: jnp.ndarray   # (batch,) |rhs|^2 per slot
    it: jnp.ndarray   # (batch,) int32, active iterations taken


class BatchedCGResult(NamedTuple):
    x: BatchedField
    iterations: jnp.ndarray  # (batch,) int32
    residual: jnp.ndarray    # (batch,) final |r|^2 / |b|^2 per slot


def batched_cg_state(rhs: BatchedField, config: TargetConfig) -> BatchedCGState:
    """Initial state: x = 0, r = p = rhs, per-slot norms — each slot set up
    exactly as :func:`cg` sets up a single solve."""
    b2 = batched_dot(rhs, rhs, config)
    x0 = rhs.with_data(jnp.zeros_like(rhs.data))
    return BatchedCGState(x=x0, r=rhs, p=rhs, rr=b2, b2=b2,
                          it=jnp.zeros((rhs.batch,), jnp.int32))


def batched_cg_active(state: BatchedCGState, *, tol: float,
                      max_iter: int) -> jnp.ndarray:
    """(batch,) liveness mask — per slot, exactly the single-solve loop
    condition ``rr/b2 > tol and it < max_iter`` (NaN-false for empty
    slots, whose b2 is 0)."""
    return jnp.logical_and(state.rr / state.b2 > tol,
                           state.it < max_iter)


def batched_cg_iteration(
    state: BatchedCGState,
    apply_a_dot,
    *,
    config: TargetConfig,
    tol: float,
    max_iter: int,
) -> BatchedCGState:
    """One convergence-masked CG iteration over the whole batch: the fused
    normal-operator launch and the fused masked update chain each run ONCE
    for the full stack.  A live slot takes exactly the single-solve step
    (bitwise: the masked kernels select the identically computed update);
    a converged/empty slot's x, r, p, rr are bitwise frozen — it stays in
    the batch without perturbing anyone's residuals until the scheduler
    drains it."""
    act = batched_cg_active(state, tol=tol, max_iter=max_iter)
    m = act.astype(state.r.dtype)
    ap, pap = apply_a_dot(state.p)
    # guard the frozen lanes' divides (their alpha/beta are never selected)
    alpha = jnp.where(act, state.rr / jnp.where(act, pap, 1.0), 0.0)
    x, r, rr_vec = fused_masked_cg_update(
        state.x, state.r, state.p, ap, alpha, m, config)
    rr_new = jnp.where(act, rr_vec.sum(axis=-1), state.rr)
    beta = jnp.where(act, rr_new / jnp.where(act, state.rr, 1.0), 0.0)
    p = fused_masked_xpay(r, beta, state.p, m, config)
    return BatchedCGState(x=x, r=r, p=p, rr=rr_new, b2=state.b2,
                          it=state.it + act.astype(state.it.dtype))


def batched_cg_refresh(state: BatchedCGState, rhs: BatchedField,
                       apply_a_dot_hi, *, tol: float, max_iter: int,
                       refine_every: int) -> BatchedCGState:
    """Reliable-update restart for the batched loop: on every slot whose
    active iteration count hits a multiple of ``refine_every``, replace the
    recurrence residual with the *true* residual ``b - A x`` (computed
    through the high-precision operator) and restart the search direction
    there; all other slots are bitwise untouched.  This is what keeps the
    batched/serve path converging to the working-precision tolerance when
    the per-iteration launches run under a bf16/fp32-storage policy — the
    recurrence residual drifts from the truth in low precision, and the
    periodic exact recompute re-aims the iteration."""
    act = batched_cg_active(state, tol=tol, max_iter=max_iter)
    sel = jnp.logical_and(act, state.it % refine_every == 0)
    ax, _ = apply_a_dot_hi(state.x)
    rt = (rhs.data.astype(jnp.float32)
          - ax.data.astype(jnp.float32)).astype(state.r.data.dtype)
    rr_t = state.r.with_data(rt).canonical().astype(jnp.float32)
    rr_t = jnp.sum(rr_t * rr_t, axis=(-2, -1)).astype(state.rr.dtype)
    selb = sel.reshape((-1,) + (1,) * (rt.ndim - 1))
    return BatchedCGState(
        x=state.x,
        r=state.r.with_data(jnp.where(selb, rt, state.r.data)),
        p=state.p.with_data(jnp.where(selb, rt, state.p.data)),
        rr=jnp.where(sel, rr_t, state.rr),
        b2=state.b2, it=state.it)


def cg_batched(
    apply_a_dot,
    rhs: BatchedField,
    *,
    config: TargetConfig,
    tol: float = 1e-8,
    max_iter: int = 500,
    refine_every: int = 0,
    apply_a_dot_hi=None,
) -> BatchedCGResult:
    """CG on a stack of independent right-hand sides under one shared
    operator, per-request convergence-masked: every iteration runs one
    fused operator launch and one fused update launch for the whole batch,
    and each slot's trajectory is bit-identical to :func:`cg` on that slot
    alone (asserted in tests/test_batch.py).  The loop runs until every
    slot has converged or hit max_iter; slots that finish early ride along
    frozen.

    ``refine_every > 0`` enables reliable-update restarts for
    mixed-precision configs (see :func:`batched_cg_refresh`): every that
    many active iterations a slot's residual is recomputed exactly as
    ``b - A x`` through ``apply_a_dot_hi`` (defaults to ``apply_a_dot``;
    pass the policy-free operator) and its search direction restarted.
    With ``refine_every=0`` the loop is bitwise the historical one."""
    hi = apply_a_dot_hi or apply_a_dot
    state0 = batched_cg_state(rhs, config)

    def cond(state):
        return jnp.any(batched_cg_active(state, tol=tol, max_iter=max_iter))

    def trig(state):
        return jnp.logical_and(
            batched_cg_active(state, tol=tol, max_iter=max_iter),
            state.it % refine_every == 0)

    def body(state):
        state = batched_cg_iteration(state, apply_a_dot, config=config,
                                     tol=tol, max_iter=max_iter)
        if refine_every > 0:
            state = jax.lax.cond(
                jnp.any(trig(state)),
                lambda s: batched_cg_refresh(
                    s, rhs, hi, tol=tol, max_iter=max_iter,
                    refine_every=refine_every),
                lambda s: s, state)
        return state

    state = jax.lax.while_loop(cond, body, state0)
    return BatchedCGResult(x=state.x, iterations=state.it,
                           residual=state.rr / state.b2)
