"""Input specs and sharding plans per (arch x shape x mesh) dry-run cell.

Everything here is ShapeDtypeStruct-based (the shannon/kernels pattern):
weak-type-correct, shardable, zero device allocation.  Parameter and
optimizer shapes come from jax.eval_shape over the real init functions, so
the dry-run lowers exactly the production step functions.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import init_cache, init_params
from repro.train.optimizer import OptConfig, init_opt, opt_specs
from repro.train.sharding import DEFAULT_RULES
from .mesh import batch_axes, dp_size

N_IMG_TOKENS = 256  # vlm stub: patch embeddings spliced at sequence head


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _axes_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def resolve_spec(spec: P, leaf, mesh) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim
    (jit input shardings are strict about divisibility; odd vocabs like
    49155 or 256206 fall back to replicated on that dim)."""
    entries = list(tuple(spec)) + [None] * (len(leaf.shape) - len(tuple(spec)))
    out = []
    for dim, entry in zip(leaf.shape, entries):
        out.append(entry if dim % _axes_size(mesh, entry) == 0 else None)
    return P(*out)


def resolve_tree(specs, sds_tree, mesh):
    """resolve_spec over a whole (specs, shapes) tree pair."""
    return jax.tree_util.tree_map(
        lambda s, l: resolve_spec(s, l, mesh), specs, sds_tree,
        is_leaf=lambda t: isinstance(t, P),
    )


def rules_for(cfg: ArchConfig, shape: ShapeCfg, mesh) -> Dict:
    bdp = batch_axes(mesh)
    r = dict(DEFAULT_RULES)
    r["batch"] = bdp if shape.global_batch % dp_size(mesh) == 0 else None
    # Megatron-style sequence parallelism for training activations: the
    # remat-saved scan carries shrink by the TP degree (required to fit
    # deepseek-67b train_4k in HBM).
    from repro import tuning as _tuning
    r["seq"] = "model" if (shape.kind == "train"
                           and _tuning.get().seq_shard) else None
    # Megatron-style: q rows seq-sharded inside attention (k/v full) keeps
    # the S^2 score block sharded by the TP degree
    r["seq_q"] = "model" if (_tuning.get().attn_seq_shard
                             and shape.kind in ("train", "prefill")) else None
    # logits/cotangent sharding: vocab over "model" unless seq already
    # rides "model" (a spec may not use one mesh axis twice)
    r["logits_vocab"] = None if r["seq"] == "model" else "model"
    r["kv_heads"] = "model" if (cfg.n_kv_heads and cfg.n_kv_heads % mesh.shape["model"] == 0) else None
    return r


def batch_sharding(shape: ShapeCfg, mesh):
    bdp = batch_axes(mesh)
    return bdp if shape.global_batch % dp_size(mesh) == 0 else None


def params_plan(cfg: ArchConfig, mesh):
    """(param ShapeDtypeStructs, param PartitionSpecs, NamedShardings)."""
    from repro.train.sharding import param_specs

    p_sds = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0)
    )
    pspecs = resolve_tree(param_specs(p_sds), p_sds, mesh)
    shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    return p_sds, pspecs, shard


def train_batch_plan(cfg: ArchConfig, shape: ShapeCfg, mesh,
                     with_labels: bool = True):
    B, S = shape.global_batch, shape.seq_len
    bdp = batch_sharding(shape, mesh)
    specs: Dict[str, Tuple] = {
        "tokens": (sds((B, S), jnp.int32), P(bdp, None)),
    }
    if with_labels:
        specs["labels"] = (sds((B, S), jnp.int32), P(bdp, None))
    if cfg.family == "vlm":
        specs["image_embeds"] = (
            sds((B, N_IMG_TOKENS, cfg.d_model), jnp.bfloat16),
            P(bdp, None, None),
        )
        specs["positions"] = (sds((3, B, S), jnp.int32), P(None, bdp, None))
    if cfg.enc_dec:
        specs["frames"] = (
            sds((B, S, cfg.d_model), jnp.bfloat16), P(bdp, None, None)
        )
    batch_sds = {k: v[0] for k, v in specs.items()}
    batch_shard = {
        k: NamedSharding(mesh, v[1]) for k, v in specs.items()
    }
    return batch_sds, batch_shard


def cache_plan(cfg: ArchConfig, shape: ShapeCfg, mesh):
    """Cache ShapeDtypeStructs + shardings for a decode cell."""
    B, S = shape.global_batch, shape.seq_len
    bdp = batch_sharding(shape, mesh)
    kv_div = cfg.n_kv_heads and cfg.n_kv_heads % mesh.shape["model"] == 0

    cache_sds = jax.eval_shape(
        functools.partial(init_cache, cfg, B, S, s_enc=S),
    )

    def spec_for(path_key: str, leaf) -> P:
        nd = len(leaf.shape)
        if path_key in ("k", "v", "mem_k", "mem_v"):
            # (L, B, S, KV, dh): heads over model when divisible, else the
            # sequence axis carries the model shard (decode caches dominate
            # HBM at 32k/500k; they must shard over the full mesh).
            if kv_div:
                return P(None, bdp, None, "model", None)
            return P(None, bdp, "model", None, None)
        if path_key == "wkv":      # (L, B, H, dk, dv)
            return P(None, bdp, "model", None, None)
        if path_key == "ssm_h":    # (L, B, di, ds)
            return P(None, bdp, "model", None)
        if path_key == "conv":     # (L, B, K-1, di)
            return P(None, bdp, None, "model")
        if path_key in ("att_xprev", "ffn_xprev"):  # (L, B, d)
            return P(None, bdp, "model")
        return P(*((None,) * nd))

    cache_specs = {k: spec_for(k, v) if hasattr(v, "shape") else P()
                   for k, v in cache_sds.items()}
    cache_specs = resolve_tree(cache_specs, cache_sds, mesh)
    cache_shard = {k: NamedSharding(mesh, s) for k, s in cache_specs.items()}
    return cache_sds, cache_shard


def opt_plan(cfg: ArchConfig, p_sds, pspecs, mesh, ocfg: OptConfig):
    o_sds = jax.eval_shape(lambda p: init_opt(p, ocfg), p_sds)
    ospecs = resolve_tree(opt_specs(pspecs, p_sds, ocfg), o_sds, mesh)
    oshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), ospecs,
        is_leaf=lambda t: isinstance(t, P),
    )
    return o_sds, oshard
