"""Roofline terms from a compiled dry-run artifact (TPU v5e model).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = per-device collective bytes (ring-factored) / link_bw

``cost_analysis()`` on an SPMD executable reports per-device module
FLOPs/bytes, so no further division by chip count is needed.  Collective
bytes are not in cost_analysis: we parse the optimized HLO text, sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, apply ring factors (all-reduce 2(n-1)/n,
all-gather & reduce-scatter (n-1)/n, permute/all-to-all 1), and multiply
collectives inside while-loop bodies by the loop trip count (scan-over-
layers executes its body collectives n_layers times — a static text parse
sees them once).  Trip counts are matched per while body; when the parse
cannot associate a body with a count it falls back to the supplied
default multiplier and says so.

Hardware constants (v5e): 197 TFLOP/s bf16 per chip; 819 GB/s HBM;
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a possibly-tuple HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [groups, group_size]
        return int(m.group(2))
    return default


def _ring_factor(kind: str, n: int) -> float:
    if kind == "all-reduce":
        return 2.0 * (n - 1) / max(n, 1)
    if kind in ("all-gather", "reduce-scatter"):
        return 1.0 * (n - 1) / max(n, 1)
    return 1.0


def parse_collectives(hlo_text: str, *, default_group: int = 16,
                      loop_multiplier: int = 1) -> Dict[str, float]:
    """Sum per-device collective bytes (ring-factored) by kind.

    Collectives inside while-loop body computations are multiplied by
    ``loop_multiplier`` (the scan trip count, e.g. n_layers).
    """
    out = {k: 0.0 for k in _COLL_KINDS}
    out["raw_count"] = 0
    # Split into computations: lines like "%name (...) -> ... {" or
    # "ENTRY %name ...".  Track whether current computation is a loop body.
    current_is_body = False
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.endswith("{") and ("(" in ls):
            name = ls.split("(")[0].strip().lstrip("%")
            current_is_body = bool(re.search(r"body|while", name))
            continue
        for kind in _COLL_KINDS:
            # match "kind(" or "kind-start(" as the instruction opcode
            if re.search(rf"= *\S+ {re.escape(kind)}(-start)?\(", ls):
                shape_str = ls.split("=", 1)[1].split(kind)[0]
                nbytes = _shape_bytes(shape_str)
                n = _group_size(ls, default_group)
                mult = loop_multiplier if current_is_body else 1
                out[kind] += nbytes * _ring_factor(kind, n) * mult
                out["raw_count"] += 1
                break
    out["total_bytes"] = sum(out[k] for k in _COLL_KINDS)
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_collective: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.bytes_hbm,
            "collective_bytes_per_device": self.bytes_collective,
        }


def terms_from(cost: Dict, coll: Dict, *, peak=PEAK_FLOPS_BF16,
               hbm=HBM_BW, link=ICI_LINK_BW) -> RooflineTerms:
    if isinstance(cost, (list, tuple)):  # old jax: one dict per partition
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.get("total_bytes", 0.0))
    return RooflineTerms(
        compute_s=flops / peak,
        memory_s=nbytes / hbm,
        collective_s=cbytes / link,
        flops=flops,
        bytes_hbm=nbytes,
        bytes_collective=cbytes,
    )


def model_flops(arch, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), D = tokens/step.

    For decode shapes D = global_batch (one token each); training counts
    the full batch x seq.  Per-device value (divided by chip count) is
    reported alongside for direct comparison with cost_analysis numbers.
    """
    n_active = arch.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token each
