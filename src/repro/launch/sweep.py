"""Dry-run sweep driver: every (arch x shape x mesh) cell in a subprocess.

Subprocess isolation bounds host memory per cell and lets one failing cell
report an error row without killing the sweep.  Results append to a JSONL
file consumed by benchmarks/ and EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun.jsonl
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def cells(meshes=("single", "multi")):
    from repro.configs import ARCH_IDS
    from repro.configs.base import LM_SHAPES

    for mesh in meshes:
        for arch in ARCH_IDS:
            for shape in LM_SHAPES:
                yield arch, shape.name, mesh


def run_cell(arch, shape, mesh, out, timeout=1800):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mesh, "--out", out]
    if mesh == "multi":
        # multi-pod pass is the shardability proof; the roofline table is
        # single-pod only (spec), so skip the L1/L2 cost probes here.
        cmd.append("--no-exact-loops")
    env = dict(os.environ)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        ok = proc.returncode == 0
        if not ok:
            # dryrun already appended an error row unless it crashed hard
            tail = (proc.stdout + proc.stderr)[-2000:]
            if '"status"' not in proc.stdout:
                with open(out, "a") as f:
                    f.write(json.dumps({
                        "arch": arch, "shape": shape, "mesh": mesh,
                        "status": "crash", "error": tail}) + "\n")
    except subprocess.TimeoutExpired:
        with open(out, "a") as f:
            f.write(json.dumps({"arch": arch, "shape": shape, "mesh": mesh,
                                "status": "timeout"}) + "\n")
        ok = False
    return ok, time.time() - t0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args(argv)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    done = set()
    if os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except Exception:
                pass

    meshes = tuple(args.mesh.split(","))
    todo = [c for c in cells(meshes) if c not in done
            and (args.only_arch is None or c[0] == args.only_arch)]
    print(f"{len(todo)} cells to run ({len(done)} already done)")
    for i, (arch, shape, mesh) in enumerate(todo):
        ok, dt = run_cell(arch, shape, mesh, args.out)
        print(f"[{i+1}/{len(todo)}] {arch} x {shape} x {mesh}: "
              f"{'OK' if ok else 'FAIL'} ({dt:.0f}s)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
