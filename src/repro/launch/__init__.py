"""Launch layer: production mesh, dry-run lowering, roofline analysis,
train/serve entry points."""
