"""Production training launcher: mesh + sharding rules + fault-tolerant
loop for any --arch.

On real TPU pods this process runs per host under the usual multi-host
bootstrap (jax.distributed.initialize); on this container it degrades to
the single local device with identical code paths.  XLA flags for
compute/collective overlap (latency-hiding scheduler) are set here.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --steps 100 --smoke-arch
"""

import os

# collective/compute overlap: enable XLA's latency-hiding scheduler and
# async collectives (the TPU defaults; stated explicitly because they are
# part of the §Perf story)
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true",
)

import argparse
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, get_arch
from repro.models import init_params
from repro.train.data import DataConfig, TokenStream
from repro.train.fault import LoopConfig, run_loop
from repro.train.optimizer import OptConfig, init_opt, opt_kind_for
from repro.train.sharding import param_specs, set_rules
from repro.train.train_step import TrainConfig, build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--smoke-arch", action="store_true")
    ap.add_argument("--data-path", default=None,
                    help="raw token file (synthetic stream if omitted)")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke_arch)
    if args.smoke_arch:
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)

    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        # square-ish (data, model) mesh from whatever devices exist
        import numpy as np
        d = int(np.sqrt(n_dev))
        while n_dev % d:
            d -= 1
        from repro.core import compat
        mesh = compat.make_mesh((n_dev // d, d), ("data", "model"))
        set_rules({"batch": ("data",), "seq": None, "seq_attn": None,
                   "embed": None, "heads": None, "kv_heads": None,
                   "head_dim": None, "mlp": "model", "vocab": "model",
                   "expert": "model", "state": None})

    okind = opt_kind_for(cfg.name, cfg.param_count())
    tcfg = TrainConfig(opt=OptConfig(kind=okind, lr=args.lr))
    params = init_params(cfg, jax.random.PRNGKey(0))
    if mesh is not None:
        from repro.launch.specs import resolve_tree
        pspecs = resolve_tree(param_specs(params), params, mesh)
        params = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            params, pspecs)
    state = {"params": params, "opt": init_opt(params, tcfg.opt), "ef": None}

    step = jax.jit(build_train_step(cfg, tcfg))
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=0,
                                    path=args.data_path))

    def make_batch(tokens, labels):
        b = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if cfg.family == "vlm":
            b["image_embeds"] = jnp.zeros((args.batch, 4, cfg.d_model),
                                          cfg.dtype)
            b["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None],
                (3, args.batch, args.seq)).astype(jnp.int32)
        if cfg.enc_dec:
            b["frames"] = jnp.zeros((args.batch, args.seq, cfg.d_model),
                                    cfg.dtype)
        return b

    def on_step(i, m):
        if i % 10 == 0:
            print(f"step {i:5d} loss {m['loss']:.4f} "
                  f"({m['step_time_s']*1e3:.0f} ms)", flush=True)

    ctx = mesh if mesh is not None else _nullctx()
    with ctx:
        run_loop(step, state, stream,
                 LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                            ckpt_every=25, async_save=True),
                 make_batch=make_batch, on_step=on_step)


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
