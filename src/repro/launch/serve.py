"""Production serving launcher: batched LM decode and batched lattice-solve
serving.

LM path (``--arch``): batched autoregressive decode against KV/state caches,
as before.

Solve path (``--solve``): a shape-bucketed request scheduler for
multi-simulation serving.  Requests (source Fields) are admitted into
per-lattice-shape queues; each bucket owns a fixed number of batch *slots*
and replays ONE jitted convergence-masked batched CG iteration
(train.serve_step.build_cg_serve_step) over all of its slots — one fused
operator pallas_call + one fused masked-update pallas_call per tick,
regardless of how many requests are packed in.  Completed solves are
drained continuously: a converged (or max_iter'd) slot is harvested and
refilled from the queue at the next tick, while in-flight slots are
untouched — the masking is a bitwise select, so every request's
trajectory is identical to a dedicated single-lattice solve
(tests/test_serve.py asserts bit-identity against apps.milc.driver.solve).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke-arch
  PYTHONPATH=src python -m repro.launch.serve --solve --requests 6 --slots 2
"""

import argparse
import dataclasses
import time
from collections import deque
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BatchedField, Field, TargetConfig, telemetry


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One inversion request: solve M x = b for the bucket's operator."""
    rid: int
    b: Field


@dataclasses.dataclass(frozen=True)
class SolveOutcome:
    rid: int
    x: Field
    iterations: int
    residual: float


class _Bucket:
    """All state for one lattice shape: the operator, a FIFO admission
    queue, ``slots`` batch slots and the jitted masked-iteration step."""

    def __init__(self, u: Field, kappa: float, config: TargetConfig,
                 slots: int, tol: float, max_iter: int,
                 refine_every: int = 0):
        from repro.apps.milc.cg import make_wilson_op
        from repro.train.serve_step import build_cg_serve_step

        self.u, self.kappa, self.config = u, float(kappa), config
        self.tol, self.max_iter, self.slots = tol, max_iter, slots
        self.refine_every = int(refine_every)
        # refinement recomputes residuals against the high-precision (policy
        # free) operator, so admission must use the same reference operator
        _, self.apply_mdag, _ = make_wilson_op(u, self.kappa, config)
        self.step = build_cg_serve_step(u, self.kappa, config, tol=tol,
                                        max_iter=max_iter,
                                        refine_every=self.refine_every)
        self.queue: deque = deque()
        self.slot_rid: list = [None] * slots
        self.state = None  # lazily shaped from the first admitted source
        self.rhs = None    # per-slot rhs stack (kept for refinement restarts)
        self.iterations_run = 0
        # telemetry: per-shape-bucket metric names + in-flight request spans
        self.label = "x".join(map(str, u.lattice))
        self._req_spans: Dict[int, object] = {}

    # -- slot state ------------------------------------------------------

    def _init_state(self, proto: Field):
        from repro.apps.milc.cg import BatchedCGState

        z = BatchedField.zeros("x", self.slots, proto.ncomp, proto.lattice,
                               proto.layout, dtype=proto.dtype)
        v = jnp.zeros((self.slots,), proto.dtype)
        self.state = BatchedCGState(x=z, r=z, p=z, rr=v, b2=v,
                                    it=jnp.zeros((self.slots,), jnp.int32))
        self.rhs = z

    def _admit(self, slot: int, req: SolveRequest):
        """Pack a request into a free slot: rhs and |rhs|^2 come through the
        single-lattice M^dag / dot path (the exact values a dedicated
        ``cg`` solve would start from), then land in the batch via
        per-slot .at[slot].set writes — in-flight slots' bits never move."""
        from repro.apps.milc.cg import BatchedCGState, dot

        rhs = self.apply_mdag(req.b)
        if self.state is None:
            self._init_state(rhs)
        b2 = dot(rhs, rhs, self.config)
        st = self.state
        x0 = rhs.with_data(jnp.zeros_like(rhs.data))
        self.state = BatchedCGState(
            x=st.x.with_element(slot, x0),
            r=st.r.with_element(slot, rhs),
            p=st.p.with_element(slot, rhs),
            rr=st.rr.at[slot].set(b2),
            b2=st.b2.at[slot].set(b2),
            it=st.it.at[slot].set(0),
        )
        self.rhs = self.rhs.with_element(slot, rhs)
        self.slot_rid[slot] = req.rid
        telemetry.inc("serve.admitted")
        # admission->harvest latency span, closed by _harvest; admit_tick
        # is the bucket tick count BEFORE this tick's masked iteration, so
        # harvest_tick - admit_tick == the request's active iterations
        self._req_spans[req.rid] = telemetry.begin_span(
            "serve/request", rid=req.rid, bucket=self.label, slot=slot,
            admit_tick=self.iterations_run)

    def _harvest(self, slot: int) -> SolveOutcome:
        st = self.state
        out = SolveOutcome(
            rid=self.slot_rid[slot],
            x=st.x.element(slot),
            iterations=int(st.it[slot]),
            residual=float(st.rr[slot] / st.b2[slot]),
        )
        self.slot_rid[slot] = None
        telemetry.inc("serve.harvested")
        rspan = self._req_spans.pop(out.rid, None)
        if rspan is not None:
            rspan.end(harvest_tick=self.iterations_run,
                      iterations=out.iterations, residual=out.residual)
        return out

    # -- scheduler tick --------------------------------------------------

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_rid)

    def tick(self) -> Dict[int, SolveOutcome]:
        """Admit into free slots, run one masked batched iteration, drain
        finished slots.  Returns {rid: outcome} for requests that completed
        this tick."""
        from repro.apps.milc.cg import batched_cg_active

        # queue depth sampled before admission, occupancy after: the
        # oracle drain test replays exactly this schedule
        telemetry.sample(f"serve.queue_depth.{self.label}", len(self.queue))
        for slot in range(self.slots):
            if self.slot_rid[slot] is None and self.queue:
                self._admit(slot, self.queue.popleft())
        occupied = sum(r is not None for r in self.slot_rid)
        telemetry.sample(f"serve.slot_occupancy.{self.label}", occupied)
        if not occupied:
            return {}
        with telemetry.span("serve/tick", bucket=self.label,
                            tick=self.iterations_run + 1, occupied=occupied):
            if self.refine_every > 0:
                self.state = self.step(self.state, self.rhs)
            else:
                self.state = self.step(self.state)
        self.iterations_run += 1
        telemetry.inc("serve.ticks")
        telemetry.inc(f"serve.ticks.{self.label}")
        act = np.asarray(
            batched_cg_active(self.state, tol=self.tol,
                              max_iter=self.max_iter))
        done = {}
        for slot in range(self.slots):
            if self.slot_rid[slot] is not None and not act[slot]:
                out = self._harvest(slot)
                done[out.rid] = out
        return done


class SolveServer:
    """Shape-bucketed batched solve scheduler.

    ``register(u, kappa)`` declares the operator for requests on
    ``u.lattice``; ``submit`` enqueues sources; ``run`` drains every queue
    to completion, interleaving ticks across buckets so mixed-shape
    request streams make progress together.  Each bucket packs up to
    ``slots`` heterogeneous requests into one batched launch chain."""

    def __init__(self, config: TargetConfig, *, slots: int = 4,
                 tol: float = 1e-8, max_iter: int = 500,
                 refine_every: int = 0):
        self.config = config
        self.slots, self.tol, self.max_iter = slots, tol, max_iter
        self.refine_every = int(refine_every)
        self.buckets: Dict[Tuple[int, ...], _Bucket] = {}

    def register(self, u: Field, kappa: float,
                 slots: Optional[int] = None) -> None:
        """Declare the gauge field + kappa serving ``u.lattice``-shaped
        requests (one operator per shape bucket)."""
        self.buckets[u.lattice] = _Bucket(
            u, kappa, self.config, slots or self.slots, self.tol,
            self.max_iter, self.refine_every)

    def submit(self, req: SolveRequest) -> None:
        if req.b.lattice not in self.buckets:
            raise KeyError(
                f"no operator registered for lattice {req.b.lattice}; "
                f"known: {sorted(self.buckets)}")
        self.buckets[req.b.lattice].queue.append(req)

    def run(self) -> Dict[int, SolveOutcome]:
        """Tick all buckets round-robin until every queue and slot is
        drained.  Returns {rid: SolveOutcome}."""
        results: Dict[int, SolveOutcome] = {}
        with telemetry.span("serve/drain", buckets=len(self.buckets)) as ds:
            while any(b.busy for b in self.buckets.values()):
                for bucket in self.buckets.values():
                    if bucket.busy:
                        results.update(bucket.tick())
            ds.set(requests=len(results))
        return results


# -- CLI -------------------------------------------------------------------

def _main_decode(args):
    from repro.configs import get_arch
    from repro.models import init_params
    from repro.train.serve_step import build_serve_step, generate

    cfg = get_arch(args.arch, smoke=args.smoke_arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (args.batch, 8)),
                          jnp.int32)
    jit_step = jax.jit(build_serve_step(cfg))
    t0 = time.perf_counter()
    out = generate(params, cfg, prompts, steps=args.steps,
                   s_max=8 + args.steps + 8, jit_step=jit_step)
    dt = time.perf_counter() - t0
    print(f"{args.batch * args.steps} tokens in {dt:.2f}s")
    print(np.asarray(out)[0].tolist())


def _main_solve(args):
    from repro.apps.milc import driver, fields

    cfg = driver.MilcConfig(
        lattice=(4, 4, 4, 8), kappa=0.10, tol=1e-8, max_iter=args.steps,
        target=TargetConfig(args.engine, vvl=128,
                            plan_policy=args.plan_policy))
    server = SolveServer(cfg.target, slots=args.slots, tol=cfg.tol,
                         max_iter=cfg.max_iter,
                         refine_every=args.refine_every)
    shapes = [(4, 4, 4, 8), (4, 4, 8, 8)]
    for i, lat in enumerate(shapes):
        u = Field.from_numpy(
            "u", fields.random_su3_gauge(lat, seed=i, hot=cfg.hot), lat,
            cfg.layout)
        server.register(u, cfg.kappa)
        for j in range(args.requests // len(shapes)):
            b = Field.from_numpy(
                "b", fields.random_spinor(lat, seed=100 + 10 * i + j), lat,
                cfg.layout)
            server.submit(SolveRequest(rid=10 * i + j, b=b))
    t0 = time.perf_counter()
    results = server.run()
    dt = time.perf_counter() - t0
    ticks = sum(b.iterations_run for b in server.buckets.values())
    print(f"{len(results)} solves in {dt:.2f}s "
          f"({ticks} batched iterations across {len(server.buckets)} buckets)")
    for rid in sorted(results):
        r = results[rid]
        print(f"  rid={rid} lattice={r.x.lattice} iters={r.iterations} "
              f"residual={r.residual:.3e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=None, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--smoke-arch", action="store_true")
    ap.add_argument("--solve", action="store_true",
                    help="serve batched lattice solves instead of LM decode")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--engine", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--refine-every", type=int, default=0,
                    help="reliable-update period for mixed-precision "
                         "serving: every N active iterations a slot's "
                         "residual is recomputed exactly (b - A x) and "
                         "its search direction restarted; 0 disables")
    ap.add_argument("--plan-policy", default="default",
                    choices=["default", "tuned"],
                    help="lowering-plan policy for serving launches: "
                         "'tuned' picks persisted autotune winners "
                         "(rsplit split reductions included) from the "
                         "TARGETDP_TUNE_PATH table")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry for the run and write a Chrome "
                         "trace (load at ui.perfetto.dev) to PATH; also "
                         "prints the telemetry report snapshot")
    args = ap.parse_args()
    if args.trace:
        telemetry.enable()
        telemetry.configure_logging()
    if args.solve:
        _main_solve(args)
    else:
        if args.arch is None:
            ap.error("--arch is required unless --solve is given")
        _main_decode(args)
    if args.trace:
        print(telemetry.format_report())
        print(f"chrome trace: {telemetry.export_chrome_trace(args.trace)}")


if __name__ == "__main__":
    main()
