"""Production serving launcher: batched decode against KV/state caches.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke-arch
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import init_params
from repro.train.serve_step import build_serve_step, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--smoke-arch", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke_arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (args.batch, 8)),
                          jnp.int32)
    jit_step = jax.jit(build_serve_step(cfg))
    t0 = time.perf_counter()
    out = generate(params, cfg, prompts, steps=args.steps,
                   s_max=8 + args.steps + 8, jit_step=jit_step)
    dt = time.perf_counter() - t0
    print(f"{args.batch * args.steps} tokens in {dt:.2f}s")
    print(np.asarray(out)[0].tolist())


if __name__ == "__main__":
    main()
