"""Production mesh construction (16x16 per pod; 2 pods multi-pod).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (jax locks the device count on first backend
init, and tests/benches must see the single real CPU device).
"""

from __future__ import annotations


from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes that compose the data-parallel (batch) dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh) -> int:
    n = 1
    for ax in batch_axes(mesh):
        n *= mesh.shape[ax]
    return n
