import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax-importing statement — jax locks
the device count at first backend init; the dry-run (and only the
dry-run) needs 512 placeholder host devices so jax.make_mesh can build
the production meshes (16x16 single-pod, 2x16x16 multi-pod).

Per cell this lowers the *production* step function:
  train_*    build_train_step (remat + optimizer + FSDP/TP/SP shardings)
  prefill_*  forward (blockwise attention for 32k)
  decode_*   decode_step against a full-length cache
then ``.lower().compile()`` and records memory_analysis / cost_analysis /
parsed collective bytes into a JSON row for the roofline report.

Loop-exact costs: XLA's HloCostAnalysis counts a while-loop body ONCE, so
a scanned-layers module under-reports FLOPs/bytes by ~n_layers.  Each cell
is therefore additionally lowered at n_layers=1 and n_layers=2 (same
widths) and the per-layer delta is extrapolated:
    total(L) = cost(L1) + (L - 1) * (cost(L2) - cost(L1))
which is exact for scan (identical body per iteration) and needs no
HLO-text loop heuristics.  The FULL config is still compiled — that
compile is the runnability proof and supplies memory_analysis.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k \
      --mesh single --out out.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, get_shape, shape_supported
from repro.configs.base import ArchConfig, ShapeCfg
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    batch_sharding,
    cache_plan,
    opt_plan,
    params_plan,
    rules_for,
    sds,
    train_batch_plan,
)
from repro.models import decode_step, forward
from repro.train.optimizer import OptConfig, opt_kind_for
from repro.train.sharding import set_rules
from repro.train.train_step import TrainConfig, build_train_step


def _lower_one(cfg: ArchConfig, shape: ShapeCfg, mesh, opt_kind: str):
    """Lower + compile one config; return (compiled, lowered)."""
    set_rules(rules_for(cfg, shape, mesh))
    p_sds, pspecs, pshard = params_plan(cfg, mesh)

    with mesh:
        if shape.kind == "train":
            from repro import tuning as _tuning
            ocfg = OptConfig(kind=opt_kind)
            tcfg = TrainConfig(opt=ocfg,
                               microbatches=_tuning.get().microbatches)
            o_sds, oshard = opt_plan(cfg, p_sds, pspecs, mesh, ocfg)
            b_sds, bshard = train_batch_plan(cfg, shape, mesh)
            step = build_train_step(cfg, tcfg)
            fn = jax.jit(
                step,
                in_shardings=(pshard, oshard, None, bshard),
                out_shardings=(pshard, oshard, None, None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(p_sds, o_sds, None, b_sds)
        elif shape.kind == "prefill":
            b_sds, bshard = train_batch_plan(cfg, shape, mesh,
                                             with_labels=False)

            def prefill(params, batch):
                logits, _ = forward(params, cfg, batch)
                return logits

            fn = jax.jit(prefill, in_shardings=(pshard, bshard))
            lowered = fn.lower(p_sds, b_sds)
        else:  # decode
            c_sds, cshard = cache_plan(cfg, shape, mesh)
            bdp = batch_sharding(shape, mesh)
            tok_sds = sds((shape.global_batch,), jnp.int32)
            tok_shard = NamedSharding(mesh, P(bdp))

            def serve_step(params, cache, tokens):
                return decode_step(params, cfg, cache, tokens)

            fn = jax.jit(
                serve_step,
                in_shardings=(pshard, cshard, tok_shard),
                out_shardings=(None, cshard),
                donate_argnums=(1,),
            )
            lowered = fn.lower(p_sds, c_sds, tok_sds)
        compiled = lowered.compile()
    return compiled


def _reduced(cfg: ArchConfig, n: int) -> ArchConfig:
    kw = {"n_layers": n}
    if cfg.enc_dec:
        kw["n_enc_layers"] = n
    return dataclasses.replace(cfg, **kw)


def _cost_and_coll(compiled, mesh):
    cost = compiled.cost_analysis()
    coll = RL.parse_collectives(compiled.as_text(),
                                default_group=mesh.shape["model"],
                                loop_multiplier=1)
    return cost, coll


def lower_cell(arch_id: str, shape_name: str, mesh_kind: str,
               smoke: bool = False, exact_loops: bool = True,
               variant: str = None):
    from repro import tuning
    tuning.reset()
    if variant:
        tuning.set_tuning(**tuning.parse_variant(variant))
    cfg = get_arch(arch_id, smoke=smoke)
    shape = get_shape(shape_name)
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why,
                "variant": variant or "baseline"}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    opt_kind = opt_kind_for(cfg.name, cfg.param_count())

    t0 = time.time()
    compiled = _lower_one(cfg, shape, mesh, opt_kind)   # the runnability proof
    t_full = time.time() - t0
    mem = compiled.memory_analysis()

    if exact_loops and cfg.n_layers > 1:
        from repro import probe
        probe.set_probe(True)
        try:
            c1 = _lower_one(_reduced(cfg, 1), shape, mesh, opt_kind)
            c2 = _lower_one(_reduced(cfg, 2), shape, mesh, opt_kind)
        finally:
            probe.set_probe(False)
        cost1, coll1 = _cost_and_coll(c1, mesh)
        cost2, coll2 = _cost_and_coll(c2, mesh)
        L = cfg.n_layers

        def extrap(a, b):
            # clamp the per-layer delta at 0: GSPMD occasionally picks a
            # different strategy at L=1 vs L=2 (e.g. replicating a small
            # model), which would otherwise extrapolate negative traffic
            return max(a, a + (L - 1) * max(0.0, b - a))

        cost = {
            "flops": extrap(cost1.get("flops", 0.0), cost2.get("flops", 0.0)),
            "bytes accessed": extrap(cost1.get("bytes accessed", 0.0),
                                     cost2.get("bytes accessed", 0.0)),
        }
        coll = {k: extrap(coll1.get(k, 0.0), coll2.get(k, 0.0))
                for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute", "total_bytes")}
        coll["raw_count"] = coll1.get("raw_count", 0)
        cost_method = "L1/L2 extrapolation"
    else:
        cost, coll = _cost_and_coll(compiled, mesh)
        cost_method = "direct (body counted once!)"

    terms = RL.terms_from(cost, coll)
    n_dev = mesh.devices.size
    mf_total = RL.model_flops(cfg, shape)
    row = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "ok",
        "variant": variant or "baseline",
        "devices": int(n_dev),
        "compile_s": round(t_full, 1),
        "cost_method": cost_method,
        "memory": {
            "args_bytes": int(mem.argument_size_in_bytes),
            "out_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "live_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
                / 2**30, 3),
        },
        "roofline": terms.as_dict(),
        "collectives": {k: coll.get(k, 0.0) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute", "raw_count")},
        "model_flops_total": mf_total,
        "model_flops_per_device": mf_total / n_dev,
        "useful_flops_ratio": (mf_total / n_dev) / max(terms.flops, 1.0),
    }
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--out", default=None)
    ap.add_argument("--smoke-arch", action="store_true",
                    help="use the reduced config (debugging the harness)")
    ap.add_argument("--no-exact-loops", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="perf knobs, e.g. remat=dots,kv_block=2048")
    args = ap.parse_args(argv)

    try:
        row = lower_cell(args.arch, args.shape, args.mesh,
                         smoke=args.smoke_arch,
                         exact_loops=not args.no_exact_loops,
                         variant=args.variant)
    except Exception as e:
        row = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}

    print(json.dumps(row, indent=2))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(row) + "\n")
    return 0 if row["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
