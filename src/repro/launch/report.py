"""Render EXPERIMENTS.md tables from results/dryrun.jsonl.

  PYTHONPATH=src python -m repro.launch.report [--results results/dryrun.jsonl]
prints the §Dry-run and §Roofline markdown tables.
"""

from __future__ import annotations

import argparse
import json
from collections import OrderedDict


def load(path):
    rows = OrderedDict()
    for line in open(path):
        try:
            r = json.loads(line)
        except Exception:
            continue
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("variant", "baseline"))
        if key in rows and rows[key].get("status") in ("ok", "skipped") \
                and r.get("status") not in ("ok", "skipped"):
            continue  # keep the successful row over a later crash duplicate
        rows[key] = r
    return rows


def fmt_bytes(b):
    if b >= 2**30:
        return f"{b/2**30:.1f}G"
    if b >= 2**20:
        return f"{b/2**20:.1f}M"
    return f"{b/2**10:.0f}K"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | status | HBM live/dev | compile | collectives (AG/AR/RS/A2A/CP count) |",
           "|---|---|---|---|---|---|---|"]
    for (a, s, m, v), r in rows.items():
        if v != "baseline":
            continue
        if r["status"] == "ok":
            mem = f"{r['memory']['live_per_device_gib']:.2f} GiB"
            comp = f"{r.get('compile_s', 0):.0f}s"
            c = r["collectives"]
            cc = f"n={c.get('raw_count', 0)}"
            out.append(f"| {a} | {s} | {m} | ok | {mem} | {comp} | {cc} |")
        elif r["status"] == "skipped":
            reason = r.get("reason", "")[:60]
            out.append(f"| {a} | {s} | {m} | skip | — | — | {reason} |")
        else:
            out.append(f"| {a} | {s} | {m} | **{r['status']}** | — | — | "
                       f"{r.get('error', '')[:60]} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for (a, s, m, v), r in rows.items():
        if m != "single" or v != "baseline" or r["status"] != "ok":
            continue
        t = r["roofline"]
        step = max(t["compute_s"], t["memory_s"], t["collective_s"])
        # roofline fraction: ideal compute-bound time over the modeled step
        frac = t["compute_s"] / step if step else 0.0
        out.append(
            f"| {a} | {s} | {t['compute_s']:.4f} | {t['memory_s']:.4f} | "
            f"{t['collective_s']:.4f} | {t['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | {frac:.3f} |")
    return "\n".join(out)


def variants_table(rows):
    out = ["| arch | shape | variant | compute s | memory s | collective s | dominant |",
           "|---|---|---|---|---|---|---|"]
    have = False
    for (a, s, m, v), r in rows.items():
        if r["status"] != "ok":
            continue
        if v == "baseline" and not any(k[0] == a and k[1] == s and k[3] != "baseline" for k in rows):
            continue
        t = r["roofline"]
        out.append(f"| {a} | {s} | {v} | {t['compute_s']:.4f} | "
                   f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
                   f"{t['dominant']} |")
        have = True
    return "\n".join(out) if have else "(no variants yet)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun.jsonl")
    ap.add_argument("--section", default="all",
                    choices=("all", "dryrun", "roofline", "variants"))
    args = ap.parse_args()
    rows = load(args.results)
    if args.section in ("all", "dryrun"):
        print("### Dry-run\n")
        print(dryrun_table(rows))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline (single-pod)\n")
        print(roofline_table(rows))
        print()
    if args.section in ("all", "variants"):
        print("### Perf variants\n")
        print(variants_table(rows))


if __name__ == "__main__":
    main()
