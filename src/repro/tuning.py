"""Perf-tuning knobs for the §Perf hillclimb (trace-time configuration).

The dry-run accepts ``--variant k=v,...`` and installs values here before
lowering; each knob changes the lowered HLO, and the roofline terms
before/after are the measurement.  Knobs:

  remat        "full" (checkpoint everything, default), "dots" (save matmul
               outputs — jax dots_with_no_batch_dims_saveable policy),
               "none" (no rematerialization)
  q_block /    blockwise-attention tile sizes (long-sequence path)
  kv_block
  rwkv_chunk   WKV chunk length
  seq_shard    sequence-parallel activations in training (bool)
  logits_fp32  materialize fp32 logits in the loss (bool; False keeps
               logsumexp in bf16 inputs -> fp32 accum only)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax


@dataclasses.dataclass
class Tuning:
    remat: str = "full"
    q_block: int = 1024
    kv_block: int = 1024
    rwkv_chunk: int = 64
    seq_shard: bool = True
    logits_fp32: bool = True
    scores_bf16: bool = False   # bf16 attention scores, fp32 softmax stats
    attn_fast: bool = False     # transpose-free einsum order + additive mask
    microbatches: int = 1       # gradient-accumulation passes per step
    attn_seq_shard: bool = False  # force q-sequence sharding inside attention


_TUNING = Tuning()


def get() -> Tuning:
    return _TUNING


def set_tuning(**kw) -> Tuning:
    global _TUNING
    _TUNING = dataclasses.replace(_TUNING, **kw)
    return _TUNING


def reset() -> None:
    global _TUNING
    _TUNING = Tuning()


def checkpoint_wrap(fn):
    """Apply the configured remat policy to a scan body."""
    t = _TUNING
    if t.remat == "none":
        return fn
    if t.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def parse_variant(spec: Optional[str]) -> dict:
    """'remat=dots,kv_block=2048,seq_shard=0' -> kwargs dict."""
    if not spec:
        return {}
    out = {}
    for item in spec.split(","):
        k, v = item.split("=", 1)
        k = k.strip()
        if k in ("q_block", "kv_block", "rwkv_chunk", "microbatches"):
            out[k] = int(v)
        elif k in ("seq_shard", "logits_fp32", "scores_bf16", "attn_fast",
                   "attn_seq_shard"):
            out[k] = v.strip() not in ("0", "false", "False")
        else:
            out[k] = v.strip()
    return out
