from .domain import Domain  # noqa: F401
