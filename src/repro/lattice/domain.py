"""Domain decomposition of a global lattice over a named device mesh.

The paper's applications decompose the lattice across MPI ranks with halo
regions (§2.1).  Domain carries that geometry for the shard_map runtime:
which lattice dims map to which mesh axes, local shapes, halo width, and the
PartitionSpecs used to shard canonical (ncomp, *lattice) arrays.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import halo as _halo

__all__ = ["Domain"]


@dataclasses.dataclass(frozen=True)
class Domain:
    """Geometry of a decomposed lattice.

    global_shape   full lattice, e.g. (nx, ny, nz)
    mesh           jax Mesh (may be None for single-process use)
    dim_axes       per lattice dim: mesh axis name or None (not decomposed)
    halo           halo width (max stencil reach; 1 for D3Q19 & Wilson)
    """

    global_shape: Tuple[int, ...]
    mesh: Optional[Mesh] = None
    dim_axes: Tuple[Optional[str], ...] = ()
    halo: int = 1

    def __post_init__(self):
        if self.dim_axes and len(self.dim_axes) != len(self.global_shape):
            raise ValueError("dim_axes must match lattice rank")

    # -- shapes ----------------------------------------------------------------

    def axis_size(self, name: Optional[str]) -> int:
        if name is None or self.mesh is None:
            return 1
        return self.mesh.shape[name]

    @property
    def local_shape(self) -> Tuple[int, ...]:
        """Per-shard interior shape (no halos)."""
        out = []
        for d, n in enumerate(self.global_shape):
            ax = self.dim_axes[d] if self.dim_axes else None
            size = self.axis_size(ax)
            if n % size:
                raise ValueError(
                    f"lattice dim {d} ({n}) not divisible by mesh axis "
                    f"{ax} ({size})"
                )
            out.append(n // size)
        return tuple(out)

    @property
    def local_shape_halo(self) -> Tuple[int, ...]:
        return tuple(
            n + 2 * self.halo if (self.dim_axes and self.dim_axes[d]) else n
            for d, n in enumerate(self.local_shape)
        )

    @property
    def decomposed(self) -> Tuple[Tuple[int, str, int], ...]:
        """(array_dim_in_canonical_nd, mesh_axis, size) per decomposed dim.

        array dim is offset by 1 for the leading component axis.
        """
        out = []
        for d, ax in enumerate(self.dim_axes or ()):
            if ax is not None:
                out.append((d + 1, ax, self.axis_size(ax)))
        return tuple(out)

    # -- sharding --------------------------------------------------------------

    def spec(self) -> P:
        """PartitionSpec for canonical (ncomp, *lattice) arrays."""
        return P(None, *(self.dim_axes or (None,) * len(self.global_shape)))

    def sharding(self) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec())

    # -- halo ops (inside shard_map) --------------------------------------------

    def exchange(self, x_local: jax.Array) -> jax.Array:
        """Fill halos of a local (ncomp, *local_shape_halo) array."""
        return _halo.exchange(x_local, self.decomposed, width=self.halo)

    def add_halo(self, x_local: jax.Array) -> jax.Array:
        """Interior -> halo'd local array (halo values undefined until
        exchange)."""
        pads = [(0, 0)] * x_local.ndim
        for dim, _, _ in self.decomposed:
            pads[dim] = (self.halo, self.halo)
        return jnp.pad(x_local, pads)

    def strip_halo(self, x_local: jax.Array) -> jax.Array:
        idx = [slice(None)] * x_local.ndim
        for dim, _, _ in self.decomposed:
            idx[dim] = slice(self.halo, x_local.shape[dim] - self.halo)
        return x_local[tuple(idx)]

    @property
    def nsites_local(self) -> int:
        return math.prod(self.local_shape)

    @property
    def nsites_global(self) -> int:
        return math.prod(self.global_shape)
