"""Comms/compute overlap scheduler: interior/boundary split launches.

The paper's scaling story (§5, Fig. 5) composes targetDP with MPI halo
exchange, and per-step exchange becomes the scalability ceiling once the
subdomain thins.  Production lattice codes hide it by overlapping boundary
communication with interior compute — the decomposition the OpenACC LQCD
port of Bonati et al. (arXiv:1701.00426) uses to sustain multi-GPU scaling.
This module makes that schedule a *planned* lowering strategy
(``LoweringPlan.halo == "overlap"``) instead of a driver rewrite:

1. **start** the halo exchange of the boundary slabs (``core.halo`` —
   ppermute over the mesh; on TPU, ICI transfers),
2. run the fused kernel over the **interior** region whose stencil ring
   never reaches exchanged data — this sub-launch reads only locally-owned
   sites, so it has *no data dependence* on (1) and XLA is free to overlap
   the collective with the compute,
3. run thin **boundary-slab** sub-launches once the exchanged halos land,
4. assemble the slab outputs into the interior-lattice result.

Geometry
--------
Let ``R = max`` halo ring over the graph's external inputs and ``L_d`` the
local interior extent of lattice dim ``d``.  Output sites further than
``R`` from every decomposed subdomain face depend only on owned data; the
rest is covered by two thickness-``R`` slabs per decomposed dim (earlier
dims restricted to their interior range, later dims full — a disjoint
cover, so sites are computed exactly once).  Each slab runs the *same*
fused graph via ``LaunchGraph.launch(halo="pre")`` on a sliced window, so
the whole planning/caching machinery applies per sub-launch.

Numerics
--------
Field outputs are assembled from per-slab windows whose per-site
arithmetic is identical to the single ``halo="pre"`` launch — bit-identical
results (asserted under the 8-fake-device harness in
tests/test_distributed.py).  Terminal *reductions* are combined from
per-slab partials in deterministic slab order; that reassociates the
fp accumulation relative to the single-launch fold, so drivers that need
cross-strategy bit-stability (e.g. the CG inner products steering the
iteration) compute their dots from the assembled Fields instead — see
``apps/milc/driver.py``.

Entry points
------------
``execute_split``   called by ``LaunchGraph.launch`` when the resolved
                    plan says ``halo="overlap"``: splits a pre-exchanged
                    launch (all windows read one fully-valid halo'd array;
                    measures the split overhead, e.g. under the autotuner).
``overlap_launch``  the sharded form (inside shard_map): owns the
                    exchange, feeds the interior sub-launch from the
                    *unexchanged* padded arrays and the boundary
                    sub-launches from the exchanged ones — the real
                    comms/compute overlap.
``split_boxes``     the interior/boundary decomposition itself.
"""

from __future__ import annotations

import dataclasses
import logging
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from . import halo as halo_mod
from . import plan as plan_mod
from . import telemetry
from .field import BatchedField, Field
from .layout import SOA
from .plan import LoweringPlan
from .target import TargetConfig

__all__ = ["split_boxes", "execute_split", "overlap_launch"]

log = logging.getLogger(__name__)

# (start, stop) per lattice dim, in interior (output) coordinates
Box = Tuple[Tuple[int, int], ...]


def split_boxes(
    lattice: Sequence[int], ring: int, dims: Sequence[int]
) -> Tuple[Optional[Box], List[Box]]:
    """Interior/boundary decomposition of a local lattice.

    lattice  per-shard interior extents
    ring     boundary thickness: the max halo ring of the launch's inputs
    dims     lattice dims whose halos arrive by exchange (decomposed dims)

    Returns ``(interior_box, boundary_boxes)``: the interior box shrinks by
    ``ring`` along every dim in ``dims``; the boundary is covered by two
    thickness-``ring`` slabs per dim (dims earlier in the order restricted
    to their interior range — a disjoint cover).  Returns ``(None, [])``
    when some decomposed dim is too thin to hold an interior slab
    (``L - 2*ring < 1``) — callers fall back to ``halo="pre"``.
    """
    dims = sorted(set(int(d) for d in dims))
    for d in dims:
        if d < 0 or d >= len(lattice):
            raise ValueError(
                f"split dim {d} out of range for lattice {tuple(lattice)}")
    interior = [(0, L) for L in lattice]
    for d in dims:
        if lattice[d] - 2 * ring < 1:
            return None, []
        interior[d] = (ring, lattice[d] - ring)
    boxes: List[Box] = []
    for i, d in enumerate(dims):
        base = [(0, L) for L in lattice]
        for dj in dims[:i]:
            base[dj] = (ring, lattice[dj] - ring)
        lo = list(base)
        lo[d] = (0, ring)
        hi = list(base)
        hi[d] = (lattice[d] - ring, lattice[d])
        boxes.append(tuple(lo))
        boxes.append(tuple(hi))
    return tuple(interior), boxes


def _window(f, box: Box, ring: int):
    """Slice the halo'd window a sub-launch over ``box`` needs from a
    pre-halo'd input Field (ring ``ring``): halo'd coords
    ``[start, stop + 2*ring)`` per dim.  Windows stay SOA — arbitrary slab
    extents do not stay AoSoA-block-aligned, so ``sub_lattice_plan`` pins
    every sub-launch to the staged-nd view (a native-block outer plan still
    assembles into the requested output layout, bit-identically; the
    per-site arithmetic is view-independent).  BatchedField inputs window
    every batch element identically (the box geometry is per-lattice)."""
    nd = f.canonical_nd()
    site_sl = tuple(slice(s, e + 2 * ring) for (s, e) in box)
    if getattr(f, "batch", 0):
        w = nd[(slice(None), slice(None)) + site_sl]
        return BatchedField.from_canonical(f.name, w, tuple(w.shape[2:]), SOA)
    w = nd[(slice(None),) + site_sl]
    return Field.from_canonical(f.name, w, tuple(w.shape[1:]), SOA)


def _sub_plan(outer: LoweringPlan, config, box_lat: Tuple[int, ...]) -> LoweringPlan:
    """The per-slab plan: the outer (overlap) plan rebased onto the slab's
    lattice with halo='pre' (boundary slabs are thin, so the x-slab may
    shrink) — the planning layer owns the slab choice.  A tiled outer plan
    (by/bz) keeps its y/z tiles on every sub-launch whose sub-lattice they
    still divide (the interior always qualifies when tiles divide the
    shard; thin boundary slabs may fall back to whole-axis), so sharded
    ``halo="overlap"`` runs compose with >VMEM tiling."""
    return plan_mod.sub_lattice_plan(outer, config, box_lat, halo="pre")


def _split_launch(
    graph,
    ins_interior: Mapping[str, Field],
    ins_boundary: Mapping[str, Field],
    *,
    dims: Sequence[int],
    config: TargetConfig,
    outputs: Sequence[str],
    scalars: Optional[Mapping],
    out_layouts: Mapping,
    plan: LoweringPlan,
) -> Optional[Dict[str, Union[Field, jax.Array]]]:
    """Run the interior + boundary sub-launches and assemble.

    ``ins_interior`` feeds the interior box (safe to read before the halo
    exchange lands: the window never touches decomposed-dim halo slots);
    ``ins_boundary`` feeds the boundary slabs (must be fully exchanged).
    Returns None when the split is degenerate (caller falls back to pre).
    """
    ext = [n for n in graph.external_inputs() if n in ins_boundary]
    rings = graph.halo_widths(outputs)
    ring = max((rings.get(n, 0) for n in ext), default=0)
    first = ins_boundary[ext[0]]
    r0 = rings.get(ext[0], 0)
    lattice = tuple(s - 2 * r0 for s in first.lattice)
    if ring < 1:
        return None
    interior_box, boundary = split_boxes(lattice, ring, dims)
    if interior_box is None:
        return None

    red_names = set(graph._reduce_outputs())
    field_outputs = tuple(o for o in outputs if o not in red_names)
    red_outputs = tuple(o for o in outputs if o in red_names)
    red_specs = {o: s for o, s in graph.reduce_specs().items()
                 if o in red_outputs}

    out_layouts = dict(out_layouts or {})
    for o in field_outputs:
        out_layouts.setdefault(o, first.layout)

    def launch_box(box: Box, source: Mapping[str, Field]):
        sub_ins = {n: _window(source[n], box, rings.get(n, 0)) for n in ext}
        box_lat = tuple(e - s for (s, e) in box)
        return graph.launch(
            sub_ins,
            config=config,
            outputs=outputs,
            scalars=scalars,
            halo="pre",
            plan=_sub_plan(plan, config, box_lat),
        )

    # dependency order: the interior sub-launch first — it reads only
    # locally-owned sites, so XLA may run it concurrently with the halo
    # exchange the boundary sub-launches depend on.  The interior/boundary
    # spans make the split schedule visible as a trace (core.telemetry);
    # the nested launch/* spans are the sub-launches themselves.
    gname = getattr(graph, "name", "?")
    with telemetry.span("overlap/interior", graph=gname,
                        box=str(interior_box)):
        results = [(interior_box, launch_box(interior_box, ins_interior))]
    for box in boundary:
        with telemetry.span("overlap/boundary", graph=gname, box=str(box)):
            results.append((box, launch_box(box, ins_boundary)))

    batch = max((int(getattr(ins_boundary[n], "batch", 0)) for n in ext),
                default=0)
    out: Dict[str, Union[Field, jax.Array]] = {}
    for o in field_outputs:
        first_val = results[0][1][o]
        ncomp, dtype = first_val.ncomp, first_val.dtype
        lead = (batch, ncomp) if batch else (ncomp,)
        acc = jnp.zeros(lead + lattice, dtype)
        for box, res in results:
            starts = (0,) * len(lead) + tuple(s for (s, _) in box)
            acc = jax.lax.dynamic_update_slice(
                acc, res[o].canonical_nd(), starts)
        if batch:
            out[o] = BatchedField.from_canonical(o, acc, lattice,
                                                 out_layouts[o])
        else:
            out[o] = Field.from_canonical(o, acc, lattice, out_layouts[o])
    for o in red_outputs:
        # per-slab partials merge through the shared stage-2 combine
        # (ReduceSpec.combine_partials) — the same deterministic
        # segment-order fold the split-reduction (rsplit) lowering uses,
        # stacked in slab order (interior first, then boundary slabs)
        parts = jnp.stack([res[o] for _, res in results])
        out[o] = red_specs[o].combine_partials(parts, axis=0)
    return out


def execute_split(
    graph,
    ins: Mapping[str, Field],
    *,
    config: TargetConfig,
    outputs: Sequence[str],
    scalars: Optional[Mapping],
    out_layouts: Mapping,
    plan: LoweringPlan,
    dims: Optional[Sequence[int]] = None,
) -> Dict[str, Union[Field, jax.Array]]:
    """Split execution of a pre-exchanged halo'd launch (the
    ``LaunchGraph.launch`` backend for ``plan.halo == "overlap"``).

    All windows read the same fully-valid halo'd inputs, so this measures
    and exercises the split schedule without owning an exchange — the
    sharded form with a live exchange is :func:`overlap_launch`.  ``dims``
    defaults to every lattice dim (the worst-case split).  Falls back to a
    single ``halo="pre"`` launch (logged) when the interior is too thin.
    """
    ext = [n for n in graph.external_inputs() if n in ins]
    rings = graph.halo_widths(outputs)
    r0 = rings.get(ext[0], 0)
    lattice = tuple(s - 2 * r0 for s in ins[ext[0]].lattice)
    if dims is None:
        dims = range(len(lattice))
    out = _split_launch(
        graph, ins, ins, dims=dims, config=config, outputs=outputs,
        scalars=scalars, out_layouts=out_layouts, plan=plan)
    if out is not None:
        return out
    log.warning(
        "halo='overlap' for graph %r: interior of lattice %s too thin for "
        "ring %d along dims %s — falling back to halo='pre'",
        getattr(graph, "name", "?"), lattice,
        max((rings.get(n, 0) for n in ext), default=0), list(dims))
    return graph.launch(
        ins, config=config, outputs=outputs, scalars=scalars,
        out_layouts=out_layouts, halo="pre",
        plan=dataclasses.replace(plan, halo="pre"))


def _resolve_strategy(graph, ins, *, config, outputs, plan):
    """Which halo strategy a sharded launch should use, from the planning
    layer: an explicit plan (or the tuned table, keyed exactly as a
    halo='pre' launch) may choose 'overlap'; the default policy stays
    'pre' (bit-identical to the pre-overlap drivers)."""
    if plan is None:
        policy = getattr(config, "plan_policy", "default")
        if isinstance(policy, LoweringPlan):
            plan = policy
        elif policy == "tuned":
            from . import tune
            plan = tune.lookup(graph.plan_key(
                ins, config=config, outputs=outputs, halo="pre"))
    strategy = "overlap" if (plan is not None and plan.halo == "overlap") \
        else "pre"
    return strategy, plan


def overlap_launch(
    graph,
    ins: Mapping[str, Field],
    *,
    decomposed: Sequence[Tuple[int, str, int]],
    config: Optional[TargetConfig] = None,
    outputs: Optional[Sequence[str]] = None,
    scalars: Optional[Mapping] = None,
    out_layouts: Optional[Mapping] = None,
    halo: Optional[str] = None,
    exchanged: Sequence[str] = (),
    plan: Optional[LoweringPlan] = None,
) -> Dict[str, Union[Field, jax.Array]]:
    """Sharded halo'd launch with comms/compute overlap (inside shard_map).

    ins         graph value -> Field on the *padded* local lattice (every
                dim padded by that input's halo ring, non-decomposed dims
                wrap-filled — the ``halo="pre"`` contract *before* the
                exchange).  This function owns the exchange.
    decomposed  ``Domain.decomposed`` entries: (canonical-nd array dim,
                mesh axis name, mesh axis size) per decomposed lattice dim.
    halo        "pre" (exchange, then one launch — the legacy schedule),
                "overlap" (split schedule), or None: resolve from the
                planning layer (``config.plan_policy`` / tuned table —
                the default policy keeps "pre").
    exchanged   input names whose decomposed-dim halos are already valid
                (e.g. a gauge field exchanged once per solve) — skipped by
                the per-call exchange.

    Under "overlap" the interior sub-launch reads the *unexchanged* arrays
    (it only touches owned sites), so XLA sees no data dependence between
    it and the ppermutes — the collective and the interior compute may run
    concurrently; the boundary slabs read the exchanged arrays.  Falls
    back to "pre" (logged) when the interior is too thin.
    """
    config = config or TargetConfig()
    if not graph.has_stencil:
        raise ValueError(
            "overlap_launch applies only to graphs with stencil stages "
            "(site-local graphs have no halo to exchange)")
    if halo not in (None, "pre", "overlap"):
        raise ValueError(
            f"halo must be None, 'pre' or 'overlap', got {halo!r}")
    if outputs is None:
        outputs = [v for (_, v, _, _) in graph._stages[-1].outs]
    outputs = tuple(outputs)
    rings = graph.halo_widths(outputs)
    ext = [n for n in graph.external_inputs() if n in ins]

    # exchange every input by its ring over the decomposed dims (the
    # dimension-ordered exchange of core.halo, so corners land correctly).
    # The exchange span brackets the ppermute issue — against the
    # interior sub-launch span below, the overlap win is a visible trace
    # gap, not an assertion.
    ex_ins: Dict[str, Field] = {}
    with telemetry.span(
            "overlap/exchange", graph=getattr(graph, "name", "?"),
            inputs=",".join(n for n in ext if n not in exchanged),
            pre_exchanged=",".join(n for n in ext if n in exchanged),
            dims=str([d - 1 for (d, _, _) in decomposed])):
        for n in ext:
            f = ins[n]
            r = rings.get(n, 0)
            if n not in exchanged:
                # layout-preserving: AoSoA-backed shards come back as
                # AoSoA, so a native-block plan's "pre" fallback launch
                # stages them as-is
                ex_ins[n] = halo_mod.exchange_field(f, decomposed, width=r)
            else:
                ex_ins[n] = f

    if halo is None:
        strategy, plan = _resolve_strategy(
            graph, ex_ins, config=config, outputs=outputs, plan=plan)
    else:
        strategy = halo

    if strategy == "overlap":
        if plan is None:
            r0 = rings.get(ext[0], 0)
            lattice = tuple(s - 2 * r0 for s in ins[ext[0]].lattice)
            layouts = [ins[n].layout for n in ext]
            plan = plan_mod.default_plan(
                config, nsites=int(math.prod(lattice)), layouts=layouts,
                stencil=True, lattice=lattice, halo="pre")
        dims = [d - 1 for (d, _, _) in decomposed]
        out = _split_launch(
            graph, ins, ex_ins, dims=dims, config=config, outputs=outputs,
            scalars=scalars, out_layouts=out_layouts or {}, plan=plan)
        if out is not None:
            return out
        log.warning(
            "overlap_launch for graph %r: interior too thin for the halo "
            "ring along decomposed dims %s — falling back to halo='pre'",
            getattr(graph, "name", "?"), [d - 1 for (d, _, _) in decomposed])

    sub_plan = None
    if plan is not None:
        sub_plan = dataclasses.replace(plan, halo="pre")
    return graph.launch(
        ex_ins, config=config, outputs=outputs, scalars=scalars,
        out_layouts=out_layouts, halo="pre", plan=sub_plan)
