"""Halo exchange over the device mesh — the paper's MPI layer, on ICI.

targetDP handles intra-node parallelism; the paper composes it with MPI halo
exchange on a domain-decomposed lattice (§2.1, §5).  Here the inter-"rank"
layer is ``jax.shard_map`` over a named mesh and the exchange is
``jax.lax.ppermute`` (XLA collective-permute, which lowers to neighbour ICI
transfers on TPU — the "CUDA-aware MPI" the paper wishes for is the default:
halos move HBM->ICI->HBM with no host staging).

All functions here run *inside* shard_map.  Arrays are local canonical
views ``(ncomp, *local_lattice)`` whose site dims already include ``width``
halo slots at both ends of every decomposed dimension.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax import lax

__all__ = ["exchange_dim", "exchange", "axis_perms"]


def axis_perms(n: int):
    """Forward/backward neighbour permutations for a periodic 1-D rank line."""
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def _take(x, dim: int, lo: int, hi: int):
    idx = [slice(None)] * x.ndim
    idx[dim] = slice(lo, hi)
    return x[tuple(idx)]


def _put(x, dim: int, lo: int, hi: int, val):
    idx = [slice(None)] * x.ndim
    idx[dim] = slice(lo, hi)
    return x.at[tuple(idx)].set(val)


def exchange_dim(
    x: jax.Array, *, axis_name: str, axis_size: int, dim: int, width: int
) -> jax.Array:
    """Fill the two halo slabs of lattice dim ``dim`` from the neighbours.

    Periodic global topology (both applications use periodic boundaries at
    the decomposition level; physical walls are applied by the apps on top).
    With axis_size == 1 the self-permutation reproduces the periodic wrap.
    """
    n = axis_size
    fwd, bwd = axis_perms(n)
    L = x.shape[dim]
    lo_interior = _take(x, dim, width, 2 * width)
    hi_interior = _take(x, dim, L - 2 * width, L - width)
    # my high interior -> right neighbour's low halo
    recv_lo = lax.ppermute(hi_interior, axis_name, perm=fwd)
    # my low interior -> left neighbour's high halo
    recv_hi = lax.ppermute(lo_interior, axis_name, perm=bwd)
    x = _put(x, dim, 0, width, recv_lo)
    x = _put(x, dim, L - width, L, recv_hi)
    return x


def exchange(
    x: jax.Array,
    decomposed: Sequence[Tuple[int, str, int]],
    *,
    width: int,
) -> jax.Array:
    """Exchange halos over every decomposed lattice dim.

    decomposed: sequence of (array_dim, mesh_axis_name, mesh_axis_size).
    Exchanges are ordered so that corner/edge halos become correct (each
    pass includes the previously-filled halos of the other dims, the
    standard dimension-by-dimension MPI trick the paper's applications use).
    """
    for dim, axis_name, axis_size in decomposed:
        x = exchange_dim(
            x, axis_name=axis_name, axis_size=axis_size, dim=dim, width=width
        )
    return x
