"""Halo exchange over the device mesh — the paper's MPI layer, on ICI.

targetDP handles intra-node parallelism; the paper composes it with MPI halo
exchange on a domain-decomposed lattice (§2.1, §5).  Here the inter-"rank"
layer is ``jax.shard_map`` over a named mesh and the exchange is
``jax.lax.ppermute`` (XLA collective-permute, which lowers to neighbour ICI
transfers on TPU — the "CUDA-aware MPI" the paper wishes for is the default:
halos move HBM->ICI->HBM with no host staging).

All functions here run *inside* shard_map.  Arrays are local canonical
views ``(ncomp, *local_lattice)`` whose site dims already include ``width``
halo slots at both ends of every decomposed dimension.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
from jax import lax

__all__ = [
    "exchange_dim",
    "exchange",
    "exchange_field",
    "exchange_boundary",
    "start_exchange",
    "finish_exchange",
    "PendingExchange",
    "axis_perms",
]


def axis_perms(n: int):
    """Forward/backward neighbour permutations for a periodic 1-D rank line."""
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def _take(x, dim: int, lo: int, hi: int):
    idx = [slice(None)] * x.ndim
    idx[dim] = slice(lo, hi)
    return x[tuple(idx)]


def _put(x, dim: int, lo: int, hi: int, val):
    idx = [slice(None)] * x.ndim
    idx[dim] = slice(lo, hi)
    return x.at[tuple(idx)].set(val)


def exchange_dim(
    x: jax.Array, *, axis_name: str, axis_size: int, dim: int, width: int
) -> jax.Array:
    """Fill the two halo slabs of lattice dim ``dim`` from the neighbours.

    Periodic global topology (both applications use periodic boundaries at
    the decomposition level; physical walls are applied by the apps on top).
    With axis_size == 1 the self-permutation reproduces the periodic wrap.
    """
    n = axis_size
    fwd, bwd = axis_perms(n)
    L = x.shape[dim]
    if L < 3 * width:
        # the interior (L - 2*width) is thinner than the halo: the "interior"
        # slabs below would overlap the halo slots and silently exchange
        # corrupt data — refuse instead (thicken the local extent by using
        # fewer ranks along this dim, or shrink the stencil ring)
        raise ValueError(
            f"halo exchange of dim {dim}: local halo'd extent {L} is too "
            f"thin for width {width} (interior {L - 2 * width} < width; "
            f"need extent >= {3 * width})")
    lo_interior = _take(x, dim, width, 2 * width)
    hi_interior = _take(x, dim, L - 2 * width, L - width)
    # my high interior -> right neighbour's low halo
    recv_lo = lax.ppermute(hi_interior, axis_name, perm=fwd)
    # my low interior -> left neighbour's high halo
    recv_hi = lax.ppermute(lo_interior, axis_name, perm=bwd)
    x = _put(x, dim, 0, width, recv_lo)
    x = _put(x, dim, L - width, L, recv_hi)
    return x


def exchange(
    x: jax.Array,
    decomposed: Sequence[Tuple[int, str, int]],
    *,
    width: int,
) -> jax.Array:
    """Exchange halos over every decomposed lattice dim.

    decomposed: sequence of (array_dim, mesh_axis_name, mesh_axis_size).
    Exchanges are ordered so that corner/edge halos become correct (each
    pass includes the previously-filled halos of the other dims, the
    standard dimension-by-dimension MPI trick the paper's applications use).
    """
    for dim, axis_name, axis_size in decomposed:
        x = exchange_dim(
            x, axis_name=axis_name, axis_size=axis_size, dim=dim, width=width
        )
    return x


def exchange_field(f, decomposed: Sequence[Tuple[int, str, int]], *, width: int):
    """Halo-exchange a :class:`~repro.core.field.Field` whose lattice is the
    halo'd local lattice, returning a Field in the SAME physical layout.

    The AoSoA-backed-shard form of :func:`exchange`: the ppermutes run on
    the canonical-nd view (collectives move whole halo planes — the
    physical layout of the wire format is irrelevant), and the result is
    re-packed into the input's layout, so a downstream native-AoSoA stencil
    launch (``LoweringPlan.view == "block"``) receives the physical tile
    stack it stages as-is.  With ``width`` 0 or no decomposed dims the
    Field is returned untouched."""
    if width < 1 or not decomposed:
        return f
    nd = exchange(f.canonical_nd(), decomposed, width=width)
    return f.with_canonical(nd.reshape(f.ncomp, -1))


def exchange_boundary(
    x: jax.Array,
    decomposed: Sequence[Tuple[int, str, int]],
    *,
    width: int,
    dims: Sequence[int] = None,
) -> jax.Array:
    """Slab-granular exchange: fill only the halos of the listed lattice
    dims (array dims), in decomposition order.  ``dims=None`` exchanges
    everything (== :func:`exchange`).  The overlap scheduler
    (core.overlap) uses this to exchange exactly the boundary slabs its
    thin sub-launches consume."""
    wanted = None if dims is None else set(dims)
    for dim, axis_name, axis_size in decomposed:
        if wanted is not None and dim not in wanted:
            continue
        x = exchange_dim(
            x, axis_name=axis_name, axis_size=axis_size, dim=dim, width=width
        )
    return x


@dataclasses.dataclass(frozen=True)
class PendingExchange:
    """Handle returned by :func:`start_exchange`.

    The ppermutes are already part of the traced program, but nothing
    forces them to complete before unrelated compute: an interior
    sub-launch built between ``start_exchange`` and ``finish_exchange``
    has no data dependence on the exchanged array, so XLA's scheduler (and
    the TPU's async collectives) may run the two concurrently — the
    comms/compute overlap of core.overlap.  ``finish_exchange`` (or
    ``.array``) yields the fully exchanged array for the boundary
    sub-launches."""

    array: jax.Array


def start_exchange(
    x: jax.Array,
    decomposed: Sequence[Tuple[int, str, int]],
    *,
    width: int,
) -> PendingExchange:
    """Begin the dimension-ordered halo exchange of ``x`` and return a
    :class:`PendingExchange`; consume it with :func:`finish_exchange` only
    where the exchanged halos are actually read (the boundary slabs), so
    interior compute issued in between stays dependence-free."""
    return PendingExchange(exchange(x, decomposed, width=width))


def finish_exchange(pending: PendingExchange) -> jax.Array:
    """The exchanged array of a :func:`start_exchange` handle."""
    return pending.array
