"""Persisted per-(chain, layout, backend) plan autotuner (paper §3.2.2).

The paper tunes VVL per architecture by hand; this module does the sweep
the paper's authors did manually and *persists* the winners, so later
sessions (and `plan_policy="tuned"` launches) load the table instead of
re-sweeping.  One entry per plan key — (graph signature, input layouts and
dtypes, lattice shape, engine, halo strategy, requested outputs, jax
backend) — holding the winning :class:`~repro.core.plan.LoweringPlan` plus
the sweep timings for audit.

Table location: ``.targetdp_tune.json`` in the working directory, or the
``TARGETDP_TUNE_PATH`` environment variable.  The in-memory table is cached
per path; :func:`clear_table_cache` drops it (tests use this to simulate a
fresh process — the acceptance probe is *zero sweep launches* on a second
run that hits the persisted table).

The file is stamped with a ``schema_version``; a table whose version is
missing or unknown (e.g. written by an older build whose plans lacked the
``overlap`` halo strategy) degrades to an empty table — every lookup
misses and the tuner re-sweeps, rather than mis-decoding stale entries.

Usage::

    from repro.core import tune
    plan, info = tune.autotune_graph(graph, ins, config=cfg,
                                     outputs=("dist2", "u"))
    # later processes: TargetConfig(..., plan_policy="tuned") makes every
    # LaunchGraph.launch look its plan up in the persisted table.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import time
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import plan as plan_mod
from . import telemetry
from .plan import LoweringPlan

__all__ = [
    "DEFAULT_PATH",
    "ENV_VAR",
    "tune_path",
    "load_table",
    "save_table",
    "clear_table_cache",
    "lookup",
    "record",
    "block_view_for",
    "plan_candidates_for",
    "autotune_graph",
    "stats",
    "reset_stats",
]

DEFAULT_PATH = ".targetdp_tune.json"
ENV_VAR = "TARGETDP_TUNE_PATH"
# bumped to 2 when plans gained the "overlap" halo strategy: older tables
# (version 1 wrote a "version" key, no "schema_version") load as empty.
# bumped to 3 when plans gained the split-reduction axis ``rsplit``:
# persisted plan JSON must name the axis (a version-2 entry predates the
# tolerance-vs-bitwise reduction contract), so version-2 tables load as a
# clean miss — every lookup misses, the tuner re-sweeps and re-stamps.
# bumped to 4 when plans gained the mixed-precision ``dtypes`` policy
# (storage/compute/accumulate): a version-3 entry predates the accuracy
# gate, so version-3 tables load as a clean miss and the tuner re-sweeps
# (now with dtype-policy twins) rather than trusting an un-gated winner.
SCHEMA_VERSION = 4

log = logging.getLogger(__name__)

_TABLE: Optional[Dict[str, dict]] = None
_TABLE_PATH: Optional[str] = None

# sweep_launches counts timed candidate launches (incl. warmup): the
# "no re-sweep on a warm table" probe.  lookups/hits instrument the
# plan_policy="tuned" path.  The counters live in the core.telemetry
# registry under the "tune." prefix; stats()/reset_stats() are the
# back-compat shims over it (same keys as ever).
_STAT_KEYS = ("sweep_launches", "lookups", "hits", "tunes")


def stats() -> Dict[str, int]:
    return {k: telemetry.counter_value(f"tune.{k}") for k in _STAT_KEYS}


def reset_stats() -> None:
    telemetry.reset_counters("tune.")


# -- the persisted table -------------------------------------------------------

def tune_path() -> str:
    """Where the table lives: $TARGETDP_TUNE_PATH or ./.targetdp_tune.json."""
    return os.environ.get(ENV_VAR) or DEFAULT_PATH


def load_table(path: Optional[str] = None) -> Dict[str, dict]:
    """The in-memory table for ``path`` (lazy-loaded from disk, cached per
    path).  A missing or corrupt file — or one stamped with an unknown or
    missing ``schema_version`` (pre-overlap tables wrote no stamp) —
    yields an empty table: every lookup misses, so a schema change can
    trigger a re-sweep but never a mis-decoded plan, and tuning must
    never break a launch."""
    global _TABLE, _TABLE_PATH
    path = path or tune_path()
    if _TABLE is None or _TABLE_PATH != path:
        try:
            with open(path) as f:
                raw = json.load(f)
            entries = raw.get("entries", {})
            if raw.get("schema_version") != SCHEMA_VERSION:
                entries = {}
            _TABLE = dict(entries) if isinstance(entries, dict) else {}
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            _TABLE = {}
        _TABLE_PATH = path
    return _TABLE


def clear_table_cache() -> None:
    """Drop the in-memory table so the next access re-reads disk (what a
    fresh process would see)."""
    global _TABLE, _TABLE_PATH
    _TABLE, _TABLE_PATH = None, None


def save_table(path: Optional[str] = None) -> str:
    """Write the in-memory table to disk (atomic replace).  Returns path."""
    path = path or tune_path()
    table = load_table(path)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "entries": table}, f,
                  indent=2, sort_keys=True)
    os.replace(tmp, path)
    return path


def lookup(key: str, path: Optional[str] = None) -> Optional[LoweringPlan]:
    """The persisted winner for ``key``, or None (plan_policy="tuned" falls
    back to the default heuristics on a miss).  A structurally malformed
    entry (hand-edited table, truncated write, schema drift) is treated as
    a miss — tuning must never break a launch."""
    telemetry.inc("tune.lookups")
    entry = load_table(path).get(key)
    if entry is None:
        return None
    try:
        plan = LoweringPlan.from_json(dict(entry["plan"]))
        # structural sanity only (launch re-validates against the lattice);
        # stencil entries carry bx>0 or the overlap strategy, so validate
        # in the matching shape
        plan.validate(stencil=plan.bx > 0 or plan.halo == "overlap")
    except (KeyError, TypeError, ValueError):
        return None
    telemetry.inc("tune.hits")
    return plan


def record(
    key: str,
    plan: LoweringPlan,
    *,
    timings_us: Optional[Mapping[str, float]] = None,
    default: Optional[LoweringPlan] = None,
    meta: Optional[Mapping] = None,
    save: bool = True,
    path: Optional[str] = None,
) -> None:
    """Store ``plan`` as the winner for ``key`` (and persist by default)."""
    entry = {"plan": plan.to_json()}
    if timings_us:
        entry["timings_us"] = {k: round(float(v), 3)
                               for k, v in timings_us.items()}
    if default is not None:
        entry["default_plan"] = default.to_json()
    entry["meta"] = dict(meta or {})
    entry["meta"].setdefault("created", time.time())
    load_table(path)[key] = entry
    if save:
        save_table(path)


# -- the sweep -----------------------------------------------------------------

def _sweep(graph, ins, launch_kw, cands, iters: int, warmup: int):
    """Time every candidate: one warmup pass (compile) per candidate, then
    ``iters`` timed *round-robin* rounds — interleaving the candidates so
    machine drift biases them equally — taking the per-candidate min (the
    noise-robust estimator for ranking).  A candidate that raises (e.g. a
    slab over the VMEM budget on a real TPU) is recorded as failed and
    skipped, never aborting the sweep.  Every launch, warmup included,
    counts in the sweep_launches probe.

    Returns (times, failed): candidate -> best seconds / candidate ->
    error repr.  Telemetry: one ``tune/candidate`` span per candidate and
    timed round, a ``tune/failed`` instant per failure, and failures
    logged through the ``repro.core.tune`` logger."""
    def run(plan):
        out = graph.launch(ins, plan=plan, **launch_kw)
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
        telemetry.inc("tune.sweep_launches")

    gname = getattr(graph, "name", "?")

    def fail(cand, e):
        failed[cand] = repr(e)
        log.warning("tune sweep: candidate %s failed for graph %r: %r",
                    cand.describe(), gname, e)
        telemetry.event("tune/failed", graph=gname, plan=cand.describe(),
                        reason=repr(e))

    times: Dict[LoweringPlan, float] = {}
    failed: Dict[LoweringPlan, str] = {}
    sweep_span = telemetry.span("tune/sweep", graph=gname,
                                candidates=len(cands))
    for cand in cands:
        with telemetry.span("tune/candidate", graph=gname,
                            plan=cand.describe(), phase="warmup"):
            try:
                for _ in range(warmup):
                    run(cand)
            except Exception as e:  # noqa: BLE001 - any lowering failure
                fail(cand, e)
    for _ in range(max(1, iters)):
        for cand in cands:
            if cand in failed:
                continue
            cspan = telemetry.span("tune/candidate", graph=gname,
                                   plan=cand.describe(), phase="timed")
            try:
                t0 = time.perf_counter()
                run(cand)
                dt = time.perf_counter() - t0
            except Exception as e:  # noqa: BLE001
                cspan.end(error=repr(e))
                fail(cand, e)
                times.pop(cand, None)
                continue
            cspan.end(best_us=dt * 1e6)
            times[cand] = min(times.get(cand, dt), dt)
    sweep_span.end(timed=len(times), failed=len(failed))
    return times, failed


def _interior_lattice(graph, ins, outputs, halo) -> Tuple[int, ...]:
    """The lattice launch plans are made for: the first input's lattice,
    minus its halo ring when the caller pre-exchanged (halo='pre') — the
    same derivation LaunchGraph.launch performs, so autotune keys and
    tuned-policy lookup keys agree."""
    first_name = next(iter(ins))
    lattice = tuple(ins[first_name].lattice)
    if graph.has_stencil and halo in ("pre", "overlap"):
        ring = graph.halo_widths(outputs).get(first_name, 0)
        lattice = tuple(s - 2 * ring for s in lattice)
    return lattice


def block_view_for(graph, ins, outputs, halo="periodic") -> bool:
    """Precise native-AoSoA eligibility for this launch geometry
    (core.plan.block_view_ok): per-input halo'd inner-plane counts come
    from the graph's ring analysis, output layouts from the launch default
    (the first input's layout) — so the candidate sweep only proposes
    ``view="block"`` plans that will actually lower."""
    if not graph.has_stencil:
        return False
    outs = tuple(outputs) if outputs is not None else None
    rings = graph.halo_widths(outs)
    in_views = []
    for n, f in ins.items():
        r = rings.get(n, 0)
        hlat = (tuple(f.lattice) if halo in ("pre", "overlap")
                else tuple(s + 2 * r for s in f.lattice))
        inner_h = 1
        for s in hlat[1:]:
            inner_h *= s
        in_views.append((f.layout, inner_h))
    interior = _interior_lattice(graph, ins, outs, halo)
    interior_inner = 1
    for s in interior[1:]:
        interior_inner *= s
    first = next(iter(ins.values()))
    return plan_mod.block_view_ok(in_views, [first.layout], interior_inner)


def plan_candidates_for(
    graph,
    ins,
    *,
    config,
    outputs: Optional[Sequence[str]] = None,
    halo: str = "periodic",
    max_candidates: int = 8,
) -> Tuple[LoweringPlan, ...]:
    """Candidate plans for launching ``graph`` with ``ins`` (first entry is
    always the default heuristic plan) — the sweep set of autotune_graph,
    also what benchmarks use to time default-vs-tuned.  Stencil sweeps with
    an aligned AoSoA input include native-block (``view="block"``) twins,
    so a persisted winner can flip the hot halo'd launches to the native
    AoSoA lowering per backend.  Graphs ending in a terminal reduction
    additionally sweep split-reduction (``rsplit``) twins, so a persisted
    winner can flip the reduction to the two-stage partial lowering."""
    lattice = _interior_lattice(graph, ins, outputs, halo)
    nsites = 1
    for s in lattice:
        nsites *= s
    layouts = [f.layout for f in ins.values()]
    batch = max((int(getattr(f, "batch", 0)) for f in ins.values()),
                default=0)
    vmem_views = None
    if graph.has_stencil:
        # per-site staging shapes for the VMEM budget model — same
        # derivation LaunchGraph.launch feeds default_plan, so the sweep
        # filters (and logs) exactly the candidates a launch would reject
        outs = tuple(outputs) if outputs is not None else None
        rings = graph.halo_widths(outs)
        first = next(iter(ins.values()))
        prod = graph._produced()
        red = set(graph._reduce_outputs())
        names = outs if outs is not None else tuple(prod)
        out_views = []
        for o in names:
            if o in red or o not in prod:
                continue
            nc, dt = prod[o]
            out_views.append(
                (int(nc), jnp.dtype(dt or first.dtype).itemsize))
        vmem_views = (
            tuple((f.ncomp, rings.get(n, 0), jnp.dtype(f.dtype).itemsize)
                  for n, f in ins.items()),
            tuple(out_views),
        )
    in_dtype = str(jnp.dtype(next(iter(ins.values())).dtype))
    return plan_mod.candidate_plans(
        config, nsites=nsites, layouts=layouts, stencil=graph.has_stencil,
        lattice=lattice, halo=halo, max_candidates=max_candidates,
        block_view=block_view_for(graph, ins, outputs, halo), batch=batch,
        reduce=bool(graph._reduce_outputs()), vmem_views=vmem_views,
        in_dtype=in_dtype)


def _accuracy_gate_for(policy) -> float:
    """Default hard accuracy gate (max rel-L2 vs the fp64-accumulate
    baseline) for a dtype-policy candidate, scaled to how much precision
    its storage dtype throws away: half-precision storage gets a loose
    1e-2 gate, fp32 narrowing 1e-5, anything else (accumulate-only
    policies must be a strict improvement) 1e-6."""
    if policy.storage in ("bfloat16", "float16"):
        return 1e-2
    if policy.storage == "float32":
        return 1e-5
    return 1e-6


def _rel_l2(out, ref) -> float:
    """Relative L2 distance between two launch-output pytrees, pooled over
    every floating-point leaf (fields and reduction scalars alike)."""
    num = den = 0.0
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        b = jnp.asarray(b)
        if not jnp.issubdtype(b.dtype, jnp.floating):
            continue
        a32 = jnp.asarray(a).astype(jnp.float32)
        b32 = b.astype(jnp.float32)
        num += float(jnp.sum((a32 - b32) ** 2))
        den += float(jnp.sum(b32 ** 2))
    return (num / den) ** 0.5 if den > 0.0 else 0.0


def _gate_policy_candidates(graph, ins, launch_kw, cands, default,
                            accuracy_gate):
    """The hard accuracy constraint: every dtype-policy candidate is probed
    once against the fp64-accumulate baseline (the default plan with
    ``accumulate="float64"`` — resolved to compensated fp32 where fp64 is
    unavailable) and rejected — logged, never timed, never persisted —
    unless its pooled rel-L2 stays under the gate.  Returns
    (surviving candidates, rejected {plan: reason})."""
    pol_cands = [c for c in cands if c.dtypes]
    if not pol_cands:
        return cands, {}
    gname = getattr(graph, "name", "?")
    base = dataclasses.replace(
        default, dtypes=plan_mod.DtypePolicy(accumulate="float64"))
    with telemetry.span("tune/accuracy_baseline", graph=gname,
                        plan=base.describe()):
        ref = graph.launch(ins, plan=base, **launch_kw)
        jax.block_until_ready(jax.tree_util.tree_leaves(ref))
    rejected: Dict[LoweringPlan, str] = {}
    for cand in pol_cands:
        gate = (accuracy_gate if accuracy_gate is not None
                else _accuracy_gate_for(cand.dtypes))
        try:
            out = graph.launch(ins, plan=cand, **launch_kw)
            err = _rel_l2(out, ref)
        except Exception as e:  # noqa: BLE001 - any lowering failure
            rejected[cand] = f"accuracy probe raised: {e!r}"
            log.warning("tune accuracy gate: probe for %s failed on graph "
                        "%r: %r", cand.describe(), gname, e)
            telemetry.event("tune/accuracy_rejected", graph=gname,
                            plan=cand.describe(), reason=repr(e))
            continue
        if err > gate:
            rejected[cand] = f"rel_l2 {err:.3e} > gate {gate:.1e}"
            log.warning("tune accuracy gate: rejecting %s on graph %r: "
                        "rel_l2 %.3e exceeds gate %.1e",
                        cand.describe(), gname, err, gate)
            telemetry.event("tune/accuracy_rejected", graph=gname,
                            plan=cand.describe(), rel_l2=err, gate=gate)
    return [c for c in cands if c not in rejected], rejected


def autotune_graph(
    graph,
    ins,
    *,
    config,
    outputs: Optional[Sequence[str]] = None,
    scalars: Optional[Mapping] = None,
    out_layouts: Optional[Mapping] = None,
    halo: str = "periodic",
    iters: int = 3,
    warmup: int = 1,
    max_candidates: int = 8,
    min_gain: float = 0.05,
    force: bool = False,
    save: bool = True,
    path: Optional[str] = None,
    accuracy_gate: Optional[float] = None,
    cost_model: Optional[Callable[[LoweringPlan], float]] = None,
) -> Tuple[LoweringPlan, dict]:
    """Sweep candidate plans for one LaunchGraph launch and persist the
    winner.  Returns ``(plan, info)`` where info holds the key, whether the
    table already had it (``cached``), the per-candidate timings, and any
    failed candidates.

    A warm table short-circuits the sweep entirely (``info["cached"] is
    True``, zero sweep launches) unless ``force=True``.  Candidates come
    from :func:`repro.core.plan.candidate_plans`; each is timed with the
    ordinary launch machinery (same cache, same probes) in round-robin
    rounds.  ``min_gain`` is hysteresis toward the default heuristic plan:
    a candidate only dethrones it by beating it by more than that relative
    margin, so timing noise cannot persist a plan that is merely noisily
    fast.  Candidates whose lowering fails (e.g. over the VMEM budget) are
    skipped and recorded — logged in ``info["failed"]`` and the table
    entry, not silently dropped.

    Mixed precision: dtype-policy candidates face a *hard accuracy
    constraint* before they are ever timed — each is probed once against
    the fp64-accumulate baseline and rejected (logged to telemetry as
    ``tune/accuracy_rejected``, reported in ``info["rejected"]`` and the
    table entry meta, never persisted as a winner) unless its pooled
    rel-L2 stays under the gate.  ``accuracy_gate`` overrides the
    per-policy default (bf16/f16 storage 1e-2, fp32 storage 1e-5, else
    1e-6).  ``cost_model`` maps a candidate plan to a cost *multiplier*
    applied on top of its measured launch time — for solver graphs pass
    measured iterations-to-tolerance per policy so ranking (and the
    min_gain hysteresis) compares time-to-solution, not raw launch time."""
    lattice = _interior_lattice(graph, ins, outputs, halo)
    key = graph.plan_key(ins, config=config, outputs=outputs, halo=halo,
                         lattice=lattice)
    if not force:
        hit = lookup(key, path)
        if hit is not None:
            return hit, {"key": key, "cached": True}

    cands = plan_candidates_for(
        graph, ins, config=config, outputs=outputs, halo=halo,
        max_candidates=max_candidates)
    default = cands[0]

    launch_kw = dict(config=config, outputs=outputs, scalars=scalars,
                     out_layouts=out_layouts, halo=halo)
    telemetry.inc("tune.tunes")
    cands, rejected = _gate_policy_candidates(
        graph, ins, launch_kw, cands, default, accuracy_gate)
    times, failed = _sweep(graph, ins, launch_kw, cands, iters, warmup)
    if not times:
        raise RuntimeError(
            f"every candidate plan failed for {getattr(graph, 'name', '?')}: "
            f"{ {c.describe(): e for c, e in failed.items()} }")
    # convergence-aware ranking: a cost multiplier (e.g. measured
    # iterations-to-tolerance for a solver graph) scales each candidate's
    # launch time into an effective time-to-solution
    cost = (lambda c: times[c] * float(cost_model(c))) if cost_model \
        else (lambda c: times[c])
    best = min(times, key=lambda c: (cost(c), c.describe()))
    # hysteresis: keep the deterministic default unless the winner is
    # *measurably* better — noise must not persist an unproven plan
    if default in times and cost(best) > cost(default) * (1.0 - min_gain):
        best = default

    timings_us = {c.describe(): t * 1e6 for c, t in times.items()}
    failed_desc = {c.describe(): e for c, e in failed.items()}
    rejected_desc = {c.describe(): e for c, e in rejected.items()}
    record(key, best, timings_us=timings_us, default=default,
           meta={"graph": getattr(graph, "name", "?"),
                 "backend": jax.default_backend(),
                 "lattice": list(lattice),
                 "vmem_bytes": plan_mod.resolved_vmem_bytes(config),
                 "failed": failed_desc,
                 "rejected": rejected_desc},
           save=save, path=path)
    return best, {"key": key, "cached": False, "timings_us": timings_us,
                  "failed": failed_desc, "rejected": rejected_desc,
                  "default": default, "best_us": times[best] * 1e6}
