"""Launch graphs: fuse chains of site-local kernels into one device kernel.

The paper's kernels are memory-bandwidth bound (§4), so the dominant cost of
a multi-kernel timestep is the HBM round-trip between ``__targetLaunch__``es:
every intermediate field is written to HBM by one kernel and re-read by the
next.  A :class:`LaunchGraph` takes an ordered chain of
:class:`~repro.core.target.TargetKernel` stages whose outputs feed later
inputs, traces the composed body once, and lowers it to a **single**
``pl.pallas_call`` over the site-block grid — intermediates stay as values in
VMEM/VREGs and never touch HBM.  The jnp engine runs the same composed body
over whole-lattice canonical arrays (and is the fusion oracle).

Launch cache
------------
Each distinct (kernel chain, layouts, vvl, out_specs, input signature) is
built and ``jax.jit``-compiled once; repeated launches reuse the compiled
callable, so a timestep loop does not re-trace (a plain ``core.target.launch``
builds a fresh ``pallas_call`` per invocation).  The cache key is purely
structural — stage *params* must be static Python values.  Runtime scalars
(e.g. CG's traced alpha/beta) are passed via ``scalars=``: they become
``(1, 1)`` array arguments of the jitted callable (a VMEM block each program
reads), not cache-key material.

Probes: :func:`stats` counts traces and ``pallas_call`` constructions (each
fused pallas launch builds exactly one), so tests can assert both the
single-kernel lowering and cache hits.  :func:`clear_cache` /
:func:`reset_stats` give tests a clean slate.

Example::

    g = (LaunchGraph("chain")
         .add(body_a, ins={"x": "x"}, out_specs={"t": 3})
         .add(body_b, ins={"t": "t", "y": "y"}, out_specs={"out": 3}))
    out = g.launch({"x": fx, "y": fy}, config=TargetConfig("pallas"))["out"]

Stage ``ins`` maps body argument names to graph value names (external Field
inputs or earlier stage outputs); ``rename=`` relabels a body output in the
graph namespace so one body can appear in several stages.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .field import Field
from .layout import Layout
from .target import (
    TargetConfig,
    TargetKernel,
    build_in_specs,
    build_out_specs,
    resolve_vvl,
)

__all__ = [
    "LaunchGraph",
    "fused_launch",
    "stats",
    "reset_stats",
    "clear_cache",
]

_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
_CACHE_CAP = 256

_STATS = {"traces": 0, "pallas_calls": 0, "cache_hits": 0, "cache_misses": 0}


def stats() -> Dict[str, int]:
    """Launch-cache counters: traces (jit trace-time executions of a fused
    callable), pallas_calls (pallas_call constructions — one per fused pallas
    trace), cache_hits/cache_misses."""
    return dict(_STATS)


def reset_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def clear_cache() -> None:
    _CACHE.clear()


def _hashable(v) -> bool:
    try:
        hash(v)
    except TypeError:
        return False
    return True


@dataclasses.dataclass(frozen=True)
class _Stage:
    kernel: TargetKernel
    ins: Tuple[Tuple[str, str], ...]              # (body arg, graph value name)
    outs: Tuple[Tuple[str, str, int, object], ...]  # (body key, value, ncomp, dtype|None)
    params: Tuple[Tuple[str, object], ...]

    def signature(self):
        # keyed on the body *function*, not the TargetKernel wrapper, so
        # graphs rebuilt per call (e.g. per LudwigConfig) still hit the cache
        return (self.kernel.body, self.kernel.name, self.ins, self.outs, self.params)


class LaunchGraph:
    """An ordered chain of site-local kernel stages fused into one launch."""

    def __init__(self, name: str = "fused"):
        self.name = name
        self._stages: List[_Stage] = []

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"LaunchGraph({self.name}, stages={[s.kernel.name for s in self._stages]})"

    def add(
        self,
        kern: Union[TargetKernel, Callable],
        ins: Mapping[str, str],
        out_specs: Mapping[str, Union[int, Tuple[int, object]]],
        *,
        params: Optional[Mapping] = None,
        rename: Optional[Mapping[str, str]] = None,
    ) -> "LaunchGraph":
        """Append a stage.  Returns self (chainable).

        ins        body argument name -> graph value name.
        out_specs  body output key -> ncomp (or (ncomp, dtype)).
        rename     body output key -> graph value name (default: the key).
        params     static keyword arguments baked into the trace (and the
                   cache key).  Traced values must go through launch scalars.
        """
        if not isinstance(kern, TargetKernel):
            kern = TargetKernel(kern)
        params = dict(params or {})
        for k, v in params.items():
            # params are baked into the (hashed) cache key: traced values and
            # arrays must go through launch scalars instead
            if isinstance(v, (jax.core.Tracer, jax.Array)) or not _hashable(v):
                raise TypeError(
                    f"stage {kern.name!r} param {k!r} is a traced/array/"
                    f"unhashable value; pass runtime scalars via "
                    f"launch(..., scalars={{...}}) or use a static Python value"
                )
        rename = dict(rename or {})
        produced = {v for st in self._stages for (_, v, _, _) in st.outs}
        outs = []
        for body_key, spec in out_specs.items():
            ncomp, dtype = spec if isinstance(spec, tuple) else (spec, None)
            vname = rename.get(body_key, body_key)
            if vname in produced:
                raise ValueError(
                    f"graph value {vname!r} produced twice; use rename= to "
                    f"give stage {kern.name!r}'s output a fresh name"
                )
            produced.add(vname)
            outs.append((body_key, vname, int(ncomp), dtype))
        self._stages.append(
            _Stage(
                kern,
                tuple(sorted(ins.items())),
                tuple(outs),
                tuple(sorted(params.items())),
            )
        )
        return self

    # -- graph structure -------------------------------------------------------

    def external_inputs(self) -> List[str]:
        """Value names consumed but never produced by an earlier stage, in
        first-use order — what launch() must be fed as Fields or scalars."""
        produced, ext = set(), []
        for st in self._stages:
            for _, vname in st.ins:
                if vname not in produced and vname not in ext:
                    ext.append(vname)
            for _, vname, _, _ in st.outs:
                produced.add(vname)
        return ext

    def _produced(self) -> Dict[str, Tuple[int, object]]:
        return {
            vname: (ncomp, dtype)
            for st in self._stages
            for (_, vname, ncomp, dtype) in st.outs
        }

    def bytes_moved(
        self,
        ins_ncomp: Mapping[str, int],
        nsites: int,
        outputs: Optional[Sequence[str]] = None,
        itemsize: int = 4,
    ) -> Dict[str, int]:
        """HBM traffic model of this chain, fused vs unfused (paper Fig. 4
        counting: reads + writes, itemsize bytes per element).

        unfused: every stage reads all its inputs from and writes all its
        outputs to HBM.  fused: each distinct external input is read once and
        only the requested graph outputs are written.  Scalars are ignored.
        """
        ncomp = dict(ins_ncomp)
        for vname, (nc, _) in self._produced().items():
            ncomp[vname] = nc
        if outputs is None:
            outputs = [v for (_, v, _, _) in self._stages[-1].outs]
        unfused = 0
        for st in self._stages:
            for _, vname in st.ins:
                unfused += ncomp.get(vname, 0)
            for _, vname, nc, _ in st.outs:
                unfused += nc
        fused = sum(ncomp.get(n, 0) for n in self.external_inputs())
        fused += sum(ncomp[o] for o in outputs)
        return {
            "unfused": unfused * nsites * itemsize,
            "fused": fused * nsites * itemsize,
        }

    # -- execution --------------------------------------------------------------

    def launch(
        self,
        ins: Dict[str, Field],
        *,
        config: Optional[TargetConfig] = None,
        outputs: Optional[Sequence[str]] = None,
        scalars: Optional[Mapping] = None,
        out_layouts: Optional[Mapping[str, Layout]] = None,
    ) -> Dict[str, Field]:
        """Execute the fused chain (the multi-kernel __targetLaunch__).

        ins         graph value name -> input Field (all sharing nsites).
        outputs     graph value names to materialize as Fields (default: the
                    last stage's outputs).  Intermediates not listed here
                    never touch HBM on the pallas engine.
        scalars     graph value name -> runtime scalar (traced values OK);
                    bodies see them as (1, 1) arrays that broadcast.
        out_layouts graph output name -> Layout (default: first input's).
        """
        if not self._stages:
            raise ValueError("LaunchGraph has no stages")
        if not ins:
            raise ValueError("fused launch needs at least one input Field")
        config = config or TargetConfig()
        scalars = dict(scalars or {})

        first = next(iter(ins.values()))
        nsites = first.nsites
        bad = {k: f.lattice for k, f in ins.items() if f.lattice != first.lattice}
        if bad:
            raise ValueError(
                f"all Fields in a fused launch must share nsites and lattice "
                f"shape: {first.name!r} has {first.lattice}, mismatched {bad}"
            )

        double = sorted(set(ins) & set(scalars))
        if double:
            raise ValueError(
                f"value(s) {double} supplied as both input Fields and "
                f"scalars; each graph value must have exactly one binding"
            )
        ext = self.external_inputs()
        missing = [n for n in ext if n not in ins and n not in scalars]
        if missing:
            raise ValueError(
                f"graph consumes value(s) {missing} produced by no earlier "
                f"stage and not supplied as inputs or scalars"
            )
        ordered_ins = [n for n in ext if n in ins]
        ordered_scalars = [n for n in ext if n in scalars]

        prod = self._produced()
        if outputs is None:
            outputs = [v for (_, v, _, _) in self._stages[-1].outs]
        outputs = tuple(outputs)
        unknown = [o for o in outputs if o not in prod]
        if unknown:
            raise ValueError(f"requested outputs {unknown} produced by no stage")

        out_layouts = dict(out_layouts or {})
        for o in outputs:
            out_layouts.setdefault(o, first.layout)
        # resolve default dtypes now so they are part of the cache key
        out_info = {
            o: (prod[o][0], jnp.dtype(prod[o][1] or first.dtype)) for o in outputs
        }

        engine = config.engine
        if engine == "pallas":
            vvl = resolve_vvl(
                config,
                nsites,
                [ins[n].layout for n in ordered_ins]
                + [out_layouts[o] for o in outputs],
            )
            interpret = config.resolved_interpret()
        elif engine == "jnp":
            vvl, interpret = 0, False
        else:
            raise ValueError(f"unknown engine {engine!r}")

        key = (
            engine,
            vvl,
            interpret,
            nsites,
            tuple(st.signature() for st in self._stages),
            tuple(
                (n, ins[n].ncomp, str(ins[n].dtype), ins[n].layout)
                for n in ordered_ins
            ),
            tuple(ordered_scalars),
            outputs,
            tuple((o, out_layouts[o], str(out_info[o][1])) for o in outputs),
        )
        fn = _CACHE.get(key)
        if fn is None:
            _STATS["cache_misses"] += 1
            fn = self._build(
                engine=engine,
                ordered_ins=ordered_ins,
                in_meta=[(ins[n].ncomp, ins[n].layout) for n in ordered_ins],
                ordered_scalars=ordered_scalars,
                outputs=outputs,
                out_info=out_info,
                out_layouts=out_layouts,
                nsites=nsites,
                vvl=vvl,
                interpret=interpret,
            )
            _CACHE[key] = fn
            while len(_CACHE) > _CACHE_CAP:
                _CACHE.popitem(last=False)
        else:
            _STATS["cache_hits"] += 1
            _CACHE.move_to_end(key)

        datas = tuple(ins[n].data for n in ordered_ins)
        svals = tuple(
            jnp.asarray(scalars[n], first.dtype).reshape(1, 1)
            for n in ordered_scalars
        )
        results = fn(datas, svals)

        fields = {}
        for o, phys in zip(outputs, results):
            ncomp, _ = out_info[o]
            fields[o] = Field(o, ncomp, first.lattice, out_layouts[o], phys)
        return fields

    # -- lowering ---------------------------------------------------------------

    def _run_stages(self, values: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Composed body: one pass over all stages, in either engine's trace.
        ``values`` maps graph names to (ncomp, L) arrays (L = nsites for jnp,
        vvl inside the pallas kernel) plus (1, 1) scalars."""
        for st in self._stages:
            chunks = {arg: values[v] for arg, v in st.ins}
            outs = st.kernel.body(chunks, **dict(st.params))
            for body_key, vname, ncomp, _ in st.outs:
                arr = outs[body_key]
                if arr.shape[0] != ncomp:
                    raise ValueError(
                        f"stage {st.kernel.name!r} output {body_key!r} has "
                        f"ncomp {arr.shape[0]}, declared {ncomp}"
                    )
                values[vname] = arr
        return values

    def _build(
        self,
        *,
        engine: str,
        ordered_ins: Sequence[str],
        in_meta: Sequence[Tuple[int, Layout]],
        ordered_scalars: Sequence[str],
        outputs: Tuple[str, ...],
        out_info: Mapping[str, Tuple[int, object]],
        out_layouts: Mapping[str, Layout],
        nsites: int,
        vvl: int,
        interpret: bool,
    ) -> Callable:
        run_stages = self._run_stages

        if engine == "jnp":

            def fn(datas, svals):
                _STATS["traces"] += 1
                values = {}
                for n, (_, lay), d in zip(ordered_ins, in_meta, datas):
                    values[n] = lay.unpack(d)
                for n, s in zip(ordered_scalars, svals):
                    values[n] = s
                values = run_stages(values)
                return tuple(
                    out_layouts[o].pack(values[o].astype(out_info[o][1]))
                    for o in outputs
                )

            return jax.jit(fn)

        # pallas: the whole chain is ONE pallas_call over the site-block grid
        grid = (nsites // vvl,)
        nin, nsc = len(ordered_ins), len(ordered_scalars)
        in_specs = build_in_specs(in_meta, vvl) + [
            pl.BlockSpec((1, 1), lambda i: (0, 0)) for _ in range(nsc)
        ]
        out_shapes, out_block_specs = build_out_specs(
            outputs, out_info, out_layouts, nsites, vvl
        )
        name = self.name

        def fused_kernel(*refs):
            in_refs = refs[:nin]
            sc_refs = refs[nin : nin + nsc]
            out_refs = refs[nin + nsc :]
            values = {}
            for n, (ncomp, lay), r in zip(ordered_ins, in_meta, in_refs):
                values[n] = lay.block_to_canonical(r[...], ncomp, vvl)
            for n, r in zip(ordered_scalars, sc_refs):
                values[n] = r[...]
            values = run_stages(values)
            for o, r in zip(outputs, out_refs):
                ncomp, dtype = out_info[o]
                r[...] = out_layouts[o].canonical_to_block(
                    values[o].astype(dtype), ncomp, vvl
                )

        def fn(datas, svals):
            _STATS["traces"] += 1
            _STATS["pallas_calls"] += 1
            call = pl.pallas_call(
                fused_kernel,
                grid=grid,
                in_specs=in_specs,
                out_specs=(
                    out_block_specs if len(out_block_specs) > 1 else out_block_specs[0]
                ),
                out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
                interpret=interpret,
                name=name,
            )
            res = call(*datas, *svals)
            if len(outputs) == 1:
                res = (res,)
            return tuple(res)

        return jax.jit(fn)


def fused_launch(
    stages: Sequence[Tuple],
    ins: Dict[str, Field],
    *,
    config: Optional[TargetConfig] = None,
    outputs: Optional[Sequence[str]] = None,
    scalars: Optional[Mapping] = None,
    out_layouts: Optional[Mapping[str, Layout]] = None,
    name: str = "fused",
) -> Dict[str, Field]:
    """One-shot form: each stage is (kernel, ins, out_specs[, params[, rename]]).

    Equivalent to building a LaunchGraph and launching it; the launch cache
    keys on the stage bodies, so rebuilt graphs still hit."""
    g = LaunchGraph(name)
    for st in stages:
        kern, st_ins, st_outs = st[0], st[1], st[2]
        params = st[3] if len(st) > 3 else None
        rename = st[4] if len(st) > 4 else None
        g.add(kern, st_ins, st_outs, params=params, rename=rename)
    return g.launch(
        ins, config=config, outputs=outputs, scalars=scalars, out_layouts=out_layouts
    )
