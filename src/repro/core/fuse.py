"""Launch graphs: fuse chains of kernels into one device kernel.

The paper's kernels are memory-bandwidth bound (§4), so the dominant cost of
a multi-kernel timestep is the HBM round-trip between ``__targetLaunch__``es:
every intermediate field is written to HBM by one kernel and re-read by the
next.  A :class:`LaunchGraph` takes an ordered chain of
:class:`~repro.core.target.TargetKernel` stages whose outputs feed later
inputs, traces the composed body once, and lowers it to a **single**
``pl.pallas_call`` — intermediates stay as values in VMEM/VREGs and never
touch HBM.  The jnp engine runs the same composed body over whole-lattice
canonical arrays (and is the fusion oracle).

Three stage kinds (paper §2.1.1 classifies kernels as site-local vs stencil;
§3.2.3 adds reductions):

``add``          site-local ("map") stage: the body sees canonical
                 ``(ncomp, L)`` chunks, one value per site.
``add_stencil``  stencil stage: the body additionally receives a
                 ``gather(name, disp)`` closure returning the input window
                 displaced by ``disp`` (``out(r) = in(r - disp)``,
                 ``|disp| <= width`` per dim).  Neighbour reads resolve from
                 VMEM-resident halo'd blocks, not a separate launch.
``add_reduce``   terminal reduction stage (``target_sum``/``target_max``
                 semantics): each program folds its block into a per-block
                 partial and accumulates it into a single small buffer, so
                 the reduction input never materializes in HBM.

Stencil graphs lower under one of two canonical-view strategies
(``LoweringPlan.view``).  ``"staged-nd"`` (the default) unpacks every input
to a canonical SoA-nd view as XLA ops around the single kernel — layout
round-trips through HBM for AoSoA data.  ``"block"`` is the *native AoSoA*
lowering: a halo'd AoSoA input is staged whole into VMEM in its physical
``(nblocks, ncomp, SAL)`` tile shape, each program rebases its x-slab
window onto the block axis (``SAL | halo'd inner-plane count`` keeps every
window a whole number of short arrays) and un-/re-packs in VMEM, and an
aligned AoSoA output is written back as native blocks — so the paper's
layout sweep (§3.1) reaches the halo'd chains (LB step, fused CG) with no
XLA pack/unpack round-trip.  Both views run the identical composed body on
identical window values: bit-identical outputs, asserted in
tests/test_view.py.

Site-local-only graphs lower over the flat 1-D site-block grid exactly as
before.  Graphs containing a stencil stage lower over **x-slabs of the
halo'd lattice**: every external input is halo-padded by the ring the
backward width analysis (:meth:`LaunchGraph.halo_widths`) assigns it —
periodic single-shard via ``core.stencil.halo_pad`` (``halo="periodic"``),
or pre-exchanged by the caller through ``core.halo`` inside shard_map
(``halo="pre"``) — and staged whole into VMEM (overlapping slab windows are
not expressible as disjoint BlockSpec windows; see
``target.build_halo_in_specs``).  Site-local stages are recomputed on halo
sites so a downstream stencil stage can gather neighbours of an
*intermediate* (e.g. LB collision fused into propagation's gather); each
value carries a shrinking "valid ring" and a stencil stage consuming a
ring-0 value raises a clear error.

Launch cache
------------
Each distinct (kernel chain, layouts, LoweringPlan, out_specs, input
signature) is built and ``jax.jit``-compiled once; repeated launches reuse
the compiled callable, so a timestep loop does not re-trace.  The cache key
is purely structural — stage *params* must be static Python values.  Runtime
scalars (e.g. CG's traced alpha/beta) are passed via ``scalars=``.

Planning
--------
How a graph lowers (vvl for the flat site-block grid, the x-slab ``bx`` for
the halo'd stencil grid, interpret fallback, halo strategy, canonical-view
choice) is a :class:`~repro.core.plan.LoweringPlan`, resolved per launch
from ``config.plan_policy`` ("default" heuristics / persisted "tuned" table
via ``core.tune`` / explicit plan) or overridden with ``launch(...,
plan=...)`` — which is how the autotuner times candidate plans through this
very machinery.

Probes: :func:`stats` counts traces and ``pallas_call`` constructions (each
fused pallas launch builds exactly one), so tests can assert both the
single-kernel lowering and cache hits.

Example (the CG residual loop, stencil + reduction)::

    g = (LaunchGraph("cg_op")
         .add_stencil(dslash_body, {"psi": "p", "u": "u"}, {"d": 24}, width=1)
         .add(xpay_body, ins={"x": "p", "d": "d"}, out_specs={"ap": 24})
         .add(mul_body, ins={"x": "p", "y": "ap"}, out_specs={"prod": 24})
         .add_reduce("prod", op="sum", name="pap"))
    out = g.launch({"p": fp, "u": fu}, config=TargetConfig("pallas"),
                   outputs=("ap", "pap"))
    out["ap"]   # Field (interior lattice)
    out["pap"]  # jnp array (ncomp,) — per-component sum, never in HBM
"""

from __future__ import annotations

import dataclasses
import logging
import math
from collections import OrderedDict
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import plan as plan_mod
from . import telemetry
from .field import BatchedField, Field
from .layout import Layout, LayoutKind
from .plan import VIEW_BLOCK, LoweringPlan
from .stencil import halo_pad, halo_pad_physical
from .target import (
    TargetConfig,
    TargetKernel,
    build_block_out_specs,
    build_halo_in_specs,
    build_in_specs,
    build_out_specs,
    build_reduce_specs,
    build_slab_out_specs,
    build_split_reduce_specs,
    build_tiled_out_specs,
)

__all__ = [
    "LaunchGraph",
    "BoundLaunch",
    "ReduceSpec",
    "fused_launch",
    "kahan_fold",
    "reduce_combine",
    "stats",
    "reset_stats",
    "clear_cache",
]

log = logging.getLogger(__name__)

_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
_CACHE_CAP = 256

# launch-cache counters now live in the core.telemetry registry under the
# "fuse." prefix; stats()/reset_stats() below are back-compat shims over it
_STAT_KEYS = ("traces", "pallas_calls", "cache_hits", "cache_misses")

# reduction monoids, keyed by op name (the single source ReduceSpec wraps)
_RED_COMBINE = {"sum": lambda a, b: a + b, "max": jnp.maximum}
_RED_FOLD = {"sum": jnp.sum, "max": jnp.max}


def stats() -> Dict[str, int]:
    """Launch-cache counters: traces (jit trace-time executions of a fused
    callable), pallas_calls (pallas_call constructions — one per fused pallas
    trace), cache_hits/cache_misses.  Thin view over the ``fuse.*``
    counters of :mod:`repro.core.telemetry` (same keys as ever)."""
    return {k: telemetry.counter_value(f"fuse.{k}") for k in _STAT_KEYS}


def reset_stats() -> None:
    telemetry.reset_counters("fuse.")


def clear_cache() -> None:
    _CACHE.clear()


@dataclasses.dataclass(frozen=True)
class ReduceSpec:
    """One terminal reduction's metadata: the single definition of a
    reduction monoid that the fused lowering, the overlap scheduler's
    per-slab combine and the split-reduction stage-2 combine all consume
    (previously an ad-hoc ``reduce_info()`` string tuple plus a separate
    ``reduce_combine(op)`` lookup plus an inline monoid table).

    op       "sum" | "max".
    source   the graph value being folded (None for a bare-op spec).
    ncomp    per-component width when statically known from the producing
             stage (None when the reduced value is an external input —
             launch resolves it from the input Field).
    dtype    the accumulate dtype (None: the launch's default out dtype).
    """

    op: str
    source: Optional[str] = None
    ncomp: Optional[int] = None
    dtype: Optional[object] = None

    def __post_init__(self):
        if self.op not in _RED_COMBINE:
            raise ValueError(
                f"unknown reduction op {self.op!r}; have {list(_RED_COMBINE)}")

    @property
    def combine(self) -> Callable:
        """The monoid combine fn — how any two partials merge."""
        return _RED_COMBINE[self.op]

    def init(self, shape, dtype) -> jax.Array:
        """Identity-filled accumulator (dtype-aware: integer max starts at
        iinfo.min, not a float -inf cast)."""
        dt = jnp.dtype(dtype)
        if self.op == "max":
            if jnp.issubdtype(dt, jnp.integer):
                return jnp.full(shape, jnp.iinfo(dt).min, dt)
            return jnp.full(shape, -jnp.inf, dt)
        return jnp.zeros(shape, dt)

    def fold(self, x: jax.Array, axis: int = -1) -> jax.Array:
        """Per-block fold along ``axis`` (the site axis)."""
        return _RED_FOLD[self.op](x, axis=axis)

    def combine_partials(self, parts: jax.Array, axis: int = 0) -> jax.Array:
        """The stage-2 combine: fold stage-1 partials along ``axis`` by a
        sequential monoid combine in index order.  Deterministic (fixed
        association for a fixed partial count) — the overlap scheduler's
        slab partials and the split-reduction rsplit rows both combine
        through here, so both strategies share one numerics contract:
        exact for max and integer sums, tolerance-level reassociation
        relative to the unsplit fold for fp sums."""
        n = parts.shape[axis]
        idx = [slice(None)] * parts.ndim
        idx[axis] = 0
        acc = parts[tuple(idx)]
        for k in range(1, n):
            idx[axis] = k
            acc = self.combine(acc, parts[tuple(idx)])
        return acc


def reduce_combine(op: str) -> Callable:
    """The combine function of a reduction monoid (``"sum"``/``"max"``) —
    kept as a thin shim over :class:`ReduceSpec` for existing callers."""
    return ReduceSpec(op=op).combine


def kahan_fold(x: jax.Array, axis: int = -1) -> jax.Array:
    """Compensated (Kahan) summation along ``axis``: a sequential
    sum-plus-compensation scan whose error is O(eps), independent of the
    element count — the fp32 stand-in for fp64 accumulation on targets
    where jax x64 is disabled (``core.plan.resolve_accumulate``).  All
    other axes are carried elementwise, so a (ncomp, nsites) fold costs
    one scan of length nsites with (ncomp,) carries."""
    x = jnp.moveaxis(x, axis, 0)

    def step(carry, xi):
        s, c = carry
        y = xi - c
        t = s + y
        return (t, (t - s) - y), None

    zero = jnp.zeros(x.shape[1:], x.dtype)
    (s, _c), _ = jax.lax.scan(step, (zero, zero), x)
    return s


def _kahan_combine(acc: jax.Array, part: jax.Array) -> jax.Array:
    """Kahan combine for a widened ``(..., ncomp, 2)`` accumulator —
    column 0 the running sum, column 1 the running compensation — folding
    a ``(..., ncomp, 1)`` partial in.  This is the cross-block combine of
    a compensated fused reduction: per-block partials fold plainly in the
    compute dtype, the grid-sequential accumulation across blocks carries
    compensation (the hierarchical contract tests/test_dtype.py pins)."""
    s, c = acc[..., 0:1], acc[..., 1:2]
    y = part - c
    t = s + y
    return jnp.concatenate([t, (t - s) - y], axis=-1)


def _hashable(v) -> bool:
    try:
        hash(v)
    except TypeError:
        return False
    return True


def _block_geometry(
    ordered_ins: Sequence[str],
    in_meta: Sequence[Tuple[int, Layout]],
    in_lats: Sequence[Tuple[int, ...]],
    in_rings: Sequence[int],
    halo: str,
    view: str,
    out_layouts: Mapping[str, Layout],
    field_outputs: Sequence[str],
    lattice: Tuple[int, ...],
    tiled: bool = False,
) -> Tuple[List[Tuple[int, ...]], List[bool]]:
    """Per-input halo'd lattices and native-AoSoA staging flags for a
    stencil lowering.  Under ``view="block"`` this is the launch-time form
    of ``core.plan.block_view_ok``: raises ValueError (naming the offending
    value) when an AoSoA input/output is not block-aligned or when nothing
    in the launch is AoSoA at all.

    ``tiled`` (LoweringPlan.by/.bz set) applies the same discipline per
    tile: *input* alignment is unchanged — native windows still slice whole
    x-planes on the block axis, the y/z tile is cut after the VMEM unpack,
    so SAL-aligned tile edges come for free — but native AoSoA *outputs*
    degrade to canonical tile writes (a y/z tile is not a contiguous block
    run), so the output-alignment check does not apply and an AoSoA input
    is required for the view to pay at all."""
    # in "pre"/"overlap" mode the caller's lattices already carry the halo
    hlats = [
        tuple(s + (2 * ring if halo == "periodic" else 0) for s in lat)
        for lat, ring in zip(in_lats, in_rings)
    ]
    native_in = [False] * len(in_lats)
    if view != VIEW_BLOCK:
        return hlats, native_in
    aosoa_in_play = False
    for idx, ((ncomp, lay), hlat) in enumerate(zip(in_meta, hlats)):
        if lay.kind is not LayoutKind.AOSOA:
            continue
        aosoa_in_play = True
        inner_h = int(math.prod(hlat[1:]))
        if inner_h % lay.sal:
            raise ValueError(
                f"view='block': AoSoA(sal={lay.sal}) input "
                f"{ordered_ins[idx]!r} has halo'd inner-plane site "
                f"count {inner_h} not divisible by sal — x-slab "
                f"windows would split short arrays; use "
                f"view='staged-nd' or a conforming sal "
                f"(core.plan.block_view_ok)")
        native_in[idx] = True
    if tiled:
        if not aosoa_in_play:
            raise ValueError(
                "view='block' under a tiled plan (by/bz) lowers AoSoA "
                "*inputs* natively (tiled outputs always write canonical "
                "tiles), but no input layout of this launch is AoSoA — "
                "use view='staged-nd'")
        return hlats, native_in
    if not aosoa_in_play and not any(
            out_layouts[o].kind is LayoutKind.AOSOA for o in field_outputs):
        raise ValueError(
            "view='block' lowers AoSoA tiles natively, but no "
            "input or output layout of this launch is AoSoA — "
            "use view='staged-nd'")
    inner = int(math.prod(lattice[1:]))
    bad = [o for o in field_outputs
           if out_layouts[o].kind is LayoutKind.AOSOA
           and inner % out_layouts[o].sal]
    if bad:
        raise ValueError(
            f"view='block': AoSoA output(s) {bad} have sal not "
            f"dividing the interior inner-plane site count {inner} "
            f"— slab rows would split short arrays; use "
            f"view='staged-nd' or a conforming sal")
    return hlats, native_in


def _stage_in_cast(storage_dt, compute_dt, in_dtypes):
    """The DtypePolicy stage-in cast over a launch's input arrays: floating
    inputs truncate to the storage dtype (the fidelity cost — and the HBM
    bytes cut — of narrow storage) and upcast to the effective compute
    dtype for kernel arithmetic; non-float inputs pass through bitwise.
    Returns None when the policy casts nothing (the bitwise default)."""
    if storage_dt is None and compute_dt is None:
        return None
    cdt = compute_dt or storage_dt
    floats = tuple(jnp.issubdtype(jnp.dtype(dt), jnp.floating)
                   for dt in in_dtypes)

    def cast(datas):
        out = []
        for d, isf in zip(datas, floats):
            if isf:
                if storage_dt is not None and d.dtype != storage_dt:
                    d = d.astype(storage_dt)
                if d.dtype != cdt:
                    d = d.astype(cdt)
            out.append(d)
        return tuple(out)

    return cast


def _crop_ring(arr: jax.Array, r_from: int, r_to: int) -> jax.Array:
    """Shrink an (ncomp, *window) value from valid ring r_from to r_to."""
    if r_from == r_to:
        return arr
    d = r_from - r_to
    sl = (slice(None),) + tuple(slice(d, s - d) for s in arr.shape[1:])
    return arr[sl]


@dataclasses.dataclass(frozen=True)
class _Stage:
    kernel: Optional[TargetKernel]
    ins: Tuple[Tuple[str, str], ...]              # (body arg, graph value name)
    outs: Tuple[Tuple[str, str, Optional[int], object], ...]
    params: Tuple[Tuple[str, object], ...]
    kind: str = "map"                             # "map" | "stencil" | "reduce"
    width: int = 0                                # stencil halo reach
    op: str = ""                                  # reduce monoid

    def signature(self):
        # keyed on the body *function*, not the TargetKernel wrapper, so
        # graphs rebuilt per call (e.g. per LudwigConfig) still hit the cache
        body = self.kernel.body if self.kernel is not None else None
        name = self.kernel.name if self.kernel is not None else self.op
        return (self.kind, self.width, self.op, body, name, self.ins,
                self.outs, self.params)


class LaunchGraph:
    """An ordered chain of kernel stages fused into one launch."""

    def __init__(self, name: str = "fused"):
        self.name = name
        self._stages: List[_Stage] = []
        # telemetry: bytes_moved is a per-shape constant but a full graph
        # walk — memoized so the launch span costs O(dict lookup), keeping
        # the enabled path under the CI <=1% overhead gate
        self._bytes_memo: Dict[tuple, Dict[str, int]] = {}

    def __repr__(self):  # pragma: no cover - cosmetic
        names = [s.kernel.name if s.kernel else f"reduce:{s.op}"
                 for s in self._stages]
        return f"LaunchGraph({self.name}, stages={names})"

    # -- construction ----------------------------------------------------------

    def _check_not_after_reduce(self, kind: str, name: str) -> None:
        red = [s for s in self._stages if s.kind == "reduce"]
        if red:
            raise ValueError(
                f"{kind} stage {name!r} cannot follow a reduction stage: a "
                f"reduction changes the value shape (per-site lattice -> "
                f"per-component), so only further terminal reductions may "
                f"come after it"
            )

    def _prepare_stage(self, kern, ins, out_specs, params, rename):
        if not isinstance(kern, TargetKernel):
            kern = TargetKernel(kern)
        params = dict(params or {})
        for k, v in params.items():
            # params are baked into the (hashed) cache key: traced values and
            # arrays must go through launch scalars instead
            if isinstance(v, (jax.core.Tracer, jax.Array)) or not _hashable(v):
                raise TypeError(
                    f"stage {kern.name!r} param {k!r} is a traced/array/"
                    f"unhashable value; pass runtime scalars via "
                    f"launch(..., scalars={{...}}) or use a static Python value"
                )
        rename = dict(rename or {})
        produced = {v for st in self._stages for (_, v, _, _) in st.outs}
        outs = []
        for body_key, spec in out_specs.items():
            ncomp, dtype = spec if isinstance(spec, tuple) else (spec, None)
            vname = rename.get(body_key, body_key)
            if vname in produced:
                raise ValueError(
                    f"graph value {vname!r} produced twice; use rename= to "
                    f"give stage {kern.name!r}'s output a fresh name"
                )
            produced.add(vname)
            outs.append((body_key, vname, int(ncomp), dtype))
        return kern, tuple(sorted(ins.items())), tuple(outs), tuple(
            sorted(params.items()))

    def add(
        self,
        kern: Union[TargetKernel, Callable],
        ins: Mapping[str, str],
        out_specs: Mapping[str, Union[int, Tuple[int, object]]],
        *,
        params: Optional[Mapping] = None,
        rename: Optional[Mapping[str, str]] = None,
    ) -> "LaunchGraph":
        """Append a site-local stage.  Returns self (chainable).

        ins        body argument name -> graph value name.
        out_specs  body output key -> ncomp (or (ncomp, dtype)).
        rename     body output key -> graph value name (default: the key).
        params     static keyword arguments baked into the trace (and the
                   cache key).  Traced values must go through launch scalars.
        """
        kern, ins_t, outs, params_t = self._prepare_stage(
            kern, ins, out_specs, params, rename)
        self._check_not_after_reduce("site-local", kern.name)
        self._stages.append(_Stage(kern, ins_t, outs, params_t))
        return self

    def add_stencil(
        self,
        kern: Union[TargetKernel, Callable],
        ins: Mapping[str, str],
        out_specs: Mapping[str, Union[int, Tuple[int, object]]],
        *,
        width: int = 1,
        params: Optional[Mapping] = None,
        rename: Optional[Mapping[str, str]] = None,
    ) -> "LaunchGraph":
        """Append a stencil stage reaching ``width`` sites per lattice dim.

        The body signature gains a gather closure::

            def body(v, gather, **params) -> dict

        ``v[arg]`` is the centered (ncomp, *window) value; ``gather(arg, d)``
        is the same window displaced by ``d`` (``out(r) = in(r - d)``,
        ``|d_j| <= width``).  Bodies see nd windows, not flat chunks, because
        displacement is geometric.  Inputs must be valid on a ring >= width:
        external Fields are halo-padded automatically (periodic) or by the
        caller (``halo="pre"``); intermediates are valid wherever earlier
        stages computed them (site-local stages recompute on halo sites).
        """
        if width < 1:
            raise ValueError(f"stencil stage needs width >= 1, got {width}")
        kern, ins_t, outs, params_t = self._prepare_stage(
            kern, ins, out_specs, params, rename)
        self._check_not_after_reduce("stencil", kern.name)
        self._stages.append(
            _Stage(kern, ins_t, outs, params_t, kind="stencil",
                   width=int(width)))
        return self

    def add_reduce(
        self, value: str, op: str = "sum", *, name: Optional[str] = None
    ) -> "LaunchGraph":
        """Append a terminal reduction of graph value ``value`` over all
        (interior) sites.  The result, named ``name`` (default
        ``"{value}_{op}"``), is returned by launch() as a per-component
        ``(ncomp,)`` jnp array — it is an accumulator, not a Field, and its
        per-site input never touches HBM on the pallas engine."""
        if op not in _RED_COMBINE:
            raise ValueError(
                f"unknown reduction op {op!r}; have {list(_RED_COMBINE)}")
        out_name = name or f"{value}_{op}"
        reduced = {v for st in self._stages if st.kind == "reduce"
                   for (_, v, _, _) in st.outs}
        if value in reduced:
            raise ValueError(
                f"cannot reduce {value!r}: it is itself a reduction result")
        produced = {v for st in self._stages for (_, v, _, _) in st.outs}
        if out_name in produced:
            raise ValueError(f"graph value {out_name!r} produced twice")
        self._stages.append(
            _Stage(None, (("x", value),), (("out", out_name, None, None),),
                   (), kind="reduce", op=op))
        return self

    # -- graph structure -------------------------------------------------------

    @property
    def has_stencil(self) -> bool:
        return any(st.kind == "stencil" for st in self._stages)

    def external_inputs(self) -> List[str]:
        """Value names consumed but never produced by an earlier stage, in
        first-use order — what launch() must be fed as Fields or scalars."""
        produced, ext = set(), []
        for st in self._stages:
            for _, vname in st.ins:
                if vname not in produced and vname not in ext:
                    ext.append(vname)
            for _, vname, _, _ in st.outs:
                produced.add(vname)
        return ext

    def _produced(self) -> Dict[str, Tuple[Optional[int], object]]:
        return {
            vname: (ncomp, dtype)
            for st in self._stages
            for (_, vname, ncomp, dtype) in st.outs
        }

    def _reduce_outputs(self) -> List[str]:
        return [v for st in self._stages if st.kind == "reduce"
                for (_, v, _, _) in st.outs]

    def reduce_specs(self) -> Dict[str, ReduceSpec]:
        """reduce output name -> :class:`ReduceSpec` — the one definition of
        this graph's reduction metadata, consumed by the overlap
        scheduler's per-slab combine and the split-reduction stage-2
        combine.  The mapping is exact per (output, input) pair: a reduce
        stage folds exactly one graph value, and a stage that somehow
        carries several inputs is rejected here rather than silently keyed
        on the last one (which would mis-combine overlap partials).
        ``ncomp`` is filled in when the reduced value is produced by an
        earlier stage (None for reductions of external inputs — launch
        resolves those from the input Field)."""
        prod = self._produced()
        specs: Dict[str, ReduceSpec] = {}
        for st in self._stages:
            if st.kind != "reduce":
                continue
            if len(st.ins) != 1:
                raise ValueError(
                    f"reduce stage producing {[o for (_, o, _, _) in st.outs]} "
                    f"has {len(st.ins)} inputs {[v for (_, v) in st.ins]}; a "
                    f"terminal reduction folds exactly one graph value")
            ((_, vname),) = st.ins
            for (_, out, _, dtype) in st.outs:
                specs[out] = ReduceSpec(
                    op=st.op, source=vname,
                    ncomp=prod.get(vname, (None, None))[0], dtype=dtype)
        return specs

    def reduce_info(self) -> Dict[str, Tuple[str, str]]:
        """reduce output name -> (source graph value, monoid op): the
        legacy string-tuple view of :meth:`reduce_specs`, kept for
        existing callers."""
        return {o: (s.source, s.op) for o, s in self.reduce_specs().items()}

    def _required_rings(self, outputs: Sequence[str]) -> Dict[str, int]:
        """Backward width analysis: minimum valid halo ring each graph value
        needs so the requested outputs are exact on the interior."""
        need: Dict[str, int] = {o: 0 for o in outputs}
        for st in reversed(self._stages):
            if st.kind == "reduce":
                for _, v in st.ins:
                    need[v] = max(need.get(v, 0), 0)
                continue
            r = max((need.get(v, 0) for (_, v, _, _) in st.outs), default=0)
            w = st.width if st.kind == "stencil" else 0
            for _, v in st.ins:
                need[v] = max(need.get(v, 0), r + w)
        return need

    def halo_widths(
        self, outputs: Optional[Sequence[str]] = None
    ) -> Dict[str, int]:
        """Halo ring each external input needs (0 for site-local-only graphs).

        ``halo="periodic"`` pads inputs by exactly these widths via
        ``stencil.halo_pad``; ``halo="pre"`` callers must supply Fields
        already padded (and exchanged via ``core.halo``) by them."""
        if outputs is None:
            outputs = [v for (_, v, _, _) in self._stages[-1].outs]
        need = self._required_rings(tuple(outputs))
        return {n: need.get(n, 0) for n in self.external_inputs()}

    def plan_signature(self):
        """Process-stable structural signature for the autotune-table key:
        kernel *names* plus chain structure, not function objects (which do
        not survive a process boundary the persisted table must cross)."""
        sig = []
        for st in self._stages:
            name = st.kernel.name if st.kernel is not None else st.op
            sig.append((st.kind, name, st.width, st.op, st.ins, st.outs,
                        tuple((k, repr(v)) for k, v in st.params)))
        return (self.name, tuple(sig))

    def plan_key(
        self,
        ins: Mapping[str, Field],
        *,
        config: Optional[TargetConfig] = None,
        outputs: Optional[Sequence[str]] = None,
        halo: str = "periodic",
        lattice: Optional[Tuple[int, ...]] = None,
    ) -> str:
        """The persisted-autotuner key for launching this graph with these
        inputs: (graph signature, input layouts/dtypes, lattice, engine,
        halo, outputs, jax backend) — see core.plan.graph_plan_key."""
        config = config or TargetConfig()
        ext = self.external_inputs()
        ordered_ins = [n for n in ext if n in ins]
        if outputs is None:
            outputs = [v for (_, v, _, _) in self._stages[-1].outs]
        if lattice is None:
            lattice = next(iter(ins.values())).lattice
        inputs = tuple(
            (n, ins[n].ncomp, str(ins[n].dtype), ins[n].layout.name,
             tuple(ins[n].lattice))
            for n in ordered_ins)
        # 'pre' and 'overlap' share the input contract (pre-exchanged
        # halos), so they share table entries: the strategy choice lives in
        # the persisted plan's halo field, not the key
        halo_key = "pre" if halo == "overlap" else halo
        # a batched launch tunes (and persists winners) per batch size and
        # per batched-vs-shared input split; batch=0 keeps pre-batch keys
        batch = max((int(getattr(ins[n], "batch", 0)) for n in ordered_ins),
                    default=0)
        batch_key = 0
        if batch:
            batch_key = (batch,) + tuple(
                int(bool(getattr(ins[n], "batch", 0))) for n in ordered_ins)
        return plan_mod.graph_plan_key(
            self.plan_signature(), engine=config.engine, halo=halo_key,
            outputs=tuple(outputs), inputs=inputs, lattice=tuple(lattice),
            backend=jax.default_backend(), batch=batch_key)

    def bytes_moved(
        self,
        ins_ncomp: Mapping[str, int],
        nsites: int,
        outputs: Optional[Sequence[str]] = None,
        itemsize: int = 4,
        dtypes=None,
    ) -> Dict[str, int]:
        """HBM traffic model of this chain, fused vs unfused (paper Fig. 4
        counting: reads + writes, itemsize bytes per element).  ``dtypes``
        (a :class:`~repro.core.plan.DtypePolicy`) re-prices every element
        at the policy's *storage* dtype itemsize — the traffic a
        mixed-precision plan actually contracts to move.

        unfused: every stage reads all its inputs from and writes all its
        outputs to HBM — including the per-site reduction input a separate
        ``target_sum`` pass would re-read.  fused: each distinct external
        input is read once and only the requested non-reduction graph
        outputs are written (reduction partials are O(ncomp), counted as 0).
        Stencil halo re-reads are not modelled (halo/interior -> 0 with
        lattice size).  Scalars are ignored.
        """
        if dtypes is not None and dtypes.storage:
            itemsize = dtypes.storage_itemsize(itemsize)
        ncomp = dict(ins_ncomp)
        for vname, (nc, _) in self._produced().items():
            ncomp[vname] = 0 if nc is None else nc
        if outputs is None:
            outputs = [v for (_, v, _, _) in self._stages[-1].outs]
        unfused = 0
        for st in self._stages:
            for _, vname in st.ins:
                unfused += ncomp.get(vname, 0)
            for _, vname, nc, _ in st.outs:
                unfused += 0 if nc is None else nc
        fused = sum(ncomp.get(n, 0) for n in self.external_inputs())
        fused += sum(ncomp[o] for o in outputs)
        return {
            "unfused": unfused * nsites * itemsize,
            "fused": fused * nsites * itemsize,
        }

    # -- execution --------------------------------------------------------------

    def bind(
        self,
        *,
        config: Optional[TargetConfig] = None,
        outputs: Optional[Sequence[str]] = None,
        out_layouts: Optional[Mapping[str, Layout]] = None,
        halo: str = "periodic",
        plan: Optional[LoweringPlan] = None,
    ) -> "BoundLaunch":
        """Freeze the launch-site keyword sprawl into a reusable callable.

        Every driver threads the same ``config=/outputs=/out_layouts=/
        halo=`` keywords verbatim through each ``launch`` call; ``bind``
        captures them once and returns a :class:`BoundLaunch` — call it
        with just the input Fields (plus per-call ``scalars=``/``plan=``,
        or keyword overrides).  The raw ``launch(...)`` form keeps working
        unchanged::

            step = graph.bind(config=cfg, outputs=("ap", "pap"))
            out = step({"p": p, "u": u}, scalars={"alpha": a})
        """
        return BoundLaunch(
            self,
            config=config,
            outputs=tuple(outputs) if outputs is not None else None,
            out_layouts=dict(out_layouts) if out_layouts else None,
            halo=halo,
            plan=plan,
        )

    def launch(
        self,
        ins: Dict[str, Field],
        *,
        config: Optional[TargetConfig] = None,
        outputs: Optional[Sequence[str]] = None,
        scalars: Optional[Mapping] = None,
        out_layouts: Optional[Mapping[str, Layout]] = None,
        halo: str = "periodic",
        plan: Optional[LoweringPlan] = None,
    ) -> Dict[str, Union[Field, jax.Array]]:
        """Execute the fused chain (the multi-kernel __targetLaunch__).

        ins         graph value name -> input Field (all sharing a lattice).
        outputs     graph value names to materialize (default: the last
                    stage's outputs).  Intermediates not listed here never
                    touch HBM on the pallas engine.  Reduction outputs come
                    back as (ncomp,) jnp arrays, everything else as Fields.
        scalars     graph value name -> runtime scalar (traced values OK).
        out_layouts graph output name -> Layout (default: first input's).
        halo        stencil graphs only: "periodic" pads external inputs by
                    halo_widths() with periodic wrap (single shard);
                    "pre" expects inputs already padded + exchanged by the
                    caller (core.halo inside shard_map), so the launch
                    composes with the MPI-layer decomposition; "overlap"
                    takes the same pre-exchanged inputs but executes as
                    interior/boundary split sub-launches (core.overlap —
                    a plan with halo="overlap", e.g. a persisted tuner
                    winner, upgrades a "pre" call the same way).
        plan        explicit LoweringPlan for this launch (overrides
                    config.plan_policy — the autotuner's sweep hook).
        """
        if not self._stages:
            raise ValueError("LaunchGraph has no stages")
        if not ins:
            raise ValueError("fused launch needs at least one input Field")
        if halo not in ("periodic", "pre", "overlap"):
            raise ValueError(
                f"halo must be 'periodic', 'pre' or 'overlap', got {halo!r}")
        config = config or TargetConfig()
        scalars = dict(scalars or {})
        stencil = self.has_stencil
        if halo in ("pre", "overlap") and not stencil:
            raise ValueError(
                f"halo={halo!r} only applies to graphs with stencil stages")

        first = next(iter(ins.values()))
        # leading batch axis: BatchedField inputs stack `batch` independent
        # same-shape lattices; plain Fields are shared across the batch
        # (e.g. one gauge field serving many right-hand sides)
        in_batch = {n: int(getattr(f, "batch", 0)) for n, f in ins.items()}
        batch = max(in_batch.values(), default=0)
        if batch:
            bad_b = {n: b for n, b in in_batch.items() if b not in (0, batch)}
            if bad_b:
                raise ValueError(
                    f"batched inputs disagree on the batch size: {bad_b} "
                    f"vs {batch}; every BatchedField in one launch must "
                    f"stack the same number of lattices")
        double = sorted(set(ins) & set(scalars))
        if double:
            raise ValueError(
                f"value(s) {double} supplied as both input Fields and "
                f"scalars; each graph value must have exactly one binding"
            )
        ext = self.external_inputs()
        missing = [n for n in ext if n not in ins and n not in scalars]
        if missing:
            raise ValueError(
                f"graph consumes value(s) {missing} produced by no earlier "
                f"stage and not supplied as inputs or scalars"
            )
        ordered_ins = [n for n in ext if n in ins]
        ordered_scalars = [n for n in ext if n in scalars]

        prod = self._produced()
        if outputs is None:
            outputs = [v for (_, v, _, _) in self._stages[-1].outs]
        outputs = tuple(outputs)
        unknown = [o for o in outputs if o not in prod]
        if unknown:
            raise ValueError(f"requested outputs {unknown} produced by no stage")
        red_names = set(self._reduce_outputs())
        field_outputs = tuple(o for o in outputs if o not in red_names)
        red_outputs = tuple(o for o in outputs if o in red_names)

        # halo rings per external Field input (0 unless a stencil needs it)
        need = self._required_rings(outputs) if stencil else {}
        in_rings = tuple(need.get(n, 0) for n in ordered_ins)

        # interior lattice: what output Fields live on
        if stencil and halo in ("pre", "overlap"):
            interiors = {
                n: tuple(s - 2 * r for s in ins[n].lattice)
                for n, r in zip(ordered_ins, in_rings)
            }
            lattice = interiors[ordered_ins[0]]
            bad = {n: lat for n, lat in interiors.items() if lat != lattice}
            if bad or any(s < 1 for s in lattice):
                raise ValueError(
                    f"pre-halo'd inputs disagree on the interior lattice "
                    f"(lattice - 2*ring per input, rings {dict(zip(ordered_ins, in_rings))}): "
                    f"{ {n: ins[n].lattice for n in ordered_ins} }"
                )
        else:
            lattice = first.lattice
            bad = {k: f.lattice for k, f in ins.items() if f.lattice != lattice}
            if bad:
                raise ValueError(
                    f"all Fields in a fused launch must share nsites and "
                    f"lattice shape: {first.name!r} has {lattice}, "
                    f"mismatched {bad}"
                )
        nsites = int(math.prod(lattice))

        out_layouts = dict(out_layouts or {})
        for o in field_outputs:
            out_layouts.setdefault(o, first.layout)
        # resolve default dtypes (and reduce ncomp) now: part of the cache key
        out_info = {}
        for o in outputs:
            nc, dt = prod[o]
            if nc is None:  # reduction: ncomp of the reduced value
                (src,) = [v for st in self._stages if st.kind == "reduce"
                          for (_, v2, _, _) in st.outs if v2 == o
                          for (_, v) in st.ins]
                src_nc = prod.get(src, (None, None))[0]
                if src_nc is None:
                    src_nc = ins[src].ncomp
                nc = src_nc
            out_info[o] = (int(nc), jnp.dtype(dt or first.dtype))

        # per-site staging shapes for the VMEM budget model: what the
        # planner needs to estimate a candidate's per-program footprint
        # (and auto-tile y/z when whole-staging would blow the budget)
        vmem_views = None
        if stencil:
            vmem_views = (
                tuple((ins[n].ncomp, r, jnp.dtype(ins[n].dtype).itemsize)
                      for n, r in zip(ordered_ins, in_rings)),
                tuple((out_info[o][0], out_info[o][1].itemsize)
                      for o in field_outputs),
            )

        # -- planning: every lowering decision comes from a LoweringPlan ----
        all_layouts = ([ins[n].layout for n in ordered_ins]
                       + [out_layouts[o] for o in field_outputs])
        from_table = False
        if plan is None:
            policy = getattr(config, "plan_policy", "default")
            if isinstance(policy, LoweringPlan):
                plan = policy
            elif policy == "tuned":
                from . import tune
                plan = tune.lookup(self.plan_key(
                    ins, config=config, outputs=outputs, halo=halo,
                    lattice=lattice))
                from_table = plan is not None
            elif policy != "default":
                raise ValueError(
                    f"unknown plan_policy {policy!r}; use 'default', "
                    f"'tuned' or an explicit LoweringPlan")
        if plan is None:  # default policy, or tuned-table miss
            plan = plan_mod.default_plan(
                config, nsites=nsites, layouts=all_layouts,
                stencil=stencil, lattice=lattice, halo=halo,
                vmem_views=vmem_views)
        else:
            plan = plan_mod.adapt_plan(plan, stencil=stencil, halo=halo)
            try:
                plan.validate(nsites=nsites, lattice=lattice,
                              layouts=all_layouts, stencil=stencil)
                if (stencil and plan.engine == "pallas"
                        and plan.view == VIEW_BLOCK):
                    # alignment pre-check: same errors _build_nd would
                    # raise, surfaced here so a stale table entry can
                    # degrade instead of crashing the launch
                    _block_geometry(
                        ordered_ins,
                        [(ins[n].ncomp, ins[n].layout) for n in ordered_ins],
                        [ins[n].lattice for n in ordered_ins],
                        in_rings, halo, plan.view, out_layouts,
                        field_outputs, lattice,
                        tiled=bool(plan.by or plan.bz))
            except ValueError:
                if not from_table:
                    raise
                # tuning must never break a launch (e.g. a persisted
                # native-block winner meeting an out_layouts override
                # whose SAL cannot tile the interior): degrade to the
                # default heuristics, logged not fatal
                log.warning(
                    "tuned plan %s does not fit launch of graph %r "
                    "(lattice %s) — falling back to the default plan",
                    plan.describe(), self.name, lattice, exc_info=True)
                plan = plan_mod.default_plan(
                    config, nsites=nsites, layouts=all_layouts,
                    stencil=stencil, lattice=lattice, halo=halo,
                    vmem_views=vmem_views)

        # -- dtype policy: precision becomes a lowering decision ------------
        # a config-level policy applies when the resolved plan carries none
        # of its own (a tuned/explicit plan's policy wins); with no policy
        # anywhere every path below is bitwise the pre-policy code
        cfg_dtypes = getattr(config, "dtypes", None)
        if cfg_dtypes and plan.dtypes is None:
            plan = dataclasses.replace(plan, dtypes=cfg_dtypes)
        storage_dt = compute_dt = None
        acc_fold = {}  # red output -> (accumulate jnp dtype, compensated?)
        if plan.dtypes:
            pol = plan.dtypes.validate()
            storage_dt = jnp.dtype(pol.storage) if pol.storage else None
            compute_dt = jnp.dtype(pol.compute) if pol.compute else None
            acc_name, acc_comp = plan_mod.resolve_accumulate(pol.accumulate)
            red_ops = {o: s.op for o, s in self.reduce_specs().items()}
            for o in outputs:
                nc, dt = out_info[o]
                # float-only rule: integer fields and max/integer
                # reductions are bitwise exempt from the dtype axis
                if not jnp.issubdtype(dt, jnp.floating):
                    continue
                if o in red_names:
                    if acc_name and red_ops.get(o) == "sum":
                        out_info[o] = (nc, jnp.dtype(acc_name))
                        acc_fold[o] = (jnp.dtype(acc_name), acc_comp)
                elif storage_dt is not None:
                    out_info[o] = (nc, storage_dt)

        if stencil and plan.halo == "overlap":
            # split schedule: interior + boundary sub-launches (each a
            # plain halo="pre" launch through this very machinery)
            from . import overlap as overlap_mod
            return overlap_mod.execute_split(
                self, ins, config=config, outputs=outputs, scalars=scalars,
                out_layouts=out_layouts, plan=plan)

        engine, interpret = plan.engine, plan.interpret
        vvl, bx = plan.vvl, plan.bx

        # launch span (core.telemetry): host-side only — attrs are strings
        # and ints, the traced computation is untouched.  The disabled path
        # costs one predicate; plan.describe() is only built when recording.
        t_override = getattr(config, "telemetry", None)
        tspan = (telemetry.span(
            f"launch/{self.name}",
            override=t_override,
            plan=plan.describe(),
            engine=engine,
            lattice=str(tuple(lattice)),
            batch=batch,
            halo=halo,
            from_tuned_table=from_table,
        ) if telemetry.enabled(t_override)
            else telemetry.NULL_SPAN)

        in_batched = tuple(bool(in_batch[n]) for n in ordered_ins)
        key = (
            plan,
            lattice,
            batch,
            in_batched,
            tuple(st.signature() for st in self._stages),
            tuple(
                (n, ins[n].ncomp, str(ins[n].dtype), ins[n].layout,
                 ins[n].lattice, r)
                for n, r in zip(ordered_ins, in_rings)
            ),
            tuple(ordered_scalars),
            outputs,
            tuple((o, out_layouts.get(o), str(out_info[o][1])) for o in outputs),
        )
        fn = _CACHE.get(key)
        if fn is None:
            telemetry.inc("fuse.cache_misses")
            tspan.set(cache="miss")
            build = self._build_nd if stencil else self._build_flat
            build_kw = dict(
                engine=engine,
                ordered_ins=ordered_ins,
                in_meta=[(ins[n].ncomp, ins[n].layout) for n in ordered_ins],
                in_lats=[ins[n].lattice for n in ordered_ins],
                in_rings=in_rings,
                ordered_scalars=ordered_scalars,
                field_outputs=field_outputs,
                red_outputs=red_outputs,
                out_info=out_info,
                out_layouts=out_layouts,
                lattice=lattice,
                halo=halo,
                vvl=vvl,
                bx=bx,
                interpret=interpret,
                rsplit=plan.rsplit,
                batch=batch,
                in_batched=in_batched,
                by=plan.by,
                bz=plan.bz,
                in_dtypes=tuple(jnp.dtype(ins[n].dtype)
                                for n in ordered_ins),
                storage_dt=storage_dt,
                compute_dt=compute_dt,
                acc_fold=acc_fold,
            )
            if stencil:  # only the stencil lowering is view-sensitive
                build_kw["view"] = plan.view
            fn = build(**build_kw)
            _CACHE[key] = fn
            while len(_CACHE) > _CACHE_CAP:
                _CACHE.popitem(last=False)
        else:
            telemetry.inc("fuse.cache_hits")
            tspan.set(cache="hit")
            _CACHE.move_to_end(key)

        datas = tuple(ins[n].data for n in ordered_ins)
        # scalars join kernel arithmetic, so they cast to the effective
        # compute dtype under a policy (float launches only)
        scalar_dt = first.dtype
        if (compute_dt is not None or storage_dt is not None) and \
                jnp.issubdtype(jnp.dtype(first.dtype), jnp.floating):
            scalar_dt = compute_dt or storage_dt
        if batch:
            # scalars may be per-request, shape (batch,) — e.g. the masked
            # CG's per-slot alpha/beta — or plain scalars broadcast to all
            svals = []
            for n in ordered_scalars:
                v = jnp.asarray(scalars[n], scalar_dt)
                if v.ndim == 0:
                    v = jnp.broadcast_to(v, (batch,))
                elif v.shape != (batch,):
                    raise ValueError(
                        f"batched launch scalar {n!r} must be a scalar or a "
                        f"({batch},) per-request vector, got shape {v.shape}")
                svals.append(v.reshape(batch, 1, 1))
            svals = tuple(svals)
        else:
            svals = tuple(
                jnp.asarray(scalars[n], scalar_dt).reshape(1, 1)
                for n in ordered_scalars
            )
        results = fn(datas, svals)
        if tspan:
            # modeled HBM bytes (the fig3/fig4 counting) over the measured
            # wall interval -> achieved GB/s + live roofline placement.
            # Under a storage dtype policy the per-element byte count is
            # the *storage* itemsize — that is the traffic the policy
            # exists to cut — and the memo is keyed per policy so twin
            # plans never share rows
            itemsize = jnp.dtype(first.dtype).itemsize
            if plan.dtypes and plan.dtypes.storage:
                itemsize = plan.dtypes.storage_itemsize(itemsize)
            bkey = (tuple((n, ins[n].ncomp) for n in ordered_ins), nsites,
                    outputs, itemsize, plan.dtypes)
            bm = self._bytes_memo.get(bkey)
            if bm is None:
                bm = self._bytes_memo[bkey] = self.bytes_moved(
                    {n: ins[n].ncomp for n in ordered_ins}, nsites,
                    outputs=outputs, itemsize=itemsize)
            bfac = max(batch, 1)
            tspan.set(
                bytes_fused=bm["fused"] * bfac,
                bytes_unfused=bm["unfused"] * bfac,
                **telemetry.roofline_placement(
                    bm["fused"] * bfac, tspan.elapsed))
            tspan.end()

        out: Dict[str, Union[Field, jax.Array]] = {}
        ordered_out = list(field_outputs) + list(red_outputs)
        for o, val in zip(ordered_out, results):
            if o in red_names:
                out[o] = val  # (ncomp,) or batched (batch, ncomp)
            elif batch:
                ncomp, _ = out_info[o]
                out[o] = BatchedField(o, batch, ncomp, lattice,
                                      out_layouts[o], val)
            else:
                ncomp, _ = out_info[o]
                out[o] = Field(o, ncomp, lattice, out_layouts[o], val)
        return out

    # -- composed bodies ---------------------------------------------------------

    def _run_stages(self, values: Dict[str, jax.Array]) -> Tuple[
            Dict[str, jax.Array], Dict[str, jax.Array]]:
        """Flat composed body (site-local graphs): one pass over all stages.
        ``values`` maps graph names to (ncomp, L) arrays (L = nsites for jnp,
        vvl inside the pallas kernel) plus (1, 1) scalars.  Returns (values,
        partials) where partials holds per-block reduction folds."""
        partials: Dict[str, jax.Array] = {}
        for st in self._stages:
            if st.kind == "reduce":
                ((_, vname),) = st.ins
                partials[st.outs[0][1]] = _RED_FOLD[st.op](
                    values[vname], axis=1)
                continue
            chunks = {arg: values[v] for arg, v in st.ins}
            outs = st.kernel.body(chunks, **dict(st.params))
            for body_key, vname, ncomp, _ in st.outs:
                arr = outs[body_key]
                if arr.shape[0] != ncomp:
                    raise ValueError(
                        f"stage {st.kernel.name!r} output {body_key!r} has "
                        f"ncomp {arr.shape[0]}, declared {ncomp}"
                    )
                values[vname] = arr
        return values, partials

    def _run_stages_nd(
        self,
        values: Dict[str, Tuple[jax.Array, Optional[int]]],
        site_ndim: int,
    ) -> Tuple[Dict[str, Tuple[jax.Array, Optional[int]]],
               Dict[str, jax.Array]]:
        """Stencil composed body: values are (array, ring) pairs where array
        has shape (ncomp, *window) and ring counts valid halo sites around
        the window's interior.  Site-local stages run (flattened) over the
        whole window — recomputing on halo sites so later stencil stages can
        gather from intermediates; stencil stages shrink the ring by their
        width; reductions fold the ring-0 interior into per-block partials."""
        partials: Dict[str, jax.Array] = {}
        for st in self._stages:
            if st.kind == "reduce":
                ((_, vname),) = st.ins
                arr, r = values[vname]
                a0 = _crop_ring(arr, r, 0)
                partials[st.outs[0][1]] = _RED_FOLD[st.op](
                    a0.reshape(a0.shape[0], -1), axis=1)
                continue

            stage_ins = [(arg, values[v]) for arg, v in st.ins]
            rings = [r for _, (_, r) in stage_ins if r is not None]
            if not rings:
                raise ValueError(
                    f"stage {st.kernel.name!r} has no Field inputs")
            r_in = min(rings)

            if st.kind == "stencil":
                r_out = r_in - st.width
                if r_out < 0:
                    raise ValueError(
                        f"stencil stage {st.kernel.name!r} (width {st.width})"
                        f" consumes a value valid only on ring {r_in}; its "
                        f"inputs need ring >= {st.width} — pad/exchange "
                        f"external inputs by halo_widths(), and do not chain "
                        f"it after a stage that already consumed the halo"
                    )
                by_arg = dict(stage_ins)
                width = st.width

                def gather(name, disp, _by_arg=by_arg, _r_out=r_out,
                           _width=width):
                    if name not in _by_arg:
                        raise KeyError(
                            f"gather({name!r}): not an input of this stage")
                    arr, r = _by_arg[name]
                    if r is None:
                        raise ValueError(
                            f"gather({name!r}): scalars have no geometry")
                    disp = tuple(int(d) for d in disp)
                    if len(disp) != site_ndim:
                        raise ValueError(
                            f"gather({name!r}): disp {disp} must have one "
                            f"entry per lattice dim ({site_ndim})")
                    if any(abs(d) > _width for d in disp):
                        raise ValueError(
                            f"gather({name!r}): |disp|={disp} exceeds stage "
                            f"width {_width}")
                    off = r - _r_out
                    sl = (slice(None),) + tuple(
                        slice(off - d, arr.shape[j + 1] - off - d)
                        for j, d in enumerate(disp)
                    )
                    return arr[sl]

                zeros = (0,) * site_ndim
                chunks = {}
                for arg, (arr, r) in stage_ins:
                    if r is None:  # scalar: broadcast over the nd window
                        chunks[arg] = arr.reshape((1,) * (1 + site_ndim))
                    else:
                        chunks[arg] = gather(arg, zeros)
                outs = st.kernel.body(chunks, gather, **dict(st.params))
                for body_key, vname, ncomp, _ in st.outs:
                    arr = outs[body_key]
                    if arr.shape[0] != ncomp:
                        raise ValueError(
                            f"stage {st.kernel.name!r} output {body_key!r} "
                            f"has ncomp {arr.shape[0]}, declared {ncomp}"
                        )
                    values[vname] = (arr, r_out)
                continue

            # site-local: crop all inputs to the common ring, flatten, run
            win_shape = None
            chunks = {}
            for arg, (arr, r) in stage_ins:
                if r is None:
                    chunks[arg] = arr  # (1, 1) broadcasts against (ncomp, L)
                else:
                    w = _crop_ring(arr, r, r_in)
                    win_shape = w.shape[1:]
                    chunks[arg] = w.reshape(w.shape[0], -1)
            outs = st.kernel.body(chunks, **dict(st.params))
            for body_key, vname, ncomp, _ in st.outs:
                arr = outs[body_key]
                if arr.shape[0] != ncomp:
                    raise ValueError(
                        f"stage {st.kernel.name!r} output {body_key!r} has "
                        f"ncomp {arr.shape[0]}, declared {ncomp}"
                    )
                values[vname] = (arr.reshape((ncomp,) + win_shape), r_in)
        return values, partials

    # -- lowering: flat site-block grid (site-local graphs) ----------------------

    def _build_flat(
        self,
        *,
        engine: str,
        ordered_ins: Sequence[str],
        in_meta: Sequence[Tuple[int, Layout]],
        in_lats,
        in_rings,
        ordered_scalars: Sequence[str],
        field_outputs: Tuple[str, ...],
        red_outputs: Tuple[str, ...],
        out_info: Mapping[str, Tuple[int, object]],
        out_layouts: Mapping[str, Layout],
        lattice: Tuple[int, ...],
        halo: str,
        vvl: int,
        bx: int,
        interpret: bool,
        rsplit: int = 1,
        batch: int = 0,
        in_batched: Sequence[bool] = (),
        by: int = 0,
        bz: int = 0,
        in_dtypes: Sequence[object] = (),
        storage_dt=None,
        compute_dt=None,
        acc_fold: Optional[Mapping[str, Tuple[object, bool]]] = None,
    ) -> Callable:
        # by/bz only drive the stencil (_build_nd) lowering; plan.validate()
        # rejects tiles on site-local chains, so they are always 0 here —
        # accepted so launch() can share one build_kw
        del by, bz
        run_stages = self._run_stages
        nsites = int(math.prod(lattice))
        red_spec = self.reduce_specs()
        acc_fold = dict(acc_fold or {})
        cast_in = _stage_in_cast(storage_dt, compute_dt, in_dtypes)
        if not in_batched:
            in_batched = (False,) * len(ordered_ins)

        def red_partial(o, values, partials):
            """One reduction output's per-launch partial.  Policy-
            accumulated sums refold the (whole-lattice) source in the
            accumulate dtype — Kahan when compensated — instead of casting
            the compute-dtype fold after the fact."""
            if o in acc_fold:
                dt, comp = acc_fold[o]
                src = values[red_spec[o].source].astype(dt)
                return kahan_fold(src, axis=1) if comp \
                    else jnp.sum(src, axis=1)
            return partials[o].astype(out_info[o][1])

        if engine == "jnp":

            def one(datas, svals):
                if cast_in is not None:
                    datas = cast_in(datas)
                values = {}
                for n, (_, lay), d in zip(ordered_ins, in_meta, datas):
                    values[n] = lay.unpack(d)
                for n, s in zip(ordered_scalars, svals):
                    values[n] = s
                values, partials = run_stages(values)
                res = [
                    out_layouts[o].pack(values[o].astype(out_info[o][1]))
                    for o in field_outputs
                ]
                res += [red_partial(o, values, partials)
                        for o in red_outputs]
                return tuple(res)

            if batch:
                # one trace, vmapped over the stack; shared (plain Field)
                # inputs broadcast with in_axes=None — the batched analogue
                # of the whole-lattice oracle, element-bitwise identical to
                # running `one` per batch element
                vone = jax.vmap(one, in_axes=(
                    tuple(0 if b else None for b in in_batched), 0))

                def fn(datas, svals):
                    telemetry.inc("fuse.traces")
                    return vone(datas, svals)
            else:

                def fn(datas, svals):
                    telemetry.inc("fuse.traces")
                    return one(datas, svals)

            return jax.jit(fn)

        # pallas: the whole chain is ONE pallas_call over the site-block
        # grid — batched launches grow a leading batch grid axis, so the
        # grid is (batch, nblocks) and every BlockSpec picks its batch
        # row.  A split-reduction plan (rsplit > 1) partitions the block
        # axis into (rsplit, nblocks/rsplit): split segment s covers
        # blocks [s*per, (s+1)*per) in the unsplit order, accumulating its
        # own stage-1 partial row; the stage-2 combine folds the rows in
        # segment order after the call.
        nblocks = nsites // vvl
        per = nblocks // rsplit
        site_grid = (rsplit, per) if rsplit > 1 else (nblocks,)
        grid = ((batch,) + site_grid) if batch else site_grid
        nin, nsc = len(ordered_ins), len(ordered_scalars)
        in_specs = build_in_specs(in_meta, vvl)
        out_shapes, out_block_specs = build_out_specs(
            field_outputs, out_info, out_layouts, nsites, vvl
        )
        # compensated (Kahan) sums widen their accumulator to (ncomp, 2):
        # column 0 the running sum, column 1 the running compensation
        red_widths = {o: 2 for o in red_outputs
                      if o in acc_fold and acc_fold[o][1]}
        if rsplit > 1:
            in_specs = _split_specs(in_specs, per)
            out_block_specs = _split_specs(out_block_specs, per)
            red_shapes, red_block_specs = build_split_reduce_specs(
                red_outputs, out_info, rsplit, red_widths)
        else:
            red_shapes, red_block_specs = build_reduce_specs(
                red_outputs, out_info, red_widths)
        if batch:
            in_specs = _batch_specs(in_specs, in_batched)
            in_specs += [pl.BlockSpec((1, 1, 1), lambda b, *_: (b, 0, 0))
                         for _ in range(nsc)]
            out_shapes = _batch_shapes(out_shapes, batch)
            out_block_specs = _batch_specs(
                out_block_specs, [True] * len(out_block_specs))
            red_shapes = _batch_shapes(red_shapes, batch)
            red_block_specs = _batch_specs(
                red_block_specs, [True] * len(red_block_specs))
        else:
            in_specs += [pl.BlockSpec((1, 1), lambda *_: (0, 0))
                         for _ in range(nsc)]
        out_shapes += red_shapes
        out_block_specs += red_block_specs
        nfield = len(field_outputs)
        name = self.name
        red_axis = len(grid) - 1

        def fused_kernel(*refs):
            in_refs = refs[:nin]
            sc_refs = refs[nin : nin + nsc]
            out_refs = refs[nin + nsc : nin + nsc + nfield]
            acc_refs = refs[nin + nsc + nfield :]
            values = {}
            for n, (ncomp, lay), bat, r in zip(
                    ordered_ins, in_meta, in_batched, in_refs):
                blk = r[...][0] if (batch and bat) else r[...]
                values[n] = lay.block_to_canonical(blk, ncomp, vvl)
            for n, r in zip(ordered_scalars, sc_refs):
                values[n] = r[...][0] if batch else r[...]
            values, partials = run_stages(values)
            for o, r in zip(field_outputs, out_refs):
                ncomp, dtype = out_info[o]
                blk = out_layouts[o].canonical_to_block(
                    values[o].astype(dtype), ncomp, vvl
                )
                r[...] = blk[None] if batch else blk
            for o, r in zip(red_outputs, acc_refs):
                spec = red_spec[o]
                part = partials[o][:, None].astype(out_info[o][1])
                while part.ndim < len(r.shape):
                    part = part[None]
                # compensated sums carry (sum, compensation) columns
                # across blocks; per-block partials fold plainly in the
                # compute dtype (the hierarchical Kahan contract)
                comb = _kahan_combine if o in red_widths else spec.combine
                _accumulate(r, comb, spec.init, part,
                            axes=(red_axis,))

        def fn(datas, svals):
            telemetry.inc("fuse.traces")
            telemetry.inc("fuse.pallas_calls")
            if cast_in is not None:
                datas = cast_in(datas)
            call = pl.pallas_call(
                fused_kernel,
                grid=grid,
                in_specs=in_specs,
                out_specs=(
                    out_block_specs if len(out_block_specs) > 1 else out_block_specs[0]
                ),
                out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
                interpret=interpret,
                name=name,
            )
            res = call(*datas, *svals)
            if len(out_shapes) == 1:
                res = (res,)
            # reduction accumulators (..., ncomp, 1) -> (..., ncomp); a
            # split plan's (..., rsplit, ncomp) stage-1 rows go through
            # the stage-2 combine in segment order
            out = []
            for i, r in enumerate(res):
                if i < nfield:
                    out.append(r)
                    continue
                acc = r[..., 0]
                if rsplit > 1:
                    acc = red_spec[red_outputs[i - nfield]].combine_partials(
                        acc, axis=-2)
                out.append(acc)
            return tuple(out)

        return jax.jit(fn)

    # -- lowering: halo'd x-slab grid (stencil graphs) ---------------------------

    def _build_nd(
        self,
        *,
        engine: str,
        ordered_ins: Sequence[str],
        in_meta: Sequence[Tuple[int, Layout]],
        in_lats: Sequence[Tuple[int, ...]],
        in_rings: Sequence[int],
        ordered_scalars: Sequence[str],
        field_outputs: Tuple[str, ...],
        red_outputs: Tuple[str, ...],
        out_info: Mapping[str, Tuple[int, object]],
        out_layouts: Mapping[str, Layout],
        lattice: Tuple[int, ...],
        halo: str,
        vvl: int,
        bx: int,
        interpret: bool,
        view: str,
        rsplit: int = 1,
        batch: int = 0,
        in_batched: Sequence[bool] = (),
        by: int = 0,
        bz: int = 0,
        in_dtypes: Sequence[object] = (),
        storage_dt=None,
        compute_dt=None,
        acc_fold: Optional[Mapping[str, Tuple[object, bool]]] = None,
    ) -> Callable:
        run_nd = self._run_stages_nd
        site_ndim = len(lattice)
        site_dims = tuple(range(1, site_ndim + 1))
        red_spec = self.reduce_specs()
        acc_fold = dict(acc_fold or {})
        cast_in = _stage_in_cast(storage_dt, compute_dt, in_dtypes)
        if not in_batched:
            in_batched = (False,) * len(ordered_ins)

        def to_halo_nd(n, meta, lat, ring, d):
            """Physical data -> canonical (ncomp, *padded_lattice)."""
            ncomp, lay = meta
            nd = lay.unpack(d).reshape((ncomp,) + tuple(lat))
            if halo == "periodic" and ring > 0:
                nd = halo_pad(nd, ring, site_dims)
            return nd

        def red_partial_nd(o, values, partials):
            """As _build_flat's red_partial: policy-accumulated sums refold
            the ring-0 interior of the source in the accumulate dtype."""
            if o in acc_fold:
                dt, comp = acc_fold[o]
                arr, r = values[red_spec[o].source]
                a0 = _crop_ring(arr, r, 0)
                a0 = a0.reshape(a0.shape[0], -1).astype(dt)
                return kahan_fold(a0, axis=1) if comp \
                    else jnp.sum(a0, axis=1)
            return partials[o].astype(out_info[o][1])

        if engine == "jnp":

            def one(datas, svals):
                if cast_in is not None:
                    datas = cast_in(datas)
                values = {}
                for n, meta, lat, ring, d in zip(
                        ordered_ins, in_meta, in_lats, in_rings, datas):
                    values[n] = (to_halo_nd(n, meta, lat, ring, d), ring)
                for n, s in zip(ordered_scalars, svals):
                    values[n] = (s, None)
                values, partials = run_nd(values, site_ndim)
                res = []
                for o in field_outputs:
                    arr, r = values[o]
                    a0 = _crop_ring(arr, r, 0)
                    ncomp, dtype = out_info[o]
                    res.append(out_layouts[o].pack(
                        a0.reshape(ncomp, -1).astype(dtype)))
                res += [red_partial_nd(o, values, partials)
                        for o in red_outputs]
                return tuple(res)

            if batch:
                vone = jax.vmap(one, in_axes=(
                    tuple(0 if b else None for b in in_batched), 0))

                def fn(datas, svals):
                    telemetry.inc("fuse.traces")
                    return vone(datas, svals)
            else:

                def fn(datas, svals):
                    telemetry.inc("fuse.traces")
                    return one(datas, svals)

            return jax.jit(fn)

        # pallas: ONE pallas_call over x-slabs of the halo'd lattice.  The
        # halo'd inputs are staged whole into VMEM (overlapping slab windows
        # are not disjoint Blocked windows); each program dynamic-slices its
        # halo'd window out, runs every stage on it, writes its interior
        # slab, and accumulates reduction partials into the shared buffer.
        #
        # view="staged-nd": inputs are unpacked to canonical nd views (XLA
        # ops) before staging and outputs packed after — AoSoA data pays an
        # HBM relayout round-trip on both sides of the kernel.
        # view="block" (native AoSoA): an aligned AoSoA input is staged in
        # its physical (nblocks, ncomp, SAL) tile shape — in "pre" mode the
        # caller's array is used as-is, zero staging ops — the per-program
        # window slice is rebased to the block axis (row_blocks = halo'd
        # inner-plane sites / SAL tiles per x-plane) and unpacked in VMEM;
        # an aligned AoSoA output is packed in VMEM and written as native
        # blocks.  Non-AoSoA values take the staged path either way (SOA
        # staging is a view, AoS a transpose).
        #
        # A *tiled* plan (by/bz > 0) appends one sequential grid axis per
        # tiled lattice dim after the x-slab axis, iterating fastest — each
        # program computes one (bx, by, bz) tile from a halo'd tile window.
        # On the interpret/off-TPU fallback the inputs still stage whole
        # (the window is a dynamic_slice of VMEM-staged data, bitwise
        # identical to the untiled lowering); on a real TPU the inputs stay
        # in HBM and each tile window is DMA'd into one of two VMEM scratch
        # slots while the previous tile computes (double-buffered
        # prefetch), so per-program VMEM is bounded by the tile, not the
        # lattice.
        nslabs = lattice[0] // bx
        per = nslabs // rsplit
        tiled = bool(by or bz)
        nty = (lattice[1] // by) if by else 1
        ntz = (lattice[2] // bz) if bz else 1
        site_grid = (rsplit, per) if rsplit > 1 else (nslabs,)
        if by:
            site_grid += (nty,)
        if bz:
            site_grid += (ntz,)
        grid = ((batch,) + site_grid) if batch else site_grid
        nin, nsc = len(ordered_ins), len(ordered_scalars)
        hlats, native_in = _block_geometry(
            ordered_ins, in_meta, in_lats, in_rings, halo, view,
            out_layouts, field_outputs, lattice, tiled=tiled)
        stage_shapes = []
        for (ncomp, lay), hlat, nat in zip(in_meta, hlats, native_in):
            if nat:
                hsites = int(math.prod(hlat))
                stage_shapes.append((hsites // lay.sal, ncomp, lay.sal))
            else:
                stage_shapes.append((ncomp,) + hlat)
        in_specs = build_halo_in_specs(stage_shapes)
        if tiled:
            # disjoint (bx, by, bz) tiles are directly expressible as
            # Blocked windows; native AoSoA *outputs* degrade to canonical
            # tile writes (a y/z tile is not a contiguous block run)
            out_shapes, out_block_specs = build_tiled_out_specs(
                field_outputs, out_info, lattice, bx, by, bz
            )
            native_out = [False] * len(field_outputs)
        elif view == VIEW_BLOCK:
            # _block_geometry already rejected misaligned AoSoA outputs
            out_shapes, out_block_specs, native_out = build_block_out_specs(
                field_outputs, out_info, out_layouts, lattice, bx
            )
        else:
            out_shapes, out_block_specs = build_slab_out_specs(
                field_outputs, out_info, lattice, bx
            )
            native_out = [False] * len(field_outputs)
        # compensated (Kahan) sums widen their accumulator to (ncomp, 2)
        red_widths = {o: 2 for o in red_outputs
                      if o in acc_fold and acc_fold[o][1]}
        if rsplit > 1:
            in_specs = _split_specs(in_specs, per)
            out_block_specs = _split_specs(out_block_specs, per)
            red_shapes, red_block_specs = build_split_reduce_specs(
                red_outputs, out_info, rsplit, red_widths)
        else:
            red_shapes, red_block_specs = build_reduce_specs(
                red_outputs, out_info, red_widths)
        if batch:
            in_specs = _batch_specs(in_specs, in_batched)
            in_specs += [pl.BlockSpec((1, 1, 1), lambda b, *_: (b, 0, 0))
                         for _ in range(nsc)]
            out_shapes = _batch_shapes(out_shapes, batch)
            out_block_specs = _batch_specs(
                out_block_specs, [True] * len(out_block_specs))
            red_shapes = _batch_shapes(red_shapes, batch)
            red_block_specs = _batch_specs(
                red_block_specs, [True] * len(red_block_specs))
        else:
            in_specs += [pl.BlockSpec((1, 1), lambda *_: (0, 0))
                         for _ in range(nsc)]
        out_shapes += red_shapes
        out_block_specs += red_block_specs
        nfield = len(field_outputs)
        inner_int = int(math.prod(lattice[1:]))
        name = self.name
        axis0 = 1 if batch else 0
        # accumulator rows initialize at the first program of *all* axes
        # addressing one row: the x-slab axis plus any trailing tile axes
        # (batch and split-segment axes select separate buffer rows)
        acc_axes = tuple(range(axis0 + (1 if rsplit > 1 else 0), len(grid)))

        def tile_tail(ys, zs, ring, hlat):
            """(starts, sizes) of a program's halo'd window on the lattice
            dims after x: tiled dims cut a (tile + 2*ring) window at the
            tile origin, untiled dims cover the whole halo'd extent."""
            starts, sizes = [], []
            for d in range(1, site_ndim):
                if d == 1 and by:
                    starts.append(ys)
                    sizes.append(by + 2 * ring)
                elif d == 2 and bz:
                    starts.append(zs)
                    sizes.append(bz + 2 * ring)
                else:
                    starts.append(0)
                    sizes.append(hlat[d])
            return starts, sizes

        def finish_tile(values, sc_refs, out_refs, acc_refs):
            """Shared kernel tail: scalars in, stages, tile writes,
            reduction accumulation — identical for the staged fallback
            and the DMA-pipelined kernel (bitwise-identity lever)."""
            for n, r in zip(ordered_scalars, sc_refs):
                values[n] = (r[...][0] if batch else r[...], None)
            values, partials = run_nd(values, site_ndim)
            for o, nat, r in zip(field_outputs, native_out, out_refs):
                arr, ring = values[o]
                a0 = _crop_ring(arr, ring, 0).astype(out_info[o][1])
                if nat:  # pack the interior slab in VMEM: native blocks out
                    ncomp = out_info[o][0]
                    sal = out_layouts[o].sal
                    a0 = a0.reshape(
                        ncomp, bx * inner_int // sal, sal).transpose(1, 0, 2)
                r[...] = a0[None] if batch else a0
            for o, r in zip(red_outputs, acc_refs):
                spec = red_spec[o]
                part = partials[o][:, None].astype(out_info[o][1])
                while part.ndim < len(r.shape):
                    part = part[None]
                comb = _kahan_combine if o in red_widths else spec.combine
                _accumulate(r, comb, spec.init, part, axes=acc_axes)

        def fused_kernel(*refs):
            in_refs = refs[:nin]
            sc_refs = refs[nin : nin + nsc]
            out_refs = refs[nin + nsc : nin + nsc + nfield]
            acc_refs = refs[nin + nsc + nfield :]
            if rsplit > 1:  # x-slab index rebased from the split grid axes
                i = pl.program_id(axis0) * per + pl.program_id(axis0 + 1)
                tax = axis0 + 2
            else:
                i = pl.program_id(axis0)
                tax = axis0 + 1
            jt = 0
            if by:
                jt = pl.program_id(tax)
                tax += 1
            kt = pl.program_id(tax) if bz else 0
            xs = i * bx
            ys = jt * by
            zs = kt * bz
            values = {}
            for n, (ncomp, lay), hlat, ring, nat, bat, r in zip(
                    ordered_ins, in_meta, hlats, in_rings, native_in,
                    in_batched, in_refs):
                # full halo'd stage (VMEM); batched refs carry a leading
                # length-1 batch-row axis
                arr = r[...][0] if (batch and bat) else r[...]
                rows = bx + 2 * ring
                tstarts, tsizes = tile_tail(ys, zs, ring, hlat)
                if nat:
                    # block-coordinate rebase: each x-plane of the halo'd
                    # lattice is row_blocks whole short arrays, so the
                    # window [xs, xs + rows) is a contiguous run on the
                    # block axis; the canonical nd window is recovered by
                    # the AoSoA unpack on VMEM-resident data (transpose of
                    # a (nblk, ncomp, sal) tile stack — never through HBM).
                    # Under a tiled plan the y/z tile is then cut from the
                    # unpacked canonical window — tile edges never split a
                    # short array, so view="block" composes with any
                    # dividing by/bz (the per-tile block_view_ok
                    # discipline)
                    row_blocks = int(math.prod(hlat[1:])) // lay.sal
                    tile = jax.lax.dynamic_slice(
                        arr,
                        (xs * row_blocks, 0, 0),
                        (rows * row_blocks, ncomp, lay.sal),
                    )
                    window = tile.transpose(1, 0, 2).reshape(
                        (ncomp, rows) + hlat[1:])
                    if tiled:
                        window = jax.lax.dynamic_slice(
                            window, (0, 0, *tstarts),
                            (ncomp, rows, *tsizes))
                else:
                    window = jax.lax.dynamic_slice(
                        arr,
                        (0, xs, *tstarts),
                        (ncomp, rows, *tsizes),
                    )
                values[n] = (window, ring)
            finish_tile(values, sc_refs, out_refs, acc_refs)

        # Double-buffered DMA pipeline (tiled pallas on a real TPU only):
        # inputs stay in HBM (memory_space=ANY) and each program DMAs its
        # halo'd tile window into one of two VMEM scratch slots, starting
        # the copy for tile t+1 before waiting on tile t's — grid axes are
        # sequential on TPU, so tile t+1's transfer overlaps tile t's
        # compute.  Gated off under interpret (no async-copy semantics),
        # rsplit/batch (extra grid axes ahead of the tile axes would need
        # their own linearization), and native AoSoA inputs (block-rebased
        # windows are staged whole).  Everything downstream of the window
        # (finish_tile) is shared with the fallback, so the pipeline is a
        # pure data-movement change.
        use_dma = (
            tiled and not interpret and rsplit == 1 and not batch
            and not any(native_in)
            and jax.default_backend() == "tpu"
        )
        n_lin = nslabs * nty * ntz
        win_shapes = []
        for (ncomp, lay), hlat, ring in zip(in_meta, hlats, in_rings):
            _, tsz = tile_tail(0, 0, ring, hlat)
            win_shapes.append((ncomp, bx + 2 * ring) + tuple(tsz))

        def dma_kernel(*refs):
            from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

            in_refs = refs[:nin]
            sc_refs = refs[nin : nin + nsc]
            out_refs = refs[nin + nsc : nin + nsc + nfield]
            nred = len(red_outputs)
            acc_refs = refs[nin + nsc + nfield : nin + nsc + nfield + nred]
            bufs = refs[nin + nsc + nfield + nred :
                        nin + nsc + nfield + nred + nin]
            sems = refs[nin + nsc + nfield + nred + nin :]
            tax = 1
            jt = 0
            if by:
                jt = pl.program_id(tax)
                tax += 1
            kt = pl.program_id(tax) if bz else 0
            i = pl.program_id(0)
            # linear tile index: the grid iterates the z-tile axis fastest
            t = (i * nty + jt) * ntz + kt

            def copy(tl, slot, idx):
                """Async-copy descriptor for input idx's halo'd window of
                linear tile tl into scratch slot ``slot``."""
                ii = tl // (nty * ntz)
                jj = (tl // ntz) % nty
                kk = tl % ntz
                ring = in_rings[idx]
                hlat = hlats[idx]
                src = [slice(None), pl.ds(ii * bx, bx + 2 * ring)]
                for d in range(1, site_ndim):
                    if d == 1 and by:
                        src.append(pl.ds(jj * by, by + 2 * ring))
                    elif d == 2 and bz:
                        src.append(pl.ds(kk * bz, bz + 2 * ring))
                    else:
                        src.append(slice(0, hlat[d]))
                return pltpu.make_async_copy(
                    in_refs[idx].at[tuple(src)],
                    bufs[idx].at[slot],
                    sems[idx].at[slot],
                )

            slot = jax.lax.rem(t, 2)

            @pl.when(t == 0)
            def _warm_up():
                for ix in range(nin):
                    copy(t, slot, ix).start()

            @pl.when(t + 1 < n_lin)
            def _prefetch():
                for ix in range(nin):
                    copy(t + 1, 1 - slot, ix).start()

            values = {}
            for ix, (n, ring) in enumerate(zip(ordered_ins, in_rings)):
                copy(t, slot, ix).wait()
                values[n] = (bufs[ix][slot], ring)
            finish_tile(values, sc_refs, out_refs, acc_refs)

        def stage_in(n, meta, lat, ring, nat, d):
            if not nat:
                return to_halo_nd(n, meta, lat, ring, d)
            if halo == "periodic" and ring > 0:
                ncomp, lay = meta
                return halo_pad_physical(d, lay, ncomp, lat, ring)
            return d  # "pre": the caller's physical array, staged as-is

        def fn(datas, svals):
            telemetry.inc("fuse.traces")
            telemetry.inc("fuse.pallas_calls")
            if cast_in is not None:
                datas = cast_in(datas)
            staged = []
            for n, meta, lat, ring, nat, bat, d in zip(
                    ordered_ins, in_meta, in_lats, in_rings, native_in,
                    in_batched, datas):
                if batch and bat:  # stage each batch element, stacked
                    staged.append(jax.vmap(
                        lambda x, _n=n, _m=meta, _l=lat, _r=ring, _na=nat:
                        stage_in(_n, _m, _l, _r, _na, x))(d))
                else:
                    staged.append(stage_in(n, meta, lat, ring, nat, d))
            kernel = fused_kernel
            call_kw = dict(in_specs=in_specs)
            if use_dma:
                from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

                kernel = dma_kernel
                # inputs stay in HBM; two window slots + one DMA
                # semaphore pair of scratch per input
                call_kw["in_specs"] = (
                    [pl.BlockSpec(memory_space=pltpu.ANY)
                     for _ in range(nin)] + list(in_specs[nin:])
                )
                dts = in_dtypes or tuple(
                    jnp.float32 for _ in range(nin))
                if cast_in is not None:
                    # staged float inputs were cast to the effective
                    # compute dtype, so the DMA window slots match it
                    cdt = compute_dt or storage_dt
                    dts = tuple(
                        cdt if jnp.issubdtype(jnp.dtype(dt), jnp.floating)
                        else dt for dt in dts)
                call_kw["scratch_shapes"] = (
                    [pltpu.VMEM((2,) + w, jnp.dtype(dt))
                     for w, dt in zip(win_shapes, dts)]
                    + [pltpu.SemaphoreType.DMA((2,)) for _ in range(nin)]
                )
            call = pl.pallas_call(
                kernel,
                grid=grid,
                out_specs=(
                    out_block_specs if len(out_block_specs) > 1 else out_block_specs[0]
                ),
                out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
                interpret=interpret,
                name=name,
                **call_kw,
            )
            res = call(*staged, *svals)
            if len(out_shapes) == 1:
                res = (res,)
            out = []
            for idx, r in enumerate(res):
                if idx >= nfield:  # reduction accumulator (..., ncomp, 1);
                    # split plans fold the (..., rsplit, ncomp) stage-1
                    # rows through the stage-2 combine in segment order
                    acc = r[..., 0]
                    if rsplit > 1:
                        acc = red_spec[red_outputs[idx - nfield]] \
                            .combine_partials(acc, axis=-2)
                    out.append(acc)
                elif native_out[idx]:  # already the physical AoSoA array
                    out.append(r)
                else:  # canonical nd -> requested physical layout
                    o = field_outputs[idx]
                    ncomp, _ = out_info[o]
                    pack = (lambda a, _o=o, _nc=ncomp:
                            out_layouts[_o].pack(a.reshape(_nc, -1)))
                    out.append(jax.vmap(pack)(r) if batch else pack(r))
            return tuple(out)

        return jax.jit(fn)


@dataclasses.dataclass(frozen=True)
class BoundLaunch:
    """A :meth:`LaunchGraph.launch` with its keyword sprawl frozen
    (:meth:`LaunchGraph.bind`): a reusable callable the drivers invoke
    with just the input Fields.  Per-call keywords override the bound
    ones (``out_layouts`` merges, call entries winning), so one bound
    launch serves call sites that differ only in, say, the output
    layout."""

    graph: LaunchGraph
    config: Optional[TargetConfig] = None
    outputs: Optional[Tuple[str, ...]] = None
    out_layouts: Optional[Mapping[str, Layout]] = None
    halo: str = "periodic"
    plan: Optional[LoweringPlan] = None

    def __call__(
        self,
        ins: Dict[str, Field],
        *,
        scalars: Optional[Mapping] = None,
        config: Optional[TargetConfig] = None,
        outputs: Optional[Sequence[str]] = None,
        out_layouts: Optional[Mapping[str, Layout]] = None,
        halo: Optional[str] = None,
        plan: Optional[LoweringPlan] = None,
    ) -> Dict[str, Union[Field, jax.Array]]:
        layouts = dict(self.out_layouts or {})
        if out_layouts:
            layouts.update(out_layouts)
        return self.graph.launch(
            ins,
            config=config if config is not None else self.config,
            outputs=outputs if outputs is not None else self.outputs,
            scalars=scalars,
            out_layouts=layouts or None,
            halo=halo if halo is not None else self.halo,
            plan=plan if plan is not None else self.plan,
        )


def _split_specs(specs, per: int) -> List[pl.BlockSpec]:
    """Grow a leading split-reduction grid axis (``LoweringPlan.rsplit``)
    on single-lattice BlockSpecs: the site-block/x-slab index is rebased
    to ``s * per + i``, so split segment ``s`` covers blocks
    [s*per, (s+1)*per) — the same block order as the unsplit grid, just
    regrouped into rsplit stage-1 partials.  Trailing grid coordinates
    (the y/z tile axes of a tiled stencil plan) pass through unchanged,
    so the split axis composes with tiling."""
    out = []
    for spec in specs:
        shape, m = tuple(spec.block_shape), spec.index_map
        out.append(pl.BlockSpec(
            shape,
            lambda s, i, *rest, _m=m, _p=per: tuple(_m(s * _p + i, *rest))))
    return out


def _batch_specs(specs, batched) -> List[pl.BlockSpec]:
    """Grow a leading batch grid axis on single-lattice BlockSpecs: a
    batched operand gets a length-1 batch-row block selected by the batch
    program id; a shared operand keeps its rank and ignores it.  The
    wrapped index map passes the remaining grid coordinates through, so
    it composes with the split-reduction axis of ``_split_specs``."""
    out = []
    for spec, bat in zip(specs, batched):
        shape, m = tuple(spec.block_shape), spec.index_map
        if bat:
            out.append(pl.BlockSpec(
                (1,) + shape, lambda b, *idx, _m=m: (b,) + tuple(_m(*idx))))
        else:
            out.append(pl.BlockSpec(
                shape, lambda b, *idx, _m=m: tuple(_m(*idx))))
    return out


def _batch_shapes(shapes, batch: int) -> List[jax.ShapeDtypeStruct]:
    return [jax.ShapeDtypeStruct((batch,) + tuple(s.shape), s.dtype)
            for s in shapes]


def _accumulate(ref, combine, init, partial, axes: Sequence[int] = (0,)):
    """Grid-sequential accumulation into a constant-index-map buffer (the
    fused analogue of core.reduce's partial-sum kernel).  ``axes`` are the
    grid axes that together address one accumulator row — the site-block
    (or x-slab) axis plus any trailing y/z tile axes of a tiled stencil
    plan; batch and split-segment axes are excluded because their rows are
    separate buffer blocks selected by the BlockSpec.  The row initializes
    at the program where *every* listed axis is 0 (its first visit)."""
    cond = pl.program_id(axes[0]) == 0
    for a in axes[1:]:
        cond = jnp.logical_and(cond, pl.program_id(a) == 0)

    @pl.when(cond)
    def _init():
        ref[...] = init(ref.shape, ref.dtype)

    ref[...] = combine(ref[...], partial)


def fused_launch(
    stages: Sequence[Tuple],
    ins: Dict[str, Field],
    *,
    config: Optional[TargetConfig] = None,
    outputs: Optional[Sequence[str]] = None,
    scalars: Optional[Mapping] = None,
    out_layouts: Optional[Mapping[str, Layout]] = None,
    name: str = "fused",
) -> Dict[str, Union[Field, jax.Array]]:
    """One-shot form: each stage is (kernel, ins, out_specs[, params[, rename]]).

    Equivalent to building a LaunchGraph of site-local stages and launching
    it; the launch cache keys on the stage bodies, so rebuilt graphs still
    hit."""
    g = LaunchGraph(name)
    for st in stages:
        kern, st_ins, st_outs = st[0], st[1], st[2]
        params = st[3] if len(st) > 3 else None
        rename = st[4] if len(st) > 4 else None
        g.add(kern, st_ins, st_outs, params=params, rename=rename)
    return g.launch(
        ins, config=config, outputs=outputs, scalars=scalars, out_layouts=out_layouts
    )
