"""Lattice-wide reductions (paper §3.2.3, ``targetDoubleSum`` et al.).

The application produces a per-site array (a Field); the reduction API
combines it.  jnp engine: a plain sum.  pallas engine: a grid-sequential
accumulation kernel — each program adds its site-block into a (ncomp, VVL)
partial-sum buffer (TPU pallas grids execute sequentially per core, so
read-modify-write accumulation across grid steps is well defined), and the
final (ncomp, VVL) -> (ncomp,) fold happens outside.  Across shards, callers
compose with ``jax.lax.psum`` (see core.halo / apps drivers), mirroring the
paper's MPI_Allreduce-above-targetDP split.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .field import Field  # noqa: F401  (re-exported reduction operand type)
from .plan import plan_for_launch
from .target import TargetConfig

__all__ = ["target_sum", "target_max"]

_MONOIDS = {
    "sum": (lambda a, b: a + b, lambda shape, dt: jnp.zeros(shape, dt), jnp.sum),
    "max": (
        lambda a, b: jnp.maximum(a, b),
        lambda shape, dt: jnp.full(shape, -jnp.inf, dt),
        jnp.max,
    ),
}


def _reduce(field, config: Optional[TargetConfig], op: str) -> jax.Array:
    config = config or TargetConfig()
    combine, init, fold = _MONOIDS[op]
    batch = int(getattr(field, "batch", 0))
    # lowering decisions (vvl conformance, interpret fallback, plan policy)
    # come from the planning layer, like every other launch
    plan = plan_for_launch(config, field.nsites, [field.layout])
    if plan.engine == "jnp":
        # batched: (batch, ncomp, nsites) -> (batch, ncomp); the per-row
        # fold is the same whole-lattice fold as the single-Field path
        return fold(field.canonical(), axis=-1)

    vvl = plan.vvl
    nsites, ncomp = field.nsites, field.ncomp
    layout = field.layout
    blk = tuple(layout.block_shape(ncomp, vvl))
    bmap = layout.block_index_map()
    if batch:
        # leading batch grid axis: each batch row accumulates its own
        # (ncomp, vvl) partial in the same site-block order as the
        # single-Field kernel — per-element bitwise identical
        grid = (batch, nsites // vvl)
        in_spec = pl.BlockSpec((1,) + blk,
                               lambda b, i, _m=bmap: (b,) + tuple(_m(i)))
        out_spec = pl.BlockSpec((1, ncomp, vvl), lambda b, i: (b, 0, 0))
        out_shape = jax.ShapeDtypeStruct((batch, ncomp, vvl), field.dtype)
        blk_axis = 1
    else:
        grid = (nsites // vvl,)
        in_spec = pl.BlockSpec(blk, bmap)
        out_spec = pl.BlockSpec((ncomp, vvl), lambda i: (0, 0))
        out_shape = jax.ShapeDtypeStruct((ncomp, vvl), field.dtype)
        blk_axis = 0

    def kern(x_ref, acc_ref):
        @pl.when(pl.program_id(blk_axis) == 0)
        def _init():
            acc_ref[...] = init(acc_ref.shape, acc_ref.dtype)

        x = x_ref[...][0] if batch else x_ref[...]
        chunk = layout.block_to_canonical(x, ncomp, vvl)
        acc_ref[...] = combine(acc_ref[...], chunk[None] if batch else chunk)

    partial = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=plan.interpret,
        name=f"target_{op}",
    )(field.data)
    return fold(partial, axis=-1)


def target_sum(field, config: Optional[TargetConfig] = None) -> jax.Array:
    """targetDoubleSum: per-component sum over all local lattice sites.
    A :class:`~repro.core.field.BatchedField` reduces per batch element to
    ``(batch, ncomp)`` — each row bitwise the single-Field reduction."""
    return _reduce(field, config, "sum")


def target_max(field, config: Optional[TargetConfig] = None) -> jax.Array:
    """Per-component max over all local lattice sites (per batch element
    for a BatchedField)."""
    return _reduce(field, config, "max")
