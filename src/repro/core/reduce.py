"""Lattice-wide reductions (paper §3.2.3, ``targetDoubleSum`` et al.).

The application produces a per-site array (a Field); the reduction API
combines it.  jnp engine: a plain sum.  pallas engine: a grid-sequential
accumulation kernel — each program adds its site-block into a (ncomp, VVL)
partial-sum buffer (TPU pallas grids execute sequentially per core, so
read-modify-write accumulation across grid steps is well defined), and the
final (ncomp, VVL) -> (ncomp,) fold happens outside.  Across shards, callers
compose with ``jax.lax.psum`` (see core.halo / apps drivers), mirroring the
paper's MPI_Allreduce-above-targetDP split.

Split reductions: a plan with ``rsplit > 1`` (an explicit
``TargetConfig.plan_policy`` plan — the standalone path has no graph key to
tune on) partitions the site-block grid into ``rsplit`` segments, each
accumulating its own ``(ncomp, VVL)`` stage-1 partial row; a tiny stage-2
combine folds the rows in segment order.  Same contract as the fused
lowering (core.fuse): deterministic for a fixed ``rsplit``, bitwise exact
for max and integer sums, tolerance-level reassociation for fp sums.

The reduction monoid itself (combine/init/fold) is the shared
:class:`~repro.core.fuse.ReduceSpec` definition.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .field import Field  # noqa: F401  (re-exported reduction operand type)
from .fuse import ReduceSpec, kahan_fold
from .plan import plan_for_launch, resolve_accumulate
from .target import TargetConfig

__all__ = ["target_sum", "target_max"]


def _reduce(field, config: Optional[TargetConfig], op: str) -> jax.Array:
    config = config or TargetConfig()
    spec = ReduceSpec(op=op)
    batch = int(getattr(field, "batch", 0))
    # lowering decisions (vvl conformance, interpret fallback, plan policy)
    # come from the planning layer, like every other launch
    plan = plan_for_launch(config, field.nsites, [field.layout])
    # Accumulate-dtype policy: applies only to floating-point sums (max and
    # integer reductions stay bitwise-unchanged by the dtype axis).  The
    # plan's own policy wins over the config-level one, like core.fuse.
    acc_dt, comp = None, False
    pol = plan.dtypes or getattr(config, "dtypes", None)
    if (pol and pol.accumulate and op == "sum"
            and jnp.issubdtype(jnp.dtype(field.dtype), jnp.floating)):
        acc_name, comp = resolve_accumulate(pol.accumulate)
        if acc_name:
            acc_dt = jnp.dtype(acc_name)
    if plan.engine == "jnp":
        # batched: (batch, ncomp, nsites) -> (batch, ncomp); the per-row
        # fold is the same whole-lattice fold as the single-Field path
        x = field.canonical()
        if acc_dt is not None:
            x = x.astype(acc_dt)
            return kahan_fold(x, axis=-1) if comp else spec.fold(x, axis=-1)
        return spec.fold(x, axis=-1)

    vvl, rsplit = plan.vvl, plan.rsplit
    nsites, ncomp = field.nsites, field.ncomp
    layout = field.layout
    blk = tuple(layout.block_shape(ncomp, vvl))
    bmap = layout.block_index_map()
    nblocks = nsites // vvl
    per = nblocks // rsplit
    # grid axes, outermost first: (batch?, rsplit?, blocks-per-segment);
    # each (batch row, split segment) accumulates its own (ncomp, vvl)
    # partial in the same site-block order as the unsplit kernel
    if rsplit > 1:
        in_map = lambda s, i, _m=bmap: tuple(_m(s * per + i))  # noqa: E731
        out_blk, out_map = (1, ncomp, vvl), lambda s, i: (s, 0, 0)
        acc_shape = (rsplit, ncomp, vvl)
    else:
        in_map = bmap
        out_blk, out_map = (ncomp, vvl), lambda i: (0, 0)
        acc_shape = (ncomp, vvl)
    out_dt = acc_dt if acc_dt is not None else field.dtype
    if comp:
        # compensated (Kahan) accumulation: widen with a trailing
        # (sum, compensation) axis carried across grid steps
        acc_shape = acc_shape + (2,)
        out_blk = out_blk + (2,)
        _m0 = out_map
        out_map = lambda *idx, _m=_m0: tuple(_m(*idx)) + (0,)  # noqa: E731
    if batch:
        grid = ((batch, rsplit, per) if rsplit > 1 else (batch, nblocks))
        in_spec = pl.BlockSpec(
            (1,) + blk, lambda b, *idx, _m=in_map: (b,) + tuple(_m(*idx)))
        out_spec = pl.BlockSpec(
            (1,) + out_blk, lambda b, *idx, _m=out_map: (b,) + tuple(_m(*idx)))
        out_shape = jax.ShapeDtypeStruct((batch,) + acc_shape, out_dt)
    else:
        grid = (rsplit, per) if rsplit > 1 else (nblocks,)
        in_spec = pl.BlockSpec(blk, in_map)
        out_spec = pl.BlockSpec(out_blk, out_map)
        out_shape = jax.ShapeDtypeStruct(acc_shape, out_dt)
    blk_axis = len(grid) - 1

    def kern(x_ref, acc_ref):
        @pl.when(pl.program_id(blk_axis) == 0)
        def _init():
            acc_ref[...] = spec.init(acc_ref.shape, acc_ref.dtype)

        x = x_ref[...][0] if batch else x_ref[...]
        chunk = layout.block_to_canonical(x, ncomp, vvl)
        if acc_dt is not None:
            chunk = chunk.astype(acc_dt)
        if comp:
            while chunk.ndim < len(acc_ref.shape) - 1:
                chunk = chunk[None]
            acc = acc_ref[...]
            s, c = acc[..., 0], acc[..., 1]
            y = chunk - c
            t = s + y
            acc_ref[...] = jnp.stack([t, (t - s) - y], axis=-1)
        else:
            while chunk.ndim < len(acc_ref.shape):
                chunk = chunk[None]
            acc_ref[...] = spec.combine(acc_ref[...], chunk)

    partial = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=plan.interpret,
        name=f"target_{op}",
    )(field.data)
    if comp:
        # drop the compensation column, then fold the vvl lanes with the
        # same compensated summation used across grid steps
        folded = kahan_fold(partial[..., 0], axis=-1)
    else:
        folded = spec.fold(partial, axis=-1)
    if rsplit > 1:  # stage-2 combine over the split-segment rows
        folded = spec.combine_partials(folded, axis=-2)
    return folded


def target_sum(field, config: Optional[TargetConfig] = None) -> jax.Array:
    """targetDoubleSum: per-component sum over all local lattice sites.
    A :class:`~repro.core.field.BatchedField` reduces per batch element to
    ``(batch, ncomp)`` — each row bitwise the single-Field reduction."""
    return _reduce(field, config, "sum")


def target_max(field, config: Optional[TargetConfig] = None) -> jax.Array:
    """Per-component max over all local lattice sites (per batch element
    for a BatchedField)."""
    return _reduce(field, config, "max")
