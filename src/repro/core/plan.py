"""LoweringPlan: every launch decision in one hashable place (paper §3.2.2).

The paper tunes the Virtual Vector Length *per architecture by hand* and
reports that the optimum differs across CPU, Xeon Phi and GPU; the targetDP
report (Gray & Stratford 2014) frames VVL and friends as per-target
compile-time constants behind a single abstraction.  Before this module the
JAX port scattered those decisions across three call sites — the
single-kernel pallas path, the site-local fused path and the halo'd-stencil
fused path — each re-deriving vvl/slab/interpret inline.  Now every launch
routes through a :class:`LoweringPlan`:

  engine      "jnp" (host C / OpenMP analogue) or "pallas" (device analogue)
  vvl         sites per pallas program (site-local lowering; 0 otherwise)
  bx          x-slab planes per program (halo'd stencil lowering; 0 otherwise)
  interpret   pallas interpret-mode fallback (True automatically off-TPU)
  halo        stencil halo strategy: "periodic" pad, caller-"pre"-exchanged,
              or "overlap" (interior/boundary split launches overlapping the
              halo exchange with interior compute — core.overlap)
  view        canonical-view strategy: "block" (layout pack/unpack inside the
              kernel, per VMEM block) or "staged-nd" (canonical SoA-nd views
              packed/unpacked as XLA ops around the single halo'd kernel).
              Site-local lowerings always use "block" (BlockSpec tiling per
              Layout).  Stencil lowerings default to "staged-nd"; "block" is
              the *native AoSoA* stencil lowering: halo'd AoSoA inputs are
              staged whole as physical ``(nblocks, ncomp, SAL)`` tiles, each
              program slices its halo'd x-slab window on the *block* axis
              and un-/re-packs in VMEM, so the paper's layout sweep reaches
              halo'd chains without an XLA pack/unpack round-trip
              (``block_view_ok`` states the alignment precondition).  The
              dataclass default is the "auto" sentinel: resolved per shape
              by ``adapt_plan`` (block for site-local, staged-nd for
              stencil), so hand-built plans without view= behave as before
              the knob existed

``choose_vvl`` / ``choose_slab`` live here as plan *candidate generators*:
they enumerate the divisors of the lattice extent (memoized — the previous
linear scan was O(nsites) per uncached launch for prime-ish lattices) and
``default_plan`` picks the largest conforming one, reproducing the
pre-plan heuristics bit-for-bit.  ``candidate_plans`` enumerates the whole
conforming set for the autotuner (core.tune), which persists per-(chain,
layout, backend) winners so applications get architecture-specific tuning
without touching kernel or driver code — the paper's central claim, made a
layer instead of a hand edit.

Policy (``TargetConfig.plan_policy``):

  "default"        the heuristic plan (bit-identical to the pre-plan code)
  "tuned"          look up the persisted autotuner table (core.tune) by the
                   launch's plan key; fall back to "default" on a miss
  LoweringPlan     use exactly this plan (validated against the launch)
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import logging
import math
import os
from typing import Optional, Sequence, Tuple

from .layout import Layout, LayoutKind

__all__ = [
    "DtypePolicy",
    "LoweringPlan",
    "dtype_itemsize",
    "resolve_accumulate",
    "divisors",
    "choose_vvl",
    "choose_slab",
    "choose_tiles",
    "resolve_vvl",
    "sal_alignment",
    "block_view_ok",
    "default_plan",
    "plan_for_launch",
    "sub_lattice_plan",
    "candidate_plans",
    "graph_plan_key",
    "tile_extents",
    "estimate_vmem_bytes",
    "resolved_vmem_bytes",
]

log = logging.getLogger(__name__)

# environment override for the per-program VMEM byte budget (see
# resolved_vmem_bytes); an unset/empty value means "unbounded", which keeps
# every default plan bit-identical to the pre-budget heuristics
VMEM_ENV = "TARGETDP_VMEM_BYTES"

VIEW_BLOCK = "block"
VIEW_STAGED_ND = "staged-nd"
# dataclass default: resolved per lowering shape by adapt_plan (site-local
# -> block, stencil -> staged-nd), so hand-built plans that never set view=
# keep the exact pre-view-knob behavior; requesting the native-AoSoA stencil
# lowering is always an explicit view=VIEW_BLOCK
VIEW_AUTO = "auto"


# -- dtype policy (mixed-precision lowering axis) ------------------------------

# itemsizes for the dtype names a policy may carry, kept as a plain table so
# plan construction / budget estimation never import jax or numpy
_DTYPE_ITEMSIZE = {
    "float64": 8, "float32": 4, "float16": 2, "bfloat16": 2,
    "int64": 8, "int32": 4, "int16": 2, "int8": 1,
}
# compact describe() abbreviations — persisted timing labels use these
_DTYPE_SHORT = {
    "float64": "f64", "float32": "f32", "float16": "f16", "bfloat16": "bf16",
    "compensated": "kf32",
}
# the accumulate slot additionally admits the explicit compensated request
ACCUM_COMPENSATED = "compensated"


def dtype_itemsize(name: str, fallback: int = 4) -> int:
    """Itemsize in bytes of a policy dtype name ('' -> ``fallback``)."""
    return _DTYPE_ITEMSIZE.get(name, fallback) if name else fallback


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """The precision triple of a launch — storage, compute, accumulate —
    as a lowering decision (ROADMAP: mixed-precision solvers as a tuned
    plan axis).  Every slot is a dtype *name* ('' = inherit):

      storage      dtype field data is staged in and field outputs are
                   written in ('' = the caller's input dtype).  This is
                   what cuts HBM bytes: bf16 storage nearly halves the
                   traffic of every memory-bound kernel.
      compute      dtype kernel arithmetic runs in ('' = the input/storage
                   dtype).  Inputs are upcast on stage-in, so bf16-stored
                   fields can still multiply in fp32.
      accumulate   dtype terminal sum reductions (fused ReduceSpec sums,
                   rsplit stage-1 partials, standalone target_sum)
                   accumulate in.  '' = the pre-policy behavior (the
                   output dtype).  'float64' requests fp64 accumulation
                   and *degrades to compensated (Kahan) fp32* when the
                   runtime has no fp64 (jax x64 disabled) — see
                   :func:`resolve_accumulate`.  'compensated' requests
                   Kahan fp32 explicitly.  Max and integer reductions
                   ignore this slot and stay bitwise exact.

    The empty policy (all '') — and a plan with ``dtypes=None`` — lowers
    bit-identically to the pre-policy code on every path."""

    storage: str = ""
    compute: str = ""
    accumulate: str = ""

    def __bool__(self) -> bool:
        return bool(self.storage or self.compute or self.accumulate)

    def tag(self) -> str:
        """Compact label component, e.g. ``bf16:f32:f64``."""
        return ":".join(_DTYPE_SHORT.get(s, s) if s else "-"
                        for s in (self.storage, self.compute, self.accumulate))

    def storage_itemsize(self, fallback: int) -> int:
        return dtype_itemsize(self.storage, fallback)

    def validate(self) -> "DtypePolicy":
        for slot, name in (("storage", self.storage),
                           ("compute", self.compute)):
            if name and name not in _DTYPE_ITEMSIZE:
                raise ValueError(
                    f"DtypePolicy.{slot}={name!r} is not a known dtype "
                    f"name; use one of {sorted(_DTYPE_ITEMSIZE)}")
        acc = self.accumulate
        if acc and acc != ACCUM_COMPENSATED and (
                acc not in _DTYPE_ITEMSIZE or not acc.startswith("float")):
            raise ValueError(
                f"DtypePolicy.accumulate={acc!r} must be '', a float dtype "
                f"name, or {ACCUM_COMPENSATED!r}")
        return self


def resolve_accumulate(name: str):
    """Resolve an accumulate request to ``(dtype_name, compensated)``.

    'compensated' -> ('float32', True).  'float64' stays fp64 only when the
    runtime actually has it (``jax.config.jax_enable_x64``); otherwise jnp
    would *silently truncate* the accumulator to fp32, so the request
    degrades to compensated (Kahan) fp32 — strictly more accurate than the
    silent truncation and the documented contract on fp64-less targets.
    '' and any other float name pass through uncompensated."""
    if not name:
        return "", False
    if name == ACCUM_COMPENSATED:
        return "float32", True
    if name == "float64":
        import jax

        if not jax.config.jax_enable_x64:
            return "float32", True
    return name, False


# -- divisor enumeration (memoized candidate generators) -----------------------

@functools.lru_cache(maxsize=4096)
def divisors(n: int) -> Tuple[int, ...]:
    """All divisors of n, ascending.  O(sqrt n) once, then memoized — called
    on every uncached launch, so the old per-launch linear scan mattered for
    prime-ish lattice extents."""
    if n < 1:
        raise ValueError(f"divisors of n >= 1 only, got {n}")
    small, large = [], []
    d = 1
    while d * d <= n:
        if n % d == 0:
            small.append(d)
            if d != n // d:
                large.append(n // d)
        d += 1
    return tuple(small + large[::-1])


@functools.lru_cache(maxsize=4096)
def choose_vvl(nsites: int, preferred: int = 128, multiple_of: int = 1) -> int:
    """Largest divisor of nsites that is <= preferred and a multiple of
    ``multiple_of`` (the lcm of the AoSoA SALs in play, so every VMEM block
    is a whole number of short arrays).  When no such divisor <= preferred
    exists, falls back to ``multiple_of`` itself — correctness (SAL-aligned
    blocks) wins over the preferred block size — and raises only when even
    that cannot divide the lattice."""
    best = 0
    for v in divisors(nsites):
        if v > preferred:
            break
        if v % multiple_of == 0:
            best = v
    if best:
        return best
    if multiple_of <= nsites and nsites % multiple_of == 0:
        return multiple_of
    raise ValueError(
        f"no vvl <= {preferred} divides nsites={nsites} and is a multiple "
        f"of sal alignment {multiple_of}"
    )


@functools.lru_cache(maxsize=4096)
def choose_slab(
    x_dim: int,
    inner_sites: int,
    vvl: int,
    site_bytes: int = 0,
    vmem_bytes: Optional[int] = None,
) -> int:
    """Sites-per-program for a stencil (x-slab) grid: the largest divisor
    ``bx`` of the leading lattice dim whose slab (bx * inner_sites sites)
    stays within the budget.  The stencil analogue of choose_vvl — when
    vvl does not divide the interior block (inner_sites ∤ vvl) the slab
    shrinks to the best conforming divisor instead of raising, and a single
    x-plane (bx=1) is always valid.

    The budget is ``max(vvl, inner_sites)`` sites (the pre-budget heuristic,
    bit-identical when no byte budget is in play), additionally capped by an
    explicit VMEM byte budget when one is configured: ``site_bytes`` is the
    per-site traffic of the launch (sum of input+output ncomp*itemsize) and
    ``vmem_bytes`` the budget (``TargetConfig.vmem_bytes`` /
    ``$TARGETDP_VMEM_BYTES``)."""
    budget = max(int(vvl), inner_sites)
    if vmem_bytes and site_bytes:
        budget = min(budget, max(vmem_bytes // site_bytes, 1))
    best = 1
    for bx in divisors(x_dim):
        if bx * inner_sites <= budget:
            best = bx
    return best


def sal_alignment(layouts: Sequence[Layout]) -> int:
    """lcm of the AoSoA short-array lengths touched by a launch."""
    align = 1
    for lay in layouts:
        if lay.kind is LayoutKind.AOSOA:
            align = align * lay.sal // math.gcd(align, lay.sal)
    return align


def block_view_ok(
    in_views: Sequence[Tuple[Layout, int]],
    out_layouts: Sequence[Layout],
    interior_inner: int,
) -> bool:
    """Whether a stencil launch can lower natively on AoSoA blocks
    (``view="block"``).

    in_views        (layout, halo'd inner-plane site count) per external
                    input — ``prod(halo'd_lattice[1:])``, the site count of
                    one x-plane of the *staged* (halo'd) array.
    out_layouts     layout per field output.
    interior_inner  ``prod(interior_lattice[1:])``.

    True iff at least one *input* is AoSoA (the knob only pays when a halo'd
    input would otherwise round-trip through an XLA unpack) and every AoSoA
    layout in play is block-aligned: an input's SAL must divide its halo'd
    inner-plane count (so every x-slab window is a whole number of short
    arrays and the per-program ``dynamic_slice`` can be rebased to block
    coordinates), and an output's SAL must divide the interior inner-plane
    count (so the disjoint slab BlockSpec rows are whole blocks)."""
    if not any(lay.kind is LayoutKind.AOSOA for lay, _ in in_views):
        return False
    for lay, halo_inner in in_views:
        if lay.kind is LayoutKind.AOSOA and halo_inner % lay.sal:
            return False
    for lay in out_layouts:
        if lay.kind is LayoutKind.AOSOA and interior_inner % lay.sal:
            return False
    return True


def tile_extents(
    lattice: Sequence[int], bx: int, by: int = 0, bz: int = 0
) -> Tuple[int, ...]:
    """Per-dim tile extents of a (possibly) tiled stencil program: ``bx``
    planes on the leading dim, ``by``/``bz`` on the next two when set (0 =
    whole axis), every further dim whole.  The tiles with these extents
    cover the lattice exactly and disjointly (validate() enforces the
    divisibility that makes that true)."""
    ext = [bx or lattice[0]]
    if len(lattice) > 1:
        ext.append(by or lattice[1])
    if len(lattice) > 2:
        ext.append(bz or lattice[2])
    ext.extend(lattice[3:])
    return tuple(ext)


def resolved_vmem_bytes(config) -> Optional[int]:
    """The per-program VMEM byte budget in effect: an explicit
    ``TargetConfig.vmem_bytes`` wins, else ``$TARGETDP_VMEM_BYTES``, else
    None (unbounded — the pre-budget behavior, so default plans stay
    bit-identical unless a budget is actually configured)."""
    vb = getattr(config, "vmem_bytes", None)
    if vb is not None:
        return int(vb) or None
    env = os.environ.get(VMEM_ENV, "")
    if env:
        try:
            return int(env) or None
        except ValueError:
            log.warning("ignoring non-integer $%s=%r", VMEM_ENV, env)
    return None


def estimate_vmem_bytes(
    plan: "LoweringPlan",
    *,
    lattice: Sequence[int],
    in_views: Sequence[Tuple[int, int, int]],
    out_views: Sequence[Tuple[int, int]] = (),
) -> int:
    """Model the per-program VMEM footprint of a stencil launch in bytes.

    in_views    (ncomp, halo ring, dtype itemsize) per external input
    out_views   (ncomp, dtype itemsize) per field output

    Untiled plans stage every input *whole* (the halo'd array is resident
    for the launch) plus one output slab per program.  Tiled plans hold two
    halo'd tile windows per input (the double-buffered DMA slots pipelining
    tile t+1 against tile t) plus one output tile — which is what bounds a
    shard by the tile, not the lattice.

    A plan carrying a storage :class:`DtypePolicy` is priced at the
    *storage* itemsize (fields are staged and written in the storage
    dtype), so bf16 candidates are budgeted against their real footprint
    rather than the caller's fp32 one."""
    bx = plan.bx or lattice[0]
    tiled = bool(plan.by or plan.bz)
    if plan.dtypes is not None and plan.dtypes.storage:
        in_views = [(nc, ring, plan.dtypes.storage_itemsize(isz))
                    for nc, ring, isz in in_views]
        out_views = [(nc, plan.dtypes.storage_itemsize(isz))
                     for nc, isz in out_views]
    total = 0
    for ncomp, ring, isz in in_views:
        if tiled:
            win = [bx + 2 * ring]
            if len(lattice) > 1:
                win.append((plan.by or lattice[1]) + 2 * ring)
            if len(lattice) > 2:
                win.append((plan.bz or lattice[2]) + 2 * ring)
            win.extend(s + 2 * ring for s in lattice[3:])
            total += 2 * ncomp * int(math.prod(win)) * isz
        else:
            total += ncomp * int(
                math.prod(s + 2 * ring for s in lattice)) * isz
    tile_sites = int(math.prod(tile_extents(lattice, bx, plan.by, plan.bz)))
    for ncomp, isz in out_views:
        total += ncomp * tile_sites * isz
    return total


def choose_tiles(
    lattice: Sequence[int],
    bx: int,
    *,
    in_views: Sequence[Tuple[int, int, int]],
    out_views: Sequence[Tuple[int, int]],
    vmem_bytes: int,
    dtypes: Optional["DtypePolicy"] = None,
) -> Tuple[int, int]:
    """Pick the largest (by, bz) tile whose estimated footprint fits the
    byte budget, preferring to keep the minor (z) axis whole — tile windows
    stay contiguous along the fast axis, which is what the DMA engine
    wants.  Returns (0, 0) when untiled whole-staging already fits, and the
    finest legal tile (best effort) when even it exceeds the budget.
    ``dtypes`` prices the probe at the policy's storage itemsize."""

    def fp(by, bz):
        probe = LoweringPlan("pallas", bx=bx, by=by, bz=bz, dtypes=dtypes)
        return estimate_vmem_bytes(
            probe, lattice=lattice, in_views=in_views, out_views=out_views)

    if fp(0, 0) <= vmem_bytes:
        return (0, 0)
    bys = [d for d in divisors(lattice[1])] if len(lattice) > 1 else [0]
    bzs = [d for d in divisors(lattice[2])] if len(lattice) > 2 else [0]
    pairs = [(by, bz) for by in bys for bz in bzs]
    # largest tile first; prefer whole-z (bz == lattice[2]) on ties
    pairs.sort(key=lambda p: ((p[0] or 1) * (p[1] or 1), p[1] or 1),
               reverse=True)
    for by, bz in pairs:
        by_eff = 0 if (len(lattice) > 1 and by == lattice[1]) else by
        bz_eff = 0 if (len(lattice) > 2 and bz == lattice[2]) else bz
        if not (by_eff or bz_eff):
            continue  # the untiled probe already failed
        if fp(by_eff, bz_eff) <= vmem_bytes:
            return (by_eff, bz_eff)
    return (1 if len(lattice) > 1 and lattice[1] > 1 else 0,
            1 if len(lattice) > 2 and lattice[2] > 1 else 0)


def resolve_vvl(config, nsites: int, layouts: Sequence[Layout]) -> int:
    """config.vvl when it fits, else the best choose_vvl fallback.

    'Fits' means vvl | nsites and sal | vvl for every AoSoA layout touched by
    the launch; otherwise the largest conforming divisor is substituted, so
    odd lattice sizes launch instead of raising (auto-vvl)."""
    align = sal_alignment(layouts)
    vvl = config.vvl
    if nsites % vvl == 0 and vvl % align == 0:
        return vvl
    return choose_vvl(nsites, vvl, multiple_of=align)


# -- the plan itself -----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LoweringPlan:
    """One launch's worth of lowering decisions, hashable (it is the launch
    cache key's planning component) and JSON-serializable (it is what the
    autotuner persists)."""

    engine: str = "jnp"
    vvl: int = 0
    bx: int = 0
    interpret: bool = False
    halo: str = "periodic"
    view: str = VIEW_AUTO
    # split-reduction factor: 1 lowers terminal reductions as the single
    # grid-sequential accumulator (bit-identical to the pre-rsplit code);
    # rsplit > 1 partitions the site-block (or x-slab) grid into rsplit
    # segments, each accumulating its own stage-1 partial row, combined by
    # a tiny stage-2 fold in segment order.  Deterministic for a fixed
    # rsplit; tolerance-equal (not bitwise) to rsplit=1 for fp sums, exact
    # for max and integer sums.  Pallas engine only.
    rsplit: int = 1
    # y/z tile extents for the halo'd stencil grid (pallas engine only).
    # 0 = whole axis: the pre-tiling x-slab lowering, so every persisted
    # plan (and every hand-built plan that never set them) lowers exactly
    # as before — no tune-table schema bump needed.  When set, each dim's
    # extent must divide the lattice extent, the grid gains a trailing
    # (sequential, fastest-iterating) tile axis per set extent, and each
    # program computes one (bx, by, bz) tile from a halo'd tile window —
    # on a real TPU the window is DMA'd into a double-buffered VMEM
    # scratch slot while the previous tile computes, so per-program VMEM
    # is bounded by the tile, not the lattice.  Field outputs stay bitwise
    # identical to the untiled lowering; terminal fp-sum reductions are
    # tolerance-equal (per-tile partials fold in tile order — the same
    # contract as rsplit), exact for max and integer sums.
    by: int = 0
    bz: int = 0
    # mixed-precision dtype policy (storage/compute/accumulate — see
    # :class:`DtypePolicy`).  None = the pre-policy lowering, bit-identical
    # on every engine/halo/layout path; a set policy is a tuned/explicit
    # opt-in whose field outputs are tolerance-equal (accuracy-gated by the
    # tuner) and whose max/integer reductions stay bitwise exact.  Persisted
    # plans carry it, hence the tune-table schema_version 3 -> 4 bump.
    dtypes: Optional[DtypePolicy] = None

    # -- serialization (core.tune persists plans as JSON) ----------------------

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "LoweringPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        if isinstance(d.get("dtypes"), dict):
            d["dtypes"] = DtypePolicy(**{
                k: v for k, v in d["dtypes"].items()
                if k in ("storage", "compute", "accumulate")})
        return cls(**d)

    def describe(self, footprint: Optional[int] = None) -> str:
        """Short human/table label: the knob that distinguishes candidates.
        ``footprint`` (bytes, from :func:`estimate_vmem_bytes`) appends the
        estimated per-program VMEM footprint — the tuner's over-budget skip
        log and the benchmarks pass it; plain labels stay stable."""
        suffix = "/overlap" if self.halo == "overlap" else ""
        # the dtype policy is named whenever it is in play: a tuned
        # mixed-precision winner must be identifiable in persisted timing
        # labels; policy-free labels stay byte-stable
        if self.dtypes:
            suffix += f"/dt={self.dtypes.tag()}"
        fp = f" [~{footprint / 1024:.0f}KiB/prog]" if footprint else ""
        if self.engine != "pallas":
            return self.engine + suffix + fp
        knob = f"bx={self.bx}" if self.bx else f"vvl={self.vvl}"
        # the y/z tile axes are named whenever they are in play, like
        # rsplit: a tuned tiled winner must be identifiable in persisted
        # timing labels; untiled labels stay byte-stable
        tile = ((f"/ty{self.by}" if self.by else "")
                + (f"/tz{self.bz}" if self.bz else ""))
        # stencil plans carry the canonical-view knob (native AoSoA blocks
        # vs staged-nd); site-local plans are always "block", untagged so
        # persisted timing labels stay stable
        view = "/block" if (self.bx and self.view == VIEW_BLOCK) else ""
        # the split-reduction axis is named whenever it is in play — a
        # tuned rsplit>1 winner must be identifiable in the persisted
        # timing labels (its results are tolerance-, not bitwise-equal)
        rs = f"/rs{self.rsplit}" if self.rsplit > 1 else ""
        return (f"pallas/{knob}{tile}{view}{rs}"
                + ("/interpret" if self.interpret else "") + suffix + fp)

    # -- validation -------------------------------------------------------------

    def validate(
        self,
        *,
        nsites: Optional[int] = None,
        lattice: Optional[Tuple[int, ...]] = None,
        layouts: Sequence[Layout] = (),
        stencil: bool = False,
    ) -> "LoweringPlan":
        """Check this plan against a concrete launch; raises ValueError with
        the violated invariant.  Returns self (chainable)."""
        if self.engine not in ("jnp", "pallas"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.halo not in ("periodic", "pre", "overlap"):
            raise ValueError(
                f"halo must be 'periodic', 'pre' or 'overlap', "
                f"got {self.halo!r}")
        if self.view not in (VIEW_AUTO, VIEW_BLOCK, VIEW_STAGED_ND):
            raise ValueError(f"unknown canonical-view strategy {self.view!r}")
        if self.halo == "overlap" and not stencil:
            raise ValueError(
                "halo='overlap' applies only to stencil graphs: a "
                "site-local graph has no halo exchange to overlap "
                "(add a stencil stage or use the default halo)")
        if self.rsplit < 1:
            raise ValueError(f"rsplit must be >= 1, got {self.rsplit}")
        if self.by < 0 or self.bz < 0:
            raise ValueError(
                f"tile extents must be >= 0 (0 = whole axis), got "
                f"by={self.by} bz={self.bz}")
        if self.dtypes is not None:
            self.dtypes.validate()
        if self.engine == "jnp":
            if self.rsplit > 1:
                raise ValueError(
                    "rsplit > 1 splits the pallas reduction grid into "
                    "stage-1 partial segments; the jnp engine folds "
                    "whole-lattice arrays and has no grid to split")
            if self.by or self.bz:
                raise ValueError(
                    "by/bz tile the pallas stencil grid; the jnp engine "
                    "folds whole-lattice arrays and has no grid to tile")
            return self
        if stencil:
            if self.by and lattice is not None:
                if len(lattice) < 2:
                    raise ValueError(
                        f"by={self.by} tiles the second lattice dim, but "
                        f"the lattice {lattice} has no y axis")
                if lattice[1] % self.by:
                    raise ValueError(
                        f"by={self.by} must divide the y lattice dim "
                        f"{lattice[1]} so the tile cover is exact and "
                        f"disjoint")
            if self.bz and lattice is not None:
                if len(lattice) < 3:
                    raise ValueError(
                        f"bz={self.bz} tiles the third lattice dim, but "
                        f"the lattice {lattice} has no z axis")
                if lattice[2] % self.bz:
                    raise ValueError(
                        f"bz={self.bz} must divide the z lattice dim "
                        f"{lattice[2]} so the tile cover is exact and "
                        f"disjoint")
            if self.bx < 1:
                raise ValueError(
                    f"stencil lowering needs an x-slab bx >= 1, got plan "
                    f"{self.describe()}")
            if lattice is not None and lattice[0] % self.bx:
                raise ValueError(
                    f"bx={self.bx} must divide the leading lattice dim "
                    f"{lattice[0]}")
            if (self.rsplit > 1 and lattice is not None
                    and (lattice[0] // self.bx) % self.rsplit):
                raise ValueError(
                    f"rsplit={self.rsplit} must divide the x-slab count "
                    f"{lattice[0] // self.bx} (bx={self.bx} over "
                    f"lattice[0]={lattice[0]}) so every stage-1 partial "
                    f"covers a whole number of slabs")
            if self.view == VIEW_BLOCK and layouts and not any(
                    lay.kind is LayoutKind.AOSOA for lay in layouts):
                raise ValueError(
                    "view='block' lowers stencil graphs natively on AoSoA "
                    "tiles, but no launch layout is AoSoA — use "
                    "view='staged-nd' (the per-input block alignment is "
                    "checked at launch, where halo rings are known)")
        else:
            if self.vvl < 1:
                raise ValueError(
                    f"site-local lowering needs vvl >= 1, got plan "
                    f"{self.describe()}")
            if self.bx:
                raise ValueError(
                    f"site-local lowering takes no x-slab (bx={self.bx})")
            if self.by or self.bz:
                raise ValueError(
                    f"site-local lowering takes no y/z tiles "
                    f"(by={self.by}, bz={self.bz}); tiles partition the "
                    f"halo'd stencil grid")
            if nsites is not None and nsites % self.vvl:
                raise ValueError(
                    f"vvl={self.vvl} must divide nsites={nsites} "
                    f"(use a conforming candidate from candidate_plans)")
            if (self.rsplit > 1 and nsites is not None
                    and (nsites // self.vvl) % self.rsplit):
                raise ValueError(
                    f"rsplit={self.rsplit} must divide the site-block "
                    f"count {nsites // self.vvl} (vvl={self.vvl} over "
                    f"nsites={nsites}) so every stage-1 partial covers a "
                    f"whole number of blocks")
            for lay in layouts:
                if lay.kind is LayoutKind.AOSOA and self.vvl % lay.sal:
                    raise ValueError(
                        f"vvl={self.vvl} must be a multiple of AoSoA "
                        f"sal={lay.sal}")
            if self.view not in (VIEW_AUTO, VIEW_BLOCK):
                raise ValueError(
                    "site-local lowering packs/unpacks per-block inside the "
                    "kernel (view='block')")
        return self


def adapt_plan(plan: LoweringPlan, *, stencil: bool, halo: str) -> LoweringPlan:
    """Fit an externally supplied plan (explicit policy or tuned-table entry)
    to a concrete launch: the call-site halo strategy is authoritative (the
    sharded drivers pass halo='pre') and the view must fit the lowering
    shape — site-local lowerings are always 'block'; a *stencil* plan keeps
    an explicitly chosen view (this is how a persisted native-AoSoA winner
    reaches a launch, and an explicit 'block' that cannot lower fails
    loudly at validation), while the 'auto' dataclass default resolves to
    'staged-nd' — so hand-built plans that never set view=, e.g.
    ``LoweringPlan("pallas", bx=2)`` from the pre-view era, launch exactly
    as they always did regardless of layout or alignment.  The jnp stencil
    lowering is staged by construction.  One exception on halo: 'pre' and
    'overlap' are interchangeable strategies for pre-exchanged stencil
    launches (same input contract, different schedule), so a plan that
    chose 'overlap' — e.g. a persisted autotuner winner — upgrades a
    call-site 'pre' launch to the split schedule."""
    eff = halo
    if halo == "pre" and plan.halo == "overlap" and stencil:
        eff = "overlap"
    if not stencil:
        view = VIEW_BLOCK
    elif plan.engine != "pallas" or plan.view == VIEW_AUTO:
        view = VIEW_STAGED_ND
    else:
        view = plan.view
    return dataclasses.replace(plan, halo=eff, view=view)


# -- planners ------------------------------------------------------------------

def _site_bytes(vmem_views) -> int:
    """Per-site traffic (bytes) of a launch from its (in_views, out_views)
    footprint descriptor — the coarse per-site cost choose_slab caps by."""
    in_views, out_views = vmem_views
    return (sum(ncomp * isz for ncomp, _ring, isz in in_views)
            + sum(ncomp * isz for ncomp, isz in out_views))


def default_plan(
    config,
    *,
    nsites: int,
    layouts: Sequence[Layout],
    stencil: bool = False,
    lattice: Optional[Tuple[int, ...]] = None,
    halo: str = "periodic",
    vmem_views=None,
) -> LoweringPlan:
    """The heuristic plan — bit-identical to the pre-plan inline decisions:
    jnp lowers whole-lattice; pallas site-local takes the largest conforming
    vvl divisor; pallas stencil takes the largest conforming x-slab within
    the config.vvl budget; interpret falls back automatically off-TPU.

    When a VMEM byte budget is configured (``TargetConfig.vmem_bytes`` /
    ``$TARGETDP_VMEM_BYTES``) and the launch passes its footprint
    descriptor ``vmem_views = (in_views, out_views)`` (see
    :func:`estimate_vmem_bytes`), a stencil plan whose whole-staging
    footprint exceeds the budget auto-tiles: the largest (by, bz) tile that
    fits is chosen, so a lattice too large to stage whole still launches —
    shard size bounded by the tile, not the lattice.  Without a budget the
    result is byte-identical to the pre-budget heuristics."""
    engine = config.engine
    if engine == "jnp":
        return LoweringPlan(
            "jnp", halo=halo,
            view=VIEW_STAGED_ND if stencil else VIEW_BLOCK)
    if engine != "pallas":
        raise ValueError(f"unknown engine {engine!r}")
    interpret = config.resolved_interpret()
    if stencil:
        if lattice is None:
            raise ValueError("stencil plans need the lattice shape")
        budget = resolved_vmem_bytes(config)
        site_bytes = _site_bytes(vmem_views) if (budget and vmem_views) else 0
        bx = choose_slab(lattice[0], int(math.prod(lattice[1:])), config.vvl,
                         site_bytes, budget if site_bytes else None)
        by = bz = 0
        if budget and vmem_views:
            by, bz = choose_tiles(
                lattice, bx, in_views=vmem_views[0],
                out_views=vmem_views[1], vmem_bytes=budget)
        return LoweringPlan("pallas", vvl=0, bx=bx, interpret=interpret,
                            halo=halo, view=VIEW_STAGED_ND, by=by, bz=bz)
    vvl = resolve_vvl(config, nsites, layouts)
    return LoweringPlan("pallas", vvl=vvl, bx=0, interpret=interpret,
                        halo=halo, view=VIEW_BLOCK)


def plan_for_launch(config, nsites: int, layouts: Sequence[Layout]) -> LoweringPlan:
    """Plan a single-kernel site-local launch (core.target.launch and the
    bespoke kernel ops wrappers).  Honors an explicit-plan policy; the
    "tuned" policy falls back to the default heuristics here because single
    launches carry no graph signature to key the table on (wrap the kernel
    in a LaunchGraph to tune it)."""
    policy = getattr(config, "plan_policy", "default")
    if isinstance(policy, LoweringPlan):
        return policy.validate(nsites=nsites, layouts=layouts, stencil=False)
    if policy not in ("default", "tuned"):
        raise ValueError(
            f"unknown plan_policy {policy!r}; use 'default', 'tuned' or an "
            f"explicit LoweringPlan")
    return default_plan(config, nsites=nsites, layouts=layouts, stencil=False)


def interpret_for(config) -> bool:
    """The interpret decision alone, for bespoke pallas kernels whose
    tiling is internal (no vvl/slab planning surface): an explicit-plan
    policy's interpret wins, else the config's off-TPU fallback."""
    policy = getattr(config, "plan_policy", "default")
    if isinstance(policy, LoweringPlan) and policy.engine == "pallas":
        return policy.interpret
    return config.resolved_interpret()


def sub_lattice_plan(
    plan: LoweringPlan, config, lattice: Tuple[int, ...], *, halo: str = "pre"
) -> LoweringPlan:
    """Fit a stencil plan to a sub-lattice — how the overlap scheduler
    (core.overlap) plans its interior/boundary slab sub-launches: keep the
    outer plan's engine/interpret, keep its x-slab ``bx`` when it divides
    the slab's leading extent, otherwise re-choose the largest conforming
    slab for the (thin) sub-lattice.  The view drops to 'staged-nd': the
    scheduler's sliced windows are SOA Fields (arbitrary slab extents do
    not stay block-aligned), so a native-AoSoA outer plan executes its
    sub-launches on staged canonical views — bit-identical arithmetic, the
    relayout happens at assembly.  ``rsplit`` likewise drops to 1: the
    scheduler already combines per-slab reduction partials through the
    stage-2 combine (the slabs *are* the split), and a thin boundary slab's
    block count rarely keeps the outer split factor's divisibility.

    The y/z tile extents ``by``/``bz`` are *inherited* whenever they still
    divide the sub-lattice (the interior box keeps the outer tiling, so a
    >VMEM shard stays tiled under ``halo="overlap"``); a tile that no
    longer divides — thin boundary slabs, usually — drops to 0 (whole
    axis), which is always within budget for slab-thin sub-lattices."""

    def _tiles(lat):
        by = plan.by if (plan.by and len(lat) > 1
                         and lat[1] % plan.by == 0) else 0
        bz = plan.bz if (plan.bz and len(lat) > 2
                         and lat[2] % plan.bz == 0) else 0
        return by, bz

    if plan.engine != "pallas":
        return dataclasses.replace(plan, halo=halo, rsplit=1)
    by, bz = _tiles(lattice)
    if plan.bx >= 1 and lattice[0] % plan.bx == 0:
        return dataclasses.replace(plan, halo=halo, view=VIEW_STAGED_ND,
                                   rsplit=1, by=by, bz=bz)
    bx = choose_slab(
        lattice[0], int(math.prod(lattice[1:])),
        max(int(getattr(config, "vvl", 128)), 1))
    return dataclasses.replace(plan, halo=halo, bx=bx, view=VIEW_STAGED_ND,
                               rsplit=1, by=by, bz=bz)


def _rsplit_factors(nblocks: int, cap: int = 16, k: int = 2):
    """Valid split-reduction twin factors for a grid of ``nblocks``
    programs: up to ``k`` divisors > 1, preferring factors <= ``cap``
    (a split per block is legal but pays stage-2 combine latency for
    nothing).  Empty when the grid has a single program."""
    rs = [r for r in divisors(nblocks) if r > 1]
    capped = [r for r in rs if r <= cap]
    return _spread(capped or rs[:1], k)


def _spread(values, k: int):
    """Deterministic evenly-spaced subset of size <= k (keeps both ends)."""
    if len(values) <= k:
        return list(values)
    if k <= 1:
        return [values[-1]]
    idx = {round(i * (len(values) - 1) / (k - 1)) for i in range(k)}
    return [values[i] for i in sorted(idx)]


def _dtype_twin_policies(in_dtype: Optional[str]):
    """Dtype-policy twins worth sweeping for a launch whose external float
    inputs share ``in_dtype``: narrower storage with full-precision compute
    and fp64 (or compensated — resolve_accumulate degrades at runtime)
    accumulation.  The tuner rejects any twin that misses its accuracy
    gate, so the sweep proposes and the gate disposes."""
    if in_dtype == "float32":
        return [DtypePolicy(storage="bfloat16", compute="float32",
                            accumulate="float64")]
    if in_dtype == "float64":
        return [DtypePolicy(storage="float32", compute="float32",
                            accumulate="float64")]
    return []


def candidate_plans(
    config,
    *,
    nsites: int,
    layouts: Sequence[Layout],
    stencil: bool = False,
    lattice: Optional[Tuple[int, ...]] = None,
    halo: str = "periodic",
    max_candidates: int = 8,
    devices: Optional[int] = None,
    block_view: Optional[bool] = None,
    batch: int = 0,
    reduce: bool = False,
    vmem_views=None,
    in_dtype: Optional[str] = None,
) -> Tuple[LoweringPlan, ...]:
    """Enumerate valid plans for the autotuner sweep, deterministically.

    ``batch`` is the leading batch-axis extent of a batched launch (0 for
    single-lattice launches).  The candidate *geometry* is per batch
    element — vvl/bx tile one lattice, the batch axis is a whole extra grid
    dimension — so the set is the same, but the tuner keys its sweep (and
    persists winners) per batch size via ``graph_plan_key``; a sharded
    overlap twin makes no sense for a packed serving batch, so the
    halo="overlap" twins are dropped when ``batch > 0``.

    Site-local: vvl over the SAL-conforming divisors of nsites (evenly
    spread when more than ``max_candidates``).  Stencil: bx over the
    divisors of the leading lattice dim.  Exploration is bounded to 8x the
    heuristic budget (preferred vvl / slab budget) so the sweep never
    proposes whole-lattice blocks that cannot fit VMEM on a real device;
    the tuner additionally skips (and records) any candidate whose
    lowering fails.  The default (heuristic) plan is always included
    first; every candidate passes :meth:`LoweringPlan.validate` — the
    property tests (tests/test_plan.py, tests/test_property.py) assert
    this for arbitrary nsites/sal/x_dim.

    Sharded stencil launches (``halo="pre"`` and more than one device —
    ``devices`` defaults to ``jax.device_count()``) additionally get two
    ``halo="overlap"`` twins (the default slab and the widest swept one),
    so the tuner can rank the comms/compute-overlap schedule
    (core.overlap) per lattice/backend without sacrificing bx sweep
    resolution.  In-process sweeps time the split *overhead* only (there
    is no live exchange to hide), so the min_gain hysteresis keeps "pre"
    unless overlap wins decisively — a sharded timing harness (or an
    explicitly recorded winner) is what flips launches to the split
    schedule.  On a single device there is no exchange at all and the
    twins are skipped.

    Stencil launches with an AoSoA input additionally get two
    ``view="block"`` twins (the default slab and the widest swept one) —
    the native-AoSoA lowering, so the tuner can rank it against staged-nd
    per lattice/backend.  ``block_view`` gates them: ``None`` emits twins
    whenever some input layout is AoSoA (the tuner skips+records a
    candidate whose alignment fails at launch); callers that know the
    halo'd geometry pass the precise :func:`block_view_ok` verdict
    (``core.tune.plan_candidates_for`` does).

    Launches ending in a terminal reduction (``reduce=True`` —
    ``plan_candidates_for`` passes the graph's verdict) additionally get
    two ``rsplit`` twins: the default geometry with the smallest and
    largest split factor (capped at 16) dividing its block count, so the
    tuner can rank the two-stage split reduction per lattice/backend.  An
    rsplit winner is the first plan axis whose results are
    tolerance-equal rather than bitwise-equal to the default for fp sums
    (deterministic for the fixed factor; exact for max and integer
    sums).

    Stencil lattices with a y (and z) axis additionally get up to two
    tiled twins — the default slab with its y axis split (and with y+z
    split), so the tuner sweeps the tiled lowering and persists tiled
    winners.  When a VMEM byte budget is configured and the launch passes
    ``vmem_views`` (see :func:`estimate_vmem_bytes`), any candidate whose
    estimated per-program footprint exceeds the budget is dropped and
    logged with the estimate; if *no* untiled slab fits, the set degrades
    to tiled-only candidates — the budget-exceeding lattice still gets a
    sweepable, launchable plan set.

    ``in_dtype`` (the shared dtype of the launch's external float inputs,
    as a string) additionally yields dtype-policy twins off the default
    geometry (:func:`_dtype_twin_policies`): bf16 storage for fp32 inputs,
    fp32 storage for fp64 inputs, always with full-precision compute and
    fp64/compensated accumulation.  These are the first candidates whose
    *field outputs* are tolerance- rather than bitwise-equal, so the tuner
    pairs them with a hard accuracy gate (core.tune) and rejects any twin
    that drifts past it — rejected twins are logged and never persisted."""
    default = default_plan(config, nsites=nsites, layouts=layouts,
                           stencil=stencil, lattice=lattice, halo=halo,
                           vmem_views=vmem_views)
    if default.engine != "pallas":
        return (default,)
    if stencil:
        inner = int(math.prod(lattice[1:]))
        budget = max(int(config.vvl), inner)
        vmem_budget = resolved_vmem_bytes(config)
        untiled_default = (default if not (default.by or default.bz)
                           else dataclasses.replace(default, by=0, bz=0))

        def over_budget(c):
            if not (vmem_budget and vmem_views):
                return False
            fp = estimate_vmem_bytes(c, lattice=lattice,
                                     in_views=vmem_views[0],
                                     out_views=vmem_views[1])
            if fp <= vmem_budget:
                return False
            log.info(
                "candidate %s skipped: estimated per-program VMEM %d B "
                "exceeds budget %d B", c.describe(footprint=fp), fp,
                vmem_budget)
            from . import telemetry
            telemetry.event("tune/pruned", plan=c.describe(), footprint=fp,
                            budget=vmem_budget, reason="vmem-budget")
            return True

        bxs = [bx for bx in divisors(lattice[0])
               if bx * inner <= 8 * budget
               and not over_budget(dataclasses.replace(untiled_default,
                                                       bx=bx))]
        bxs = bxs or ([] if (default.by or default.bz) else [default.bx])
        if devices is None:
            import jax
            devices = jax.device_count()
        with_overlap = halo == "pre" and devices > 1 and not batch
        if block_view is None:
            block_view = any(lay.kind is LayoutKind.AOSOA for lay in layouts)
        # split-reduction twins come off the default geometry (or the
        # narrowest swept slab when the default lowers the whole extent as
        # one program); computed first so the bx sweep only cedes budget
        # for twins that actually exist
        red_twins = []
        if reduce:
            base = default
            if bxs and lattice[0] // base.bx < 2 and min(bxs) < base.bx:
                base = dataclasses.replace(default, bx=min(bxs))
            red_twins = [dataclasses.replace(base, rsplit=r)
                         for r in _rsplit_factors(lattice[0] // base.bx)]
        # tiled twins: the default slab with y split (and with y+z split),
        # skipping extents with nothing to split and over-budget tiles
        tile_twins = []
        if len(lattice) > 1 and len(divisors(lattice[1])) > 1:
            t1 = dataclasses.replace(default, by=divisors(lattice[1])[-2],
                                     bz=0)
            tile_twins.append(t1)
            if len(lattice) > 2 and len(divisors(lattice[2])) > 1:
                tile_twins.append(dataclasses.replace(
                    t1, bz=divisors(lattice[2])[-2]))
        tile_twins = [t for t in tile_twins
                      if t != default and not over_budget(t)]
        # dtype-policy twins off the default geometry: narrower storage,
        # full-precision compute, fp64/compensated accumulate.  Budget
        # pruning prices them at the storage itemsize (estimate_vmem_bytes
        # is policy-aware), and the tuner's accuracy gate rejects any twin
        # whose results drift past the rel-L2 budget.
        dtype_twins = [dataclasses.replace(default, dtypes=p)
                       for p in _dtype_twin_policies(in_dtype)]
        dtype_twins = [t for t in dtype_twins if not over_budget(t)]
        n_twins = ((2 if with_overlap else 0) + (2 if block_view else 0)
                   + len(red_twins) + len(tile_twins) + len(dtype_twins))
        k = max(1, max_candidates - n_twins)
        spread_bxs = _spread(bxs, k)
        cands = [dataclasses.replace(untiled_default, bx=bx)
                 for bx in spread_bxs]
        twin_bxs = sorted({default.bx, *spread_bxs[-1:]})[:2]
        if with_overlap:
            cands += [dataclasses.replace(default, bx=bx, halo="overlap")
                      for bx in twin_bxs]
        if block_view:
            cands += [dataclasses.replace(default, bx=bx, view=VIEW_BLOCK)
                      for bx in twin_bxs]
        cands += red_twins + tile_twins + dtype_twins
    else:
        align = sal_alignment(layouts)
        cap = 8 * max(int(config.vvl), 128)
        vs = [v for v in divisors(nsites)
              if v % align == 0 and v <= cap] or [default.vvl]
        red_twins = []
        if reduce:
            base = default
            if nsites // base.vvl < 2 and vs and vs[0] < base.vvl:
                base = dataclasses.replace(default, vvl=vs[0])
            red_twins = [dataclasses.replace(base, rsplit=r)
                         for r in _rsplit_factors(nsites // base.vvl)]
        dtype_twins = [dataclasses.replace(default, dtypes=p)
                       for p in _dtype_twin_policies(in_dtype)]
        k = max(1, max_candidates - len(red_twins) - len(dtype_twins))
        cands = [dataclasses.replace(default, vvl=v)
                 for v in _spread(vs, k)]
        cands += red_twins + dtype_twins
    out = [default]
    for c in cands:
        if c not in out:
            out.append(c)
    for c in out:
        c.validate(nsites=nsites, lattice=lattice, layouts=layouts,
                   stencil=stencil)
    return tuple(out[:max_candidates + 1])


# -- tuner keys ----------------------------------------------------------------

def graph_plan_key(
    signature,
    *,
    engine: str,
    halo: str,
    outputs: Sequence[str],
    inputs,
    lattice: Tuple[int, ...],
    backend: str,
    batch=0,
) -> str:
    """Stable string key for the persisted tune table: one entry per
    (graph signature, input layouts/dtypes, lattice shape, engine, halo,
    outputs, backend, batch shape).  The signature must be process-stable
    (kernel *names* and structure, not function objects — see
    LaunchGraph.plan_signature).  ``batch`` is the batched-launch key
    component ((batch size, per-input batched flags) from
    ``LaunchGraph.plan_key``); the falsy default keeps every pre-batch key
    byte-identical, so existing persisted tables stay warm."""
    parts = (signature, engine, halo, tuple(outputs), tuple(inputs),
             tuple(lattice), backend)
    if batch:
        parts = parts + (batch,)
    blob = repr(parts)
    digest = hashlib.sha256(blob.encode()).hexdigest()[:16]
    name = signature[0] if isinstance(signature, tuple) and signature else "g"
    return f"{name}|{backend}|{engine}|{digest}"
