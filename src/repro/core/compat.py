"""Version gates for JAX APIs that moved between releases.

The sharded wrappers (core.halo callers, train sharding, the distributed
tests) were written against the modern surface: ``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``.  Older
jax (e.g. 0.4.x, where shard_map still lives in ``jax.experimental``) ships
none of those, so every sharded entry point routes through this module
instead of feature-detecting inline.  Single-shard code paths never import
these symbols at call time, preserving the paper's portability discipline:
the same application source runs on whatever runtime is underneath.
"""

from __future__ import annotations

from typing import Sequence

import jax

__all__ = ["shard_map", "make_mesh", "HAS_NATIVE_SHARD_MAP"]

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

if HAS_NATIVE_SHARD_MAP:
    shard_map = jax.shard_map
else:  # jax < 0.5: the experimental home, same keyword surface
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(f, **kwargs):
        # the experimental replication checker has no rule for while_loop
        # (the CG solver's carrier); the native one does — disable it rather
        # than forbid control flow under old runtimes
        kwargs.setdefault("check_rep", False)
        return _experimental_shard_map(f, **kwargs)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis_types when the runtime has them
    (explicit-sharding jax), plain otherwise (0.4.x: every mesh axis is
    implicitly auto, which is the behaviour the sharded wrappers assume)."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(
        axis_shapes, axis_names,
        axis_types=(AxisType.Auto,) * len(axis_names),
    )
