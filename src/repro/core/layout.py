"""Data-layout abstraction: the JAX analogue of the targetDP ``INDEX()`` macro.

The paper (Gray & Stratford 2016, §3.1) abstracts the linearization of
multi-valued lattice data — ``ncomp`` numerical components stored at each of
``nsites`` lattice sites — behind a C-preprocessor macro so the layout can be
switched per architecture without touching application code.  The three
layouts, in the paper's rgb-pixel notation:

  AoS    |rgb|rgb|rgb|rgb|          index = site*ncomp + comp
  SoA    |rrrr|gggg|bbbb|           index = comp*nsites + site
  AoSoA  ||rr|gg|bb|||rr|gg|bb||    index = (site/SAL)*ncomp*SAL
                                            + comp*SAL + (site - (site/SAL)*SAL)

Here the same abstraction is an axis *order* of the backing ``jax.Array``
(XLA stores arrays row-major, so the flat memory order of each physical shape
reproduces the paper's linearizations exactly):

  SoA    physical shape (ncomp, nsites)
  AoS    physical shape (nsites, ncomp)
  AoSoA  physical shape (nsites//SAL, ncomp, SAL)

The *canonical* (logical) view used by every kernel body is ``(ncomp,
nsites)`` — kernels never see the layout, exactly as targetDP kernels only
ever write ``field[INDEX(comp, site)]``.

On the TPU target the short-array length SAL plays the role the paper gives
the Virtual Vector Length on AVX/IMCI hardware: SAL equal to the 128-wide
lane dimension (or a multiple) makes a site-chunk land as contiguous
(sublane=comp, lane=site) VREG tiles, which is the layout the VPU/MXU wants.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Tuple


__all__ = ["LayoutKind", "Layout", "AOS", "SOA", "aosoa", "tileable_layout"]


class LayoutKind(enum.Enum):
    AOS = "aos"
    SOA = "soa"
    AOSOA = "aosoa"


@dataclasses.dataclass(frozen=True)
class Layout:
    """A concrete data layout: kind + short-array length (AoSoA only).

    ``sal`` is the paper's SAL preprocessor constant.  AoS and SoA are the
    SAL=1 and SAL=nsites degenerate cases respectively (paper §3.1); we keep
    them as distinct kinds because their physical shapes are 2-D.
    """

    kind: LayoutKind
    sal: int = 1

    def __post_init__(self):
        if self.kind is LayoutKind.AOSOA and self.sal < 1:
            raise ValueError(f"AoSoA needs sal >= 1, got {self.sal}")

    # -- shape bookkeeping ---------------------------------------------------

    def physical_shape(self, ncomp: int, nsites: int) -> Tuple[int, ...]:
        if self.kind is LayoutKind.SOA:
            return (ncomp, nsites)
        if self.kind is LayoutKind.AOS:
            return (nsites, ncomp)
        if nsites % self.sal:
            raise ValueError(
                f"AoSoA(sal={self.sal}) requires sal | nsites, got nsites={nsites}"
            )
        return (nsites // self.sal, ncomp, self.sal)

    def fits(self, nsites: int) -> bool:
        """Whether this layout can tile ``nsites`` sites (AoSoA needs
        SAL | nsites; SoA/AoS always fit).  Drivers use this to fall back
        to SOA for halo'd temporaries whose padded site count the
        configured SAL cannot tile."""
        return self.kind is not LayoutKind.AOSOA or nsites % self.sal == 0

    # -- the INDEX() macro ----------------------------------------------------

    def flat_index(self, comp, site, ncomp: int, nsites: int):
        """The paper's INDEX(comp, site) linearization (for tests/tools).

        Accepts scalars or integer arrays.  Matches the flat (row-major)
        memory order of :meth:`pack`'s output by construction; the property
        test in tests/test_layout.py asserts this.
        """
        if self.kind is LayoutKind.SOA:
            return comp * nsites + site
        if self.kind is LayoutKind.AOS:
            return site * ncomp + comp
        sal = self.sal
        return (site // sal) * ncomp * sal + comp * sal + (site - (site // sal) * sal)

    # -- canonical <-> physical ------------------------------------------------

    def pack(self, canonical):
        """(ncomp, nsites) canonical -> physical array in this layout."""
        ncomp, nsites = canonical.shape
        if self.kind is LayoutKind.SOA:
            return canonical
        if self.kind is LayoutKind.AOS:
            return canonical.T
        sal = self.sal
        if nsites % sal:
            raise ValueError(f"AoSoA(sal={sal}): sal must divide nsites={nsites}")
        # (ncomp, nblk, sal) -> (nblk, ncomp, sal)
        return canonical.reshape(ncomp, nsites // sal, sal).transpose(1, 0, 2)

    def unpack(self, physical):
        """Physical array in this layout -> canonical (ncomp, nsites)."""
        if self.kind is LayoutKind.SOA:
            return physical
        if self.kind is LayoutKind.AOS:
            return physical.T
        nblk, ncomp, sal = physical.shape
        return physical.transpose(1, 0, 2).reshape(ncomp, nblk * sal)

    # -- pallas BlockSpec support ----------------------------------------------

    def block_shape(self, ncomp: int, vvl: int) -> Tuple[int, ...]:
        """Physical VMEM block shape covering `vvl` sites x all components.

        vvl (the Virtual Vector Length, paper §3.2.2) is the number of lattice
        sites one pallas program instance owns.  For AoSoA we require
        sal | vvl so a block is a whole number of short arrays.
        """
        if self.kind is LayoutKind.SOA:
            return (ncomp, vvl)
        if self.kind is LayoutKind.AOS:
            return (vvl, ncomp)
        if vvl % self.sal:
            raise ValueError(f"AoSoA(sal={self.sal}): sal must divide vvl={vvl}")
        return (vvl // self.sal, ncomp, self.sal)

    def block_index_map(self):
        """index_map for a 1-D site-block grid, in units of block_shape."""
        if self.kind is LayoutKind.SOA:
            return lambda i: (0, i)
        if self.kind is LayoutKind.AOS:
            return lambda i: (i, 0)
        return lambda i: (i, 0, 0)

    def block_to_canonical(self, block, ncomp: int, vvl: int):
        """Physical VMEM block -> canonical (ncomp, vvl) chunk for the body."""
        if self.kind is LayoutKind.SOA:
            return block
        if self.kind is LayoutKind.AOS:
            return block.T
        nblk = vvl // self.sal
        return block.transpose(1, 0, 2).reshape(ncomp, vvl)

    def canonical_to_block(self, chunk, ncomp: int, vvl: int):
        """Canonical (ncomp, vvl) chunk -> physical VMEM block."""
        if self.kind is LayoutKind.SOA:
            return chunk
        if self.kind is LayoutKind.AOS:
            return chunk.T
        return chunk.reshape(ncomp, vvl // self.sal, self.sal).transpose(1, 0, 2)

    # -- descriptive -----------------------------------------------------------

    @property
    def name(self) -> str:
        if self.kind is LayoutKind.AOSOA:
            return f"aosoa{self.sal}"
        return self.kind.value

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Layout({self.name})"


AOS = Layout(LayoutKind.AOS)
SOA = Layout(LayoutKind.SOA)


def aosoa(sal: int) -> Layout:
    """AoSoA with short-array length ``sal`` (TPU-native at sal=128)."""
    return Layout(LayoutKind.AOSOA, sal)


def tileable_layout(layout: Layout, lattice) -> Layout:
    """``layout`` when it can tile this lattice, else SOA.

    The drivers' fallback policy for halo'd local Fields: the configured
    layout is kept wherever the (possibly padded) site count stays
    SAL-tileable — so tuned native-AoSoA stencil plans apply sharded —
    and degrades to SOA instead of failing the step otherwise."""
    nsites = 1
    for s in lattice:
        nsites *= int(s)
    return layout if layout.fits(nsites) else SOA


def parse_layout(spec: str) -> Layout:
    """Parse 'aos' | 'soa' | 'aosoa<N>' — the config-file entry point."""
    s = spec.strip().lower()
    if s == "aos":
        return AOS
    if s == "soa":
        return SOA
    if s.startswith("aosoa"):
        return aosoa(int(s[len("aosoa"):] or 128))
    raise ValueError(f"unknown layout spec {spec!r}")
