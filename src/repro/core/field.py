"""Field: a multi-valued lattice quantity stored in a configurable Layout.

A Field is the targetDP-JAX unit of data: ``ncomp`` components at every site
of a (possibly multi-dimensional) lattice, physically stored per its Layout
(paper §3.1).  Kernels (core.target) consume and produce Fields; the kernel
body only ever sees canonical ``(ncomp, VVL)`` chunks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layout import Layout, SOA

__all__ = ["Field", "BatchedField"]


@dataclasses.dataclass
class Field:
    """ncomp values per site on a lattice, in a given physical layout.

    data      physical jax.Array, shape == layout.physical_shape(ncomp, nsites)
    lattice   site-space shape, e.g. (nx, ny, nz); nsites = prod(lattice)
    """

    name: str
    ncomp: int
    lattice: Tuple[int, ...]
    layout: Layout
    data: jax.Array

    # -- constructors ----------------------------------------------------------

    @classmethod
    def zeros(cls, name, ncomp, lattice, layout=SOA, dtype=jnp.float32):
        nsites = math.prod(lattice)
        data = jnp.zeros(layout.physical_shape(ncomp, nsites), dtype)
        return cls(name, ncomp, tuple(lattice), layout, data)

    @classmethod
    def from_canonical(cls, name, canonical, lattice, layout=SOA):
        """canonical: (ncomp, *lattice) or (ncomp, nsites)."""
        canonical = jnp.asarray(canonical)
        ncomp = canonical.shape[0]
        nsites = math.prod(lattice)
        flat = canonical.reshape(ncomp, nsites)
        return cls(name, ncomp, tuple(lattice), layout, layout.pack(flat))

    @classmethod
    def from_numpy(cls, name, array_cs, lattice, layout=SOA, dtype=jnp.float32):
        return cls.from_canonical(name, jnp.asarray(array_cs, dtype), lattice, layout)

    # -- views -----------------------------------------------------------------

    @property
    def nsites(self) -> int:
        return math.prod(self.lattice)

    @property
    def dtype(self):
        return self.data.dtype

    def canonical(self) -> jax.Array:
        """(ncomp, nsites) logical view (layout-independent)."""
        return self.layout.unpack(self.data)

    def canonical_nd(self) -> jax.Array:
        """(ncomp, *lattice) logical view — stencil/geometry operations."""
        return self.canonical().reshape((self.ncomp,) + self.lattice)

    def to_numpy(self) -> np.ndarray:
        return np.asarray(self.canonical_nd())

    # -- functional updates ----------------------------------------------------

    def with_data(self, data: jax.Array) -> "Field":
        return dataclasses.replace(self, data=data)

    def with_canonical(self, canonical: jax.Array) -> "Field":
        flat = canonical.reshape(self.ncomp, self.nsites)
        return dataclasses.replace(self, data=self.layout.pack(flat))

    def as_layout(self, layout: Layout) -> "Field":
        """Relayout (the paper's per-architecture layout switch)."""
        if layout == self.layout:
            return self
        return dataclasses.replace(
            self, layout=layout, data=layout.pack(self.canonical())
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"Field({self.name!r}, ncomp={self.ncomp}, lattice={self.lattice}, "
            f"layout={self.layout.name}, dtype={self.dtype})"
        )


@dataclasses.dataclass
class BatchedField:
    """A stack of ``batch`` independent same-shape Fields, one leading axis.

    data has shape ``(batch,) + layout.physical_shape(ncomp, nsites)`` —
    every batch element is an ordinary Field's physical array, so
    ``element(b)`` / ``unstack()`` round-trip bitwise.  The serving layer
    (launch.serve) packs many small simulations into one of these and the
    fused launch lowers the whole stack through a single kernel
    (core.fuse grows a leading grid axis).
    """

    name: str
    batch: int
    ncomp: int
    lattice: Tuple[int, ...]
    layout: Layout
    data: jax.Array

    # -- constructors ----------------------------------------------------------

    @classmethod
    def stack(cls, fields, name=None):
        """Stack same-(ncomp, lattice, layout) Fields along a new batch axis."""
        fields = list(fields)
        if not fields:
            raise ValueError("BatchedField.stack needs at least one Field")
        f0 = fields[0]
        for f in fields[1:]:
            if (f.ncomp, f.lattice, f.layout) != (f0.ncomp, f0.lattice, f0.layout):
                raise ValueError(
                    f"cannot stack {f!r} with {f0!r}: batch elements must "
                    f"share ncomp, lattice and layout")
        data = jnp.stack([f.data for f in fields])
        return cls(name or f0.name, len(fields), f0.ncomp, f0.lattice,
                   f0.layout, data)

    @classmethod
    def zeros(cls, name, batch, ncomp, lattice, layout=SOA, dtype=jnp.float32):
        nsites = math.prod(lattice)
        shape = (batch,) + layout.physical_shape(ncomp, nsites)
        return cls(name, batch, ncomp, tuple(lattice), layout,
                   jnp.zeros(shape, dtype))

    @classmethod
    def from_canonical(cls, name, canonical, lattice, layout=SOA):
        """canonical: (batch, ncomp, *lattice) or (batch, ncomp, nsites)."""
        canonical = jnp.asarray(canonical)
        batch, ncomp = canonical.shape[:2]
        nsites = math.prod(lattice)
        flat = canonical.reshape(batch, ncomp, nsites)
        return cls(name, batch, ncomp, tuple(lattice), layout,
                   jax.vmap(layout.pack)(flat))

    # -- views -----------------------------------------------------------------

    @property
    def nsites(self) -> int:
        return math.prod(self.lattice)

    @property
    def dtype(self):
        return self.data.dtype

    def element(self, b: int) -> Field:
        """Batch element ``b`` as an ordinary Field (bitwise the stacked data)."""
        return Field(f"{self.name}[{b}]", self.ncomp, self.lattice,
                     self.layout, self.data[b])

    def unstack(self):
        return [self.element(b) for b in range(self.batch)]

    def canonical(self) -> jax.Array:
        """(batch, ncomp, nsites) logical view."""
        return jax.vmap(self.layout.unpack)(self.data)

    def canonical_nd(self) -> jax.Array:
        """(batch, ncomp, *lattice) logical view."""
        return self.canonical().reshape((self.batch, self.ncomp) + self.lattice)

    def to_numpy(self) -> np.ndarray:
        return np.asarray(self.canonical_nd())

    # -- functional updates ----------------------------------------------------

    def with_data(self, data: jax.Array) -> "BatchedField":
        return dataclasses.replace(self, data=data)

    def with_element(self, b, field: Field) -> "BatchedField":
        """Replace batch slot ``b`` with a Field's data (same shape/layout)."""
        f = field.as_layout(self.layout)
        return dataclasses.replace(self, data=self.data.at[b].set(f.data))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"BatchedField({self.name!r}, batch={self.batch}, "
            f"ncomp={self.ncomp}, lattice={self.lattice}, "
            f"layout={self.layout.name}, dtype={self.dtype})"
        )


# Fields are pytrees: data is the leaf, everything else is static metadata.
def _field_flatten(f: Field):
    return (f.data,), (f.name, f.ncomp, f.lattice, f.layout)


def _field_unflatten(aux, children):
    name, ncomp, lattice, layout = aux
    return Field(name, ncomp, lattice, layout, children[0])


jax.tree_util.register_pytree_node(Field, _field_flatten, _field_unflatten)


def _bfield_flatten(f: BatchedField):
    return (f.data,), (f.name, f.batch, f.ncomp, f.lattice, f.layout)


def _bfield_unflatten(aux, children):
    name, batch, ncomp, lattice, layout = aux
    return BatchedField(name, batch, ncomp, lattice, layout, children[0])


jax.tree_util.register_pytree_node(
    BatchedField, _bfield_flatten, _bfield_unflatten)
