"""Stencil helpers on canonical (ncomp, *lattice) views.

targetDP classes kernels as site-local or stencil (paper §2.1.1); stencil
kernels read neighbour sites.  Single-shard (periodic) stencils use rolls;
multi-shard stencils read halo'd arrays filled by core.halo.  These helpers
are the jnp-engine implementations and the oracles for the bespoke pallas
stencil kernels in repro.kernels.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "shift_periodic",
    "interior",
    "halo_pad",
    "halo_pad_physical",
    "shifted_window",
]


def shift_periodic(x_nd: jax.Array, disp: Sequence[int]) -> jax.Array:
    """Value at site r of the result = value at site (r - disp) of x (periodic).

    x_nd: (ncomp, *lattice); disp indexes the lattice dims.  This is the LB
    propagation semantics: f'(r + c_i) = f(r), i.e. out(r) = in(r - c_i).
    """
    out = x_nd
    for d, s in enumerate(disp):
        if s:
            out = jnp.roll(out, shift=s, axis=d + 1)
    return out


def halo_pad(x_nd: jax.Array, width: int, site_dims: Sequence[int]) -> jax.Array:
    """Pad with periodic wrap — the single-shard halo fill."""
    pads = [(0, 0)] * x_nd.ndim
    for d in site_dims:
        pads[d] = (width, width)
    return jnp.pad(x_nd, pads, mode="wrap")


def halo_pad_physical(
    data: jax.Array, layout, ncomp: int, lattice: Sequence[int], width: int
) -> jax.Array:
    """Halo-pad a *physical* array by periodic wrap, returning the physical
    array over the padded lattice in the same layout.

    The single-shard halo fill for the native-AoSoA stencil lowering
    (``LoweringPlan.view == "block"``): the padded sites re-linearize, so a
    3-D AoSoA ``(nsites/SAL, ncomp, SAL)`` shape is re-blocked over the
    padded site count — which therefore must stay a multiple of SAL (a
    clear ValueError otherwise; the plan layer only proposes block views
    whose SAL divides the halo'd inner-plane count, see
    ``core.plan.block_view_ok``).  For SOA/AoS this is pack(pad(unpack)),
    where pack/unpack are views."""
    if width < 1:
        return data
    lattice = tuple(int(s) for s in lattice)
    nd = layout.unpack(data).reshape((ncomp,) + lattice)
    padded = halo_pad(nd, width, range(1, nd.ndim))
    return layout.pack(padded.reshape(ncomp, -1))


def interior(x_halo: jax.Array, width: int, site_dims: Sequence[int]) -> jax.Array:
    """Strip halos back off."""
    idx = [slice(None)] * x_halo.ndim
    for d in site_dims:
        idx[d] = slice(width, x_halo.shape[d] - width)
    return x_halo[tuple(idx)]


def shifted_window(
    x_halo: jax.Array, disp: Sequence[int], width: int, site_dims: Sequence[int]
) -> jax.Array:
    """Interior-shaped window of a halo'd array displaced by -disp.

    out(r) = x(r - disp) for every interior site r; reads reach at most
    ``width`` into the halo, so require max|disp| <= width.
    """
    idx = [slice(None)] * x_halo.ndim
    for d, dim in enumerate(site_dims):
        s = disp[d]
        if abs(s) > width:
            raise ValueError(f"|disp|={abs(s)} exceeds halo width {width}")
        lo = width - s
        hi = x_halo.shape[dim] - width - s
        idx[dim] = slice(lo, hi)
    return x_halo[tuple(idx)]
