"""Stencil helpers on canonical (ncomp, *lattice) views.

targetDP classes kernels as site-local or stencil (paper §2.1.1); stencil
kernels read neighbour sites.  Single-shard (periodic) stencils use rolls;
multi-shard stencils read halo'd arrays filled by core.halo.  These helpers
are the jnp-engine implementations and the oracles for the bespoke pallas
stencil kernels in repro.kernels.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "shift_periodic",
    "interior",
    "halo_pad",
    "halo_pad_physical",
    "shifted_window",
    "tile_boxes",
]


def tile_boxes(
    lattice: Sequence[int], bx: int, by: int = 0, bz: int = 0,
) -> List[Tuple[Tuple[int, int], ...]]:
    """Enumerate the tile cover of a tiled stencil lowering
    (``LoweringPlan`` bx/by/bz): a list of boxes, one per pallas program,
    each a per-dim ``(start, extent)`` tuple over the *interior* lattice.
    ``by``/``bz`` of 0 mean whole-axis (the untiled default); extents must
    divide their dims, so the cover is exact and disjoint by construction.
    Enumeration order matches the grid's sequential iteration (x-slab
    outermost, z-tiles fastest) — the order tile DMA and reduction
    accumulation visit the lattice.
    """
    lattice = tuple(int(s) for s in lattice)
    exts = []
    for d, s in enumerate(lattice):
        if d == 0:
            exts.append(int(bx))
        elif d == 1 and by:
            exts.append(int(by))
        elif d == 2 and bz:
            exts.append(int(bz))
        else:
            exts.append(s)
    counts = []
    for d, e in enumerate(exts):
        if e <= 0 or lattice[d] % e:
            raise ValueError(
                f"tile extent {e} does not divide lattice[{d}]={lattice[d]}")
        counts.append(lattice[d] // e)
    boxes = []
    idx = [0] * len(lattice)
    total = 1
    for c in counts:
        total *= c
    for _ in range(total):
        boxes.append(tuple((idx[d] * exts[d], exts[d])
                           for d in range(len(lattice))))
        for d in reversed(range(len(lattice))):  # z fastest
            idx[d] += 1
            if idx[d] < counts[d]:
                break
            idx[d] = 0
    return boxes


def shift_periodic(x_nd: jax.Array, disp: Sequence[int]) -> jax.Array:
    """Value at site r of the result = value at site (r - disp) of x (periodic).

    x_nd: (ncomp, *lattice); disp indexes the lattice dims.  This is the LB
    propagation semantics: f'(r + c_i) = f(r), i.e. out(r) = in(r - c_i).
    """
    out = x_nd
    for d, s in enumerate(disp):
        if s:
            out = jnp.roll(out, shift=s, axis=d + 1)
    return out


def halo_pad(x_nd: jax.Array, width: int, site_dims: Sequence[int]) -> jax.Array:
    """Pad with periodic wrap — the single-shard halo fill."""
    pads = [(0, 0)] * x_nd.ndim
    for d in site_dims:
        pads[d] = (width, width)
    return jnp.pad(x_nd, pads, mode="wrap")


def halo_pad_physical(
    data: jax.Array, layout, ncomp: int, lattice: Sequence[int], width: int
) -> jax.Array:
    """Halo-pad a *physical* array by periodic wrap, returning the physical
    array over the padded lattice in the same layout.

    The single-shard halo fill for the native-AoSoA stencil lowering
    (``LoweringPlan.view == "block"``): the padded sites re-linearize, so a
    3-D AoSoA ``(nsites/SAL, ncomp, SAL)`` shape is re-blocked over the
    padded site count — which therefore must stay a multiple of SAL (a
    clear ValueError otherwise; the plan layer only proposes block views
    whose SAL divides the halo'd inner-plane count, see
    ``core.plan.block_view_ok``).  For SOA/AoS this is pack(pad(unpack)),
    where pack/unpack are views."""
    if width < 1:
        return data
    lattice = tuple(int(s) for s in lattice)
    nd = layout.unpack(data).reshape((ncomp,) + lattice)
    padded = halo_pad(nd, width, range(1, nd.ndim))
    return layout.pack(padded.reshape(ncomp, -1))


def interior(x_halo: jax.Array, width: int, site_dims: Sequence[int]) -> jax.Array:
    """Strip halos back off."""
    idx = [slice(None)] * x_halo.ndim
    for d in site_dims:
        idx[d] = slice(width, x_halo.shape[d] - width)
    return x_halo[tuple(idx)]


def shifted_window(
    x_halo: jax.Array, disp: Sequence[int], width: int, site_dims: Sequence[int]
) -> jax.Array:
    """Interior-shaped window of a halo'd array displaced by -disp.

    out(r) = x(r - disp) for every interior site r; reads reach at most
    ``width`` into the halo, so require max|disp| <= width.
    """
    idx = [slice(None)] * x_halo.ndim
    for d, dim in enumerate(site_dims):
        s = disp[d]
        if abs(s) > width:
            raise ValueError(f"|disp|={abs(s)} exceeds halo width {width}")
        lo = width - s
        hi = x_halo.shape[dim] - width - s
        idx[dim] = slice(lo, hi)
    return x_halo[tuple(idx)]
