"""Host/target memory-space abstraction (paper §3.2.3).

targetDP keeps an explicit host/target distinction even when both are the
same device, so the application is portable to split-memory hardware.  On
TPU the split is real again (host DRAM vs device HBM), and one level down a
second split (HBM vs VMEM) is handled per-kernel by BlockSpecs.  This module
provides the paper-named API; under JAX the implementations are thin on
purpose — the *model* (explicit transfers, no implicit aliasing) is what we
preserve.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "target_malloc",
    "target_free",
    "copy_to_target",
    "copy_from_target",
    "copy_const_to_target",
    "target_synchronize",
]


def target_malloc(shape, dtype=jnp.float32, *, sharding: Optional[object] = None):
    """targetMalloc: allocate target (device) memory."""
    z = jnp.zeros(shape, dtype)
    if sharding is not None:
        z = jax.device_put(z, sharding)
    return z


def target_free(buf) -> None:
    """targetFree: drop the device buffer (JAX arrays are GC'd; delete eagerly)."""
    try:
        buf.delete()
    except Exception:
        pass


def copy_to_target(host_array, *, sharding: Optional[object] = None, dtype=None):
    """copyToTarget: host -> target transfer (device_put, optionally sharded)."""
    arr = jnp.asarray(host_array, dtype=dtype)
    if sharding is not None:
        arr = jax.device_put(arr, sharding)
    return arr


def copy_from_target(target_array) -> np.ndarray:
    """copyFromTarget: target -> host transfer (blocks until ready)."""
    return np.asarray(jax.device_get(target_array))


def copy_const_to_target(value):
    """__targetConst__/copyConstToTarget: constants are closed over and baked
    into the compiled executable — the analogue of GPU constant memory is the
    scalar cache / inlined immediates on TPU."""
    return value


def target_synchronize(*arrays) -> None:
    """targetSynchronize: barrier on outstanding device work."""
    if arrays:
        jax.block_until_ready(arrays)
    else:  # global barrier: sync a trivial op
        jax.block_until_ready(jnp.zeros(()))
