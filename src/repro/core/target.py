"""Engine dispatch: one kernel body, two targets (paper §3.2).

targetDP compiles the same source to OpenMP (host C) or CUDA.  Here a kernel
body is a Python function over canonical ``(ncomp, VVL)`` site-chunks and is
*traced* by two engines:

  engine="jnp"     TLP and ILP collapse into whole-lattice array ops — the
                   paper's C/OpenMP build.  Also serves as the oracle.
  engine="pallas"  ``pl.pallas_call`` over a 1-D grid of site blocks; VMEM
                   tiling comes from each Field's Layout via BlockSpec, so
                   the body never sees the layout — the paper's CUDA build,
                   re-tiled for the TPU memory hierarchy (HBM -> VMEM ->
                   (8,128) VREG tiles).

__targetTLP__  -> the pallas grid (site blocks across TensorCores)
__targetILP__  -> the trailing VVL axis of each chunk (VPU lanes)
VVL            -> sites per pallas program; multiples of 128 are the TPU
                  analogue of VVL=4 (AVX) / VVL=8 (IMCI-512).

Site-local kernels only (collision, stress, LC update, MILC linear algebra).
Stencil kernels (propagation, dslash) have bespoke pallas implementations in
``repro.kernels`` and jnp implementations via ``core.stencil``; both engines
remain available for them through their ops.py wrappers.

Chains of site-local launches whose outputs feed later inputs can be fused
into a *single* device kernel (intermediates never round-trip through HBM)
with ``core.fuse.LaunchGraph`` / ``core.fuse.fused_launch``, which shares the
BlockSpec machinery below (``build_in_specs`` / ``build_out_specs``) and adds
a ``jax.jit``-backed launch cache.  A single ``launch`` remains un-cached by
design: its params may be traced values (e.g. CG's alpha under
``lax.while_loop``), which must not enter a cache key.

Every lowering decision (vvl, stencil slab, interpret fallback, halo
strategy, canonical-view choice) is planned in ``core.plan`` — this module
only *executes* a :class:`~repro.core.plan.LoweringPlan`.  ``choose_vvl`` /
``choose_slab`` / ``resolve_vvl`` are re-exported from there for backwards
compatibility; ``TargetConfig.plan_policy`` selects how plans are made
("default" heuristics, the persisted "tuned" table of ``core.tune``, or an
explicit plan).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .field import Field
from .layout import Layout
from .plan import (  # noqa: F401  (re-exported: the planning layer owns them)
    DtypePolicy,
    LoweringPlan,
    choose_slab,
    choose_vvl,
    plan_for_launch,
    resolve_vvl,
)

__all__ = [
    "TargetConfig",
    "DtypePolicy",
    "kernel",
    "launch",
    "choose_vvl",
    "resolve_vvl",
    "choose_slab",
    "LoweringPlan",
    "TargetKernel",
]


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


@dataclasses.dataclass(frozen=True)
class TargetConfig:
    """Compile-time configuration (the paper's build options).

    engine       "jnp" (host C / OpenMP analogue) or "pallas" (device analogue)
    vvl          Virtual Vector Length: lattice sites per pallas program.
    interpret    run pallas in interpret mode (True automatically off-TPU).
    plan_policy  how lowering decisions are made (core.plan):
                 "default" — the heuristic plan (largest conforming vvl/slab);
                 "tuned"   — look up the persisted autotune table (core.tune)
                             by the launch's plan key, falling back to the
                             default heuristics on a miss;
                 a LoweringPlan — use exactly this plan (validated per launch).
    vmem_bytes   per-program VMEM byte budget for stencil lowering.  None
                 defers to $TARGETDP_VMEM_BYTES, and an unset/0 budget means
                 unbounded — the pre-budget behavior, default plans stay
                 bit-identical.  With a budget, a stencil launch whose
                 whole-staging footprint exceeds it auto-tiles the y/z axes
                 (LoweringPlan.by/.bz) so per-program VMEM is bounded by the
                 tile, and the tuner skips (and logs) over-budget candidates.
    telemetry    per-launch override of the core.telemetry span recording:
                 None defers to the process switch ($TARGETDP_TELEMETRY /
                 telemetry.enable()); True/False force it for launches made
                 with this config.  Spans are host-side only — flipping this
                 never changes a single bit of any launch output.
    dtypes       mixed-precision DtypePolicy (storage/compute/accumulate —
                 core.plan.DtypePolicy) applied to every launch made with
                 this config whose resolved plan does not already carry its
                 own policy (a tuned/explicit plan's policy wins).  None —
                 the default — changes nothing: lowering stays bit-identical
                 to the pre-policy code.
    """

    engine: str = "jnp"
    vvl: int = 128
    interpret: Optional[bool] = None
    plan_policy: Union[str, LoweringPlan] = "default"
    vmem_bytes: Optional[int] = None
    telemetry: Optional[bool] = None
    dtypes: Optional[DtypePolicy] = None

    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return not _on_tpu()

    def resolved_vmem_bytes(self) -> Optional[int]:
        from .plan import resolved_vmem_bytes
        return resolved_vmem_bytes(self)


def build_halo_in_specs(
    shapes: Sequence[Tuple[int, ...]],
) -> List[pl.BlockSpec]:
    """BlockSpecs for halo'd stencil-graph inputs: overlapping x-slab windows
    are not expressible as disjoint Blocked windows, so each halo'd array is
    staged whole into VMEM (constant index map) and the kernel slices the
    per-program halo'd window out with ``lax.dynamic_slice`` — displacement
    becomes slice arithmetic on VMEM-resident data (see
    kernels/lb_propagation for the single-kernel precedent).  Shapes are
    whatever the staging produced: canonical ``(ncomp, *halo'd_lattice)``
    under ``view="staged-nd"``, or the physical 3-D AoSoA
    ``(nblocks, ncomp, SAL)`` tile stack under the native ``view="block"``
    lowering (the kernel then slices on the *block* axis)."""
    specs = []
    for shp in shapes:
        zeros = (0,) * len(shp)
        # variadic: the site grid may carry trailing y/z tile axes
        # (LoweringPlan.by/.bz) — whole-staged inputs are tile-invariant
        specs.append(pl.BlockSpec(shp, lambda *_i, _z=zeros: _z))
    return specs


def build_slab_out_specs(
    out_names: Sequence[str],
    out_specs: Mapping[str, Tuple[int, object]],
    lattice: Tuple[int, ...],
    bx: int,
) -> Tuple[List[jax.ShapeDtypeStruct], List[pl.BlockSpec]]:
    """(out_shape, BlockSpec) per interior nd output of a stencil graph:
    canonical (ncomp, X, *inner) arrays blocked into disjoint x-slabs."""
    inner = tuple(lattice[1:])
    shapes, specs = [], []
    for k in out_names:
        ncomp, dtype = out_specs[k]
        shapes.append(
            jax.ShapeDtypeStruct((ncomp,) + tuple(lattice), dtype)
        )
        block = (ncomp, bx) + inner
        idx = lambda i: (0, i) + (0,) * len(inner)
        specs.append(pl.BlockSpec(block, idx))
    return shapes, specs


def build_block_out_specs(
    out_names: Sequence[str],
    out_specs: Mapping[str, Tuple[int, object]],
    out_layouts: Mapping[str, Layout],
    lattice: Tuple[int, ...],
    bx: int,
) -> Tuple[List[jax.ShapeDtypeStruct], List[pl.BlockSpec], List[bool]]:
    """(out_shape, BlockSpec, native?) per output of a ``view="block"``
    stencil graph.

    An AoSoA output whose SAL divides the interior inner-plane site count
    is written *natively*: the out_shape is the physical
    ``(nsites/SAL, ncomp, SAL)`` array and each program owns a disjoint
    run of ``bx * inner / SAL`` whole blocks on the leading axis — the
    kernel packs its interior slab in VMEM and no XLA relayout runs after
    the launch.  Anything else falls back to the canonical x-slab spec of
    :func:`build_slab_out_specs` (packing for SoA is a view and for AoS a
    transpose), flagged ``native=False`` so the caller packs as usual."""
    from .layout import LayoutKind

    inner = int(math.prod(lattice[1:]))
    nsites = int(math.prod(lattice))
    shapes, specs, native = [], [], []
    for k in out_names:
        ncomp, dtype = out_specs[k]
        lay = out_layouts[k]
        if lay.kind is LayoutKind.AOSOA and inner % lay.sal == 0:
            sal = lay.sal
            shapes.append(
                jax.ShapeDtypeStruct((nsites // sal, ncomp, sal), dtype))
            specs.append(
                pl.BlockSpec((bx * inner // sal, ncomp, sal),
                             lambda i: (i, 0, 0)))
            native.append(True)
        else:
            s, p = build_slab_out_specs([k], out_specs, lattice, bx)
            shapes += s
            specs += p
            native.append(False)
    return shapes, specs, native


def build_reduce_specs(
    out_names: Sequence[str],
    out_specs: Mapping[str, Tuple[int, object]],
    widths: Optional[Mapping[str, int]] = None,
) -> Tuple[List[jax.ShapeDtypeStruct], List[pl.BlockSpec]]:
    """(out_shape, BlockSpec) per terminal-reduction accumulator: a single
    (ncomp, width) partial buffer with a constant index map, revisited by
    every program (TPU pallas grids execute sequentially per core, so
    cross-block read-modify-write accumulation is well defined — same idiom
    as core.reduce).  ``widths`` widens a buffer's trailing axis (default
    1, the pre-policy shape); compensated (Kahan) accumulation under a
    DtypePolicy uses width 2 — column 0 the running sum, column 1 the
    running compensation."""
    shapes, specs = [], []
    for k in out_names:
        ncomp, dtype = out_specs[k]
        w = (widths or {}).get(k, 1)
        shapes.append(jax.ShapeDtypeStruct((ncomp, w), dtype))
        # variadic: revisited by every program of the (possibly tiled) grid
        specs.append(pl.BlockSpec((ncomp, w), lambda *_i: (0, 0)))
    return shapes, specs


def build_split_reduce_specs(
    out_names: Sequence[str],
    out_specs: Mapping[str, Tuple[int, object]],
    rsplit: int,
    widths: Optional[Mapping[str, int]] = None,
) -> Tuple[List[jax.ShapeDtypeStruct], List[pl.BlockSpec]]:
    """(out_shape, BlockSpec) per terminal-reduction accumulator under a
    split-reduction plan (``LoweringPlan.rsplit > 1``): a ``(rsplit,
    ncomp, width)`` stage-1 partial buffer whose rows are selected by the
    split grid axis — each of the ``rsplit`` grid segments accumulates
    its own row, and the tiny stage-2 combine folds the rows in segment
    order after the call (core.fuse).  ``widths`` as in
    :func:`build_reduce_specs` (compensated accumulation widens to 2)."""
    shapes, specs = [], []
    for k in out_names:
        ncomp, dtype = out_specs[k]
        w = (widths or {}).get(k, 1)
        shapes.append(jax.ShapeDtypeStruct((rsplit, ncomp, w), dtype))
        # variadic beyond the split axis: the per-segment site axis may
        # carry trailing tile axes; the buffer row follows the segment only
        specs.append(pl.BlockSpec((1, ncomp, w), lambda s, *_i: (s, 0, 0)))
    return shapes, specs


def build_tiled_out_specs(
    out_names: Sequence[str],
    out_specs: Mapping[str, Tuple[int, object]],
    lattice: Tuple[int, ...],
    bx: int,
    by: int,
    bz: int,
) -> Tuple[List[jax.ShapeDtypeStruct], List[pl.BlockSpec]]:
    """(out_shape, BlockSpec) per interior nd output of a *tiled* stencil
    graph (``LoweringPlan.by``/``.bz``): canonical ``(ncomp, X, Y, Z, ...)``
    arrays blocked into disjoint ``(bx, by, bz)`` tiles.  Unlike the
    overlapping input windows, output tiles are exactly expressible as
    disjoint Blocked windows — the index map consumes one grid coordinate
    per *active* tile axis (x always; y iff ``by``; z iff ``bz``), matching
    the trailing tile axes core.fuse appends to the site grid."""
    nd = len(lattice)
    tail = []
    for d in range(1, nd):
        if d == 1 and by:
            tail.append(by)
        elif d == 2 and bz:
            tail.append(bz)
        else:
            tail.append(lattice[d])
    tail = tuple(tail)

    def idx(i, *tiles):
        out = [0, i]
        t = iter(tiles)
        for d in range(1, nd):
            if (d == 1 and by) or (d == 2 and bz):
                out.append(next(t))
            else:
                out.append(0)
        return tuple(out)

    shapes, specs = [], []
    for k in out_names:
        ncomp, dtype = out_specs[k]
        shapes.append(jax.ShapeDtypeStruct((ncomp,) + tuple(lattice), dtype))
        specs.append(pl.BlockSpec((ncomp, bx) + tail, idx))
    return shapes, specs


def build_in_specs(
    in_meta: Sequence[Tuple[int, Layout]], vvl: int
) -> List[pl.BlockSpec]:
    """One BlockSpec per (ncomp, Layout) input, derived from its Layout
    (shared by the single-kernel path and the fused launch-graph path)."""
    return [
        pl.BlockSpec(lay.block_shape(ncomp, vvl), lay.block_index_map())
        for ncomp, lay in in_meta
    ]


def build_out_specs(
    out_names: Sequence[str],
    out_specs: Mapping[str, Tuple[int, object]],
    out_layouts: Mapping[str, Layout],
    nsites: int,
    vvl: int,
) -> Tuple[List[jax.ShapeDtypeStruct], List[pl.BlockSpec]]:
    """(out_shape, out BlockSpec) per output, derived from its Layout."""
    shapes, specs = [], []
    for k in out_names:
        ncomp, dtype = out_specs[k]
        lay = out_layouts[k]
        shapes.append(jax.ShapeDtypeStruct(lay.physical_shape(ncomp, nsites), dtype))
        specs.append(pl.BlockSpec(lay.block_shape(ncomp, vvl), lay.block_index_map()))
    return shapes, specs


class TargetKernel:
    """A site-local data-parallel kernel (the paper's __targetEntry__ unit)."""

    def __init__(self, body: Callable, name: Optional[str] = None):
        self.body = body
        self.name = name or getattr(body, "__name__", "kernel")

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"TargetKernel({self.name})"

    # -- engines ---------------------------------------------------------------

    def _run_jnp(self, ins: Dict[str, Field], params: Mapping) -> Dict[str, jax.Array]:
        chunks = {k: f.canonical() for k, f in ins.items()}
        return self.body(chunks, **dict(params))

    def _run_pallas(
        self,
        ins: Dict[str, Field],
        out_specs: Mapping[str, Tuple[int, object]],
        params: Mapping,
        plan: LoweringPlan,
        out_layouts: Mapping[str, Layout],
    ) -> Dict[str, jax.Array]:
        names = list(ins)
        nsites = ins[names[0]].nsites
        for f in ins.values():
            if f.nsites != nsites:
                raise ValueError("all fields in one launch must share nsites")
        vvl, interpret = plan.vvl, plan.interpret
        if nsites % vvl:
            raise ValueError(
                f"vvl={vvl} must divide nsites={nsites} "
                f"(use a conforming plan or pad the lattice)"
            )
        grid = (nsites // vvl,)

        in_block_specs = build_in_specs(
            [(f.ncomp, f.layout) for f in ins.values()], vvl
        )
        out_names = list(out_specs)
        out_shapes, out_block_specs = build_out_specs(
            out_names, out_specs, out_layouts, nsites, vvl
        )

        body = self.body
        static_params = dict(params)
        in_fields = list(ins.values())

        def pallas_kernel(*refs):
            in_refs = refs[: len(in_fields)]
            out_refs = refs[len(in_fields):]
            chunks = {}
            for k, f, r in zip(names, in_fields, in_refs):
                chunks[k] = f.layout.block_to_canonical(r[...], f.ncomp, vvl)
            outs = body(chunks, **static_params)
            for k, r in zip(out_names, out_refs):
                ncomp, _ = out_specs[k]
                r[...] = out_layouts[k].canonical_to_block(outs[k], ncomp, vvl)

        call = pl.pallas_call(
            pallas_kernel,
            grid=grid,
            in_specs=in_block_specs,
            out_specs=(
                out_block_specs if len(out_block_specs) > 1 else out_block_specs[0]
            ),
            out_shape=out_shapes if len(out_shapes) > 1 else out_shapes[0],
            interpret=interpret,
            name=self.name,
        )
        result = call(*[f.data for f in in_fields])
        if len(out_names) == 1:
            result = [result]
        # physical -> canonical
        out = {}
        for k, phys in zip(out_names, result):
            out[k] = out_layouts[k].unpack(phys)
        return out


def kernel(fn: Optional[Callable] = None, *, name: Optional[str] = None):
    """Decorator: register a site-local kernel body.

    Body signature::

        def body(v: dict[str, Array(ncomp, VVL)], **params) -> dict[str, Array]
    """

    def wrap(f):
        return TargetKernel(f, name=name)

    return wrap(fn) if fn is not None else wrap


def _normalize_out_specs(out_specs, ref_dtype):
    norm = {}
    for k, v in out_specs.items():
        if isinstance(v, tuple):
            norm[k] = (int(v[0]), v[1])
        else:
            norm[k] = (int(v), ref_dtype)
    return norm


def launch(
    kern: Union[TargetKernel, Callable],
    ins: Dict[str, Field],
    out_specs: Mapping[str, Union[int, Tuple[int, object]]],
    *,
    config: Optional[TargetConfig] = None,
    params: Optional[Mapping] = None,
    out_layouts: Optional[Mapping[str, Layout]] = None,
) -> Dict[str, Field]:
    """Execute a kernel over the lattice (the paper's __targetLaunch__).

    ins         name -> input Field (all sharing nsites; layouts may differ).
    out_specs   name -> ncomp (or (ncomp, dtype)) of each output Field.
    Returns     name -> output Field (same lattice; layout = out_layouts[name]
                or the first input's layout).
    """
    if not isinstance(kern, TargetKernel):
        kern = TargetKernel(kern)
    config = config or TargetConfig()
    params = params or {}
    first = next(iter(ins.values()))
    out_specs = _normalize_out_specs(out_specs, first.dtype)
    out_layouts = dict(out_layouts or {})
    for k in out_specs:
        out_layouts.setdefault(k, first.layout)

    # every lowering decision (auto-vvl, interpret fallback, policy) is made
    # by the planning layer; this function only executes the plan
    plan = plan_for_launch(
        config,
        first.nsites,
        [f.layout for f in ins.values()] + [out_layouts[k] for k in out_specs],
    )
    if plan.engine == "jnp":
        outs = kern._run_jnp(ins, params)
    else:  # "pallas" (plan_for_launch validated the engine)
        outs = kern._run_pallas(
            ins, out_specs, params, plan=plan, out_layouts=out_layouts
        )

    fields = {}
    for k, (ncomp, dtype) in out_specs.items():
        arr = outs[k].astype(dtype)
        fields[k] = Field(
            k, ncomp, first.lattice, out_layouts[k], out_layouts[k].pack(arr)
        )
    return fields
