"""Process-wide telemetry: counters, spans, JSONL sink, Chrome-trace export.

The paper assesses every port "within the context of the Roofline model"
(§5); this module makes that assessment *live*.  Every hot seam of the
stack is instrumented against one registry:

* **counters** — monotonically increasing named integers.  Always on:
  they are the same dict increments the old ``fuse._STATS`` /
  ``tune._STATS`` probes already paid for (those public ``stats()``
  functions are now thin shims over this registry).
* **gauges** — point-in-time samples (serve queue depth, slot occupancy).
  Recorded only while telemetry is enabled.
* **spans** — timed intervals with attributes (one per ``LaunchGraph``
  launch, tuner candidate, overlap sub-launch, pipeline step, serve
  request).  Launch spans carry the resolved plan label, cache hit/miss,
  the modeled HBM bytes of ``LaunchGraph.bytes_moved`` and a live
  roofline placement against the ``launch/roofline.py`` ceilings.
* **events** — zero-duration instants (pruned/failed tune candidates).

Gating: the module switch starts from ``$TARGETDP_TELEMETRY`` (1/true/on
/yes) and is flipped at runtime with :func:`enable` / :func:`disable`;
``TargetConfig.telemetry`` overrides it per launch.  The disabled path is
a no-op closure — ``span()`` hands back a shared null object whose enter/
exit/set do nothing, so instrumented code pays one predicate per site
(the bench-smoke CI gate holds the enabled-vs-disabled overhead of the
fused smoke row under 1%).  Telemetry never touches traced values: every
attribute is a host-side scalar/string, so enabling it cannot perturb a
single bit of any launch output.

Export: :func:`export_chrome_trace` writes the Chrome trace-event JSON
(``{"traceEvents": [...]}``) that Perfetto / ``chrome://tracing`` load
directly; :func:`write_jsonl` (or the live sink of ``enable(jsonl=...)``)
streams one JSON object per finished span.  :func:`report` returns the
aggregate snapshot; :func:`configure_logging` wires every ``repro.*``
child logger through one stderr handler.
"""

from __future__ import annotations

import json
import logging
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "ENV_VAR",
    "enabled",
    "enable",
    "disable",
    "inc",
    "counter_value",
    "counters",
    "reset_counters",
    "sample",
    "gauges",
    "span",
    "begin_span",
    "event",
    "events",
    "reset",
    "report",
    "format_report",
    "export_chrome_trace",
    "write_jsonl",
    "roofline_placement",
    "configure_logging",
]

ENV_VAR = "TARGETDP_TELEMETRY"

_TRUTHY = ("1", "true", "on", "yes")


def _env_enabled(value: Optional[str]) -> bool:
    return (value or "").strip().lower() in _TRUTHY


# -- registry state ------------------------------------------------------------

_lock = threading.Lock()
_enabled: bool = _env_enabled(os.environ.get(ENV_VAR))
_counters: Dict[str, int] = {}
_gauges: Dict[str, List[tuple]] = {}  # name -> [(ts, value), ...]
_events: List[dict] = []  # finished spans + instants, in finish order
_jsonl: Optional[Any] = None  # open file object of the live sink
_T0 = time.perf_counter()  # trace time base (relative perf_counter)
_MAX_EVENTS = 500_000  # hard cap: long serve runs must not grow unbounded
_dropped = 0


def enabled(override: Optional[bool] = None) -> bool:
    """Whether spans/gauges record.  ``override`` (a per-launch
    ``TargetConfig.telemetry``) wins over the process switch when set."""
    if override is not None:
        return bool(override)
    return _enabled


def enable(jsonl: Optional[str] = None) -> None:
    """Turn span/gauge recording on (optionally streaming finished spans
    to a JSONL file at ``jsonl``, one JSON object per line)."""
    global _enabled, _jsonl
    with _lock:
        _enabled = True
        if jsonl is not None:
            if _jsonl is not None:
                _jsonl.close()
            _jsonl = open(jsonl, "a")


def disable() -> None:
    """Turn span/gauge recording off (counters keep counting — they are
    the pre-telemetry ``stats()`` probes) and close any JSONL sink."""
    global _enabled, _jsonl
    with _lock:
        _enabled = False
        if _jsonl is not None:
            _jsonl.close()
            _jsonl = None


# -- counters (always on) ------------------------------------------------------

def inc(name: str, n: int = 1) -> None:
    """Add ``n`` to counter ``name`` (created at 0)."""
    _counters[name] = _counters.get(name, 0) + n


def counter_value(name: str) -> int:
    return _counters.get(name, 0)


def counters(prefix: Optional[str] = None) -> Dict[str, int]:
    """Snapshot of the counter registry (optionally only ``prefix``-ed)."""
    if prefix is None:
        return dict(_counters)
    return {k: v for k, v in _counters.items() if k.startswith(prefix)}


def reset_counters(prefix: Optional[str] = None) -> None:
    """Zero every counter (or only those under ``prefix``) — the
    back-compat ``reset_stats()`` shims scope themselves this way."""
    if prefix is None:
        _counters.clear()
        return
    for k in [k for k in _counters if k.startswith(prefix)]:
        _counters[k] = 0


# -- gauges (gated) ------------------------------------------------------------

def sample(name: str, value: float) -> None:
    """Record a point-in-time sample of gauge ``name`` (no-op when
    disabled)."""
    if not _enabled:
        return
    _gauges.setdefault(name, []).append(
        (time.perf_counter() - _T0, float(value)))


def gauges(prefix: Optional[str] = None) -> Dict[str, List[tuple]]:
    if prefix is None:
        return {k: list(v) for k, v in _gauges.items()}
    return {k: list(v) for k, v in _gauges.items() if k.startswith(prefix)}


# -- spans (gated) -------------------------------------------------------------

class Span:
    """One timed interval.  Use as a context manager (``with span(...)``)
    or manually via :func:`begin_span` / :meth:`end`.  ``set()`` attaches
    attributes mid-flight (e.g. cache hit/miss discovered during the
    launch, achieved GB/s computed after it)."""

    __slots__ = ("name", "attrs", "t0", "t1")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.t0 = time.perf_counter() - _T0
        self.t1: Optional[float] = None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.attrs.setdefault("error", repr(exc))
        self.end()

    def end(self, **attrs) -> None:
        if self.t1 is not None:  # already closed
            return
        self.attrs.update(attrs)
        self.t1 = time.perf_counter() - _T0
        _record({
            "type": "span",
            "name": self.name,
            "ts": self.t0,
            "dur": self.t1 - self.t0,
            "tid": threading.get_ident(),
            "attrs": self.attrs,
        })

    @property
    def elapsed(self) -> float:
        """Seconds since the span opened (closed spans: the duration)."""
        return (self.t1 if self.t1 is not None
                else time.perf_counter() - _T0) - self.t0


class _NullSpan:
    """The disabled path: a shared do-nothing closure.  Every method is a
    no-op returning ``self``, so instrumented code never branches beyond
    the single ``enabled`` predicate inside :func:`span`."""

    __slots__ = ()
    name = None
    attrs: Dict[str, Any] = {}
    elapsed = 0.0

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def end(self, **attrs) -> None:
        return None

    def __bool__(self) -> bool:
        return False


NULL_SPAN = _NullSpan()


def span(name: str, *, override: Optional[bool] = None, **attrs):
    """A new :class:`Span` when telemetry records, else the shared
    :data:`NULL_SPAN` no-op."""
    if not enabled(override):
        return NULL_SPAN
    return Span(name, attrs)


def begin_span(name: str, *, override: Optional[bool] = None, **attrs):
    """Manual-lifetime form of :func:`span` (close with ``.end()``) — for
    intervals that do not nest lexically, e.g. a serve request's
    admission-to-harvest latency."""
    return span(name, override=override, **attrs)


def event(name: str, *, override: Optional[bool] = None, **attrs) -> None:
    """A zero-duration instant (a pruned tune candidate, a degrade)."""
    if not enabled(override):
        return
    _record({
        "type": "event",
        "name": name,
        "ts": time.perf_counter() - _T0,
        "dur": 0.0,
        "tid": threading.get_ident(),
        "attrs": attrs,
    })


def _record(rec: dict) -> None:
    global _dropped
    with _lock:
        if len(_events) >= _MAX_EVENTS:
            _dropped += 1
            return
        _events.append(rec)
        if _jsonl is not None:
            _jsonl.write(json.dumps(rec, default=str) + "\n")
            _jsonl.flush()


def events(name_prefix: Optional[str] = None) -> List[dict]:
    """Snapshot of finished spans/instants (optionally filtered by name
    prefix)."""
    with _lock:
        evs = list(_events)
    if name_prefix is None:
        return evs
    return [e for e in evs if e["name"].startswith(name_prefix)]


def reset() -> None:
    """Clear spans, instants and gauges (counters too — tests start
    clean)."""
    global _dropped
    with _lock:
        _events.clear()
        _dropped = 0
    _gauges.clear()
    _counters.clear()


# -- roofline placement --------------------------------------------------------

_HBM_BW: Optional[float] = None


def roofline_placement(bytes_moved: int, seconds: float) -> Dict[str, Any]:
    """Live roofline fields for a launch span: achieved GB/s from the
    modeled HBM bytes over the measured wall interval, as a fraction of
    the ``launch/roofline.py`` HBM ceiling.  The stack's kernels sit far
    below every ridge point (paper C4, fig 4), so the HBM bandwidth roof
    is the binding ceiling — ``placement`` names it with the achieved
    fraction.  Host-side wall time includes dispatch/interpret overhead;
    on real hardware the fraction approaches the paper's %STREAM."""
    global _HBM_BW
    if _HBM_BW is None:
        from repro.launch.roofline import HBM_BW
        _HBM_BW = HBM_BW

    gbps = (bytes_moved / seconds / 1e9) if seconds > 0 else 0.0
    ceiling = _HBM_BW / 1e9
    frac = gbps / ceiling if ceiling else 0.0
    return {
        "gbps_achieved": gbps,
        "roofline_ceiling_gbps": ceiling,
        "roofline_frac": frac,
        "roofline_placement": (
            f"memory-roof {frac * 100:.2f}% of {ceiling:.0f} GB/s HBM"),
    }


# -- reporting / export --------------------------------------------------------

def report() -> Dict[str, Any]:
    """Aggregate snapshot: counters, per-gauge min/max/last, and per-name
    span statistics (count, total/mean/max seconds)."""
    evs = events()
    by_name: Dict[str, Dict[str, float]] = {}
    for e in evs:
        agg = by_name.setdefault(
            e["name"], {"count": 0, "total_s": 0.0, "max_s": 0.0})
        agg["count"] += 1
        agg["total_s"] += e["dur"]
        agg["max_s"] = max(agg["max_s"], e["dur"])
    for agg in by_name.values():
        agg["mean_s"] = agg["total_s"] / max(agg["count"], 1)
    gg = {
        name: {"samples": len(vals),
               "min": min(v for _, v in vals),
               "max": max(v for _, v in vals),
               "last": vals[-1][1]}
        for name, vals in _gauges.items() if vals
    }
    return {
        "enabled": _enabled,
        "counters": counters(),
        "gauges": gg,
        "spans": by_name,
        "events_recorded": len(evs),
        "events_dropped": _dropped,
    }


def format_report() -> str:
    """Human-readable :func:`report` (the ``--trace`` CLIs print this)."""
    r = report()
    lines = [f"telemetry report (enabled={r['enabled']}, "
             f"{r['events_recorded']} events)"]
    if r["counters"]:
        lines.append("  counters:")
        for k in sorted(r["counters"]):
            lines.append(f"    {k:<40s} {r['counters'][k]}")
    if r["gauges"]:
        lines.append("  gauges (min/max/last):")
        for k in sorted(r["gauges"]):
            g = r["gauges"][k]
            lines.append(f"    {k:<40s} {g['min']:g}/{g['max']:g}/"
                         f"{g['last']:g} ({g['samples']} samples)")
    if r["spans"]:
        lines.append("  spans (count, total, mean):")
        for k in sorted(r["spans"]):
            s = r["spans"][k]
            lines.append(f"    {k:<40s} {s['count']:>6d}  "
                         f"{s['total_s'] * 1e3:9.2f} ms  "
                         f"{s['mean_s'] * 1e6:9.1f} us")
    return "\n".join(lines)


def export_chrome_trace(path: str) -> str:
    """Write every recorded span/instant/gauge as a Chrome trace-event
    JSON file — load it at https://ui.perfetto.dev or chrome://tracing.
    Spans become complete ("X") events with their attributes under
    ``args``; instants become "i" events; gauge samples become counter
    ("C") tracks.  Returns ``path``."""
    pid = os.getpid()
    trace_events: List[dict] = [{
        "ph": "M", "pid": pid, "name": "process_name",
        "args": {"name": "targetdp-jax"},
    }]
    for e in events():
        rec = {
            "ph": "X" if e["type"] == "span" else "i",
            "name": e["name"],
            "cat": e["name"].split("/", 1)[0],
            "ts": e["ts"] * 1e6,
            "pid": pid,
            "tid": e["tid"],
            "args": {k: v if isinstance(v, (int, float, bool, str))
                     else str(v) for k, v in e["attrs"].items()},
        }
        if e["type"] == "span":
            rec["dur"] = e["dur"] * 1e6
        else:
            rec["s"] = "t"  # thread-scoped instant
        trace_events.append(rec)
    for name, vals in _gauges.items():
        for ts, v in vals:
            trace_events.append({
                "ph": "C", "name": name, "cat": name.split(".", 1)[0],
                "ts": ts * 1e6, "pid": pid, "args": {"value": v},
            })
    with open(path, "w") as f:
        json.dump({"traceEvents": trace_events, "displayTimeUnit": "ms"},
                  f, indent=1)
    return path


def write_jsonl(path: str) -> str:
    """Dump every recorded span/instant to ``path``, one JSON object per
    line (the batch form of the ``enable(jsonl=...)`` live sink)."""
    with open(path, "w") as f:
        for e in events():
            f.write(json.dumps(e, default=str) + "\n")
    return path


# -- logging -------------------------------------------------------------------

_LOG_HANDLER_FLAG = "_targetdp_telemetry_handler"


def configure_logging(level: int = logging.INFO,
                      stream=None) -> logging.Logger:
    """One entry point for the ``repro.*`` logger tree: attach a stderr
    (or ``stream``) handler with a uniform format to the ``repro`` root
    logger and set its level.  Every module logger in the stack is a
    ``logging.getLogger(__name__)`` child of it (``repro.core.fuse``,
    ``repro.core.overlap``, ``repro.core.tune``, ...), so the tuner's
    candidate-failure capture, the overlap thin-interior fallback and the
    tuned-misfit degrade messages all land here.  Idempotent: repeat
    calls re-level the existing handler instead of stacking new ones."""
    logger = logging.getLogger("repro")
    handler = next(
        (h for h in logger.handlers if getattr(h, _LOG_HANDLER_FLAG, False)),
        None)
    if handler is None:
        handler = logging.StreamHandler(stream)
        handler.setFormatter(logging.Formatter(
            "%(asctime)s %(levelname)-7s %(name)s: %(message)s"))
        setattr(handler, _LOG_HANDLER_FLAG, True)
        logger.addHandler(handler)
    handler.setLevel(level)
    logger.setLevel(level)
    return logger
