"""targetDP-JAX core: the paper's abstraction layer, adapted to TPU.

Layout (INDEX macro)  ->  core.layout
Field                  ->  core.field
Engines / launch       ->  core.target   (__targetTLP__/__targetILP__/VVL)
Memory spaces          ->  core.memspace (targetMalloc / copyToTarget / ...)
Reductions             ->  core.reduce   (targetDoubleSum ...)
Stencils               ->  core.stencil
Halo exchange (MPI)    ->  core.halo     (shard_map + ppermute)
Kernel fusion          ->  core.fuse     (LaunchGraph: site-local, stencil and
                                          terminal-reduction stages -> one
                                          pallas_call)
Lowering plans (VVL)   ->  core.plan     (LoweringPlan: vvl/slab/interpret/
                                          halo/view decisions, candidates)
Plan autotuner         ->  core.tune     (persisted per-(chain, layout,
                                          backend) sweep table)
Comms/compute overlap  ->  core.overlap  (interior/boundary split launches
                                          hiding the halo exchange)
Multi-step pipelines   ->  core.schedule (StepPipeline: donated
                                          double-buffers, async dispatch)
Version gates          ->  core.compat   (shard_map / make_mesh across jax
                                          releases)
Telemetry              ->  core.telemetry (counters/spans/gauges, JSONL +
                                          Chrome-trace export, live
                                          roofline placement per launch)
"""

from .layout import (  # noqa: F401
    AOS, SOA, Layout, LayoutKind, aosoa, parse_layout, tileable_layout,
)
from .field import BatchedField, Field  # noqa: F401
from .plan import DtypePolicy, LoweringPlan  # noqa: F401
from .target import (  # noqa: F401
    TargetConfig,
    TargetKernel,
    choose_slab,
    choose_vvl,
    kernel,
    launch,
    resolve_vvl,
)
from .fuse import BoundLaunch, LaunchGraph, ReduceSpec, fused_launch  # noqa: F401
from . import plan, tune  # noqa: F401
from . import compat  # noqa: F401
from . import overlap  # noqa: F401
from .overlap import overlap_launch  # noqa: F401
from .schedule import StepPipeline  # noqa: F401
from .memspace import (  # noqa: F401
    copy_const_to_target,
    copy_from_target,
    copy_to_target,
    target_free,
    target_malloc,
    target_synchronize,
)
from .reduce import target_max, target_sum  # noqa: F401
from . import halo, stencil  # noqa: F401
from . import telemetry  # noqa: F401
