"""StepPipeline: a multi-step runner with donated double-buffers.

Timestep loops (a Ludwig LB step, a MILC CG iteration block) apply the
same jitted function over and over with the previous outputs as the next
inputs.  Two costs ride on a naive host loop: every step allocates fresh
HBM for its outputs while the old state lingers (peak memory = 2x state
plus fragmentation), and a host that blocks per step serializes dispatch
with device compute.  :class:`StepPipeline` addresses both:

* **donated double-buffers** — the step is jitted with every state arg
  donated (``donate_argnums``), so XLA aliases each output buffer onto an
  input buffer: the state ping-pongs between two device allocations for
  the whole run, no per-step allocation.  (CPU jax ignores donation with a
  warning; donation is auto-disabled there unless forced.)
* **pipelined dispatch** — the loop enqueues steps without blocking; jax's
  async dispatch lets the host race ahead and the device queue stay full.
  ``run(..., block=True)`` blocks only on the final state.

The step function must be state-shape-preserving (outputs congruent with
inputs — true of the sharded drivers, whose state is (dist_nd, q_nd) or
the CG carry).  With donation enabled the caller must not reuse the input
arrays after ``run`` — they are consumed by the first step.

Usage::

    from repro.core.schedule import StepPipeline
    pipe = StepPipeline(make_sharded_step(cfg, dom, halo="overlap"))
    dist_nd, q_nd = pipe.run((dist_nd, q_nd), steps=100)
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Tuple

import jax

from . import telemetry

__all__ = ["StepPipeline"]


class StepPipeline:
    """Drive a state-preserving step function for many steps.

    step_fn  callable ``(*state) -> state`` (tuple or single array) whose
             outputs match the inputs in shape/dtype/sharding.
    donate   True: donate every state arg (double-buffering); False: never;
             None (default): donate except on the cpu backend, which does
             not implement buffer donation (jax warns and copies).
    """

    def __init__(self, step_fn: Callable, *, donate: Optional[bool] = None):
        self._step = step_fn
        self._donate = donate
        self._jitted = {}

    def _resolved_donate(self) -> bool:
        if self._donate is not None:
            return self._donate
        return jax.default_backend() != "cpu"

    def _fn(self, nargs: int) -> Callable:
        fn = self._jitted.get(nargs)
        if fn is None:
            donate = tuple(range(nargs)) if self._resolved_donate() else ()
            fn = jax.jit(self._step, donate_argnums=donate)
            self._jitted[nargs] = fn
        return fn

    def run(
        self,
        state: Tuple,
        steps: int,
        *,
        block: bool = True,
        on_step: Optional[Callable[[int, Tuple], None]] = None,
    ) -> Tuple:
        """Run ``steps`` applications of the step function.

        state    tuple of arrays (a single array is wrapped).
        on_step  optional ``hook(i, state)`` after each step — with
                 donation enabled it must not hold earlier states.
        Returns the final state tuple (blocked on when ``block``).
        """
        if steps < 0:
            raise ValueError(f"steps must be >= 0, got {steps}")
        if not isinstance(state, tuple):
            state = (state,)
        fn = self._fn(len(state))
        name = getattr(self._step, "__name__", "step")
        for i in range(steps):
            # per-step span (core.telemetry): the dispatch interval of each
            # pipelined step — what run_timed's single run-level number
            # used to hide.  Async dispatch means the span measures enqueue
            # time once the device queue fills; the final step's span plus
            # the block below bound the drain.
            with telemetry.span(f"pipeline/{name}", step=i):
                out = fn(*state)
            state = out if isinstance(out, tuple) else (out,)
            if on_step is not None:
                on_step(i, state)
        if block:
            with telemetry.span(f"pipeline/{name}.block", steps=steps):
                jax.block_until_ready(state)
        return state

    def run_timed(
        self, state: Tuple, steps: int, *, warmup: int = 1
    ) -> Tuple[Tuple, float]:
        """``run`` with wall-clock: returns (final_state, seconds_per_step)
        over ``steps`` timed steps after ``warmup`` untimed ones (compile +
        queue fill)."""
        state = self.run(state, warmup, block=True)
        t0 = time.perf_counter()
        state = self.run(state, steps, block=True)
        dt = time.perf_counter() - t0
        return state, dt / max(steps, 1)
