"""D3Q19 lattice-Boltzmann model constants (Ludwig's velocity set).

19 discrete velocities on a 3-D lattice: rest particle, 6 face neighbours,
12 edge neighbours.  cs^2 = 1/3 lattice units.
"""

from __future__ import annotations

import numpy as np

NVEL = 19
CS2 = 1.0 / 3.0

# velocity vectors c_i (Ludwig ordering: rest first, then faces, then edges)
CV = np.array(
    [
        (0, 0, 0),
        (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1),
        (1, 1, 0), (1, -1, 0), (-1, 1, 0), (-1, -1, 0),
        (1, 0, 1), (1, 0, -1), (-1, 0, 1), (-1, 0, -1),
        (0, 1, 1), (0, 1, -1), (0, -1, 1), (0, -1, -1),
    ],
    dtype=np.int32,
)

# quadrature weights
WV = np.array(
    [1.0 / 3.0]
    + [1.0 / 18.0] * 6
    + [1.0 / 36.0] * 12,
    dtype=np.float64,
)

assert CV.shape == (NVEL, 3)
assert abs(WV.sum() - 1.0) < 1e-12
# lattice tensor identities: sum_i w_i c_ia c_ib = cs2 * delta_ab
_t = np.einsum("i,ia,ib->ab", WV, CV, CV)
assert np.allclose(_t, CS2 * np.eye(3), atol=1e-12)
