r"""SU(3) x Dirac algebra on split re/im arrays (TPU has no complex dtype).

Conventions (MILC/DeGrand-Rossi basis):
  - A Wilson spinor at a site is psi[s, c] with s in 0..3 (spin), c in 0..2
    (color), complex.  Stored as two real arrays (re, im) of shape
    (4, 3, ...) where ... are site/vector dims.
  - A gauge link is U[a, b], 3x3 complex, stored as (3, 3, ...) pairs.
  - gamma matrices in the DeGrand-Rossi basis; the Wilson hopping term uses
    the spin projectors P^\mp_mu = (1 -+ gamma_mu)/2 to halve the work
    ("Extract" in MILC = apply the projector, "Mult" = SU(3) x half-spinor).

All routines are shape-polymorphic jnp code: they trace identically inside
a pallas kernel body (VVL trailing axis) and in whole-lattice jnp form —
the single-source property the paper demands.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

Pair = Tuple[jnp.ndarray, jnp.ndarray]  # (re, im)


# ---------------------------------------------------------------------------
# complex primitives on (re, im) pairs
# ---------------------------------------------------------------------------

def cmul(a: Pair, b: Pair) -> Pair:
    ar, ai = a
    br, bi = b
    return ar * br - ai * bi, ar * bi + ai * br


def cmul_conj(a: Pair, b: Pair) -> Pair:
    """conj(a) * b."""
    ar, ai = a
    br, bi = b
    return ar * br + ai * bi, ar * bi - ai * br


def cadd(a: Pair, b: Pair) -> Pair:
    return a[0] + b[0], a[1] + b[1]


def csub(a: Pair, b: Pair) -> Pair:
    return a[0] - b[0], a[1] - b[1]


def cscale(a: Pair, s) -> Pair:
    return a[0] * s, a[1] * s


def ci_mul(a: Pair) -> Pair:
    """i * a."""
    return -a[1], a[0]


def cneg_i_mul(a: Pair) -> Pair:
    """-i * a."""
    return a[1], -a[0]


# ---------------------------------------------------------------------------
# SU(3) action on color vectors
# ---------------------------------------------------------------------------

def su3_mult_vec(u: Pair, v: Pair) -> Pair:
    """(U v): u = (3,3,...), v = (3,...) -> (3,...)."""
    ur, ui = u
    vr, vi = v
    outr = jnp.einsum("ab...,b...->a...", ur, vr) - jnp.einsum(
        "ab...,b...->a...", ui, vi
    )
    outi = jnp.einsum("ab...,b...->a...", ur, vi) + jnp.einsum(
        "ab...,b...->a...", ui, vr
    )
    return outr, outi


def su3_adj_mult_vec(u: Pair, v: Pair) -> Pair:
    """(U^dagger v)."""
    ur, ui = u
    vr, vi = v
    outr = jnp.einsum("ba...,b...->a...", ur, vr) + jnp.einsum(
        "ba...,b...->a...", ui, vi
    )
    outi = jnp.einsum("ba...,b...->a...", ur, vi) - jnp.einsum(
        "ba...,b...->a...", ui, vr
    )
    return outr, outi


def su3_mult_halfspinor(u: Pair, h: Pair) -> Pair:
    """(U h) with an explicit leading spin axis: u (3,3,...), h (s,3,...)."""
    ur, ui = u
    hr, hi = h
    outr = jnp.einsum("ab...,sb...->sa...", ur, hr) - jnp.einsum(
        "ab...,sb...->sa...", ui, hi
    )
    outi = jnp.einsum("ab...,sb...->sa...", ur, hi) + jnp.einsum(
        "ab...,sb...->sa...", ui, hr
    )
    return outr, outi


def su3_adj_mult_halfspinor(u: Pair, h: Pair) -> Pair:
    """(U^dagger h) with an explicit leading spin axis."""
    ur, ui = u
    hr, hi = h
    outr = jnp.einsum("ba...,sb...->sa...", ur, hr) + jnp.einsum(
        "ba...,sb...->sa...", ui, hi
    )
    outi = jnp.einsum("ba...,sb...->sa...", ur, hi) - jnp.einsum(
        "ba...,sb...->sa...", ui, hr
    )
    return outr, outi


# ---------------------------------------------------------------------------
# Wilson spin projection (DeGrand-Rossi gamma basis)
#
# gamma_x = [[0,0,0,i],[0,0,i,0],[0,-i,0,0],[-i,0,0,0]]
# gamma_y = [[0,0,0,-1],[0,0,1,0],[0,1,0,0],[-1,0,0,0]]
# gamma_z = [[0,0,i,0],[0,0,0,-i],[-i,0,0,0],[0,i,0,0]]
# gamma_t = [[0,0,1,0],[0,0,0,1],[1,0,0,0],[0,1,0,0]]
#
# P^-_mu = (1 - gamma_mu)/2 projects a 4-spinor to an effective 2-spinor
# (rows 2,3 are +-(i) linear combinations of rows 0,1); "project" returns
# the upper two spin components h[0:2], "reconstruct" rebuilds all four.
# ---------------------------------------------------------------------------

def _sp(psi: Pair, s: int) -> Pair:
    return psi[0][s], psi[1][s]


def project_minus(psi: Pair, mu: int) -> Pair:
    """h = upper two spin rows of (1 - gamma_mu) psi. psi: (4,3,...)."""
    p0, p1, p2, p3 = (_sp(psi, s) for s in range(4))
    if mu == 0:  # x: h0 = p0 - i p3, h1 = p1 - i p2
        h0 = csub(p0, ci_mul(p3))
        h1 = csub(p1, ci_mul(p2))
    elif mu == 1:  # y: h0 = p0 + p3, h1 = p1 - p2
        h0 = cadd(p0, p3)
        h1 = csub(p1, p2)
    elif mu == 2:  # z: h0 = p0 - i p2, h1 = p1 + i p3
        h0 = csub(p0, ci_mul(p2))
        h1 = cadd(p1, ci_mul(p3))
    else:  # t: h0 = p0 - p2, h1 = p1 - p3
        h0 = csub(p0, p2)
        h1 = csub(p1, p3)
    return (
        jnp.stack([h0[0], h1[0]]),
        jnp.stack([h0[1], h1[1]]),
    )


def project_plus(psi: Pair, mu: int) -> Pair:
    """h = upper two spin rows of (1 + gamma_mu) psi."""
    p0, p1, p2, p3 = (_sp(psi, s) for s in range(4))
    if mu == 0:
        h0 = cadd(p0, ci_mul(p3))
        h1 = cadd(p1, ci_mul(p2))
    elif mu == 1:
        h0 = csub(p0, p3)
        h1 = cadd(p1, p2)
    elif mu == 2:
        h0 = cadd(p0, ci_mul(p2))
        h1 = csub(p1, ci_mul(p3))
    else:
        h0 = cadd(p0, p2)
        h1 = cadd(p1, p3)
    return (
        jnp.stack([h0[0], h1[0]]),
        jnp.stack([h0[1], h1[1]]),
    )


def reconstruct_minus(h: Pair, mu: int) -> Pair:
    """Rebuild the 4-spinor (1 - gamma_mu) psi from its half-spinor h."""
    h0 = (h[0][0], h[1][0])
    h1 = (h[0][1], h[1][1])
    if mu == 0:  # p2 = i h1, p3 = i h0
        p2, p3 = ci_mul(h1), ci_mul(h0)
    elif mu == 1:  # p2 = -h1, p3 = h0
        p2, p3 = cscale(h1, -1.0), h0
    elif mu == 2:  # p2 = i h0, p3 = -i h1
        p2, p3 = ci_mul(h0), cneg_i_mul(h1)
    else:  # t: p2 = -h0, p3 = -h1
        p2, p3 = cscale(h0, -1.0), cscale(h1, -1.0)
    return (
        jnp.stack([h0[0], h1[0], p2[0], p3[0]]),
        jnp.stack([h0[1], h1[1], p2[1], p3[1]]),
    )


def reconstruct_plus(h: Pair, mu: int) -> Pair:
    """Rebuild the 4-spinor (1 + gamma_mu) psi from its half-spinor h."""
    h0 = (h[0][0], h[1][0])
    h1 = (h[0][1], h[1][1])
    if mu == 0:
        p2, p3 = cneg_i_mul(h1), cneg_i_mul(h0)
    elif mu == 1:
        p2, p3 = h1, cscale(h0, -1.0)
    elif mu == 2:
        p2, p3 = cneg_i_mul(h0), ci_mul(h1)
    else:
        p2, p3 = h0, h1
    return (
        jnp.stack([h0[0], h1[0], p2[0], p3[0]]),
        jnp.stack([h0[1], h1[1], p2[1], p3[1]]),
    )


# ---------------------------------------------------------------------------
# dense gamma matrices (oracle checks in tests)
# ---------------------------------------------------------------------------

def gamma_dense(mu: int) -> np.ndarray:
    i = 1j
    g = {
        0: np.array(
            [[0, 0, 0, i], [0, 0, i, 0], [0, -i, 0, 0], [-i, 0, 0, 0]]
        ),
        1: np.array(
            [[0, 0, 0, -1], [0, 0, 1, 0], [0, 1, 0, 0], [-1, 0, 0, 0]]
        ),
        2: np.array(
            [[0, 0, i, 0], [0, 0, 0, -i], [-i, 0, 0, 0], [0, i, 0, 0]]
        ),
        3: np.array(
            [[0, 0, 1, 0], [0, 0, 0, 1], [1, 0, 0, 0], [0, 1, 0, 0]]
        ),
    }[mu]
    return g.astype(np.complex128)
