"""Shared numerical building blocks (lattice models, SU(3)/Dirac algebra)."""
