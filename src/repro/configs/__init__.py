"""Architecture registry: --arch <id> -> ArchConfig (FULL and SMOKE)."""

from __future__ import annotations

from . import (
    arctic_480b,
    deepseek_67b,
    granite_3_2b,
    hymba_1_5b,
    olmo_1b,
    qwen2_vl_7b,
    qwen3_moe_30b_a3b,
    rwkv6_7b,
    seamless_m4t_medium,
    starcoder2_7b,
)
from .base import ArchConfig, LM_SHAPES, ShapeCfg, get_shape, shape_supported  # noqa: F401

_MODULES = {
    "qwen2-vl-7b": qwen2_vl_7b,
    "granite-3-2b": granite_3_2b,
    "starcoder2-7b": starcoder2_7b,
    "olmo-1b": olmo_1b,
    "deepseek-67b": deepseek_67b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "arctic-480b": arctic_480b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "hymba-1.5b": hymba_1_5b,
    "rwkv6-7b": rwkv6_7b,
}

ARCH_IDS = tuple(_MODULES)


def get_arch(arch_id: str, *, smoke: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = _MODULES[arch_id]
    return mod.SMOKE if smoke else mod.FULL
