"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, qk-norm
[hf:Qwen/Qwen3-30B-A3B; hf]."""

from .base import ArchConfig, MoECfg

FULL = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=768,  # per-expert ff
    vocab=151936,
    norm="rmsnorm",
    act="silu",
    qk_norm=True,
    moe=MoECfg(n_experts=128, top_k=8, d_ff_expert=768, capacity_factor=1.25),
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=96,
    vocab=256,
    qk_norm=True,
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=96, capacity_factor=1.5),
    tie_embeddings=False,
)
