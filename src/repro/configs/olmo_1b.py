"""olmo-1b [dense] — non-parametric LN [arXiv:2402.00838; hf]."""

from .base import ArchConfig

FULL = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,   # MHA (kv == heads)
    d_ff=8192,
    vocab=50304,
    norm="nonparam_ln",
    act="silu",
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="olmo-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    norm="nonparam_ln",
    act="silu",
    tie_embeddings=True,
)
