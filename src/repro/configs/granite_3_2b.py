"""granite-3-2b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""

from .base import ArchConfig

FULL = ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="granite-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    tie_embeddings=True,
)
