"""deepseek-67b [dense] — llama-arch, 95 layers [arXiv:2401.02954; hf]."""

from .base import ArchConfig

FULL = ArchConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="deepseek-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    tie_embeddings=False,
)
