"""hymba-1.5b [hybrid] — parallel attn+mamba heads, meta tokens, SWA+global
mix [arXiv:2411.13676; hf].  Sub-quadratic: runs long_500k."""

from .base import ArchConfig, HybridCfg, SSMCfg

FULL = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    norm="rmsnorm",
    act="silu",
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2),
    hybrid=HybridCfg(swa_window=1024, meta_tokens=128),
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="hymba-smoke",
    family="hybrid",
    n_layers=4,  # layers {0, 2, 3} global, layer 1 SWA: both paths exercised
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    ssm=SSMCfg(d_state=4, d_conv=4, expand=2),
    hybrid=HybridCfg(swa_window=8, meta_tokens=4),
    tie_embeddings=True,
    subquadratic=True,
)
