"""Architecture configuration schema shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_d_ff: int = 0          # arctic: parallel dense-residual MLP width


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    swa_window: int = 1024
    global_every: int = 8        # every k-th layer uses global attention
    meta_tokens: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"            # silu | gelu
    mlp_gated: bool = True       # False: plain 2-matrix MLP (starcoder2, seamless)
    qk_norm: bool = False        # qwen3
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid: Optional[HybridCfg] = None
    enc_dec: bool = False
    n_enc_layers: int = 0
    attn_free: bool = False      # rwkv6
    tie_embeddings: bool = True
    dtype: object = jnp.bfloat16
    # shape-support metadata
    subquadratic: bool = False   # supports long_500k
    has_decoder: bool = True

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab padded to a multiple of 256 (standard
        MaxText-style padding: keeps the vocab dim TP-shardable for odd
        tokenizer sizes like 49155/256206/32001)."""
        return ((self.vocab + 255) // 256) * 256

    def param_count(self) -> int:
        """Approximate total parameters (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        if not self.attn_free:
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        else:
            attn = 6 * d * d  # rwkv time-mix r,k,v,g,o + decay
        if self.moe:
            ff = self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
            ff += 3 * d * self.moe.dense_d_ff
        else:
            ff = 3 * d * self.d_ff
        if self.ssm is not None and self.hybrid is not None:
            di = self.ssm.expand * d
            ff_ssm = d * di * 2 + di * d + di * (2 * self.ssm.d_state + 1)
            attn += ff_ssm
        blocks = L * (attn + ff)
        if self.enc_dec:
            blocks += self.n_enc_layers * (attn + ff) + L * attn  # cross-attn
        return int(n + blocks)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        ff_all = L * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        ff_act = L * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return int(full - ff_all + ff_act)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeCfg("train_4k", 4096, 256, "train"),
    ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    ShapeCfg("decode_32k", 32768, 128, "decode"),
    ShapeCfg("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeCfg:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def shape_supported(arch: "ArchConfig", shape: ShapeCfg) -> Tuple[bool, str]:
    """(supported, reason-if-not). long_500k needs sub-quadratic attention;
    decode shapes need a decoder."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "pure full-attention arch: 524k-token decode requires sub-quadratic attention (DESIGN.md §Arch-applicability)"
    if shape.kind == "decode" and not arch.has_decoder:
        return False, "encoder-only arch has no decode step"
    return True, ""
