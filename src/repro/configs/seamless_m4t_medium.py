"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

Backbone only: the speech frontend is a stub (input_specs provides
precomputed frame embeddings to the encoder)."""

from .base import ArchConfig

FULL = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,          # decoder layers
    n_enc_layers=12,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="seamless-smoke",
    family="audio",
    n_layers=2,
    n_enc_layers=2,
    enc_dec=True,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
)
