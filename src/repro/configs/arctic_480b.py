"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

Arctic's dense-MoE hybrid: a dense residual MLP runs in parallel with the
routed experts in every block.  At 480B parameters this is the memory
stress case: the launcher selects 8-bit optimizer moments for it."""

from .base import ArchConfig, MoECfg

FULL = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    norm="rmsnorm",
    act="silu",
    moe=MoECfg(n_experts=128, top_k=2, d_ff_expert=4864,
               capacity_factor=1.25, dense_d_ff=4864),
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=256,
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=96, capacity_factor=1.5,
               dense_d_ff=96),
    tie_embeddings=False,
)
