"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only; the vision frontend is a stub (input_specs provides
precomputed patch embeddings spliced into the token stream)."""

from .base import ArchConfig

FULL = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    norm="rmsnorm",
    act="silu",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),  # t/h/w splits of head_dim/2 = 64
    tie_embeddings=False,
)

SMOKE = ArchConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    d_head=16,
    norm="rmsnorm",
    act="silu",
    mrope_sections=(4, 2, 2),
    tie_embeddings=False,
)
