"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf].  Sub-quadratic: runs long_500k; the WKV recurrence
is the flagship pallas kernel (repro.kernels.rwkv6_scan)."""

from .base import ArchConfig

FULL = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_head=64,            # wkv head size
    d_ff=14336,
    vocab=65536,
    norm="layernorm",
    act="silu",
    attn_free=True,
    tie_embeddings=False,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="rwkv6-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_head=16,
    d_ff=128,
    vocab=256,
    norm="layernorm",
    attn_free=True,
    tie_embeddings=False,
    subquadratic=True,
)
