"""starcoder2-7b [dense] — GQA, RoPE [arXiv:2402.19173; hf].

LayerNorm + plain (non-gated) GELU MLP per the StarCoder2 architecture."""

from .base import ArchConfig

FULL = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="starcoder2-smoke",
    family="dense",
    n_layers=2,
    d_model=72,
    n_heads=6,
    n_kv_heads=2,
    d_ff=144,
    vocab=256,
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
)
