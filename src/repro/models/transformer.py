"""Decoder-only LM assembly for all families (dense/moe/vlm/hybrid/ssm).

Layers are stacked on a leading axis and iterated with jax.lax.scan +
jax.checkpoint (activation rematerialization): compile time and HLO size
are O(1) in depth — deepseek-67b's 95 layers lower as one loop body.
Per-layer heterogeneity (hymba's global-vs-SWA attention) rides through
the scan as a scanned (L,) window array, keeping a single traced block.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.train.sharding import lconstraint
from . import attention as attn
from repro import probe, tuning
from . import layers, mamba, moe, rwkv6


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    d, dt = cfg.d_model, cfg.dtype
    p = {"norm1": layers.init_norm(ks[0], d, cfg.norm, dt),
         "norm2": layers.init_norm(ks[1], d, cfg.norm, dt)}
    if cfg.attn_free:
        blk = rwkv6.init_rwkv_block(ks[2], d, cfg.d_ff, cfg.head_dim, dt)
        p["rwkv"] = blk
        return p
    p["attn"] = attn.init_attn(
        ks[2], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt, cfg.qk_norm
    )
    if cfg.hybrid is not None:
        p["ssm"] = mamba.init_ssm(ks[3], d, cfg.ssm, dt)
    if cfg.moe is not None:
        p["moe"] = moe.init_moe(ks[4], d, cfg.moe, dt)
    else:
        p["mlp"] = layers.init_mlp(ks[5], d, cfg.d_ff, dt, cfg.mlp_gated)
    return p


def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """Per-layer attention window (-1 = global).  Hymba: global attention on
    the first, middle and last layers, SWA elsewhere."""
    L = cfg.n_layers
    w = np.full((L,), -1, np.int32)
    if cfg.hybrid is not None:
        w[:] = cfg.hybrid.swa_window
        for g in {0, L // 2, L - 1}:
            w[g] = -1
    return w


def init_lm(cfg: ArchConfig, key):
    ks = jax.random.split(key, cfg.n_layers + 3)
    blocks = [_init_block(ks[i], cfg) for i in range(cfg.n_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    p = {
        "tok": layers.init_embed(ks[-1], cfg.padded_vocab, cfg.d_model,
                                 cfg.dtype, cfg.tie_embeddings),
        "layers": stacked,
        "norm_f": layers.init_norm(ks[-2], cfg.d_model, cfg.norm, cfg.dtype),
    }
    if cfg.hybrid is not None and cfg.hybrid.meta_tokens:
        p["meta"] = 0.02 * jax.random.normal(
            ks[-3], (cfg.hybrid.meta_tokens, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_forward(bp, x, cos, sin, window, cfg: ArchConfig, wkv_engine: str):
    """One block, full sequence.  Returns (x_out, aux, cache_seed)."""
    aux = {}
    h = layers.apply_norm(bp["norm1"], x, cfg.norm)
    if cfg.attn_free:
        B = x.shape[0]
        x_prev0 = jnp.zeros((B, cfg.d_model), x.dtype)
        wkv0 = None
        o, _, wkvT = rwkv6.time_mix(bp["rwkv"]["tmix"], h, x_prev0, wkv0,
                                    cfg.head_dim, engine=wkv_engine)
        x = x + o
        h2 = layers.apply_norm(bp["norm2"], x, cfg.norm)
        o2, _ = rwkv6.channel_mix(bp["rwkv"]["cmix"], h2, x_prev0)
        x = x + o2
        return x, aux, {}

    ao, (k_seed, v_seed) = attn.attention(
        bp["attn"], h, cos, sin,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        causal=True, window=window, qk_norm=cfg.qk_norm,
    )
    if cfg.hybrid is not None:
        so, _ = mamba.apply_ssm(bp["ssm"], h, cfg.ssm)
        ao = 0.5 * (ao + so)
    x = x + ao
    h2 = layers.apply_norm(bp["norm2"], x, cfg.norm)
    if cfg.moe is not None:
        mo, moe_aux = moe.apply_moe(bp["moe"], h2, cfg.moe, act=cfg.act)
        aux.update(moe_aux)
    else:
        mo = layers.apply_mlp(bp["mlp"], h2, cfg.act, cfg.mlp_gated)
    x = x + mo
    return x, aux, {"k": k_seed, "v": v_seed}


def lm_forward(params, cfg: ArchConfig, batch: Dict, *,
               wkv_engine: str = "jnp", collect_cache: bool = False):
    """batch: tokens (B, S) [+ image_embeds, positions].  Returns
    (logits (B, S, vocab), aux)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = layers.embed_tokens(params["tok"], tokens).astype(cfg.dtype)

    if cfg.family == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(cfg.dtype)     # (B, n_img, d)
        n_img = img.shape[1]
        x = jnp.concatenate([img, x[:, n_img:]], axis=1)  # stub frontend splice

    n_meta = 0
    if cfg.hybrid is not None and "meta" in params:
        n_meta = params["meta"].shape[0]
        meta = jnp.broadcast_to(params["meta"][None], (B, n_meta, cfg.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)

    x = lconstraint(x, "batch", "seq", "embed")
    S_tot = x.shape[1]

    if cfg.attn_free:
        cos = sin = None
    else:
        if cfg.mrope_sections is not None and "positions" in batch:
            pos = batch["positions"]                      # (3, B, S)
            if n_meta:
                ext = jnp.broadcast_to(jnp.arange(n_meta)[None, None], (3, B, n_meta))
                pos = jnp.concatenate([ext, pos + n_meta], axis=-1)
            cos, sin = attn.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta,
                                         cfg.mrope_sections)
        else:
            pos = jnp.broadcast_to(jnp.arange(S_tot)[None], (B, S_tot))
            cos, sin = attn.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    windows = jnp.asarray(layer_windows(cfg))

    def body(x, scanned):
        bp, window = scanned
        x_out, aux, _ = _block_forward(bp, x, cos, sin, window, cfg, wkv_engine)
        lb = aux.get("lb_loss", jnp.float32(0.0))
        return x_out, lb

    body = tuning.checkpoint_wrap(body)
    x, lbs = jax.lax.scan(body, x, (params["layers"], windows),
                          unroll=probe.scan_unroll())

    if n_meta:
        x = x[:, n_meta:]
    x = layers.apply_norm(params["norm_f"], x, cfg.norm)
    logits = layers.lm_logits(params["tok"], x, cfg.tie_embeddings)
    # constraining the primal also constrains the cotangent: without this
    # the lm-head/embedding gradient chain materializes fp32 REPLICATED
    # (measured +30 GiB/device on deepseek-67b train_4k)
    logits = lconstraint(logits, "batch", "seq", "logits_vocab")
    return logits, {"lb_loss": jnp.sum(lbs)}


# ---------------------------------------------------------------------------
# decode (single token against a cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None):
    """Zeroed cache pytree (eval_shape-friendly)."""
    dtype = dtype or cfg.dtype
    L, B = cfg.n_layers, batch
    c: Dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.attn_free:
        H = cfg.d_model // cfg.head_dim
        c["att_xprev"] = jnp.zeros((L, B, cfg.d_model), dtype)
        c["ffn_xprev"] = jnp.zeros((L, B, cfg.d_model), dtype)
        c["wkv"] = jnp.zeros((L, B, H, cfg.head_dim, cfg.head_dim), jnp.float32)
        return c
    c["k"] = jnp.zeros((L, B, s_max, cfg.n_kv_heads, cfg.head_dim), dtype)
    c["v"] = jnp.zeros((L, B, s_max, cfg.n_kv_heads, cfg.head_dim), dtype)
    if cfg.hybrid is not None:
        di = cfg.ssm.expand * cfg.d_model
        c["ssm_h"] = jnp.zeros((L, B, di, cfg.ssm.d_state), jnp.float32)
        c["conv"] = jnp.zeros((L, B, cfg.ssm.d_conv - 1, di), dtype)
    return c


def lm_decode_step(params, cfg: ArchConfig, cache: Dict, tokens):
    """tokens: (B,) int32 — one new token per sequence.
    Returns (logits (B, vocab), new cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = layers.embed_tokens(params["tok"], tokens)[:, None, :].astype(cfg.dtype)

    if cfg.attn_free:
        cos1 = sin1 = None
    else:
        p1 = jnp.broadcast_to(pos[None, None], (B, 1))
        if cfg.mrope_sections is not None:
            p3 = jnp.broadcast_to(pos[None, None, None], (3, B, 1))
            cos1, sin1 = attn.rope_cos_sin(p3, cfg.head_dim, cfg.rope_theta,
                                           cfg.mrope_sections)
        else:
            cos1, sin1 = attn.rope_cos_sin(p1, cfg.head_dim, cfg.rope_theta)

    windows = jnp.asarray(layer_windows(cfg))

    if cfg.attn_free:
        def body(x, scanned):
            bp, axp, fxp, wkv = scanned
            h = layers.apply_norm(bp["norm1"], x[:, 0], cfg.norm)
            o, axp2, wkv2 = rwkv6.time_mix_decode(bp["rwkv"]["tmix"], h, axp,
                                                  wkv, cfg.head_dim)
            x = x + o[:, None]
            h2 = layers.apply_norm(bp["norm2"], x[:, 0], cfg.norm)
            o2, fxp2 = rwkv6.channel_mix_decode(bp["rwkv"]["cmix"], h2, fxp)
            x = x + o2[:, None]
            return x, (axp2.astype(cache["att_xprev"].dtype),
                       fxp2.astype(cache["ffn_xprev"].dtype), wkv2)

        x, (axp, fxp, wkv) = jax.lax.scan(
            body, x, (params["layers"], cache["att_xprev"],
                      cache["ffn_xprev"], cache["wkv"]),
            unroll=probe.scan_unroll(),
        )
        new_cache = dict(cache, att_xprev=axp, ffn_xprev=fxp, wkv=wkv,
                         pos=pos + 1)
    else:
        def body(x, scanned):
            if cfg.hybrid is not None:
                bp, window, ck, cv, hssm, conv = scanned
            else:
                bp, window, ck, cv = scanned
            h = layers.apply_norm(bp["norm1"], x, cfg.norm)
            ao, ck2, cv2 = attn.decode_attention(
                bp["attn"], h, ck, cv, pos, cos1, sin1,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, window=window, qk_norm=cfg.qk_norm,
            )
            extra = ()
            if cfg.hybrid is not None:
                so, (h2s, conv2) = mamba.decode_ssm(bp["ssm"], h, cfg.ssm,
                                                    hssm, conv)
                ao = 0.5 * (ao + so)
                extra = (h2s, conv2.astype(conv.dtype))
            x = x + ao
            hh = layers.apply_norm(bp["norm2"], x, cfg.norm)
            if cfg.moe is not None:
                mo, _ = moe.apply_moe(bp["moe"], hh, cfg.moe, act=cfg.act)
            else:
                mo = layers.apply_mlp(bp["mlp"], hh, cfg.act, cfg.mlp_gated)
            x = x + mo
            return x, (ck2, cv2) + extra

        if cfg.hybrid is not None:
            xs = (params["layers"], windows, cache["k"], cache["v"],
                  cache["ssm_h"], cache["conv"])
            x, (k2, v2, h2, c2) = jax.lax.scan(body, x, xs, unroll=probe.scan_unroll())
            new_cache = dict(cache, k=k2, v=v2, ssm_h=h2, conv=c2, pos=pos + 1)
        else:
            xs = (params["layers"], windows, cache["k"], cache["v"])
            x, (k2, v2) = jax.lax.scan(body, x, xs, unroll=probe.scan_unroll())
            new_cache = dict(cache, k=k2, v=v2, pos=pos + 1)

    x = layers.apply_norm(params["norm_f"], x[:, 0], cfg.norm)
    logits = layers.lm_logits(params["tok"], x, cfg.tie_embeddings)
    return logits, new_cache
