"""LM model zoo for the assigned architectures, built on the substrate.

All models are functional JAX: ``init_params(cfg, key)`` -> pytree;
forward passes are pure functions with logical-axis sharding annotations
(repro.train.sharding).  Layers are stacked (leading n_layers axis) and
iterated with jax.lax.scan for O(1)-in-depth compile time.
"""

from .model_factory import init_params, forward, decode_step, init_cache  # noqa: F401
