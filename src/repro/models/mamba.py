"""Mamba-style selective SSM (Hymba's parallel SSM heads).

Diagonal selective state space: per channel c, state s:
    h_t[c,s] = exp(dt_t[c] A[c,s]) h_{t-1}[c,s] + dt_t[c] B_t[s] x_t[c]
    y_t[c]   = sum_s C_t[s] h_t[c,s] + D[c] x_t[c]
with dt_t = softplus(proj(x) + dt_bias), A = -exp(a_log), and a depthwise
causal conv front-end.  Sequence processing is a lax.scan carrying
(B, d_inner, d_state) — O(1) memory in T and a single HLO loop body (the
Pallas chunked variant is the rwkv6_scan pattern; see DESIGN.md perf
notes).  Decode is the same update for a single step.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from . import layers


def init_ssm(key, d_model: int, cfg: SSMCfg, dtype):
    di = cfg.expand * d_model
    ks = jax.random.split(key, 7)
    return {
        "w_in": layers.dense_init(ks[0], (d_model, 2 * di), dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32).astype(dtype),
        "w_bc": layers.dense_init(ks[2], (di, 2 * cfg.d_state), dtype),
        "w_dt": layers.dense_init(ks[3], (di, 1), dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (di, cfg.d_state))
        ),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": layers.dense_init(ks[5], (di, d_model), dtype, fan_in=di),
    }


def _conv_causal(xc, conv_w, conv_state=None):
    """Depthwise causal conv. xc: (B, T, di); conv_w: (K, di).
    conv_state: (B, K-1, di) carried inputs for decode."""
    K = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xc.shape[0], K - 1, xc.shape[2]), xc.dtype)
    else:
        pad = conv_state.astype(xc.dtype)
    xp = jnp.concatenate([pad, xc], axis=1)  # (B, T+K-1, di)
    out = 0.0
    for i in range(K):
        out = out + xp[:, i : i + xc.shape[1]] * conv_w[i][None, None, :]
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out, new_state


def _ssm_step(h, inputs):
    """h: (B, di, ds); inputs: per-step tensors."""
    da, dbx, c_t = inputs  # (B, di, ds), (B, di, ds), (B, ds)
    h = jnp.exp(da) * h + dbx
    y = jnp.einsum("bds,bs->bd", h, c_t)
    return h, y


def apply_ssm(p, x, cfg: SSMCfg, h0=None, conv_state=None):
    """x: (B, T, d_model) -> (B, T, d_model), (hT, conv_stateT)."""
    B, T, d = x.shape
    di = cfg.expand * d
    xz = x @ p["w_in"]
    xc, z = xz[..., :di], xz[..., di:]
    xc, conv_state_new = _conv_causal(xc, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)

    bc = xc @ p["w_bc"]                                      # (B, T, 2*ds)
    b_t, c_t = bc[..., : cfg.d_state], bc[..., cfg.d_state :]
    dt = jax.nn.softplus(
        (xc @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"][None, None]
    )                                                        # (B, T, di)
    A = -jnp.exp(p["a_log"])                                 # (di, ds)

    da = dt[..., None] * A[None, None]                       # (B, T, di, ds)
    # (B, T, di, ds) = (dt * x) (B,T,di) outer B_t (B,T,ds)
    dbx = (dt * xc.astype(jnp.float32))[..., :, None] * b_t.astype(jnp.float32)[..., None, :]

    if h0 is None:
        h0 = jnp.zeros((B, di, cfg.d_state), jnp.float32)
    xs = (
        jnp.moveaxis(da, 1, 0),
        jnp.moveaxis(dbx, 1, 0),
        jnp.moveaxis(c_t.astype(jnp.float32), 1, 0),
    )
    hT, ys = jax.lax.scan(_ssm_step, h0, xs)                 # ys: (T, B, di)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    y = y + xc * p["d_skip"].astype(x.dtype)[None, None]
    y = y * jax.nn.silu(z)
    return y @ p["w_out"], (hT, conv_state_new)


def decode_ssm(p, x1, cfg: SSMCfg, h, conv_state):
    """Single-token decode. x1: (B, 1, d); h: (B, di, ds);
    conv_state: (B, K-1, di)."""
    out, (hT, conv_new) = apply_ssm(p, x1, cfg, h0=h, conv_state=conv_state)
    return out, (hT, conv_new)
