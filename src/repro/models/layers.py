"""Shared layer primitives: norms, MLPs, embeddings, initializers."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.train.sharding import lconstraint


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in or shape[0]
    std = 1.0 / math.sqrt(fan)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# -- norms ---------------------------------------------------------------------

def rmsnorm(x, scale):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + 1e-6)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def nonparam_ln(x):
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)


def init_norm(key, d, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparam_ln":
        return {}
    raise ValueError(kind)


def apply_norm(params, x, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    if kind == "nonparam_ln":
        return nonparam_ln(x)
    raise ValueError(kind)


# -- MLP -------------------------------------------------------------------------

def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def init_mlp(key, d_model, d_ff, dtype, gated: bool):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype, fan_in=d_ff),
    }
    if gated:
        p["w_gate"] = dense_init(ks[0], (d_model, d_ff), dtype)
    return p


def apply_mlp(p, x, act: str, gated: bool):
    up = x @ p["w_up"]
    if gated:
        h = _act(x @ p["w_gate"], act) * up
    else:
        h = _act(up, act)
    h = lconstraint(h, "batch", "seq", "mlp")
    return h @ p["w_down"]


# -- embeddings -------------------------------------------------------------------

def init_embed(key, vocab, d_model, dtype, tie: bool):
    ks = jax.random.split(key, 2)
    p = {"embed": dense_init(ks[0], (vocab, d_model), dtype, fan_in=d_model)}
    if not tie:
        p["lm_head"] = dense_init(ks[1], (d_model, vocab), dtype)
    return p


def embed_tokens(p, tokens):
    return jnp.take(p["embed"], tokens, axis=0)


def lm_logits(p, x, tie: bool):
    if tie:
        return x @ p["embed"].T
    return x @ p["lm_head"]
