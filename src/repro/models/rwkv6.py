"""RWKV6 ("Finch") block: data-dependent-decay time mix + channel mix.

The WKV recurrence runs through repro.kernels.rwkv6_scan (pallas on TPU,
chunked jnp otherwise) — the LM-side instance of the paper's pattern: one
kernel source, engine selected by configuration.

Time-mix (per head, dk = dv = head size):
    token-shift interpolation with learned mu per r/k/v/w/g
    decay  w_t = exp(-exp(w0 + tanh(x_t A_w) B_w))   (LoRA-style, bounded)
    o_t    = wkv(r, k, v, w, u)  ->  per-head groupnorm -> * silu(g) -> W_o
Channel-mix: r = sigmoid(xr W_r); out = r * (relu(xk W_k)^2 W_v).
Decode state per layer: (x_prev_att, x_prev_ffn, wkv state (H, dk, dv)).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan import rwkv6 as wkv_op
from repro.kernels.rwkv6_scan import rwkv6_decode_step as wkv_decode
from . import layers

LORA_R = 64


def init_rwkv_block(key, d_model: int, d_ff: int, head_dim: int, dtype):
    H = d_model // head_dim
    ks = jax.random.split(key, 12)
    tmix = {
        "mu": 0.5 * jnp.ones((5, d_model), jnp.float32),  # r,k,v,w,g
        "w_r": layers.dense_init(ks[0], (d_model, d_model), dtype),
        "w_k": layers.dense_init(ks[1], (d_model, d_model), dtype),
        "w_v": layers.dense_init(ks[2], (d_model, d_model), dtype),
        "w_g": layers.dense_init(ks[3], (d_model, d_model), dtype),
        "w_o": layers.dense_init(ks[4], (d_model, d_model), dtype),
        "decay_w0": -6.0 * jnp.ones((d_model,), jnp.float32),
        "decay_a": layers.dense_init(ks[5], (d_model, LORA_R), dtype),
        "decay_b": layers.dense_init(ks[6], (LORA_R, d_model), dtype),
        "bonus": jnp.zeros((H, head_dim), jnp.float32),
        "ln_scale": jnp.ones((d_model,), dtype),  # output groupnorm scale
    }
    cmix = {
        "mu": 0.5 * jnp.ones((2, d_model), jnp.float32),  # r,k
        "w_r": layers.dense_init(ks[7], (d_model, d_model), dtype),
        "w_k": layers.dense_init(ks[8], (d_model, d_ff), dtype),
        "w_v": layers.dense_init(ks[9], (d_ff, d_model), dtype, fan_in=d_ff),
    }
    return {"tmix": tmix, "cmix": cmix}


def _token_shift(x, x_prev):
    """x: (B, T, d); x_prev: (B, d) last token of previous segment.
    Returns (xx = shifted x, new x_prev)."""
    xx = jnp.concatenate([x_prev[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    return xx, x[:, -1, :]


def _heads(x, H, hd):
    B, T, _ = x.shape
    return x.reshape(B, T, H, hd).transpose(0, 2, 1, 3)  # (B, H, T, hd)


def _unheads(x):
    B, H, T, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, T, H * hd)


def _group_norm(x, scale, H, hd):
    """Per-head layer norm on (B, T, d)."""
    B, T, d = x.shape
    xh = x.reshape(B, T, H, hd).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (xh.reshape(B, T, d) * scale.astype(jnp.float32)).astype(x.dtype)


def time_mix(p, x, x_prev, wkv_state, head_dim: int, engine: str = "jnp"):
    """x: (B, T, d).  Returns (out, new_x_prev, new_wkv_state)."""
    B, T, d = x.shape
    H = d // head_dim
    xx, x_last = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    xr = x + (xx - x) * mu[0]
    xk = x + (xx - x) * mu[1]
    xv = x + (xx - x) * mu[2]
    xw = x + (xx - x) * mu[3]
    xg = x + (xx - x) * mu[4]

    r = _heads(xr @ p["w_r"], H, head_dim)
    k = _heads(xk @ p["w_k"], H, head_dim)
    v = _heads(xv @ p["w_v"], H, head_dim)
    g = xg @ p["w_g"]

    # bounded data-dependent decay
    lora = jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]        # (B, T, d)
    wlog = -jnp.exp(
        jnp.clip(p["decay_w0"][None, None].astype(jnp.float32)
                 + lora.astype(jnp.float32), -8.0, 1.0)
    )
    w = _heads(jnp.exp(wlog).astype(x.dtype), H, head_dim)   # decay in (0,1)

    u = p["bonus"].astype(jnp.float32)
    from repro import tuning as _tuning
    o, sT = wkv_op(r, k, v, w, u, wkv_state, engine=engine,
                   chunk=_tuning.get().rwkv_chunk)
    o = _unheads(o)
    o = _group_norm(o, p["ln_scale"], H, head_dim)
    out = (o * jax.nn.silu(g)) @ p["w_o"]
    return out, x_last, sT


def channel_mix(p, x, x_prev):
    xx, x_last = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    xr = x + (xx - x) * mu[0]
    xk = x + (xx - x) * mu[1]
    r = jax.nn.sigmoid(xr @ p["w_r"])
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return r * (k @ p["w_v"]), x_last


def time_mix_decode(p, x1, x_prev, wkv_state, head_dim: int):
    """Single token: x1 (B, d)."""
    B, d = x1.shape
    H = d // head_dim
    mu = p["mu"].astype(x1.dtype)
    xx = x_prev.astype(x1.dtype)
    xr = x1 + (xx - x1) * mu[0]
    xk = x1 + (xx - x1) * mu[1]
    xv = x1 + (xx - x1) * mu[2]
    xw = x1 + (xx - x1) * mu[3]
    xg = x1 + (xx - x1) * mu[4]
    hshape = lambda t: t.reshape(B, H, head_dim)
    r = hshape(xr @ p["w_r"])
    k = hshape(xk @ p["w_k"])
    v = hshape(xv @ p["w_v"])
    g = xg @ p["w_g"]
    lora = jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
    wlog = -jnp.exp(jnp.clip(p["decay_w0"].astype(jnp.float32)
                             + lora.astype(jnp.float32), -8.0, 1.0))
    w = hshape(jnp.exp(wlog).astype(x1.dtype))
    u = p["bonus"].astype(jnp.float32)
    o, sT = wkv_decode(r, k, v, w, u, wkv_state)
    o = o.reshape(B, d)
    o = _group_norm(o[:, None, :], p["ln_scale"], H, head_dim)[:, 0]
    out = (o * jax.nn.silu(g)) @ p["w_o"]
    return out, x1, sT


def channel_mix_decode(p, x1, x_prev):
    mu = p["mu"].astype(x1.dtype)
    xx = x_prev.astype(x1.dtype)
    xr = x1 + (xx - x1) * mu[0]
    xk = x1 + (xx - x1) * mu[1]
    r = jax.nn.sigmoid(xr @ p["w_r"])
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return r * (k @ p["w_v"]), x1
