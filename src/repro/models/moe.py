"""Mixture-of-Experts layer: top-k routing, capacity dropping, sort-based
dispatch (EP-shardable), optional parallel dense-residual MLP (Arctic).

Dispatch is argsort-based rather than dense one-hot einsum: a (T, E, C)
dispatch tensor at production token counts is O(10^13) elements, whereas
sort+gather is O(T k log(T k)) and lowers to TPU-friendly bitonic sorts.
Expert compute is a single batched einsum over the (E, C, d) buffer, so
HLO FLOPs stay ~ capacity_factor x active-parameter FLOPs (important for
the MODEL_FLOPS / HLO_FLOPs ratio in the roofline report).

Sharding: experts ride the "model" mesh axis (expert parallelism); the
gather/scatter across the token<->expert boundary is GSPMD-scheduled
(all-to-all on ICI); the router stays replicated.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg
from repro.train.sharding import lconstraint
from . import layers


def init_moe(key, d_model: int, cfg: MoECfg, dtype):
    ks = jax.random.split(key, 5)
    E, ff = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": layers.dense_init(ks[0], (d_model, E), jnp.float32),
        "experts": {
            "w_gate": layers.dense_init(ks[1], (E, d_model, ff), dtype),
            "w_up": layers.dense_init(ks[2], (E, d_model, ff), dtype),
            "w_down": layers.dense_init(ks[3], (E, ff, d_model), dtype, fan_in=ff),
        },
    }
    if cfg.dense_d_ff:
        p["mlp"] = layers.init_mlp(ks[4], d_model, cfg.dense_d_ff, dtype, gated=True)
    return p


def apply_moe(p, x, cfg: MoECfg, act: str = "silu", router_noise_key=None):
    """x: (B, S, d) -> (B, S, d) plus aux losses dict.

    Dispatch is per batch-row GROUP (t5x-style): the sort/capacity logic is
    vmapped over B, so every dispatch tensor keeps a leading batch axis that
    rides the data sharding — a single global argsort over B*S*k entries is
    an inherently unsharded shuffle (measured ~290 GiB/device at arctic
    train_4k).  Capacity is per group."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    Cg = max(1, int(cfg.capacity_factor * S * k / E))

    def dispatch_one(xg, probs_g):
        """xg: (S, d); probs_g: (S, E) -> (y (S, d), counts (E,), drop)."""
        gate, expert_idx = jax.lax.top_k(probs_g, k)            # (S, k)
        gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)
        tok_ids = jnp.repeat(jnp.arange(S), k)
        e_flat = expert_idx.reshape(-1)
        g_flat = gate.reshape(-1)
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        tok_sorted = tok_ids[order]
        g_sorted = g_flat[order]
        first = jnp.searchsorted(e_sorted, e_sorted, side="left")
        pos_in_e = jnp.arange(S * k) - first
        keep = pos_in_e < Cg
        e_idx = jnp.where(keep, e_sorted, 0)
        c_idx = jnp.where(keep, pos_in_e, Cg - 1)
        vals = xg[tok_sorted] * keep[:, None].astype(xg.dtype)
        expert_in = jnp.zeros((E, Cg, d), xg.dtype).at[e_idx, c_idx].add(vals)
        counts = jnp.zeros((E,), jnp.float32).at[e_flat].add(1.0) / (S * k)
        return expert_in, (e_idx, c_idx, tok_sorted, g_sorted, keep), counts

    xf = x.reshape(T, d)
    logits = (xf.astype(jnp.float32) @ p["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1).reshape(B, S, E)

    expert_in, idxs, counts = jax.vmap(dispatch_one)(x, probs)
    # expert_in: (B, E, Cg, d) — batch axis sharded over data, experts over
    # model; the einsums below contract per group
    expert_in = lconstraint(expert_in, "batch", "expert", None, None)

    we = p["experts"]
    up = jnp.einsum("becd,edf->becf", expert_in, we["w_up"])
    gatep = jnp.einsum("becd,edf->becf", expert_in, we["w_gate"])
    h = (jax.nn.silu(gatep) if act == "silu" else jax.nn.gelu(gatep)) * up
    h = lconstraint(h, "batch", "expert", None, None)
    out_e = jnp.einsum("becf,efd->becd", h, we["w_down"])
    out_e = lconstraint(out_e, "batch", "expert", None, None)

    def combine_one(out_g, idx):
        e_idx, c_idx, tok_sorted, g_sorted, keep = idx
        contrib = out_g[e_idx, c_idx]
        contrib = contrib * (g_sorted * keep).astype(out_g.dtype)[:, None]
        return jnp.zeros((S, d), out_g.dtype).at[tok_sorted].add(contrib)

    y = jax.vmap(combine_one)(out_e, idxs)  # (B, S, d)
    keep_frac = jax.vmap(lambda i: i[4].mean())(idxs).mean()

    if "mlp" in p:  # Arctic dense residual, parallel to the MoE branch
        y = y + layers.apply_mlp(p["mlp"], x, act="silu", gated=True)

    # aux: load-balancing loss (Switch-style) + drop fraction diagnostic
    me = probs.reshape(T, E).mean(0)
    ce = counts.mean(0)
    aux = {
        "lb_loss": E * jnp.sum(me * ce),
        "drop_frac": 1.0 - keep_frac,
    }
    return y, aux
