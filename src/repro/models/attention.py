"""GQA attention with RoPE / M-RoPE, sliding windows, KV cache decode, and
blockwise (memory-efficient) attention for long sequences.

Grouped-query attention never materialises repeated K/V: queries are
reshaped to (B, S, KV, rep, dh) and contracted against grouped keys — at
decode_32k cache sizes a materialised repeat would be ~8x the cache
footprint, far past HBM.

Blockwise attention is the pure-JAX flash pattern: lax.map over query
blocks, lax.scan over KV blocks with an online-softmax carry — O(S) memory
instead of O(S^2), which is what lets prefill_32k lower within HBM.  On
TPU the XLA fusion of the inner block is MXU-shaped (block x head_dim
matmuls); a hand-tiled pallas flash kernel is a further hillclimb step.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.train.sharding import lconstraint
from repro import probe, tuning
from . import layers

NEG_INF = -1e30

# blockwise thresholds (hillclimb-tunable)
BLOCKWISE_MIN_SEQ = 8192
Q_BLOCK = 1024
KV_BLOCK = 1024


# -- RoPE -------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim: int, theta: float,
                 sections: Optional[Tuple[int, int, int]] = None):
    """cos/sin tables.

    positions: (B, S) int32, or (3, B, S) for M-RoPE with ``sections``
    (temporal/height/width frequency splits, qwen2-vl).
    Returns cos, sin of shape (B, S, half).
    """
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if sections is None:
        ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, half)
    else:
        assert sum(sections) == half, (sections, half)
        parts = []
        off = 0
        for i, sec in enumerate(sections):
            p = positions[i].astype(jnp.float32)[..., None]   # (B, S, 1)
            parts.append(p * inv[off : off + sec])
            off += sec
        ang = jnp.concatenate(parts, axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, dh); cos/sin: (B, S, half) -> rotated x (rotate-half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# -- parameter init ------------------------------------------------------------------

def init_attn(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              dtype, qk_norm: bool = False):
    ks = jax.random.split(key, 6)
    p = {
        "wq": layers.dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": layers.dense_init(ks[1], (d_model, n_kv_heads * head_dim), dtype),
        "wv": layers.dense_init(ks[2], (d_model, n_kv_heads * head_dim), dtype),
        "wo": layers.dense_init(
            ks[3], (n_heads * head_dim, d_model), dtype, fan_in=n_heads * head_dim
        ),
    }
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((head_dim,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((head_dim,), dtype)}
    return p


def _project_qkv(p, x, n_heads, n_kv_heads, head_dim, qk_norm):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(B, S, n_kv_heads, head_dim)
    if qk_norm:
        q = layers.rmsnorm(q, p["q_norm"]["scale"])
        k = layers.rmsnorm(k, p["k_norm"]["scale"])
    return q, k, v


def _group_q(q, n_kv_heads):
    """(B, S, H, dh) -> (B, S, KV, rep, dh)."""
    B, S, H, dh = q.shape
    return q.reshape(B, S, n_kv_heads, H // n_kv_heads, dh)


# -- dense (short-seq) path ------------------------------------------------------------

def _mask_ok(S_q, S_k, *, causal: bool, window, q_offset=0):
    """(S_q, S_k) boolean visibility.  window <= 0 means unlimited; window
    may be a traced scalar (hybrid per-layer windows under scan)."""
    qi = jnp.arange(S_q)[:, None] + q_offset
    kj = jnp.arange(S_k)[None, :]
    ok = jnp.ones((S_q, S_k), bool)
    if causal:
        ok = ok & (kj <= qi)
    win = jnp.asarray(window)
    ok = ok & ((win <= 0) | (qi - kj < win))
    return ok


def _dense_gqa_fast(q, k, v, ok):
    """Transpose-free formulation: the (S, dh)-sized bf16 operands are
    pre-transposed once (MBs) so no S^2 fp32 tensor is ever re-laid-out
    (the baseline einsum order costs ~8 x 2 GiB fp32 transposes per layer
    at train_4k, measured from the lowered HLO); the mask enters as a
    small additive bias instead of an S^2 select; the probability matrix
    is cast to bf16 for the PV contraction (halves its read traffic)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    qt = q.transpose(0, 2, 3, 1, 4)          # (B, KV, rep, Sq, dh) bf16
    kt = k.transpose(0, 2, 3, 1)             # (B, KV, dh, Sk) bf16
    vt = v.transpose(0, 2, 1, 3)             # (B, KV, Sk, dh) bf16
    s = jnp.einsum("bgrqd,bgdk->bgrqk", qt, kt).astype(jnp.float32) * scale
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)  # (Sq, Sk)
    s = s + bias[None, None, None]
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bgrqk,bgkd->bgrqd", w, vt)
    return o.transpose(0, 3, 1, 2, 4)        # (B, Sq, KV, rep, dh)


def _dense_gqa(q, k, v, ok):
    """q: (B, Sq, KV, rep, dh), k/v: (B, Sk, KV, dh), ok: (Sq, Sk) bool."""
    if tuning.get().attn_fast:
        return _dense_gqa_fast(q, k, v, ok)
    scale = 1.0 / math.sqrt(q.shape[-1])
    if tuning.get().scores_bf16 and q.dtype == jnp.bfloat16:
        # bf16 score traffic, fp32 max/denominator statistics: halves the
        # dominant S^2 HBM stream while keeping softmax normalization exact
        s = jnp.einsum("bqgrd,bkgd->bgrqk", q, k) * jnp.bfloat16(scale)
        s = jnp.where(ok[None, None, None], s, jnp.bfloat16(-3e38))
        m = jax.lax.stop_gradient(
            s.max(axis=-1, keepdims=True).astype(jnp.float32))
        p = jnp.exp((s.astype(jnp.float32) - m).astype(jnp.bfloat16)
                    .astype(jnp.float32)).astype(jnp.bfloat16)
        denom = p.astype(jnp.float32).sum(axis=-1, keepdims=True)
        w = (p.astype(jnp.float32) / denom).astype(q.dtype)
        return jnp.einsum("bgrqk,bkgd->bqgrd", w, v)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", q, k).astype(jnp.float32) * scale
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrqk,bkgd->bqgrd", w, v)


# -- blockwise (long-seq) path -----------------------------------------------------------

def _blockwise_gqa(q, k, v, *, causal: bool, window):
    """Online-softmax attention, O(S) memory.
    q: (B, S, KV, rep, dh); k/v: (B, S, KV, dh)."""
    B, S, KV, rep, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    tqb, tkb = tuning.get().q_block, tuning.get().kv_block
    qb = tqb if S % tqb == 0 else S
    kb = tkb if S % tkb == 0 else S
    nq, nk = S // qb, S // kb
    qs = q.reshape(B, nq, qb, KV, rep, dh)
    ks = k.reshape(B, nk, kb, KV, dh)
    vs = v.reshape(B, nk, kb, KV, dh)
    win = jnp.asarray(window)

    def q_block(qi):
        qblk = qs[:, qi]  # (B, qb, KV, rep, dh)
        q_off = qi * qb

        def kv_step(carry, ki):
            acc, m, l = carry
            kblk = ks[:, ki]
            vblk = vs[:, ki]
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk).astype(jnp.float32) * scale
            qi_ix = q_off + jnp.arange(qb)[:, None]
            kj_ix = ki * kb + jnp.arange(kb)[None, :]
            ok = jnp.ones((qb, kb), bool)
            if causal:
                ok = ok & (kj_ix <= qi_ix)
            ok = ok & ((win <= 0) | (qi_ix - kj_ix < win))
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            upd = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(v.dtype), vblk)
            acc_new = acc * corr[..., None] + upd.astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KV, rep, qb, dh), jnp.float32)
        m0 = jnp.full((B, KV, rep, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, qb), jnp.float32)
        if probe.probing():  # unrolled for exact cost analysis
            carry = (acc0, m0, l0)
            for ki in range(nk):
                carry, _ = kv_step(carry, ki)
            acc, m, l = carry
        else:
            (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                          jnp.arange(nk))
        # (B, KV, rep, qb, dh) -> (B, qb, KV, rep, dh)
        return (acc / l[..., None]).astype(q.dtype).transpose(0, 3, 1, 2, 4)

    if probe.probing():
        out = jnp.stack([q_block(qi) for qi in range(nq)])
    else:
        out = jax.lax.map(q_block, jnp.arange(nq))  # (nq, B, qb, KV, rep, dh)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, rep, dh)
    return out


# -- public entry points --------------------------------------------------------------

def attention(p, x, cos, sin, *, n_heads, n_kv_heads, head_dim,
              causal: bool = True, window=0, qk_norm: bool = False):
    """Full-sequence attention (train/prefill).  x: (B, S, d).
    Returns (out (B, S, d), (k, v) for cache seeding)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, qk_norm)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # "seq_attn" is distinct from "seq": under Megatron-style sequence
    # parallelism the between-block activations are seq-sharded but
    # attention itself needs the full sequence per (sharded) head group.
    q = lconstraint(q, "batch", "seq_q", "kv_heads", None)
    k = lconstraint(k, "batch", "seq_attn", "kv_heads", None)
    v = lconstraint(v, "batch", "seq_attn", "kv_heads", None)
    qg = _group_q(q, n_kv_heads)

    if S >= BLOCKWISE_MIN_SEQ:
        out = _blockwise_gqa(qg, k, v, causal=causal, window=window)
    else:
        ok = _mask_ok(S, S, causal=causal, window=window)
        out = _dense_gqa(qg, k, v, ok)
    out = out.reshape(B, S, n_heads * head_dim)
    return out @ p["wo"], (k, v)


def decode_attention(p, x1, cache_k, cache_v, pos, cos1, sin1, *,
                     n_heads, n_kv_heads, head_dim, window=0,
                     qk_norm: bool = False):
    """Single-token decode.  x1: (B, 1, d); cache_k/v: (B, S_max, KV, dh);
    pos: scalar int32 current position.  Returns (out (B, 1, d), new caches).
    """
    B = x1.shape[0]
    S_max = cache_k.shape[1]
    q, k1, v1 = _project_qkv(p, x1, n_heads, n_kv_heads, head_dim, qk_norm)
    if cos1 is not None:
        q = apply_rope(q, cos1, sin1)
        k1 = apply_rope(k1, cos1, sin1)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k1.astype(cache_k.dtype), (0, pos, 0, 0)
    )
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v1.astype(cache_v.dtype), (0, pos, 0, 0)
    )
    qg = _group_q(q, n_kv_heads)  # (B, 1, KV, rep, dh)
    scale = 1.0 / math.sqrt(head_dim)
    scores = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qg, cache_k.astype(qg.dtype)
    ).astype(jnp.float32) * scale
    kj = jnp.arange(S_max)
    ok = kj <= pos
    win = jnp.asarray(window)
    ok = ok & ((win <= 0) | (pos - kj < win))
    scores = jnp.where(ok[None, None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x1.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, cache_v.astype(w.dtype))
    out = out.reshape(B, 1, n_heads * head_dim)
    return out @ p["wo"], cache_k, cache_v


def cross_attention(p, x, mem_k, mem_v, *, n_heads, n_kv_heads, head_dim):
    """Decoder cross-attention over precomputed encoder memory K/V
    (B, S_enc, KV, dh).  No RoPE on cross attention."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    qg = _group_q(q, n_kv_heads)
    scale = 1.0 / math.sqrt(head_dim)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, mem_k.astype(qg.dtype))
    scores = scores.astype(jnp.float32) * scale
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, mem_v.astype(w.dtype))
    out = out.reshape(B, S, n_heads * head_dim)
    return out @ p["wo"]


def mem_kv(p, mem, *, n_kv_heads, head_dim):
    """Project encoder memory to cross-attention K/V once."""
    B, S, _ = mem.shape
    k = (mem @ p["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = (mem @ p["wv"]).reshape(B, S, n_kv_heads, head_dim)
    return k, v
