"""Encoder-decoder stack (seamless-m4t backbone).

Per the assignment, the modality frontend is a STUB: ``input_specs`` feeds
precomputed audio-frame embeddings (B, S_enc, d_model) straight into the
encoder.  The decoder is a standard causal stack with per-layer cross-
attention over the encoder memory; serving precomputes the cross K/V once
("encoder KV cache") and then decodes against a growing self cache.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.train.sharding import lconstraint
from . import attention as attn
from repro import probe, tuning
from . import layers


def _init_enc_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    d, dt = cfg.d_model, cfg.dtype
    return {
        "norm1": layers.init_norm(ks[0], d, cfg.norm, dt),
        "attn": attn.init_attn(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dt),
        "norm2": layers.init_norm(ks[2], d, cfg.norm, dt),
        "mlp": layers.init_mlp(ks[3], d, cfg.d_ff, dt, cfg.mlp_gated),
    }


def _init_dec_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    d, dt = cfg.d_model, cfg.dtype
    return {
        "norm1": layers.init_norm(ks[0], d, cfg.norm, dt),
        "attn": attn.init_attn(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dt),
        "norm_x": layers.init_norm(ks[2], d, cfg.norm, dt),
        "xattn": attn.init_attn(ks[3], d, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, dt),
        "norm2": layers.init_norm(ks[4], d, cfg.norm, dt),
        "mlp": layers.init_mlp(ks[5], d, cfg.d_ff, dt, cfg.mlp_gated),
    }


def init_encdec(cfg: ArchConfig, key):
    kse = jax.random.split(key, cfg.n_enc_layers)
    ksd = jax.random.split(jax.random.fold_in(key, 1), cfg.n_layers)
    enc = [_init_enc_block(k, cfg) for k in kse]
    dec = [_init_dec_block(k, cfg) for k in ksd]
    stack = lambda blocks: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    k2 = jax.random.fold_in(key, 2)
    return {
        "tok": layers.init_embed(k2, cfg.padded_vocab, cfg.d_model,
                                 cfg.dtype, cfg.tie_embeddings),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "enc_norm_f": layers.init_norm(jax.random.fold_in(key, 3), cfg.d_model,
                                       cfg.norm, cfg.dtype),
        "norm_f": layers.init_norm(jax.random.fold_in(key, 4), cfg.d_model,
                                   cfg.norm, cfg.dtype),
    }


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, S_enc, d_model) stub embeddings -> encoder memory."""
    B, S, _ = frames.shape
    x = frames.astype(cfg.dtype)
    x = lconstraint(x, "batch", "seq", "embed")
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = attn.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        h = layers.apply_norm(bp["norm1"], x, cfg.norm)
        ao, _ = attn.attention(bp["attn"], h, cos, sin, n_heads=cfg.n_heads,
                               n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                               causal=False)
        x = x + ao
        h2 = layers.apply_norm(bp["norm2"], x, cfg.norm)
        x = x + layers.apply_mlp(bp["mlp"], h2, cfg.act, cfg.mlp_gated)
        return x, None

    x, _ = jax.lax.scan(tuning.checkpoint_wrap(body), x, params["enc_layers"],
                        unroll=probe.scan_unroll())
    return layers.apply_norm(params["enc_norm_f"], x, cfg.norm)


def decode_train(params, cfg: ArchConfig, tokens, memory):
    """Teacher-forced decoder. tokens (B, S_dec); memory (B, S_enc, d)."""
    B, S = tokens.shape
    x = layers.embed_tokens(params["tok"], tokens).astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = attn.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def body(x, bp):
        h = layers.apply_norm(bp["norm1"], x, cfg.norm)
        ao, _ = attn.attention(bp["attn"], h, cos, sin, n_heads=cfg.n_heads,
                               n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                               causal=True)
        x = x + ao
        hx = layers.apply_norm(bp["norm_x"], x, cfg.norm)
        mk, mv = attn.mem_kv(bp["xattn"], memory, n_kv_heads=cfg.n_kv_heads,
                             head_dim=cfg.head_dim)
        x = x + attn.cross_attention(bp["xattn"], hx, mk, mv,
                                     n_heads=cfg.n_heads,
                                     n_kv_heads=cfg.n_kv_heads,
                                     head_dim=cfg.head_dim)
        h2 = layers.apply_norm(bp["norm2"], x, cfg.norm)
        x = x + layers.apply_mlp(bp["mlp"], h2, cfg.act, cfg.mlp_gated)
        return x, None

    x, _ = jax.lax.scan(tuning.checkpoint_wrap(body), x, params["dec_layers"],
                        unroll=probe.scan_unroll())
    x = layers.apply_norm(params["norm_f"], x, cfg.norm)
    return layers.lm_logits(params["tok"], x, cfg.tie_embeddings)


def encdec_forward(params, cfg: ArchConfig, batch: Dict):
    memory = encode(params, cfg, batch["frames"])
    logits = decode_train(params, cfg, batch["tokens"], memory)
    return logits, {"lb_loss": jnp.float32(0.0)}


def init_encdec_cache(cfg: ArchConfig, batch: int, s_max: int, s_enc: int,
                      dtype=None):
    dtype = dtype or cfg.dtype
    L, B = cfg.n_layers, batch
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((L, B, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, B, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
        "mem_k": jnp.zeros((L, B, s_enc, cfg.n_kv_heads, cfg.head_dim), dtype),
        "mem_v": jnp.zeros((L, B, s_enc, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def seed_encdec_cache(params, cfg: ArchConfig, cache: Dict, memory):
    """Precompute per-layer cross K/V from encoder memory (serving setup)."""
    def body(_, bp):
        mk, mv = attn.mem_kv(bp["xattn"], memory, n_kv_heads=cfg.n_kv_heads,
                             head_dim=cfg.head_dim)
        return None, (mk.astype(cache["mem_k"].dtype),
                      mv.astype(cache["mem_v"].dtype))

    _, (mk, mv) = jax.lax.scan(body, None, params["dec_layers"])
    return dict(cache, mem_k=mk, mem_v=mv)


def encdec_decode_step(params, cfg: ArchConfig, cache: Dict, tokens):
    """tokens (B,) -> (logits (B, vocab), cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = layers.embed_tokens(params["tok"], tokens)[:, None, :].astype(cfg.dtype)
    p1 = jnp.broadcast_to(pos[None, None], (B, 1))
    cos1, sin1 = attn.rope_cos_sin(p1, cfg.head_dim, cfg.rope_theta)

    def body(x, scanned):
        bp, ck, cv, mk, mv = scanned
        h = layers.apply_norm(bp["norm1"], x, cfg.norm)
        ao, ck2, cv2 = attn.decode_attention(
            bp["attn"], h, ck, cv, pos, cos1, sin1, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        )
        x = x + ao
        hx = layers.apply_norm(bp["norm_x"], x, cfg.norm)
        x = x + attn.cross_attention(bp["xattn"], hx, mk, mv,
                                     n_heads=cfg.n_heads,
                                     n_kv_heads=cfg.n_kv_heads,
                                     head_dim=cfg.head_dim)
        h2 = layers.apply_norm(bp["norm2"], x, cfg.norm)
        x = x + layers.apply_mlp(bp["mlp"], h2, cfg.act, cfg.mlp_gated)
        return x, (ck2, cv2)

    xs = (params["dec_layers"], cache["k"], cache["v"],
          cache["mem_k"], cache["mem_v"])
    x, (k2, v2) = jax.lax.scan(body, x, xs, unroll=probe.scan_unroll())
    x = layers.apply_norm(params["norm_f"], x[:, 0], cfg.norm)
    logits = layers.lm_logits(params["tok"], x, cfg.tie_embeddings)
    return logits, dict(cache, k=k2, v=v2, pos=pos + 1)
