"""Family dispatch: one entry point per lifecycle stage for every arch."""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import encdec, transformer


def init_params(cfg: ArchConfig, key):
    if cfg.enc_dec:
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def forward(params, cfg: ArchConfig, batch: Dict, *, wkv_engine: str = "jnp"):
    """Training/prefill forward -> (logits, aux)."""
    if cfg.enc_dec:
        return encdec.encdec_forward(params, cfg, batch)
    return transformer.lm_forward(params, cfg, batch, wkv_engine=wkv_engine)


def init_cache(cfg: ArchConfig, batch: int, s_max: int, *, s_enc: int = 0,
               dtype=None):
    if cfg.enc_dec:
        return encdec.init_encdec_cache(cfg, batch, s_max, s_enc or s_max,
                                        dtype=dtype)
    return transformer.init_cache(cfg, batch, s_max, dtype=dtype)


def decode_step(params, cfg: ArchConfig, cache: Dict, tokens):
    """One token of autoregressive decode -> (logits, cache)."""
    if cfg.enc_dec:
        return encdec.encdec_decode_step(params, cfg, cache, tokens)
    return transformer.lm_decode_step(params, cfg, cache, tokens)
