"""Training/serving substrate: sharding rules, optimizers, steps, data,
checkpointing, fault tolerance."""
