"""Logical-axis sharding rules (flax-style, dependency-free).

Model code annotates activations with logical names via ``lconstraint``;
the launcher installs a rules table mapping logical names to mesh axes.
With no rules installed (unit tests, single device) everything is a no-op,
so models run anywhere — the same portability discipline targetDP applies
to kernels, applied to distribution.

Parameter sharding is path-based: ``spec_for_path`` maps parameter-tree
paths (e.g. "layers/attn/wq") to PartitionSpecs implementing FSDP (shard
over "data") x TP (shard over "model") x EP (experts over "model").
"""

from __future__ import annotations

import re
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

_RULES: Dict[str, object] = {}


DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_attn": None,
    "seq_q": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "logits_vocab": "model",  # set to None when seq rides "model"
    "expert": "model",
    "state": None,
}

# sequence-parallel variant (hillclimb option): shard long sequences on
# "model" between attention blocks
SP_RULES = dict(DEFAULT_RULES, seq="model")


def set_rules(rules: Optional[Dict[str, object]]) -> None:
    global _RULES
    _RULES = dict(rules) if rules else {}


def get_rules() -> Dict[str, object]:
    return dict(_RULES)


def lconstraint(x, *logical: Optional[str]):
    """Constrain activation sharding by logical axis names (no-op without
    rules or outside a mesh context)."""
    if not _RULES:
        return x
    try:
        spec = P(*[_RULES.get(n) if n else None for n in logical])
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# -- parameter specs -----------------------------------------------------------

_PARAM_SPEC_PATTERNS: Sequence[Tuple[str, P]] = (
    # embeddings: (vocab, d_model) — vocab over model (TP), d_model over data (FSDP)
    (r"embed", P("model", "data")),
    (r"lm_head", P("data", "model")),
    # attention: wq/wk/wv (d_model, heads*dh) ; wo (heads*dh, d_model)
    (r"attn/w[qkv]$", P("data", "model")),
    (r"attn/wo$", P("model", "data")),
    # MoE experts: (n_exp, d_model, d_ff) / (n_exp, d_ff, d_model)
    (r"experts/w_(gate|up)$", P("model", "data", None)),
    (r"experts/w_down$", P("model", None, "data")),
    (r"router", P(None, "model")),
    # dense MLP: (d_model, d_ff) / (d_ff, d_model)
    (r"mlp/w_(gate|up)$", P("data", "model")),
    (r"mlp/w_down$", P("model", "data")),
    # ssm / rwkv projections: in-proj over model, out-proj back
    (r"(ssm|rwkv|tmix)/w_(in|x|r|k|v|g|b|dt)[a-z0-9_]*$", P("data", "model")),
    (r"(ssm|rwkv|tmix|cmix)/w_(out|o|down)$", P("model", "data")),
    (r"cmix/w_(k|up)$", P("data", "model")),
    # small per-channel vectors: replicate
    (r"(norm|scale|bias|a_log|dt_bias|d_skip|decay|bonus|mu|meta)", P()),
)


def spec_for_path(path: str) -> P:
    for pat, spec in _PARAM_SPEC_PATTERNS:
        if re.search(pat, path):
            return spec
    return P()  # default: replicated


def param_specs(params) -> object:
    """PartitionSpec tree mirroring a param tree, keyed by tree paths.

    Stacked-layer params (leading n_layers axis) keep the layer axis
    unsharded: specs apply to the trailing dims, so prepend None when the
    leaf rank exceeds the spec rank.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    paths_leaves, treedef = flat

    def mk(path_entries, leaf):
        path = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_entries
        )
        spec = spec_for_path(path)
        pad = leaf.ndim - len(spec)
        if pad > 0:
            spec = P(*((None,) * pad + tuple(spec)))
        elif pad < 0:
            spec = P(*tuple(spec)[-leaf.ndim:] if leaf.ndim else ())
        return spec

    specs = [mk(p, l) for p, l in paths_leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)
