"""Data pipeline: deterministic synthetic stream + memory-mapped token files.

Determinism is the straggler/fault story's foundation: batch(step) is a
pure function of (seed, step, shard), so any restart — including an
*elastic* restart on a different data-parallel size — replays or resumes
the exact stream with no coordination.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None   # token file (uint16/uint32 raw); None -> synthetic


class TokenStream:
    """Deterministic batches of (tokens, labels), next-token objective."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.path:
            dtype = np.uint32 if cfg.vocab > 65535 else np.uint16
            self._mm = np.memmap(cfg.path, dtype=dtype, mode="r")
            if self._mm.size < cfg.seq_len + 1:
                raise ValueError("token file smaller than one sequence")

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        B, S = cfg.global_batch, cfg.seq_len
        if self._mm is None:
            rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
            seqs = rng.integers(0, cfg.vocab, size=(B, S + 1), dtype=np.int64)
        else:
            n = self._mm.size - (S + 1)
            rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
            starts = rng.integers(0, n, size=(B,))
            seqs = np.stack([self._mm[s : s + S + 1] for s in starts]).astype(np.int64)
        tokens = seqs[:, :-1].astype(np.int32)
        labels = seqs[:, 1:].astype(np.int32)
        return tokens, labels

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def write_token_file(path: str, tokens: np.ndarray, vocab: int) -> None:
    dtype = np.uint32 if vocab > 65535 else np.uint16
    np.asarray(tokens, dtype).tofile(path)
