"""Fault-tolerant training loop: checkpoint cadence, auto-resume, failure
injection, straggler accounting.

Synchronous SPMD has no per-step straggler slack, so the production
mitigations are structural (see DESIGN.md §6): deterministic data (replay
from any step), step-atomic checkpoints (bounded lost work), and elastic
restart (evict a slow/failed host, reshape the mesh, resume from the same
step).  All three are exercised by tests/test_fault.py: a loop killed
mid-run by an injected failure resumes from the latest valid checkpoint —
on a different device count if asked — and reproduces the uninterrupted
loss trajectory exactly (determinism makes that assertable).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from . import checkpoint as ckpt
from .data import TokenStream


class InjectedFailure(RuntimeError):
    """Simulated node failure."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 10
    keep: int = 3
    fail_at_step: Optional[int] = None   # failure injection (tests)
    async_save: bool = False


def run_loop(
    step_fn: Callable,
    state: Dict,
    stream: TokenStream,
    cfg: LoopConfig,
    *,
    make_batch: Callable[[np.ndarray, np.ndarray], Dict],
    on_step: Optional[Callable[[int, Dict], None]] = None,
):
    """Run (or resume) the training loop.

    state: {"params": ..., "opt": OptState, "ef": tree|None}
    Resumes from the latest valid checkpoint in cfg.ckpt_dir if present —
    the restart entry point is *the same call*; crashed runs just call
    run_loop again.
    Returns (state, history) where history[i] = metrics dict of step i.
    """
    start_step = 0
    latest = ckpt.latest_valid(cfg.ckpt_dir)
    if latest is not None:
        step0, path, manifest = latest
        tree = {"params": state["params"], "opt": state["opt"], "ef": state["ef"]}
        restored, _ = ckpt.restore(path, tree)
        state = dict(state, **restored)
        start_step = step0 + 1

    history = []
    pending = None
    for step in range(start_step, cfg.total_steps):
        if cfg.fail_at_step is not None and step == cfg.fail_at_step:
            raise InjectedFailure(f"injected failure at step {step}")
        tokens, labels = stream.batch(step)
        batch = make_batch(tokens, labels)
        t0 = time.perf_counter()
        params, opt, ef, metrics = step_fn(
            state["params"], state["opt"], state["ef"], batch
        )
        jax.block_until_ready(jax.tree_util.tree_leaves(metrics))
        state = {"params": params, "opt": opt, "ef": ef}
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step_time_s"] = time.perf_counter() - t0
        metrics["step"] = step
        history.append(metrics)
        if on_step:
            on_step(step, metrics)
        if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.total_steps:
            tree = {"params": state["params"], "opt": state["opt"],
                    "ef": state["ef"]}
            if cfg.async_save:
                if pending is not None:
                    pending.result()
                pending = ckpt.save_async(cfg.ckpt_dir, step, tree)
            else:
                ckpt.save(cfg.ckpt_dir, step, tree)
            ckpt.prune(cfg.ckpt_dir, cfg.keep)
    if pending is not None:
        pending.result()
    return state, history
