"""Optimizers with sharded, memory-tiered state.

  adamw      fp32 moments + fp32 master params (default quality tier)
  adamw8bit  row-wise int8 moments, bf16 params, no master (Arctic-class
             models: cuts optimizer HBM from ~12 to ~2.1 bytes/param)
  adafactor  factored second moment + bf16 first moment

Quantized moments are rank-preserving (int8 codes in the parameter's own
shape + one fp32 scale per trailing-dim row), so every optimizer-state
leaf inherits the parameter's PartitionSpec — ZeRO-style sharding over the
full (data x model) mesh falls out of FSDP with no extra machinery
(``opt_specs`` below).

Implementation is flatten-based: one pass over zipped leaf lists, no
nested tree_map/is_leaf tricks.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# -- row-wise int8 quantization (rank preserving) ---------------------------------

def _q8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """fp32 -> (int8 codes same shape, fp32 scale with trailing dim 1).
    Linear signed absmax — fine for the (roughly symmetric) first moment."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _dq8(codes, scale):
    return codes.astype(jnp.float32) * scale


_V_TINY = 1e-16


def _q8v(x: jnp.ndarray):
    """Non-negative second moment -> log-space int8 (the dynamic range of v
    spans many orders of magnitude; linear codes zero out small rows and
    blow up the preconditioner — bitsandbytes solves this with a dynamic
    code, we use an explicit log transform).
    Returns (int8 codes, fp32 offset (...,1), fp32 scale (...,1))."""
    y = jnp.log(jnp.maximum(x, 0.0) + _V_TINY)
    lo = jnp.min(y, axis=-1, keepdims=True)
    hi = jnp.max(y, axis=-1, keepdims=True)
    scale = (hi - lo) / 254.0 + 1e-12
    codes = jnp.clip(jnp.round((y - lo) / scale) - 127, -127, 127).astype(jnp.int8)
    return codes, lo.astype(jnp.float32), scale.astype(jnp.float32)


def _dq8v(codes, lo, scale):
    y = (codes.astype(jnp.float32) + 127.0) * scale + lo
    v = jnp.exp(y) - _V_TINY
    return jnp.maximum(v, 0.0)


class OptState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object
    master: object


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adamw8bit | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def _map(fn, tree):
    return jax.tree_util.tree_map(fn, tree)


def init_opt(params, cfg: OptConfig) -> OptState:
    if cfg.kind == "adamw":
        return OptState(
            jnp.zeros((), jnp.int32),
            _map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            _map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            _map(lambda p: p.astype(jnp.float32), params),
        )
    if cfg.kind == "adamw8bit":
        qz = lambda p: (jnp.zeros(p.shape, jnp.int8),
                        jnp.full(p.shape[:-1] + (1,), 1e-12, jnp.float32))
        vz = lambda p: _q8v(jnp.zeros(p.shape, jnp.float32))
        return OptState(jnp.zeros((), jnp.int32), _map(qz, params),
                        _map(vz, params), None)
    if cfg.kind == "adafactor":
        def vfact(p):
            if p.ndim >= 2:
                return (jnp.zeros(p.shape[:-1], jnp.float32),
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return (jnp.zeros(p.shape, jnp.float32),)
        return OptState(
            jnp.zeros((), jnp.int32),
            _map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
            jax.tree_util.tree_map(vfact, params),
            None,
        )
    raise ValueError(cfg.kind)


def opt_specs(pspecs, params_shape, cfg: OptConfig):
    """PartitionSpec trees for OptState, derived from the param specs."""
    drop_last = lambda s: P(*(tuple(s)[:-1] + (None,))) if len(tuple(s)) else s

    if cfg.kind == "adamw":
        return OptState(P(), pspecs, pspecs, pspecs)
    if cfg.kind == "adamw8bit":
        qspec = jax.tree_util.tree_map(lambda s: (s, drop_last(s)), pspecs,
                                       is_leaf=lambda t: isinstance(t, P))
        vspec = jax.tree_util.tree_map(
            lambda s: (s, drop_last(s), drop_last(s)), pspecs,
            is_leaf=lambda t: isinstance(t, P),
        )
        return OptState(P(), qspec, vspec, None)
    if cfg.kind == "adafactor":
        def vf(s, shp):
            t = tuple(s) + (None,) * (len(shp.shape) - len(tuple(s)))
            if len(shp.shape) >= 2:
                return (P(*t[:-1]), P(*(t[:-2] + t[-1:])))
            return (P(*t),)
        vspec = jax.tree_util.tree_map(
            vf, pspecs, params_shape, is_leaf=lambda t: isinstance(t, P)
        )
        return OptState(P(), pspecs, vspec, None)
    raise ValueError(cfg.kind)


def _global_norm(leaves):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = [g.astype(jnp.float32) for g in treedef.flatten_up_to(grads)]
    gnorm = _global_norm(g_leaves)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    g_leaves = [g * clip for g in g_leaves]
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** sf
    bc2 = 1.0 - cfg.b2 ** sf

    new_p, new_m, new_v, new_master = [], [], [], []

    if cfg.kind == "adamw":
        m_l = treedef.flatten_up_to(state.m)
        v_l = treedef.flatten_up_to(state.v)
        mp_l = treedef.flatten_up_to(state.master)
        for p, g, m, v, mp in zip(p_leaves, g_leaves, m_l, v_l, mp_l):
            m2 = cfg.b1 * m + (1 - cfg.b1) * g
            v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
            mp2 = mp - cfg.lr * (u + cfg.weight_decay * mp)
            new_p.append(mp2.astype(p.dtype))
            new_m.append(m2); new_v.append(v2); new_master.append(mp2)
        st = OptState(step,
                      jax.tree_util.tree_unflatten(treedef, new_m),
                      jax.tree_util.tree_unflatten(treedef, new_v),
                      jax.tree_util.tree_unflatten(treedef, new_master))
    elif cfg.kind == "adamw8bit":
        m_l = treedef.flatten_up_to(state.m)
        v_l = treedef.flatten_up_to(state.v)
        for p, g, mq, vq in zip(p_leaves, g_leaves, m_l, v_l):
            m = _dq8(*mq)
            v = _dq8v(*vq)
            m2 = cfg.b1 * m + (1 - cfg.b1) * g
            v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
            pf = p.astype(jnp.float32)
            p2 = pf - cfg.lr * (u + cfg.weight_decay * pf)
            new_p.append(p2.astype(p.dtype))
            new_m.append(_q8(m2)); new_v.append(_q8v(v2))
        st = OptState(step,
                      jax.tree_util.tree_unflatten(treedef, new_m),
                      jax.tree_util.tree_unflatten(treedef, new_v), None)
    elif cfg.kind == "adafactor":
        m_l = treedef.flatten_up_to(state.m)
        v_l = treedef.flatten_up_to(state.v)
        for p, g, m, v in zip(p_leaves, g_leaves, m_l, v_l):
            if p.ndim >= 2:
                vr, vc = v
                vr2 = cfg.b2 * vr + (1 - cfg.b2) * jnp.mean(g * g, axis=-1)
                vc2 = cfg.b2 * vc + (1 - cfg.b2) * jnp.mean(g * g, axis=-2)
                vhat = (vr2[..., :, None] * vc2[..., None, :]) / (
                    jnp.mean(vr2, axis=-1)[..., None, None] + 1e-30
                )
                v2 = (vr2, vc2)
            else:
                (vv,) = v
                vhat = cfg.b2 * vv + (1 - cfg.b2) * g * g
                v2 = (vhat,)
            m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
            u = (m2 / bc1) / (jnp.sqrt(vhat / bc2) + cfg.eps)
            pf = p.astype(jnp.float32)
            p2 = pf - cfg.lr * (u + cfg.weight_decay * pf)
            new_p.append(p2.astype(p.dtype))
            new_m.append(m2.astype(jnp.bfloat16)); new_v.append(v2)
        st = OptState(step,
                      jax.tree_util.tree_unflatten(treedef, new_m),
                      jax.tree_util.tree_unflatten(treedef, new_v), None)
    else:
        raise ValueError(cfg.kind)

    return jax.tree_util.tree_unflatten(treedef, new_p), st, {"grad_norm": gnorm}


def opt_kind_for(arch_name: str, param_count: int) -> str:
    """Launcher policy: 8-bit moments for >=100B-parameter models."""
    return "adamw8bit" if param_count >= 100e9 else "adamw"
