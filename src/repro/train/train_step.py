"""Train/loss step builders: remat'd forward, microbatch gradient
accumulation, optional int8 error-feedback gradient compression.

``build_train_step`` returns a pure function
    (params, opt_state, [ef_state,] batch) -> (params, opt_state, metrics)
suitable for jit with in/out shardings (the dry-run lowers exactly this).

Gradient compression: before the optimizer, gradients pass through a
row-wise int8 quantize/dequantize with a persistent error-feedback
accumulator — the arithmetic the compressed DP all-reduce performs at
scale (quantize -> sum -> dequantize), expressed shard-locally so it works
in both the GSPMD path and the shard_map path.  The EF residual keeps the
scheme convergent (Karimireddy et al.); the 8-device shard_map test
exercises the true ppermute-ring variant in tests/test_distributed.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import forward
from .optimizer import OptConfig, OptState, apply_updates, _q8, _dq8


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1            # gradient accumulation
    z_loss: float = 1e-4
    lb_coef: float = 1e-2            # MoE load-balance coefficient
    grad_compression: bool = False   # int8 + error feedback


def loss_fn(params, cfg: ArchConfig, batch: Dict, *, z_coef: float,
            lb_coef: float):
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    from repro import tuning as _tuning
    if _tuning.get().logits_fp32:
        logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0].astype(jnp.float32)
    nll = jnp.mean(logz - ll)
    z = z_coef * jnp.mean(jnp.square(logz))
    lb = lb_coef * aux.get("lb_loss", 0.0)
    return nll + z + lb, {"nll": nll, "z_loss": z, "lb_loss": lb}


def init_ef_state(params):
    """Error-feedback residuals (fp32, same shapes as params)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _compress_grads(grads, ef):
    """int8 quantize/dequantize with error feedback."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        codes, scale = _q8(gf)
        deq = _dq8(codes, scale)
        return deq, gf - deq
    out = jax.tree_util.tree_map(one, grads, ef)
    g2 = jax.tree_util.tree_map(lambda t: t[0], out,
                                is_leaf=lambda t: isinstance(t, tuple))
    ef2 = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return g2, ef2


def build_train_step(cfg: ArchConfig, tcfg: TrainConfig, grad_specs=None):
    """Returns step(params, opt_state, ef_state|None, batch) -> tuple.

    grad_specs: optional PartitionSpec tree (the param specs); gradients
    are sharding-constrained to it before the optimizer — without this the
    embedding-gradient scatter materializes fp32 replicated vocab x d
    tensors (+30 GiB/device measured on deepseek-67b)."""

    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, z_coef=tcfg.z_loss, lb_coef=tcfg.lb_coef),
        has_aux=True,
    )

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, cfg, batch)
            return loss, metrics, grads

        mb = tcfg.microbatches
        split = lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
        batch_mb = {k: split(v) for k, v in batch.items()}

        def acc_step(carry, mb_batch):
            g_acc, l_acc = carry
            (loss, metrics), grads = grad_fn(params, cfg, mb_batch)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / mb, g_acc, grads
            )
            return (g_acc, l_acc + loss / mb), metrics

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        from repro import probe as _probe
        (grads, loss), metrics = jax.lax.scan(acc_step, (g0, 0.0), batch_mb,
                                              unroll=_probe.scan_unroll())
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def step(params, opt_state: OptState, ef_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if grad_specs is not None:
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_specs)
        if tcfg.grad_compression:
            grads, ef_state = _compress_grads(grads, ef_state)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, tcfg.opt
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, ef_state, metrics

    return step
