"""Sharded, step-atomic, integrity-checked checkpointing with elastic
restore.

Layout:  <dir>/step_<N>/
             manifest.json   tree structure, shapes, dtypes, sha256 per file
             <leaf_id>.npy   one file per pytree leaf
         <dir>/LATEST        atomic pointer (written last)

Guarantees:
  * atomicity — written to step_<N>.tmp, fsync'd, renamed; LATEST updated
    only after the rename, so a crash mid-save never corrupts the latest
    valid checkpoint;
  * integrity — every .npy is sha256-verified against the manifest on
    restore; a corrupt/partial checkpoint is skipped and the previous one
    is used (tests simulate truncation);
  * elasticity — leaves are stored as full logical arrays; restore takes a
    target sharding tree and device_puts per the *new* mesh, so a job may
    resume on a different device count (at >100B scale one would store
    per-shard slices + an index instead; format versioned for that);
  * async — saves can run on a background thread (snapshot to host first).
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

FORMAT_VERSION = 1


def _leaf_files(tree) -> Tuple[Any, list]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, leaves


def _sha(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None) -> str:
    """Blocking save.  Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    treedef, leaves = _leaf_files(tree)
    manifest = {
        "version": FORMAT_VERSION,
        "step": step,
        "treedef": str(treedef),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "sha256": _sha(fpath)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


_EXEC = ThreadPoolExecutor(max_workers=1)


def save_async(ckpt_dir: str, step: int, tree, *, extra=None) -> Future:
    """Snapshot to host memory now, write on a background thread."""
    host_tree = jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), tree
    )
    return _EXEC.submit(save, ckpt_dir, step, host_tree, extra=extra)


def list_checkpoints(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in sorted(os.listdir(ckpt_dir)):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append((int(m.group(1)), os.path.join(ckpt_dir, name)))
    return out


def _validate(path: str) -> Optional[Dict]:
    mpath = os.path.join(path, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for entry in manifest["leaves"]:
            fpath = os.path.join(path, entry["file"])
            if _sha(fpath) != entry["sha256"]:
                return None
        return manifest
    except Exception:
        return None


def latest_valid(ckpt_dir: str) -> Optional[Tuple[int, str, Dict]]:
    """Newest checkpoint that passes integrity checks (corrupt ones are
    skipped — the crash-mid-save / bitrot recovery path)."""
    for step, path in reversed(list_checkpoints(ckpt_dir)):
        manifest = _validate(path)
        if manifest is not None:
            return step, path, manifest
    return None


def restore(path: str, tree_like, *, shardings=None):
    """Load into the structure of ``tree_like``; device_put per
    ``shardings`` (a matching tree of NamedSharding) for elastic restore
    onto whatever mesh is current."""
    manifest = _validate(path)
    if manifest is None:
        raise IOError(f"checkpoint {path} failed integrity validation")
    treedef, like_leaves = _leaf_files(tree_like)
    if len(manifest["leaves"]) != len(like_leaves):
        raise ValueError("checkpoint/tree leaf count mismatch")
    leaves = []
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None
        else [None] * len(like_leaves)
    )
    for entry, like, shd in zip(manifest["leaves"], like_leaves, shard_leaves):
        arr = np.load(os.path.join(path, entry["file"]))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {entry['file']}: {arr.shape} vs {like.shape}"
            )
        arr = arr.astype(like.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def prune(ckpt_dir: str, keep: int = 3) -> None:
    ckpts = list_checkpoints(ckpt_dir)
    for _, path in ckpts[:-keep]:
        shutil.rmtree(path, ignore_errors=True)
