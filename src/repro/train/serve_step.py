"""Serving steps: prefill and batched autoregressive decode.

``serve_step`` is the dry-run decode unit: one new token per sequence
against a KV/state cache of seq_len — exactly the decode_32k / long_500k
shapes.  ``generate`` drives it for the runnable serving example (greedy
or temperature sampling over a batch of requests).

``build_cg_serve_step`` is the lattice-solver analogue: the jitted unit
of work the request scheduler (launch/serve.py) replays between admission
and drain — one convergence-masked batched CG iteration over a fixed
(lattice, batch-slots) bucket.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import decode_step, forward, init_cache


def build_serve_step(cfg: ArchConfig):
    """(params, cache, tokens (B,)) -> (logits (B, vocab), cache)."""

    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)

    return step


def build_prefill(cfg: ArchConfig):
    """(params, batch) -> logits — the prefill_32k dry-run unit."""

    def prefill(params, batch):
        logits, _ = forward(params, cfg, batch)
        return logits

    return prefill


def build_cg_serve_step(u, kappa: float, config, *, tol: float,
                        max_iter: int, refine_every: int = 0,
                        config_hi=None):
    """Jitted masked-iteration step for batched CG serving: (BatchedCGState)
    -> BatchedCGState, one fused operator launch + one fused masked-update
    launch for the whole slot batch.  Converged/empty slots ride along
    bitwise frozen, so the scheduler can drain and refill them between
    calls without perturbing in-flight solves (apps.milc.cg semantics).

    ``refine_every > 0`` switches the returned step to the reliable-update
    signature ``step(state, rhs)``: every that many active iterations a
    slot's residual is recomputed exactly as ``b - A x`` through the
    ``config_hi`` operator (default: ``config`` stripped of any dtype
    policy) and its search direction restarted — the serving analogue of
    :func:`repro.apps.milc.cg.cg_batched`'s mixed-precision restarts."""
    import dataclasses

    from repro.apps.milc.cg import (
        batched_cg_iteration, batched_cg_refresh, wilson_normal_graph,
    )

    # the serving unit is a bound launch: graph + config + outputs fixed
    # at build time, only the solve vector (and its layout) vary per call
    bound = wilson_normal_graph(float(kappa)).bind(
        config=config, outputs=("ap", "pap"))

    def apply_a_dot(p):
        out = bound({"p": p, "u": u}, out_layouts={"ap": p.layout})
        return p.with_data(out["ap"].data), out["pap"].sum(axis=-1)

    if refine_every <= 0:
        def step(state):
            return batched_cg_iteration(state, apply_a_dot, config=config,
                                        tol=tol, max_iter=max_iter)

        return jax.jit(step)

    hi_cfg = config_hi or (
        dataclasses.replace(config, dtypes=None)
        if getattr(config, "dtypes", None) else config)
    bound_hi = wilson_normal_graph(float(kappa)).bind(
        config=hi_cfg, outputs=("ap", "pap"))

    def apply_a_dot_hi(p):
        out = bound_hi({"p": p, "u": u}, out_layouts={"ap": p.layout})
        return p.with_data(out["ap"].data), out["pap"].sum(axis=-1)

    def step_refined(state, rhs):
        state = batched_cg_iteration(state, apply_a_dot, config=config,
                                     tol=tol, max_iter=max_iter)
        return jax.lax.cond(
            jnp.any(jnp.logical_and(
                state.rr / state.b2 > tol,
                jnp.logical_and(state.it < max_iter,
                                state.it % refine_every == 0))),
            lambda s: batched_cg_refresh(
                s, rhs, apply_a_dot_hi, tol=tol, max_iter=max_iter,
                refine_every=refine_every),
            lambda s: s, state)

    return jax.jit(step_refined)


def generate(params, cfg: ArchConfig, prompt_tokens, *, steps: int,
             s_max: int, temperature: float = 0.0, rng=None,
             jit_step=None):
    """Greedy/sampled generation for the examples (CPU, smoke configs).
    prompt_tokens: (B, P) int32.  Returns (B, P+steps) tokens.

    ``rng`` is only consulted when ``temperature > 0``; it defaults to a
    fixed PRNGKey(0) so sampled generation is usable (and reproducible)
    out of the box — passing rng=None used to crash in jax.random.split."""
    B, P = prompt_tokens.shape
    if temperature > 0.0 and rng is None:
        rng = jax.random.PRNGKey(0)
    cache = init_cache(cfg, B, s_max)
    step = jit_step or jax.jit(build_serve_step(cfg))
    toks = [prompt_tokens[:, i] for i in range(P)]
    logits = None
    for i in range(P):
        logits, cache = step(params, cache, toks[i])
    out = list(toks)
    for t in range(steps):
        if temperature > 0.0:
            rng, sub = jax.random.split(rng)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)
        out.append(nxt)
        logits, cache = step(params, cache, nxt)
    return jnp.stack(out, axis=1)
