"""Pallas TPU kernel for D3Q19 propagation (Ludwig "Propagation").

Stencil kernel: each output site reads 19 displaced neighbours.  targetDP
GPU codes implement this as 19 strided gathers; the TPU-native adaptation
streams x-slabs of the *halo'd* input through VMEM and materialises each
velocity's displaced window as a static slice — displacement becomes slice
arithmetic, which the VPU does as pure data movement.

Tiling: the grid runs over output x-slabs of ``bx`` planes.  The input
block (19, bx+2, Y+2, Z+2) is *not* expressible as a disjoint Blocked
window (windows overlap by the halo), so the input is staged whole into
VMEM.  VMEM budget (fp32): 19*(X+2)(Y+2)(Z+2)*4 B for the input stage plus
19*bx*Y*Z*4 B per output block — fine for the per-shard slabs used here
(e.g. 34^3 lattice = 3.2 MiB).  The production variant for >VMEM shards
adds y/z tiling with double-buffered ``make_async_copy`` DMA from an ANY-
space ref; the slab schedule and slice arithmetic are identical, which is
what the dry-run roofline models (propagation is pure HBM bandwidth either
way).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.maths import d3q19


def propagate_pallas(
    f_halo: jax.Array, *, width: int = 1, bx: int = 8, interpret: bool = True
) -> jax.Array:
    """f_halo: (19, X+2w, Y+2w, Z+2w) SoA canonical-nd, halos exchanged.
    Returns interior (19, X, Y, Z)."""
    nvel, xh, yh, zh = f_halo.shape
    w = width
    X, Y, Z = xh - 2 * w, yh - 2 * w, zh - 2 * w
    bx = min(bx, X)
    while X % bx:
        bx -= 1
    grid = (X // bx,)

    def kern(f_ref, out_ref):
        xs = pl.program_id(0) * bx  # output-slab origin (interior coords)
        f = f_ref[...]  # full halo'd stage (VMEM)
        outs = []
        for i in range(nvel):
            cx, cy, cz = (int(c) for c in d3q19.CV[i])
            # out_i(r) = f_i(r - c_i); interior r -> halo coords r + w
            sl = jax.lax.dynamic_slice(
                f,
                (i, xs + w - cx, w - cy, w - cz),
                (1, bx, Y, Z),
            )
            outs.append(sl[0])
        out_ref[...] = jnp.stack(outs)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nvel, xh, yh, zh), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((nvel, bx, Y, Z), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nvel, X, Y, Z), f_halo.dtype),
        interpret=interpret,
        name="lb_propagation",
    )(f_halo)
