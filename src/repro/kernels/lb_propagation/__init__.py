from .ops import propagate  # noqa: F401
