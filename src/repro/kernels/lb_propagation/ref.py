"""Pure-jnp oracle for D3Q19 propagation (Ludwig "Propagation").

Streaming step: f'_i(r + c_i) = f_i(r), i.e. out_i(r) = f_i(r - c_i).
Pure data movement (OI ~ 0 F/B — the paper's most bandwidth-bound kernel).
Periodic form uses rolls; halo form reads displaced interior windows of a
halo'd array (multi-shard path, halos filled by core.halo/Domain.exchange).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import stencil
from repro.maths import d3q19


def propagate_ref(f_nd: jnp.ndarray) -> jnp.ndarray:
    """Periodic propagation. f_nd: (19, X, Y, Z) canonical."""
    outs = []
    for i in range(d3q19.NVEL):
        disp = tuple(int(c) for c in d3q19.CV[i])
        outs.append(stencil.shift_periodic(f_nd[i : i + 1], disp)[0])
    return jnp.stack(outs)


def propagate_halo_ref(f_halo: jnp.ndarray, width: int = 1) -> jnp.ndarray:
    """Halo'd propagation. f_halo: (19, X+2w, Y+2w, Z+2w) with halos already
    exchanged; returns interior (19, X, Y, Z)."""
    site_dims = (1, 2, 3)
    outs = []
    for i in range(d3q19.NVEL):
        disp = tuple(int(c) for c in d3q19.CV[i])
        outs.append(
            stencil.shifted_window(f_halo[i : i + 1], disp, width, site_dims)[0]
        )
    return jnp.stack(outs)
