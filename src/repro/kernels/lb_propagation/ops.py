"""Public wrapper for LB propagation (engine dispatch)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import Field, TargetConfig, stencil
from . import kernel, ref


def propagate(dist: Field, *, config: TargetConfig) -> Field:
    """Periodic streaming step on a single shard (the multi-shard driver
    exchanges halos and calls the halo variants directly)."""
    f_nd = dist.canonical_nd()
    if config.engine == "jnp":
        out = ref.propagate_ref(f_nd)
    elif config.engine == "pallas":
        f_halo = stencil.halo_pad(f_nd, 1, (1, 2, 3))
        out = kernel.propagate_pallas(
            f_halo, width=1, interpret=config.resolved_interpret()
        )
    else:
        raise ValueError(f"unknown engine {config.engine!r}")
    return dist.with_canonical(out.reshape(dist.ncomp, dist.nsites))


def propagate_halo(dist_halo: jnp.ndarray, *, config: TargetConfig, width: int = 1):
    """Halo'd-array form used inside shard_map (halos already exchanged)."""
    if config.engine == "jnp":
        return ref.propagate_halo_ref(dist_halo, width)
    if config.engine == "pallas":
        return kernel.propagate_pallas(
            dist_halo, width=width, interpret=config.resolved_interpret()
        )
    raise ValueError(f"unknown engine {config.engine!r}")
