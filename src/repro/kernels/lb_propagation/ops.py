"""Public wrapper for LB propagation (engine dispatch) and the fused
collision -> propagation LB step.

Propagation is a stencil (site-neighbour gather), so it cannot be fused
site-locally into one pallas program with the collision; the fusion here is
at the launch level: both stages run inside one cached ``jax.jit`` callable,
so the post-collision distributions flow straight into the streaming step
without a host round-trip or re-trace per timestep (the collision itself
goes through the bespoke pallas kernel / jnp oracle as configured)."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import Field, Layout, TargetConfig, stencil
from . import kernel, ref


def propagate(dist: Field, *, config: TargetConfig) -> Field:
    """Periodic streaming step on a single shard (the multi-shard driver
    exchanges halos and calls the halo variants directly)."""
    f_nd = dist.canonical_nd()
    if config.engine == "jnp":
        out = ref.propagate_ref(f_nd)
    elif config.engine == "pallas":
        f_halo = stencil.halo_pad(f_nd, 1, (1, 2, 3))
        out = kernel.propagate_pallas(
            f_halo, width=1, interpret=config.resolved_interpret()
        )
    else:
        raise ValueError(f"unknown engine {config.engine!r}")
    return dist.with_canonical(out.reshape(dist.ncomp, dist.nsites))


@functools.lru_cache(maxsize=64)
def _fused_step(lattice: Tuple[int, ...], ncomp: int, lay: Layout,
                fncomp: int, flay: Layout, tau: float, config: TargetConfig):
    """Build + jit one collide->propagate step per (lattice, ncomps, layouts,
    tau, config); jax.jit handles dtype/shape retraces within an entry."""
    from repro.kernels.lb_collision.ops import collide

    def step(dist_data, force_data):
        d = Field("dist", ncomp, lattice, lay, dist_data)
        g = Field("force", fncomp, lattice, flay, force_data)
        d1 = collide(d, g, tau=tau, config=config)
        return propagate(d1, config=config).data

    return jax.jit(step)


def collide_propagate(
    dist: Field, force: Field, *, tau: float, config: TargetConfig
) -> Field:
    """Fused LB step: BGK collision immediately followed by streaming,
    compiled once per (layouts, lattice, tau, engine config) and cached.

    tau is static (baked into the compiled step, one cache entry per
    value) — for a traced tau sweep call collide/propagate directly."""
    fn = _fused_step(dist.lattice, dist.ncomp, dist.layout,
                     force.ncomp, force.layout, float(tau), config)
    return dist.with_data(fn(dist.data, force.data))


def propagate_halo(dist_halo: jnp.ndarray, *, config: TargetConfig, width: int = 1):
    """Halo'd-array form used inside shard_map (halos already exchanged)."""
    if config.engine == "jnp":
        return ref.propagate_halo_ref(dist_halo, width)
    if config.engine == "pallas":
        return kernel.propagate_pallas(
            dist_halo, width=width, interpret=config.resolved_interpret()
        )
    raise ValueError(f"unknown engine {config.engine!r}")
