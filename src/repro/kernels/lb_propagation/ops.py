"""Public wrapper for LB propagation (engine dispatch) and the fused
collision -> propagation LB step.

Propagation is a stencil (site-neighbour gather).  The fused step runs it
as a *stencil stage* of a ``core.fuse.LaunchGraph``: collision (site-local)
is recomputed on the halo ring of each VMEM-resident halo'd block, and the
streaming step gathers the displaced post-collision values straight out of
VMEM — one halo'd ``pallas_call`` per timestep, with no HBM round-trip for
the post-collision distributions (the HBM traffic a separate propagation
launch would mandate: one write + one read of the 19-component field).
"""

from __future__ import annotations


import jax.numpy as jnp

from repro.core import Field, LaunchGraph, TargetConfig, stencil
from repro.core.plan import interpret_for
from repro.kernels.lb_collision.ops import collide_kernel
from repro.maths import d3q19
from . import kernel, ref


def propagate(dist: Field, *, config: TargetConfig) -> Field:
    """Periodic streaming step on a single shard (the multi-shard driver
    exchanges halos and calls the halo variants directly)."""
    f_nd = dist.canonical_nd()
    if config.engine == "jnp":
        out = ref.propagate_ref(f_nd)
    elif config.engine == "pallas":
        f_halo = stencil.halo_pad(f_nd, 1, (1, 2, 3))
        out = kernel.propagate_pallas(
            f_halo, width=1, interpret=interpret_for(config))
    else:
        raise ValueError(f"unknown engine {config.engine!r}")
    return dist.with_canonical(out.reshape(dist.ncomp, dist.nsites))


def propagate_body(v, gather):
    """Propagation as a fused stencil-stage body: f'_i(r) = f_i(r - c_i),
    each velocity's displaced window materialized as slice arithmetic on the
    VMEM-resident halo'd block (no separate pallas_call)."""
    return {
        "dist": jnp.stack([
            gather("dist", tuple(int(c) for c in d3q19.CV[i]))[i]
            for i in range(d3q19.NVEL)
        ])
    }


def collide_propagate_graph(tau: float) -> LaunchGraph:
    """BGK collision fused *into* propagation's gather: ONE halo'd kernel.

    Collision is recomputed on halo sites (cheap, site-local) so the
    streaming gather reads post-collision neighbours from VMEM; the launch
    cache keys on (bodies, tau, layouts, lattice), so a timestep loop reuses
    the compiled callable."""
    return (
        LaunchGraph("lb_collide_propagate")
        .add(collide_kernel, {"dist": "dist", "force": "force"}, {"dist": 19},
             rename={"dist": "dist1"}, params=dict(tau=tau))
        .add_stencil(propagate_body, {"dist": "dist1"}, {"dist": 19},
                     width=1, rename={"dist": "dist2"})
    )


def collide_propagate(
    dist: Field, force: Field, *, tau: float, config: TargetConfig
) -> Field:
    """Fused LB step: BGK collision immediately followed by streaming, as a
    single halo'd launch (one pallas_call on the pallas engine).

    tau is static (baked into the launch-cache key, one entry per value) —
    for a traced tau sweep call collide/propagate directly."""
    out = collide_propagate_graph(float(tau)).launch(
        {"dist": dist, "force": force},
        config=config,
        outputs=("dist2",),
        out_layouts={"dist2": dist.layout},
    )["dist2"]
    return dist.with_data(out.data)


def propagate_halo(dist_halo: jnp.ndarray, *, config: TargetConfig, width: int = 1):
    """Halo'd-array form used inside shard_map (halos already exchanged)."""
    if config.engine == "jnp":
        return ref.propagate_halo_ref(dist_halo, width)
    if config.engine == "pallas":
        return kernel.propagate_pallas(
            dist_halo, width=width, interpret=interpret_for(config)
        )
    raise ValueError(f"unknown engine {config.engine!r}")
