"""Public wrapper for the RWKV6 WKV op (engine dispatch + jit-friendly)."""

from __future__ import annotations


import jax.numpy as jnp

from repro.core.target import _on_tpu
from . import kernel, ref


def rwkv6(
    r, k, v, w, u, s0=None, *, engine: str = "auto", chunk: int = 64
):
    """RWKV6 WKV over a sequence.

    r, k, w: (B, H, T, dk); v: (B, H, T, dv); u: (H, dk);
    s0: optional (B, H, dk, dv).
    Returns o (B, H, T, dv) in r.dtype, sT (B, H, dk, dv) fp32.

    engine: "auto" (pallas on TPU else chunked jnp), "jnp" (chunked),
            "scan" (exact sequential oracle), "pallas".
    """
    if engine == "auto":
        engine = "pallas" if _on_tpu() else "jnp"
    B, H, T, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, T)
    while T % chunk:
        chunk -= 1
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    if engine == "scan":
        o, sT = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    elif engine == "jnp":
        o, sT = ref.rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    elif engine == "pallas":
        BH = B * H
        rr = lambda x, d: x.reshape(BH, T, d)
        ub = jnp.broadcast_to(u[None], (B, H, dk)).reshape(BH, dk)
        o, sT = kernel.rwkv6_pallas(
            rr(r, dk),
            rr(k, dk),
            rr(v, dv),
            rr(w, dk),
            ub,
            s0.reshape(BH, dk, dv),
            chunk=chunk,
            interpret=not _on_tpu(),
        )
        o = o.reshape(B, H, T, dv)
        sT = sT.reshape(B, H, dk, dv)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    return o.astype(r.dtype), sT


def rwkv6_decode_step(r1, k1, v1, w1, u, s):
    """One autoregressive token: O(dk*dv) per head, no sequence dim.
    r1,k1,w1: (B,H,dk); v1: (B,H,dv); s: (B,H,dk,dv) fp32 carried state."""
    o, s = ref.rwkv6_decode_ref(r1, k1, v1, w1, u, s)
    return o.astype(r1.dtype), s
