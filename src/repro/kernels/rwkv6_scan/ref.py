"""RWKV6 ("Finch") WKV recurrence — oracle + chunked closed form.

Per head: state S in R^{dk x dv};  w_t in (0,1)^{dk} is the data-dependent
decay, u in R^{dk} the first-token bonus:

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

``rwkv6_scan_ref`` is the exact sequential oracle.  ``rwkv6_chunked`` is
the O(T/C * (C^2 dk + C dk dv)) block-parallel form used for prefill: all
pairwise decay factors are expressed as exp(L_{t-1,d} - L_{s,d}) with
L = cumsum(log w) — the exponent is <= 0 wherever the causal mask admits it,
so the chunked form is overflow-free by construction (unlike the 1/P
"unnormalized" trick common in GPU linear-attention kernels; this is the
TPU-friendly numerically-safe variant).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import probe as _probe


def rwkv6_scan_ref(r, k, v, w, u, s0=None):
    """Exact recurrence.

    r, k, w: (B, H, T, dk); v: (B, H, T, dv); u: (H, dk);
    s0: (B, H, dk, dv) or None.
    Returns o: (B, H, T, dv), sT: (B, H, dk, dv).  fp32 internally.
    """
    B, H, T, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), f32)

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B,H,dk) ... (B,H,dv)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,dk,dv)
        wkv = S + u[None, :, :, None] * kv                  # bonus on current
        ot = jnp.einsum("bhk,bhkv->bhv", rt, wkv)
        S = wt[..., :, None] * S + kv
        return S, ot

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (r, k, v, w))
    sT, o = jax.lax.scan(step, s0.astype(f32), xs)
    return jnp.moveaxis(o, 0, 2), sT


def chunk_body(r, k, v, lw, u, s0):
    """One chunk, one head: the body shared by the jnp engine and the
    pallas kernel.

    r, k: (C, dk); v: (C, dv); lw = log(w): (C, dk); u: (dk,);
    s0: (dk, dv).  Returns (o (C, dv), s1 (dk, dv)).
    """
    C, dk = r.shape
    Lc = jnp.cumsum(lw, axis=0)          # L_t, t = 1..C      (C, dk)
    Lprev = Lc - lw                      # L_{t-1}            (C, dk)

    q = r * jnp.exp(Lprev)               # decayed receptance
    inter = q @ s0                       # (C, dv) cross-chunk

    # intra-chunk pairwise: A[t,s] = sum_d r_td k_sd exp(L_{t-1,d} - L_{s,d})
    expo = Lprev[:, None, :] - Lc[None, :, :]          # (C, C, dk)
    expo = jnp.minimum(expo, 0.0)                      # masked region safety
    A = jnp.einsum("td,tsd,sd->ts", r, jnp.exp(expo), k)
    mask = jnp.tril(jnp.ones((C, C), A.dtype), k=-1)   # strictly causal
    intra = (A * mask) @ v                             # (C, dv)

    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v

    o = inter + intra + bonus

    # state propagation: S' = exp(L_C) . S0 + sum_s exp(L_C - L_s) k_s v_s^T
    decay_all = jnp.exp(Lc[-1])                        # (dk,)
    kd = k * jnp.exp(Lc[-1][None, :] - Lc)             # (C, dk)
    s1 = decay_all[:, None] * s0 + kd.T @ v
    return o, s1


def rwkv6_chunked(r, k, v, w, u, s0=None, *, chunk: int = 64):
    """Block-parallel closed form (jnp engine).  Same signature/returns as
    rwkv6_scan_ref; T must be a multiple of ``chunk``."""
    B, H, T, dk = r.shape
    dv = v.shape[-1]
    if T % chunk:
        raise ValueError(f"chunk={chunk} must divide T={T}")
    f32 = jnp.float32
    r, k, v = (x.astype(f32) for x in (r, k, v))
    # clamp: w can underflow to 0 (extreme decay); log(0) = -inf makes
    # (-inf) - (-inf) = NaN in the pairwise form.  exp(-60) is already far
    # below fp32 resolution of any accumulated state.
    lw = jnp.log(jnp.maximum(w.astype(f32), 1e-26))
    u = u.astype(f32)
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), f32)

    nC = T // chunk
    resh = lambda x, d: x.reshape(B, H, nC, chunk, d).transpose(2, 0, 1, 3, 4)
    rs, ks, lws = resh(r, dk), resh(k, dk), resh(lw, dk)
    vs = resh(v, dv)

    body = jax.vmap(jax.vmap(chunk_body, in_axes=(0, 0, 0, 0, 0, 0)),
                    in_axes=(0, 0, 0, 0, None, 0))
    # vmap over B (outer) then H (inner); u varies per head only.

    def scan_step(S, xs):
        rc, kc, vc, lwc = xs  # (B, H, C, d*)
        o, S1 = body(rc, kc, vc, lwc, u, S)
        return S1, o

    sT, os = jax.lax.scan(scan_step, s0, (rs, ks, vs, lws),
                          unroll=_probe.scan_unroll())
    # os: (nC, B, H, C, dv) -> (B, H, T, dv)
    o = os.transpose(1, 2, 0, 3, 4).reshape(B, H, T, dv)
    return o, sT


def rwkv6_decode_ref(r1, k1, v1, w1, u, s):
    """Single decode step.  r1,k1,w1: (B,H,dk); v1: (B,H,dv); s: (B,H,dk,dv).
    Returns (o (B,H,dv), s')."""
    f32 = jnp.float32
    r1, k1, v1, w1 = (x.astype(f32) for x in (r1, k1, v1, w1))
    kv = k1[..., :, None] * v1[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", r1, s + u.astype(f32)[None, :, :, None] * kv)
    s = w1[..., :, None] * s + kv
    return o, s
