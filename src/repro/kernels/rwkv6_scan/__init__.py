from .ops import rwkv6, rwkv6_decode_step  # noqa: F401
