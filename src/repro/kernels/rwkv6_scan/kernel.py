"""Pallas TPU kernel for the RWKV6 chunked WKV recurrence.

TPU adaptation of the GPU recurrent/chunked WKV kernels (e.g. FLA): the
pallas grid is (B*H, T/C) with the chunk dimension minor-most — on TPU the
grid executes *sequentially* per core, so the (dk, dv) recurrent state is
carried across chunk steps in a VMEM scratch buffer, replacing the CUDA
pattern of one threadblock owning a head and looping over time.  Between
heads (major grid dim) the state is re-initialised from the s0 input.

Blocks per program (fp32): r/k/w (C, dk), v (C, dv), o (C, dv), state
(dk, dv), u (dk,) plus the (C, C, dk) pairwise-decay temporary.  With
C = dk = dv = 64: ~1.2 MiB — comfortably inside VMEM; C=64, dk=128:
~4.5 MiB, still fine.  All matmul shapes are (C, dk)x(dk, dv) and
(C, C)x(C, dv) — MXU-aligned when C, dk, dv are multiples of 128 (bf16) /
8x128 tiles (fp32); dk=dv=64 heads still map efficiently via 2x packing.

The chunk math is ref.chunk_body — the identical source traced by the jnp
engine (paper C1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref


def rwkv6_pallas(r, k, v, w, u, s0, *, chunk: int = 64, interpret: bool = True):
    """r,k,w: (BH, T, dk); v: (BH, T, dv); u: (BH, dk); s0: (BH, dk, dv).
    Returns o (BH, T, dv), sT (BH, dk, dv).  fp32 in/out."""
    BH, T, dk = r.shape
    dv = v.shape[-1]
    C = chunk
    if T % C:
        raise ValueError(f"chunk={C} must divide T={T}")
    nC = T // C
    grid = (BH, nC)  # minor-most (chunk) dim iterates fastest => sequential

    def kern(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref, s_scr):
        tc = pl.program_id(1)

        @pl.when(tc == 0)
        def _init():
            s_scr[...] = s0_ref[0]

        rc = r_ref[0]
        kc = k_ref[0]
        vc = v_ref[0]
        lwc = jnp.log(jnp.maximum(w_ref[0], 1e-26))
        uu = u_ref[0]
        o, s1 = ref.chunk_body(rc, kc, vc, lwc, uu, s_scr[...])
        o_ref[0] = o
        s_scr[...] = s1
        sT_ref[0] = s1  # last write (tc == nC-1) is the final state

    seq_spec = lambda d: pl.BlockSpec((1, C, d), lambda bh, tc: (bh, tc, 0))
    head_spec2 = lambda d: pl.BlockSpec((1, d), lambda bh, tc: (bh, 0))
    head_spec3 = pl.BlockSpec((1, dk, dv), lambda bh, tc: (bh, 0, 0))

    o, sT = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            seq_spec(dk),  # r
            seq_spec(dk),  # k
            seq_spec(dv),  # v
            seq_spec(dk),  # w
            head_spec2(dk),  # u
            head_spec3,  # s0
        ],
        out_specs=[seq_spec(dv), head_spec3],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, dv), jnp.float32),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
        name="rwkv6_scan",
    )(
        r.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        w.astype(jnp.float32),
        u.astype(jnp.float32),
        s0.astype(jnp.float32),
    )
    return o, sT
