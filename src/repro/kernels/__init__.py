"""Pallas TPU kernels for the performance hot-spots.

Each kernel package has three files (per the repo convention):
  kernel.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target,
             validated in interpret mode on CPU)
  ref.py     pure-jnp oracle (also the "host C" engine of the paper)
  ops.py     jit'd public wrapper with engine dispatch

Hot-spots mirror the paper's profiled kernels: LB collision & propagation
(Ludwig), the Wilson-Dirac hopping term (MILC), and — for the assigned LM
architectures — the RWKV6 chunked linear-recurrence scan.
"""
