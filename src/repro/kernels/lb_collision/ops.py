"""Public wrapper for the LB collision kernel (engine dispatch + jit)."""

from __future__ import annotations



from repro.core import Field, TargetConfig, TargetKernel
from repro.core.plan import plan_for_launch
from . import kernel, ref


def _collide_body(v, *, tau: float):
    """Site-local chunk body — the same source as the bespoke pallas kernel,
    exposed as a TargetKernel so collision can join fused launch graphs
    (core.fuse) with other site-local stages."""
    return {"dist": ref.collide_chunk(v["dist"], v["force"], tau)}


collide_kernel = TargetKernel(_collide_body, name="lb_collision")


def collide(
    dist: Field, force: Field, *, tau: float, config: TargetConfig
) -> Field:
    """Post-collision distributions; same Field layout/lattice as ``dist``."""
    if config.engine == "jnp":
        out = ref.collide_ref(dist.canonical(), force.canonical(), tau)
        return dist.with_canonical(out)
    if config.engine == "pallas":
        # vvl/interpret through the planning layer (auto-vvl, plan policy)
        plan = plan_for_launch(config, dist.nsites, [dist.layout, force.layout])
        phys = kernel.collide_pallas(
            dist.data,
            force.data,
            tau=tau,
            layout=dist.layout,
            force_layout=force.layout,
            vvl=plan.vvl,
            nsites=dist.nsites,
            interpret=plan.interpret,
        )
        return dist.with_data(phys)
    raise ValueError(f"unknown engine {config.engine!r}")
