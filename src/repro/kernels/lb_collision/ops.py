"""Public wrapper for the LB collision kernel (engine dispatch + jit)."""

from __future__ import annotations

import functools

import jax

from repro.core import Field, TargetConfig
from . import kernel, ref


def collide(
    dist: Field, force: Field, *, tau: float, config: TargetConfig
) -> Field:
    """Post-collision distributions; same Field layout/lattice as ``dist``."""
    if config.engine == "jnp":
        out = ref.collide_ref(dist.canonical(), force.canonical(), tau)
        return dist.with_canonical(out)
    if config.engine == "pallas":
        phys = kernel.collide_pallas(
            dist.data,
            force.data,
            tau=tau,
            layout=dist.layout,
            force_layout=force.layout,
            vvl=config.vvl,
            nsites=dist.nsites,
            interpret=config.resolved_interpret(),
        )
        return dist.with_data(phys)
    raise ValueError(f"unknown engine {config.engine!r}")
