"""Pallas TPU kernel for the D3Q19 BGK collision (Ludwig "Collision").

Site-local and embarrassingly data-parallel: a 1-D grid of site blocks, one
block of VVL sites per program.  The VMEM tiles are derived from the Field
Layout exactly as targetDP derives addresses from INDEX():

  SoA         block (19, VVL)           — lane axis = sites (TPU-native)
  AoS         block (VVL, 19)           — deliberately wrong on TPU: minor
                                          dim 19 pads to 128 lanes (C2)
  AoSoA(SAL)  block (VVL/SAL, 19, SAL)  — short arrays ride the lanes

VMEM budget per program (fp32): (19 + 3 + 19) * VVL * 4 bytes plus
temporaries ~ 5 * VVL * 4; at VVL=1024 that is ~188 KiB, far under the
~16 MiB/core VMEM, so VVL can be raised until the grid is coarse enough to
amortize control overhead (the paper tunes VVL the same way, §3.2.2).

The body is ``ref.collide_chunk`` — the same source the jnp engine runs.
"""

from __future__ import annotations


import jax
from jax.experimental import pallas as pl

from repro.core.layout import Layout
from . import ref


def collide_pallas(
    dist: jax.Array,
    force: jax.Array,
    *,
    tau: float,
    layout: Layout,
    force_layout: Layout,
    vvl: int,
    nsites: int,
    interpret: bool = True,
) -> jax.Array:
    """dist/force are *physical* arrays in their layouts; returns physical."""
    if nsites % vvl:
        raise ValueError(f"vvl={vvl} must divide nsites={nsites}")
    grid = (nsites // vvl,)
    nvel, ndim = 19, 3

    def kern(f_ref, frc_ref, out_ref):
        f = layout.block_to_canonical(f_ref[...], nvel, vvl)
        frc = force_layout.block_to_canonical(frc_ref[...], ndim, vvl)
        out = ref.collide_chunk(f, frc, tau)
        out_ref[...] = layout.canonical_to_block(out, nvel, vvl)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(layout.block_shape(nvel, vvl), layout.block_index_map()),
            pl.BlockSpec(
                force_layout.block_shape(ndim, vvl), force_layout.block_index_map()
            ),
        ],
        out_specs=pl.BlockSpec(layout.block_shape(nvel, vvl), layout.block_index_map()),
        out_shape=jax.ShapeDtypeStruct(
            layout.physical_shape(nvel, nsites), dist.dtype
        ),
        interpret=interpret,
        name="lb_collision",
    )(dist, force)
