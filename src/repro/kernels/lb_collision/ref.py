"""Pure-jnp oracle for the D3Q19 BGK collision with Guo forcing.

This is Ludwig's "Collision" kernel (paper §2.1.1): site-local, the most
FLOP-dense part of the LB update (OI ~ 1.9 F/B in the paper's Fig. 4).

``collide_chunk`` is written on canonical (ncomp, VVL) chunks, so the very
same function body is traced by the jnp engine (whole lattice as one chunk)
and inside the pallas kernel (one VMEM block per call) — the paper's
single-source property.

The velocity set is unrolled at trace time with Python-int coefficients
(c_ia in {-1,0,1}), as production LB kernels do: dot products with c_i
become adds/subs, no array-valued constants enter the kernel (a pallas
requirement, and on TPU it keeps everything in VPU adds).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.maths import d3q19

_CV = [tuple(int(c) for c in row) for row in d3q19.CV]
_WV = [float(w) for w in d3q19.WV]


def _cdot(c, vec3):
    """c . vec with c in {-1,0,1}^3 and vec3 a list of 3 arrays."""
    out = None
    for ca, va in zip(c, vec3):
        if ca == 0:
            continue
        term = va if ca == 1 else -va
        out = term if out is None else out + term
    if out is None:
        return jnp.zeros_like(vec3[0])
    return out


def collide_chunk(f: jnp.ndarray, force: jnp.ndarray, tau: float):
    """BGK collision + Guo forcing on a chunk of sites.

    f      (19, VVL) distributions
    force  (3, VVL)  body force (e.g. divergence of the chemical stress)
    tau    relaxation time (static)
    returns (19, VVL) post-collision distributions
    """
    rho = jnp.sum(f, axis=0)  # (VVL,)
    # momentum = sum_i c_i f_i, unrolled
    mom = [None, None, None]
    for i, c in enumerate(_CV):
        for a in range(3):
            if c[a]:
                term = f[i] if c[a] == 1 else -f[i]
                mom[a] = term if mom[a] is None else mom[a] + term
    frc = [force[a] for a in range(3)]
    u = [(mom[a] + 0.5 * frc[a]) / rho for a in range(3)]

    usq = u[0] * u[0] + u[1] * u[1] + u[2] * u[2]
    uf = u[0] * frc[0] + u[1] * frc[1] + u[2] * frc[2]
    pref = 1.0 - 0.5 / tau
    omega = 1.0 / tau

    outs = []
    for i, c in enumerate(_CV):
        w = _WV[i]
        cu = _cdot(c, u)
        cf = _cdot(c, frc)
        feq = w * rho * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq)
        fi = pref * w * (3.0 * (cf - uf) + 9.0 * cu * cf)
        outs.append(f[i] - omega * (f[i] - feq) + fi)
    return jnp.stack(outs)


def collide_ref(f: jnp.ndarray, force: jnp.ndarray, tau: float) -> jnp.ndarray:
    """Oracle on the full canonical lattice (19, N) x (3, N)."""
    return collide_chunk(f, force, tau)


def moments(f: jnp.ndarray):
    """(rho, u (3, N)) hydrodynamic moments of (19, N) distributions."""
    rho = jnp.sum(f, axis=0)
    mom = [None, None, None]
    for i, c in enumerate(_CV):
        for a in range(3):
            if c[a]:
                term = f[i] if c[a] == 1 else -f[i]
                mom[a] = term if mom[a] is None else mom[a] + term
    u = jnp.stack([mom[a] / rho for a in range(3)])
    return rho, u


def equilibrium(rho: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """f_eq for given (rho (N,), u (3, N)) — initialization helper."""
    ul = [u[a] for a in range(3)]
    usq = ul[0] * ul[0] + ul[1] * ul[1] + ul[2] * ul[2]
    outs = []
    for i, c in enumerate(_CV):
        cu = _cdot(c, ul)
        outs.append(_WV[i] * rho * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq))
    return jnp.stack(outs)
