from .ops import collide  # noqa: F401
