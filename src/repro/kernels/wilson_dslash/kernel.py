"""Pallas TPU kernel for the fused site-local Wilson-dslash stage.

After the Shift stage gathers the 8 neighbour spinors (halo windows across
shards, rolls within), the hopping term is site-local: per site read
72 + 72 + 192 fp32 components, write 24, ~1320 flops.  The grid is 1-D over
site blocks of VVL sites; the three input Fields and the output share the
Layout-derived BlockSpecs of the core layer, so layout is a config knob
here exactly as in the collision kernel.

VMEM per program (fp32): (72+72+192+24) * VVL * 4 B = 1.4 KiB/site; VVL=512
-> ~0.7 MiB plus temporaries; hardware-aligned when VVL is a multiple of
128.  The color einsums contract a length-3 axis — too small for the MXU —
so the multiply-adds run on the VPU across the VVL lane axis, which is why
AoSoA/SoA (sites minor) is the right layout on TPU and AoS collapses
(paper C2, quantified in benchmarks/fig4).
"""

from __future__ import annotations

import jax
from jax.experimental import pallas as pl

from repro.core.layout import Layout
from . import ref


def dslash_site_pallas(
    u_fwd: jax.Array,
    u_bwd: jax.Array,
    nbrs: jax.Array,
    *,
    layout: Layout,
    vvl: int,
    nsites: int,
    interpret: bool = True,
) -> jax.Array:
    """Physical arrays in `layout`; returns physical (24-comp) D psi."""
    if nsites % vvl:
        raise ValueError(f"vvl={vvl} must divide nsites={nsites}")
    grid = (nsites // vvl,)
    NU, NN, NS = ref.GAUGE_NCOMP, ref.NBR_NCOMP, ref.SPINOR_NCOMP

    def kern(uf_ref, ub_ref, nb_ref, out_ref):
        uf = layout.block_to_canonical(uf_ref[...], NU, vvl)
        ub = layout.block_to_canonical(ub_ref[...], NU, vvl)
        nb = layout.block_to_canonical(nb_ref[...], NN, vvl)
        out = ref.dslash_site_chunk(uf, ub, nb)
        out_ref[...] = layout.canonical_to_block(out, NS, vvl)

    spec = lambda ncomp: pl.BlockSpec(
        layout.block_shape(ncomp, vvl), layout.block_index_map()
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec(NU), spec(NU), spec(NN)],
        out_specs=spec(NS),
        out_shape=jax.ShapeDtypeStruct(
            layout.physical_shape(NS, nsites), u_fwd.dtype
        ),
        interpret=interpret,
        name="wilson_dslash",
    )(u_fwd, u_bwd, nbrs)
