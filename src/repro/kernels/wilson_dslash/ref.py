"""Pure-jnp oracle for the Wilson-Dirac hopping term (MILC).

D psi(x) = sum_mu [ (1 - gamma_mu) U_mu(x)        psi(x + mu)
                  + (1 + gamma_mu) U_mu^dag(x-mu) psi(x - mu) ]

MILC decomposes this into "Extract" (spin projection), "Extract and Mult"
(SU(3) x half-spinor), "Insert (and Mult)" (reconstruction) and "Shift"
(neighbour gather) kernels — paper §2.1.2.  ``dslash_site_chunk`` fuses the
site-local parts on canonical chunks (same source for both engines);
``dslash_ref`` adds the periodic Shift and is the end-to-end oracle.

Storage (fp32 pairs, no complex dtype on TPU):
  spinor field  ncomp = 24: index = (spin*3 + color)*2 + reim
  gauge field   ncomp = 72: index = ((mu*3 + a)*3 + b)*2 + reim
  neighbour pack ncomp = 192: mu-major, forward then backward spinor.

Flops: 8 directions x (proj 24 + su3*halfspinor 132 + reconstruct ~12)
~ 1320 flops/site, the textbook Wilson-dslash count; with 24+72(+72 read
bw links)+192 reads and 24 writes the OI sits ~1 F/B — memory-bound on
every architecture in Table 1 and still memory-bound against TPU v5e's
240 F/B ridge.
"""

from __future__ import annotations


import jax.numpy as jnp

from repro.core import stencil
from repro.maths import su3

NSPIN, NCOL = 4, 3
SPINOR_NCOMP = NSPIN * NCOL * 2      # 24
GAUGE_NCOMP = 4 * NCOL * NCOL * 2    # 72
NBR_NCOMP = 8 * SPINOR_NCOMP         # 192


# -- (ncomp, ...) <-> re/im pair views --------------------------------------

def spinor_pair(chunk: jnp.ndarray) -> su3.Pair:
    """(24, ...) -> ((4,3,...), (4,3,...))."""
    s = chunk.reshape((NSPIN, NCOL, 2) + chunk.shape[1:])
    return s[:, :, 0], s[:, :, 1]


def pair_spinor(p: su3.Pair) -> jnp.ndarray:
    """((4,3,...), (4,3,...)) -> (24, ...)."""
    re, im = p
    out = jnp.stack([re, im], axis=2)  # (4,3,2,...)
    return out.reshape((SPINOR_NCOMP,) + re.shape[2:])


def gauge_pair(chunk: jnp.ndarray, mu: int) -> su3.Pair:
    """(72, ...) -> ((3,3,...), (3,3,...)) link for direction mu."""
    g = chunk.reshape((4, NCOL, NCOL, 2) + chunk.shape[1:])
    return g[mu, :, :, 0], g[mu, :, :, 1]


# -- the site-local fused kernel body ----------------------------------------

def dslash_site_chunk(
    u_fwd: jnp.ndarray, u_bwd: jnp.ndarray, nbrs: jnp.ndarray
) -> jnp.ndarray:
    """Fused project/mult/reconstruct over all 8 directions.

    u_fwd (72, VVL) U_mu(x);  u_bwd (72, VVL) U_mu(x - mu);
    nbrs  (192, VVL) [psi(x+mu), psi(x-mu)] per mu.
    Returns D psi (24, VVL).
    """
    acc = None
    for mu in range(4):
        fwd = spinor_pair(nbrs[mu * 48 : mu * 48 + 24])
        bwd = spinor_pair(nbrs[mu * 48 + 24 : mu * 48 + 48])
        u = gauge_pair(u_fwd, mu)
        ub = gauge_pair(u_bwd, mu)

        # forward: (1 - gamma_mu) U psi(x+mu); project first (halves work)
        h = su3.project_minus(fwd, mu)            # (2,3,...) pair
        uh = su3.su3_mult_halfspinor(u, h)        # einsum over color
        full = su3.reconstruct_minus(uh, mu)      # (4,3,...) pair

        # backward: (1 + gamma_mu) U^dag psi(x-mu)
        hb = su3.project_plus(bwd, mu)
        uhb = su3.su3_adj_mult_halfspinor(ub, hb)
        fullb = su3.reconstruct_plus(uhb, mu)

        term = su3.cadd(full, fullb)
        acc = term if acc is None else su3.cadd(acc, term)
    return pair_spinor(acc)


# -- neighbour gather (the MILC "Shift" kernel) -------------------------------

def gather_neighbours_periodic(psi_nd: jnp.ndarray) -> jnp.ndarray:
    """psi_nd (24, X, Y, Z, T) -> nbr pack (192, X, Y, Z, T), periodic."""
    packs = []
    for mu in range(4):
        e = [0, 0, 0, 0]
        e[mu] = 1
        # psi(x + mu): out(r) = in(r - disp) with disp = -e
        packs.append(stencil.shift_periodic(psi_nd, [-x for x in e]))
        packs.append(stencil.shift_periodic(psi_nd, e))
    return jnp.concatenate(packs, axis=0)


def gather_gauge_bwd_periodic(u_nd: jnp.ndarray) -> jnp.ndarray:
    """U_mu(x - mu) per mu: shift each direction's links forward."""
    outs = []
    for mu in range(4):
        e = [0, 0, 0, 0]
        e[mu] = 1
        outs.append(stencil.shift_periodic(u_nd[mu * 18 : (mu + 1) * 18], e))
    return jnp.concatenate(outs, axis=0)


# -- end-to-end oracle --------------------------------------------------------

def dslash_ref(psi_nd: jnp.ndarray, u_nd: jnp.ndarray) -> jnp.ndarray:
    """Full periodic D psi. psi_nd (24, X,Y,Z,T), u_nd (72, X,Y,Z,T)."""
    lat = psi_nd.shape[1:]
    nbrs = gather_neighbours_periodic(psi_nd)
    u_bwd = gather_gauge_bwd_periodic(u_nd)
    flat = lambda a: a.reshape(a.shape[0], -1)
    out = dslash_site_chunk(flat(u_nd), flat(u_bwd), flat(nbrs))
    return out.reshape((SPINOR_NCOMP,) + lat)


def wilson_matvec_ref(
    psi_nd: jnp.ndarray, u_nd: jnp.ndarray, kappa: float
) -> jnp.ndarray:
    """M psi = psi - kappa * D psi (MILC's Wilson matrix convention)."""
    return psi_nd - kappa * dslash_ref(psi_nd, u_nd)
