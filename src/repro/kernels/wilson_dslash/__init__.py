from .ops import dslash, wilson_matvec  # noqa: F401
