"""Public wrapper for the Wilson-Dirac operator (engine dispatch)."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import Field, TargetConfig
from . import kernel, ref


def dslash(psi: Field, u: Field, *, config: TargetConfig) -> Field:
    """D psi on a single shard (periodic). psi: 24-comp, u: 72-comp fields
    over a 4-D lattice."""
    psi_nd, u_nd = psi.canonical_nd(), u.canonical_nd()
    if config.engine == "jnp":
        out = ref.dslash_ref(psi_nd, u_nd)
        return psi.with_canonical(out.reshape(psi.ncomp, psi.nsites))
    if config.engine == "pallas":
        nbrs = ref.gather_neighbours_periodic(psi_nd)
        u_bwd = ref.gather_gauge_bwd_periodic(u_nd)
        flat = lambda a: a.reshape(a.shape[0], -1)
        lay = psi.layout
        out_phys = kernel.dslash_site_pallas(
            lay.pack(flat(u_nd)),
            lay.pack(flat(u_bwd)),
            lay.pack(flat(nbrs)),
            layout=lay,
            vvl=config.vvl,
            nsites=psi.nsites,
            interpret=config.resolved_interpret(),
        )
        return psi.with_data(out_phys)
    raise ValueError(f"unknown engine {config.engine!r}")


def dslash_halo(
    psi_h: jnp.ndarray, u_h: jnp.ndarray, *, config: TargetConfig, width: int = 1
) -> jnp.ndarray:
    """Halo'd-array form for shard_map: psi_h (24, X+2w, ...), u_h (72, ...)
    with halos exchanged; returns interior D psi (24, X, Y, Z, T).

    The periodic gathers on the halo'd local array read at most ``width``
    into the halo (neighbour data), so the cropped interior is exact.
    """

    def crop(x):
        sl = (slice(None),) + tuple(
            slice(width, s - width) for s in x.shape[1:]
        )
        return x[sl]

    nbrs = crop(ref.gather_neighbours_periodic(psi_h))
    u_bwd = crop(ref.gather_gauge_bwd_periodic(u_h))
    u_fwd = crop(u_h)
    lat = u_fwd.shape[1:]
    flat = lambda a: a.reshape(a.shape[0], -1)
    if config.engine == "jnp":
        out = ref.dslash_site_chunk(flat(u_fwd), flat(u_bwd), flat(nbrs))
    elif config.engine == "pallas":
        from repro.core.layout import SOA

        nsites = int(np.prod(lat))
        out_phys = kernel.dslash_site_pallas(
            flat(u_fwd), flat(u_bwd), flat(nbrs),
            layout=SOA, vvl=config.vvl, nsites=nsites,
            interpret=config.resolved_interpret(),
        )
        out = out_phys
    else:
        raise ValueError(f"unknown engine {config.engine!r}")
    return out.reshape((ref.SPINOR_NCOMP,) + lat)


def wilson_matvec(psi: Field, u: Field, *, kappa: float, config: TargetConfig) -> Field:
    """M psi = psi - kappa D psi."""
    d = dslash(psi, u, config=config)
    return psi.with_canonical(psi.canonical() - kappa * d.canonical())
