"""Public wrapper for the Wilson-Dirac operator (engine dispatch), plus the
stencil-stage body that lets dslash join fused launch graphs (core.fuse):
the MILC "Shift" kernel becomes gather calls on a VMEM-resident halo'd
block, feeding the site-local project/mult/reconstruct math in the same
kernel — so D psi fuses with the CG axpy chain and the residual reduction
(see apps/milc/cg.py)."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import Field, TargetConfig
from repro.core.plan import plan_for_launch
from . import kernel, ref


def dslash_stencil_body(v, gather):
    """Fused-graph stencil stage: v = {"psi": (24, *win), "u": (72, *win)}.

    Gathers the 8 neighbour spinors and the backward gauge links from the
    halo'd window (the "Shift" kernel, width 1), then runs the site-local
    hopping term — returns {"d": D psi (24, *win_out)}."""
    packs = []
    for mu in range(4):
        e = [0, 0, 0, 0]
        e[mu] = 1
        # psi(x + mu): out(r) = in(r - d) with d = -e
        packs.append(gather("psi", tuple(-x for x in e)))
        packs.append(gather("psi", tuple(e)))
    nbrs = jnp.concatenate(packs, axis=0)                       # (192, *win)
    u_fwd = v["u"]
    u_bwd = jnp.concatenate(
        [gather("u", (0,) * mu + (1,) + (0,) * (3 - mu))[mu * 18:(mu + 1) * 18]
         for mu in range(4)],
        axis=0,
    )                                                           # (72, *win)
    win = u_fwd.shape[1:]
    flat = lambda a: a.reshape(a.shape[0], -1)
    out = ref.dslash_site_chunk(flat(u_fwd), flat(u_bwd), flat(nbrs))
    return {"d": out.reshape((ref.SPINOR_NCOMP,) + win)}


def dslash(psi: Field, u: Field, *, config: TargetConfig) -> Field:
    """D psi on a single shard (periodic). psi: 24-comp, u: 72-comp fields
    over a 4-D lattice."""
    psi_nd, u_nd = psi.canonical_nd(), u.canonical_nd()
    if config.engine == "jnp":
        out = ref.dslash_ref(psi_nd, u_nd)
        return psi.with_canonical(out.reshape(psi.ncomp, psi.nsites))
    if config.engine == "pallas":
        nbrs = ref.gather_neighbours_periodic(psi_nd)
        u_bwd = ref.gather_gauge_bwd_periodic(u_nd)
        flat = lambda a: a.reshape(a.shape[0], -1)
        lay = psi.layout
        # vvl/interpret through the planning layer (auto-vvl: the seed
        # passed config.vvl raw and raised on non-dividing lattices)
        plan = plan_for_launch(config, psi.nsites, [lay])
        out_phys = kernel.dslash_site_pallas(
            lay.pack(flat(u_nd)),
            lay.pack(flat(u_bwd)),
            lay.pack(flat(nbrs)),
            layout=lay,
            vvl=plan.vvl,
            nsites=psi.nsites,
            interpret=plan.interpret,
        )
        return psi.with_data(out_phys)
    raise ValueError(f"unknown engine {config.engine!r}")


def dslash_halo(
    psi_h: jnp.ndarray, u_h: jnp.ndarray, *, config: TargetConfig, width: int = 1
) -> jnp.ndarray:
    """Halo'd-array form for shard_map: psi_h (24, X+2w, ...), u_h (72, ...)
    with halos exchanged; returns interior D psi (24, X, Y, Z, T).

    The periodic gathers on the halo'd local array read at most ``width``
    into the halo (neighbour data), so the cropped interior is exact.
    """

    def crop(x):
        sl = (slice(None),) + tuple(
            slice(width, s - width) for s in x.shape[1:]
        )
        return x[sl]

    nbrs = crop(ref.gather_neighbours_periodic(psi_h))
    u_bwd = crop(ref.gather_gauge_bwd_periodic(u_h))
    u_fwd = crop(u_h)
    lat = u_fwd.shape[1:]
    flat = lambda a: a.reshape(a.shape[0], -1)
    if config.engine == "jnp":
        out = ref.dslash_site_chunk(flat(u_fwd), flat(u_bwd), flat(nbrs))
    elif config.engine == "pallas":
        from repro.core.layout import SOA

        nsites = int(np.prod(lat))
        plan = plan_for_launch(config, nsites, [SOA])
        out_phys = kernel.dslash_site_pallas(
            flat(u_fwd), flat(u_bwd), flat(nbrs),
            layout=SOA, vvl=plan.vvl, nsites=nsites,
            interpret=plan.interpret,
        )
        out = out_phys
    else:
        raise ValueError(f"unknown engine {config.engine!r}")
    return out.reshape((ref.SPINOR_NCOMP,) + lat)


def wilson_matvec(psi: Field, u: Field, *, kappa: float, config: TargetConfig) -> Field:
    """M psi = psi - kappa D psi."""
    d = dslash(psi, u, config=config)
    return psi.with_canonical(psi.canonical() - kappa * d.canonical())
