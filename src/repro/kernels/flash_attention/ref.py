"""Pure-jnp oracle for the GQA flash-attention kernel.

Computes masked softmax attention per (batch, kv-group, rep) with the
same grouped layout the kernel uses:
  q: (BG, S, dh) where BG = B * KV * rep (grouped queries, row-major)
  k, v: (BKV, S, dh) where BKV = B * KV (each row serves `rep` q rows)
"""

from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def flash_ref(q, k, v, *, rep: int, causal: bool = True, window: int = 0):
    """Returns (BG, S, dh) in q.dtype; softmax statistics in fp32."""
    BG, S, dh = q.shape
    kk = jnp.repeat(k, rep, axis=0)
    vv = jnp.repeat(v, rep, axis=0)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok = ok & (kj <= qi)
    if window > 0:
        ok = ok & (qi - kj < window)
    s = jnp.where(ok[None], s, NEG_INF)
    w = jnp.exp(s - s.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", w, vv.astype(jnp.float32)).astype(q.dtype)
