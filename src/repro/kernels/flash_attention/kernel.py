"""Pallas TPU flash attention (GQA, causal/windowed) — the beyond-paper
optimization identified by the train_4k hillclimb (EXPERIMENTS.md §Perf).

The HLO profile of the baseline train step shows the dominant memory-term
contributor is S^2-sized fp32 score traffic (scores, mask, softmax ops,
and their transposes/gradients) materialized between fusion boundaries —
~10 GiB/layer/device at train_4k.  This kernel keeps the entire score
block in VMEM (the targetDP memory-space discipline applied one level
down): HBM sees only q/k/v/out.

Design (TPU v5e):
  grid = (BG, S/qb) with BG = B*KV*rep grouped query rows.  Per program:
    q block   (qb, dh)            VMEM via BlockSpec
    k, v      (S, dh) full rows   VMEM via BlockSpec (index_map bg//rep —
                                  GQA sharing without materialized repeat)
    scores    (qb, S) fp32        VMEM value (never HBM)
  qb=256, S=4096, dh=128 -> ~4.5 MiB/program: scores 4 MiB + k/v 2 MiB.
  For S beyond ~16k the k/v rows outgrow VMEM and the kv-chunked variant
  (online softmax over pl.ds slices, same math as models.attention's
  blockwise path) takes over; both are exercised in interpret mode.

Mask arithmetic uses broadcasted_iota (TPU needs >=2-D iota).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _mask(qi0, qb, S, causal: bool, window: int):
    qi = qi0 + jax.lax.broadcasted_iota(jnp.int32, (qb, S), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (qb, S), 1)
    ok = jnp.ones((qb, S), bool)
    if causal:
        ok = ok & (kj <= qi)
    if window > 0:
        ok = ok & (qi - kj < window)
    return ok


def flash_pallas(q, k, v, *, rep: int, causal: bool = True, window: int = 0,
                 q_block: int = 256, interpret: bool = True):
    """q: (BG, S, dh); k/v: (BKV, S, dh); BG = BKV * rep.
    Returns (BG, S, dh) in q.dtype."""
    BG, S, dh = q.shape
    qb = min(q_block, S)
    while S % qb:
        qb -= 1
    scale = 1.0 / math.sqrt(dh)
    grid = (BG, S // qb)

    def kern(q_ref, k_ref, v_ref, o_ref):
        qi0 = pl.program_id(1) * qb
        qblk = q_ref[0].astype(jnp.float32)          # (qb, dh)
        kall = k_ref[0].astype(jnp.float32)          # (S, dh)
        vall = v_ref[0].astype(jnp.float32)
        s = qblk @ kall.T * scale                    # (qb, S) fp32, VMEM only
        ok = _mask(qi0, qb, S, causal, window)
        s = jnp.where(ok, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        o = (p @ vall) / jnp.sum(p, axis=-1, keepdims=True)
        o_ref[0] = o.astype(o_ref.dtype)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, dh), lambda bg, qi: (bg, qi, 0)),
            pl.BlockSpec((1, S, dh), lambda bg, qi: (bg // rep, 0, 0)),
            pl.BlockSpec((1, S, dh), lambda bg, qi: (bg // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, dh), lambda bg, qi: (bg, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BG, S, dh), q.dtype),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)


def flash_pallas_kvchunk(q, k, v, *, rep: int, causal: bool = True,
                         window: int = 0, q_block: int = 256,
                         kv_block: int = 1024, interpret: bool = True):
    """Long-sequence variant: online softmax over kv chunks so VMEM holds
    only (qb, kvb) scores + running stats; k/v stream through VMEM blocks
    via a third grid dimension (sequential minor-most on TPU)."""
    BG, S, dh = q.shape
    qb = min(q_block, S)
    while S % qb:
        qb -= 1
    kvb = min(kv_block, S)
    while S % kvb:
        kvb -= 1
    nk = S // kvb
    scale = 1.0 / math.sqrt(dh)
    grid = (BG, S // qb, nk)

    def kern(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
        ki = pl.program_id(2)

        @pl.when(ki == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
            l_ref[...] = jnp.zeros_like(l_ref)

        qi0 = pl.program_id(1) * qb
        kj0 = ki * kvb
        qblk = q_ref[0].astype(jnp.float32)
        kblk = k_ref[0].astype(jnp.float32)          # (kvb, dh)
        vblk = v_ref[0].astype(jnp.float32)
        s = qblk @ kblk.T * scale                    # (qb, kvb)
        qi = qi0 + jax.lax.broadcasted_iota(jnp.int32, (qb, kvb), 0)
        kj = kj0 + jax.lax.broadcasted_iota(jnp.int32, (qb, kvb), 1)
        ok = jnp.ones((qb, kvb), bool)
        if causal:
            ok = ok & (kj <= qi)
        if window > 0:
            ok = ok & (qi - kj < window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ vblk
        m_ref[...] = m_new

        @pl.when(ki == nk - 1)
        def _fin():
            o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)

    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, dh), lambda bg, qi, ki: (bg, qi, 0)),
            pl.BlockSpec((1, kvb, dh), lambda bg, qi, ki: (bg // rep, ki, 0)),
            pl.BlockSpec((1, kvb, dh), lambda bg, qi, ki: (bg // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, dh), lambda bg, qi, ki: (bg, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BG, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, dh), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention_kvchunk",
    )(q, k, v)
