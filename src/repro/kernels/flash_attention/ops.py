"""Public wrapper: grouped-layout flash attention with engine dispatch."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.target import _on_tpu
from . import kernel, ref


def flash_attention(q, k, v, *, rep: int, causal: bool = True,
                    window: int = 0, engine: str = "auto",
                    q_block: int = 256, kv_block: int = 1024):
    """q: (BG, S, dh); k/v: (BKV, S, dh); BG = BKV * rep.

    engine: "auto" (pallas on TPU, ref otherwise), "jnp", "pallas",
            "pallas_kvchunk" (long-sequence streaming variant).
    """
    if engine == "auto":
        engine = "pallas" if _on_tpu() else "jnp"
    if engine == "jnp":
        return ref.flash_ref(q, k, v, rep=rep, causal=causal, window=window)
    if engine == "pallas":
        return kernel.flash_pallas(
            q, k, v, rep=rep, causal=causal, window=window,
            q_block=q_block, interpret=not _on_tpu())
    if engine == "pallas_kvchunk":
        return kernel.flash_pallas_kvchunk(
            q, k, v, rep=rep, causal=causal, window=window,
            q_block=q_block, kv_block=kv_block, interpret=not _on_tpu())
    raise ValueError(engine)
