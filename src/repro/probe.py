"""Cost-probe mode: unroll structural loops for exact HloCostAnalysis.

XLA's cost analysis counts a while-loop body once, so the dry-run lowers
each cell twice more at n_layers=1/2 with every structural loop unrolled
(layer scan, blockwise-attention q/kv loops, rwkv chunk scan) and
extrapolates the per-layer delta.  Production lowering keeps the loops
(compile time and HLO size stay O(1) in depth).

The only loop left rolled under probe mode is the mamba per-token scan —
its recurrence body is a few elementwise ops (~0.6% of a hymba block's
FLOPs), noted in EXPERIMENTS.md §Roofline caveats.
"""

_PROBE = False


def set_probe(on: bool) -> None:
    global _PROBE
    _PROBE = bool(on)


def probing() -> bool:
    return _PROBE


def scan_unroll():
    """Pass as lax.scan's unroll= for structural (layer/chunk) scans."""
    return True if _PROBE else 1
