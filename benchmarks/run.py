"""Benchmark aggregator: one module per paper table/figure.

  table1_ridge    Table 1 ridge points (+ TPU v5e)
  fig3_kernels    per-kernel time decomposition + layout/VVL sweep
  fig4_bandwidth  OI + achieved-bandwidth fraction per kernel
  fig5_scaling    strong-scaling model (Titan/ARCHER analogue on v5e)
  lm_roofline     assigned-architecture roofline table from the dry-run

Prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import fig3_kernels, fig4_bandwidth, fig5_scaling, lm_roofline, \
        table1_ridge

    print("name,us_per_call,derived")
    for mod in (table1_ridge, fig3_kernels, fig4_bandwidth, fig5_scaling,
                lm_roofline):
        try:
            mod.main()
        except Exception as e:  # a failing table should not hide the rest
            print(f"{mod.__name__},0.0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
