"""Paper Fig. 5: strong scaling of Ludwig and MILC on multi-node systems.

The paper's measured Titan/ARCHER curves are reproduced as a first-
principles model on the v5e machine constants, using the real
decomposition geometry of our sharded drivers (per-shard interior bytes
over HBM bandwidth + halo-surface bytes over ICI links, per step/
CG-iteration).  The qualitative claims to recover (C5): near-ideal
scaling while the subdomain is fat, then communication dominance when
halo surface/volume catches up; the crossover arrives later for the
larger problem.

On top of the model curves, ``--smoke``/``--measured`` runs the *measured*
multi-shard check on whatever devices this process has (CI forces 8 fake
host devices): the sharded Ludwig LB step and the fused sharded MILC CG
under ``halo="pre"`` vs ``halo="overlap"`` — the comms/compute overlap
scheduler of core.overlap — timing both schedules through the
StepPipeline runner and asserting they are bit-identical.  A mismatch is
a regression in the split-launch path and fails the run (the bench-smoke
CI gate); the timings land in the JSON artifact for trend review.

``--json PATH`` writes rows + structured metrics in the fig3 top-level
schema (``rows`` / ``metrics`` / ``gate``), uploaded from CI alongside
``BENCH_ci.json``.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.launch.roofline import HBM_BW, ICI_LINK_BW

try:
    from .common import csv_row, time_fn
except ImportError:  # run as a script: python benchmarks/fig5_scaling.py
    from common import csv_row, time_fn

FP = 4  # fp32 bytes


def _decompose(nodes: int):
    """Factor nodes into a near-square 2-D process grid (our dim_axes map)."""
    a = int(np.sqrt(nodes))
    while nodes % a:
        a -= 1
    return a, nodes // a


def ludwig_step_model(lattice, nodes):
    nx, ny, nz = lattice
    px, py = _decompose(nodes)
    lx, ly = nx // px, ny // py
    interior = lx * ly * nz
    # per step HBM traffic/site: all stage reads+writes (fig4 accounting)
    bytes_site = (19 * 4 + 3 + 19 * 2 + 5 * 10 + 9 + 15) * FP
    t_mem = interior * bytes_site / HBM_BW
    # halo: dist (19) w=1 + q (5) w=2 + u (3) w=1 on 4 faces of the 2-D decomp
    halo_bytes = FP * 2 * ((19 + 3 + 2 * 5) * (ly * nz + lx * nz))
    t_ici = halo_bytes / ICI_LINK_BW
    return t_mem, t_ici


def milc_iter_model(lattice, nodes):
    v = int(np.prod(lattice))
    px, py = _decompose(nodes)
    lx, ly = lattice[0] // px, lattice[1] // py
    interior = v // nodes
    bytes_site = (24 * 6 + 72 * 2) * FP * 2  # two dslash per normal-eq matvec
    t_mem = interior * bytes_site / HBM_BW
    halo_bytes = FP * 2 * 2 * 24 * 2 * (
        ly * lattice[2] * lattice[3] + lx * lattice[2] * lattice[3])
    t_ici = halo_bytes / ICI_LINK_BW
    return t_mem, t_ici


def model_rows():
    """The paper's strong-scaling curves as a machine model, with the
    overlap lower bound max(t_mem, t_ici) — what the core.overlap schedule
    targets — next to the serialized sum the pre-exchange schedule pays."""
    rows = []
    cases = [
        ("ludwig_small", ludwig_step_model, (256, 256, 256)),
        ("ludwig_large", ludwig_step_model, (1024, 1024, 512)),
        ("milc_small", milc_iter_model, (64, 64, 64, 32)),
        ("milc_large", milc_iter_model, (128, 128, 128, 64)),
    ]
    for name, model, lattice in cases:
        crossover = None
        for nodes in [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]:
            if any(l % _decompose(nodes)[i % 2] for i, l in
                   enumerate(lattice[:2])):
                continue
            t_mem, t_ici = model(lattice, nodes)
            t = max(t_mem, t_ici)  # overlap lower bound
            if crossover is None and t_ici > t_mem:
                crossover = nodes
            rows.append(csv_row(
                f"fig5/{name}/nodes={nodes}", t * 1e6,
                f"t_mem_us={t_mem*1e6:.1f};t_halo_us={t_ici*1e6:.1f};"
                f"t_serial_us={(t_mem+t_ici)*1e6:.1f};"
                f"comm_bound={t_ici > t_mem}"))
        rows.append(csv_row(f"fig5/{name}/crossover", 0.0,
                            f"comm_dominates_at_nodes={crossover}"))
    return rows


# -- measured sharded steps: overlap vs pre ------------------------------------

def measured_ludwig(smoke: bool, steps: int = 3):
    """Time the sharded LB step under the pre-exchange and overlap
    schedules on this process's devices (both dims of a near-square mesh
    decomposed), and check the trajectories are bit-identical."""
    import jax
    import jax.numpy as jnp

    from repro.core import TargetConfig
    from repro.core.compat import make_mesh
    from repro.core.schedule import StepPipeline
    from repro.apps.ludwig import LudwigConfig, init_state
    from repro.apps.ludwig.driver import make_sharded_step
    from repro.lattice import Domain

    ndev = jax.device_count()
    px, py = _decompose(ndev)
    mesh = make_mesh((px, py), ("sx", "sy"))
    # locals stay >= 3 (one interior plane + two width-1 boundary slabs),
    # so the overlap split is real, not the thin-interior fallback
    lattice = (4 * px, 4 * py, 8) if smoke else (8 * px, 8 * py, 16)
    cfg = LudwigConfig(lattice=lattice, target=TargetConfig("jnp"))
    dom = Domain(global_shape=lattice, mesh=mesh,
                 dim_axes=("sx", "sy", None), halo=2)
    st0 = init_state(cfg, seed=0)
    sh = dom.sharding()
    d0 = jax.device_put(jnp.asarray(st0.dist.to_numpy()), sh)
    q0 = jax.device_put(jnp.asarray(st0.q.to_numpy()), sh)

    out = {}
    times = {}
    for mode in ("pre", "overlap"):
        # donate=False: both modes start from the same (d0, q0) buffers —
        # donation would consume them on the first mode's first step on
        # accelerator backends
        pipe = StepPipeline(make_sharded_step(cfg, dom, halo=mode),
                            donate=False)
        (d, q), per_step = pipe.run_timed((d0, q0), steps, warmup=1)
        out[mode] = (np.asarray(d), np.asarray(q))
        times[mode] = per_step
    equal = (np.array_equal(out["pre"][0], out["overlap"][0])
             and np.array_equal(out["pre"][1], out["overlap"][1]))
    metrics = {
        "devices": ndev, "lattice": list(lattice),
        "pre_s": times["pre"], "overlap_s": times["overlap"],
        "bit_identical": bool(equal),
    }
    rows = [
        csv_row("fig5_measured/ludwig_lb_step_pre", times["pre"] * 1e6,
                f"devices={ndev};lattice={'x'.join(map(str, lattice))}"),
        csv_row("fig5_measured/ludwig_lb_step_overlap",
                times["overlap"] * 1e6,
                f"devices={ndev};bit_identical={equal}"),
    ]
    return rows, metrics


def measured_milc(smoke: bool, iters: int = 3):
    """Time the fused sharded CG (fixed iteration count) under the
    pre-exchange and overlap schedules; trajectories must be bitwise
    equal (the inner products are producer-independent by construction)."""
    import jax
    import jax.numpy as jnp

    from repro.core import TargetConfig
    from repro.core.compat import make_mesh
    from repro.apps.milc import MilcConfig, init_problem
    from repro.apps.milc.driver import make_sharded_solver
    from repro.lattice import Domain

    ndev = jax.device_count()
    mesh = make_mesh((ndev,), ("mx",))
    # local x-extent 5 = one interior plane between two ring-2 slabs
    lattice = (5 * ndev, 4, 4, 4) if smoke else (6 * ndev, 8, 8, 8)
    cfg = MilcConfig(lattice=lattice, kappa=0.10, tol=0.0, max_iter=iters,
                     target=TargetConfig("jnp"))
    u, b = init_problem(cfg, seed=0)
    dom = Domain(global_shape=lattice, mesh=mesh,
                 dim_axes=("mx", None, None, None), halo=1)
    un, bn = jnp.asarray(u.to_numpy()), jnp.asarray(b.to_numpy())

    out = {}
    times = {}
    for mode in ("pre", "overlap"):
        solver = make_sharded_solver(cfg, dom, halo=mode)
        times[mode] = time_fn(solver, un, bn,
                              iters=3, warmup=1) / max(iters, 1)
        out[mode] = tuple(np.asarray(v) for v in solver(un, bn))
    equal = all(np.array_equal(a, b_) for a, b_ in zip(out["pre"],
                                                       out["overlap"]))
    metrics = {
        "devices": ndev, "lattice": list(lattice), "cg_iters": iters,
        "pre_s": times["pre"], "overlap_s": times["overlap"],
        "bit_identical": bool(equal),
    }
    rows = [
        csv_row("fig5_measured/milc_cg_iter_pre", times["pre"] * 1e6,
                f"devices={ndev};lattice={'x'.join(map(str, lattice))}"),
        csv_row("fig5_measured/milc_cg_iter_overlap", times["overlap"] * 1e6,
                f"devices={ndev};bit_identical={equal}"),
    ]
    return rows, metrics


def gate_measured(metrics):
    """The bench-smoke gate for the split-launch path: the overlap
    schedule must reproduce the pre schedule bit-for-bit (timing on fake
    CPU devices is reported, not gated — there is no real ICI to hide)."""
    failures = []
    for name, m in metrics.items():
        if not m.get("bit_identical", True):
            failures.append(
                f"{name}: halo='overlap' diverged from halo='pre' "
                f"(split-launch regression)")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny lattices + the measured overlap-vs-pre "
                         "sharded rows (CI-sized run)")
    ap.add_argument("--measured", action="store_true",
                    help="include the measured sharded rows at full size")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows/metrics/gate to PATH (fig3 schema)")
    args = ap.parse_args(argv)

    rows = model_rows()
    metrics, failures = {}, []
    if args.smoke or args.measured:
        lrows, lmet = measured_ludwig(smoke=args.smoke)
        mrows, mmet = measured_milc(smoke=args.smoke)
        rows += lrows + mrows
        metrics = {"ludwig_lb_step": lmet, "milc_cg_iter": mmet}
        failures = gate_measured(metrics)
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "metrics": metrics,
                       "smoke": args.smoke, "mode": "scaling",
                       "gate": {"tolerance": None, "failures": failures}},
                      f, indent=2)
    if failures:
        print("OVERLAP EQUALITY GATE FAILED:", *failures, sep="\n  ",
              file=sys.stderr)
    return rows, metrics, failures


if __name__ == "__main__":
    _, _, _failures = main()
    sys.exit(1 if _failures else 0)
