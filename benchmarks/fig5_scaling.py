"""Paper Fig. 5: strong scaling of Ludwig and MILC on multi-node systems.

The paper's measured Titan/ARCHER curves are reproduced as a first-
principles model on the v5e machine constants, using the real
decomposition geometry of our sharded drivers (per-shard interior bytes
over HBM bandwidth + halo-surface bytes over ICI links, per step/
CG-iteration).  The qualitative claims to recover (C5): near-ideal
scaling while the subdomain is fat, then communication dominance when
halo surface/volume catches up; the crossover arrives later for the
larger problem.  We also emit the *measured* multi-shard check: the
1-device vs 8-fake-device sharded step running the identical physics
(tests/test_distributed.py asserts equality; here we record the halo
traffic accounting).
"""

from __future__ import annotations

import numpy as np

from repro.launch.roofline import HBM_BW, ICI_LINK_BW
from .common import csv_row

FP = 4  # fp32 bytes


def _decompose(nodes: int):
    """Factor nodes into a near-square 2-D process grid (our dim_axes map)."""
    a = int(np.sqrt(nodes))
    while nodes % a:
        a -= 1
    return a, nodes // a


def ludwig_step_model(lattice, nodes):
    nx, ny, nz = lattice
    px, py = _decompose(nodes)
    lx, ly = nx // px, ny // py
    interior = lx * ly * nz
    # per step HBM traffic/site: all stage reads+writes (fig4 accounting)
    bytes_site = (19 * 4 + 3 + 19 * 2 + 5 * 10 + 9 + 15) * FP
    t_mem = interior * bytes_site / HBM_BW
    # halo: dist (19) w=1 + q (5) w=2 + u (3) w=1 on 4 faces of the 2-D decomp
    halo_bytes = FP * 2 * ((19 + 3 + 2 * 5) * (ly * nz + lx * nz))
    t_ici = halo_bytes / ICI_LINK_BW
    return t_mem, t_ici


def milc_iter_model(lattice, nodes):
    v = int(np.prod(lattice))
    px, py = _decompose(nodes)
    lx, ly = lattice[0] // px, lattice[1] // py
    interior = v // nodes
    bytes_site = (24 * 6 + 72 * 2) * FP * 2  # two dslash per normal-eq matvec
    t_mem = interior * bytes_site / HBM_BW
    halo_bytes = FP * 2 * 2 * 24 * 2 * (
        ly * lattice[2] * lattice[3] + lx * lattice[2] * lattice[3])
    t_ici = halo_bytes / ICI_LINK_BW
    return t_mem, t_ici


def main():
    rows = []
    cases = [
        ("ludwig_small", ludwig_step_model, (256, 256, 256)),
        ("ludwig_large", ludwig_step_model, (1024, 1024, 512)),
        ("milc_small", milc_iter_model, (64, 64, 64, 32)),
        ("milc_large", milc_iter_model, (128, 128, 128, 64)),
    ]
    for name, model, lattice in cases:
        crossover = None
        for nodes in [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]:
            if any(l % _decompose(nodes)[i % 2] for i, l in
                   enumerate(lattice[:2])):
                continue
            t_mem, t_ici = model(lattice, nodes)
            t = max(t_mem, t_ici)  # overlap lower bound
            if crossover is None and t_ici > t_mem:
                crossover = nodes
            rows.append(csv_row(
                f"fig5/{name}/nodes={nodes}", t * 1e6,
                f"t_mem_us={t_mem*1e6:.1f};t_halo_us={t_ici*1e6:.1f};"
                f"comm_bound={t_ici > t_mem}"))
        rows.append(csv_row(f"fig5/{name}/crossover", 0.0,
                            f"comm_dominates_at_nodes={crossover}"))
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    main()
