"""Paper Fig. 3: full-application time decomposed per kernel, plus the
layout x VVL configuration sweep (bottom panel) and the fused-vs-unfused
launch-graph comparison (``--fused``): the Ludwig 3-kernel LC chain and the
MILC CG update chain, each timed unfused (one launch per kernel, every
intermediate through HBM) and fused (one launch for the chain), with the
bytes-moved model from LaunchGraph.bytes_moved — the Roofline gain of
core.fuse measured, not asserted.

On this CPU-only container the *measured* numbers are the jnp-engine wall
times (the paper's "host C" build); per-processor *modelled* times come
from each kernel's bytes-per-site over the Table-1 STREAM bandwidths —
valid because every kernel is memory-bound (C4), which is exactly how the
paper reasons about Fig. 3/4.  The layout sweep measures the real effect
of AoS/SoA/AoSoA on the measurable engine (C2) and reports the structural
penalty of each layout for the pallas/TPU target.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Field, SOA, AOS, TargetConfig, aosoa, launch
from repro.apps.ludwig import LudwigConfig, init_state
from repro.apps.ludwig.driver import (
    _be_rhs_body, _mol_field_body, _q_update_body, lc_chain_graph, step_timed,
)
from repro.apps.milc import MilcConfig, init_problem
from repro.apps.milc.cg import (
    _square_body, cg_update_graph, fused_cg_update, make_wilson_op, axpy, dot,
)

try:
    from .common import (
        LUDWIG_KERNELS, MILC_KERNELS, PROCESSORS, csv_row, time_fn, traffic_row,
    )
except ImportError:  # run as a script: python benchmarks/fig3_kernels.py
    from common import (
        LUDWIG_KERNELS, MILC_KERNELS, PROCESSORS, csv_row, time_fn, traffic_row,
    )


def ludwig_decomposition(lattice=(16, 16, 16), steps=3):
    cfg = LudwigConfig(lattice=lattice, target=TargetConfig("jnp"))
    state = init_state(cfg, seed=0)
    state, _ = step_timed(state, cfg)  # warmup/compile
    acc = {}
    for _ in range(steps):
        state, t = step_timed(state, cfg)
        for k, v in t.items():
            acc[k] = acc.get(k, 0.0) + v / steps
    nsites = int(np.prod(lattice))
    rows = []
    for k, t in acc.items():
        model = ""
        if k in LUDWIG_KERNELS:
            bps, fps = LUDWIG_KERNELS[k]
            models = {p: nsites * bps / bw
                      for p, (_, bw) in PROCESSORS.items()}
            model = ";".join(f"t_{p}_us={v*1e6:.1f}" for p, v in models.items())
        rows.append(csv_row(f"fig3_ludwig/{k}", t * 1e6, model))
    return rows


def milc_decomposition(lattice=(8, 8, 8, 8)):
    cfg = MilcConfig(lattice=lattice, kappa=0.1)
    u, b = init_problem(cfg, seed=0)
    apply_m, _, _ = make_wilson_op(u, cfg.kappa, cfg.target)
    nsites = int(np.prod(lattice))
    rows = []
    t_mv = time_fn(jax.jit(lambda x: apply_m(x).data), b)
    rows.append(csv_row("fig3_milc/wilson_matvec", t_mv * 1e6,
                        f"sites={nsites}"))
    t_ax = time_fn(jax.jit(lambda x: axpy(0.5, x, x, cfg.target).data), b)
    rows.append(csv_row("fig3_milc/scalar_mult_add", t_ax * 1e6, ""))
    t_dot = time_fn(jax.jit(lambda x: dot(x, x, cfg.target)), b)
    rows.append(csv_row("fig3_milc/dot_reduction", t_dot * 1e6, ""))
    for k, (bps, fps) in MILC_KERNELS.items():
        models = {p: nsites * bps / bw for p, (_, bw) in PROCESSORS.items()}
        rows.append(csv_row(
            f"fig3_milc_model/{k}", 0.0,
            ";".join(f"t_{p}_us={v*1e6:.1f}" for p, v in models.items())))
    return rows


def layout_vvl_sweep(lattice=(16, 16, 16), steps=3):
    """Bottom panel of Fig. 3: configuration sweep on the measurable engine.
    The pallas/TPU structural penalties (tile padding waste) are reported
    as derived columns."""
    rows = []
    base = LudwigConfig(lattice=lattice, target=TargetConfig("jnp"))
    for lay in [SOA, AOS, aosoa(64), aosoa(128)]:
        cfg = dataclasses.replace(base, layout=lay)
        state = init_state(cfg, seed=0)
        state, _ = step_timed(state, cfg)
        tot = 0.0
        for _ in range(steps):
            state, t = step_timed(state, cfg)
            tot += sum(t.values()) / steps
        # structural TPU penalty: minor-dim padding of one (comp, VVL) tile
        if lay.kind.value == "aos":
            pad = 128 / 19  # 19-comp minor dim padded to 128 lanes
        else:
            pad = 1.0
        rows.append(csv_row(f"fig3_sweep/layout={lay.name}", tot * 1e6,
                            f"tpu_tile_pad_factor={pad:.2f}"))
    return rows


def fused_vs_unfused(lattice=(16, 16, 16), milc_lattice=(8, 8, 8, 8),
                     engine="jnp"):
    """Fused launch graphs vs one-launch-per-kernel on the same chains.

    Three rows per chain: ``unfused`` is the seed behavior (one un-cached
    launch per kernel, re-traced every call), ``unfused_jit`` wraps the same
    per-kernel sequence in one jax.jit (the fair launch-cache baseline),
    ``fused`` is the LaunchGraph.  bytes_moved is engine-aware: on the
    pallas engine every pallas_call has mandated HBM I/O, so unfused_jit is
    charged full per-stage traffic; on the jnp engine XLA fuses the
    elementwise chain inside one jit, so unfused_jit is charged the same
    external traffic as fused (the LaunchGraph's traffic win is a property
    of the pallas/TPU target — on jnp its win is the launch cache and the
    guaranteed single kernel).  On a memory-bound kernel set the byte ratio
    IS the roofline-speedup bound (paper §4)."""
    rows = []
    tgt = TargetConfig(engine, vvl=128)
    rng = np.random.default_rng(0)

    # ---- Ludwig 3-kernel LC chain: molecular field -> BE rhs -> Q update
    cfg = LudwigConfig(lattice=lattice, target=tgt)
    nsites = int(np.prod(lattice))

    def mk(name, ncomp):
        arr = (0.01 * rng.normal(size=(ncomp, *lattice))).astype(np.float32)
        return Field.from_numpy(name, arr, lattice, cfg.layout)

    ins = {"q": mk("q", 5), "lapq": mk("lapq", 5), "w": mk("w", 9),
           "adv": mk("adv", 5)}
    graph = lc_chain_graph(cfg)
    bm = graph.bytes_moved({k: f.ncomp for k, f in ins.items()}, nsites,
                           outputs=("q_new",))
    # XLA fuses a jitted jnp chain, eliding the intermediates pallas_calls
    # must round-trip — charge unfused_jit accordingly
    jit_bytes = bm["unfused"] if engine == "pallas" else bm["fused"]

    def lc_unfused(q, lapq, w, adv):
        h = launch(_mol_field_body, {"q": q, "lapq": lapq}, {"h": 5},
                   config=tgt,
                   params=dict(a0=cfg.a0, gamma=cfg.gamma, kappa=cfg.kappa))["h"]
        rhs = launch(_be_rhs_body, {"q": q, "h": h, "w": w}, {"rhs": 5},
                     config=tgt,
                     params=dict(gamma_rot=cfg.gamma_rot, xi=cfg.xi))["rhs"]
        return launch(_q_update_body, {"q": q, "rhs": rhs, "adv": adv},
                      {"q": 5}, config=tgt, params=dict(dt=cfg.dt))["q"].data

    def lc_fused(q, lapq, w, adv):
        return graph.launch({"q": q, "lapq": lapq, "w": w, "adv": adv},
                            config=tgt, outputs=("q_new",))["q_new"].data

    args = (ins["q"], ins["lapq"], ins["w"], ins["adv"])
    rows.append(traffic_row("fig3_fused/ludwig_lc_chain_unfused",
                            time_fn(lc_unfused, *args), bm["unfused"]))
    rows.append(traffic_row("fig3_fused/ludwig_lc_chain_unfused_jit",
                            time_fn(jax.jit(lc_unfused), *args), jit_bytes))
    rows.append(traffic_row("fig3_fused/ludwig_lc_chain_fused",
                            time_fn(lc_fused, *args), bm["fused"]))

    # ---- MILC CG update chain: x+alpha p, r-alpha ap, r.r square
    nsites4 = int(np.prod(milc_lattice))

    def mk4(name):
        arr = rng.normal(size=(24, *milc_lattice)).astype(np.float32)
        return Field.from_numpy(name, arr, milc_lattice, SOA)

    x, r, p, ap = mk4("x"), mk4("r"), mk4("p"), mk4("ap")
    cg_graph = cg_update_graph(24)
    bm4 = cg_graph.bytes_moved({"x": 24, "r": 24, "p": 24, "ap": 24}, nsites4,
                               outputs=("x_new", "r_new", "rr_prod"))

    def cg_unfused(x, r, p, ap):
        xn = axpy(0.3, p, x, tgt)
        rn = axpy(-0.3, ap, r, tgt)
        prod = launch(_square_body, {"x": rn}, {"out": 24}, config=tgt)["out"]
        return xn.data, rn.data, prod.data

    def cg_fused(x, r, p, ap):
        xn, rn, prod = fused_cg_update(x, r, p, ap, jnp.float32(0.3), tgt)
        return xn.data, rn.data, prod.data

    rows.append(traffic_row("fig3_fused/milc_cg_update_unfused",
                            time_fn(cg_unfused, x, r, p, ap), bm4["unfused"]))
    jit_bytes4 = bm4["unfused"] if engine == "pallas" else bm4["fused"]
    rows.append(traffic_row("fig3_fused/milc_cg_update_unfused_jit",
                            time_fn(jax.jit(cg_unfused), x, r, p, ap),
                            jit_bytes4))
    rows.append(traffic_row("fig3_fused/milc_cg_update_fused",
                            time_fn(cg_fused, x, r, p, ap), bm4["fused"]))

    # ---- LB step: collide -> propagate (launch-level fusion: propagation is
    # a stencil, so the fusion is one cached jit, not one pallas program)
    from repro.kernels.lb_collision import collide
    from repro.kernels.lb_propagation import propagate
    from repro.kernels.lb_propagation.ops import collide_propagate

    dist = mk("dist", 19)
    dist = dist.with_canonical(1.0 + 0.1 * dist.canonical())
    force = mk("force", 3)

    def lb_unfused(d, g):
        return propagate(collide(d, g, tau=0.8, config=tgt), config=tgt).data

    def lb_fused(d, g):
        return collide_propagate(d, g, tau=0.8, config=tgt).data

    # per-kernel traffic from the shared Fig. 4 model.  collide_propagate is
    # launch-level fusion (one jit, still two kernels on pallas): only the
    # jnp engine's XLA fusion can elide the post-collision intermediate's
    # HBM round-trip (one write + one read of the 19-component field)
    lb_un = (LUDWIG_KERNELS["collision"][0]
             + LUDWIG_KERNELS["propagation"][0]) * nsites
    lb_fu = lb_un if engine == "pallas" else lb_un - 2 * 19 * 4 * nsites
    rows.append(traffic_row("fig3_fused/lb_step_unfused",
                            time_fn(lb_unfused, dist, force), lb_un))
    rows.append(traffic_row("fig3_fused/lb_step_unfused_jit",
                            time_fn(jax.jit(lb_unfused), dist, force),
                            lb_un if engine == "pallas" else lb_fu))
    rows.append(traffic_row("fig3_fused/lb_step_fused",
                            time_fn(lb_fused, dist, force), lb_fu))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fused", action="store_true",
                    help="only the fused-vs-unfused launch-graph comparison")
    ap.add_argument("--engine", default="jnp", choices=["jnp", "pallas"],
                    help="engine for the fused comparison wall-clock")
    args = ap.parse_args(argv)
    rows = []
    if args.fused:
        rows += fused_vs_unfused(engine=args.engine)
    else:
        rows += ludwig_decomposition()
        rows += milc_decomposition()
        rows += layout_vvl_sweep()
        rows += fused_vs_unfused(engine=args.engine)
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    main()
