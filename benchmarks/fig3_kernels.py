"""Paper Fig. 3: full-application time decomposed per kernel, plus the
layout x VVL configuration sweep (bottom panel) and the fused-vs-unfused
launch-graph comparison (``--fused``): the Ludwig 3-kernel LC chain, the
MILC CG update chain (with its fused terminal residual reduction), the
fused-*stencil* LB collide->propagate step and the fused Wilson
dslash+axpy+dot normal-operator application — each timed unfused (one
launch per kernel, every intermediate and reduction input through HBM) and
fused (one launch for the chain), with the bytes-moved model from
LaunchGraph.bytes_moved — the Roofline gain of core.fuse measured, not
asserted.

CI mode: ``--smoke --json BENCH_ci.json --gate 0.10`` runs tiny lattices,
writes the rows + structured metrics to JSON, and exits non-zero if any
fused chain is slower than its per-launch unfused baseline beyond the
given relative tolerance — the perf-regression gate wired into
.github/workflows/ci.yml (job: bench-smoke).

``--layout-sweep`` times the fused *stencil* chains (lb_step,
wilson_normal) across SoA/AoS/AoSoA{4,8,16}: the staged-nd lowering
against the native-AoSoA block lowering (``view="block"``) side by side,
gated on bit-identity — the paper's layout sweep finally reaching the
halo'd launches (see README "Layouts in stencil chains").

On this CPU-only container the *measured* numbers are the jnp-engine wall
times (the paper's "host C" build); per-processor *modelled* times come
from each kernel's bytes-per-site over the Table-1 STREAM bandwidths —
valid because every kernel is memory-bound (C4), which is exactly how the
paper reasons about Fig. 3/4.  The layout sweep measures the real effect
of AoS/SoA/AoSoA on the measurable engine (C2) and reports the structural
penalty of each layout for the pallas/TPU target.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Field, SOA, AOS, TargetConfig, aosoa, launch, target_sum
from repro.apps.ludwig import LudwigConfig, init_state
from repro.apps.ludwig.driver import (
    _be_rhs_body, _mol_field_body, _q_update_body, lc_chain_graph, step_timed,
)
from repro.apps.milc import MilcConfig, init_problem
from repro.apps.milc.cg import (
    _square_body, cg_update_graph, fused_cg_update, make_fused_normal,
    make_wilson_op, wilson_normal_graph, axpy, dot,
)

try:
    from .common import (
        LUDWIG_KERNELS, MILC_KERNELS, PROCESSORS, csv_row, time_fn, traffic_row,
    )
except ImportError:  # run as a script: python benchmarks/fig3_kernels.py
    from common import (
        LUDWIG_KERNELS, MILC_KERNELS, PROCESSORS, csv_row, time_fn, traffic_row,
    )


def ludwig_decomposition(lattice=(16, 16, 16), steps=3):
    cfg = LudwigConfig(lattice=lattice, target=TargetConfig("jnp"))
    state = init_state(cfg, seed=0)
    state, _ = step_timed(state, cfg)  # warmup/compile
    acc = {}
    for _ in range(steps):
        state, t = step_timed(state, cfg)
        for k, v in t.items():
            acc[k] = acc.get(k, 0.0) + v / steps
    nsites = int(np.prod(lattice))
    rows = []
    for k, t in acc.items():
        model = ""
        if k in LUDWIG_KERNELS:
            bps, fps = LUDWIG_KERNELS[k]
            models = {p: nsites * bps / bw
                      for p, (_, bw) in PROCESSORS.items()}
            model = ";".join(f"t_{p}_us={v*1e6:.1f}" for p, v in models.items())
        rows.append(csv_row(f"fig3_ludwig/{k}", t * 1e6, model))
    return rows


def milc_decomposition(lattice=(8, 8, 8, 8)):
    cfg = MilcConfig(lattice=lattice, kappa=0.1)
    u, b = init_problem(cfg, seed=0)
    apply_m, _, _ = make_wilson_op(u, cfg.kappa, cfg.target)
    nsites = int(np.prod(lattice))
    rows = []
    t_mv = time_fn(jax.jit(lambda x: apply_m(x).data), b)
    rows.append(csv_row("fig3_milc/wilson_matvec", t_mv * 1e6,
                        f"sites={nsites}"))
    t_ax = time_fn(jax.jit(lambda x: axpy(0.5, x, x, cfg.target).data), b)
    rows.append(csv_row("fig3_milc/scalar_mult_add", t_ax * 1e6, ""))
    t_dot = time_fn(jax.jit(lambda x: dot(x, x, cfg.target)), b)
    rows.append(csv_row("fig3_milc/dot_reduction", t_dot * 1e6, ""))
    for k, (bps, fps) in MILC_KERNELS.items():
        models = {p: nsites * bps / bw for p, (_, bw) in PROCESSORS.items()}
        rows.append(csv_row(
            f"fig3_milc_model/{k}", 0.0,
            ";".join(f"t_{p}_us={v*1e6:.1f}" for p, v in models.items())))
    return rows


def layout_vvl_sweep(lattice=(16, 16, 16), steps=3):
    """Bottom panel of Fig. 3: configuration sweep on the measurable engine.
    The pallas/TPU structural penalties (tile padding waste) are reported
    as derived columns."""
    rows = []
    base = LudwigConfig(lattice=lattice, target=TargetConfig("jnp"))
    for lay in [SOA, AOS, aosoa(64), aosoa(128)]:
        cfg = dataclasses.replace(base, layout=lay)
        state = init_state(cfg, seed=0)
        state, _ = step_timed(state, cfg)
        tot = 0.0
        for _ in range(steps):
            state, t = step_timed(state, cfg)
            tot += sum(t.values()) / steps
        # structural TPU penalty: minor-dim padding of one (comp, VVL) tile
        if lay.kind.value == "aos":
            pad = 128 / 19  # 19-comp minor dim padded to 128 lanes
        else:
            pad = 1.0
        rows.append(csv_row(f"fig3_sweep/layout={lay.name}", tot * 1e6,
                            f"tpu_tile_pad_factor={pad:.2f}"))
    return rows


def fused_vs_unfused(lattice=(16, 16, 16), milc_lattice=(8, 8, 8, 8),
                     engine="jnp"):
    """Fused launch graphs vs one-launch-per-kernel on the same chains.

    Three rows per chain: ``unfused`` is the seed behavior (one un-cached
    launch per kernel, re-traced every call), ``unfused_jit`` wraps the same
    per-kernel sequence in one jax.jit (the fair launch-cache baseline),
    ``fused`` is the LaunchGraph.  bytes_moved is engine-aware: on the
    pallas engine every pallas_call has mandated HBM I/O, so unfused_jit is
    charged full per-stage traffic; on the jnp engine XLA fuses the
    elementwise chain inside one jit, so unfused_jit is charged the same
    external traffic as fused (the LaunchGraph's traffic win is a property
    of the pallas/TPU target — on jnp its win is the launch cache and the
    guaranteed single kernel).  On a memory-bound kernel set the byte ratio
    IS the roofline-speedup bound (paper §4).

    Returns (rows, metrics): metrics maps chain -> {unfused_s,
    unfused_jit_s, fused_s} wall-clock seconds for the CI gate."""
    rows = []
    metrics = {}
    tgt = TargetConfig(engine, vvl=128)
    rng = np.random.default_rng(0)

    def chain(name, bm_unfused, bm_jit, bm_fused, t_un, t_jit, t_fu):
        metrics[name] = {"unfused_s": t_un, "unfused_jit_s": t_jit,
                         "fused_s": t_fu}
        rows.append(traffic_row(f"fig3_fused/{name}_unfused", t_un, bm_unfused))
        rows.append(traffic_row(f"fig3_fused/{name}_unfused_jit", t_jit, bm_jit))
        rows.append(traffic_row(f"fig3_fused/{name}_fused", t_fu, bm_fused))

    # ---- Ludwig 3-kernel LC chain: molecular field -> BE rhs -> Q update
    cfg = LudwigConfig(lattice=lattice, target=tgt)
    nsites = int(np.prod(lattice))

    def mk(name, ncomp):
        arr = (0.01 * rng.normal(size=(ncomp, *lattice))).astype(np.float32)
        return Field.from_numpy(name, arr, lattice, cfg.layout)

    ins = {"q": mk("q", 5), "lapq": mk("lapq", 5), "w": mk("w", 9),
           "adv": mk("adv", 5)}
    graph = lc_chain_graph(cfg)
    bm = graph.bytes_moved({k: f.ncomp for k, f in ins.items()}, nsites,
                           outputs=("q_new",))
    # XLA fuses a jitted jnp chain, eliding the intermediates pallas_calls
    # must round-trip — charge unfused_jit accordingly
    jit_bytes = bm["unfused"] if engine == "pallas" else bm["fused"]

    def lc_unfused(q, lapq, w, adv):
        h = launch(_mol_field_body, {"q": q, "lapq": lapq}, {"h": 5},
                   config=tgt,
                   params=dict(a0=cfg.a0, gamma=cfg.gamma, kappa=cfg.kappa))["h"]
        rhs = launch(_be_rhs_body, {"q": q, "h": h, "w": w}, {"rhs": 5},
                     config=tgt,
                     params=dict(gamma_rot=cfg.gamma_rot, xi=cfg.xi))["rhs"]
        return launch(_q_update_body, {"q": q, "rhs": rhs, "adv": adv},
                      {"q": 5}, config=tgt, params=dict(dt=cfg.dt))["q"].data

    def lc_fused(q, lapq, w, adv):
        return graph.launch({"q": q, "lapq": lapq, "w": w, "adv": adv},
                            config=tgt, outputs=("q_new",))["q_new"].data

    args = (ins["q"], ins["lapq"], ins["w"], ins["adv"])
    chain("ludwig_lc_chain", bm["unfused"], jit_bytes, bm["fused"],
          time_fn(lc_unfused, *args), time_fn(jax.jit(lc_unfused), *args),
          time_fn(lc_fused, *args))

    # ---- MILC CG update chain: x+alpha p, r-alpha ap, |r_new|^2 — the
    # residual square AND its reduction fuse into the one launch, so the
    # unfused baseline includes the separate target_sum pass that re-reads
    # rr_prod from HBM
    nsites4 = int(np.prod(milc_lattice))

    def mk4(name, ncomp=24):
        arr = rng.normal(size=(ncomp, *milc_lattice)).astype(np.float32)
        return Field.from_numpy(name, arr, milc_lattice, SOA)

    x, r, p, ap = mk4("x"), mk4("r"), mk4("p"), mk4("ap")
    cg_graph = cg_update_graph(24)
    bm4 = cg_graph.bytes_moved({"x": 24, "r": 24, "p": 24, "ap": 24}, nsites4,
                               outputs=("x_new", "r_new", "rr"))

    def cg_unfused(x, r, p, ap):
        xn = axpy(0.3, p, x, tgt)
        rn = axpy(-0.3, ap, r, tgt)
        prod = launch(_square_body, {"x": rn}, {"out": 24}, config=tgt)["out"]
        return xn.data, rn.data, target_sum(prod, tgt)

    def cg_fused(x, r, p, ap):
        xn, rn, rr = fused_cg_update(x, r, p, ap, jnp.float32(0.3), tgt)
        return xn.data, rn.data, rr

    jit_bytes4 = bm4["unfused"] if engine == "pallas" else bm4["fused"]
    chain("milc_cg_update", bm4["unfused"], jit_bytes4, bm4["fused"],
          time_fn(cg_unfused, x, r, p, ap),
          time_fn(jax.jit(cg_unfused), x, r, p, ap),
          time_fn(cg_fused, x, r, p, ap))

    # ---- LB step: collision fused INTO propagation's gather — a stencil
    # stage of the launch graph, so the fused variant is ONE halo'd kernel
    # even on the pallas engine and the post-collision distributions never
    # round-trip HBM (the fused-stencil bytes-moved model)
    from repro.kernels.lb_collision import collide
    from repro.kernels.lb_propagation import propagate
    from repro.kernels.lb_propagation.ops import (
        collide_propagate, collide_propagate_graph,
    )

    dist = mk("dist", 19)
    dist = dist.with_canonical(1.0 + 0.1 * dist.canonical())
    force = mk("force", 3)

    def lb_unfused(d, g):
        return propagate(collide(d, g, tau=0.8, config=tgt), config=tgt).data

    def lb_fused(d, g):
        return collide_propagate(d, g, tau=0.8, config=tgt).data

    lb_bm = collide_propagate_graph(0.8).bytes_moved(
        {"dist": 19, "force": 3}, nsites, outputs=("dist2",))
    chain("lb_step", lb_bm["unfused"],
          lb_bm["unfused"] if engine == "pallas" else lb_bm["fused"],
          lb_bm["fused"],
          time_fn(lb_unfused, dist, force),
          time_fn(jax.jit(lb_unfused), dist, force),
          time_fn(lb_fused, dist, force))

    # ---- MILC normal-operator application: both dslash stencils fused into
    # the xpay/g5 chain with <p, Ap> as a terminal reduction (one halo'd
    # kernel) vs one launch per dslash/axpy plus a separate dot
    cfg4 = MilcConfig(lattice=milc_lattice, kappa=0.1, target=tgt)
    u4, b4 = init_problem(cfg4, seed=0)
    _, _, apply_normal = make_wilson_op(u4, cfg4.kappa, tgt)
    fused_normal = make_fused_normal(u4, cfg4.kappa, tgt)
    wn_bm = wilson_normal_graph(cfg4.kappa).bytes_moved(
        {"p": 24, "u": 72}, nsites4, outputs=("ap", "pap"))

    def wn_unfused(pf):
        ap = apply_normal(pf)
        return ap.data, dot(pf, ap, tgt)

    def wn_fused(pf):
        ap, pap = fused_normal(pf)
        return ap.data, pap

    chain("milc_wilson_normal", wn_bm["unfused"],
          wn_bm["unfused"] if engine == "pallas" else wn_bm["fused"],
          wn_bm["fused"],
          time_fn(wn_unfused, b4), time_fn(jax.jit(wn_unfused), b4),
          time_fn(wn_fused, b4))
    return rows, metrics


def _time_interleaved(run, plan_a, plan_b, iters=5, warmup=2):
    """Best wall seconds for each of two plans, timed in interleaved
    rounds (the same estimator the tuner's sweep uses)."""
    import time as _time

    for _ in range(warmup):
        jax.block_until_ready(run(plan_a))
        jax.block_until_ready(run(plan_b))
    best = [float("inf"), float("inf")]
    for _ in range(iters):
        for i, plan in enumerate((plan_a, plan_b)):
            t0 = _time.perf_counter()
            jax.block_until_ready(run(plan))
            best[i] = min(best[i], _time.perf_counter() - t0)
    return best[0], best[1]


def tuned_vs_default(lattice=(16, 16, 16), milc_lattice=(8, 8, 8, 8),
                     engine="jnp", iters=3, warmup=1, min_gain=0.05):
    """``--tune`` mode: wall-clock per chain under the default heuristic
    plan vs the autotuned plan — the paper's hand-run per-architecture VVL
    sweep (§3.2.2) as a persisted artifact.  The first run sweeps candidate
    plans through core.tune and writes the winners to the tune table
    (``.targetdp_tune.json`` / $TARGETDP_TUNE_PATH); later runs load the
    table and skip the sweep (``cached`` in the metrics).

    Returns (rows, metrics): metrics maps chain -> {default_s, tuned_s,
    default_plan, tuned_plan, cached, key} for the tune-smoke CI gate."""
    from repro.core import tune
    from repro.kernels.lb_propagation.ops import collide_propagate_graph

    tgt = TargetConfig(engine, vvl=128)
    rng = np.random.default_rng(0)
    cfg = LudwigConfig(lattice=lattice, target=tgt)

    def mk(name, ncomp):
        arr = (0.01 * rng.normal(size=(ncomp, *lattice))).astype(np.float32)
        return Field.from_numpy(name, arr, lattice, cfg.layout)

    def mk4(name, ncomp=24):
        arr = rng.normal(size=(ncomp, *milc_lattice)).astype(np.float32)
        return Field.from_numpy(name, arr, milc_lattice, SOA)

    dist = mk("dist", 19)
    dist = dist.with_canonical(1.0 + 0.1 * dist.canonical())
    cfg4 = MilcConfig(lattice=milc_lattice, kappa=0.1, target=tgt)
    u4, b4 = init_problem(cfg4, seed=0)

    # (chain, graph, ins, outputs, scalars) — the four launch graphs the
    # fused comparison times, now swept by the planning layer
    cases = [
        ("ludwig_lc_chain", lc_chain_graph(cfg),
         {"q": mk("q", 5), "lapq": mk("lapq", 5), "w": mk("w", 9),
          "adv": mk("adv", 5)},
         ("q_new",), None),
        ("milc_cg_update", cg_update_graph(24),
         {"x": mk4("x"), "r": mk4("r"), "p": mk4("p"), "ap": mk4("ap")},
         ("x_new", "r_new", "rr"), {"alpha": 0.3, "neg_alpha": -0.3}),
        ("lb_step", collide_propagate_graph(0.8),
         {"dist": dist, "force": mk("force", 3)}, ("dist2",), None),
        ("milc_wilson_normal", wilson_normal_graph(cfg4.kappa),
         {"p": b4, "u": u4}, ("ap", "pap"), None),
    ]

    rows, metrics = [], {}
    for name, graph, gins, outs, sc in cases:
        default = tune.plan_candidates_for(
            graph, gins, config=tgt, outputs=outs)[0]
        tuned, info = tune.autotune_graph(
            graph, gins, config=tgt, outputs=outs, scalars=sc,
            iters=iters, warmup=warmup, min_gain=min_gain)

        def run(plan, _g=graph, _i=gins, _o=outs, _s=sc):
            return jax.tree_util.tree_leaves(
                _g.launch(_i, config=tgt, outputs=_o, scalars=_s, plan=plan))

        # gate timing mirrors the sweep's methodology — interleaved rounds,
        # per-plan min — so machine drift between two sequential median
        # measurements cannot flip the comparison
        t_def, t_tun = _time_interleaved(run, default, tuned)
        metrics[name] = {
            "default_s": t_def, "tuned_s": t_tun,
            "default_plan": default.describe(),
            "tuned_plan": tuned.describe(),
            "cached": bool(info.get("cached")), "key": info["key"],
        }
        rows.append(csv_row(f"fig3_tune/{name}_default", t_def * 1e6,
                            f"plan={default.describe()}"))
        rows.append(csv_row(f"fig3_tune/{name}_tuned", t_tun * 1e6,
                            f"plan={tuned.describe()};cached={info.get('cached')}"))
    return rows, metrics


LAYOUT_SWEEP = ("soa", "aos", "aosoa4", "aosoa8", "aosoa16")


def layout_stencil_sweep(lattice=(8, 14, 16), milc_lattice=(8, 8, 8, 8),
                         engine="pallas"):
    """``--layout-sweep``: the paper's layout switch (§3.1) applied to the
    *fused halo'd stencil chains* — the launches that dominate Figs. 3–5 —
    across SoA/AoS/AoSoA{4,8,16}, timing the staged-nd lowering against the
    native-AoSoA block lowering (``LoweringPlan.view == "block"``,
    core.plan/core.fuse) side by side where the SAL is block-aligned.

    Every native-block launch is checked **bit-identical** to its staged-nd
    twin (field outputs and on-chip reductions) — the CI layout-sweep smoke
    gates on this, so a mismatch in the native lowering fails the build.
    Lattices are chosen so the halo'd inner planes of both chains stay
    SAL-tileable up to AoSoA16 (ineligible combinations are reported as
    such, not silently dropped).

    Returns (rows, metrics): metrics maps "{chain}/{layout}" ->
    {staged_s, native_s, native_eligible, bitwise_equal, plan labels}."""
    from repro.core import tune
    from repro.core import plan as plan_mod
    from repro.core.layout import parse_layout
    from repro.kernels.lb_propagation.ops import collide_propagate_graph

    tgt = TargetConfig(engine, vvl=128)
    rng = np.random.default_rng(0)
    dist_np = (1.0 + 0.1 * rng.normal(size=(19, *lattice))).astype(np.float32)
    force_np = (0.01 * rng.normal(size=(3, *lattice))).astype(np.float32)
    cfg4 = MilcConfig(lattice=milc_lattice, kappa=0.1, target=tgt)
    u4, b4 = init_problem(cfg4, seed=0)

    cases = [
        ("lb_step", collide_propagate_graph(0.8),
         lambda lay: {"dist": Field.from_numpy("dist", dist_np, lattice, lay),
                      "force": Field.from_numpy("force", force_np, lattice,
                                                lay)},
         ("dist2",), int(np.prod(lattice))),
        ("wilson_normal", wilson_normal_graph(cfg4.kappa),
         lambda lay: {"p": b4.as_layout(lay), "u": u4.as_layout(lay)},
         ("ap", "pap"), int(np.prod(milc_lattice))),
    ]
    rows, metrics = [], {}
    for name, graph, mk_ins, outs, nsites in cases:
        for spec in LAYOUT_SWEEP:
            lay = parse_layout(spec)
            label = f"{name}/{lay.name}"
            if not lay.fits(nsites):
                rows.append(csv_row(f"fig3_layout/{label}", 0.0,
                                    "skipped=sal_does_not_tile_lattice"))
                continue
            ins = mk_ins(lay)
            default = tune.plan_candidates_for(
                graph, ins, config=tgt, outputs=outs)[0]

            def run(plan, _g=graph, _i=ins, _o=outs):
                return jax.tree_util.tree_leaves(
                    _g.launch(_i, config=tgt, outputs=_o, plan=plan))

            eligible = (engine == "pallas"
                        and tune.block_view_for(graph, ins, outs))
            m = {"staged_plan": default.describe(), "staged_s": None,
                 "native_s": None, "native_eligible": bool(eligible),
                 "bitwise_equal": None}
            if eligible:
                native = dataclasses.replace(default,
                                             view=plan_mod.VIEW_BLOCK)
                m["native_plan"] = native.describe()
                t_st, t_na = _time_interleaved(run, default, native)
                m["staged_s"], m["native_s"] = t_st, t_na
                a = graph.launch(ins, config=tgt, outputs=outs, plan=default)
                b = graph.launch(ins, config=tgt, outputs=outs, plan=native)
                equal = True
                for o in outs:
                    va = a[o].data if isinstance(a[o], Field) else a[o]
                    vb = b[o].data if isinstance(b[o], Field) else b[o]
                    equal = equal and bool(
                        np.array_equal(np.asarray(va), np.asarray(vb)))
                m["bitwise_equal"] = equal
                rows.append(csv_row(
                    f"fig3_layout/{label}_staged", t_st * 1e6,
                    f"plan={default.describe()}"))
                rows.append(csv_row(
                    f"fig3_layout/{label}_native", t_na * 1e6,
                    f"plan={native.describe()};bitwise_equal={equal}"))
            else:
                m["staged_s"] = time_fn(run, default)
                rows.append(csv_row(
                    f"fig3_layout/{label}_staged", m["staged_s"] * 1e6,
                    f"plan={default.describe()};native=ineligible"))
            metrics[label] = m
    return rows, metrics


def _stencil_vmem_views(graph, ins, outs):
    """(in_views, out_views) for the VMEM footprint model — the same
    derivation LaunchGraph.launch feeds the planner."""
    rings = graph.halo_widths(tuple(outs))
    prod = graph._produced()
    red = set(graph._reduce_outputs())
    first = next(iter(ins.values()))
    in_views = tuple(
        (f.ncomp, rings.get(n, 0), np.dtype(str(f.dtype)).itemsize)
        for n, f in ins.items())
    out_views = tuple(
        (int(prod[o][0]), np.dtype(str(prod[o][1] or first.dtype)).itemsize)
        for o in outs if o not in red)
    return in_views, out_views


def tile_stencil_sweep(lattice=(8, 14, 16), milc_lattice=(8, 8, 8, 8),
                       engine="pallas"):
    """``--tile-sweep``: the tiled y/z lowering (``LoweringPlan.by``/``bz``
    + double-buffered tile DMA on a real TPU) against whole-staging on the
    fused stencil chains — the launches whose per-program VMEM bounds the
    shard size.  Two checks per chain, both CI-gated:

    * identity: the tiled launch's field outputs are **bitwise** equal to
      the whole-staged launch and its fp sum reductions tolerance-equal
      (per-tile fold order — the rsplit contract).  The wall-clock
      regression bound is measured on the *single-tile* plan (by/bz =
      whole axes: same program count through the tiled code path), which
      isolates the lowering overhead; the multi-tile twin's timing is
      reported unbounded — on interpret/CPU more programs cost linearly
      (tiles are a capacity lever here; the DMA overlap win needs a real
      TPU).
    * capacity: a VMEM byte budget sized *below* the chain's whole-staged
      footprint makes ``candidate_plans`` reject every untiled pallas
      candidate (logged with the footprint estimate) while the default
      policy auto-tiles and the launch **runs to completion**, bit-identical
      to the unbudgeted run — the "shard bounded by tile, not lattice"
      acceptance demo.

    Returns (rows, metrics): metrics maps chain -> {whole_s, tiled_s, plan
    labels, fields_bitwise, reductions_close, budget_demo}."""
    from repro.core import plan as plan_mod
    from repro.core import tune
    from repro.kernels.lb_propagation.ops import collide_propagate_graph

    tgt = TargetConfig(engine, vvl=128)
    rng = np.random.default_rng(0)
    dist_np = (1.0 + 0.1 * rng.normal(size=(19, *lattice))).astype(np.float32)
    force_np = (0.01 * rng.normal(size=(3, *lattice))).astype(np.float32)
    cfg4 = MilcConfig(lattice=milc_lattice, kappa=0.1, target=tgt)
    u4, b4 = init_problem(cfg4, seed=0)

    def mid_div(n):  # a proper divisor that actually tiles (n>1 dims)
        divs = [d for d in range(1, n + 1) if n % d == 0]
        return divs[-2] if len(divs) > 1 else 0

    cases = [
        ("lb_step", collide_propagate_graph(0.8),
         {"dist": Field.from_numpy("dist", dist_np, lattice, SOA),
          "force": Field.from_numpy("force", force_np, lattice, SOA)},
         ("dist2",), lattice),
        ("wilson_normal", wilson_normal_graph(cfg4.kappa),
         {"p": b4, "u": u4}, ("ap", "pap"), milc_lattice),
    ]
    rows, metrics = [], {}
    for name, graph, ins, outs, lat in cases:
        whole = tune.plan_candidates_for(
            graph, ins, config=tgt, outputs=outs)[0]
        tiled = dataclasses.replace(
            whole, by=mid_div(lat[1]), bz=mid_div(lat[2]))
        # whole-axis tiles: one program per slab, same as untiled, but
        # through the tiled code path — the overhead the gate bounds
        tiled1 = dataclasses.replace(whole, by=lat[1], bz=lat[2])
        in_views, out_views = _stencil_vmem_views(graph, ins, outs)
        fp_whole = plan_mod.estimate_vmem_bytes(
            whole, lattice=lat, in_views=in_views, out_views=out_views)
        fp_tiled = plan_mod.estimate_vmem_bytes(
            tiled, lattice=lat, in_views=in_views, out_views=out_views)

        def run(plan, _g=graph, _i=ins, _o=outs):
            return jax.tree_util.tree_leaves(
                _g.launch(_i, config=tgt, outputs=_o, plan=plan))

        t_wh, t_t1 = _time_interleaved(run, whole, tiled1)
        _, t_ti = _time_interleaved(run, whole, tiled)
        a = graph.launch(ins, config=tgt, outputs=outs, plan=whole)
        fields_bitwise, reds_close = True, True
        for plan in (tiled, tiled1):
            b = graph.launch(ins, config=tgt, outputs=outs, plan=plan)
            for o in outs:
                if isinstance(a[o], Field):
                    fields_bitwise = fields_bitwise and bool(np.array_equal(
                        np.asarray(a[o].data), np.asarray(b[o].data)))
                else:  # fp reduction: per-tile fold => tolerance contract
                    reds_close = reds_close and bool(np.allclose(
                        np.asarray(a[o]), np.asarray(b[o]),
                        rtol=1e-5, atol=1e-7))

        # capacity demo: budget below the whole-staged footprint
        budget = max(fp_whole // 2, fp_tiled + 1)
        cfg_b = dataclasses.replace(tgt, vmem_bytes=budget)
        cands = tune.plan_candidates_for(
            graph, ins, config=cfg_b, outputs=outs)
        untiled_rejected = all(
            (c.by or c.bz) for c in cands if c.engine == "pallas")
        auto = cands[0]
        try:  # default policy under the budget: must run to completion
            c = graph.launch(ins, config=cfg_b, outputs=outs)
            runs = True
            demo_bitwise = all(
                bool(np.array_equal(np.asarray(a[o].data),
                                    np.asarray(c[o].data)))
                for o in outs if isinstance(a[o], Field))
        except Exception as e:  # surfaced through the gate, not a crash
            runs, demo_bitwise = False, False
            print(f"budget demo launch failed for {name}: {e}",
                  file=sys.stderr)
        metrics[name] = {
            "whole_s": t_wh, "tiled_s": t_ti, "tiled1_s": t_t1,
            "whole_plan": whole.describe(footprint=fp_whole),
            "tiled_plan": tiled.describe(footprint=fp_tiled),
            "tiled1_plan": tiled1.describe(),
            "fields_bitwise": fields_bitwise,
            "reductions_close": reds_close,
            "budget_demo": {
                "vmem_bytes": budget,
                "untiled_rejected": bool(untiled_rejected),
                "auto_plan": auto.describe(),
                "auto_tiled": bool(auto.by or auto.bz),
                "runs": runs,
                "fields_bitwise": demo_bitwise,
            },
        }
        rows.append(csv_row(f"fig3_tile/{name}_whole", t_wh * 1e6,
                            f"plan={whole.describe(footprint=fp_whole)}"))
        rows.append(csv_row(
            f"fig3_tile/{name}_tiled1", t_t1 * 1e6,
            f"plan={tiled1.describe()};bitwise={fields_bitwise}"))
        rows.append(csv_row(
            f"fig3_tile/{name}_tiled", t_ti * 1e6,
            f"plan={tiled.describe(footprint=fp_tiled)};"
            f"bitwise={fields_bitwise}"))
        rows.append(csv_row(
            f"fig3_tile/{name}_budget_demo", 0.0,
            f"vmem_bytes={budget};auto_plan={auto.describe()};runs={runs}"))
    return rows, metrics


def telemetry_trace(path, lattice=(32, 32, 32), engine="jnp", iters=20,
                    warmup=3):
    """``--trace``: the telemetry gate on the fused LB collide->propagate
    step (one Ludwig LB step = one fused halo'd launch), exporting a
    Perfetto-loadable Chrome trace of the run to ``path``.

    Three checks feed the CI gate (``--trace-gate``):

    * overhead — the SAME cached launch timed with per-launch telemetry
      off vs on (``TargetConfig.telemetry``) in interleaved best-of
      rounds, the tuner's estimator, so machine drift cannot favour one
      arm.  The span path must cost <= the gate tolerance (default 1%)
      relative.  The 32^3 jnp row is fixed even under ``--smoke``: the
      span path costs ~10us host-side per launch but launch-to-launch
      wall noise is +-20-30us (profiled: all in block_until_ready, both
      arms hitting the same cached executable), so the row must be long
      enough (~12ms) that 1% clears BOTH — on 8^3-16^3 rows the
      comparison is timer noise, not a measurement.
    * bitwise — the telemetry-on output equals the telemetry-off output
      bit for bit (spans are host-side only; enabling observability may
      never perturb the computation).
    * schema — every recorded ``launch/`` span carries the full
      plan/engine/lattice/cache/bytes/roofline field set the README
      Observability glossary documents.

    Returns (rows, metrics)."""
    from repro.core import telemetry
    from repro.kernels.lb_propagation.ops import collide_propagate_graph

    tgt = TargetConfig(engine, vvl=128)
    rng = np.random.default_rng(0)
    dist = Field.from_numpy(
        "dist",
        (1.0 + 0.1 * rng.normal(size=(19, *lattice))).astype(np.float32),
        lattice, SOA)
    force = Field.from_numpy(
        "force", (0.01 * rng.normal(size=(3, *lattice))).astype(np.float32),
        lattice, SOA)
    ins = {"dist": dist, "force": force}
    graph = collide_propagate_graph(0.8)
    cfg_off = dataclasses.replace(tgt, telemetry=False)
    cfg_on = dataclasses.replace(tgt, telemetry=True)

    def run(cfg):
        return graph.launch(ins, config=cfg, outputs=("dist2",))["dist2"].data

    telemetry.reset()
    out_off = np.asarray(run(cfg_off))
    out_on = np.asarray(run(cfg_on))
    bitwise = bool(np.array_equal(out_off, out_on))

    t_off, t_on = _time_interleaved(run, cfg_off, cfg_on, iters=iters,
                                    warmup=warmup)
    overhead = t_on / t_off - 1.0

    spans = telemetry.events("launch/")
    required = ("plan", "engine", "lattice", "cache", "bytes_fused",
                "bytes_unfused", "gbps_achieved", "roofline_frac",
                "roofline_placement")
    missing = sorted({f for s in spans for f in required
                      if f not in s["attrs"]})
    telemetry.export_chrome_trace(path)
    with open(path) as f:
        n_trace = len(json.load(f)["traceEvents"])

    metrics = {"lb_step": {
        "off_s": t_off, "on_s": t_on, "overhead_frac": overhead,
        "bitwise_equal": bitwise, "launch_spans": len(spans),
        "schema_missing": missing, "trace_path": path,
        "trace_events": n_trace,
    }}
    rows = [
        csv_row("fig3_trace/lb_step_telemetry_off", t_off * 1e6, ""),
        csv_row("fig3_trace/lb_step_telemetry_on", t_on * 1e6,
                f"overhead={overhead * 100:+.2f}%;bitwise={bitwise};"
                f"launch_spans={len(spans)}"),
        csv_row("fig3_trace/chrome_trace", 0.0,
                f"path={path};events={n_trace}"),
    ]
    print(telemetry.format_report())
    return rows, metrics


DTYPE_SWEEP_STORAGE = ("float64", "float32", "bfloat16")


def dtype_sweep(lattice=(16, 16, 16), milc_lattice=(8, 8, 8, 8),
                engine="jnp", lb_steps=3):
    """``--dtype-sweep``: the mixed-precision storage sweep on the two
    chains the dtype-policy axis targets — the fused LB step under
    ``LudwigConfig.storage`` and the full Wilson-CG solve under
    ``MilcConfig.storage`` (iterative-refinement restarts, see
    apps/milc/cg.cg_refined) — one row per storage dtype in
    {float64, float32, bfloat16}.

    Each row reports *per-iteration* wall time and *time-to-solution*
    (for the solver: measured wall x measured iterations-to-tolerance —
    narrower storage may need more iterations, which is exactly what the
    tuner's convergence-aware cost model prices), final rel-L2 against the
    fp64-storage baseline row, and the modeled fused HBM bytes per
    application priced at the policy's storage itemsize
    (``LaunchGraph.bytes_moved(..., dtypes=...)``).

    Honesty note: with ``jax_enable_x64`` off (this container) the
    float64-storage row is *emulated* — jax truncates the casts to fp32,
    so its numerics coincide with the float32 row while its modeled bytes
    still price itemsize 8 (flagged ``emulated_fp64`` in the metrics).
    The accumulate leg of the policy falls back to compensated (Kahan)
    fp32 the same way, so the baseline is still the widest-accumulation
    run the platform can execute.

    Returns (rows, metrics): metrics maps chain -> storage -> row dict
    for the dtype-sweep CI gate (``gate_dtype``)."""
    import time as _time

    from repro.apps.ludwig.driver import lb_step_graph
    from repro.apps.milc.driver import residual_check, solve as milc_solve
    from repro.core.plan import DtypePolicy

    tgt = TargetConfig(engine, vvl=128)
    x64 = bool(jax.config.jax_enable_x64)
    rows, metrics = [], {"lb_step": {}, "wilson_normal_cg": {}}

    def policy(storage):
        return DtypePolicy(storage=storage, compute="float32",
                           accumulate="float64")

    # ---- fused LB step: distributions stream through HBM in the storage
    # dtype; the carried state is cast back each step (driver contract)
    nsites = int(np.prod(lattice))
    lb_ref = None
    for storage in DTYPE_SWEEP_STORAGE:
        cfg = LudwigConfig(lattice=lattice, target=tgt, storage=storage)
        state = init_state(cfg, seed=0)
        state, _ = step_timed(state, cfg)  # warmup/compile
        t_lb = 0.0
        for _ in range(lb_steps):
            state, t = step_timed(state, cfg)
            t_lb += t["lb_step"] / lb_steps
        dist = np.asarray(state.dist.canonical(), dtype=np.float64)
        if lb_ref is None:
            lb_ref = dist
        rel = float(np.linalg.norm(dist - lb_ref)
                    / max(float(np.linalg.norm(lb_ref)), 1e-30))
        pol = policy(storage)
        bm = lb_step_graph(cfg).bytes_moved(
            {"dist": 19, "force": 3}, nsites, outputs=("dist2", "u"),
            dtypes=pol)
        metrics["lb_step"][storage] = {
            "per_iter_s": t_lb,
            "time_to_solution_s": t_lb * lb_steps,
            "iterations": lb_steps,
            "rel_l2_vs_baseline": rel,
            "bytes_fused": bm["fused"],
            "storage_itemsize": pol.storage_itemsize(4),
            "emulated_fp64": storage == "float64" and not x64,
        }
        rows.append(csv_row(
            f"fig3_dtype/lb_step@{storage}", t_lb * 1e6,
            f"rel_l2={rel:.2e};bytes_fused={bm['fused']};"
            f"itemsize={pol.storage_itemsize(4)}"))

    # ---- Wilson-CG solve: the per-iteration operator launches move
    # storage-dtype bytes, refinement restarts recover the tolerance
    nsites4 = int(np.prod(milc_lattice))
    x_ref = None
    for storage in DTYPE_SWEEP_STORAGE:
        cfg4 = MilcConfig(lattice=milc_lattice, kappa=0.1, tol=1e-10,
                          target=tgt, storage=storage)
        u4, b4 = init_problem(cfg4, seed=0)
        res = milc_solve(cfg4, u4, b4)  # warmup/compile + the solution
        jax.block_until_ready(res.x.data)
        t0 = _time.perf_counter()
        jax.block_until_ready(milc_solve(cfg4, u4, b4).x.data)
        wall = _time.perf_counter() - t0
        iters = int(res.iterations)
        x = np.asarray(res.x.canonical(), dtype=np.float64)
        if x_ref is None:
            x_ref = x
        rel = float(np.linalg.norm(x - x_ref)
                    / max(float(np.linalg.norm(x_ref)), 1e-30))
        pol = policy(storage)
        bm = wilson_normal_graph(cfg4.kappa).bytes_moved(
            {"p": 24, "u": 72}, nsites4, outputs=("ap", "pap"), dtypes=pol)
        metrics["wilson_normal_cg"][storage] = {
            "per_iter_s": wall / max(iters, 1),
            "time_to_solution_s": wall,
            "iterations": iters,
            "rel_l2_vs_baseline": rel,
            "residual": residual_check(cfg4, u4, b4, res.x),
            "bytes_fused": bm["fused"],
            "storage_itemsize": pol.storage_itemsize(4),
            "emulated_fp64": storage == "float64" and not x64,
        }
        rows.append(csv_row(
            f"fig3_dtype/wilson_normal_cg@{storage}", wall * 1e6,
            f"iters={iters};per_iter_us={wall / max(iters, 1) * 1e6:.1f};"
            f"rel_l2={rel:.2e};bytes_fused={bm['fused']};"
            f"itemsize={pol.storage_itemsize(4)}"))
    return rows, metrics


def gate_dtype(metrics):
    """The dtype-sweep CI gate: accuracy vs the fp64-storage baseline row
    and bytes monotonicity.

    * solver rows: rel-L2 <= 1e-6 (fp32 storage) / 1e-3 (bf16 storage) —
      achievable because iterative refinement recovers the storage
      quantization each restart;
    * LB rows: fp32 <= 1e-6, but the LB step has no refinement loop (a
      single fused kernel whose output is quantized once per step), so
      its bf16 row is gated at the bf16 storage-quantization bound 1e-2 —
      the same accuracy gate the tuner applies to bf16 candidates;
    * modeled fused bytes must strictly shrink with the storage itemsize
      (8 -> 4 -> 2) — the traffic win the policy exists to buy."""
    TOL = {"wilson_normal_cg": {"float32": 1e-6, "bfloat16": 1e-3},
           "lb_step": {"float32": 1e-6, "bfloat16": 1e-2}}
    failures = []
    for chain, per in metrics.items():
        for storage, tol in TOL.get(chain, {}).items():
            m = per.get(storage)
            if m is None:
                failures.append(f"{chain}: missing {storage} row")
                continue
            if m["rel_l2_vs_baseline"] > tol:
                failures.append(
                    f"{chain}@{storage}: rel-L2 "
                    f"{m['rel_l2_vs_baseline']:.2e} vs the fp64-storage "
                    f"baseline exceeds {tol:g}")
        seq = [(s, per[s]) for s in DTYPE_SWEEP_STORAGE if s in per]
        for (sa, a), (sb, b) in zip(seq, seq[1:]):
            if not b["bytes_fused"] < a["bytes_fused"]:
                failures.append(
                    f"{chain}: modeled bytes did not shrink with the "
                    f"storage itemsize ({sa}={a['bytes_fused']} -> "
                    f"{sb}={b['bytes_fused']})")
    return failures


def gate_trace(metrics, tolerance):
    """The trace CI gate: enabling telemetry must cost <= ``tolerance``
    relative on the launch row, never change a bit of the output, and
    every launch span must carry the documented schema."""
    failures = []
    for name, m in metrics.items():
        if tolerance is not None and m["overhead_frac"] > tolerance:
            failures.append(
                f"{name}: telemetry-on {m['on_s']*1e6:.1f}us > "
                f"telemetry-off {m['off_s']*1e6:.1f}us * "
                f"(1+{tolerance:.2f}) — span overhead "
                f"{m['overhead_frac']*100:+.2f}%")
        if not m["bitwise_equal"]:
            failures.append(
                f"{name}: telemetry-on output differs bitwise from "
                f"telemetry-off — observability perturbed the launch")
        if not m["launch_spans"]:
            failures.append(f"{name}: no launch/ spans were recorded")
        if m["schema_missing"]:
            failures.append(
                f"{name}: launch spans missing schema fields "
                f"{m['schema_missing']}")
        if not m["trace_events"]:
            failures.append(f"{name}: exported Chrome trace is empty")
    return failures


def gate_tile(metrics, tolerance):
    """The tile-sweep CI gate: tiled lowering must be bitwise identical on
    fields, tolerance-equal on fp reductions, within the wall-clock bound,
    and the over-budget demo must reject untiled candidates yet run to
    completion through the auto-tiled default."""
    failures = []
    for name, m in metrics.items():
        if not m["fields_bitwise"]:
            failures.append(
                f"{name}: tiled field outputs differ bitwise from "
                f"whole-staging ({m['tiled_plan']} vs {m['whole_plan']})")
        if not m["reductions_close"]:
            failures.append(
                f"{name}: tiled reductions exceed the fp tolerance "
                f"contract ({m['tiled_plan']})")
        if tolerance is not None and m["tiled1_s"] > m["whole_s"] * (1.0 + tolerance):
            failures.append(
                f"{name}: tiled lowering overhead at equal program count "
                f"{m['tiled1_s']*1e6:.1f}us > whole-staged "
                f"{m['whole_s']*1e6:.1f}us * (1+{tolerance:.2f})")
        d = m["budget_demo"]
        if not d["untiled_rejected"]:
            failures.append(
                f"{name}: an untiled pallas candidate survived the "
                f"{d['vmem_bytes']}B budget sweep")
        if not (d["auto_tiled"] and d["runs"] and d["fields_bitwise"]):
            failures.append(
                f"{name}: over-budget demo did not run tiled to completion "
                f"bit-identically (auto_plan={d['auto_plan']}, "
                f"runs={d['runs']}, bitwise={d['fields_bitwise']})")
    return failures


def gate_layout_identity(metrics):
    """The layout-sweep CI gate: every native-block launch must be bitwise
    identical to its staged-nd twin — the view is a data-movement knob,
    never a semantics knob."""
    return [
        f"{label}: native-block output differs bitwise from staged-nd "
        f"(plans {m.get('native_plan')} vs {m['staged_plan']})"
        for label, m in metrics.items()
        if m.get("bitwise_equal") is False
    ]


def gate_tuned(metrics, tolerance):
    """The tune-smoke CI gate: a tuned plan must never be slower than the
    default heuristic plan beyond ``tolerance`` relative (when the sweep
    picked the default plan itself there is nothing to compare)."""
    failures = []
    for name, m in metrics.items():
        if m["tuned_plan"] == m["default_plan"]:
            continue
        if m["tuned_s"] > m["default_s"] * (1.0 + tolerance):
            failures.append(
                f"{name}: tuned plan {m['tuned_plan']} "
                f"{m['tuned_s']*1e6:.1f}us > default {m['default_plan']} "
                f"{m['default_s']*1e6:.1f}us * (1+{tolerance:.2f})"
            )
    return failures


def gate_regressions(metrics, tolerance):
    """The CI perf gate: every fused chain must beat (or tie, within
    ``tolerance`` relative) its per-launch unfused baseline — the seed
    behavior the fusion subsystem exists to improve on."""
    failures = []
    for name, m in metrics.items():
        limit = m["unfused_s"] * (1.0 + tolerance)
        if m["fused_s"] > limit:
            failures.append(
                f"{name}: fused {m['fused_s']*1e6:.1f}us > unfused "
                f"{m['unfused_s']*1e6:.1f}us * (1+{tolerance:.2f})"
            )
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fused", action="store_true",
                    help="only the fused-vs-unfused launch-graph comparison")
    ap.add_argument("--engine", default="jnp", choices=["jnp", "pallas"],
                    help="engine for the fused comparison wall-clock")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny lattices (CI-sized run)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows/metrics/gate results to PATH")
    ap.add_argument("--gate", type=float, default=None, metavar="TOL",
                    help="exit 1 if any fused chain is slower than its "
                         "unfused baseline beyond TOL (e.g. 0.10)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune plans per chain (persisting winners to "
                         "the tune table) and report default-plan vs "
                         "tuned-plan wall-clock instead of fused-vs-unfused")
    ap.add_argument("--tune-gate", type=float, default=None, metavar="TOL",
                    help="with --tune: exit 1 if any tuned plan is slower "
                         "than the default plan beyond TOL (e.g. 0.05)")
    ap.add_argument("--layout-sweep", action="store_true",
                    help="sweep the fused stencil chains across "
                         "SoA/AoS/AoSoA{4,8,16}, native-block vs staged-nd "
                         "side by side, gated on bit-identity")
    ap.add_argument("--tile-sweep", action="store_true",
                    help="tiled (by/bz) vs whole-staged fused stencil "
                         "chains, gated on bit-identity and the over-budget "
                         "auto-tiling demo")
    ap.add_argument("--tile-gate", type=float, default=None, metavar="TOL",
                    help="with --tile-sweep: exit 1 on identity/demo "
                         "failure or if a tiled launch is slower than "
                         "whole-staging beyond TOL (e.g. 0.10)")
    ap.add_argument("--dtype-sweep", action="store_true",
                    help="mixed-precision storage sweep (fp64/fp32/bf16) on "
                         "the fused LB step and the refined Wilson-CG "
                         "solve, gated on rel-L2 vs the fp64-storage "
                         "baseline and on modeled bytes shrinking with the "
                         "storage itemsize")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="telemetry mode: time the fused LB step with "
                         "spans off vs on, write a Perfetto-loadable "
                         "Chrome trace to PATH, and gate on overhead, "
                         "bitwise identity and launch-span schema")
    ap.add_argument("--trace-gate", type=float, default=0.01, metavar="TOL",
                    help="with --trace: max relative span overhead on the "
                         "launch row (default 0.01)")
    args = ap.parse_args(argv)
    sizes = (dict(lattice=(8, 8, 8), milc_lattice=(4, 4, 4, 4))
             if args.smoke else {})
    rows, metrics, failures = [], {}, []
    if args.trace:
        # the trace row keeps its 32^3 lattice under --smoke: the <=1%
        # overhead gate needs a launch long enough to resolve the span cost
        rows, metrics = telemetry_trace(args.trace, engine=args.engine)
        failures += gate_trace(metrics, args.trace_gate)
    elif args.dtype_sweep:
        rows, metrics = dtype_sweep(engine=args.engine,
                                    lb_steps=2 if args.smoke else 3, **sizes)
        failures += gate_dtype(metrics)
    elif args.tile_sweep:
        tsizes = (dict(lattice=(4, 14, 16), milc_lattice=(4, 4, 4, 4))
                  if args.smoke else {})
        rows, metrics = tile_stencil_sweep(engine=args.engine, **tsizes)
        failures += gate_tile(metrics, args.tile_gate)
    elif args.layout_sweep:
        # lattices keep the halo'd inner planes SAL-tileable up to AoSoA16
        lsizes = (dict(lattice=(4, 14, 16), milc_lattice=(4, 4, 4, 4))
                  if args.smoke else {})
        rows, metrics = layout_stencil_sweep(engine=args.engine, **lsizes)
        failures += gate_layout_identity(metrics)
    elif args.tune:
        # smoke lattices are tiny, so per-launch timings are noise-heavy:
        # demand a decisive (25%) swept gain before leaving the default
        # plan, keeping the tuned-vs-default gate deterministic in CI
        rows, metrics = tuned_vs_default(
            engine=args.engine, iters=3 if args.smoke else 5,
            min_gain=0.25 if args.smoke else 0.05, **sizes)
        if args.tune_gate is not None:
            failures += gate_tuned(metrics, args.tune_gate)
    else:
        if not args.fused:
            rows += ludwig_decomposition()
            rows += milc_decomposition()
            rows += layout_vvl_sweep()
        frows, metrics = fused_vs_unfused(engine=args.engine, **sizes)
        rows += frows
        if args.gate is not None:
            failures += gate_regressions(metrics, args.gate)
    for r in rows:
        print(r)
    if args.json:
        mode = ("trace" if args.trace
                else "dtype-sweep" if args.dtype_sweep
                else "tile-sweep" if args.tile_sweep
                else "layout-sweep" if args.layout_sweep
                else "tune" if args.tune else "fused")
        tol = (args.trace_gate if args.trace
               else None if args.dtype_sweep
               else args.tile_gate if args.tile_sweep
               else args.tune_gate if args.tune else args.gate)
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "metrics": metrics,
                       "engine": args.engine, "smoke": args.smoke,
                       "mode": mode,
                       "gate": {"tolerance": tol,
                                "failures": failures}}, f, indent=2)
    if failures:
        print("PERF REGRESSION GATE FAILED:", *failures, sep="\n  ",
              file=sys.stderr)
    return rows, metrics, failures


if __name__ == "__main__":
    _, _, _failures = main()
    sys.exit(1 if _failures else 0)
