"""Paper Fig. 3: full-application time decomposed per kernel, plus the
layout x VVL configuration sweep (bottom panel).

On this CPU-only container the *measured* numbers are the jnp-engine wall
times (the paper's "host C" build); per-processor *modelled* times come
from each kernel's bytes-per-site over the Table-1 STREAM bandwidths —
valid because every kernel is memory-bound (C4), which is exactly how the
paper reasons about Fig. 3/4.  The layout sweep measures the real effect
of AoS/SoA/AoSoA on the measurable engine (C2) and reports the structural
penalty of each layout for the pallas/TPU target.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import SOA, AOS, TargetConfig, aosoa
from repro.apps.ludwig import LudwigConfig, init_state
from repro.apps.ludwig.driver import step_timed
from repro.apps.milc import MilcConfig, init_problem
from repro.apps.milc.cg import make_wilson_op, axpy, dot
from .common import LUDWIG_KERNELS, MILC_KERNELS, PROCESSORS, csv_row, time_fn


def ludwig_decomposition(lattice=(16, 16, 16), steps=3):
    cfg = LudwigConfig(lattice=lattice, target=TargetConfig("jnp"))
    state = init_state(cfg, seed=0)
    state, _ = step_timed(state, cfg)  # warmup/compile
    acc = {}
    for _ in range(steps):
        state, t = step_timed(state, cfg)
        for k, v in t.items():
            acc[k] = acc.get(k, 0.0) + v / steps
    nsites = int(np.prod(lattice))
    rows = []
    for k, t in acc.items():
        model = ""
        if k in LUDWIG_KERNELS:
            bps, fps = LUDWIG_KERNELS[k]
            models = {p: nsites * bps / bw
                      for p, (_, bw) in PROCESSORS.items()}
            model = ";".join(f"t_{p}_us={v*1e6:.1f}" for p, v in models.items())
        rows.append(csv_row(f"fig3_ludwig/{k}", t * 1e6, model))
    return rows


def milc_decomposition(lattice=(8, 8, 8, 8)):
    cfg = MilcConfig(lattice=lattice, kappa=0.1)
    u, b = init_problem(cfg, seed=0)
    apply_m, _, _ = make_wilson_op(u, cfg.kappa, cfg.target)
    nsites = int(np.prod(lattice))
    rows = []
    t_mv = time_fn(jax.jit(lambda x: apply_m(x).data), b)
    rows.append(csv_row("fig3_milc/wilson_matvec", t_mv * 1e6,
                        f"sites={nsites}"))
    t_ax = time_fn(jax.jit(lambda x: axpy(0.5, x, x, cfg.target).data), b)
    rows.append(csv_row("fig3_milc/scalar_mult_add", t_ax * 1e6, ""))
    t_dot = time_fn(jax.jit(lambda x: dot(x, x, cfg.target)), b)
    rows.append(csv_row("fig3_milc/dot_reduction", t_dot * 1e6, ""))
    for k, (bps, fps) in MILC_KERNELS.items():
        models = {p: nsites * bps / bw for p, (_, bw) in PROCESSORS.items()}
        rows.append(csv_row(
            f"fig3_milc_model/{k}", 0.0,
            ";".join(f"t_{p}_us={v*1e6:.1f}" for p, v in models.items())))
    return rows


def layout_vvl_sweep(lattice=(16, 16, 16), steps=3):
    """Bottom panel of Fig. 3: configuration sweep on the measurable engine.
    The pallas/TPU structural penalties (tile padding waste) are reported
    as derived columns."""
    rows = []
    base = LudwigConfig(lattice=lattice, target=TargetConfig("jnp"))
    for lay in [SOA, AOS, aosoa(64), aosoa(128)]:
        cfg = dataclasses.replace(base, layout=lay)
        state = init_state(cfg, seed=0)
        state, _ = step_timed(state, cfg)
        tot = 0.0
        for _ in range(steps):
            state, t = step_timed(state, cfg)
            tot += sum(t.values()) / steps
        # structural TPU penalty: minor-dim padding of one (comp, VVL) tile
        if lay.kind.value == "aos":
            pad = 128 / 19  # 19-comp minor dim padded to 128 lanes
        else:
            pad = 1.0
        rows.append(csv_row(f"fig3_sweep/layout={lay.name}", tot * 1e6,
                            f"tpu_tile_pad_factor={pad:.2f}"))
    return rows


def main():
    rows = []
    rows += ludwig_decomposition()
    rows += milc_decomposition()
    rows += layout_vvl_sweep()
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    main()
