"""Paper Table 1: processors, peak FLOP/s, STREAM bandwidth, ridge points.

Reproduces the paper's derived ridge points (Ivy-Bridge 5.2, Xeon Phi 6.4,
K40 7.4 F/B) and extends the table with the TPU v5e target (240 F/B bf16) —
the quantitative basis for claim C4: every application kernel (OI 0.4–2.2)
is memory-bound on every processor, and dramatically more so on TPU.
"""

from __future__ import annotations

from .common import PROCESSORS, ridge_point


def main(print_csv: bool = True):
    rows = []
    for name, (peak, bw) in PROCESSORS.items():
        rp = ridge_point(name)
        rows.append((name, peak, bw, rp))
        if print_csv:
            print(f"table1_ridge/{name},0.0,"
                  f"peak_gflops={peak/1e9:.0f};stream_gbs={bw/1e9:.1f};"
                  f"ridge_fpb={rp:.1f}")
    # paper-published ridge values as a regression check
    assert abs(ridge_point("ivy-bridge") - 5.2) < 0.1
    assert abs(ridge_point("xeon-phi") - 6.4) < 0.1
    assert abs(ridge_point("k40") - 7.4) < 0.1
    return rows


if __name__ == "__main__":
    main()
