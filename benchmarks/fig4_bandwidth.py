"""Paper Fig. 4: per-kernel operational intensity + achieved-bandwidth
fraction.

The wall-clock %STREAM measurement of the paper is replaced by its
structural equivalent on the compiled artifact: for each kernel we lower
the jnp engine on CPU and compare *useful* bytes (the minimal per-site
traffic of the algorithm, the counting the paper uses for OI) against the
HLO "bytes accessed" — useful/HLO = the fraction of achievable bandwidth
the compiled kernel can reach, assuming the memory system runs at STREAM
rate on the rest.  OIs land in the paper's 0.4-2.2 F/B band, far below
every Table-1 ridge point (C4).

``--json PATH`` writes the rows plus structured per-kernel metrics in the
same top-level schema as fig3 (``rows`` / ``metrics`` / ``gate``), so the
``BENCH_*.json`` trajectory tooling covers the bandwidth sweep too.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ludwig import gradients as LG
from repro.kernels.lb_collision import ref as lbref
from repro.kernels.lb_propagation import ref as propref
from repro.kernels.wilson_dslash import ref as wdref

try:
    from .common import LUDWIG_KERNELS, MILC_KERNELS, csv_row, ridge_point
except ImportError:  # run as a script: python benchmarks/fig4_bandwidth.py
    from common import LUDWIG_KERNELS, MILC_KERNELS, csv_row, ridge_point


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, (list, tuple)):  # older jax: one dict per computation
        c = c[0] if c else {}
    c = c or {}
    return float(c.get("flops", 0)), float(c.get("bytes accessed", 0))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows + per-kernel metrics to PATH "
                         "(fig3-compatible schema)")
    args = ap.parse_args(argv)
    rows = []
    metrics = {}
    lat = (16, 16, 16)
    nsites = int(np.prod(lat))
    f19 = jax.ShapeDtypeStruct((19, *lat), jnp.float32)
    f5 = jax.ShapeDtypeStruct((5, *lat), jnp.float32)
    f3 = jax.ShapeDtypeStruct((3, *lat), jnp.float32)
    flat = lambda s: jax.ShapeDtypeStruct((s.shape[0], nsites), jnp.float32)

    cases = {
        "collision": (lambda f, g: lbref.collide_ref(f, g, 0.8),
                      (flat(f19), flat(f3)), LUDWIG_KERNELS["collision"]),
        "propagation": (propref.propagate_ref, (f19,),
                        LUDWIG_KERNELS["propagation"]),
        "order_parameter_gradients": (
            lambda q: (LG.grad_central(q), LG.laplacian(q)), (f5,),
            LUDWIG_KERNELS["order_parameter_gradients"]),
        "advection": (LG.advective_divergence, (f5, f3),
                      LUDWIG_KERNELS["advection"]),
    }
    lat4 = (8, 8, 8, 8)
    nsites4 = int(np.prod(lat4))
    psi = jax.ShapeDtypeStruct((24, *lat4), jnp.float32)
    u = jax.ShapeDtypeStruct((72, *lat4), jnp.float32)
    cases["wilson_dslash"] = (wdref.dslash_ref, (psi, u),
                              MILC_KERNELS["extract_and_mult"])

    for name, (fn, fargs, (bps, fps)) in cases.items():
        n = nsites4 if name == "wilson_dslash" else nsites
        flops, hbytes = _cost(fn, *fargs)
        useful = n * bps
        oi = fps / bps if bps else 0.0
        frac = useful / max(hbytes, 1.0)
        metrics[name] = {
            "oi_fpb": oi,
            "useful_bytes": useful,
            "hlo_bytes": hbytes,
            "hlo_flops": flops,
            "achievable_bw_frac": frac,
            "memory_bound_on_v5e": bool(oi < ridge_point("tpu-v5e")),
        }
        rows.append(csv_row(
            f"fig4/{name}", 0.0,
            f"oi_fpb={oi:.2f};useful_bytes={useful};hlo_bytes={hbytes:.0f};"
            f"achievable_bw_frac={frac:.2f};"
            f"memory_bound_on_v5e={oi < ridge_point('tpu-v5e')}"))
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "metrics": metrics, "mode": "fig4",
                       "gate": {"tolerance": None, "failures": []}},
                      f, indent=2)
    return rows


if __name__ == "__main__":
    main()
