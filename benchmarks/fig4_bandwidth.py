"""Paper Fig. 4: per-kernel operational intensity + achieved-bandwidth
fraction.

The wall-clock %STREAM measurement of the paper is replaced by its
structural equivalent on the compiled artifact: for each kernel we lower
the jnp engine on CPU and compare *useful* bytes (the minimal per-site
traffic of the algorithm, the counting the paper uses for OI) against the
HLO "bytes accessed" — useful/HLO = the fraction of achievable bandwidth
the compiled kernel can reach, assuming the memory system runs at STREAM
rate on the rest.  OIs land in the paper's 0.4-2.2 F/B band, far below
every Table-1 ridge point (C4).

``--json PATH`` writes the rows plus structured per-kernel metrics in the
same top-level schema as fig3 (``rows`` / ``metrics`` / ``gate``), so the
``BENCH_*.json`` trajectory tooling covers the bandwidth sweep too.

The ``fig4_tile/*`` rows extend the roofline to tiled lowerings
(``LoweringPlan.by``/``bz``): at the tile the planner itself picks under a
half-footprint VMEM budget, they record bytes moved per tile against the
whole-staging lowering — what tiling buys (per-program footprint bounded
by the tile, not the lattice) and what it costs (halo overfetch where
adjacent tile windows overlap).
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.ludwig import gradients as LG
from repro.core import plan as plan_mod
from repro.core.stencil import tile_boxes
from repro.kernels.lb_collision import ref as lbref
from repro.kernels.lb_propagation import ref as propref
from repro.kernels.wilson_dslash import ref as wdref

try:
    from .common import LUDWIG_KERNELS, MILC_KERNELS, csv_row, ridge_point
except ImportError:  # run as a script: python benchmarks/fig4_bandwidth.py
    from common import LUDWIG_KERNELS, MILC_KERNELS, csv_row, ridge_point


def _tile_roofline(name, lattice, in_views, out_views, rows, metrics,
                   dtypes=None):
    """Tiled-launch roofline row: bytes moved per tile vs whole-staging.

    Pure geometry — ``tile_boxes`` enumerates the cover and the planner's
    own VMEM model (``estimate_vmem_bytes``) prices the footprints, at the
    (by, bz) ``choose_tiles`` picks under a budget of half the untiled
    footprint.  No launch runs; these rows track the *traffic contract* of
    the tiled lowering across the perf trajectory.

    ``dtypes`` (a :class:`repro.core.DtypePolicy`) prices every view at
    the plan's *storage* dtype itemsize — the byte counts, footprints and
    tile picks below are exactly what the policy-aware planner would see,
    so the mixed-precision roofline rows stay honest."""
    if dtypes is not None and dtypes.storage:
        in_views = tuple((nc, r, dtypes.storage_itemsize(isz))
                         for nc, r, isz in in_views)
        out_views = tuple((nc, dtypes.storage_itemsize(isz))
                          for nc, isz in out_views)
        name = f"{name}@{dtypes.tag()}"
    bx = 1
    whole = plan_mod.LoweringPlan("pallas", bx=bx, dtypes=dtypes)
    fp_whole = plan_mod.estimate_vmem_bytes(
        whole, lattice=lattice, in_views=in_views, out_views=out_views)
    by, bz = plan_mod.choose_tiles(
        lattice, bx, in_views=in_views, out_views=out_views,
        vmem_bytes=fp_whole // 2, dtypes=dtypes)
    tiled = plan_mod.LoweringPlan("pallas", bx=bx, by=by, bz=bz,
                                  dtypes=dtypes)
    fp_tiled = plan_mod.estimate_vmem_bytes(
        tiled, lattice=lattice, in_views=in_views, out_views=out_views)
    boxes = tile_boxes(lattice, bx, by, bz)
    exts = [e for _, e in boxes[0]]
    # per-tile DMA payload: one halo'd window per input + the output tile
    tile_in = sum(ncomp * int(np.prod([e + 2 * r for e in exts])) * isz
                  for ncomp, r, isz in in_views)
    tile_out = sum(ncomp * int(np.prod(exts)) * isz
                   for ncomp, isz in out_views)
    whole_in = sum(ncomp * int(np.prod([s + 2 * r for s in lattice])) * isz
                   for ncomp, r, isz in in_views)
    useful_in = sum(ncomp * int(np.prod(lattice)) * isz
                    for ncomp, _, isz in in_views)
    # adjacent tile windows overlap by the halo ring, so total tile
    # traffic overfetches the minimal (whole-staged) input bytes
    overfetch = len(boxes) * tile_in / max(useful_in, 1)
    metrics[f"tile_{name}"] = {
        "tile": [bx, by, bz],
        "tiles": len(boxes),
        "bytes_per_tile": tile_in + tile_out,
        "bytes_whole_staged": whole_in,
        "vmem_tiled": fp_tiled,
        "vmem_whole": fp_whole,
        "overfetch_vs_useful": overfetch,
    }
    rows.append(csv_row(
        f"fig4_tile/{name}", 0.0,
        f"tile={bx}x{by or lattice[1]}x{bz or lattice[2]};"
        f"tiles={len(boxes)};bytes_per_tile={tile_in + tile_out};"
        f"bytes_whole_staged={whole_in};vmem_tiled={fp_tiled};"
        f"vmem_whole={fp_whole};overfetch_vs_useful={overfetch:.2f}"))


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, (list, tuple)):  # older jax: one dict per computation
        c = c[0] if c else {}
    c = c or {}
    return float(c.get("flops", 0)), float(c.get("bytes accessed", 0))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows + per-kernel metrics to PATH "
                         "(fig3-compatible schema)")
    args = ap.parse_args(argv)
    rows = []
    metrics = {}
    lat = (16, 16, 16)
    nsites = int(np.prod(lat))
    f19 = jax.ShapeDtypeStruct((19, *lat), jnp.float32)
    f5 = jax.ShapeDtypeStruct((5, *lat), jnp.float32)
    f3 = jax.ShapeDtypeStruct((3, *lat), jnp.float32)
    flat = lambda s: jax.ShapeDtypeStruct((s.shape[0], nsites), jnp.float32)

    cases = {
        "collision": (lambda f, g: lbref.collide_ref(f, g, 0.8),
                      (flat(f19), flat(f3)), LUDWIG_KERNELS["collision"]),
        "propagation": (propref.propagate_ref, (f19,),
                        LUDWIG_KERNELS["propagation"]),
        "order_parameter_gradients": (
            lambda q: (LG.grad_central(q), LG.laplacian(q)), (f5,),
            LUDWIG_KERNELS["order_parameter_gradients"]),
        "advection": (LG.advective_divergence, (f5, f3),
                      LUDWIG_KERNELS["advection"]),
    }
    lat4 = (8, 8, 8, 8)
    nsites4 = int(np.prod(lat4))
    psi = jax.ShapeDtypeStruct((24, *lat4), jnp.float32)
    u = jax.ShapeDtypeStruct((72, *lat4), jnp.float32)
    cases["wilson_dslash"] = (wdref.dslash_ref, (psi, u),
                              MILC_KERNELS["extract_and_mult"])

    for name, (fn, fargs, (bps, fps)) in cases.items():
        n = nsites4 if name == "wilson_dslash" else nsites
        flops, hbytes = _cost(fn, *fargs)
        useful = n * bps
        oi = fps / bps if bps else 0.0
        frac = useful / max(hbytes, 1.0)
        metrics[name] = {
            "oi_fpb": oi,
            "useful_bytes": useful,
            "hlo_bytes": hbytes,
            "hlo_flops": flops,
            "achievable_bw_frac": frac,
            "memory_bound_on_v5e": bool(oi < ridge_point("tpu-v5e")),
        }
        rows.append(csv_row(
            f"fig4/{name}", 0.0,
            f"oi_fpb={oi:.2f};useful_bytes={useful};hlo_bytes={hbytes:.0f};"
            f"achievable_bw_frac={frac:.2f};"
            f"memory_bound_on_v5e={oi < ridge_point('tpu-v5e')}"))
    # tiled-launch roofline: views mirror what launch() feeds the planner
    # (dist width-1 + width-0 force for the LB stencil; width-2 spinor +
    # gauge for the fused M^dag M)
    tile_cases = {
        "lb_stencil": (lat, ((19, 1, 4), (3, 0, 4)), ((19, 4),)),
        "wilson_normal": (lat4, ((24, 2, 4), (72, 2, 4)), ((24, 4),)),
    }
    # every tile case also gets a mixed-precision twin row: identical
    # geometry, views priced at the policy's storage itemsize
    policies = (None,
                plan_mod.DtypePolicy(storage="float32", compute="float32",
                                     accumulate="float64"),
                plan_mod.DtypePolicy(storage="bfloat16", compute="float32",
                                     accumulate="float64"))
    for name, (tlat, iv, ov) in tile_cases.items():
        for pol in policies:
            _tile_roofline(name, tlat, iv, ov, rows, metrics, dtypes=pol)
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "metrics": metrics, "mode": "fig4",
                       "gate": {"tolerance": None, "failures": []}},
                      f, indent=2)
    return rows


if __name__ == "__main__":
    main()
