"""Batched-serving smoke benchmark: multi-simulation solve throughput.

Times the batched CG serving path (apps.milc.driver.solve_batched — one
fused operator pallas_call + one fused masked-update pallas_call per
iteration for the WHOLE batch) against the looped single-solve oracle at
batch sizes 1/4/16, and gates on the serving contract: every slot of the
batched solve must be *bitwise identical* to the corresponding dedicated
solve.  Timings off-accelerator are trend-only (interpret-mode CPU); the
bit-identity gate is the CI pass/fail.

CI mode: ``--smoke --json SERVE_ci.json`` runs a tiny lattice at a fixed
iteration count (tol=0, so every batch size does identical per-request
work) and writes the fig3-schema artifact (``rows``/``metrics``/``gate``).
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

try:  # runnable both as a module and as a script
    from .common import csv_row, time_fn
except ImportError:
    from common import csv_row, time_fn

from repro.apps.milc import driver, fields
from repro.core import Field, SOA, TargetConfig

BATCHES = (1, 4, 16)


def measured_serving(smoke: bool, engine: str, iters: int):
    lattice = (4, 4, 4, 8) if smoke else (8, 8, 8, 8)
    cfg = driver.MilcConfig(lattice=lattice, kappa=0.10, tol=0.0,
                            max_iter=iters, layout=SOA,
                            target=TargetConfig(engine, vvl=128))
    u, _ = driver.init_problem(cfg, seed=0)
    sources = [Field.from_numpy(
        "b", fields.random_spinor(lattice, seed=100 + i), lattice,
        cfg.layout) for i in range(max(BATCHES))]

    rows, metrics = [], {}
    # looped oracle timing: one solve, scaled — every request is the same
    # work at tol=0, and the loop has no cross-request reuse to measure
    t_single = time_fn(lambda: driver.solve(cfg, u, sources[0]),
                       iters=3, warmup=1)
    for bsz in BATCHES:
        bs = sources[:bsz]
        t_batched = time_fn(lambda _bs=bs: driver.solve_batched(cfg, u, _bs),
                            iters=3, warmup=1)
        res = driver.solve_batched(cfg, u, bs)
        identical = True
        for i, b in enumerate(bs):
            r1 = driver.solve(cfg, u, b)
            identical &= np.array_equal(np.asarray(res.x.element(i).data),
                                        np.asarray(r1.x.data))
            identical &= int(res.iterations[i]) == int(r1.iterations)
            identical &= np.array_equal(np.asarray(res.residual[i]),
                                        np.asarray(r1.residual))
        per_req = t_batched / bsz
        speedup = t_single / per_req if per_req > 0 else 0.0
        name = f"serve_smoke/batched_cg_b{bsz}"
        rows.append(csv_row(
            name, per_req * 1e6,
            f"batch={bsz};iters={iters};vs_loop={speedup:.2f}x;"
            f"bit_identical={identical}"))
        metrics[name] = {
            "batch": bsz, "cg_iters": iters, "engine": engine,
            "lattice": list(lattice), "batched_s": t_batched,
            "single_s": t_single, "per_request_s": per_req,
            "speedup_vs_loop": speedup, "bit_identical": bool(identical),
        }
    return rows, metrics


def gate_serving(metrics):
    """CI pass/fail: the batched lowering must reproduce the dedicated
    per-request solves bit-for-bit at every batch size (throughput is
    archived for trend review, not gated — off-accelerator timings
    jitter)."""
    return [f"{name}: batched solve diverged from the looped single-solve "
            f"oracle (serving-path regression)"
            for name, m in metrics.items() if not m["bit_identical"]]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny lattice (CI-sized run)")
    ap.add_argument("--engine", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--iters", type=int, default=12,
                    help="fixed CG iterations per request (tol=0)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows/metrics/gate to PATH (fig3 schema)")
    args = ap.parse_args(argv)

    rows, metrics = measured_serving(args.smoke, args.engine, args.iters)
    failures = gate_serving(metrics)
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "metrics": metrics,
                       "smoke": args.smoke, "mode": "serving",
                       "gate": {"tolerance": None, "failures": failures}},
                      f, indent=2)
    if failures:
        print("SERVING BIT-IDENTITY GATE FAILED:", *failures, sep="\n  ",
              file=sys.stderr)
        sys.exit(1)
    return rows, metrics, failures


if __name__ == "__main__":
    main()
