"""Batched-serving smoke benchmark: multi-simulation solve throughput.

Times the batched CG serving path (apps.milc.driver.solve_batched — one
fused operator pallas_call + one fused masked-update pallas_call per
iteration for the WHOLE batch) against the looped single-solve oracle at
batch sizes 1/4/16, and gates on the serving contract: every slot of the
batched solve must be *bitwise identical* to the corresponding dedicated
solve.  Timings off-accelerator are trend-only (interpret-mode CPU); the
bit-identity gate is the CI pass/fail.

CI mode: ``--smoke --json SERVE_ci.json`` runs a tiny lattice at a fixed
iteration count (tol=0, so every batch size does identical per-request
work) and writes the fig3-schema artifact (``rows``/``metrics``/``gate``).

``--rsplit-sweep`` (CI artifact ``SPLIT_ci.json``) instead drives the
small-batch-many-requests serving shape through a *split-reduction*
(rsplit > 1) tuned plan for the fused normal operator and compares
per-request throughput against the unsplit default.  The gate is the
split-reduction contract, not the timing: split solutions must match the
unsplit ones within the documented fp tolerance (the <p, Ap> partials are
reassociated, nothing else is) and replay bitwise-identically.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile

import numpy as np

try:  # runnable both as a module and as a script
    from .common import csv_row, time_fn
except ImportError:
    from common import csv_row, time_fn

from repro.apps.milc import driver, fields
from repro.core import BatchedField, Field, SOA, TargetConfig, tune

BATCHES = (1, 4, 16)

# split-vs-unsplit solution agreement for the rsplit gate: only the fused
# <p, Ap> accumulation order differs, so the CG trajectories stay within
# a few ulps per iteration (see README "Split reductions")
RSPLIT_REL_TOL = 1e-4


def measured_serving(smoke: bool, engine: str, iters: int):
    lattice = (4, 4, 4, 8) if smoke else (8, 8, 8, 8)
    cfg = driver.MilcConfig(lattice=lattice, kappa=0.10, tol=0.0,
                            max_iter=iters, layout=SOA,
                            target=TargetConfig(engine, vvl=128))
    u, _ = driver.init_problem(cfg, seed=0)
    sources = [Field.from_numpy(
        "b", fields.random_spinor(lattice, seed=100 + i), lattice,
        cfg.layout) for i in range(max(BATCHES))]

    rows, metrics = [], {}
    # looped oracle timing: one solve, scaled — every request is the same
    # work at tol=0, and the loop has no cross-request reuse to measure
    t_single = time_fn(lambda: driver.solve(cfg, u, sources[0]),
                       iters=3, warmup=1)
    for bsz in BATCHES:
        bs = sources[:bsz]
        t_batched = time_fn(lambda _bs=bs: driver.solve_batched(cfg, u, _bs),
                            iters=3, warmup=1)
        res = driver.solve_batched(cfg, u, bs)
        identical = True
        for i, b in enumerate(bs):
            r1 = driver.solve(cfg, u, b)
            identical &= np.array_equal(np.asarray(res.x.element(i).data),
                                        np.asarray(r1.x.data))
            identical &= int(res.iterations[i]) == int(r1.iterations)
            identical &= np.array_equal(np.asarray(res.residual[i]),
                                        np.asarray(r1.residual))
        per_req = t_batched / bsz
        speedup = t_single / per_req if per_req > 0 else 0.0
        name = f"serve_smoke/batched_cg_b{bsz}"
        rows.append(csv_row(
            name, per_req * 1e6,
            f"batch={bsz};iters={iters};vs_loop={speedup:.2f}x;"
            f"bit_identical={identical}"))
        metrics[name] = {
            "batch": bsz, "cg_iters": iters, "engine": engine,
            "lattice": list(lattice), "batched_s": t_batched,
            "single_s": t_single, "per_request_s": per_req,
            "speedup_vs_loop": speedup, "bit_identical": bool(identical),
        }
    return rows, metrics


def measured_rsplit(smoke: bool, engine: str, iters: int):
    """Small-batch-many-requests CG serving, split vs unsplit reduction.

    Records an rsplit>1 winner for the fused normal-operator key into an
    isolated tune table (the ENV_VAR override), then serves ``requests``
    solves in batches of ``bsz`` under plan_policy="tuned" — only the
    wilson_normal launch flips to the split lowering; every other launch
    misses the table and keeps its default plan."""
    from repro.apps.milc.cg import wilson_normal_graph

    lattice = (4, 4, 4, 8) if smoke else (8, 8, 8, 8)
    bsz = 2
    requests = 4 if smoke else 8
    engine = "pallas"  # the split lowering is a pallas grid axis
    tgt = TargetConfig(engine, vvl=128)
    cfg = driver.MilcConfig(lattice=lattice, kappa=0.10, tol=0.0,
                            max_iter=iters, layout=SOA, target=tgt)
    u, b = driver.init_problem(cfg, seed=0)
    sources = [Field.from_numpy(
        "b", fields.random_spinor(lattice, seed=200 + i), lattice,
        cfg.layout) for i in range(requests)]

    g = wilson_normal_graph(float(cfg.kappa))
    # the batched serving launch keys the table per batch size: probe with
    # a bsz-stacked p so the recorded winner is what serving looks up
    probe = {"p": BatchedField.stack([b] * bsz, name="p"), "u": u}
    cands = tune.plan_candidates_for(g, probe, config=tgt,
                                     outputs=("ap", "pap"))
    split_cands = [c for c in cands if c.rsplit > 1]
    if not split_cands:
        raise SystemExit(
            f"no rsplit candidate for lattice {lattice}: sweep offered "
            f"{[c.describe() for c in cands]}")
    split_plan = split_cands[0]
    key = g.plan_key(probe, config=tgt, outputs=("ap", "pap"))

    def serve(run_cfg):
        outs = []
        for i in range(0, requests, bsz):
            outs.append(driver.solve_batched(run_cfg, u,
                                             sources[i:i + bsz]))
        return outs

    def stack_x(outs):
        return np.concatenate(
            [np.asarray(r.x.element(i).canonical())
             for r in outs for i in range(bsz)])

    rows, metrics = [], {}
    runs = {}
    prev_env = os.environ.get(tune.ENV_VAR)
    with tempfile.TemporaryDirectory() as tmp:
        os.environ[tune.ENV_VAR] = os.path.join(tmp, "rsplit_table.json")
        try:
            tune.clear_table_cache()
            tune.record(key, split_plan)
            for label, policy in (("unsplit", "default"), ("split", "tuned")):
                run_cfg = dataclasses.replace(
                    cfg, target=dataclasses.replace(tgt, plan_policy=policy))
                t = time_fn(lambda _c=run_cfg: serve(_c), iters=2, warmup=1)
                res = stack_x(serve(run_cfg))
                replay = stack_x(serve(run_cfg))
                runs[label] = {
                    "x": res, "t": t,
                    "reproducible": bool(np.array_equal(res, replay)),
                }
        finally:
            if prev_env is None:
                os.environ.pop(tune.ENV_VAR, None)
            else:
                os.environ[tune.ENV_VAR] = prev_env
            tune.clear_table_cache()

    rel = float(np.linalg.norm(runs["split"]["x"] - runs["unsplit"]["x"])
                / np.linalg.norm(runs["unsplit"]["x"]))
    for label, run in runs.items():
        per_req = run["t"] / requests
        other = runs["split" if label == "unsplit" else "unsplit"]
        name = f"serve_smoke/rsplit_{label}_b{bsz}"
        rows.append(csv_row(
            name, per_req * 1e6,
            f"requests={requests};iters={iters};plan="
            f"{(split_plan if label == 'split' else cands[0]).describe()};"
            f"vs_other={other['t'] / run['t']:.2f}x;"
            f"reproducible={run['reproducible']}"))
        metrics[name] = {
            "requests": requests, "batch": bsz, "cg_iters": iters,
            "engine": engine, "lattice": list(lattice),
            "plan": (split_plan if label == "split" else cands[0]).describe(),
            "total_s": run["t"], "per_request_s": per_req,
            "rel_l2_vs_unsplit": rel if label == "split" else 0.0,
            "bit_reproducible": run["reproducible"],
        }
    return rows, metrics


def gate_rsplit(metrics):
    """CI pass/fail for the split-reduction serving contract: tolerance
    agreement with the unsplit plan and bitwise replay determinism.
    Throughput is archived for trend review only."""
    failures = []
    for name, m in metrics.items():
        if m["rel_l2_vs_unsplit"] > RSPLIT_REL_TOL:
            failures.append(
                f"{name}: split solution drifted "
                f"rel={m['rel_l2_vs_unsplit']:.2e} > {RSPLIT_REL_TOL}")
        if not m["bit_reproducible"]:
            failures.append(f"{name}: replay was not bitwise identical")
    return failures


def gate_serving(metrics):
    """CI pass/fail: the batched lowering must reproduce the dedicated
    per-request solves bit-for-bit at every batch size (throughput is
    archived for trend review, not gated — off-accelerator timings
    jitter)."""
    return [f"{name}: batched solve diverged from the looped single-solve "
            f"oracle (serving-path regression)"
            for name, m in metrics.items() if not m["bit_identical"]]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny lattice (CI-sized run)")
    ap.add_argument("--engine", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--iters", type=int, default=12,
                    help="fixed CG iterations per request (tol=0)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows/metrics/gate to PATH (fig3 schema)")
    ap.add_argument("--rsplit-sweep", action="store_true",
                    help="split-vs-unsplit reduction serving comparison "
                         "(SPLIT_ci.json artifact)")
    args = ap.parse_args(argv)

    if args.rsplit_sweep:
        rows, metrics = measured_rsplit(args.smoke, args.engine, args.iters)
        failures = gate_rsplit(metrics)
        mode, tol = "rsplit", RSPLIT_REL_TOL
        fail_banner = "RSPLIT TOLERANCE GATE FAILED:"
    else:
        rows, metrics = measured_serving(args.smoke, args.engine, args.iters)
        failures = gate_serving(metrics)
        mode, tol = "serving", None
        fail_banner = "SERVING BIT-IDENTITY GATE FAILED:"
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "metrics": metrics,
                       "smoke": args.smoke, "mode": mode,
                       "gate": {"tolerance": tol, "failures": failures}},
                      f, indent=2)
    if failures:
        print(fail_banner, *failures, sep="\n  ", file=sys.stderr)
        sys.exit(1)
    return rows, metrics, failures


if __name__ == "__main__":
    main()
