"""LM architecture roofline table from the dry-run sweep results.

Reads results/dryrun.jsonl (produced by repro.launch.sweep) and prints the
per-cell three-term roofline + dominant bottleneck + useful-flops ratio —
the §Roofline table of EXPERIMENTS.md in CSV form.  Run the sweep first;
rows missing from the file are reported as such rather than recomputed
(a full sweep is ~1h of lowering on this host).
"""

from __future__ import annotations

import json
import os

from .common import csv_row

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun.jsonl")


def load_rows(path=RESULTS):
    rows = {}
    if not os.path.exists(path):
        return rows
    for line in open(path):
        try:
            r = json.loads(line)
        except Exception:
            continue
        rows[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return rows


def main():
    data = load_rows()
    out = []
    if not data:
        out.append(csv_row("lm_roofline/missing", 0.0,
                           f"run repro.launch.sweep first ({RESULTS})"))
    singles = {k: v for k, v in data.items() if k[2] == "single"}
    for (arch, shape, mesh), r in sorted(singles.items()):
        if r["status"] == "skipped":
            out.append(csv_row(f"lm_roofline/{arch}/{shape}", 0.0,
                               "status=skipped"))
            continue
        if r["status"] != "ok":
            out.append(csv_row(f"lm_roofline/{arch}/{shape}", 0.0,
                               f"status={r['status']}"))
            continue
        t = r["roofline"]
        step_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        out.append(csv_row(
            f"lm_roofline/{arch}/{shape}", step_s * 1e6,
            f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
            f"collective_s={t['collective_s']:.4f};dominant={t['dominant']};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
            f"hbm_gib_per_dev={r['memory']['live_per_device_gib']}"))
    multi_ok = sum(1 for k, v in data.items()
                   if k[2] == "multi" and v["status"] == "ok")
    multi_skip = sum(1 for k, v in data.items()
                     if k[2] == "multi" and v["status"] == "skipped")
    out.append(csv_row("lm_roofline/multi_pod_summary", 0.0,
                       f"ok={multi_ok};skipped={multi_skip}"))
    for r in out:
        print(r)
    return out


if __name__ == "__main__":
    main()
