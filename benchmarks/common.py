"""Shared benchmark utilities: hardware table (paper Table 1 + TPU v5e),
timing helpers, kernel byte/flop accounting."""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

# Paper Table 1 (+ TPU v5e target): name -> (peak double-precision-equiv
# GFLOP/s, STREAM-triad-like achievable GB/s).  For v5e we use bf16 peak
# and the HBM spec since that is the machine model of the roofline report.
PROCESSORS = {
    "ivy-bridge": (259e9, 49.8e9),
    "haswell": (154e9, 40.9e9),
    "interlagos": (141e9, 32.4e9),
    "xeon-phi": (1.01e12, 158.4e9),
    "k20x": (1.31e12, 181.3e9),
    "k40": (1.43e12, 192.1e9),
    "tpu-v5e": (197e12, 819e9),
}


def ridge_point(proc: str) -> float:
    peak, bw = PROCESSORS[proc]
    return peak / bw


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (blocks on all outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(jax.tree_util.tree_leaves(fn(*args)))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree_util.tree_leaves(fn(*args)))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def traffic_row(name: str, t_s: float, bytes_moved: int) -> str:
    """Row for the fused-vs-unfused comparison: wall time + modelled HBM
    traffic (LaunchGraph.bytes_moved counting) + implied bandwidth."""
    gbps = bytes_moved / t_s / 1e9 if t_s > 0 else 0.0
    return csv_row(name, t_s * 1e6,
                   f"bytes_moved={bytes_moved};model_GBps={gbps:.2f}")


# Per-site traffic model of each application kernel (fp32 bytes, reads +
# writes, the counting convention of the paper's Fig. 4 OI numbers).
LUDWIG_KERNELS = {
    # name: (bytes_per_site, flops_per_site)
    "collision": ((19 + 3 + 19) * 4, 300),          # f in, force in, f out
    # fused moments+collision launch: f+force in once, f'+u out (rho is an
    # unrequested intermediate and never touches HBM)
    "collision_moments": ((19 + 3 + 19 + 3) * 4, 330),
    "propagation": ((19 + 19) * 4, 0),
    # fused moments+collision+streaming stencil launch (what driver.step
    # actually runs): f+force in once, streamed f''+u out — the
    # post-collision f' never touches HBM
    "lb_step": ((19 + 3 + 19 + 3) * 4, 330),
    "order_parameter_gradients": ((5 + 15 + 5) * 4, 5 * 8),
    "chemical_stress": ((5 + 5 + 15 + 9) * 4, 450),
    "lc_update": ((5 + 5 + 9 + 5 + 5) * 4, 400),
    "advection": ((5 + 3 + 5) * 4, 60),
}

MILC_KERNELS = {
    "shift": ((24 + 24) * 4 * 8, 0),                 # 8 directions
    "extract_and_mult": ((192 + 144 + 24) * 4, 1320),
    "scalar_mult_add": ((24 + 24 + 24) * 4, 48),
}
