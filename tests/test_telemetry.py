"""core.telemetry: the unified observability registry.  Disabled no-op
path, gated spans/gauges vs always-on counters, the fuse/tune stats()
back-compat shims, launch-span schema with cache transitions and live
roofline placement, per-launch TargetConfig.telemetry override, Chrome
trace + JSONL export, report snapshots, the unified repro.* logging tree
(tuner candidate failures, overlap thin-interior fallback, tuned-misfit
degrade — all caplog-asserted), tune sweep spans and pipeline step spans.
"""

import json
import logging

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Field, LaunchGraph, LoweringPlan, SOA, StepPipeline, TargetConfig, fuse,
    telemetry, tune,
)

LAT = (4, 4, 8)  # 128 sites


@pytest.fixture(autouse=True)
def clean_telemetry():
    """Every test starts and ends with telemetry off and empty — span
    state must never leak between tests (or into other test files)."""
    telemetry.disable()
    telemetry.reset()
    yield
    telemetry.disable()
    telemetry.reset()


def _scale_body(v):
    return {"t": 2.0 * v["x"]}


def _graph(name="telemetry_probe"):
    return LaunchGraph(name).add(_scale_body, {"x": "x"}, {"t": 3})


def _field(rng):
    arr = rng.normal(size=(3, *LAT)).astype(np.float32)
    return Field.from_numpy("x", arr, LAT, SOA)


# -- gating --------------------------------------------------------------------

def test_env_parser():
    assert telemetry._env_enabled("1")
    assert telemetry._env_enabled(" TRUE ")
    assert telemetry._env_enabled("on") and telemetry._env_enabled("yes")
    assert not telemetry._env_enabled(None)
    assert not telemetry._env_enabled("")
    assert not telemetry._env_enabled("0")
    assert not telemetry._env_enabled("off")


def test_disabled_spans_are_noop_counters_still_count():
    s = telemetry.span("probe/x", a=1)
    assert s is telemetry.NULL_SPAN and not s
    with telemetry.span("probe/y") as s2:
        s2.set(k=2).end()
    telemetry.event("probe/ev", a=1)
    telemetry.sample("probe.g", 3.0)
    assert telemetry.events() == []
    assert telemetry.gauges() == {}
    # counters are the pre-telemetry stats() probes: never gated
    telemetry.inc("probe.count", 2)
    assert telemetry.counter_value("probe.count") == 2


def test_enabled_span_records_name_attrs_duration():
    telemetry.enable()
    with telemetry.span("probe/work", stage="a") as s:
        s.set(extra=1)
    (e,) = telemetry.events("probe/work")
    assert e["type"] == "span"
    assert e["attrs"] == {"stage": "a", "extra": 1}
    assert e["dur"] >= 0.0
    # an exception inside the span is recorded, not swallowed
    with pytest.raises(RuntimeError):
        with telemetry.span("probe/boom"):
            raise RuntimeError("kaboom")
    (b,) = telemetry.events("probe/boom")
    assert "RuntimeError" in b["attrs"]["error"]


def test_override_beats_process_switch():
    # off process-wide, on per call site
    s = telemetry.span("probe/forced", override=True)
    assert s is not telemetry.NULL_SPAN
    s.end()
    assert len(telemetry.events("probe/forced")) == 1
    # on process-wide, off per call site
    telemetry.enable()
    assert telemetry.span("probe/muted", override=False) is telemetry.NULL_SPAN


# -- counter shims -------------------------------------------------------------

def test_stats_shims_exact_keys_and_scoped_reset():
    fuse.reset_stats()
    tune.reset_stats()
    assert sorted(fuse.stats()) == [
        "cache_hits", "cache_misses", "pallas_calls", "traces"]
    assert sorted(tune.stats()) == [
        "hits", "lookups", "sweep_launches", "tunes"]
    telemetry.inc("fuse.traces")
    telemetry.inc("tune.lookups")
    assert fuse.stats()["traces"] == 1
    assert tune.stats()["lookups"] == 1
    fuse.reset_stats()  # prefix-scoped: must not touch tune.*
    assert fuse.stats()["traces"] == 0
    assert tune.stats()["lookups"] == 1


# -- launch spans --------------------------------------------------------------

LAUNCH_SPAN_SCHEMA = (
    "plan", "engine", "lattice", "batch", "halo", "from_tuned_table",
    "cache", "bytes_fused", "bytes_unfused", "gbps_achieved",
    "roofline_ceiling_gbps", "roofline_frac", "roofline_placement",
)


def test_launch_span_schema_cache_transition_and_bitwise(rng):
    fx = _field(rng)
    cfg = TargetConfig("jnp")
    fuse.clear_cache()
    base = _graph().launch({"x": fx}, config=cfg)["t"].to_numpy()  # disabled

    telemetry.enable()
    fuse.clear_cache()
    got = _graph().launch({"x": fx}, config=cfg)["t"].to_numpy()
    again = _graph().launch({"x": fx}, config=cfg)["t"].to_numpy()
    # observability never perturbs the computation: bit-for-bit equal
    np.testing.assert_array_equal(got, base)
    np.testing.assert_array_equal(again, base)

    spans = telemetry.events("launch/telemetry_probe")
    assert len(spans) == 2
    for e in spans:
        for field in LAUNCH_SPAN_SCHEMA:
            assert field in e["attrs"], f"launch span missing {field}"
    assert [e["attrs"]["cache"] for e in spans] == ["miss", "hit"]
    a = spans[0]["attrs"]
    assert a["engine"] == "jnp"
    assert a["lattice"] == str(LAT)
    assert a["bytes_fused"] > 0 and a["bytes_unfused"] >= a["bytes_fused"]
    assert a["gbps_achieved"] > 0 and a["roofline_frac"] > 0
    assert "memory-roof" in a["roofline_placement"]
    assert a["from_tuned_table"] is False


def test_config_telemetry_override_per_launch(rng):
    fx = _field(rng)
    # process switch off, per-launch on
    _graph("cfg_on").launch({"x": fx}, config=TargetConfig(
        "jnp", telemetry=True))
    assert len(telemetry.events("launch/cfg_on")) == 1
    # process switch on, per-launch off
    telemetry.enable()
    _graph("cfg_off").launch({"x": fx}, config=TargetConfig(
        "jnp", telemetry=False))
    assert telemetry.events("launch/cfg_off") == []


# -- export --------------------------------------------------------------------

def test_chrome_trace_export(tmp_path):
    telemetry.enable()
    with telemetry.span("probe/a", k="v"):
        pass
    telemetry.event("probe/inst", why="x")
    telemetry.sample("probe.gauge", 1.5)
    path = telemetry.export_chrome_trace(str(tmp_path / "trace.json"))
    data = json.loads(open(path).read())
    evs = data["traceEvents"]
    assert {"M", "X", "i", "C"} <= {e["ph"] for e in evs}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["name"] == "probe/a" and x["args"]["k"] == "v"
    assert x["dur"] >= 0 and x["cat"] == "probe"
    c = next(e for e in evs if e["ph"] == "C")
    assert c["name"] == "probe.gauge"


def test_jsonl_sinks(tmp_path):
    live = tmp_path / "live.jsonl"
    telemetry.enable(jsonl=str(live))
    with telemetry.span("probe/a"):
        pass
    telemetry.disable()  # closes the live sink
    lines = [json.loads(ln) for ln in live.read_text().splitlines()]
    assert [ln["name"] for ln in lines] == ["probe/a"]

    telemetry.enable()
    with telemetry.span("probe/b"):
        pass
    batch = tmp_path / "batch.jsonl"
    telemetry.write_jsonl(str(batch))
    names = [json.loads(ln)["name"] for ln in batch.read_text().splitlines()]
    assert "probe/b" in names


def test_report_and_format():
    telemetry.enable()
    telemetry.inc("probe.count", 3)
    telemetry.sample("probe.gauge", 2.0)
    telemetry.sample("probe.gauge", 4.0)
    for _ in range(2):
        with telemetry.span("probe/s"):
            pass
    r = telemetry.report()
    assert r["counters"]["probe.count"] == 3
    assert r["spans"]["probe/s"]["count"] == 2
    g = r["gauges"]["probe.gauge"]
    assert (g["min"], g["max"], g["last"]) == (2.0, 4.0, 4.0)
    txt = telemetry.format_report()
    assert "probe.count" in txt and "probe/s" in txt


def test_roofline_placement_fields():
    from repro.launch.roofline import HBM_BW

    r = telemetry.roofline_placement(int(HBM_BW), 1.0)  # exactly the roof
    assert r["gbps_achieved"] == pytest.approx(HBM_BW / 1e9)
    assert r["roofline_frac"] == pytest.approx(1.0)
    assert "memory-roof" in r["roofline_placement"]
    assert telemetry.roofline_placement(100, 0.0)["gbps_achieved"] == 0.0


# -- unified logging -----------------------------------------------------------

def test_configure_logging_idempotent():
    lg = telemetry.configure_logging(level=logging.DEBUG)
    assert lg.name == "repro"
    flagged = [h for h in lg.handlers
               if getattr(h, "_targetdp_telemetry_handler", False)]
    assert len(flagged) == 1
    try:
        lg2 = telemetry.configure_logging(level=logging.INFO)  # re-level only
        assert lg2 is lg
        assert [h for h in lg.handlers
                if getattr(h, "_targetdp_telemetry_handler", False)] == flagged
        assert lg.level == logging.INFO
    finally:
        lg.removeHandler(flagged[0])
        lg.setLevel(logging.NOTSET)


def test_tuned_misfit_degrade_logged_and_recovers(rng, monkeypatch, caplog):
    """A stale tuned-table plan that cannot validate degrades to the
    default plan through the repro.core.fuse logger — warned, not fatal,
    and numerically identical to the default policy."""
    fx = _field(rng)
    bad = LoweringPlan("jnp", rsplit=2)  # jnp has no reduction grid to split
    monkeypatch.setattr(tune, "lookup", lambda key, path=None: bad)
    want = _graph("degrade_probe").launch(
        {"x": fx}, config=TargetConfig("jnp"))["t"].to_numpy()
    with caplog.at_level(logging.WARNING, logger="repro.core.fuse"):
        got = _graph("degrade_probe").launch(
            {"x": fx}, config=TargetConfig("jnp", plan_policy="tuned"))[
                "t"].to_numpy()
    assert any("falling back to the default plan" in r.message
               for r in caplog.records)
    np.testing.assert_array_equal(got, want)


def test_overlap_thin_interior_fallback_logs_under_repro_root(rng, caplog):
    """The overlap thin-interior fallback reaches the unified ``repro``
    logger tree (configure_logging's single attachment point) as a
    ``repro.core.overlap`` child record."""
    from repro.core.stencil import halo_pad

    def body(v, gather):
        s = v["x"]
        for d in range(3):
            for sgn in (1, -1):
                disp = [0, 0, 0]
                disp[d] = sgn
                s = s + gather("x", tuple(disp))
        return {"z": s}

    g = LaunchGraph("tele_stencil").add_stencil(
        body, {"x": "x"}, {"z": 3}, width=1)
    thin = (2, 2, 2)
    arr = rng.normal(size=(3, *thin)).astype(np.float32)
    h = halo_pad(jnp.asarray(arr), 1, (1, 2, 3))
    fx = Field.from_canonical("x", h, tuple(h.shape[1:]), SOA)
    cfg = TargetConfig("jnp")
    want = g.launch({"x": fx}, config=cfg, halo="pre")["z"]
    with caplog.at_level(logging.WARNING, logger="repro"):
        got = g.launch({"x": fx}, config=cfg, halo="overlap")["z"]
    recs = [r for r in caplog.records if r.name == "repro.core.overlap"
            and "falling back to halo='pre'" in r.message]
    assert recs, "overlap fallback did not log through the repro.* tree"
    np.testing.assert_array_equal(want.to_numpy(), got.to_numpy())


# -- tune sweep spans ----------------------------------------------------------

def test_tune_sweep_spans_and_failure_capture(tmp_path, monkeypatch, rng,
                                              caplog):
    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "t.json"))
    tune.clear_table_cache()
    telemetry.enable()
    fx = _field(rng)
    g = _graph("sweep_probe")
    cfg = TargetConfig("pallas", vvl=64)
    good = tune.plan_candidates_for(g, {"x": fx}, config=cfg)[0]
    bad = LoweringPlan("jnp", rsplit=2)  # raises at plan validation
    with caplog.at_level(logging.WARNING, logger="repro.core.tune"):
        times, failed = tune._sweep(
            g, {"x": fx}, {"config": cfg}, (good, bad), 1, 1)
    assert good in times and bad in failed
    assert any("failed" in r.message for r in caplog.records)
    (sweep,) = telemetry.events("tune/sweep")
    assert sweep["attrs"]["candidates"] == 2
    assert sweep["attrs"]["failed"] == 1 and sweep["attrs"]["timed"] == 1
    cands = telemetry.events("tune/candidate")
    assert any(e["attrs"]["phase"] == "timed" for e in cands)
    fails = telemetry.events("tune/failed")
    assert fails and "rsplit" in fails[0]["attrs"]["reason"]


# -- pipeline spans ------------------------------------------------------------

def test_pipeline_step_spans():
    telemetry.enable()

    def incstep(x):
        return x + 1

    pipe = StepPipeline(incstep, donate=False)
    (out,) = pipe.run((jnp.zeros(4),), steps=3)
    np.testing.assert_array_equal(np.asarray(out), 3.0 * np.ones(4))
    steps = [e for e in telemetry.events("pipeline/incstep")
             if e["name"] == "pipeline/incstep"]
    assert [e["attrs"]["step"] for e in steps] == [0, 1, 2]
    (blk,) = telemetry.events("pipeline/incstep.block")
    assert blk["attrs"]["steps"] == 3
