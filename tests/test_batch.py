"""Batched launches (multi-simulation serving, leading batch axis).

The contract under test: lowering a BatchedField stack through ONE launch
is per-element *bitwise identical* to a Python loop of single-Field
launches — site-local chains, stencils under every halo mode, fused
terminal reductions and standalone target_sum, across layouts and both
engines — and the whole batch still costs one pallas_call.  Plus the
reduce_info regression (exact per-output input mapping, multi-input
reduce stages rejected).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AOS, SOA, BatchedField, Field, LaunchGraph, TargetConfig, aosoa,
    target_sum,
)
from repro.core import fuse

LAT = (4, 4, 8)  # 128 sites
B = 3
LAYOUTS = [AOS, SOA, aosoa(32)]
ENGINES = ["jnp", "pallas"]


def _fma(v):
    return {"out": v["y"] + v["a"] * v["x"]}


def _sq(v):
    return {"p": v["out"] * v["out"]}


def _sten(v, gather):
    return {"s": v["x"] + 0.5 * gather("x", (1, 0, 0)) - gather("x", (0, -1, 0))}


def _mkb(name, ncomp, lay, rng, lat=LAT, b=B):
    return BatchedField.from_canonical(
        name, jnp.asarray(rng.normal(size=(b, ncomp, *lat)).astype(np.float32)),
        lat, lay)


def _mk1(name, ncomp, lay, rng, lat=LAT):
    return Field.from_numpy(
        name, rng.normal(size=(ncomp, *lat)).astype(np.float32), lat, lay)


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ENGINES)
def test_batched_flat_chain_bitwise_vs_loop_one_pallas_call(lay, engine, rng):
    """Site-local chain + fused reduce: batched x, SHARED y, per-request
    scalar a — every batch element bitwise equals its single-Field launch,
    and the whole batch is one pallas_call."""
    cfg = TargetConfig(engine, vvl=64)
    g = (LaunchGraph("bflat")
         .add(_fma, {"x": "x", "y": "y", "a": "a"}, {"out": 3})
         .add(_sq, {"out": "out"}, {"p": 3})
         .add_reduce("p", "sum", name="ps"))
    bx = _mkb("x", 3, lay, rng)
    y = _mk1("y", 3, lay, rng)
    a = jnp.asarray([0.5, -1.25, 2.0], jnp.float32)
    fuse.clear_cache()
    fuse.reset_stats()
    outb = g.launch({"x": bx, "y": y}, scalars={"a": a}, config=cfg,
                    outputs=("out", "ps"))
    if engine == "pallas":
        assert fuse.stats()["pallas_calls"] == 1
    assert isinstance(outb["out"], BatchedField) and outb["out"].batch == B
    assert outb["ps"].shape == (B, 3)
    for b in range(B):
        o1 = g.launch({"x": bx.element(b), "y": y},
                      scalars={"a": float(a[b])}, config=cfg,
                      outputs=("out", "ps"))
        np.testing.assert_array_equal(
            np.asarray(outb["out"].element(b).data), np.asarray(o1["out"].data))
        np.testing.assert_array_equal(
            np.asarray(outb["ps"][b]), np.asarray(o1["ps"]))


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ENGINES)
def test_batched_stencil_periodic_bitwise_vs_loop(lay, engine, rng):
    cfg = TargetConfig(engine, vvl=64)
    g = LaunchGraph("bsten").add_stencil(_sten, {"x": "x"}, {"s": 3}, width=1)
    bx = _mkb("x", 3, lay, rng)
    outb = g.launch({"x": bx}, config=cfg)
    for b in range(B):
        o1 = g.launch({"x": bx.element(b)}, config=cfg)
        np.testing.assert_array_equal(
            np.asarray(outb["s"].element(b).data), np.asarray(o1["s"].data))


@pytest.mark.parametrize("halo", ["pre", "overlap"])
@pytest.mark.parametrize("engine", ENGINES)
def test_batched_stencil_halo_and_fused_reduce_bitwise_vs_loop(
        halo, engine, rng):
    """Pre-halo'd batched inputs through the pre/overlap schedules, with a
    fused terminal reduction riding along."""
    cfg = TargetConfig(engine, vvl=64)
    g = (LaunchGraph("bsten2")
         .add_stencil(_sten, {"x": "x"}, {"s": 3}, width=1)
         .add_reduce("s", "sum", name="ss"))
    hlat = tuple(s + 2 for s in LAT)
    bx = _mkb("x", 3, SOA, rng, lat=hlat)
    outb = g.launch({"x": bx}, config=cfg, halo=halo, outputs=("s", "ss"))
    for b in range(B):
        o1 = g.launch({"x": bx.element(b)}, config=cfg, halo=halo,
                      outputs=("s", "ss"))
        np.testing.assert_array_equal(
            np.asarray(outb["s"].element(b).data), np.asarray(o1["s"].data))
        np.testing.assert_array_equal(
            np.asarray(outb["ss"][b]), np.asarray(o1["ss"]))


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ENGINES)
def test_batched_target_sum_bitwise_vs_loop(lay, engine, rng):
    cfg = TargetConfig(engine, vvl=64)
    bx = _mkb("x", 3, lay, rng)
    ts = target_sum(bx, cfg)
    assert ts.shape == (B, 3)
    for b in range(B):
        np.testing.assert_array_equal(
            np.asarray(ts[b]), np.asarray(target_sum(bx.element(b), cfg)))


def test_batched_scalar_shape_rejected(rng):
    cfg = TargetConfig("jnp", vvl=64)
    g = LaunchGraph("bs").add(_fma, {"x": "x", "y": "y", "a": "a"}, {"out": 3})
    bx = _mkb("x", 3, SOA, rng)
    y = _mk1("y", 3, SOA, rng)
    with pytest.raises(ValueError, match="scalar"):
        g.launch({"x": bx, "y": y},
                 scalars={"a": jnp.zeros((B + 1,), jnp.float32)}, config=cfg)


def test_mismatched_batch_sizes_rejected(rng):
    cfg = TargetConfig("jnp", vvl=64)
    g = LaunchGraph("bm").add(
        lambda v: {"out": v["x"] + v["y"]}, {"x": "x", "y": "y"}, {"out": 3})
    bx = _mkb("x", 3, SOA, rng, b=2)
    by = _mkb("y", 3, SOA, rng, b=3)
    with pytest.raises(ValueError, match="batch"):
        g.launch({"x": bx, "y": by}, config=cfg)


def test_plan_key_distinguishes_batch(rng):
    """The autotuner persists per-batch-size winners: a batched launch keys
    differently from the single-Field launch of the same graph, and from a
    different batch size."""
    cfg = TargetConfig("jnp", vvl=64)
    g = LaunchGraph("bk").add(_sq, {"out": "x"}, {"p": 3})
    f1 = _mk1("x", 3, SOA, rng)
    k1 = g.plan_key({"x": f1}, config=cfg)
    k2 = g.plan_key({"x": _mkb("x", 3, SOA, rng, b=2)}, config=cfg)
    k4 = g.plan_key({"x": _mkb("x", 3, SOA, rng, b=4)}, config=cfg)
    assert len({k1, k2, k4}) == 3


def test_batched_field_roundtrip_and_slots(rng):
    bx = _mkb("x", 3, aosoa(32), rng)
    fields = bx.unstack()
    assert len(fields) == B
    re = BatchedField.stack(fields, name="x")
    np.testing.assert_array_equal(np.asarray(re.data), np.asarray(bx.data))
    # slot write: only the written slot's bits move
    f = _mk1("x", 3, SOA, rng)
    up = bx.with_element(1, f)
    np.testing.assert_array_equal(np.asarray(up.element(0).data),
                                  np.asarray(bx.element(0).data))
    np.testing.assert_array_equal(np.asarray(up.element(2).data),
                                  np.asarray(bx.element(2).data))
    np.testing.assert_array_equal(np.asarray(up.element(1).canonical()),
                                  np.asarray(f.canonical()))


# -- reduce_info regression ---------------------------------------------------

def test_reduce_info_maps_each_output_to_its_own_input(rng):
    g = (LaunchGraph("ri")
         .add(_sq, {"out": "x"}, {"p": 3})
         .add(_fma, {"x": "x", "y": "p", "a": "a"}, {"out": 3})
         .add_reduce("p", "sum", name="psum")
         .add_reduce("out", "max", name="omax"))
    info = g.reduce_info()
    assert info == {"psum": ("p", "sum"), "omax": ("out", "max")}


def test_reduce_info_rejects_multi_input_reduce_stage():
    """add_reduce can't build one, but a hand-assembled multi-input reduce
    stage must be rejected loudly instead of silently mapping the output to
    the last input (the old bug)."""
    g = LaunchGraph("rbad").add(_sq, {"out": "x"}, {"p": 3})
    g._stages.append(fuse._Stage(
        None, (("x", "p"), ("y", "p")), (("out", "bad", None, None),),
        (), kind="reduce", op="sum"))
    with pytest.raises(ValueError, match="inputs"):
        g.reduce_info()
