"""core.plan: LoweringPlan validity, candidate-generator equivalence with
the seed's linear-scan heuristics, plan-invariance of the production
graphs, and the no-direct-heuristic-callers layering guarantee.

(The hypothesis property-test forms of the candidate-validity invariants
live in tests/test_property.py with the other hypothesis suites; the
sweeps here are deterministic so they run without hypothesis installed.)"""

import re
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    AOS, SOA, Field, LoweringPlan, TargetConfig, aosoa,
)
from repro.core import plan as plan_mod


# -- candidate generators ------------------------------------------------------

def test_divisors():
    assert plan_mod.divisors(1) == (1,)
    assert plan_mod.divisors(12) == (1, 2, 3, 4, 6, 12)
    assert plan_mod.divisors(97) == (1, 97)  # prime
    with pytest.raises(ValueError):
        plan_mod.divisors(0)


@pytest.mark.parametrize("n", [2, 6, 30, 36, 97, 100, 128, 540, 4096])
def test_divisors_complete_and_sorted(n):
    ds = plan_mod.divisors(n)
    assert list(ds) == sorted(ds)
    assert all(n % d == 0 for d in ds)
    assert all((n % k != 0) or (k in ds) for k in range(1, n + 1))


def _legacy_choose_vvl(nsites, preferred, multiple_of):
    """The seed's O(nsites) linear scan (verbatim semantics)."""
    for v in range(min(preferred, nsites), 0, -1):
        if nsites % v == 0 and v % multiple_of == 0:
            return v
    if multiple_of <= nsites and nsites % multiple_of == 0:
        return multiple_of
    return None


def test_choose_vvl_matches_legacy_scan():
    """The divisor-enumeration choose_vvl is semantically identical to the
    seed's linear scan, across a broad deterministic sweep."""
    for nsites in [1, 2, 7, 12, 60, 97, 100, 128, 127, 512, 1000, 3600]:
        for preferred in [1, 3, 64, 128, 500]:
            for mult in [1, 2, 4, 8]:
                want = _legacy_choose_vvl(nsites, preferred, mult)
                if want is None:
                    with pytest.raises(ValueError):
                        plan_mod.choose_vvl(nsites, preferred,
                                            multiple_of=mult)
                else:
                    got = plan_mod.choose_vvl(nsites, preferred,
                                              multiple_of=mult)
                    assert got == want, (nsites, preferred, mult)


def test_choose_slab_matches_legacy_scan():
    for x_dim in [1, 2, 5, 8, 12, 30, 64, 97]:
        for inner in [1, 16, 42, 128, 500]:
            for vvl in [1, 64, 128, 4096]:
                budget = max(vvl, inner)
                want = 1
                for bx in range(1, x_dim + 1):
                    if x_dim % bx == 0 and bx * inner <= budget:
                        want = bx
                assert plan_mod.choose_slab(x_dim, inner, vvl) == want


def test_choose_vvl_memoized_on_prime_lattices():
    """The seed scanned O(nsites) per call; divisor enumeration + lru_cache
    makes repeated launches on prime-ish lattices O(1) after the first."""
    n = 49999  # prime
    assert plan_mod.choose_vvl(n, 4096) == 1
    info = plan_mod.choose_vvl.cache_info()
    plan_mod.choose_vvl(n, 4096)
    assert plan_mod.choose_vvl.cache_info().hits > info.hits


# -- candidate plans are always valid ------------------------------------------

@pytest.mark.parametrize("sal", [1, 2, 4, 8])
@pytest.mark.parametrize("nblk", [1, 3, 16, 63])
@pytest.mark.parametrize("preferred", [1, 32, 4096])
def test_site_local_candidates_valid(sal, nblk, preferred):
    """Every generated site-local candidate satisfies vvl | nsites and
    sal | vvl, for arbitrary (nsites, sal)."""
    nsites = sal * nblk
    layouts = [aosoa(sal), SOA]
    cfg = TargetConfig("pallas", vvl=preferred)
    cands = plan_mod.candidate_plans(cfg, nsites=nsites, layouts=layouts)
    assert cands, "at least the default plan"
    for c in cands:
        assert c.engine == "pallas" and c.bx == 0
        assert nsites % c.vvl == 0
        assert c.vvl % sal == 0
        c.validate(nsites=nsites, layouts=layouts, stencil=False)
    # the default heuristic plan comes first
    assert cands[0].vvl == plan_mod.resolve_vvl(cfg, nsites, layouts)


@pytest.mark.parametrize("x_dim", [1, 4, 7, 12, 64])
@pytest.mark.parametrize("inner", [(1, 1), (4, 8), (7, 3)])
@pytest.mark.parametrize("preferred", [1, 128, 4096])
def test_stencil_candidates_valid(x_dim, inner, preferred):
    """Every generated stencil candidate satisfies bx | x_dim."""
    lattice = (x_dim, *inner)
    nsites = x_dim * inner[0] * inner[1]
    cfg = TargetConfig("pallas", vvl=preferred)
    cands = plan_mod.candidate_plans(
        cfg, nsites=nsites, layouts=[SOA], stencil=True, lattice=lattice)
    for c in cands:
        assert c.vvl == 0 and c.bx >= 1
        assert x_dim % c.bx == 0
        c.validate(nsites=nsites, lattice=lattice, layouts=[SOA], stencil=True)
    assert cands[0].bx == plan_mod.choose_slab(
        x_dim, inner[0] * inner[1], preferred)


def test_jnp_engine_single_candidate():
    cands = plan_mod.candidate_plans(
        TargetConfig("jnp"), nsites=64, layouts=[SOA])
    # the planner resolves the view explicitly (the bare dataclass default
    # is the 'auto' sentinel)
    assert cands == (LoweringPlan("jnp", view=plan_mod.VIEW_BLOCK),)


# -- plan validation / serialization -------------------------------------------

def test_plan_validation_errors():
    with pytest.raises(ValueError, match="unknown engine"):
        LoweringPlan("cuda").validate()
    with pytest.raises(ValueError, match="must divide nsites"):
        LoweringPlan("pallas", vvl=7).validate(nsites=64)
    with pytest.raises(ValueError, match="multiple of AoSoA"):
        LoweringPlan("pallas", vvl=4).validate(nsites=64, layouts=[aosoa(8)])
    with pytest.raises(ValueError, match="no x-slab"):
        LoweringPlan("pallas", vvl=8, bx=2).validate(nsites=64)
    with pytest.raises(ValueError, match="bx=3 must divide"):
        LoweringPlan("pallas", bx=3, view="staged-nd").validate(
            lattice=(8, 4, 4), stencil=True)
    # view='block' is a legal stencil view when an AoSoA layout is in play
    # (the native-AoSoA lowering); without one it is rejected
    LoweringPlan("pallas", bx=2, view="block").validate(
        lattice=(8, 4, 4), stencil=True, layouts=[aosoa(4), SOA])
    with pytest.raises(ValueError, match="no launch layout is AoSoA"):
        LoweringPlan("pallas", bx=2, view="block").validate(
            lattice=(8, 4, 4), stencil=True, layouts=[SOA, AOS])
    # jnp plans carry no pallas constraints
    LoweringPlan("jnp").validate(nsites=7, layouts=[aosoa(8)])


def test_plan_json_roundtrip():
    p = LoweringPlan("pallas", vvl=256, interpret=True, halo="pre",
                     view="block")
    assert LoweringPlan.from_json(p.to_json()) == p
    # unknown keys from a future table version are ignored
    d = dict(p.to_json(), future_knob=3)
    assert LoweringPlan.from_json(d) == p


def test_unknown_plan_policy_raises(rng):
    lat = (4, 4, 8)
    fx = Field.from_numpy(
        "x", rng.normal(size=(3, *lat)).astype(np.float32), lat, SOA)
    from repro.core import LaunchGraph
    g = LaunchGraph("pp").add(lambda v: {"o": v["x"]}, {"x": "x"}, {"o": 3})
    with pytest.raises(ValueError, match="plan_policy"):
        g.launch({"x": fx},
                 config=TargetConfig("jnp", plan_policy="fastest"))


# -- default-policy bit-identity + explicit plans on production graphs ---------

@pytest.mark.parametrize("lay", [SOA, AOS, aosoa(32)], ids=lambda l: l.name)
def test_all_candidate_plans_match_default_lb_step(lay, rng):
    """Every *geometry* candidate plan of the fused LB step (stencil
    graph) produces the exact same field outputs as the default plan —
    plan choice is a performance knob, never a semantics knob.  The one
    exception is the dtype-policy twin family (LoweringPlan.dtypes),
    which is tolerance-equal by contract: the tuner's per-policy
    accuracy gate is its documented bound."""
    from repro.kernels.lb_propagation.ops import collide_propagate_graph
    from repro.core import tune

    lat = (4, 4, 8)
    f0 = (1.0 + 0.1 * rng.normal(size=(19, *lat))).astype(np.float32)
    frc = (0.01 * rng.normal(size=(3, *lat))).astype(np.float32)
    d = Field.from_numpy("dist", f0, lat, lay)
    frcF = Field.from_numpy("force", frc, lat, lay)
    cfg = TargetConfig("pallas", vvl=128)
    g = collide_propagate_graph(0.8)
    ins = {"dist": d, "force": frcF}
    cands = tune.plan_candidates_for(g, ins, config=cfg, outputs=("dist2",),
                                     max_candidates=3)
    base = g.launch(ins, config=cfg, outputs=("dist2",),
                    plan=cands[0])["dist2"].to_numpy()
    for cand in cands[1:]:
        got = g.launch(ins, config=cfg, outputs=("dist2",),
                       plan=cand)["dist2"].to_numpy()
        if cand.dtypes:
            err = (np.linalg.norm(got.astype(np.float64) - base)
                   / np.linalg.norm(base))
            assert err <= tune._accuracy_gate_for(cand.dtypes), \
                cand.describe()
        else:
            np.testing.assert_array_equal(got, base,
                                          err_msg=cand.describe())


def test_all_candidate_plans_match_default_wilson_normal(rng):
    """Candidate plans on the fused MILC normal operator: field output is
    bit-identical across plans; the on-chip <p, Ap> reduction may differ by
    accumulation order only (fp tolerance against the default plan)."""
    from repro.apps.milc import MilcConfig, init_problem
    from repro.apps.milc.cg import wilson_normal_graph
    from repro.core import tune

    cfg = MilcConfig(lattice=(4, 4, 4, 4), kappa=0.1)
    u, b = init_problem(cfg, seed=0)
    tgt = TargetConfig("pallas", vvl=256)
    g = wilson_normal_graph(cfg.kappa)
    ins = {"p": b, "u": u}
    cands = tune.plan_candidates_for(g, ins, config=tgt,
                                     outputs=("ap", "pap"), max_candidates=3)
    assert len(cands) > 1, "stencil sweep should offer multiple slabs"
    out0 = g.launch(ins, config=tgt, outputs=("ap", "pap"), plan=cands[0])
    base_ap = out0["ap"].to_numpy()
    base_pap = float(np.asarray(out0["pap"]).sum())
    for cand in cands[1:]:
        out = g.launch(ins, config=tgt, outputs=("ap", "pap"), plan=cand)
        got_ap = out["ap"].to_numpy()
        if cand.dtypes:  # dtype twins: tolerance-equal per the tuner gate
            err = (np.linalg.norm(got_ap.astype(np.float64) - base_ap)
                   / np.linalg.norm(base_ap))
            assert err <= tune._accuracy_gate_for(cand.dtypes), \
                cand.describe()
        else:
            np.testing.assert_array_equal(got_ap, base_ap,
                                          err_msg=cand.describe())
        np.testing.assert_allclose(float(np.asarray(out["pap"]).sum()),
                                   base_pap, rtol=1e-2 if cand.dtypes
                                   else 1e-4)


# -- layering: the planning layer owns the heuristics (satellite cleanup) ------

def test_no_direct_heuristic_callers_outside_plan():
    """After the refactor every vvl/slab decision routes through
    core.plan: no module under src/repro other than plan.py may *invoke*
    choose_vvl/choose_slab (re-exports don't call)."""
    root = Path(__file__).resolve().parents[1] / "src" / "repro"
    assert root.is_dir()
    offenders = []
    for path in sorted(root.rglob("*.py")):
        if path.name == "plan.py":
            continue
        text = path.read_text()
        for m in re.finditer(r"\b(choose_vvl|choose_slab)\s*\(", text):
            line = text[: m.start()].count("\n") + 1
            offenders.append(f"{path.relative_to(root)}:{line}")
    assert not offenders, (
        f"direct choose_vvl/choose_slab calls outside core/plan.py: "
        f"{offenders}")
