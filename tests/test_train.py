"""Training substrate: optimizers, microbatching, gradient compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import init_params
from repro.train.data import DataConfig, TokenStream, write_token_file
from repro.train.optimizer import OptConfig, _dq8, _dq8v, _q8, _q8v, init_opt
from repro.train.train_step import TrainConfig, build_train_step, init_ef_state


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_arch("granite-3-2b", smoke=True),
                              dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=16,
                                    global_batch=4))
    t, l = stream.batch(0)
    fixed = {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
    return cfg, params, fixed


# adamw stays in tier-1; the 8-bit/adafactor variants compile a second and
# third full train graph apiece, so they ride the -m slow sweep
@pytest.mark.parametrize(
    "kind",
    ["adamw",
     pytest.param("adamw8bit", marks=pytest.mark.slow),
     pytest.param("adafactor", marks=pytest.mark.slow)],
)
def test_optimizer_memorizes_fixed_batch(setup, kind):
    cfg, params, fixed = setup
    tcfg = TrainConfig(opt=OptConfig(kind=kind, lr=1e-2))
    step = jax.jit(build_train_step(cfg, tcfg))
    p, o, e = params, init_opt(params, tcfg.opt), None
    losses = []
    for _ in range(15):
        p, o, e, m = step(p, o, e, fixed)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 2.0, (kind, losses)


@pytest.mark.slow
def test_grad_compression_converges(setup):
    cfg, params, fixed = setup
    tcfg = TrainConfig(opt=OptConfig(lr=1e-2), grad_compression=True)
    step = jax.jit(build_train_step(cfg, tcfg))
    p, o, e = params, init_opt(params, tcfg.opt), init_ef_state(params)
    losses = []
    for _ in range(15):
        p, o, e, m = step(p, o, e, fixed)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 2.0


@pytest.mark.slow
def test_microbatch_equals_full_batch(setup):
    """Gradient accumulation is loss-equivalent to the full batch."""
    cfg, params, fixed = setup
    t1 = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=1)
    t2 = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=2)
    s1 = jax.jit(build_train_step(cfg, t1))
    s2 = jax.jit(build_train_step(cfg, t2))
    p1, o1, _, m1 = s1(params, init_opt(params, t1.opt), None, fixed)
    p2, o2, _, m2 = s2(params, init_opt(params, t2.opt), None, fixed)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


def test_q8_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32)) * 3.0
    codes, scale = _q8(x)
    err = np.abs(np.asarray(_dq8(codes, scale) - x))
    assert (err <= np.asarray(scale) * 0.5 + 1e-7).all()


def test_q8v_preserves_order_of_magnitude(rng):
    v = jnp.asarray(10.0 ** rng.uniform(-12, 2, size=(8, 64)))
    codes, lo, scale = _q8v(v)
    back = np.asarray(_dq8v(codes, lo, scale))
    ratio = back / np.asarray(v)
    assert (ratio > 0.5).all() and (ratio < 2.0).all()


def test_data_stream_determinism(tmp_path):
    cfg = DataConfig(vocab=1000, seq_len=8, global_batch=4, seed=3)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    for step in [0, 5, 117]:
        a, al = s1.batch(step)
        b, bl = s2.batch(step)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(al, bl)
    # labels are next-token shifted
    assert a.shape == (4, 8) and al.shape == (4, 8)

    # file-backed
    toks = np.arange(10000) % 1000
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, toks, 1000)
    fs = TokenStream(DataConfig(vocab=1000, seq_len=8, global_batch=2,
                                seed=1, path=path))
    t, l = fs.batch(0)
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])  # shift property
