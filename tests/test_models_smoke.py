"""Per-arch smoke tests (assignment requirement): reduced config of the
same family, one forward + one train step on CPU, output shapes + no NaNs.
The FULL configs are exercised only via the dry-run.

Tier-1 keeps the cheap dense representatives; the full per-arch sweep is
compile-dominated (two jitted graphs per arch, ~100 s on a 2-core CI box)
and runs under ``-m slow``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import decode_step, forward, init_cache, init_params
from repro.models.encdec import encode, seed_encdec_cache
from repro.train.optimizer import OptConfig, init_opt
from repro.train.train_step import TrainConfig, build_train_step

B, S = 2, 32

# fast tier-1 representative; every other arch rides the -m slow sweep
_FAST_ARCHS = {"olmo-1b"}
ARCH_PARAMS = [
    a if a in _FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCH_IDS
]


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            0.01 * rng.normal(size=(B, 4, cfg.d_model)), jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            0.01 * rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_forward_and_decode(arch_id, rng):
    cfg = get_arch(arch_id, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    cache = init_cache(cfg, B, 64, s_enc=S)
    if cfg.enc_dec:
        mem = encode(params, cfg, batch["frames"])
        cache = seed_encdec_cache(params, cfg, cache, mem)
    lg, cache2 = decode_step(params, cfg, cache,
                             jnp.zeros((B,), jnp.int32) + 3)
    assert lg.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_train_step(arch_id, rng):
    cfg = dataclasses.replace(get_arch(arch_id, smoke=True),
                              dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3))
    step = jax.jit(build_train_step(cfg, tcfg))
    opt = init_opt(params, tcfg.opt)
    batch = _batch(cfg, rng)
    p2, o2, _, metrics = step(params, opt, None, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2))
    )
    assert delta > 0


@pytest.mark.slow
def test_decode_matches_forward_fp32():
    """Stepwise decode reproduces teacher-forced logits (fp32, dense arch)."""
    cfg = dataclasses.replace(get_arch("granite-3-2b", smoke=True),
                              dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)
    logits_full, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, B, 16)
    outs = []
    for t in range(8):
        lg, cache = decode_step(params, cfg, cache, toks[:, t])
        outs.append(np.asarray(lg, np.float32))
    np.testing.assert_allclose(np.stack(outs, 1),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_decode_matches_forward_hybrid_fp32():
    """Same for hymba (attn + ssm + conv + meta tokens + SWA windows)."""
    cfg = dataclasses.replace(get_arch("hymba-1.5b", smoke=True),
                              dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)
    logits_full, _ = forward(params, cfg, {"tokens": toks})
    n_meta = cfg.hybrid.meta_tokens
    cache = init_cache(cfg, B, 32)
    # decode path has no meta-token prefix: replay them as ordinary context
    # is not supported; instead compare decode without meta to forward
    # without meta params
    params_nometa = {k: v for k, v in params.items() if k != "meta"}
    logits_full, _ = forward(params_nometa, cfg, {"tokens": toks})
    outs = []
    for t in range(8):
        lg, cache = decode_step(params_nometa, cfg, cache, toks[:, t])
        outs.append(np.asarray(lg, np.float32))
    np.testing.assert_allclose(np.stack(outs, 1),
                               np.asarray(logits_full, np.float32),
                               rtol=5e-4, atol=5e-4)
