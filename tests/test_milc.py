"""MILC application: CG inversion, hermiticity, kernel-layer linear algebra."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Field, SOA, TargetConfig
from repro.apps.milc import MilcConfig, init_problem, solve
from repro.apps.milc.cg import axpy, dot, g5, make_wilson_op
from repro.apps.milc.driver import residual_check
from repro.apps.milc import fields as F


@pytest.fixture(scope="module")
def problem():
    cfg = MilcConfig(lattice=(4, 4, 4, 8), kappa=0.10, tol=1e-10,
                     max_iter=2000)
    u, b = init_problem(cfg, seed=0)
    return cfg, u, b


def test_gauge_unitarity():
    u72 = F.random_su3_gauge((4, 4, 4, 4), seed=3, hot=1.0)
    assert F.unitarity_violation(u72) < 1e-5


def test_gamma5_hermiticity(problem, rng):
    cfg, u, b = problem
    apply_m, apply_mdag, _ = make_wilson_op(u, cfg.kappa, cfg.target)
    x = Field.from_numpy(
        "x", rng.normal(size=(24, *cfg.lattice)).astype(np.float32),
        cfg.lattice, cfg.layout)
    lhs = float(dot(x, apply_m(b), cfg.target))
    rhs = float(dot(apply_mdag(x), b, cfg.target))
    assert abs(lhs - rhs) < 1e-2 * abs(lhs)


def test_cg_solves_wilson(problem):
    cfg, u, b = problem
    res = solve(cfg, u, b)
    assert int(res.iterations) < cfg.max_iter
    assert float(res.residual) < cfg.tol * 10
    rc = residual_check(cfg, u, b, res.x)
    assert rc < 1e-3  # fp32 independent verification


def test_scalar_mult_add_kernel(problem, rng):
    cfg, u, b = problem
    x = rng.normal(size=(24, *cfg.lattice)).astype(np.float32)
    y = rng.normal(size=(24, *cfg.lattice)).astype(np.float32)
    fx = Field.from_numpy("x", x, cfg.lattice, SOA)
    fy = Field.from_numpy("y", y, cfg.lattice, SOA)
    for tgt in [TargetConfig("jnp"), TargetConfig("pallas", vvl=128)]:
        out = axpy(0.75, fx, fy, tgt)
        np.testing.assert_allclose(out.to_numpy(), 0.75 * x + y,
                                   rtol=1e-5, atol=1e-6)


def test_g5_involution(problem, rng):
    cfg, u, b = problem
    x = Field.from_numpy(
        "x", rng.normal(size=(24, *cfg.lattice)).astype(np.float32),
        cfg.lattice, cfg.layout)
    back = g5(g5(x, cfg.target), cfg.target)
    np.testing.assert_allclose(back.to_numpy(), x.to_numpy(), rtol=1e-7)


def test_engine_portability_dslash_in_cg(problem):
    """C1 for MILC: one Wilson matvec, jnp vs pallas engines."""
    cfg, u, b = problem
    from repro.kernels.wilson_dslash import wilson_matvec
    o1 = wilson_matvec(b, u, kappa=cfg.kappa,
                       config=TargetConfig("jnp")).to_numpy()
    o2 = wilson_matvec(b, u, kappa=cfg.kappa,
                       config=TargetConfig("pallas", vvl=128)).to_numpy()
    np.testing.assert_allclose(o2, o1, rtol=2e-4, atol=2e-4)
