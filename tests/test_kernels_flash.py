"""Flash-attention pallas kernel: shape/dtype/mask sweeps vs the oracle."""

import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


@pytest.mark.parametrize("cfg", [
    (2, 2, 64, 16, True, 0),
    (1, 4, 128, 32, True, 16),
    (3, 1, 64, 8, False, 0),
    (2, 3, 96, 16, True, 32),
], ids=str)
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_flash_vs_oracle(cfg, dtype, rng):
    import jax.numpy as jnp
    BKV, rep, S, dh, causal, window = cfg
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    BG = BKV * rep
    q = jnp.asarray(rng.normal(size=(BG, S, dh)), dt)
    k = jnp.asarray(rng.normal(size=(BKV, S, dh)), dt)
    v = jnp.asarray(rng.normal(size=(BKV, S, dh)), dt)
    tol = 2e-5 if dt == jnp.float32 else 3e-2
    o_ref = np.asarray(flash_attention(q, k, v, rep=rep, causal=causal,
                                       window=window, engine="jnp"),
                       np.float32)
    for engine, kw in [("pallas", dict(q_block=32)),
                       ("pallas_kvchunk", dict(q_block=32, kv_block=32))]:
        o = np.asarray(flash_attention(q, k, v, rep=rep, causal=causal,
                                       window=window, engine=engine, **kw),
                       np.float32)
        np.testing.assert_allclose(o, o_ref, rtol=tol, atol=tol)


def test_flash_matches_model_attention(rng):
    import jax.numpy as jnp
    from repro.models.attention import _dense_gqa, _mask_ok

    B, KV, rep, S, dh = 1, 2, 2, 64, 16
    q5 = rng.normal(size=(B, S, KV, rep, dh)).astype(np.float32)
    k4 = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    v4 = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    ok = _mask_ok(S, S, causal=True, window=0)
    o_model = np.asarray(_dense_gqa(jnp.asarray(q5), jnp.asarray(k4),
                                    jnp.asarray(v4), ok))
    qg = np.ascontiguousarray(q5.transpose(0, 2, 3, 1, 4)).reshape(B*KV*rep, S, dh)
    kg = np.ascontiguousarray(k4.transpose(0, 2, 1, 3)).reshape(B*KV, S, dh)
    vg = np.ascontiguousarray(v4.transpose(0, 2, 1, 3)).reshape(B*KV, S, dh)
    o_fl = np.asarray(flash_attention(jnp.asarray(qg), jnp.asarray(kg),
                                      jnp.asarray(vg), rep=rep,
                                      engine="pallas", q_block=32))
    o_fl = o_fl.reshape(B, KV, rep, S, dh).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(o_fl, o_model, rtol=2e-5, atol=2e-5)


def test_attn_fast_variant_exact(rng):
    """The attn_fast (transpose-free) formulation is numerically identical."""
    import jax.numpy as jnp
    from repro import tuning
    from repro.models.attention import _dense_gqa, _mask_ok

    B, KV, rep, S, dh = 2, 2, 2, 32, 16
    q5 = jnp.asarray(rng.normal(size=(B, S, KV, rep, dh)), jnp.float32)
    k4 = jnp.asarray(rng.normal(size=(B, S, KV, dh)), jnp.float32)
    v4 = jnp.asarray(rng.normal(size=(B, S, KV, dh)), jnp.float32)
    ok = _mask_ok(S, S, causal=True, window=8)
    base = np.asarray(_dense_gqa(q5, k4, v4, ok))
    try:
        tuning.set_tuning(attn_fast=True)
        fast = np.asarray(_dense_gqa(q5, k4, v4, ok))
    finally:
        tuning.reset()
    np.testing.assert_allclose(fast, base, rtol=2e-6, atol=2e-6)
