"""Batched multi-simulation serving: batched CG bit-identity against
independent solves, convergence-mask invariance, the shape-bucketed
request scheduler draining mixed-shape streams, and the generate()
sampling-path regression (temperature > 0 with the default rng)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.milc import driver, fields
from repro.apps.milc.cg import make_wilson_op
from repro.core import Field, SOA, TargetConfig
from repro.launch.serve import SolveRequest, SolveServer

LAT = (4, 4, 4, 8)


def _cfg(engine, lattice=LAT, max_iter=40):
    return driver.MilcConfig(lattice=lattice, kappa=0.10, tol=1e-8,
                             max_iter=max_iter, layout=SOA,
                             target=TargetConfig(engine, vvl=128))


def _sources(cfg, n, seed0=10):
    return [Field.from_numpy(
        "b", fields.random_spinor(cfg.lattice, seed=seed0 + i),
        cfg.lattice, cfg.layout) for i in range(n)]


def _filtered(cfg, u, b, n=6):
    """Spectrally filter a source (repeated normal-operator applications)
    so its CG converges at a different iteration count — exercises the
    frozen-slot path while the rest of the batch keeps iterating."""
    _, _, apply_normal = make_wilson_op(u, cfg.kappa, cfg.target)
    for _ in range(n):
        b = apply_normal(b)
    return b.with_data(b.data / jnp.linalg.norm(b.data))


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_solve_batched_bitwise_vs_independent_solves(engine):
    """A batch of solves with *divergent* convergence points (one slot
    freezes early, one slot is empty) — every live request's x, iteration
    count and residual are bitwise the dedicated single solve's."""
    cfg = _cfg(engine)
    u, _ = driver.init_problem(cfg, seed=0)
    bs = _sources(cfg, 3)
    bs[1] = _filtered(cfg, u, bs[1])          # converges earlier
    bs[2] = bs[2].with_data(bs[2].data * 0.0)  # empty slot
    res = driver.solve_batched(cfg, u, bs)
    its = [int(i) for i in res.iterations]
    assert its[1] < its[0], its  # the freeze path actually ran
    assert its[2] == 0 and not np.any(np.asarray(res.x.element(2).data))
    for i in (0, 1):
        r1 = driver.solve(cfg, u, bs[i])
        np.testing.assert_array_equal(np.asarray(res.x.element(i).data),
                                      np.asarray(r1.x.data))
        assert its[i] == int(r1.iterations)
        np.testing.assert_array_equal(np.asarray(res.residual[i]),
                                      np.asarray(r1.residual))


def test_convergence_mask_invariance():
    """A request's trajectory must not depend on its batch neighbours:
    solve the same source next to a fast-converging neighbour and next to
    an empty slot — identical bits both times."""
    cfg = _cfg("jnp")
    u, _ = driver.init_problem(cfg, seed=0)
    b0, b1 = _sources(cfg, 2)
    fast = _filtered(cfg, u, b1)
    empty = b1.with_data(b1.data * 0.0)
    r_fast = driver.solve_batched(cfg, u, [b0, fast])
    r_empty = driver.solve_batched(cfg, u, [b0, empty])
    np.testing.assert_array_equal(np.asarray(r_fast.x.element(0).data),
                                  np.asarray(r_empty.x.element(0).data))
    assert int(r_fast.iterations[0]) == int(r_empty.iterations[0])
    np.testing.assert_array_equal(np.asarray(r_fast.residual[0]),
                                  np.asarray(r_empty.residual[0]))


def test_scheduler_drains_mixed_shapes_bitwise():
    """Mixed-shape request stream through the bucketed scheduler, more
    requests than slots (so slots drain and refill mid-flight): every
    completed solve is bitwise the dedicated driver.solve result."""
    shapes = [LAT, (4, 4, 8, 8)]
    cfgs, us, reqs, oracle = {}, {}, [], {}
    for i, lat in enumerate(shapes):
        cfg = _cfg("jnp", lattice=lat)
        u, _ = driver.init_problem(cfg, seed=i)
        cfgs[lat], us[lat] = cfg, u
        for j in range(3):
            rid = 10 * i + j
            b = _sources(cfg, 1, seed0=100 + rid)[0]
            reqs.append(SolveRequest(rid=rid, b=b))
            oracle[rid] = driver.solve(cfg, u, b)
    server = SolveServer(cfgs[LAT].target, slots=2, tol=cfgs[LAT].tol,
                         max_iter=cfgs[LAT].max_iter)
    for lat in shapes:
        server.register(us[lat], cfgs[lat].kappa)
    # interleave shapes in the submission order
    for req in sorted(reqs, key=lambda r: r.rid % 10):
        server.submit(req)
    results = server.run()
    assert sorted(results) == sorted(o.rid for o in reqs)
    for rid, out in results.items():
        want = oracle[rid]
        np.testing.assert_array_equal(np.asarray(out.x.data),
                                      np.asarray(want.x.data))
        assert out.iterations == int(want.iterations)
        assert out.residual == float(want.residual)


def _mixed_shape_server():
    """The mixed-shape 6-request workload of the drain test, rebuilt from
    scratch (fresh buckets, fresh queues) so back-to-back runs are
    independent."""
    shapes = [LAT, (4, 4, 8, 8)]
    cfgs, us, reqs = {}, {}, []
    for i, lat in enumerate(shapes):
        cfg = _cfg("jnp", lattice=lat)
        u, _ = driver.init_problem(cfg, seed=i)
        cfgs[lat], us[lat] = cfg, u
        for j in range(3):
            rid = 10 * i + j
            b = _sources(cfg, 1, seed0=100 + rid)[0]
            reqs.append(SolveRequest(rid=rid, b=b))
    server = SolveServer(cfgs[LAT].target, slots=2, tol=cfgs[LAT].tol,
                         max_iter=cfgs[LAT].max_iter)
    for lat in shapes:
        server.register(us[lat], cfgs[lat].kappa)
    for req in sorted(reqs, key=lambda r: r.rid % 10):
        server.submit(req)
    return server


def test_drain_telemetry_matches_oracle_trace_and_disabled_is_bitwise():
    """One drain with telemetry off, one with it on: the admission/harvest
    counters, per-bucket tick counters, queue-depth/occupancy gauges and
    per-request admission->harvest spans must replay the scheduler's
    oracle request trace exactly — and the enabled run's solves must be
    bitwise identical to the disabled run's (observability never touches
    the computation)."""
    from repro.core import telemetry

    telemetry.disable()
    telemetry.reset()
    res_off = _mixed_shape_server().run()
    assert telemetry.events() == []  # disabled: no spans recorded
    telemetry.reset_counters("serve.")

    telemetry.enable()
    try:
        server = _mixed_shape_server()
        res_on = server.run()
    finally:
        telemetry.disable()

    # disabled vs enabled: bitwise identical outcomes
    assert sorted(res_on) == sorted(res_off)
    for rid, off in res_off.items():
        on = res_on[rid]
        np.testing.assert_array_equal(np.asarray(off.x.data),
                                      np.asarray(on.x.data))
        assert off.iterations == on.iterations
        assert off.residual == on.residual

    n = len(res_on)
    assert telemetry.counter_value("serve.admitted") == n
    assert telemetry.counter_value("serve.harvested") == n
    total_ticks = sum(b.iterations_run for b in server.buckets.values())
    assert telemetry.counter_value("serve.ticks") == total_ticks
    for b in server.buckets.values():
        assert (telemetry.counter_value(f"serve.ticks.{b.label}")
                == b.iterations_run)
        # 3 requests through 2 slots: depth starts at 3, drains to 0;
        # occupancy peaks at the slot count
        depth = [v for _, v in
                 telemetry.gauges(f"serve.queue_depth.{b.label}")
                 [f"serve.queue_depth.{b.label}"]]
        assert depth[0] == 3 and max(depth) == 3 and depth[-1] == 0
        occ = [v for _, v in
               telemetry.gauges(f"serve.slot_occupancy.{b.label}")
               [f"serve.slot_occupancy.{b.label}"]]
        assert max(occ) == 2

    # per-request latency spans bracket exactly the active iterations
    spans = telemetry.events("serve/request")
    assert len(spans) == n
    for e in spans:
        a = e["attrs"]
        assert a["harvest_tick"] - a["admit_tick"] == a["iterations"]
        assert a["iterations"] == res_on[a["rid"]].iterations
    (drain,) = telemetry.events("serve/drain")
    assert drain["attrs"]["requests"] == n
    assert len(telemetry.events("serve/tick")) == total_ticks


def test_scheduler_rejects_unregistered_shape():
    cfg = _cfg("jnp")
    server = SolveServer(cfg.target)
    b = _sources(cfg, 1)[0]
    with pytest.raises(KeyError, match="no operator registered"):
        server.submit(SolveRequest(rid=0, b=b))


# -- generate() sampling-path regression --------------------------------------

def _lm():
    from repro.configs import get_arch
    from repro.models import init_params

    cfg = dataclasses.replace(get_arch("olmo-1b", smoke=True),
                              dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    return cfg, params, prompt


def test_generate_greedy_path():
    from repro.train.serve_step import generate

    cfg, params, prompt = _lm()
    out = generate(params, cfg, prompt, steps=4, s_max=32)
    assert out.shape == (1, 12) and out.dtype == jnp.int32


def test_generate_sampled_path_default_rng():
    """temperature > 0 with rng left at None used to crash in
    jax.random.split(None); it must sample with a fixed default key."""
    from repro.train.serve_step import generate

    cfg, params, prompt = _lm()
    out = generate(params, cfg, prompt, steps=4, s_max=32, temperature=0.7)
    out2 = generate(params, cfg, prompt, steps=4, s_max=32, temperature=0.7)
    assert out.shape == (1, 12)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    # an explicit key still drives the sample stream
    out3 = generate(params, cfg, prompt, steps=4, s_max=32, temperature=0.7,
                    rng=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out3))
