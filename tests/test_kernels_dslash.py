"""Wilson dslash: independent dense-gamma complex oracle, engines, gamma5."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Field, SOA, TargetConfig, aosoa
from repro.kernels.wilson_dslash import dslash
from repro.kernels.wilson_dslash import ref as R
from repro.maths.su3 import gamma_dense

LAT = (4, 4, 4, 4)


def _dense_dslash(psi_c, u_c):
    out = np.zeros_like(psi_c)
    for mu in range(4):
        g = gamma_dense(mu)
        Pm, Pp = np.eye(4) - g, np.eye(4) + g
        fwd = np.roll(psi_c, -1, axis=2 + mu)
        bwd = np.roll(psi_c, 1, axis=2 + mu)
        ubwd = np.roll(u_c[mu], 1, axis=2 + mu)
        t1 = np.einsum("ab...,sb...->sa...", u_c[mu], fwd)
        t1 = np.einsum("st,ta...->sa...", Pm, t1)
        t2 = np.einsum("ba...,sb...->sa...", ubwd.conj(), bwd)
        t2 = np.einsum("st,ta...->sa...", Pp, t2)
        out += t1 + t2
    return out


def _random_problem(rng):
    psi_c = rng.normal(size=(4, 3, *LAT)) + 1j * rng.normal(size=(4, 3, *LAT))
    u_c = rng.normal(size=(4, 3, 3, *LAT)) + 1j * rng.normal(size=(4, 3, 3, *LAT))
    psi24 = np.stack([psi_c.real, psi_c.imag], 2).reshape(24, *LAT).astype(np.float32)
    u72 = np.stack([u_c.real, u_c.imag], 3).reshape(72, *LAT).astype(np.float32)
    return psi_c, u_c, psi24, u72


def test_ref_vs_dense_gamma_oracle(rng):
    psi_c, u_c, psi24, u72 = _random_problem(rng)
    want = _dense_dslash(psi_c, u_c)
    got = np.asarray(R.dslash_ref(jnp.asarray(psi24), jnp.asarray(u72)))
    got = got.reshape(4, 3, 2, *LAT)
    np.testing.assert_allclose(got[:, :, 0] + 1j * got[:, :, 1], want,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("lay", [SOA, aosoa(64)], ids=lambda l: l.name)
@pytest.mark.parametrize("vvl", [64, 128])
def test_pallas_engine_vs_jnp(lay, vvl, rng):
    _, _, psi24, u72 = _random_problem(rng)
    psiF = Field.from_numpy("psi", psi24, LAT, lay)
    uF = Field.from_numpy("u", u72, LAT, lay)
    o1 = dslash(psiF, uF, config=TargetConfig("jnp")).to_numpy()
    o2 = dslash(psiF, uF, config=TargetConfig("pallas", vvl=vvl)).to_numpy()
    np.testing.assert_allclose(o2, o1, rtol=2e-4, atol=2e-4)


def test_free_field_constant_mode(rng):
    """Unit gauge, constant spinor: D psi = 8 psi (the p=0 plane wave)."""
    import repro.apps.milc.fields as F

    u72 = F.random_su3_gauge(LAT, seed=0, hot=0.0)  # cold start = unit links
    assert F.unitarity_violation(u72) < 1e-6
    chi = rng.normal(size=(24,)).astype(np.float32)
    psi24 = np.broadcast_to(chi[:, None, None, None, None], (24, *LAT)).copy()
    got = np.asarray(R.dslash_ref(jnp.asarray(psi24), jnp.asarray(u72)))
    np.testing.assert_allclose(got, 8.0 * psi24, rtol=1e-5, atol=1e-5)


def test_gamma5_identity():
    g5 = gamma_dense(0) @ gamma_dense(1) @ gamma_dense(2) @ gamma_dense(3)
    np.testing.assert_allclose(g5, np.diag([1, 1, -1, -1]), atol=1e-12)
