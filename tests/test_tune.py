"""core.tune: the persisted plan autotuner.  Sweep -> JSON table -> warm
hit with zero sweep launches (fresh-process semantics), plan_policy="tuned"
integration, tuned == default numerics, and table robustness."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    Field, LaunchGraph, LoweringPlan, SOA, TargetConfig, aosoa, fuse, tune,
)

LAT = (4, 4, 8)  # 128 sites


@pytest.fixture()
def tune_env(tmp_path, monkeypatch):
    """Isolated tune table per test (env-overridable path is the API)."""
    path = tmp_path / "tune_table.json"
    monkeypatch.setenv(tune.ENV_VAR, str(path))
    tune.clear_table_cache()
    tune.reset_stats()
    yield path
    tune.clear_table_cache()


def _scale_body(v):
    return {"t": 2.0 * v["x"]}


def _graph():
    return LaunchGraph("tune_probe").add(
        _scale_body, {"x": "x"}, {"t": 3})


def _field(rng, lay=SOA):
    arr = rng.normal(size=(3, *LAT)).astype(np.float32)
    return Field.from_numpy("x", arr, LAT, lay)


def test_autotune_sweeps_persists_and_rehits(tune_env, rng):
    """Acceptance probe: write the table in one 'process', drop the
    in-memory cache (what a fresh process sees), re-tune — table hit, ZERO
    sweep launches the second time."""
    fx = _field(rng)
    cfg = TargetConfig("pallas", vvl=64)
    plan, info = tune.autotune_graph(
        _graph(), {"x": fx}, config=cfg, iters=1, warmup=0, max_candidates=4)
    assert not info["cached"]
    assert tune.stats()["sweep_launches"] > 0
    assert tune_env.exists()
    raw = json.loads(tune_env.read_text())
    assert raw["entries"][info["key"]]["plan"] == plan.to_json()
    # every swept candidate was a real, distinct launch
    assert len(info["timings_us"]) == tune.stats()["sweep_launches"]

    # "fresh process": nothing in memory, everything from disk
    tune.clear_table_cache()
    tune.reset_stats()
    plan2, info2 = tune.autotune_graph(
        _graph(), {"x": fx}, config=cfg, iters=1, warmup=0, max_candidates=4)
    assert info2["cached"] and plan2 == plan
    assert tune.stats()["sweep_launches"] == 0, "warm table must not re-sweep"


def test_plan_policy_tuned_round_trip(tune_env, rng):
    """plan_policy='tuned' launches look the persisted winner up by plan
    key and produce the same numerics as the default policy."""
    fx = _field(rng)
    sweep_cfg = TargetConfig("pallas", vvl=64)
    plan, _ = tune.autotune_graph(
        _graph(), {"x": fx}, config=sweep_cfg, iters=1, warmup=0,
        max_candidates=4)

    want = _graph().launch({"x": fx}, config=sweep_cfg)["t"].to_numpy()
    tune.clear_table_cache()  # force the tuned launch to re-read disk
    tune.reset_stats()
    tuned_cfg = TargetConfig("pallas", vvl=64, plan_policy="tuned")
    got = _graph().launch({"x": fx}, config=tuned_cfg)["t"].to_numpy()
    np.testing.assert_array_equal(got, want)
    s = tune.stats()
    assert s["lookups"] == 1 and s["hits"] == 1, s
    assert s["sweep_launches"] == 0


def test_plan_policy_tuned_miss_falls_back_to_default(tune_env, rng):
    """A cold table must never break a launch: tuned policy on a miss uses
    the default heuristics (and records nothing)."""
    fx = _field(rng, aosoa(32))
    cfg = TargetConfig("pallas", vvl=64, plan_policy="tuned")
    fuse.clear_cache()
    fuse.reset_stats()
    out = _graph().launch({"x": fx}, config=cfg)["t"].to_numpy()
    np.testing.assert_allclose(out, 2.0 * fx.to_numpy(), rtol=1e-6)
    s = tune.stats()
    assert s["lookups"] == 1 and s["hits"] == 0, s
    assert not tune_env.exists()
    assert fuse.stats()["pallas_calls"] == 1


def test_explicit_plan_policy_on_config(rng):
    """plan_policy can be a concrete LoweringPlan: every launch under that
    config uses it (here: forced vvl=32, interpret)."""
    fx = _field(rng)
    explicit = LoweringPlan("pallas", vvl=32, interpret=True)
    cfg = TargetConfig("pallas", vvl=64, plan_policy=explicit)
    got = _graph().launch({"x": fx}, config=cfg)["t"].to_numpy()
    np.testing.assert_allclose(got, 2.0 * fx.to_numpy(), rtol=1e-6)
    # a non-conforming explicit plan raises the plan validation error
    bad = TargetConfig("pallas", plan_policy=LoweringPlan("pallas", vvl=7))
    with pytest.raises(ValueError, match="must divide nsites"):
        _graph().launch({"x": fx}, config=bad)


def test_scalars_and_stencil_graph_tuning(tune_env, rng):
    """Tuning covers stencil graphs (bx sweep) and graphs with runtime
    scalars; the tuned launch matches the default-plan launch under its
    plan's contract — bitwise for geometry-only plans, the accuracy-gated
    tolerance when the winner carries a dtype policy (the one candidate
    family whose field outputs are tolerance- rather than bitwise-equal)."""
    from repro.kernels.lb_propagation.ops import collide_propagate_graph

    f0 = (1.0 + 0.1 * rng.normal(size=(19, *LAT))).astype(np.float32)
    frc = (0.01 * rng.normal(size=(3, *LAT))).astype(np.float32)
    d = Field.from_numpy("dist", f0, LAT, SOA)
    g = Field.from_numpy("force", frc, LAT, SOA)
    cfg = TargetConfig("pallas", vvl=128)
    graph = collide_propagate_graph(0.8)
    ins = {"dist": d, "force": g}
    plan, info = tune.autotune_graph(
        graph, ins, config=cfg, outputs=("dist2",), iters=1, warmup=0,
        max_candidates=3)
    assert plan.bx >= 1 and LAT[0] % plan.bx == 0
    want = graph.launch(ins, config=cfg, outputs=("dist2",))["dist2"]
    got = graph.launch(ins, config=cfg, outputs=("dist2",),
                       plan=plan)["dist2"]
    if plan.dtypes:
        err = (np.linalg.norm(got.to_numpy().astype(np.float64)
                              - want.to_numpy())
               / np.linalg.norm(want.to_numpy()))
        assert err <= tune._accuracy_gate_for(plan.dtypes)
    else:
        np.testing.assert_array_equal(got.to_numpy(), want.to_numpy())


def test_pre_halo_tuned_keys_agree(tune_env, rng):
    """halo='pre': autotune keys on the same interior lattice the launch
    keys on, so a tuned-policy pre-exchanged launch hits the table."""
    from repro.core.stencil import halo_pad

    def lap_body(v, gather):
        return {"z": gather("y", (1, 0, 0)) + gather("y", (-1, 0, 0))}

    g = LaunchGraph("pre_tune").add_stencil(
        lap_body, {"y": "x"}, {"z": 3}, width=1)
    x = rng.normal(size=(3, *LAT)).astype(np.float32)
    import jax.numpy as jnp
    xh = halo_pad(jnp.asarray(x), 1, (1, 2, 3))
    fxh = Field.from_canonical("x", xh, tuple(xh.shape[1:]), SOA)
    cfg = TargetConfig("pallas", vvl=64)
    plan, info = tune.autotune_graph(
        g, {"x": fxh}, config=cfg, halo="pre", iters=1, warmup=0,
        max_candidates=2)
    assert LAT[0] % plan.bx == 0  # planned for the interior, not the halo'd X
    tune.reset_stats()
    out = g.launch({"x": fxh},
                   config=TargetConfig("pallas", vvl=64, plan_policy="tuned"),
                   halo="pre")["z"]
    assert out.lattice == LAT
    s = tune.stats()
    assert s["hits"] == 1, f"pre-halo tuned lookup missed the table: {s}"
    want = np.roll(x, 1, axis=1) + np.roll(x, -1, axis=1)
    np.testing.assert_allclose(out.to_numpy(), want, rtol=1e-6)


def test_corrupt_table_yields_empty(tune_env):
    tune_env.write_text("{ not json")
    assert tune.load_table() == {}
    assert tune.lookup("nope") is None


def test_table_is_schema_version_stamped(tune_env, rng):
    """Every persisted table carries the current schema_version (so future
    schema changes can invalidate it) and round-trips through a fresh
    load."""
    fx = _field(rng)
    plan, info = tune.autotune_graph(
        _graph(), {"x": fx}, config=TargetConfig("pallas", vvl=64),
        iters=1, warmup=0, max_candidates=2)
    raw = json.loads(tune_env.read_text())
    assert raw["schema_version"] == tune.SCHEMA_VERSION
    tune.clear_table_cache()
    assert tune.lookup(info["key"]) == plan


def test_unknown_schema_version_degrades_to_misses(tune_env, rng):
    """A table with a missing or unknown schema_version (e.g. a PR-3-era
    file, which wrote a 'version' key before plans gained the overlap halo
    strategy) must behave like an empty table: lookups miss, tuned-policy
    launches fall back to the default heuristics, and a re-tune sweeps
    and re-stamps — stale entries are never mis-decoded."""
    fx = _field(rng)
    cfg = TargetConfig("pallas", vvl=64)
    g = _graph()
    key = g.plan_key({"x": fx}, config=cfg)
    good_entry = {"plan": LoweringPlan("pallas", vvl=64).to_json()}
    for stale in (
        {"version": 1, "entries": {key: good_entry}},          # PR-3 table
        {"schema_version": 99, "entries": {key: good_entry}},  # future table
        {"entries": {key: good_entry}},                        # unstamped
    ):
        tune_env.write_text(json.dumps(stale))
        tune.clear_table_cache()
        assert tune.load_table() == {}
        assert tune.lookup(key) is None
    # tuned policy still launches (default-heuristics fallback)...
    out = _graph().launch(
        {"x": fx},
        config=TargetConfig("pallas", vvl=64, plan_policy="tuned"))["t"]
    np.testing.assert_allclose(out.to_numpy(), 2.0 * fx.to_numpy(), rtol=1e-6)
    # ...and a re-tune re-sweeps (the stale table is not a warm hit) and
    # re-stamps the file with the current version
    tune.reset_stats()
    plan, info = tune.autotune_graph(
        g, {"x": fx}, config=cfg, iters=1, warmup=0, max_candidates=2)
    assert not info["cached"] and tune.stats()["sweep_launches"] > 0
    assert json.loads(tune_env.read_text())["schema_version"] == tune.SCHEMA_VERSION


def test_schema_version_2_table_is_a_clean_miss(tune_env, rng):
    """A version-2 table (pre-rsplit: its plans predate the split-reduction
    axis and the tolerance-vs-bitwise reduction contract) loads as a clean
    miss: lookups return None, and a re-tune sweeps and re-stamps the file
    at the current version with plans that name ``rsplit``."""
    fx = _field(rng)
    cfg = TargetConfig("pallas", vvl=64)
    g = _graph()
    key = g.plan_key({"x": fx}, config=cfg)
    v2_plan = {k: v for k, v in LoweringPlan("pallas", vvl=64).to_json().items()
               if k != "rsplit"}
    tune_env.write_text(json.dumps(
        {"schema_version": 2, "entries": {key: {"plan": v2_plan}}}))
    tune.clear_table_cache()
    assert tune.load_table() == {}
    assert tune.lookup(key) is None
    tune.reset_stats()
    plan, info = tune.autotune_graph(g, {"x": fx}, config=cfg, iters=1,
                                     warmup=0, max_candidates=2)
    assert not info["cached"] and tune.stats()["sweep_launches"] > 0
    raw = json.loads(tune_env.read_text())
    assert raw["schema_version"] == tune.SCHEMA_VERSION
    assert "rsplit" in raw["entries"][info["key"]]["plan"]


def test_schema_version_3_table_is_a_clean_miss(tune_env, rng):
    """A version-3 table (pre-dtype-policy: its plans predate the
    storage/compute/accumulate ``dtypes`` axis and the tuner's accuracy
    gate) loads as a clean miss: lookups return None, and a re-tune sweeps
    and re-stamps the file at the current version with plans that name
    ``dtypes``."""
    fx = _field(rng)
    cfg = TargetConfig("pallas", vvl=64)
    g = _graph()
    key = g.plan_key({"x": fx}, config=cfg)
    v3_plan = {k: v for k, v in LoweringPlan("pallas", vvl=64).to_json().items()
               if k != "dtypes"}
    tune_env.write_text(json.dumps(
        {"schema_version": 3, "entries": {key: {"plan": v3_plan}}}))
    tune.clear_table_cache()
    assert tune.load_table() == {}
    assert tune.lookup(key) is None
    tune.reset_stats()
    plan, info = tune.autotune_graph(g, {"x": fx}, config=cfg, iters=1,
                                     warmup=0, max_candidates=2)
    assert not info["cached"] and tune.stats()["sweep_launches"] > 0
    raw = json.loads(tune_env.read_text())
    assert raw["schema_version"] == tune.SCHEMA_VERSION
    assert "dtypes" in raw["entries"][info["key"]]["plan"]


def test_malformed_entry_is_a_miss_not_a_crash(tune_env, rng):
    """Valid JSON but a structurally broken entry (missing plan, bogus
    engine) must behave like a miss: tuned-policy launches fall back to
    the default heuristics instead of raising."""
    fx = _field(rng)
    cfg = TargetConfig("pallas", vvl=64, plan_policy="tuned")
    g = _graph()
    key = g.plan_key({"x": fx}, config=cfg)
    tune_env.write_text(json.dumps({"version": 1, "entries": {
        key: {"timings_us": {}},                    # no "plan" at all
        "other": {"plan": {"engine": "cuda"}},      # nonsense engine
    }}))
    tune.clear_table_cache()
    assert tune.lookup(key) is None
    assert tune.lookup("other") is None
    out = g.launch({"x": fx}, config=cfg)["t"].to_numpy()
    np.testing.assert_allclose(out, 2.0 * fx.to_numpy(), rtol=1e-6)


def test_sweep_skips_failing_candidates(tune_env, monkeypatch, rng):
    """A candidate whose lowering raises (e.g. over the VMEM budget on a
    real TPU) is recorded as failed and skipped — the sweep completes and
    persists a working winner."""
    fx = _field(rng)
    cfg = TargetConfig("pallas", vvl=64)
    real_launch = LaunchGraph.launch

    def flaky_launch(self, ins, **kw):
        plan = kw.get("plan")
        if plan is not None and plan.vvl == 128:
            raise RuntimeError("RESOURCE_EXHAUSTED: VMEM")
        return real_launch(self, ins, **kw)

    monkeypatch.setattr(LaunchGraph, "launch", flaky_launch)
    plan, info = tune.autotune_graph(
        _graph(), {"x": fx}, config=cfg, iters=1, warmup=0,
        max_candidates=4)
    assert plan.vvl != 128
    assert any("VMEM" in e for e in info["failed"].values()), info
    # the failure is recorded in the persisted entry, not silently dropped
    entry = json.loads(tune_env.read_text())["entries"][info["key"]]
    assert entry["meta"]["failed"]


def test_min_gain_hysteresis_keeps_default(tune_env, monkeypatch, rng):
    """A candidate that is only noisily faster must not dethrone the
    deterministic default plan; a decisively faster one must."""
    fx = _field(rng)
    cfg = TargetConfig("pallas", vvl=64)

    def fake_sweep(graph, ins, launch_kw, cands, iters, warmup):
        # default (first) at 100us; everyone else marginally faster
        return {c: (100e-6 if i == 0 else 97e-6)
                for i, c in enumerate(cands)}, {}

    monkeypatch.setattr(tune, "_sweep", fake_sweep)
    plan, info = tune.autotune_graph(
        _graph(), {"x": fx}, config=cfg, min_gain=0.05)
    assert plan == info["default"], "3% gain must not beat 5% hysteresis"

    def fake_sweep2(graph, ins, launch_kw, cands, iters, warmup):
        return {c: (100e-6 if i == 0 else 50e-6)
                for i, c in enumerate(cands)}, {}

    monkeypatch.setattr(tune, "_sweep", fake_sweep2)
    plan2, info2 = tune.autotune_graph(
        _graph(), {"x": fx}, config=cfg, min_gain=0.05, force=True)
    assert plan2 != info2["default"], "a 2x gain must dethrone the default"


def test_jnp_engine_tunes_to_single_candidate(tune_env, rng):
    """On the jnp engine there is no vvl/slab knob: the sweep degenerates
    to the default plan (and still persists, so the table is a complete
    record of planned launches)."""
    fx = _field(rng)
    plan, info = tune.autotune_graph(
        _graph(), {"x": fx}, config=TargetConfig("jnp"), iters=1, warmup=0)
    assert plan == LoweringPlan("jnp", view="block")  # site-local default
    assert len(info["timings_us"]) == 1


@pytest.mark.slow
def test_table_roundtrip_across_real_processes(tmp_path):
    """The acceptance probe, end to end: sweep + persist in one python
    process, load + hit (zero sweep launches) in a genuinely fresh one."""
    table = tmp_path / "cross_process.json"
    prog = textwrap.dedent("""
        import json, sys
        import numpy as np
        from repro.core import Field, LaunchGraph, SOA, TargetConfig, tune

        def body(v):
            return {"t": 2.0 * v["x"]}

        lat = (4, 4, 8)
        fx = Field.from_numpy(
            "x", np.ones((3, *lat), np.float32), lat, SOA)
        g = LaunchGraph("xproc").add(body, {"x": "x"}, {"t": 3})
        plan, info = tune.autotune_graph(
            g, {"x": fx}, config=TargetConfig("pallas", vvl=64),
            iters=1, warmup=0, max_candidates=3)
        print(json.dumps({"cached": info["cached"],
                          "sweeps": tune.stats()["sweep_launches"],
                          "plan": plan.to_json()}))
    """)
    import os

    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ, PYTHONPATH=src, TARGETDP_TUNE_PATH=str(table))
    outs = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", prog], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(json.loads(r.stdout.strip().splitlines()[-1]))
    first, second = outs
    assert not first["cached"] and first["sweeps"] > 0
    assert second["cached"] and second["sweeps"] == 0
    assert second["plan"] == first["plan"]
