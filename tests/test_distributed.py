"""Multi-device correctness on 8 fake host devices (subprocess: jax locks
the device count at first init, so these run isolated)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_script(body: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


COMMON = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import make_mesh, shard_map
mesh = make_mesh((4, 2), ("data", "model"))
"""


@pytest.mark.slow
def test_halo_exchange_matches_periodic_roll():
    run_script(COMMON + """
from repro.core import halo
from repro.lattice import Domain
dom = Domain(global_shape=(8, 8, 8), mesh=mesh,
             dim_axes=("data", "model", None), halo=1)
x = np.arange(3*8*8*8, dtype=np.float32).reshape(3, 8, 8, 8)

def local(xl):
    pads = [(0, 0)] + [(1, 1)]*3
    xh = jnp.pad(xl, pads, mode="wrap")
    xh = halo.exchange(xh, dom.decomposed, width=1)
    # after exchange: halo'd shifted window == periodic roll of global
    out = xh[:, :-2, 1:-1, 1:-1]      # shift +1 in x => value at (r-1)
    return out

f = jax.jit(shard_map(local, mesh=mesh, in_specs=dom.spec(),
            out_specs=dom.spec()))
got = np.asarray(f(jax.device_put(jnp.asarray(x), dom.sharding())))
want = np.roll(x, 1, axis=1)
np.testing.assert_array_equal(got, want)
print("halo OK")
""")


@pytest.mark.slow
def test_ludwig_sharded_equals_single():
    run_script(COMMON + """
from repro.core import TargetConfig
from repro.apps.ludwig import LudwigConfig, init_state, step
from repro.apps.ludwig.driver import make_sharded_step
from repro.lattice import Domain
cfg = LudwigConfig(lattice=(8, 8, 8), target=TargetConfig("jnp"))
st0 = init_state(cfg, seed=0)
jstep = jax.jit(step, static_argnums=1)
s = st0
for _ in range(3): s = jstep(s, cfg)
dom = Domain(global_shape=cfg.lattice, mesh=mesh,
             dim_axes=("data", "model", None), halo=2)
sstep = make_sharded_step(cfg, dom)
sh = dom.sharding()
dist_nd = jax.device_put(jnp.asarray(st0.dist.to_numpy()), sh)
q_nd = jax.device_put(jnp.asarray(st0.q.to_numpy()), sh)
for _ in range(3): dist_nd, q_nd = sstep(dist_nd, q_nd)
np.testing.assert_allclose(np.asarray(dist_nd), s.dist.to_numpy(),
                           rtol=5e-5, atol=1e-7)
np.testing.assert_allclose(np.asarray(q_nd), s.q.to_numpy(),
                           rtol=5e-5, atol=1e-7)
print("ludwig sharded OK")
""")


@pytest.mark.slow
def test_ludwig_overlap_step_bit_identical_to_pre():
    """The comms/compute overlap schedule (interior/boundary split
    launches, core.overlap) must be bit-identical to the pre-exchange
    schedule on the sharded LB step — and the run_steps StepPipeline must
    reproduce the step-by-step loop exactly."""
    run_script(COMMON + """
from repro.core import TargetConfig
from repro.apps.ludwig import LudwigConfig, init_state
from repro.apps.ludwig.driver import make_sharded_step, run_steps
from repro.lattice import Domain
cfg = LudwigConfig(lattice=(16, 8, 8), target=TargetConfig("jnp"))
st0 = init_state(cfg, seed=0)
dom = Domain(global_shape=cfg.lattice, mesh=mesh,
             dim_axes=("data", "model", None), halo=2)
sh = dom.sharding()
d0 = jax.device_put(jnp.asarray(st0.dist.to_numpy()), sh)
q0 = jax.device_put(jnp.asarray(st0.q.to_numpy()), sh)
pre = make_sharded_step(cfg, dom, halo="pre")
ov = make_sharded_step(cfg, dom, halo="overlap")
dp, qp, do, qo = d0, q0, d0, q0
for _ in range(3):
    dp, qp = pre(dp, qp)
    do, qo = ov(do, qo)
np.testing.assert_array_equal(np.asarray(dp), np.asarray(do))
np.testing.assert_array_equal(np.asarray(qp), np.asarray(qo))
# the multi-step pipeline (donated double-buffers) is the same trajectory
dr, qr = run_steps(cfg, dom, d0, q0, 3, halo="overlap")
np.testing.assert_array_equal(np.asarray(dr), np.asarray(do))
np.testing.assert_array_equal(np.asarray(qr), np.asarray(qo))
print("ludwig overlap OK")
""")


@pytest.mark.slow
def test_milc_cg_overlap_bit_identical_to_pre():
    """Fused sharded CG under halo='overlap' must follow the exact same
    trajectory as halo='pre': same iterates bit-for-bit, same iteration
    count (the inner products are computed producer-independently from the
    assembled Fields).  Physics check: both agree with the single-shard
    fused solve within fp tolerance."""
    run_script(COMMON + """
from repro.apps.milc import MilcConfig, init_problem, solve
from repro.apps.milc.driver import solve_sharded
from repro.lattice import Domain
# local dim0 extent 5 >= 2*ring+1 with ring 2: a real interior/boundary
# split (not the thin-interior fallback) on the 4-rank axis
mesh1 = make_mesh((4,), ("mx",))
cfg = MilcConfig(lattice=(20, 4, 4, 4), kappa=0.10, tol=1e-10, max_iter=2000)
u, b = init_problem(cfg, seed=0)
dom = Domain(global_shape=cfg.lattice, mesh=mesh1,
             dim_axes=("mx", None, None, None), halo=1)
un, bn = jnp.asarray(u.to_numpy()), jnp.asarray(b.to_numpy())
xp, ip, rp = solve_sharded(cfg, dom, un, bn, halo="pre")
xo, io, ro = solve_sharded(cfg, dom, un, bn, halo="overlap")
assert int(ip) == int(io), (int(ip), int(io))
np.testing.assert_array_equal(np.asarray(xp), np.asarray(xo))
np.testing.assert_array_equal(np.asarray(rp), np.asarray(ro))
res = solve(cfg, u, b)
assert float(ro) <= cfg.tol
np.testing.assert_allclose(np.asarray(xo), res.x.to_numpy(),
                           rtol=5e-4, atol=5e-6)
print("milc overlap OK")
""")


@pytest.mark.slow
def test_milc_sharded_equals_single():
    run_script(COMMON + """
from repro.apps.milc import MilcConfig, init_problem, solve
from repro.apps.milc.driver import solve_sharded, make_domain
cfg = MilcConfig(lattice=(8, 4, 4, 4), kappa=0.10, tol=1e-10, max_iter=2000)
u, b = init_problem(cfg, seed=0)
res = solve(cfg, u, b)
dom = make_domain(cfg, mesh, ("data", "model", None, None))
x_nd, iters, resid = solve_sharded(cfg, dom, jnp.asarray(u.to_numpy()),
                                   jnp.asarray(b.to_numpy()))
assert int(iters) == int(res.iterations)
np.testing.assert_allclose(np.asarray(x_nd), res.x.to_numpy(),
                           rtol=5e-4, atol=5e-6)
print("milc sharded OK")
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """FSDP+TP GSPMD train step == single-device step (same batch/params)."""
    run_script(COMMON + """
import dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch
from repro.models import init_params
from repro.train.optimizer import OptConfig, init_opt
from repro.train.train_step import TrainConfig, build_train_step
from repro.train.sharding import param_specs, set_rules
from repro.launch.specs import resolve_tree

cfg = dataclasses.replace(get_arch("granite-3-2b", smoke=True),
                          dtype=jnp.float32)
params = init_params(cfg, jax.random.PRNGKey(0))
tcfg = TrainConfig(opt=OptConfig(lr=1e-2))
step = build_train_step(cfg, tcfg)
opt = init_opt(params, tcfg.opt)
rngb = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rngb.integers(0, cfg.vocab, (4, 16)), jnp.int32),
         "labels": jnp.asarray(rngb.integers(0, cfg.vocab, (4, 16)), jnp.int32)}

# single device reference
p1, o1, _, m1 = jax.jit(step)(params, opt, None, batch)

# sharded
pspecs = resolve_tree(param_specs(params), params, mesh)
pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
params_s = jax.tree_util.tree_map(lambda x, s: jax.device_put(x, s), params, pshard)
set_rules({"batch": ("data",), "seq": None, "seq_attn": None, "embed": None,
           "heads": None, "kv_heads": None, "head_dim": None, "mlp": "model",
           "vocab": "model", "expert": "model", "state": None})
with mesh:
    p2, o2, _, m2 = jax.jit(step)(params_s, opt, None, batch)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
# fp32 collective-reduction order differs across shards (and across GSPMD
# partitioner generations: old jax needs the atol headroom)
for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)
print("sharded train step OK")
""")


@pytest.mark.slow
def test_compressed_ring_allreduce():
    """Beyond-paper distributed trick: int8 ring all-reduce over ppermute
    with error feedback matches the exact mean within quantization error."""
    run_script(COMMON + """
from repro.train.optimizer import _q8, _dq8

def compressed_allreduce(x):
    n = 8
    acc = x
    val = x
    for _ in range(n - 1):
        codes, scale = _q8(val)      # int8 on the wire
        val = _dq8(codes, scale)
        val = jax.lax.ppermute(val, "flat",
                               perm=[(i, (i + 1) % n) for i in range(n)])
        acc = acc + val
    return acc / n

mesh1 = make_mesh((8,), ("flat",))
xs = np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32)
f = jax.jit(shard_map(compressed_allreduce, mesh=mesh1,
            in_specs=jax.sharding.PartitionSpec("flat"),
            out_specs=jax.sharding.PartitionSpec("flat")))
got = np.asarray(f(jnp.asarray(xs.reshape(8*1, 64))))
want = xs.mean(0, keepdims=True)
# every shard holds an approximation of the mean
err = np.abs(got - want).max()
rel = err / (np.abs(want).max() + 1e-9)
assert rel < 0.15, rel
print("compressed ring allreduce OK, rel err", rel)
""")


@pytest.mark.slow
def test_native_block_view_sharded_bit_identical():
    """Acceptance: a sharded halo'd stencil launch (the fused LB step) with
    AoSoA inputs under the native view='block' lowering is bit-identical to
    view='staged-nd' — both for the halo='pre' single launch and the
    halo='overlap' split schedule — on 8 fake devices, and matches the
    single-shard jnp oracle."""
    run_script(COMMON + """
from repro.core import Field, TargetConfig, aosoa
from repro.core import halo as halo_mod
from repro.core.overlap import overlap_launch
from repro.core.plan import LoweringPlan
from repro.kernels.lb_propagation.ops import collide_propagate_graph
from repro.lattice import Domain

LAT = (16, 8, 8)
dom = Domain(global_shape=LAT, mesh=mesh,
             dim_axes=("data", "model", None), halo=1)
rng = np.random.default_rng(0)
dist = (1.0 + 0.1 * rng.normal(size=(19, *LAT))).astype(np.float32)
force = (0.01 * rng.normal(size=(3, *LAT))).astype(np.float32)
lay = aosoa(4)  # local padded lattice (6, 6, 10): inner planes 60, 4 | 60
g = collide_propagate_graph(0.8)
tgt = TargetConfig("pallas", vvl=64)

def pad(x):
    return jnp.pad(x, [(0, 0)] + [(1, 1)] * 3, mode="wrap")

def local(d_nd, f_nd, view, halo):
    dF = Field.from_canonical("dist", pad(d_nd), pad(d_nd).shape[1:], lay)
    fF = Field.from_canonical("force", pad(f_nd), pad(f_nd).shape[1:], lay)
    plan = LoweringPlan("pallas", bx=1, halo=halo, interpret=True, view=view)
    if halo == "pre":
        # layout-preserving exchange: AoSoA shards in, AoSoA shards out,
        # so the native-block launch stages the physical tiles as-is
        dF = halo_mod.exchange_field(dF, dom.decomposed, width=1)
        fF = halo_mod.exchange_field(fF, dom.decomposed, width=1)
        out = g.launch({"dist": dF, "force": fF}, config=tgt,
                       outputs=("dist2",), halo="pre", plan=plan)
    else:
        out = overlap_launch(g, {"dist": dF, "force": fF},
                             decomposed=dom.decomposed, config=tgt,
                             outputs=("dist2",), halo="overlap", plan=plan)
    assert out["dist2"].layout == lay
    return out["dist2"].canonical_nd()

sh = dom.sharding()
spec = dom.spec()
d = jax.device_put(jnp.asarray(dist), sh)
f = jax.device_put(jnp.asarray(force), sh)
results = {}
for view in ("staged-nd", "block"):
    for halo in ("pre", "overlap"):
        fn = jax.jit(shard_map(
            lambda a, b, _v=view, _h=halo: local(a, b, _v, _h),
            mesh=mesh, in_specs=(spec, spec), out_specs=spec))
        results[(view, halo)] = np.asarray(fn(d, f))
base = results[("staged-nd", "pre")]
for k, v in results.items():
    np.testing.assert_array_equal(v, base, err_msg=str(k))
# single-shard jnp oracle (periodic == the wrap+exchange decomposition)
distF = Field.from_canonical("dist", jnp.asarray(dist), LAT, aosoa(4))
forceF = Field.from_canonical("force", jnp.asarray(force), LAT, aosoa(4))
want = g.launch({"dist": distF, "force": forceF},
                config=TargetConfig("jnp"), outputs=("dist2",))
np.testing.assert_allclose(base, want["dist2"].canonical_nd(),
                           rtol=1e-5, atol=1e-6)
print("native block sharded OK")
""")


@pytest.mark.slow
def test_tiled_lowering_sharded_bit_identical():
    """Acceptance (tiled y/z lowering): the sharded fused LB step under a
    tiled plan (LoweringPlan.by/bz — per-shard VMEM bounded by the tile,
    not the lattice) is bit-identical to the untiled whole-staging plan on
    8 fake devices, for both the halo='pre' single launch and the
    halo='overlap' split schedule (sub-launches inherit the tiles through
    sub_lattice_plan), and matches the single-shard jnp oracle."""
    run_script(COMMON + """
import dataclasses
from repro.core import Field, SOA, TargetConfig
from repro.core import halo as halo_mod
from repro.core.overlap import overlap_launch
from repro.core.plan import LoweringPlan
from repro.kernels.lb_propagation.ops import collide_propagate_graph
from repro.lattice import Domain

LAT = (16, 8, 8)  # mesh (4, 2): local interior (4, 4, 8)
dom = Domain(global_shape=LAT, mesh=mesh,
             dim_axes=("data", "model", None), halo=1)
rng = np.random.default_rng(0)
dist = (1.0 + 0.1 * rng.normal(size=(19, *LAT))).astype(np.float32)
force = (0.01 * rng.normal(size=(3, *LAT))).astype(np.float32)
g = collide_propagate_graph(0.8)
tgt = TargetConfig("pallas", vvl=64)
untiled = LoweringPlan("pallas", bx=1, interpret=True)
tiles = [(2, 0), (0, 4), (2, 4)]  # divide the (4, 4, 8) local interior

def pad(x):
    return jnp.pad(x, [(0, 0)] + [(1, 1)] * 3, mode="wrap")

def local(d_nd, f_nd, plan, halo):
    dF = Field.from_canonical("dist", pad(d_nd), pad(d_nd).shape[1:], SOA)
    fF = Field.from_canonical("force", pad(f_nd), pad(f_nd).shape[1:], SOA)
    plan = dataclasses.replace(plan, halo=halo)
    if halo == "pre":
        dF = halo_mod.exchange_field(dF, dom.decomposed, width=1)
        fF = halo_mod.exchange_field(fF, dom.decomposed, width=1)
        out = g.launch({"dist": dF, "force": fF}, config=tgt,
                       outputs=("dist2",), halo="pre", plan=plan)
    else:
        out = overlap_launch(g, {"dist": dF, "force": fF},
                             decomposed=dom.decomposed, config=tgt,
                             outputs=("dist2",), halo="overlap", plan=plan)
    return out["dist2"].canonical_nd()

sh = dom.sharding()
spec = dom.spec()
d = jax.device_put(jnp.asarray(dist), sh)
f = jax.device_put(jnp.asarray(force), sh)
results = {}
for by, bz in [(0, 0)] + tiles:
    plan = dataclasses.replace(untiled, by=by, bz=bz)
    for halo in ("pre", "overlap"):
        fn = jax.jit(shard_map(
            lambda a, b, _p=plan, _h=halo: local(a, b, _p, _h),
            mesh=mesh, in_specs=(spec, spec), out_specs=spec))
        results[(by, bz, halo)] = np.asarray(fn(d, f))
base = results[(0, 0, "pre")]
for k, v in results.items():
    np.testing.assert_array_equal(v, base, err_msg=str(k))
# single-shard jnp oracle (periodic == the wrap+exchange decomposition)
distF = Field.from_canonical("dist", jnp.asarray(dist), LAT, SOA)
forceF = Field.from_canonical("force", jnp.asarray(force), LAT, SOA)
want = g.launch({"dist": distF, "force": forceF},
                config=TargetConfig("jnp"), outputs=("dist2",))
np.testing.assert_allclose(base, want["dist2"].canonical_nd(),
                           rtol=1e-5, atol=1e-6)
print("tiled sharded OK")
""")
