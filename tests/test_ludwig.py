"""Ludwig application physics + paper claims C1 (single source) and the
quantitative LB check (shear-wave viscous decay)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Field, TargetConfig, aosoa
from repro.apps.ludwig import LudwigConfig, LudwigState, init_state, step
from repro.apps.ludwig.driver import diagnostics
from repro.kernels.lb_collision import ref as lbref
from repro.maths import d3q19


def test_conservation_and_relaxation():
    cfg = LudwigConfig(lattice=(8, 8, 8), target=TargetConfig("jnp"))
    s0 = init_state(cfg, seed=0)
    d0 = diagnostics(s0, cfg)
    jstep = jax.jit(step, static_argnums=1)
    s = s0
    for _ in range(20):
        s = jstep(s, cfg)
    d = diagnostics(s, cfg)
    assert abs(float(d["mass"]) - float(d0["mass"])) < 1e-2
    assert float(d["free_energy"]) <= float(d0["free_energy"]) + 1e-6
    assert np.abs(np.asarray(d["momentum"])).max() < 1e-4
    assert np.isfinite(s.q.to_numpy()).all()


def test_engine_portability_full_step():
    """C1: one step jnp vs pallas engines — same physics, bit-comparable."""
    cj = LudwigConfig(lattice=(8, 8, 8), target=TargetConfig("jnp"))
    cp = LudwigConfig(lattice=(8, 8, 8),
                      target=TargetConfig("pallas", vvl=128))
    s0 = init_state(cj, seed=0)
    s1 = step(s0, cj)
    s2 = step(init_state(cp, seed=0), cp)
    np.testing.assert_allclose(s1.q.to_numpy(), s2.q.to_numpy(),
                               rtol=3e-5, atol=1e-7)
    np.testing.assert_allclose(s1.dist.to_numpy(), s2.dist.to_numpy(),
                               rtol=3e-5, atol=1e-7)


def test_layout_portability_full_step():
    """C2: layouts change performance, never physics."""
    base = LudwigConfig(lattice=(8, 8, 8), target=TargetConfig("jnp"))
    ref_q = step(init_state(base, seed=0), base).q.to_numpy()
    for lay in [aosoa(64), aosoa(128)]:
        cfg = dataclasses.replace(base, layout=lay,
                                  target=TargetConfig("pallas", vvl=128))
        got = step(init_state(cfg, seed=0), cfg).q.to_numpy()
        np.testing.assert_allclose(got, ref_q, rtol=3e-5, atol=1e-7)


def test_shear_wave_viscous_decay():
    """Quantitative LB validation: u_y(x) = u0 sin(kx) decays at
    exp(-nu k^2 t) with nu = cs^2 (tau - 1/2)."""
    tau = 0.8
    L = 32
    lat = (L, 4, 4)
    nsites = int(np.prod(lat))
    u0 = 1e-3
    xs = np.arange(L)
    uy = u0 * np.sin(2 * np.pi * xs / L)
    u = np.zeros((3, *lat), np.float32)
    u[1] = uy[:, None, None]
    rho = jnp.ones((nsites,), jnp.float32)
    feq = lbref.equilibrium(rho, jnp.asarray(u.reshape(3, -1)))
    cfg = LudwigConfig(lattice=lat, tau=tau, a0=0.0, kappa=0.0,
                       gamma_rot=0.0, xi=0.0, target=TargetConfig("jnp"))
    state = LudwigState(
        dist=Field.from_canonical("dist", feq, lat, cfg.layout),
        q=Field.zeros("q", 5, lat, cfg.layout),
    )
    jstep = jax.jit(step, static_argnums=1)
    n_steps = 50
    for _ in range(n_steps):
        state = jstep(state, cfg)
    _, u_out = lbref.moments(state.dist.canonical())
    uy_out = np.asarray(u_out[1]).reshape(lat)[:, 0, 0]
    amp = 2.0 * np.abs(np.fft.rfft(uy_out)[1]) / L
    nu = d3q19.CS2 * (tau - 0.5)
    k = 2 * np.pi / L
    want = u0 * np.exp(-nu * k * k * n_steps)
    assert abs(amp - want) / want < 0.02, (amp, want)


def test_nematic_transition_direction():
    """LdG bulk physics: gamma < 2.7 relaxes toward isotropic (|Q| down)."""
    cfg = LudwigConfig(lattice=(8, 8, 8), gamma=2.0,
                       target=TargetConfig("jnp"))
    s = init_state(cfg, seed=1, q_amp=5e-3)
    q_in = float(np.abs(s.q.to_numpy()).mean())
    jstep = jax.jit(step, static_argnums=1)
    for _ in range(30):
        s = jstep(s, cfg)
    q_out = float(np.abs(s.q.to_numpy()).mean())
    assert q_out < q_in
