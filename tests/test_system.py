"""End-to-end behaviour: train a small LM until loss drops, then serve it;
run the two paper applications end-to-end."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import init_params
from repro.train.optimizer import OptConfig, init_opt
from repro.train.serve_step import generate
from repro.train.train_step import TrainConfig, build_train_step


@pytest.mark.slow
def test_train_then_serve_roundtrip():
    """Memorize a tiny corpus, then greedy-decode it back."""
    cfg = dataclasses.replace(get_arch("olmo-1b", smoke=True),
                              dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    # corpus: the repeating sequence 1 2 3 ... 16
    period = 16
    seq = (np.arange(64) % period + 1).astype(np.int32)
    tokens = jnp.asarray(seq[None, :-1])
    labels = jnp.asarray(seq[None, 1:])
    batch = {"tokens": tokens, "labels": labels}
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3))
    step = jax.jit(build_train_step(cfg, tcfg))
    opt, ef = init_opt(params, tcfg.opt), None
    loss = None
    for _ in range(60):
        params, opt, ef, m = step(params, opt, ef, batch)
        loss = float(m["loss"])
    assert loss < 0.1, loss

    prompt = jnp.asarray(seq[None, :8].astype(np.int32))
    out = generate(params, cfg, prompt, steps=16, s_max=128)
    got = np.asarray(out)[0, 8:]
    want = (np.arange(8, 24) % period + 1)
    assert (got == want).mean() > 0.9, (got, want)


def test_ludwig_end_to_end():
    from repro.core import TargetConfig
    from repro.apps.ludwig import LudwigConfig, init_state, step
    from repro.apps.ludwig.driver import diagnostics

    cfg = LudwigConfig(lattice=(8, 8, 8), gamma=3.0,
                       target=TargetConfig("jnp"))
    s = init_state(cfg, seed=0)
    jstep = jax.jit(step, static_argnums=1)
    for _ in range(10):
        s = jstep(s, cfg)
    d = diagnostics(s, cfg)
    assert np.isfinite(float(d["free_energy"]))
    assert abs(float(d["mass"]) - 512.0) < 0.01


def test_milc_end_to_end():
    from repro.apps.milc import MilcConfig, init_problem, solve
    from repro.apps.milc.driver import residual_check

    cfg = MilcConfig(lattice=(4, 4, 4, 4), kappa=0.12, tol=1e-10,
                     max_iter=2000, hot=0.8)
    u, b = init_problem(cfg, seed=1)
    res = solve(cfg, u, b)
    assert residual_check(cfg, u, b, res.x) < 1e-3
