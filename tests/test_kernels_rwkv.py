"""RWKV6 WKV: chunked & pallas vs the exact sequential oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv6_scan import rwkv6, rwkv6_decode_step


def _problem(rng, B, H, T, dk, dv, strong_decay=True):
    r = rng.normal(size=(B, H, T, dk)).astype(np.float32)
    k = (0.3 * rng.normal(size=(B, H, T, dk))).astype(np.float32)
    v = rng.normal(size=(B, H, T, dv)).astype(np.float32)
    scale = 1.0 if strong_decay else -2.0
    w = np.exp(-np.exp(scale + rng.normal(size=(B, H, T, dk)))).astype(np.float32)
    u = (0.5 * rng.normal(size=(H, dk))).astype(np.float32)
    s0 = (0.1 * rng.normal(size=(B, H, dk, dv))).astype(np.float32)
    return r, k, v, w, u, s0


@pytest.mark.parametrize("shape", [(1, 1, 32, 8, 8), (2, 3, 128, 16, 24),
                                   (1, 2, 64, 32, 32)], ids=str)
@pytest.mark.parametrize("chunk", [16, 32])
def test_chunked_and_pallas_vs_scan(shape, chunk, rng):
    B, H, T, dk, dv = shape
    r, k, v, w, u, s0 = _problem(rng, *shape)
    o_ref, s_ref = rwkv6(r, k, v, w, u, s0, engine="scan")
    o_jnp, s_jnp = rwkv6(r, k, v, w, u, s0, engine="jnp", chunk=chunk)
    o_pl, s_pl = rwkv6(r, k, v, w, u, s0, engine="pallas", chunk=chunk)
    # scan vs chunked differ in fp32 accumulation order; tolerance scales
    # with sequence length
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_jnp), np.asarray(s_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_jnp),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_jnp),
                               rtol=3e-5, atol=3e-5)


def test_strong_decay_no_overflow(rng):
    """w near 0 (aggressive forgetting) must not overflow the chunked form
    (the 1/P trick would)."""
    B, H, T, dk, dv = 1, 1, 64, 8, 8
    r, k, v, w, u, s0 = _problem(rng, B, H, T, dk, dv, strong_decay=True)
    w = np.full_like(w, 1e-6)  # decays to ~zero each step
    o_ref, _ = rwkv6(r, k, v, w, u, s0, engine="scan")
    o_jnp, _ = rwkv6(r, k, v, w, u, s0, engine="jnp", chunk=32)
    assert np.isfinite(np.asarray(o_jnp)).all()
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_decode_continues_scan(rng):
    B, H, T, dk, dv = 2, 2, 16, 8, 8
    r, k, v, w, u, s0 = _problem(rng, B, H, T, dk, dv)
    o_ref, s_ref = rwkv6(r, k, v, w, u, s0, engine="scan")
    s = jnp.asarray(s0)
    outs = []
    for t in range(T):
        o1, s = rwkv6_decode_step(r[:, :, t], k[:, :, t], v[:, :, t],
                                  w[:, :, t], jnp.asarray(u), s)
        outs.append(np.asarray(o1))
    np.testing.assert_allclose(np.stack(outs, 2), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)
