"""Tiled y/z stencil lowering (``LoweringPlan.by``/``bz``) + VMEM budget.

The paper's premise is that lattice kernels saturate memory bandwidth at
*production* local volumes (§3.2, §5); whole-staging bounds the shard by
on-chip memory instead.  These tests pin the contract that removes that
bound: a tiled plan appends sequential y/z grid axes whose per-program
window is the halo'd tile — **bitwise identical** fields to whole-staging
on every engine path (staged-nd and native-block views, periodic/pre/
overlap halos, batched stacks, split reductions), tolerance-equal fp sum
reductions (the rsplit contract: per-tile fold order), and exact max/int
reductions.  Plan-layer satellites: by/bz default to 0 (bit-compat with
every persisted plan), describe() tags tiles and reports the footprint
estimate, validate() rejects non-dividing extents with a clear error, the
VMEM byte budget (TargetConfig.vmem_bytes / $TARGETDP_VMEM_BYTES) makes
default_plan auto-tile over-budget launches and candidate_plans skip+log
over-budget candidates, and sub_lattice_plan inherits tiles into overlap
sub-launches whenever they still divide.
"""

import dataclasses
import logging

import numpy as np
import pytest

from repro.core import (
    Field, LaunchGraph, LoweringPlan, SOA, TargetConfig, aosoa,
)
from repro.core import plan as plan_mod
from repro.core.field import BatchedField
from repro.core.plan import VIEW_BLOCK
from repro.core.stencil import tile_boxes

PCFG = TargetConfig("pallas", vvl=128)
LAT = (6, 4, 8)


def _scale(v, *, a):
    return {"y": a * v["x"]}


def _lap(v, gather, *, c):
    return {"z": (c * v["y"] + gather("y", (1, 0, 0))
                  + gather("y", (0, -1, 0))) ** 2}


def _graph():
    return (LaunchGraph("tile_g")
            .add(_scale, {"x": "x"}, {"y": 3}, params=dict(a=2.0))
            .add_stencil(_lap, {"y": "y"}, {"z": 3}, width=1,
                         params=dict(c=-2.0))
            .add_reduce("z", op="sum", name="zt")
            .add_reduce("z", op="max", name="zm"))


def _field(rng, layout=SOA, lat=LAT):
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    return Field.from_numpy("x", x, lat, layout)


def _check(a, b):
    """Fields bitwise; fp sums tolerance-equal (per-tile fold order); max
    exact."""
    np.testing.assert_array_equal(np.asarray(a["z"].data),
                                  np.asarray(b["z"].data))
    np.testing.assert_allclose(np.asarray(a["zt"]), np.asarray(b["zt"]),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a["zm"]), np.asarray(b["zm"]))


# -- lowering identity ---------------------------------------------------------

@pytest.mark.parametrize("by,bz", [(2, 0), (0, 4), (2, 4), (1, 2), (4, 8)])
def test_tiled_matches_untiled(by, bz, rng):
    g = _graph()
    fx = _field(rng)
    base = LoweringPlan("pallas", bx=2, interpret=True)
    a = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt", "zm"), plan=base)
    b = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt", "zm"),
                 plan=dataclasses.replace(base, by=by, bz=bz))
    _check(a, b)


@pytest.mark.parametrize("halo", ["pre", "overlap"])
def test_tiled_matches_untiled_pre_and_overlap(halo, rng):
    import jax.numpy as jnp
    from repro.core.stencil import halo_pad

    g = _graph()
    x = rng.normal(size=(3, *LAT)).astype(np.float32)
    xh = np.asarray(halo_pad(jnp.asarray(x), 1, (1, 2, 3)))
    fxh = Field.from_numpy("x", xh, tuple(s + 2 for s in LAT), SOA)
    base = LoweringPlan("pallas", bx=2, halo=halo, interpret=True)
    a = g.launch({"x": fxh}, config=PCFG, outputs=("z", "zt", "zm"),
                 halo=halo, plan=base)
    b = g.launch({"x": fxh}, config=PCFG, outputs=("z", "zt", "zm"),
                 halo=halo, plan=dataclasses.replace(base, by=2, bz=4))
    _check(a, b)


def test_tiled_block_view_matches_untiled(rng):
    """view='block' composes with tiles: the tile is cut from the unpacked
    VMEM window, so edges never split a short array."""
    g = _graph()
    fx = _field(rng, layout=aosoa(4))
    base = LoweringPlan("pallas", bx=2, interpret=True, view=VIEW_BLOCK)
    a = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt", "zm"), plan=base)
    b = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt", "zm"),
                 plan=dataclasses.replace(base, by=2, bz=4))
    assert a["z"].layout == aosoa(4)
    # tiled outputs degrade to canonical tile writes but the requested
    # layout survives packing after the call
    assert b["z"].layout == aosoa(4)
    _check(a, b)


def test_tiled_composes_with_rsplit(rng):
    g = _graph()
    fx = _field(rng)
    base = LoweringPlan("pallas", bx=1, interpret=True)
    a = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt", "zm"), plan=base)
    b = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt", "zm"),
                 plan=dataclasses.replace(base, rsplit=2, by=2, bz=4))
    _check(a, b)


def test_tiled_batched_matches_untiled(rng):
    g = _graph()
    xs = rng.normal(size=(4, 3, *LAT)).astype(np.float32)
    bf = BatchedField.from_canonical("x", xs, LAT, SOA)
    base = LoweringPlan("pallas", bx=2, interpret=True)
    a = g.launch({"x": bf}, config=PCFG, outputs=("z", "zt", "zm"), plan=base)
    b = g.launch({"x": bf}, config=PCFG, outputs=("z", "zt", "zm"),
                 plan=dataclasses.replace(base, by=2, bz=4))
    _check(a, b)


def test_tiled_lb_step_matches_untiled(rng):
    """The production fused LB step under tiles."""
    from repro.kernels.lb_propagation.ops import collide_propagate_graph

    lat = (4, 14, 16)
    f0 = (1.0 + 0.1 * rng.normal(size=(19, *lat))).astype(np.float32)
    frc = (0.01 * rng.normal(size=(3, *lat))).astype(np.float32)
    ins = {"dist": Field.from_numpy("dist", f0, lat, SOA),
           "force": Field.from_numpy("force", frc, lat, SOA)}
    g = collide_propagate_graph(0.8)
    base = LoweringPlan("pallas", bx=2, interpret=True)
    a = g.launch(ins, config=PCFG, outputs=("dist2",), plan=base)
    b = g.launch(ins, config=PCFG, outputs=("dist2",),
                 plan=dataclasses.replace(base, by=7, bz=4))
    np.testing.assert_array_equal(np.asarray(a["dist2"].data),
                                  np.asarray(b["dist2"].data))


# -- plan axis: defaults, describe, validate, persistence ----------------------

def test_by_bz_default_bit_compat():
    """Persisted plans predate by/bz: from_json without them loads the
    untiled default and round-trips."""
    p = LoweringPlan.from_json(
        {"engine": "pallas", "vvl": 64, "bx": 2, "interpret": True})
    assert (p.by, p.bz) == (0, 0)
    q = LoweringPlan("pallas", bx=2, by=2, bz=4)
    assert LoweringPlan.from_json(q.to_json()) == q


def test_describe_tags_tiles_and_footprint():
    p = LoweringPlan("pallas", bx=2, by=2, bz=4)
    d = p.describe()
    assert "/ty2" in d and "/tz4" in d
    assert "KiB/prog" in p.describe(footprint=48 * 1024)
    assert "KiB/prog" not in d
    assert "/ty" not in LoweringPlan("pallas", bx=2).describe()


def test_validate_rejects_bad_tiles():
    n = int(np.prod(LAT))
    with pytest.raises(ValueError, match="by"):
        LoweringPlan("pallas", bx=2, by=3).validate(
            nsites=n, lattice=LAT, stencil=True)
    with pytest.raises(ValueError, match="bz"):
        LoweringPlan("pallas", bx=2, bz=5).validate(
            nsites=n, lattice=LAT, stencil=True)
    with pytest.raises(ValueError):
        LoweringPlan("jnp", by=2).validate(nsites=n, lattice=LAT,
                                           stencil=True)
    with pytest.raises(ValueError):  # site-local chains have no grid tiles
        LoweringPlan("pallas", by=2).validate(nsites=n, stencil=False)
    # dividing tiles pass
    LoweringPlan("pallas", bx=2, by=2, bz=4).validate(
        nsites=n, lattice=LAT, stencil=True)


def test_tile_boxes_cover_and_errors():
    boxes = tile_boxes(LAT, 2, 2, 4)
    assert len(boxes) == 3 * 2 * 2
    sites = set()
    for box in boxes:
        import itertools
        for pt in itertools.product(*[range(s, s + e) for s, e in box]):
            assert pt not in sites
            sites.add(pt)
    assert len(sites) == int(np.prod(LAT))
    with pytest.raises(ValueError, match="divide"):
        tile_boxes(LAT, 2, 3, 0)


# -- VMEM budget ---------------------------------------------------------------

IN_VIEWS = ((3, 1, 4),)   # (ncomp, ring, itemsize)
OUT_VIEWS = ((3, 4),)


def test_estimate_vmem_bytes_model():
    lat = (16, 32, 32)
    untiled = plan_mod.estimate_vmem_bytes(
        LoweringPlan("pallas", bx=1), lattice=lat,
        in_views=IN_VIEWS, out_views=OUT_VIEWS)
    # whole halo'd input + one output slab
    assert untiled == 3 * 18 * 34 * 34 * 4 + 3 * 32 * 32 * 4
    tiled = plan_mod.estimate_vmem_bytes(
        LoweringPlan("pallas", bx=1, by=4, bz=4), lattice=lat,
        in_views=IN_VIEWS, out_views=OUT_VIEWS)
    # two double-buffered windows + one output tile: tile-bounded
    assert tiled == 2 * 3 * 3 * 6 * 6 * 4 + 3 * 4 * 4 * 4
    assert tiled < untiled


def test_choose_tiles():
    lat = (16, 32, 32)
    big = 10 ** 9
    assert plan_mod.choose_tiles(
        lat, 1, in_views=IN_VIEWS, out_views=OUT_VIEWS,
        vmem_bytes=big) == (0, 0)
    by, bz = plan_mod.choose_tiles(
        lat, 1, in_views=IN_VIEWS, out_views=OUT_VIEWS,
        vmem_bytes=64 * 1024)
    assert by or bz
    assert (not by or lat[1] % by == 0) and (not bz or lat[2] % bz == 0)
    p = LoweringPlan("pallas", bx=1, by=by, bz=bz)
    assert plan_mod.estimate_vmem_bytes(
        p, lattice=lat, in_views=IN_VIEWS,
        out_views=OUT_VIEWS) <= 64 * 1024
    # hopeless budget: best-effort finest tile, never an exception
    assert plan_mod.choose_tiles(
        lat, 1, in_views=IN_VIEWS, out_views=OUT_VIEWS,
        vmem_bytes=16) == (1, 1)


def test_resolved_vmem_bytes_precedence(monkeypatch):
    monkeypatch.delenv(plan_mod.VMEM_ENV, raising=False)
    assert plan_mod.resolved_vmem_bytes(PCFG) is None
    monkeypatch.setenv(plan_mod.VMEM_ENV, str(1 << 20))
    assert plan_mod.resolved_vmem_bytes(PCFG) == 1 << 20
    explicit = dataclasses.replace(PCFG, vmem_bytes=1 << 16)
    assert plan_mod.resolved_vmem_bytes(explicit) == 1 << 16
    assert TargetConfig("pallas").resolved_vmem_bytes() == 1 << 20
    monkeypatch.setenv(plan_mod.VMEM_ENV, "not-a-number")
    assert plan_mod.resolved_vmem_bytes(PCFG) is None
    # 0 = explicitly unbounded
    assert plan_mod.resolved_vmem_bytes(
        dataclasses.replace(PCFG, vmem_bytes=0)) is None


def test_default_plan_auto_tiles_over_budget(monkeypatch):
    """The acceptance demo: a lattice whose whole-staging exceeds the
    budget gets a *tiled* default plan, and that plan runs to completion
    bit-identically to the unbudgeted default."""
    monkeypatch.delenv(plan_mod.VMEM_ENV, raising=False)
    lat = (16, 32, 32)
    nsites = int(np.prod(lat))
    kw = dict(nsites=nsites, layouts=[SOA], stencil=True, lattice=lat,
              halo="periodic", vmem_views=(IN_VIEWS, OUT_VIEWS))
    free = plan_mod.default_plan(PCFG, **kw)
    assert (free.by, free.bz) == (0, 0)  # no budget => pre-PR plans
    monkeypatch.setenv(plan_mod.VMEM_ENV, str(64 * 1024))
    tight = plan_mod.default_plan(PCFG, **kw)
    assert tight.by or tight.bz
    fp = plan_mod.estimate_vmem_bytes(
        tight, lattice=lat, in_views=IN_VIEWS, out_views=OUT_VIEWS)
    assert fp <= 64 * 1024

    rng = np.random.default_rng(0)
    g = (LaunchGraph("budget_demo")
         .add(_scale, {"x": "x"}, {"y": 3}, params=dict(a=2.0))
         .add_stencil(_lap, {"y": "y"}, {"z": 3}, width=1,
                      params=dict(c=-2.0)))
    fx = _field(rng, lat=lat)
    run = dataclasses.replace(tight, interpret=True)
    got = g.launch({"x": fx}, config=PCFG, outputs=("z",), plan=run)
    ref = g.launch({"x": fx}, config=PCFG, outputs=("z",),
                   plan=dataclasses.replace(free, interpret=True))
    np.testing.assert_array_equal(np.asarray(got["z"].data),
                                  np.asarray(ref["z"].data))


def test_candidate_plans_skip_and_log_over_budget(monkeypatch, caplog):
    monkeypatch.setenv(plan_mod.VMEM_ENV, str(64 * 1024))
    lat = (16, 32, 32)
    with caplog.at_level(logging.INFO, logger="repro.core.plan"):
        cands = plan_mod.candidate_plans(
            PCFG, nsites=int(np.prod(lat)), layouts=[SOA], stencil=True,
            lattice=lat, halo="periodic",
            vmem_views=(IN_VIEWS, OUT_VIEWS))
    assert cands  # never an empty sweep
    for c in cands:
        if c.engine != "pallas":
            continue
        assert c.by or c.bz, f"over-budget untiled candidate kept: {c}"
    skips = [r for r in caplog.records if "exceeds budget" in r.message]
    assert skips and "KiB/prog" in skips[0].getMessage()


def test_launch_feeds_budget_to_default_plan(monkeypatch, rng):
    """End to end through LaunchGraph.launch: under a tiny env budget the
    default-policy launch lowers tiled (and still matches the jnp oracle)."""
    from repro.core import fuse

    monkeypatch.setenv(plan_mod.VMEM_ENV, str(64 * 1024))
    fuse.clear_cache()
    lat = (16, 32, 32)
    g = (LaunchGraph("budget_launch")
         .add(_scale, {"x": "x"}, {"y": 3}, params=dict(a=2.0))
         .add_stencil(_lap, {"y": "y"}, {"z": 3}, width=1,
                      params=dict(c=-2.0)))
    fx = _field(rng, lat=lat)
    got = g.launch({"x": fx}, config=PCFG, outputs=("z",))
    want = g.launch({"x": fx}, config=TargetConfig("jnp"), outputs=("z",))
    np.testing.assert_allclose(got["z"].to_numpy(), want["z"].to_numpy(),
                               rtol=1e-5, atol=1e-5)


# -- overlap inheritance -------------------------------------------------------

def test_sub_lattice_plan_inherits_dividing_tiles():
    outer = LoweringPlan("pallas", bx=2, halo="overlap", by=2, bz=4)
    sub = plan_mod.sub_lattice_plan(outer, PCFG, (4, 4, 8))
    assert (sub.by, sub.bz) == (2, 4)
    assert sub.halo == "pre"
    # thin boundary slab: y no longer divides -> tile drops to whole-axis
    thin = plan_mod.sub_lattice_plan(outer, PCFG, (1, 3, 8))
    assert (thin.by, thin.bz) == (0, 4)


def test_tune_candidates_carry_budget(monkeypatch, rng):
    """plan_candidates_for derives vmem_views from the graph's ring
    analysis, so the sweep set under a tight budget is tiled-only."""
    from repro.core import tune

    monkeypatch.setenv(plan_mod.VMEM_ENV, str(64 * 1024))
    lat = (16, 32, 32)
    g = (LaunchGraph("budget_tune")
         .add(_scale, {"x": "x"}, {"y": 3}, params=dict(a=2.0))
         .add_stencil(_lap, {"y": "y"}, {"z": 3}, width=1,
                      params=dict(c=-2.0)))
    fx = _field(rng, lat=lat)
    cands = tune.plan_candidates_for(
        g, {"x": fx}, config=PCFG, outputs=("z",))
    assert cands
    for c in cands:
        if c.engine == "pallas":
            assert c.by or c.bz
