"""Dry-run harness validation (reduced configs through the REAL harness:
512 fake devices, production meshes, full spec/sharding path).

The full-config 80-cell sweep runs via repro.launch.sweep and is recorded
in EXPERIMENTS.md; these tests prove the machinery itself in CI time."""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_dryrun(arch, shape, mesh, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--smoke-arch",
         "--no-exact-loops"],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    return json.loads(proc.stdout)


@pytest.mark.slow
@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_train_single_and_multi_pod(mesh):
    row = run_dryrun("granite-3-2b", "train_4k", mesh)
    assert row["status"] == "ok"
    assert row["devices"] == (512 if mesh == "multi" else 256)
    assert row["roofline"]["flops_per_device"] > 0
    assert row["memory"]["live_per_device_gib"] >= 0


@pytest.mark.slow
def test_dryrun_decode():
    row = run_dryrun("granite-3-2b", "decode_32k", "single")
    assert row["status"] == "ok"


@pytest.mark.slow
def test_dryrun_skip_long_context_for_full_attention():
    row = run_dryrun("granite-3-2b", "long_500k", "single")
    assert row["status"] == "skipped"
    assert "sub-quadratic" in row["reason"]


def test_mesh_shapes():
    """make_production_mesh contract (checked without touching devices)."""
    import repro.launch.mesh as M
    import inspect

    src = inspect.getsource(M.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src.replace("'", '"')


def test_dryrun_sets_xla_flags_first():
    """Spec requirement: the first two statements of dryrun.py set
    XLA_FLAGS before any other import."""
    path = os.path.join(SRC, "repro", "launch", "dryrun.py")
    with open(path) as f:
        lines = [l.strip() for l in f.readlines() if l.strip()]
    assert lines[0] == "import os"
    assert lines[1].startswith('os.environ["XLA_FLAGS"]')
    assert "--xla_force_host_platform_device_count=512" in lines[1]
