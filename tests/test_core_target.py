"""Engine dispatch (paper C1: single source, both targets) + reductions."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AOS, SOA, Field, TargetConfig, aosoa, choose_vvl, kernel, launch,
    target_max, target_sum,
)
from repro.core import memspace

LAYOUTS = [SOA, AOS, aosoa(4), aosoa(64)]
LAT = (4, 8, 16)  # 512 sites


@kernel
def _scale(v, a):
    return {"out": a * v["field"]}


@kernel
def _saxpy(v, a):
    return {"out": a * v["x"] + v["y"]}


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("vvl", [64, 128, 256])
def test_engines_agree_scale(lay, vvl, rng):
    if lay.kind.value == "aosoa" and vvl % lay.sal:
        pytest.skip("sal must divide vvl")
    x = rng.normal(size=(3, *LAT)).astype(np.float32)
    f = Field.from_numpy("field", x, LAT, lay)
    o1 = launch(_scale, {"field": f}, {"out": 3},
                config=TargetConfig("jnp"), params={"a": 2.5})["out"]
    o2 = launch(_scale, {"field": f}, {"out": 3},
                config=TargetConfig("pallas", vvl=vvl), params={"a": 2.5})["out"]
    np.testing.assert_allclose(o1.to_numpy(), 2.5 * x, rtol=1e-6)
    np.testing.assert_allclose(o2.to_numpy(), o1.to_numpy(), rtol=1e-6)


def test_multi_field_kernel(rng):
    x = rng.normal(size=(5, *LAT)).astype(np.float32)
    y = rng.normal(size=(5, *LAT)).astype(np.float32)
    fx = Field.from_numpy("x", x, LAT, SOA)
    fy = Field.from_numpy("y", y, LAT, aosoa(8))  # mixed layouts in one launch
    out = launch(_saxpy, {"x": fx, "y": fy}, {"out": 5},
                 config=TargetConfig("pallas", vvl=128), params={"a": -1.5})
    np.testing.assert_allclose(out["out"].to_numpy(), -1.5 * x + y,
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
def test_reductions(lay, rng):
    x = rng.normal(size=(3, *LAT)).astype(np.float32)
    f = Field.from_numpy("f", x, LAT, lay)
    want_sum = x.reshape(3, -1).sum(1)
    want_max = x.reshape(3, -1).max(1)
    for cfgt in [TargetConfig("jnp"), TargetConfig("pallas", vvl=128)]:
        np.testing.assert_allclose(np.asarray(target_sum(f, cfgt)), want_sum,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(target_max(f, cfgt)), want_max,
                                   rtol=1e-6)


def test_choose_vvl():
    assert choose_vvl(512, 128) == 128
    assert choose_vvl(100, 128) == 100
    assert choose_vvl(96, 64) == 48


def test_memspace_roundtrip(rng):
    x = rng.normal(size=(7, 13)).astype(np.float32)
    buf = memspace.target_malloc((7, 13))
    assert buf.shape == (7, 13)
    dev = memspace.copy_to_target(x)
    back = memspace.copy_from_target(dev)
    np.testing.assert_array_equal(back, x)
    memspace.target_synchronize(dev)
    memspace.target_free(dev)


def test_relayout(rng):
    x = rng.normal(size=(3, *LAT)).astype(np.float32)
    f = Field.from_numpy("f", x, LAT, SOA)
    g = f.as_layout(aosoa(16))
    np.testing.assert_array_equal(g.to_numpy(), x)
    assert g.layout.sal == 16
