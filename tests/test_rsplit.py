"""Split reductions (the ``rsplit`` plan axis): two-stage partial lowering
== unsplit within fp tolerance (bitwise for max and integer sums), bitwise
deterministic across repeat launches, candidate/tuner integration, the
public ReduceSpec monoid, and the bind()/BoundLaunch API."""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AOS, BatchedField, BoundLaunch, Field, LaunchGraph, LoweringPlan,
    ReduceSpec, SOA, TargetConfig, aosoa, target_max, target_sum, tune,
)
from repro.core import plan as plan_mod

LAT = (4, 4, 8)  # 128 sites
LAYOUTS = [AOS, SOA, aosoa(16)]  # sal 16 conforms to the vvl=16 test plans


def _mk(name, ncomp, lay, rng, lat=LAT, dtype=np.float32):
    arr = rng.normal(size=(ncomp, *lat)).astype(dtype)
    return arr, Field.from_numpy(name, arr, lat, lay)


def _cfg(plan):
    return TargetConfig("pallas", plan_policy=plan)


def _plan(rsplit, *, vvl=16, bx=0):
    if bx:
        return LoweringPlan("pallas", bx=bx, rsplit=rsplit, interpret=True)
    return LoweringPlan("pallas", vvl=vvl, rsplit=rsplit, interpret=True)


def _dot_graph(ncomp=3):
    return (LaunchGraph("rs_dot")
            .add(lambda v: {"t": v["x"] * v["y"]},
                 {"x": "x", "y": "y"}, {"t": ncomp})
            .add_reduce("t", op="sum", name="dot"))


# -- fused lowering: split == unsplit -----------------------------------------

@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("rsplit", [2, 4, 8])
def test_fused_site_local_split_matches_unsplit(lay, rsplit, rng):
    x, fx = _mk("x", 3, lay, rng)
    y, fy = _mk("y", 3, lay, rng)
    g = _dot_graph()
    ins = {"x": fx, "y": fy}
    base = g.launch(ins, config=_cfg(_plan(1)), outputs=("t", "dot"))
    out = g.launch(ins, config=_cfg(_plan(rsplit)), outputs=("t", "dot"))
    # the field output is not reassociated: bitwise across the split axis
    np.testing.assert_array_equal(out["t"].to_numpy(), base["t"].to_numpy())
    np.testing.assert_allclose(np.asarray(out["dot"]),
                               np.asarray(base["dot"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["dot"]),
                               (x * y).reshape(3, -1).sum(axis=1), rtol=1e-4)
    # deterministic: a fixed split factor reproduces its bits on relaunch
    again = g.launch(ins, config=_cfg(_plan(rsplit)), outputs=("dot",))
    np.testing.assert_array_equal(np.asarray(out["dot"]),
                                  np.asarray(again["dot"]))


def test_fused_split_max_is_bitwise_exact(rng):
    _, fx = _mk("x", 3, SOA, rng)
    g = (LaunchGraph("rs_max")
         .add(lambda v: {"t": v["x"] * v["x"]}, {"x": "x"}, {"t": 3})
         .add_reduce("t", op="max", name="tmax"))
    base = g.launch({"x": fx}, config=_cfg(_plan(1)), outputs=("tmax",))
    out = g.launch({"x": fx}, config=_cfg(_plan(4)), outputs=("tmax",))
    # max is idempotent-insensitive to reassociation: bitwise, not approx
    np.testing.assert_array_equal(np.asarray(out["tmax"]),
                                  np.asarray(base["tmax"]))


@pytest.mark.parametrize("rsplit", [2, 4])
def test_fused_stencil_split_matches_unsplit(rsplit, rng):
    x, fx = _mk("x", 3, SOA, rng)

    def lap(v, gather):
        return {"z": gather("x", (1, 0, 0)) + gather("x", (-1, 0, 0))
                - 2.0 * v["x"]}

    g = (LaunchGraph("rs_lap")
         .add_stencil(lap, {"x": "x"}, {"z": 3}, width=1)
         .add_reduce("z", op="sum", name="zsum"))
    base = g.launch({"x": fx}, config=_cfg(_plan(1, bx=1)),
                    outputs=("z", "zsum"))
    out = g.launch({"x": fx}, config=_cfg(_plan(rsplit, bx=1)),
                   outputs=("z", "zsum"))
    np.testing.assert_array_equal(out["z"].to_numpy(), base["z"].to_numpy())
    np.testing.assert_allclose(np.asarray(out["zsum"]),
                               np.asarray(base["zsum"]), rtol=1e-4,
                               atol=1e-5)
    again = g.launch({"x": fx}, config=_cfg(_plan(rsplit, bx=1)),
                     outputs=("zsum",))
    np.testing.assert_array_equal(np.asarray(out["zsum"]),
                                  np.asarray(again["zsum"]))


def test_batched_split_matches_per_element(rng):
    xs = rng.normal(size=(3, 3, *LAT)).astype(np.float32)
    ys = rng.normal(size=(3, 3, *LAT)).astype(np.float32)
    bx = BatchedField.stack([Field.from_numpy("x", a, LAT, SOA) for a in xs])
    by = BatchedField.stack([Field.from_numpy("y", a, LAT, SOA) for a in ys])
    g = _dot_graph()
    out = g.launch({"x": bx, "y": by}, config=_cfg(_plan(4)),
                   outputs=("dot",))["dot"]
    assert np.asarray(out).shape == (3, 3)
    for i in range(3):
        single = g.launch(
            {"x": Field.from_numpy("x", xs[i], LAT, SOA),
             "y": Field.from_numpy("y", ys[i], LAT, SOA)},
            config=_cfg(_plan(4)), outputs=("dot",))["dot"]
        np.testing.assert_array_equal(np.asarray(out)[i], np.asarray(single))


# -- standalone target_sum / target_max ---------------------------------------

@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
def test_standalone_split_sum_within_tolerance(lay, rng):
    x, fx = _mk("x", 3, lay, rng)
    s1 = target_sum(fx, _cfg(_plan(1)))
    s4 = target_sum(fx, _cfg(_plan(4)))
    np.testing.assert_allclose(np.asarray(s4), np.asarray(s1), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(s4),
                                  np.asarray(target_sum(fx, _cfg(_plan(4)))))


def test_standalone_split_exact_for_max_and_integers(rng):
    x, fx = _mk("x", 3, SOA, rng)
    np.testing.assert_array_equal(
        np.asarray(target_max(fx, _cfg(_plan(4)))),
        np.asarray(target_max(fx, _cfg(_plan(1)))))
    di = rng.integers(-100, 100, size=(3, 128)).astype(np.int32)
    fi = Field.from_canonical("xi", jnp.asarray(di), LAT, SOA)
    # integer addition is associative: the split sum is bitwise the unsplit
    np.testing.assert_array_equal(
        np.asarray(target_sum(fi, _cfg(_plan(2)))), di.sum(axis=1))
    np.testing.assert_array_equal(
        np.asarray(target_max(fi, _cfg(_plan(2)))), di.max(axis=1))


# -- ReduceSpec: the public reduction monoid ----------------------------------

def test_reduce_spec_contract():
    s = ReduceSpec(op="sum")
    assert float(s.combine(jnp.float32(2), jnp.float32(3))) == 5.0
    assert np.all(np.asarray(s.init((2, 3), jnp.float32)) == 0.0)
    m = ReduceSpec(op="max")
    # dtype-aware init: integer max must start at iinfo.min, not -inf
    assert int(np.asarray(m.init((1,), jnp.int32))[0]) == np.iinfo(np.int32).min
    assert np.isneginf(np.asarray(m.init((1,), jnp.float32))[0])
    parts = jnp.asarray([[1.0, 5.0], [2.0, -3.0]])
    np.testing.assert_array_equal(np.asarray(m.combine_partials(parts)),
                                  [2.0, 5.0])
    np.testing.assert_array_equal(np.asarray(s.fold(parts, axis=0)),
                                  [3.0, 2.0])
    with pytest.raises(ValueError):
        ReduceSpec(op="prod")


def test_graph_reduce_specs_resolve_op_and_source():
    g = _dot_graph()
    specs = g.reduce_specs()
    assert set(specs) == {"dot"}
    assert specs["dot"].op == "sum" and specs["dot"].source == "t"
    assert specs["dot"].ncomp == 3
    # the legacy tuple view stays consistent with the dataclass view
    assert g.reduce_info() == {"dot": ("t", "sum")}


# -- plan axis: describe/json/validate/candidates -----------------------------

def test_describe_and_json_name_rsplit():
    p = _plan(4)
    assert "rs4" in p.describe()
    assert "rs" not in _plan(1).describe()
    j = p.to_json()
    assert j["rsplit"] == 4
    assert LoweringPlan.from_json(j) == p


def test_validate_rejects_bad_rsplit():
    with pytest.raises(ValueError, match="rsplit"):
        LoweringPlan("jnp", rsplit=2).validate()
    with pytest.raises(ValueError):
        LoweringPlan("pallas", vvl=16, rsplit=3, interpret=True).validate(
            nsites=128, layouts=[SOA])  # 8 blocks, 3 does not divide
    with pytest.raises(ValueError):
        LoweringPlan("pallas", bx=1, rsplit=3, interpret=True).validate(
            nsites=128, layouts=[SOA], lattice=LAT, stencil=True)
    with pytest.raises(ValueError):
        LoweringPlan("pallas", rsplit=0).validate()


def test_candidate_rsplit_twins_gated_on_reduce():
    cfg = TargetConfig("pallas", vvl=128)
    with_red = plan_mod.candidate_plans(cfg, nsites=128, layouts=[SOA],
                                        stencil=False, reduce=True)
    without = plan_mod.candidate_plans(cfg, nsites=128, layouts=[SOA],
                                       stencil=False, reduce=False)
    assert any(c.rsplit > 1 for c in with_red)
    assert all(c.rsplit == 1 for c in without)
    st_red = plan_mod.candidate_plans(cfg, nsites=128, layouts=[SOA],
                                      stencil=True, lattice=LAT, reduce=True)
    assert any(c.rsplit > 1 for c in st_red)
    for c in with_red + st_red:
        c.validate(nsites=128, layouts=[SOA], lattice=LAT, stencil=c.bx > 0)


def test_sub_lattice_plan_resets_rsplit():
    cfg = TargetConfig("pallas", vvl=64)
    outer = LoweringPlan("pallas", bx=1, rsplit=4, interpret=True)
    sub = plan_mod.sub_lattice_plan(outer, cfg, (2, 4, 8))
    assert sub.rsplit == 1  # the overlap slabs are already the split


# -- bind(): the bound-launch API ---------------------------------------------

def test_bind_matches_launch_and_overrides(rng):
    _, fx = _mk("x", 3, SOA, rng)
    _, fy = _mk("y", 3, SOA, rng)
    g = _dot_graph()
    ins = {"x": fx, "y": fy}
    bound = g.bind(config=_cfg(_plan(4)), outputs=("t", "dot"))
    assert isinstance(bound, BoundLaunch)
    ref = g.launch(ins, config=_cfg(_plan(4)), outputs=("t", "dot"))
    out = bound(ins)
    np.testing.assert_array_equal(out["t"].to_numpy(), ref["t"].to_numpy())
    np.testing.assert_array_equal(np.asarray(out["dot"]),
                                  np.asarray(ref["dot"]))
    # per-call overrides win over the bound defaults
    over = bound(ins, config=_cfg(_plan(1)), outputs=("dot",))
    assert set(over) == {"dot"}
    np.testing.assert_allclose(np.asarray(over["dot"]),
                               np.asarray(ref["dot"]), rtol=1e-5)
    # per-call out_layouts merge on top of the bound mapping
    bound_l = g.bind(config=_cfg(_plan(1)), outputs=("t",),
                     out_layouts={"t": SOA})
    assert bound_l(ins)["t"].layout == SOA
    assert bound_l(ins, out_layouts={"t": AOS})["t"].layout == AOS


def test_bound_launch_scalars_pass_through(rng):
    _, fx = _mk("x", 3, SOA, rng)
    _, fy = _mk("y", 3, SOA, rng)
    g = LaunchGraph("rs_fma").add(
        lambda v: {"o": v["y"] + v["a"] * v["x"]},
        {"x": "x", "y": "y", "a": "a"}, {"o": 3})
    bound = g.bind(config=TargetConfig("pallas", vvl=64), outputs=("o",))
    out = bound({"x": fx, "y": fy}, scalars={"a": 0.5})["o"]
    want = g.launch({"x": fx, "y": fy}, scalars={"a": 0.5},
                    config=TargetConfig("pallas", vvl=64))["o"]
    np.testing.assert_array_equal(out.to_numpy(), want.to_numpy())


# -- tuned rsplit winner drives a real solve ----------------------------------

@pytest.fixture()
def tune_env(tmp_path, monkeypatch):
    path = tmp_path / "tune_table.json"
    monkeypatch.setenv(tune.ENV_VAR, str(path))
    tune.clear_table_cache()
    tune.reset_stats()
    yield path
    tune.clear_table_cache()


def test_tuned_rsplit_cg_converges_to_default_solution(tune_env):
    """Acceptance: a persisted rsplit>1 winner for the fused normal
    operator drives the MILC CG solve under plan_policy="tuned" to the
    same solution as the default plan within documented tolerance, and
    bitwise-reproducibly across repeat runs."""
    from repro.apps.milc import MilcConfig, init_problem, solve
    from repro.apps.milc.cg import wilson_normal_graph

    tgt = TargetConfig("pallas", vvl=256)
    cfg = MilcConfig(lattice=(4, 4, 4, 4), kappa=0.10, tol=1e-8,
                     max_iter=200, target=tgt)
    u, b = init_problem(cfg, seed=0)
    g = wilson_normal_graph(float(cfg.kappa))
    cands = tune.plan_candidates_for(g, {"p": b, "u": u}, config=tgt,
                                     outputs=("ap", "pap"))
    split = [c for c in cands if c.rsplit > 1]
    assert split, "reduce graph sweep must offer rsplit twins"
    key = g.plan_key({"p": b, "u": u}, config=tgt, outputs=("ap", "pap"))
    tune.record(key, split[0])
    tune.clear_table_cache()
    assert tune.lookup(key) == split[0]

    res_default = solve(cfg, u, b)
    tuned_cfg = dataclasses.replace(
        cfg, target=dataclasses.replace(tgt, plan_policy="tuned"))
    tune.reset_stats()
    res_tuned = solve(tuned_cfg, u, b)
    assert tune.stats()["hits"] > 0, "tuned solve never consulted the table"
    x_def = res_default.x.to_numpy()
    x_tun = res_tuned.x.to_numpy()
    # same solution within the documented split-reduction tolerance
    rel = np.linalg.norm(x_tun - x_def) / np.linalg.norm(x_def)
    assert rel < 1e-4, f"tuned-rsplit solution drifted: rel={rel}"
    assert float(res_tuned.residual) <= cfg.tol
    # bitwise-reproducible: the tuned solve replays to identical bits
    res_again = solve(tuned_cfg, u, b)
    np.testing.assert_array_equal(res_again.x.to_numpy(), x_tun)
    assert int(res_again.iterations) == int(res_tuned.iterations)


def test_persisted_rsplit_round_trips_through_table(tune_env, rng):
    """The tune-table JSON names the rsplit axis and a lookup reproduces
    the exact plan (describe included)."""
    _, fx = _mk("x", 3, SOA, rng)
    _, fy = _mk("y", 3, SOA, rng)
    g = _dot_graph()
    plan = _plan(4)
    key = g.plan_key({"x": fx, "y": fy}, config=TargetConfig("pallas"))
    tune.record(key, plan)
    raw = json.loads(tune_env.read_text())
    assert raw["entries"][key]["plan"]["rsplit"] == 4
    tune.clear_table_cache()
    got = tune.lookup(key)
    assert got == plan and "rs4" in got.describe()
