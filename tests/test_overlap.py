"""core.overlap + core.schedule: the comms/compute overlap scheduler.

Single-process coverage: the interior/boundary decomposition, split-launch
equality with the halo='pre' path on both engines (field outputs bitwise,
reductions per-slab-combined within fp tolerance), the failure modes the
issue names (no-stencil rejection, thin-interior fallback logged not
fatal, 1-device tuner sweeps skipping overlap candidates), the planning
integration (candidate twins, tuned-table upgrade, adapt_plan), the
slab-granular halo helpers (incl. the thin-extent ValueError), and the
StepPipeline multi-step runner.  The sharded bit-identity harness lives in
tests/test_distributed.py (8 fake devices, slow)."""

import logging

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    Field, LaunchGraph, LoweringPlan, SOA, TargetConfig, fuse, halo,
    overlap, tune,
)
from repro.core import plan as plan_mod
from repro.core.schedule import StepPipeline
from repro.core.stencil import halo_pad

LAT = (8, 6, 4)
SITE_DIMS = (1, 2, 3)


def _lap_body(v, gather):
    return {"z": gather("y", (1, 0, 0)) + gather("y", (-1, 0, 0)) + v["y"]}


def _sq_body(v):
    return {"out": v["x"] * v["x"]}


def _stencil_graph():
    return LaunchGraph("ov_stencil").add_stencil(
        _lap_body, {"y": "x"}, {"z": 3}, width=1)


def _reduce_graph():
    return (
        LaunchGraph("ov_reduce")
        .add_stencil(_lap_body, {"y": "x"}, {"z": 3}, width=1)
        .add(_sq_body, {"x": "z"}, {"out": 3}, rename={"out": "zz"})
        .add_reduce("zz", op="sum", name="nrm")
    )


def _padded_field(rng, lat=LAT, ncomp=3, width=1, name="x"):
    arr = rng.normal(size=(ncomp, *lat)).astype(np.float32)
    h = halo_pad(jnp.asarray(arr), width, SITE_DIMS)
    return Field.from_canonical(name, h, tuple(h.shape[1:]), SOA)


# -- split_boxes geometry ------------------------------------------------------

def test_split_boxes_disjoint_cover():
    """Interior + boundary slabs partition the lattice exactly (every site
    computed once) for 1-, 2- and 3-dim splits."""
    for dims in [(0,), (0, 1), (0, 1, 2), (1,), ()]:
        interior, boundary = overlap.split_boxes(LAT, 1, dims)
        seen = np.zeros(LAT, np.int32)
        for box in ([interior] if interior else []) + list(boundary):
            sl = tuple(slice(s, e) for (s, e) in box)
            seen[sl] += 1
        assert (seen == 1).all(), (dims, seen.min(), seen.max())
        assert len(boundary) == 2 * len(dims)


def test_split_boxes_thin_interior_is_none():
    assert overlap.split_boxes((2, 8), 1, (0,)) == (None, [])
    assert overlap.split_boxes((4, 8), 2, (0,)) == (None, [])
    # exactly one interior plane is still a valid split
    interior, boundary = overlap.split_boxes((3, 8), 1, (0,))
    assert interior == ((1, 2), (0, 8)) and len(boundary) == 2


def test_split_boxes_bad_dim_raises():
    with pytest.raises(ValueError, match="out of range"):
        overlap.split_boxes(LAT, 1, (5,))


# -- split execution == pre execution ------------------------------------------

@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_overlap_launch_matches_pre_bitwise(engine, rng):
    """halo='overlap' on pre-exchanged inputs: interior + boundary
    sub-launches assemble to the bit-identical field output of the single
    halo='pre' launch (the production LB graph)."""
    from repro.kernels.lb_propagation.ops import collide_propagate_graph

    f0 = (1.0 + 0.1 * rng.normal(size=(19, *LAT))).astype(np.float32)
    frc = (0.01 * rng.normal(size=(3, *LAT))).astype(np.float32)
    dh = halo_pad(jnp.asarray(f0), 1, SITE_DIMS)
    fh = halo_pad(jnp.asarray(frc), 1, SITE_DIMS)
    dF = Field.from_canonical("dist", dh, tuple(dh.shape[1:]), SOA)
    fF = Field.from_canonical("force", fh, tuple(fh.shape[1:]), SOA)
    g = collide_propagate_graph(0.8)
    cfg = TargetConfig(engine, vvl=64)
    ins = {"dist": dF, "force": fF}
    pre = g.launch(ins, config=cfg, outputs=("dist2",), halo="pre")["dist2"]
    fuse.reset_stats()
    ov = g.launch(ins, config=cfg, outputs=("dist2",), halo="overlap")["dist2"]
    assert ov.lattice == LAT
    np.testing.assert_array_equal(pre.to_numpy(), ov.to_numpy())
    if engine == "pallas":
        # one pallas_call per distinct sub-launch shape: the split really
        # lowered as multiple coordinated kernels, not one
        assert fuse.stats()["pallas_calls"] > 1


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_overlap_reductions_combine_per_slab(engine, rng):
    """Terminal reductions under the split: field outputs stay bitwise,
    the reduction combines per-slab partials (deterministic slab order, fp
    reassociation within tolerance of the single-launch fold)."""
    g = _reduce_graph()
    fx = _padded_field(rng)
    cfg = TargetConfig(engine, vvl=64)
    pre = g.launch({"x": fx}, config=cfg, outputs=("z", "nrm"), halo="pre")
    ov = g.launch({"x": fx}, config=cfg, outputs=("z", "nrm"), halo="overlap")
    np.testing.assert_array_equal(pre["z"].to_numpy(), ov["z"].to_numpy())
    np.testing.assert_allclose(np.asarray(pre["nrm"]), np.asarray(ov["nrm"]),
                               rtol=1e-5)


def test_overlap_launch_entry_with_no_decomposition(rng):
    """overlap_launch with an empty decomposition (single rank, nothing to
    exchange) degenerates to the plain pre launch."""
    g = _stencil_graph()
    fx = _padded_field(rng)
    cfg = TargetConfig("jnp")
    want = g.launch({"x": fx}, config=cfg, halo="pre")["z"]
    got = overlap.overlap_launch(
        g, {"x": fx}, decomposed=(), config=cfg, halo="overlap")["z"]
    np.testing.assert_array_equal(want.to_numpy(), got.to_numpy())


# -- failure modes (issue satellite) -------------------------------------------

def test_no_stencil_graph_rejects_overlap(rng):
    g = LaunchGraph("site_only").add(_sq_body, {"x": "x"}, {"out": 3})
    fx = Field.from_numpy(
        "x", rng.normal(size=(3, *LAT)).astype(np.float32), LAT, SOA)
    with pytest.raises(ValueError, match="stencil"):
        g.launch({"x": fx}, config=TargetConfig("jnp"), halo="overlap")
    with pytest.raises(ValueError, match="stencil"):
        overlap.overlap_launch(g, {"x": fx}, decomposed=(),
                               config=TargetConfig("jnp"))
    # and the plan layer itself rejects the strategy for site-local shapes
    with pytest.raises(ValueError, match="overlap"):
        LoweringPlan("pallas", vvl=64, halo="overlap").validate(
            nsites=192, layouts=[SOA], stencil=False)


def test_thin_interior_falls_back_to_pre_logged(rng, caplog):
    """An interior smaller than one slab falls back to halo='pre' — logged,
    not fatal, and still bit-identical."""
    thin = (2, 2, 2)
    arr = rng.normal(size=(3, *thin)).astype(np.float32)
    h = halo_pad(jnp.asarray(arr), 1, SITE_DIMS)
    fx = Field.from_canonical("x", h, tuple(h.shape[1:]), SOA)
    g = _stencil_graph()
    cfg = TargetConfig("jnp")
    want = g.launch({"x": fx}, config=cfg, halo="pre")["z"]
    with caplog.at_level(logging.WARNING, logger="repro.core.overlap"):
        got = g.launch({"x": fx}, config=cfg, halo="overlap")["z"]
    assert any("falling back" in r.message for r in caplog.records)
    np.testing.assert_array_equal(want.to_numpy(), got.to_numpy())


def test_single_device_sweeps_skip_overlap_candidates(rng, tmp_path, monkeypatch):
    """Tuner sweeps on 1 device must not propose overlap candidates (no
    exchange to hide); with devices forced > 1 the twins appear, capped and
    distinctly labelled."""
    cfg = TargetConfig("pallas", vvl=64)
    one = plan_mod.candidate_plans(
        cfg, nsites=192, layouts=[SOA], stencil=True, lattice=LAT,
        halo="pre", devices=1)
    assert all(c.halo == "pre" for c in one)
    many = plan_mod.candidate_plans(
        cfg, nsites=192, layouts=[SOA], stencil=True, lattice=LAT,
        halo="pre", devices=8)
    halos = {c.halo for c in many}
    assert halos == {"pre", "overlap"}
    assert many[0].halo == "pre"  # the default plan stays the pre schedule
    assert sum(c.halo == "overlap" for c in many) <= 2  # twins, not a fork
    # the twins cost at most two slots of bx sweep resolution
    assert sum(c.halo == "pre" for c in many) >= len(one) - 2
    labels = [c.describe() for c in many]
    assert len(labels) == len(set(labels))  # pre/overlap twins distinguishable
    # periodic (single-shard) stencil launches never get overlap twins
    per = plan_mod.candidate_plans(
        cfg, nsites=192, layouts=[SOA], stencil=True, lattice=LAT,
        halo="periodic", devices=8)
    assert all(c.halo == "periodic" for c in per)
    # and a real 1-device autotune over a pre-halo'd stencil graph runs
    # clean end to end (this container has exactly one device)
    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "t.json"))
    tune.clear_table_cache()
    g = _stencil_graph()
    fx = _padded_field(rng)
    plan, info = tune.autotune_graph(
        g, {"x": fx}, config=cfg, halo="pre", iters=1, warmup=0,
        max_candidates=3)
    assert plan.halo == "pre" and not info["failed"]
    tune.clear_table_cache()


# -- planning integration ------------------------------------------------------

def test_adapt_plan_pre_overlap_interchange():
    ov = LoweringPlan("pallas", bx=2, halo="overlap", view="staged-nd")
    # a tuned overlap winner upgrades a call-site 'pre' launch
    assert plan_mod.adapt_plan(ov, stencil=True, halo="pre").halo == "overlap"
    # periodic call sites are authoritative (single shard: nothing to hide)
    assert plan_mod.adapt_plan(ov, stencil=True, halo="periodic").halo == "periodic"
    pre = LoweringPlan("pallas", bx=2, halo="pre", view="staged-nd")
    assert plan_mod.adapt_plan(pre, stencil=True, halo="overlap").halo == "overlap"


def test_tuned_overlap_plan_upgrades_pre_launch(rng, tmp_path, monkeypatch):
    """A persisted overlap winner makes plan_policy='tuned' halo='pre'
    launches execute the split schedule — overlap as an autotuned strategy,
    not a driver rewrite — with unchanged field numerics."""
    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "t.json"))
    tune.clear_table_cache()
    g = _stencil_graph()
    fx = _padded_field(rng)
    cfg = TargetConfig("pallas", vvl=64)
    want = g.launch({"x": fx}, config=cfg, halo="pre")["z"]
    # key on the interior lattice, as the tuner and the launch both do
    key = g.plan_key({"x": fx}, config=cfg, halo="pre", lattice=LAT)
    # overlap launches key identically (shared table entries per contract)
    assert g.plan_key({"x": fx}, config=cfg, halo="overlap", lattice=LAT) == key
    winner = LoweringPlan("pallas", bx=2, interpret=True, halo="overlap",
                          view="staged-nd")
    tune.record(key, winner)
    tune.clear_table_cache()
    fuse.clear_cache()
    fuse.reset_stats()
    tuned_cfg = TargetConfig("pallas", vvl=64, plan_policy="tuned")
    got = g.launch({"x": fx}, config=tuned_cfg, halo="pre")["z"]
    np.testing.assert_array_equal(want.to_numpy(), got.to_numpy())
    # the upgrade really ran the split: multiple sub-launch pallas_calls
    assert fuse.stats()["pallas_calls"] > 1
    tune.clear_table_cache()


def test_default_policy_keeps_pre_schedule(rng):
    """Bit-compat guard: the default plan policy never upgrades a 'pre'
    call site to the split schedule (one pallas_call, as before this PR)."""
    g = _stencil_graph()
    fx = _padded_field(rng)
    fuse.clear_cache()
    fuse.reset_stats()
    g.launch({"x": fx}, config=TargetConfig("pallas", vvl=64), halo="pre")
    assert fuse.stats()["pallas_calls"] == 1


# -- slab-granular halo helpers ------------------------------------------------

def test_exchange_dim_thin_extent_raises():
    """2*width of halo + an interior thinner than width would exchange
    overlapping (corrupt) slices — a clear ValueError instead."""
    x = jnp.zeros((3, 5, 8))
    with pytest.raises(ValueError, match=r"dim 1.*extent 5.*width 2"):
        halo.exchange_dim(x, axis_name="ax", axis_size=2, dim=1, width=2)
    with pytest.raises(ValueError, match="too thin"):
        halo.exchange(x, [(1, "ax", 2)], width=2)


def test_exchange_boundary_dim_subset(monkeypatch):
    """exchange_boundary touches only the requested dims (probed by
    counting exchange_dim calls; no mesh needed)."""
    calls = []

    def fake_exchange_dim(x, *, axis_name, axis_size, dim, width):
        calls.append(dim)
        return x

    monkeypatch.setattr(halo, "exchange_dim", fake_exchange_dim)
    x = jnp.zeros((3, 8, 8, 8))
    dec = [(1, "a", 2), (2, "b", 2), (3, "c", 2)]
    halo.exchange_boundary(x, dec, width=1, dims=(2,))
    assert calls == [2]
    calls.clear()
    halo.exchange_boundary(x, dec, width=1)
    assert calls == [1, 2, 3]


def test_start_finish_exchange_roundtrip(monkeypatch):
    """start_exchange/finish_exchange bracket the full dimension-ordered
    exchange (the handle is the seam the overlap schedule documents)."""
    monkeypatch.setattr(
        halo, "exchange", lambda x, dec, width: x + 1.0)
    x = jnp.ones((3, 4))
    pending = halo.start_exchange(x, [(1, "a", 2)], width=1)
    assert isinstance(pending, halo.PendingExchange)
    np.testing.assert_array_equal(
        np.asarray(halo.finish_exchange(pending)), np.asarray(x) + 1.0)


# -- StepPipeline --------------------------------------------------------------

def test_step_pipeline_matches_loop():
    def step(a, b):
        return a + b, b * 1.5

    pipe = StepPipeline(step, donate=False)
    a0, b0 = jnp.arange(4.0), jnp.ones(4)
    a, b = a0, b0
    for _ in range(5):
        a, b = step(a, b)
    ga, gb = pipe.run((a0, b0), 5)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(a), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(b), rtol=1e-6)
    # zero steps is the identity; single-array state is wrapped
    (same,) = StepPipeline(lambda x: x * 2, donate=False).run(a0, 0)
    np.testing.assert_array_equal(np.asarray(same), np.asarray(a0))
    with pytest.raises(ValueError, match="steps"):
        pipe.run((a0, b0), -1)


def test_step_pipeline_donation_modes():
    """donate=None auto-disables on CPU (jax cannot alias there); forcing
    donation still computes correctly (jax falls back with a warning);
    on_step observes every intermediate state."""
    pipe = StepPipeline(lambda x: x + 1.0)
    assert pipe._resolved_donate() is False  # cpu container
    seen = []
    forced = StepPipeline(lambda x: x + 1.0, donate=True)
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # cpu: "donated buffers not usable"
        (out,) = forced.run(jnp.zeros(3), 4,
                            on_step=lambda i, s: seen.append(i))
    np.testing.assert_array_equal(np.asarray(out), np.full(3, 4.0))
    assert seen == [0, 1, 2, 3]


def test_step_pipeline_run_timed():
    pipe = StepPipeline(lambda x: x * 1.01, donate=False)
    (out,), per_step = pipe.run_timed(jnp.ones(8), 3, warmup=1)
    np.testing.assert_allclose(np.asarray(out), 1.01 ** 4 * np.ones(8),
                               rtol=1e-5)
    assert per_step > 0
