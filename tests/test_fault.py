"""Fault tolerance: checkpoint atomicity/integrity, resume-equals-
uninterrupted, corruption recovery, async save."""

import dataclasses
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import init_params
from repro.train import checkpoint as C
from repro.train.data import DataConfig, TokenStream
from repro.train.fault import InjectedFailure, LoopConfig, run_loop
from repro.train.optimizer import OptConfig, init_opt
from repro.train.train_step import TrainConfig, build_train_step


@pytest.fixture(scope="module")
def harness():
    cfg = dataclasses.replace(get_arch("olmo-1b", smoke=True),
                              dtype=jnp.float32)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-2))
    step = jax.jit(build_train_step(cfg, tcfg))
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=16,
                                    global_batch=4, seed=7))
    mb = lambda t, l: {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}

    def make_state():
        p = init_params(cfg, jax.random.PRNGKey(0))
        return {"params": p, "opt": init_opt(p, tcfg.opt), "ef": None}

    return step, make_state, stream, mb


@pytest.mark.slow
def test_resume_reproduces_uninterrupted(harness, tmp_path):
    step, make_state, stream, mb = harness
    ref_dir, dir2 = str(tmp_path / "ref"), str(tmp_path / "crash")
    _, hist_ref = run_loop(step, make_state(), stream,
                           LoopConfig(12, ref_dir, ckpt_every=4),
                           make_batch=mb)
    with pytest.raises(InjectedFailure):
        run_loop(step, make_state(), stream,
                 LoopConfig(12, dir2, ckpt_every=4, fail_at_step=7),
                 make_batch=mb)
    _, hist2 = run_loop(step, make_state(), stream,
                        LoopConfig(12, dir2, ckpt_every=4), make_batch=mb)
    assert hist2[0]["step"] == 4  # resumed after the last checkpoint (step 3)
    ref = {m["step"]: m["loss"] for m in hist_ref}
    for m in hist2:
        assert abs(m["loss"] - ref[m["step"]]) < 1e-5


def test_corrupt_checkpoint_skipped(harness, tmp_path):
    step, make_state, stream, mb = harness
    d = str(tmp_path / "c")
    run_loop(step, make_state(), stream, LoopConfig(8, d, ckpt_every=4),
             make_batch=mb)
    cks = C.list_checkpoints(d)
    assert len(cks) >= 2
    # corrupt the newest
    newest = cks[-1][1]
    leaf = glob.glob(os.path.join(newest, "leaf_*.npy"))[0]
    with open(leaf, "wb") as f:
        f.write(b"corrupt")
    got = C.latest_valid(d)
    assert got is not None and got[1] != newest


def test_save_restore_roundtrip(tmp_path, rng):
    tree = {"a": jnp.asarray(rng.normal(size=(4, 5)).astype(np.float32)),
            "b": [jnp.arange(3), {"c": jnp.float32(2.5)}]}
    path = C.save(str(tmp_path), 7, tree, extra={"note": "x"})
    got, manifest = C.restore(path, tree)
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path, rng):
    tree = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    fut = C.save_async(str(tmp_path), 1, tree)
    path = fut.result(timeout=30)
    got, _ = C.restore(path, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_prune_keeps_newest(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in range(6):
        C.save(str(tmp_path), s, tree)
    C.prune(str(tmp_path), keep=2)
    steps = [s for s, _ in C.list_checkpoints(str(tmp_path))]
    assert steps == [4, 5]
