"""Mixed precision (the ``DtypePolicy`` plan axis): empty-policy bitwise
identity, compensated (Kahan) accumulation vs the fp64 oracle on
adversarial cancellation, bitwise exemptions (max and integer reductions),
the tuner's hard accuracy gate (rejected candidates logged, never
persisted), the policy-aware VMEM/traffic models, and the solver knobs —
MILC's iterative-refinement CG under narrowed storage and Ludwig's LB
storage knob — validated against the full-precision oracle."""

import dataclasses
import json
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AOS, DtypePolicy, Field, LaunchGraph, LoweringPlan, SOA, TargetConfig,
    aosoa, fuse, target_max, target_sum, telemetry, tune,
)
from repro.core import plan as plan_mod

try:  # satellite contract: property test runs where hypothesis exists,
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # ...the rest of the module never skips with it
    HAVE_HYPOTHESIS = False

LAT = (4, 4, 8)  # 128 sites
LAYOUTS = [AOS, SOA, aosoa(16)]
BF16 = DtypePolicy(storage="bfloat16", compute="float32",
                   accumulate="float64")
ACC64 = DtypePolicy(accumulate="float64")


def _mk(name, ncomp, lay, rng, lat=LAT, dtype=np.float32):
    arr = rng.normal(size=(ncomp, *lat)).astype(dtype)
    return arr, Field.from_numpy(name, arr, lat, lay)


def _plan(dtypes=None, vvl=16):
    return LoweringPlan("pallas", vvl=vvl, interpret=True, dtypes=dtypes)


def _cfg(plan):
    return TargetConfig("pallas", plan_policy=plan)


def _dot_graph(ncomp=3):
    return (LaunchGraph("dt_dot")
            .add(lambda v: {"t": v["x"] * v["y"]},
                 {"x": "x", "y": "y"}, {"t": ncomp})
            .add_reduce("t", op="sum", name="dot"))


@pytest.fixture()
def tune_env(tmp_path, monkeypatch):
    path = tmp_path / "tune_table.json"
    monkeypatch.setenv(tune.ENV_VAR, str(path))
    tune.clear_table_cache()
    tune.reset_stats()
    yield path
    tune.clear_table_cache()


# -- default-path identity: no policy (or an empty one) changes nothing ------

@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
def test_empty_policy_is_bitwise_identity(lay, rng):
    """A default (no DtypePolicy) launch and an empty-policy launch are
    bitwise identical on every output — the dtype axis is strictly
    opt-in."""
    assert not DtypePolicy()  # falsy: attaching it selects the default path
    _, fx = _mk("x", 3, lay, rng)
    _, fy = _mk("y", 3, lay, rng)
    g = _dot_graph()
    ins = {"x": fx, "y": fy}
    base = g.launch(ins, config=_cfg(_plan(None)), outputs=("t", "dot"))
    out = g.launch(ins, config=_cfg(_plan(DtypePolicy())),
                   outputs=("t", "dot"))
    np.testing.assert_array_equal(out["t"].to_numpy(), base["t"].to_numpy())
    np.testing.assert_array_equal(np.asarray(out["dot"]),
                                  np.asarray(base["dot"]))


def _cancel_fixture(ncomp):
    """Cross-block cancellation the fused compensated path can carry but
    the plain running sum cannot: a lone +1e8 in vvl-block 0 and a lone
    -1e8 in block 4 (the rest of those blocks zero, so the WITHIN-block
    partial sums are exact), filler 0.1875 everywhere else.  Oracle sum =
    96 * 0.1875 = 18 per component; the plain cross-block fold loses the
    filler riding next to 1e8 (f32 spacing 8 there)."""
    x = np.full((ncomp, 128), 0.1875, np.float32)
    x[:, 0:16] = 0.0
    x[:, 64:80] = 0.0
    x[:, 0] = 1.0e8
    x[:, 64] = -1.0e8
    return x


def test_accumulate_only_policy_keeps_fields_bitwise(rng):
    """accumulate="float64" widens ONLY the terminal reduction: the field
    output is bitwise the default launch's, the reduction tracks the fp64
    oracle through compensated summation even under adversarial
    cross-block cancellation."""
    x = _cancel_fixture(3)
    fx = Field.from_canonical("x", jnp.asarray(x), LAT, SOA)
    fy = Field.from_canonical("y", jnp.ones((3, 128), jnp.float32), LAT, SOA)
    g = _dot_graph()
    ins = {"x": fx, "y": fy}
    base = g.launch(ins, config=_cfg(_plan(None)), outputs=("t", "dot"))
    out = g.launch(ins, config=_cfg(_plan(ACC64)), outputs=("t", "dot"))
    np.testing.assert_array_equal(out["t"].to_numpy(), base["t"].to_numpy())
    oracle = np.sum(x.astype(np.float64), axis=1)  # = 18 per component
    got_err = np.max(np.abs(np.asarray(out["dot"], np.float64) - oracle))
    plain_err = np.max(np.abs(np.asarray(base["dot"], np.float64) - oracle))
    assert got_err <= 2.0  # measured 1.0: one compensation-rounding ulp
    # teeth: the plain cross-block fold drops the filler (measured err 9)
    assert got_err < plain_err


def test_storage_policy_casts_and_halves_telemetry_bytes(rng):
    """bf16 storage: field outputs come back in the storage dtype within
    the bf16 quantization tolerance, and the launch telemetry's modeled
    bytes halve — the traffic win the policy buys."""
    _, fx = _mk("x", 3, SOA, rng)
    _, fy = _mk("y", 3, SOA, rng)
    g = _dot_graph()
    ins = {"x": fx, "y": fy}
    telemetry.enable()
    try:
        telemetry.reset()
        cfg = TargetConfig("pallas", plan_policy=_plan(None), telemetry=True)
        base = g.launch(ins, config=cfg, outputs=("t", "dot"))
        cfg_b = TargetConfig("pallas", plan_policy=_plan(BF16),
                             telemetry=True)
        out = g.launch(ins, config=cfg_b, outputs=("t", "dot"))
        spans = telemetry.events("launch/")
    finally:
        telemetry.reset()
        telemetry.disable()
    assert out["t"].data.dtype == jnp.bfloat16
    err = (np.linalg.norm(out["t"].to_numpy().astype(np.float64)
                          - base["t"].to_numpy())
           / np.linalg.norm(base["t"].to_numpy()))
    assert err < 1e-2
    pol = [s for s in spans if "dt=bf16" in s["attrs"].get("plan", "")
           and "bytes_fused" in s["attrs"]]
    ref = [s for s in spans if "dt=" not in s["attrs"].get("plan", "")
           and "bytes_fused" in s["attrs"]]
    assert pol and ref, spans
    assert pol[0]["attrs"]["bytes_fused"] * 2 == ref[0]["attrs"]["bytes_fused"]


# -- compensated accumulation vs the fp64 oracle ------------------------------

ADVERSARIAL = [
    np.array([1.0, 1e8, 1.0, -1e8] * 16, np.float32),
    np.array([1e7, 0.125, -1e7, 0.125] * 16, np.float32),
    np.concatenate([np.full(64, 3e7, np.float32),
                    np.full(64, -3e7, np.float32),
                    np.full(64, 2.0**-12, np.float32)]),
]


@pytest.mark.parametrize("case", range(len(ADVERSARIAL)))
def test_kahan_fold_matches_fp64_oracle(case):
    """Classic-Kahan error bound: O(eps) * sum(|x|), independent of the
    element count (naive sequential summation degrades with n)."""
    x = ADVERSARIAL[case]
    oracle = float(np.sum(x.astype(np.float64)))
    got = float(fuse.kahan_fold(jnp.asarray(x), axis=-1))
    scale = float(np.sum(np.abs(x.astype(np.float64))))
    assert abs(got - oracle) <= 2.5e-7 * scale + 1e-6


def test_kahan_fold_beats_naive_sequential_fold():
    """Teeth for the scan: many small increments riding on a large running
    sum — the exact regime CG dot products live in.  The naive sequential
    f32 fold loses every increment (stalls at 2^25, then cancels to 0);
    the compensated scan keeps them to within one spacing ulp."""
    x = np.concatenate([[2.0 ** 25], np.full(126, 1.0),
                        [-2.0 ** 25]]).astype(np.float32)
    oracle = float(np.sum(x.astype(np.float64)))  # 126
    got = float(fuse.kahan_fold(jnp.asarray(x), axis=-1))
    naive = np.float32(0.0)
    for v in x:
        naive = np.float32(naive + v)
    assert abs(got - oracle) <= 4.0  # measured 2.0
    assert abs(float(naive) - oracle) >= 64.0  # measured 126.0


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(
        st.floats(min_value=-1e8, max_value=1e8, allow_nan=False,
                  width=32),
        min_size=1, max_size=96))
    def test_kahan_fold_property(xs):
        """Compensated fp32 summation tracks the fp64 oracle within a few
        target-precision ulps of the absolute mass, for arbitrary (incl.
        large-cancellation) inputs."""
        x = np.asarray(xs, np.float32)
        oracle = float(np.sum(x.astype(np.float64)))
        got = float(fuse.kahan_fold(jnp.asarray(x), axis=-1))
        scale = float(np.sum(np.abs(x.astype(np.float64))))
        assert abs(got - oracle) <= 4e-7 * scale + 1e-6
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_kahan_fold_property():
        pass


# -- bitwise exemptions: max and integer reductions ---------------------------

def test_max_reduction_bitwise_under_dtype_axis(rng):
    """max is order- and accumulate-insensitive: the dtype axis must leave
    it bitwise untouched (fused and standalone)."""
    x, fx = _mk("x", 3, SOA, rng)
    g = (LaunchGraph("dt_max")
         .add(lambda v: {"t": v["x"] * v["x"]}, {"x": "x"}, {"t": 3})
         .add_reduce("t", op="max", name="tmax"))
    base = g.launch({"x": fx}, config=_cfg(_plan(None)), outputs=("tmax",))
    out = g.launch({"x": fx}, config=_cfg(_plan(ACC64)), outputs=("tmax",))
    np.testing.assert_array_equal(np.asarray(out["tmax"]),
                                  np.asarray(base["tmax"]))
    np.testing.assert_array_equal(
        np.asarray(target_max(fx, _cfg(_plan(ACC64)))),
        np.asarray(target_max(fx, _cfg(_plan(None)))))
    np.testing.assert_array_equal(
        np.asarray(target_max(fx, _cfg(_plan(BF16)))),
        np.asarray(target_max(fx, _cfg(_plan(None)))))


def test_integer_sum_bitwise_under_dtype_axis(rng):
    """Integer addition is exact and associative: the dtype axis never
    touches non-float reductions."""
    di = rng.integers(-100, 100, size=(3, 128)).astype(np.int32)
    fi = Field.from_canonical("xi", jnp.asarray(di), LAT, SOA)
    want = di.sum(axis=1)
    for pol in (None, ACC64, BF16):
        got = np.asarray(target_sum(fi, _cfg(_plan(pol))))
        np.testing.assert_array_equal(got, want)
        assert got.dtype == np.int32


def test_standalone_float_sum_accumulates_compensated(rng):
    """Standalone target_sum under an accumulate policy matches the fp64
    oracle on the cross-block cancellation fixture, on both engines; on
    jnp (the lax.scan kahan_fold path) it beats the plain fold."""
    x = _cancel_fixture(2)
    fx = Field.from_canonical("x", jnp.asarray(x), LAT, SOA)
    oracle = np.sum(x.astype(np.float64), axis=1)  # = 18 per component
    got = np.asarray(target_sum(fx, _cfg(_plan(ACC64))), np.float64)
    assert np.max(np.abs(got - oracle)) <= 2.0  # measured 0.5625
    got_j = np.asarray(
        target_sum(fx, TargetConfig(
            "jnp", plan_policy=LoweringPlan("jnp", dtypes=ACC64))),
        np.float64)
    plain_j = np.asarray(target_sum(fx, TargetConfig("jnp")), np.float64)
    assert np.max(np.abs(got_j - oracle)) <= 2.0  # measured 1.0
    # teeth: the uncompensated jnp fold drops the filler (measured err 6)
    assert np.max(np.abs(got_j - oracle)) < np.max(np.abs(plain_j - oracle))


# -- the tuner's hard accuracy gate -------------------------------------------

def test_tuner_rejects_over_budget_policy_candidates(tune_env, rng, caplog):
    """A dtype-policy candidate that misses the accuracy gate is rejected:
    logged (log + telemetry + info["rejected"] + table meta) and NEVER
    persisted as the winner."""
    _, fx = _mk("x", 3, SOA, rng)
    cfg = TargetConfig("pallas", vvl=64)
    g = LaunchGraph("dt_probe").add(
        lambda v: {"t": 2.0 * v["x"]}, {"x": "x"}, {"t": 3})
    telemetry.enable()
    try:
        telemetry.reset()
        with caplog.at_level(logging.WARNING):
            plan, info = tune.autotune_graph(
                g, {"x": fx}, config=cfg, iters=1, warmup=0,
                max_candidates=6, accuracy_gate=1e-12)
        rej_events = telemetry.events("tune/accuracy_rejected")
    finally:
        telemetry.reset()
        telemetry.disable()
    assert info["rejected"], "the bf16 twin must fail a 1e-12 gate"
    assert any("rel_l2" in r for r in info["rejected"].values())
    assert not plan.dtypes, "an over-budget candidate must never win"
    assert rej_events and any("dt=" in e["attrs"]["plan"]
                              for e in rej_events)
    assert any("accuracy gate" in r.message for r in caplog.records)
    raw = json.loads(tune_env.read_text())
    entry = raw["entries"][info["key"]]
    assert entry["plan"].get("dtypes") is None
    assert entry["meta"]["rejected"]  # the rejection is on the record
    # ...and none of the timed (surviving) candidates carried the policy
    assert all("dt=" not in d for d in info["timings_us"])


def test_tuner_passes_policy_candidate_within_gate(tune_env, rng):
    """Under its default (per-policy) gate the bf16 twin of a benign
    elementwise graph survives probing and is timed."""
    _, fx = _mk("x", 3, SOA, rng)
    cfg = TargetConfig("pallas", vvl=64)
    g = LaunchGraph("dt_probe2").add(
        lambda v: {"t": 2.0 * v["x"]}, {"x": "x"}, {"t": 3})
    plan, info = tune.autotune_graph(
        g, {"x": fx}, config=cfg, iters=1, warmup=0, max_candidates=6)
    assert any("dt=bf16" in d for d in info["timings_us"]), info
    assert not info["rejected"]


# -- policy-aware planning models ---------------------------------------------

def test_vmem_estimate_and_traffic_model_are_policy_aware():
    in_views = ((19, 1, 4), (3, 0, 4))
    out_views = ((19, 4),)
    lat = (8, 14, 16)
    base = plan_mod.LoweringPlan("pallas", bx=1)
    pol = dataclasses.replace(base, dtypes=BF16)
    fp_base = plan_mod.estimate_vmem_bytes(
        base, lattice=lat, in_views=in_views, out_views=out_views)
    fp_pol = plan_mod.estimate_vmem_bytes(
        pol, lattice=lat, in_views=in_views, out_views=out_views)
    assert fp_pol < fp_base
    # the traffic model halves exactly with the bf16 itemsize
    g = _dot_graph()
    bm = g.bytes_moved({"x": 3, "y": 3}, 128, outputs=("t", "dot"))
    bm_pol = g.bytes_moved({"x": 3, "y": 3}, 128, outputs=("t", "dot"),
                           dtypes=BF16)
    assert bm_pol["fused"] * 2 == bm["fused"]
    assert bm_pol["unfused"] * 2 == bm["unfused"]
    # choose_tiles under the same budget can afford bigger (or equal)
    # tiles when each element costs half the bytes
    budget = fp_base // 2
    by_b, bz_b = plan_mod.choose_tiles(lat, 1, in_views=in_views,
                                       out_views=out_views,
                                       vmem_bytes=budget)
    by_p, bz_p = plan_mod.choose_tiles(lat, 1, in_views=in_views,
                                       out_views=out_views,
                                       vmem_bytes=budget, dtypes=BF16)
    assert (by_p or lat[1]) * (bz_p or lat[2]) >= \
        (by_b or lat[1]) * (bz_b or lat[2])


# -- solver knobs: MILC refined CG and Ludwig's LB storage --------------------

def test_milc_bf16_storage_refined_solve_hits_tolerance(rng):
    """MilcConfig.storage="bfloat16": per-iteration operator launches move
    bf16 bytes, iterative-refinement restarts recover the fp32 working
    tolerance — the solution matches the full-precision solve and the
    independent residual check passes at 1e-5."""
    from repro.apps.milc import MilcConfig, init_problem
    from repro.apps.milc.driver import residual_check, solve

    base = MilcConfig(lattice=(4, 4, 4, 8), kappa=0.1, tol=1e-10,
                      target=TargetConfig("jnp", vvl=128))
    u, b = init_problem(base, seed=0)
    ref = solve(base, u, b)
    cfg_b = dataclasses.replace(base, storage="bfloat16")
    res = solve(cfg_b, u, b)
    assert res.x.data.dtype == ref.x.data.dtype  # carry dtype is fixed
    rel = (np.linalg.norm(res.x.to_numpy().astype(np.float64)
                          - ref.x.to_numpy())
           / np.linalg.norm(ref.x.to_numpy()))
    # both solves stagnate at the f32 working-precision floor (x64 off),
    # just not at the same point: measured rel 1.3e-5, dominated by the
    # REFERENCE's own error — its residual is 8.7e-6 while the refined
    # bf16 solve's true-residual restarts land at 6.7e-7
    assert rel < 5e-5, rel
    assert residual_check(cfg_b, u, b, res.x) < 5e-6
    assert int(res.iterations) <= 4 * int(ref.iterations)


def test_ludwig_storage_knob_vs_full_precision_oracle(rng):
    """LudwigConfig.storage: float32 storage is a bitwise no-op on fp32
    fields; bfloat16 stays within the documented quantization envelope of
    the full-precision oracle over several steps."""
    from repro.apps.ludwig import LudwigConfig, init_state
    from repro.apps.ludwig.driver import step

    base = LudwigConfig(lattice=(8, 8, 8), target=TargetConfig("jnp"))
    states = {}
    for storage in ("", "float32", "bfloat16"):
        cfg = dataclasses.replace(base, storage=storage)
        s = init_state(cfg, seed=0)
        for _ in range(3):
            s = step(s, cfg)
        states[storage] = s
    ref = states[""]
    np.testing.assert_array_equal(
        states["float32"].dist.to_numpy(), ref.dist.to_numpy())
    np.testing.assert_array_equal(
        states["float32"].q.to_numpy(), ref.q.to_numpy())
    for f in ("dist", "q"):
        a = getattr(states["bfloat16"], f).to_numpy().astype(np.float64)
        r = getattr(ref, f).to_numpy().astype(np.float64)
        assert getattr(states["bfloat16"], f).data.dtype == jnp.float32
        assert np.linalg.norm(a - r) / np.linalg.norm(r) < 1e-2


def test_tuned_bf16_winner_drives_refined_solve(tune_env, rng):
    """Acceptance: a RECORDED bf16-storage winner (persisted through the
    gated sweep) drives a full MILC CG solve under plan_policy="tuned" to
    the working tolerance, with the policy'd operator launches moving
    half the modeled HBM bytes of the policy-free ones (asserted from the
    telemetry launch spans)."""
    from repro.apps.milc import MilcConfig, init_problem
    from repro.apps.milc.cg import wilson_normal_graph
    from repro.apps.milc.driver import residual_check, solve

    tgt = TargetConfig("pallas", vvl=16)
    cfg = MilcConfig(lattice=(4, 4, 4, 4), kappa=0.08, tol=1e-10,
                     max_iter=200, storage="bfloat16", target=tgt)
    u, b = init_problem(cfg, seed=0)
    g = wilson_normal_graph(float(cfg.kappa))

    # decisive fake timings (the accuracy gate still probes for real):
    # the bf16 twin is 2x faster, so the sweep records it as the winner
    def fake_sweep(graph, ins, launch_kw, cands, iters, warmup):
        return {c: (50e-6 if c.dtypes else 100e-6) for c in cands}, {}

    orig_sweep = tune._sweep  # NOT monkeypatch: undo() would also strip
    tune._sweep = fake_sweep  # tune_env's TARGETDP_TUNE_PATH setenv
    try:
        plan, info = tune.autotune_graph(
            g, {"p": b, "u": u}, config=tgt, outputs=("ap", "pap"))
    finally:
        tune._sweep = orig_sweep
    assert plan.dtypes and plan.dtypes.tag() == "bf16:f32:f64", info
    assert not info["rejected"].get(plan.describe())

    tuned_cfg = dataclasses.replace(
        cfg, target=dataclasses.replace(tgt, plan_policy="tuned",
                                        telemetry=True))
    telemetry.enable()
    try:
        telemetry.reset()
        tune.clear_table_cache()
        tune.reset_stats()
        res = solve(tuned_cfg, u, b)
        jax.block_until_ready(res.x.data)
        spans = telemetry.events("launch/")
        # read BEFORE the reset below: the tune counters live in the
        # telemetry registry
        tune_stats = dict(tune.stats())
    finally:
        telemetry.reset()
        telemetry.disable()
    assert tune_stats["hits"] >= 1, tune_stats
    assert residual_check(tuned_cfg, u, b, res.x) < 1e-5
    # the policy'd operator spans move half the bytes of the policy-free
    # true-residual (hi) operator spans of the SAME graph
    pol = {s["attrs"]["bytes_fused"] for s in spans
           if "dt=bf16" in s["attrs"].get("plan", "")
           and "bytes_fused" in s["attrs"]}
    ref = {s["attrs"]["bytes_fused"] for s in spans
           if "dt=" not in s["attrs"].get("plan", "")
           and "bytes_fused" in s["attrs"]}
    assert pol and ref, spans
    assert min(pol) * 2 in ref, (pol, ref)
