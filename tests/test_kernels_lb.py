"""LB collision/propagation: pallas-vs-oracle sweeps (shapes, dtypes,
layouts) + physical invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AOS, SOA, Field, TargetConfig, aosoa
from repro.kernels.lb_collision import collide
from repro.kernels.lb_collision import ref as lbref
from repro.kernels.lb_propagation import propagate
from repro.kernels.lb_propagation import ref as propref
from repro.kernels.lb_propagation.ops import collide_propagate
from repro.kernels.lb_propagation.kernel import propagate_pallas
from repro.core import stencil
from repro.maths import d3q19


def _fields(lat, lay, rng, dtype=np.float32):
    f0 = (1.0 + 0.1 * rng.normal(size=(19, *lat))).astype(dtype)
    frc = (0.01 * rng.normal(size=(3, *lat))).astype(dtype)
    return (f0, frc,
            Field.from_numpy("dist", f0, lat, lay, dtype=jnp.dtype(dtype)),
            Field.from_numpy("force", frc, lat, lay, dtype=jnp.dtype(dtype)))


# (4, 4, 8) = 128 sites: one vvl=128 block; (4, 4, 16) = 256 sites: grid of
# two blocks — the smallest shapes that exercise vvl > 1 and a multi-block
# grid (the seed's (8, 8, 16) sweep bought nothing but runtime).
@pytest.mark.parametrize("lay", [SOA, AOS, aosoa(32), aosoa(128)],
                         ids=lambda l: l.name)
@pytest.mark.parametrize("lat", [(4, 4, 8), (4, 4, 16)], ids=str)
def test_collision_pallas_vs_oracle(lay, lat, rng):
    f0, frc, d, g = _fields(lat, lay, rng)
    o_ref = collide(d, g, tau=0.8, config=TargetConfig("jnp")).to_numpy()
    o_pl = collide(d, g, tau=0.8,
                   config=TargetConfig("pallas", vvl=128)).to_numpy()
    np.testing.assert_allclose(o_pl, o_ref, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("tau", [0.6, 0.8, 1.0, 1.7])
def test_collision_conserves_mass_and_momentum(tau, rng):
    lat = (8, 8, 8)
    f0, frc, d, g = _fields(lat, SOA, rng)
    out = collide(d, g, tau=tau, config=TargetConfig("jnp"))
    o = out.to_numpy()
    # mass: sum_i f'_i == rho  (Guo forcing is mass-conserving)
    np.testing.assert_allclose(o.sum(0), f0.sum(0), rtol=1e-5)
    # momentum: sum_i c_i f'_i == rho u + F/2 + (1-1/2tau)F ... net change F
    cv = np.asarray(d3q19.CV, np.float32)
    mom_in = np.einsum("ia,i...->a...", cv, f0)
    mom_out = np.einsum("ia,i...->a...", cv, o)
    np.testing.assert_allclose(mom_out - mom_in, frc, rtol=5e-2, atol=1e-5)


def test_collision_fixed_point(rng):
    """Equilibrium at rest with no force is a fixed point."""
    lat = (4, 4, 4)
    nsites = int(np.prod(lat))
    rho = jnp.ones((nsites,))
    u = jnp.zeros((3, nsites))
    feq = lbref.equilibrium(rho, u)
    d = Field.from_canonical("dist", feq, lat, SOA)
    g = Field.zeros("force", 3, lat, SOA)
    out = collide(d, g, tau=0.8, config=TargetConfig("jnp"))
    np.testing.assert_allclose(out.to_numpy(),
                               np.asarray(feq).reshape(19, *lat), atol=1e-7)


@pytest.mark.parametrize("lat", [(4, 4, 8), (6, 10, 8)], ids=str)
def test_propagation_pallas_vs_oracle(lat, rng):
    f0 = rng.normal(size=(19, *lat)).astype(np.float32)
    d = Field.from_numpy("dist", f0, lat, SOA)
    o_ref = propagate(d, config=TargetConfig("jnp")).to_numpy()
    o_pl = propagate(d, config=TargetConfig("pallas")).to_numpy()
    np.testing.assert_allclose(o_pl, o_ref, rtol=1e-6)
    # semantic spot-checks: f'_i(r + c_i) = f_i(r)
    for i in [1, 4, 7, 18]:
        c = d3q19.CV[i]
        src = (2, 3, 4)
        dst = tuple((np.array(src) + c) % np.array(lat))
        assert abs(o_ref[(i,) + dst] - f0[(i,) + src]) < 1e-6


@pytest.mark.parametrize("lay", [SOA, aosoa(32)], ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_fused_step_matches_reference(lay, engine, rng):
    """End-to-end fused collide->propagate step vs the unfused jnp oracle."""
    lat = (4, 4, 8)
    f0, frc, d, g = _fields(lat, lay, rng)
    cfg = TargetConfig(engine, vvl=128)
    got = collide_propagate(d, g, tau=0.8, config=cfg).to_numpy()
    want = np.asarray(propref.propagate_ref(
        lbref.collide_ref(jnp.asarray(f0.reshape(19, -1)),
                          jnp.asarray(frc.reshape(3, -1)),
                          0.8).reshape(19, *lat)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_propagation_halo_matches_periodic(rng):
    lat = (6, 6, 6)
    f0 = rng.normal(size=(19, *lat)).astype(np.float32)
    fh = stencil.halo_pad(jnp.asarray(f0), 1, (1, 2, 3))
    out_h = np.asarray(propref.propagate_halo_ref(fh, 1))
    out_p = np.asarray(propref.propagate_ref(jnp.asarray(f0)))
    np.testing.assert_allclose(out_h, out_p, rtol=1e-6)
    out_k = np.asarray(propagate_pallas(fh, width=1, interpret=True))
    np.testing.assert_allclose(out_k, out_p, rtol=1e-6)
