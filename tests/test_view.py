"""Native AoSoA stencil lowering (``LoweringPlan.view == "block"``).

The paper's central lever is switching the data layout per architecture
without touching kernel bodies (§3.1); these tests pin the contract that
makes that lever reach halo'd stencil chains: a stencil launch under the
native-block view is **bit-identical** to the same launch under the
staged-nd view — on every halo strategy (periodic / pre / overlap), for
the production graphs (the fused LB step and the fused Wilson normal
operator) and for mixed-layout inputs — while the physical AoSoA arrays
never round-trip through an XLA pack/unpack.  Plan-layer satellites: view
candidates are emitted only for AoSoA inputs, the default policy stays
staged-nd (bit-compat with pre-PR behavior), describe()/persisted tune
entries record the view, and plan keys keyed on different layouts never
share tuned winners.
"""

import numpy as np
import pytest

from repro.core import (
    Field, LaunchGraph, LoweringPlan, SOA, TargetConfig, aosoa, fuse,
)
from repro.core import plan as plan_mod
from repro.core.plan import VIEW_BLOCK, VIEW_STAGED_ND
from repro.core.stencil import halo_pad

PCFG = TargetConfig("pallas", vvl=128)


def _scale_body(v, *, a):
    return {"y": a * v["x"]}


def _lap_body(v, gather, *, c):
    return {"z": c * v["y"] + gather("y", (1, 0, 0)) + gather("y", (-1, 0, 0))}


def _graph():
    return (LaunchGraph("view_g")
            .add(_scale_body, {"x": "x"}, {"y": 3}, params=dict(a=2.0))
            .add_stencil(_lap_body, {"y": "y"}, {"z": 3}, width=1,
                         params=dict(c=-2.0))
            .add_reduce("z", op="sum", name="zt"))


def _plans(bx, halo="periodic"):
    staged = LoweringPlan("pallas", bx=bx, halo=halo, interpret=True,
                          view=VIEW_STAGED_ND)
    return staged, LoweringPlan("pallas", bx=bx, halo=halo, interpret=True,
                                view=VIEW_BLOCK)


# -- bit-identity: block view == staged-nd view --------------------------------

@pytest.mark.parametrize("sal", [2, 4])
@pytest.mark.parametrize("bx", [1, 2, 3])
def test_block_matches_staged_periodic(sal, bx, rng):
    """Single-shard periodic: field output (physical array!) and on-chip
    reduction are bitwise equal across views."""
    lat = (6, 4, 8)  # padded inner 6*10=60; sal 2,4 divide 60 and inner 32
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    fx = Field.from_numpy("x", x, lat, aosoa(sal))
    g = _graph()
    staged, block = _plans(bx)
    a = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt"), plan=staged)
    b = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt"), plan=block)
    assert b["z"].layout == aosoa(sal)
    np.testing.assert_array_equal(np.asarray(a["z"].data),
                                  np.asarray(b["z"].data))
    np.testing.assert_array_equal(np.asarray(a["zt"]), np.asarray(b["zt"]))
    # and both equal the jnp-engine oracle
    j = g.launch({"x": fx}, config=TargetConfig("jnp"), outputs=("z", "zt"))
    np.testing.assert_allclose(b["z"].to_numpy(), j["z"].to_numpy(),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("halo", ["pre", "overlap"])
def test_block_matches_staged_pre_and_overlap(halo, rng):
    """Pre-exchanged inputs (the sharded drivers' contract): the native
    view stages the caller's physical AoSoA array as-is; overlap splits
    into staged sub-launches and assembles back into AoSoA — all bitwise
    equal to the staged-nd single launch."""
    import jax.numpy as jnp

    lat = (6, 4, 8)
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    xh = np.asarray(halo_pad(jnp.asarray(x), 1, (1, 2, 3)))
    plat = tuple(s + 2 for s in lat)   # inner_h = 6*10 = 60
    fxh = Field.from_numpy("x", xh, plat, aosoa(4))
    g = _graph()
    staged, block = _plans(2, halo=halo)
    a = g.launch({"x": fxh}, config=PCFG, outputs=("z", "zt"), halo=halo,
                 plan=staged)
    b = g.launch({"x": fxh}, config=PCFG, outputs=("z", "zt"), halo=halo,
                 plan=block)
    assert b["z"].layout == aosoa(4)
    np.testing.assert_array_equal(np.asarray(a["z"].data),
                                  np.asarray(b["z"].data))


@pytest.mark.parametrize("sal", [4, 8, 16])
def test_lb_step_block_matches_staged(sal, rng):
    """The production fused LB step (moments+collide+propagate, the paper's
    hottest launch) under native AoSoA at hardware-ish SALs."""
    from repro.kernels.lb_propagation.ops import collide_propagate_graph

    lat = (4, 14, 16)  # inner 224, padded inner 16*18=288: 4/8/16 all align
    f0 = (1.0 + 0.1 * rng.normal(size=(19, *lat))).astype(np.float32)
    frc = (0.01 * rng.normal(size=(3, *lat))).astype(np.float32)
    d = Field.from_numpy("dist", f0, lat, aosoa(sal))
    frcF = Field.from_numpy("force", frc, lat, aosoa(sal))
    g = collide_propagate_graph(0.8)
    staged, block = _plans(2)
    fuse.clear_cache()
    fuse.reset_stats()
    a = g.launch({"dist": d, "force": frcF}, config=PCFG,
                 outputs=("dist2",), plan=staged)
    b = g.launch({"dist": d, "force": frcF}, config=PCFG,
                 outputs=("dist2",), plan=block)
    np.testing.assert_array_equal(np.asarray(a["dist2"].data),
                                  np.asarray(b["dist2"].data))
    # each view is its own single fused pallas_call and its own cache entry
    s = fuse.stats()
    assert s["pallas_calls"] == 2 and s["cache_misses"] == 2, s


def test_wilson_normal_block_matches_staged():
    """The fused MILC normal operator (2 dslash stencils + reduction):
    4-D lattice, ring-2 halos, 72-component gauge input."""
    from repro.apps.milc import MilcConfig, init_problem
    from repro.apps.milc.cg import wilson_normal_graph

    cfg = MilcConfig(lattice=(4, 4, 4, 4), kappa=0.1, layout=aosoa(8))
    u, b = init_problem(cfg, seed=0)  # inner 64, padded inner 512: 8 aligns
    g = wilson_normal_graph(cfg.kappa)
    staged, block = _plans(2)
    a = g.launch({"p": b, "u": u}, config=PCFG, outputs=("ap", "pap"),
                 plan=staged)
    o = g.launch({"p": b, "u": u}, config=PCFG, outputs=("ap", "pap"),
                 plan=block)
    np.testing.assert_array_equal(np.asarray(a["ap"].data),
                                  np.asarray(o["ap"].data))
    np.testing.assert_array_equal(np.asarray(a["pap"]), np.asarray(o["pap"]))


def test_mixed_layouts_native_and_staged_inputs(rng):
    """AoSoA + SOA inputs in one block-view launch: the AoSoA input goes
    native, the SOA input stages canonically, outputs land per out_layouts
    (native AoSoA output next to a packed SOA output)."""
    lat = (6, 4, 8)
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    f = (0.1 * rng.normal(size=(3, *lat))).astype(np.float32)
    fx = Field.from_numpy("x", x, lat, aosoa(4))
    ff = Field.from_numpy("f", f, lat, SOA)
    g = (LaunchGraph("mixed")
         .add(lambda v: {"y": v["x"] + v["f"]}, {"x": "x", "f": "f"},
              {"y": 3})
         .add_stencil(_lap_body, {"y": "y"}, {"z": 3}, width=1,
                      params=dict(c=0.5)))
    staged, block = _plans(3)
    layouts = {"z": SOA}
    a = g.launch({"x": fx, "f": ff}, config=PCFG, outputs=("z",),
                 out_layouts=layouts, plan=staged)
    b = g.launch({"x": fx, "f": ff}, config=PCFG, outputs=("z",),
                 out_layouts=layouts, plan=block)
    assert b["z"].layout == SOA
    np.testing.assert_array_equal(np.asarray(a["z"].data),
                                  np.asarray(b["z"].data))
    # flip the output native too
    a2 = g.launch({"x": fx, "f": ff}, config=PCFG, outputs=("z",),
                  out_layouts={"z": aosoa(4)}, plan=staged)
    b2 = g.launch({"x": fx, "f": ff}, config=PCFG, outputs=("z",),
                  out_layouts={"z": aosoa(4)}, plan=block)
    np.testing.assert_array_equal(np.asarray(a2["z"].data),
                                  np.asarray(b2["z"].data))


# -- alignment / eligibility errors --------------------------------------------

def test_block_view_misaligned_sal_raises(rng):
    """SAL not dividing the halo'd inner-plane count: a clear error naming
    the input, not silent corruption."""
    lat = (6, 4, 8)  # padded inner 60; sal=8 does not divide
    fx = Field.from_numpy(
        "x", rng.normal(size=(3, *lat)).astype(np.float32), lat, aosoa(8))
    _, block = _plans(2)
    with pytest.raises(ValueError, match="halo'd inner-plane"):
        _graph().launch({"x": fx}, config=PCFG, outputs=("z",), plan=block)


def test_block_view_without_aosoa_raises_loudly(rng):
    """No AoSoA in play: an *explicit* block view fails validation (there
    is no native lowering to run), both standalone and at launch."""
    lat = (6, 4, 8)
    fx = Field.from_numpy(
        "x", rng.normal(size=(3, *lat)).astype(np.float32), lat, SOA)
    _, block = _plans(2)
    with pytest.raises(ValueError, match="AoSoA"):
        block.validate(lattice=lat, stencil=True, layouts=[SOA])
    with pytest.raises(ValueError, match="AoSoA"):
        _graph().launch({"x": fx}, config=PCFG, outputs=("z",), plan=block)


def test_legacy_plans_without_view_resolve_to_staged(rng):
    """Backward compat: a hand-built plan that never set view= (the
    dataclass default is the 'auto' sentinel) launches exactly as it did
    before views became a stencil knob — the staged-nd lowering — on SOA
    inputs, on aligned AoSoA inputs (no silent strategy flip), and on
    *misaligned* AoSoA inputs where an explicit block view would be
    rejected."""
    g = _graph()
    legacy = LoweringPlan("pallas", bx=2, interpret=True)  # view defaulted
    staged, block = _plans(2)
    lat = (6, 4, 8)
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    for lay in (SOA, aosoa(4), aosoa(8)):  # aosoa8: halo'd inner 60 % 8 != 0
        fx = Field.from_numpy("x", x, lat, lay)
        a = g.launch({"x": fx}, config=PCFG, outputs=("z",), plan=legacy)
        b = g.launch({"x": fx}, config=PCFG, outputs=("z",), plan=staged)
        np.testing.assert_array_equal(np.asarray(a["z"].data),
                                      np.asarray(b["z"].data),
                                      err_msg=lay.name)
    # ... while the explicit block twin on the misaligned layout refuses
    with pytest.raises(ValueError, match="halo'd inner-plane"):
        g.launch({"x": Field.from_numpy("x", x, lat, aosoa(8))},
                 config=PCFG, outputs=("z",), plan=block)


def test_block_view_misaligned_output_raises(rng):
    """Aligned AoSoA input but an AoSoA output whose SAL splits the interior
    slab rows: rejected with the output named."""
    lat = (6, 4, 8)  # interior inner 32: sal=8 ok for input? 60 % 8 != 0 ->
    # use sal 4 input (aligns) and sal 3 output (32 % 3 != 0)
    fx = Field.from_numpy(
        "x", rng.normal(size=(3, *lat)).astype(np.float32), lat, aosoa(4))
    _, block = _plans(2)
    with pytest.raises(ValueError, match="interior inner-plane"):
        _graph().launch({"x": fx}, config=PCFG, outputs=("z",),
                        out_layouts={"z": aosoa(3)}, plan=block)


# -- planning layer ------------------------------------------------------------

def test_candidate_view_twins_only_for_aosoa_inputs():
    """candidate_plans emits view='block' twins iff an input layout is
    AoSoA; the default (first) candidate is always staged-nd, so the
    default policy is untouched."""
    lat = (8, 4, 8)
    nsites = 8 * 4 * 8
    cfg = TargetConfig("pallas", vvl=128)
    with_a = plan_mod.candidate_plans(
        cfg, nsites=nsites, layouts=[aosoa(4)], stencil=True, lattice=lat)
    assert any(c.view == VIEW_BLOCK for c in with_a)
    assert with_a[0].view == VIEW_STAGED_ND  # default heuristic unchanged
    without = plan_mod.candidate_plans(
        cfg, nsites=nsites, layouts=[SOA], stencil=True, lattice=lat)
    assert not any(c.view == VIEW_BLOCK for c in without)
    # explicit gate overrides the layout heuristic
    gated = plan_mod.candidate_plans(
        cfg, nsites=nsites, layouts=[aosoa(4)], stencil=True, lattice=lat,
        block_view=False)
    assert not any(c.view == VIEW_BLOCK for c in gated)


def test_plan_candidates_for_skips_misaligned_block(rng):
    """tune.plan_candidates_for consults the real halo geometry: an AoSoA
    input whose SAL cannot tile the halo'd planes gets no block twins
    (rather than guaranteed-failing sweep candidates)."""
    from repro.core import tune

    lat = (6, 4, 8)
    g = _graph()
    aligned = {"x": Field.from_numpy(
        "x", rng.normal(size=(3, *lat)).astype(np.float32), lat, aosoa(4))}
    cands = tune.plan_candidates_for(g, aligned, config=PCFG,
                                     outputs=("z", "zt"))
    assert any(c.view == VIEW_BLOCK for c in cands)
    misaligned = {"x": aligned["x"].as_layout(aosoa(8))}  # 8 does not
    cands = tune.plan_candidates_for(g, misaligned, config=PCFG,  # divide 60
                                     outputs=("z", "zt"))
    assert not any(c.view == VIEW_BLOCK for c in cands)


def test_default_policy_stays_staged_nd(rng):
    """Bit-compat guard: with no plan given, an AoSoA stencil launch takes
    the pre-PR staged-nd lowering (view twins are tuner candidates, never
    the default)."""
    lat = (6, 4, 8)
    plan = plan_mod.default_plan(
        TargetConfig("pallas", vvl=64), nsites=6 * 4 * 8,
        layouts=[aosoa(4)], stencil=True, lattice=lat, halo="periodic")
    assert plan.view == VIEW_STAGED_ND


def test_adapt_plan_preserves_stencil_view():
    """A tuned/explicit native-block winner survives adapt_plan (this is
    how the persisted table flips a launch to native AoSoA); jnp stencil
    plans and site-local plans keep their forced views."""
    block = LoweringPlan("pallas", bx=2, halo="pre", view=VIEW_BLOCK)
    assert plan_mod.adapt_plan(block, stencil=True, halo="pre").view \
        == VIEW_BLOCK
    staged = LoweringPlan("pallas", bx=2, halo="pre", view=VIEW_STAGED_ND)
    assert plan_mod.adapt_plan(staged, stencil=True, halo="pre").view \
        == VIEW_STAGED_ND
    jplan = LoweringPlan("jnp", view=VIEW_BLOCK)
    assert plan_mod.adapt_plan(jplan, stencil=True, halo="periodic").view \
        == VIEW_STAGED_ND
    site = LoweringPlan("pallas", vvl=8, view=VIEW_STAGED_ND)
    assert plan_mod.adapt_plan(site, stencil=False, halo="periodic").view \
        == VIEW_BLOCK
    # the 'auto' dataclass default resolves to the pre-view-knob behavior
    auto = LoweringPlan("pallas", bx=2)
    assert auto.view == plan_mod.VIEW_AUTO
    assert plan_mod.adapt_plan(auto, stencil=True, halo="periodic").view \
        == VIEW_STAGED_ND
    assert plan_mod.adapt_plan(auto, stencil=False, halo="periodic").view \
        == VIEW_BLOCK


def test_sub_lattice_plan_forces_staged_nd():
    """Overlap sub-launch windows are SOA slices: the rebased slab plan
    must never claim the native-block view."""
    outer = LoweringPlan("pallas", bx=2, halo="overlap", view=VIEW_BLOCK)
    sub = plan_mod.sub_lattice_plan(outer, TargetConfig("pallas"), (4, 4, 8),
                                    halo="pre")
    assert sub.view == VIEW_STAGED_ND and sub.halo == "pre"


def test_describe_and_persisted_entry_record_view(tmp_path, monkeypatch):
    """Auditable winners: describe() tags native-block plans and a recorded
    tune-table entry round-trips the view."""
    from repro.core import tune

    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "t.json"))
    tune.clear_table_cache()
    staged = LoweringPlan("pallas", bx=4, view=VIEW_STAGED_ND)
    block = LoweringPlan("pallas", bx=4, view=VIEW_BLOCK)
    assert staged.describe() != block.describe()
    assert "block" in block.describe()
    tune.record("k_view", block)
    tune.clear_table_cache()  # fresh-process view of the table
    got = tune.lookup("k_view")
    assert got == block and got.view == VIEW_BLOCK


def test_plan_key_distinguishes_layout_views(rng, tmp_path, monkeypatch):
    """A table tuned on one layout must not silently apply to another:
    plan keys incorporate the input layouts, so an AoSoA-keyed native-block
    winner misses for the SOA twin of the same launch."""
    from repro.core import tune

    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "t.json"))
    tune.clear_table_cache()
    lat = (6, 4, 8)
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    fa = Field.from_numpy("x", x, lat, aosoa(4))
    g = _graph()
    key_a = g.plan_key({"x": fa}, config=PCFG, outputs=("z", "zt"))
    key_s = g.plan_key({"x": fa.as_layout(SOA)}, config=PCFG,
                       outputs=("z", "zt"))
    assert key_a != key_s
    tune.record(key_a, LoweringPlan("pallas", bx=2, interpret=True,
                                    view=VIEW_BLOCK))
    assert tune.lookup(key_a) is not None
    assert tune.lookup(key_s) is None


def test_tuned_block_winner_degrades_on_misfit(rng, tmp_path, monkeypatch):
    """Tuning must never break a launch: a persisted native-block winner
    meeting an out_layouts override whose SAL cannot tile the interior
    degrades to the default plan (logged), instead of raising."""
    from repro.core import tune

    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "t.json"))
    tune.clear_table_cache()
    lat = (6, 4, 8)
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    fx = Field.from_numpy("x", x, lat, aosoa(4))
    g = _graph()
    _, block = _plans(2)
    key = g.plan_key({"x": fx}, config=PCFG, outputs=("z", "zt"),
                     lattice=lat)
    tune.record(key, block)
    tuned_cfg = TargetConfig("pallas", vvl=128, plan_policy="tuned")
    bad_out = {"z": aosoa(3)}  # 3 does not divide the interior inner 32
    got = g.launch({"x": fx}, config=tuned_cfg, outputs=("z", "zt"),
                   out_layouts=bad_out)
    want = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt"),
                    out_layouts=bad_out)
    np.testing.assert_array_equal(np.asarray(got["z"].data),
                                  np.asarray(want["z"].data))
    # an *explicit* misfit plan still fails loudly
    with pytest.raises(ValueError, match="interior inner-plane"):
        g.launch({"x": fx}, config=PCFG, outputs=("z", "zt"),
                 out_layouts=bad_out, plan=block)


def test_tuned_policy_applies_block_winner(rng, tmp_path, monkeypatch):
    """plan_policy='tuned' + a persisted native-block winner: the launch
    executes under the block view (probed via the launch cache — an
    explicit block-plan launch afterwards is a cache HIT, a staged one a
    miss) and stays bit-identical to the default policy."""
    from repro.core import tune

    monkeypatch.setenv(tune.ENV_VAR, str(tmp_path / "t.json"))
    tune.clear_table_cache()
    lat = (6, 4, 8)
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    fx = Field.from_numpy("x", x, lat, aosoa(4))
    g = _graph()
    staged, block = _plans(2)
    key = g.plan_key({"x": fx}, config=PCFG, outputs=("z", "zt"))
    tune.record(key, block)

    tuned_cfg = TargetConfig("pallas", vvl=128, plan_policy="tuned")
    fuse.clear_cache()
    fuse.reset_stats()
    t = g.launch({"x": fx}, config=tuned_cfg, outputs=("z", "zt"))
    assert fuse.stats()["cache_misses"] == 1
    g.launch({"x": fx}, config=PCFG, outputs=("z", "zt"), plan=block)
    assert fuse.stats()["cache_hits"] == 1  # tuned launch == block view
    d = g.launch({"x": fx}, config=PCFG, outputs=("z", "zt"), plan=staged)
    np.testing.assert_array_equal(np.asarray(t["z"].data),
                                  np.asarray(d["z"].data))
