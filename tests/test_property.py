"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    Field, LaunchGraph, LoweringPlan, SOA, TargetConfig, aosoa, target_sum,
)
from repro.core import plan as plan_mod
from repro.core import stencil as stencil_mod
from repro.kernels.lb_collision import collide
from repro.kernels.rwkv6_scan import rwkv6
from repro.models import moe as moe_mod
from repro.configs.base import MoECfg
from repro.train.optimizer import _dq8, _q8

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@given(
    sal=st.sampled_from([1, 2, 4, 8]),
    nblk=st.integers(1, 6),
    ncomp=st.integers(1, 7),
    seed=st.integers(0, 100),
)
def test_layout_roundtrip_property(sal, nblk, ncomp, seed):
    lay = aosoa(sal)
    nsites = nblk * sal
    x = np.random.default_rng(seed).normal(size=(ncomp, nsites)).astype(np.float32)
    back = np.asarray(lay.unpack(lay.pack(jnp.asarray(x))))
    np.testing.assert_array_equal(back, x)


@given(
    sal=st.sampled_from([1, 2, 4, 8]),
    ncomp=st.integers(1, 5),
    width=st.integers(1, 2),
    nx=st.integers(1, 5),
    ny=st.integers(1, 6),
    k=st.integers(1, 4),
    seed=st.integers(0, 100),
)
def test_halo_pad_physical_cross_layout_property(sal, ncomp, width, nx, ny,
                                                 k, seed):
    """halo_pad on the physical AoSoA array == halo_pad on the canonical
    view, at awkward extents: halo width > 1, odd slabs, and SALs that do
    NOT divide the halo'd site count — where re-blocking is impossible and
    a clear error (never silent corruption) is the contract."""
    lat = (nx, ny, sal * k)   # sal | nsites by construction
    nsites = nx * ny * sal * k
    lay = aosoa(sal)
    x = np.random.default_rng(seed).normal(
        size=(ncomp, nsites)).astype(np.float32)
    phys = lay.pack(jnp.asarray(x))
    nd = jnp.asarray(x).reshape((ncomp,) + lat)
    want = np.asarray(stencil_mod.halo_pad(nd, width, (1, 2, 3)))
    padded_sites = int(np.prod([s + 2 * width for s in lat]))
    if padded_sites % sal:
        with pytest.raises(ValueError, match="sal must divide"):
            stencil_mod.halo_pad_physical(phys, lay, ncomp, lat, width)
        return
    got_phys = stencil_mod.halo_pad_physical(phys, lay, ncomp, lat, width)
    got = np.asarray(lay.unpack(got_phys)).reshape(want.shape)
    np.testing.assert_array_equal(got, want)


@given(
    sal=st.sampled_from([2, 4]),
    width=st.integers(1, 2),
    nx=st.integers(1, 5),
    a=st.integers(1, 4),
    b=st.integers(2, 8),
    seed=st.integers(0, 50),
)
def test_block_view_stencil_matches_staged_property(sal, width, nx, a, b,
                                                    seed):
    """Native-AoSoA stencil lowering == staged-nd, bitwise, for arbitrary
    aligned geometries (odd x extents / single-plane slabs, halo width up
    to 2, SAL 2 and 4): the view is a data-movement knob, never a
    semantics knob."""
    lat = (nx, 2 * a, 2 * b)  # even inner planes: sal 2/4 always align
    x = np.random.default_rng(seed).normal(
        size=(2, *lat)).astype(np.float32)
    fx = Field.from_numpy("x", x, lat, aosoa(sal))

    def body(v, gather):
        out = v["x"] - gather("x", (width, 0, 0))
        return {"z": out + gather("x", (0, -width, 0))}

    g = LaunchGraph("prop_view").add_stencil(
        body, {"x": "x"}, {"z": 2}, width=width)
    cfg = TargetConfig("pallas", vvl=64)
    outs = []
    for view in ("staged-nd", "block"):
        plan = LoweringPlan("pallas", bx=1, interpret=True, view=view)
        outs.append(np.asarray(
            g.launch({"x": fx}, config=cfg, outputs=("z",),
                     plan=plan)["z"].data))
    np.testing.assert_array_equal(outs[0], outs[1])


@given(
    nx=st.integers(1, 4),
    a=st.integers(1, 3),
    b=st.integers(2, 6),
    width=st.integers(1, 2),
    pick=st.integers(0, 10 ** 6),
    seed=st.integers(0, 50),
)
def test_tile_geometry_property(nx, a, b, width, pick, seed):
    """Tiled stencil lowering, for random extents and halo widths: the
    tile cover enumerated by ``stencil.tile_boxes`` is exact and disjoint,
    every *dividing* (by, bz) pair lowers bitwise identical to the untiled
    whole-staging plan, and a non-dividing extent is a clear plan
    validation error (never silent corruption)."""
    import dataclasses
    import itertools

    lat = (nx, 2 * a, 2 * b)
    divs_y = [d for d in range(1, lat[1] + 1) if lat[1] % d == 0]
    divs_z = [d for d in range(1, lat[2] + 1) if lat[2] % d == 0]
    by = divs_y[pick % len(divs_y)]
    bz = divs_z[(pick // 7) % len(divs_z)]

    # exact disjoint cover, z-fastest enumeration
    boxes = stencil_mod.tile_boxes(lat, 1, by, bz)
    seen = set()
    for box in boxes:
        for pt in itertools.product(*[range(s, s + e) for s, e in box]):
            assert pt not in seen
            seen.add(pt)
    assert len(seen) == lat[0] * lat[1] * lat[2]

    # non-divisor => clear error from validate (and from tile_boxes)
    if lat[1] > 2:
        bad = dataclasses.replace(
            LoweringPlan("pallas", bx=1, by=lat[1] - 1))
        with pytest.raises(ValueError, match="by"):
            bad.validate(nsites=lat[0] * lat[1] * lat[2], lattice=lat,
                         stencil=True)

    # dividing tiles: bitwise identical to whole-staging
    x = np.random.default_rng(seed).normal(
        size=(2, *lat)).astype(np.float32)
    fx = Field.from_numpy("x", x, lat, SOA)

    def body(v, gather):
        out = v["x"] - gather("x", (width, 0, 0))
        return {"z": out + gather("x", (0, -width, 0))}

    g = LaunchGraph("prop_tile").add_stencil(
        body, {"x": "x"}, {"z": 2}, width=width)
    cfg = TargetConfig("pallas", vvl=64)
    base = LoweringPlan("pallas", bx=1, interpret=True)
    want = g.launch({"x": fx}, config=cfg, outputs=("z",), plan=base)
    got = g.launch({"x": fx}, config=cfg, outputs=("z",),
                   plan=dataclasses.replace(base, by=by, bz=bz))
    np.testing.assert_array_equal(np.asarray(want["z"].data),
                                  np.asarray(got["z"].data))


@given(
    sal=st.sampled_from([2, 4]),
    nx=st.integers(1, 3),
    a=st.integers(1, 3),
    pick=st.integers(0, 10 ** 6),
    seed=st.integers(0, 50),
)
def test_tile_block_view_sal_aligned_property(sal, nx, a, pick, seed):
    """view='block' composes with tiling: tile edges fall on whole short
    arrays by construction (the x-run rebase slices whole inner planes and
    the tile cut happens on the unpacked VMEM window), so every dividing
    tile is bitwise identical to the untiled native-block lowering —
    SAL-aligned edges are a non-event, not a constraint violation."""
    import dataclasses

    lat = (nx, 2 * a, 2 * sal)  # inner planes divisible by sal
    divs_y = [d for d in range(1, lat[1] + 1) if lat[1] % d == 0]
    by = divs_y[pick % len(divs_y)]
    x = np.random.default_rng(seed).normal(
        size=(2, *lat)).astype(np.float32)
    fx = Field.from_numpy("x", x, lat, aosoa(sal))

    def body(v, gather):
        return {"z": v["x"] + gather("x", (1, 0, 0))}

    g = LaunchGraph("prop_tile_blk").add_stencil(
        body, {"x": "x"}, {"z": 2}, width=1)
    cfg = TargetConfig("pallas", vvl=64)
    base = LoweringPlan("pallas", bx=1, interpret=True, view="block")
    want = g.launch({"x": fx}, config=cfg, outputs=("z",), plan=base)
    got = g.launch({"x": fx}, config=cfg, outputs=("z",),
                   plan=dataclasses.replace(base, by=by, bz=sal))
    np.testing.assert_array_equal(np.asarray(want["z"].data),
                                  np.asarray(got["z"].data))


@given(
    tau=st.floats(0.55, 2.0),
    seed=st.integers(0, 50),
)
def test_collision_mass_conservation_property(tau, seed):
    lat = (4, 4, 4)
    rng = np.random.default_rng(seed)
    f0 = (1.0 + 0.05 * rng.normal(size=(19, *lat))).astype(np.float32)
    frc = (0.01 * rng.normal(size=(3, *lat))).astype(np.float32)
    d = Field.from_numpy("d", f0, lat, SOA)
    g = Field.from_numpy("g", frc, lat, SOA)
    out = collide(d, g, tau=float(tau), config=TargetConfig("jnp")).to_numpy()
    np.testing.assert_allclose(out.sum(0), f0.sum(0), rtol=1e-5)


@given(
    t=st.sampled_from([8, 16, 32]),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 30),
)
def test_rwkv_chunked_matches_scan_property(t, chunk, seed):
    rng = np.random.default_rng(seed)
    B, H, dk, dv = 1, 2, 8, 8
    r = rng.normal(size=(B, H, t, dk)).astype(np.float32)
    k = (0.3 * rng.normal(size=(B, H, t, dk))).astype(np.float32)
    v = rng.normal(size=(B, H, t, dv)).astype(np.float32)
    w = np.exp(-np.exp(rng.normal(size=(B, H, t, dk)))).astype(np.float32)
    u = rng.normal(size=(H, dk)).astype(np.float32) * 0.5
    o1, s1 = rwkv6(r, k, v, w, u, engine="scan")
    o2, s2 = rwkv6(r, k, v, w, u, engine="jnp", chunk=chunk)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), rtol=3e-4,
                               atol=3e-4)


@given(seed=st.integers(0, 40))
def test_q8_error_bound_property(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=(4, 32)) * 10 ** rng.uniform(-3, 3))
                    .astype(np.float32))
    codes, scale = _q8(x)
    err = np.abs(np.asarray(_dq8(codes, scale)) - np.asarray(x))
    assert (err <= np.asarray(scale) * 0.5 + 1e-6).all()


@given(seed=st.integers(0, 25), topk=st.sampled_from([1, 2, 4]))
def test_moe_gates_normalized_and_capacity_respected(seed, topk):
    key = jax.random.PRNGKey(seed)
    B, S, d, E = 2, 16, 8, 8
    cfg = MoECfg(n_experts=E, top_k=topk, d_ff_expert=16,
                 capacity_factor=1.25)
    p = moe_mod.init_moe(key, d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d))
    y, aux = moe_mod.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert 0.0 <= float(aux["drop_frac"]) <= 1.0
    assert float(aux["lb_loss"]) > 0.0


@given(
    sal=st.sampled_from([1, 2, 4, 8, 16]),
    nblk=st.integers(1, 200),
    preferred=st.integers(1, 4096),
)
def test_candidate_plans_site_local_valid_property(sal, nblk, preferred):
    """Plan-layer invariant (paper §3.2.2 tuning knobs): for arbitrary
    (nsites, sal, preferred vvl), EVERY candidate LoweringPlan the
    autotuner may sweep satisfies vvl | nsites and sal | vvl."""
    nsites = sal * nblk
    layouts = [aosoa(sal)]
    cfg = TargetConfig("pallas", vvl=preferred)
    for c in plan_mod.candidate_plans(cfg, nsites=nsites, layouts=layouts):
        assert nsites % c.vvl == 0
        assert c.vvl % sal == 0
        c.validate(nsites=nsites, layouts=layouts, stencil=False)


@given(
    x_dim=st.integers(1, 128),
    ny=st.integers(1, 12),
    nz=st.integers(1, 12),
    preferred=st.integers(1, 4096),
)
def test_candidate_plans_stencil_valid_property(x_dim, ny, nz, preferred):
    """For arbitrary lattice extents, every stencil candidate's x-slab
    divides the leading dim (bx | x_dim)."""
    lattice = (x_dim, ny, nz)
    cfg = TargetConfig("pallas", vvl=preferred)
    for c in plan_mod.candidate_plans(
            cfg, nsites=x_dim * ny * nz, layouts=[SOA], stencil=True,
            lattice=lattice):
        assert x_dim % c.bx == 0
        c.validate(nsites=x_dim * ny * nz, lattice=lattice, layouts=[SOA],
                   stencil=True)


@given(
    nsites=st.integers(1, 100000),
    preferred=st.integers(1, 4096),
    mult=st.sampled_from([1, 2, 4, 8]),
)
def test_choose_vvl_divisor_property(nsites, preferred, mult):
    """choose_vvl either returns a SAL-aligned divisor (the largest one not
    exceeding preferred, unless only the multiple_of fallback fits) or
    raises — never an invalid vvl."""
    try:
        v = plan_mod.choose_vvl(nsites, preferred, multiple_of=mult)
    except ValueError:
        assert nsites % mult != 0 or mult > nsites
        return
    assert nsites % v == 0 and v % mult == 0
    if v <= preferred:
        # maximality among conforming divisors <= preferred
        assert not any(nsites % w == 0 and w % mult == 0
                       for w in range(v + 1, preferred + 1))
    else:
        assert v == mult  # the alignment-wins fallback


@given(seed=st.integers(0, 30))
def test_reduction_linear_property(seed):
    """target_sum(a x + b y) == a target_sum(x) + b target_sum(y)."""
    rng = np.random.default_rng(seed)
    lat = (4, 4, 4)
    x = rng.normal(size=(3, *lat)).astype(np.float32)
    y = rng.normal(size=(3, *lat)).astype(np.float32)
    fx = Field.from_numpy("x", x, lat, SOA)
    fy = Field.from_numpy("y", y, lat, SOA)
    fz = Field.from_numpy("z", 2 * x - 3 * y, lat, SOA)
    cfgt = TargetConfig("jnp")
    lhs = np.asarray(target_sum(fz, cfgt))
    rhs = 2 * np.asarray(target_sum(fx, cfgt)) - 3 * np.asarray(target_sum(fy, cfgt))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


@given(
    sal=st.sampled_from([1, 2, 4, 8]),
    nblk=st.sampled_from([2, 3, 4, 6, 8, 12, 16]),
    pick=st.integers(0, 5),
    seed=st.integers(0, 100),
)
def test_split_reduction_matches_unsplit_property(sal, nblk, pick, seed):
    """Split-reduction contract over random geometries and factors: for any
    (sal, nblocks) and any rsplit dividing the block count, the split
    target_sum is within fp tolerance of the unsplit one and bitwise
    deterministic across repeat launches, and target_max and integer sums
    are bitwise exact (their monoids are associative on the nose)."""
    from repro.core import target_max

    nsites = sal * nblk
    lat = (nsites,)
    lay = aosoa(sal) if sal > 1 else SOA
    factors = [r for r in plan_mod.divisors(nblk) if r > 1]
    r = factors[pick % len(factors)]
    p1 = TargetConfig("pallas", plan_policy=LoweringPlan(
        "pallas", vvl=sal, rsplit=1, interpret=True))
    pr = TargetConfig("pallas", plan_policy=LoweringPlan(
        "pallas", vvl=sal, rsplit=r, interpret=True))

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(2, nsites)).astype(np.float32)
    fx = Field.from_canonical("x", jnp.asarray(x), lat, lay)
    s1, sr = target_sum(fx, p1), target_sum(fx, pr)
    np.testing.assert_allclose(np.asarray(sr), np.asarray(s1),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sr),
                                  np.asarray(target_sum(fx, pr)))
    np.testing.assert_array_equal(np.asarray(target_max(fx, pr)),
                                  np.asarray(target_max(fx, p1)))

    xi = rng.integers(-1000, 1000, size=(2, nsites)).astype(np.int32)
    fi = Field.from_canonical("xi", jnp.asarray(xi), lat, lay)
    np.testing.assert_array_equal(np.asarray(target_sum(fi, pr)),
                                  xi.sum(axis=1))
    np.testing.assert_array_equal(np.asarray(target_max(fi, pr)),
                                  xi.max(axis=1))
