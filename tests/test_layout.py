"""Layout (INDEX macro) fidelity: the paper's §3.1 linearizations."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.layout import AOS, SOA, aosoa, parse_layout

LAYOUTS = [AOS, SOA, aosoa(2), aosoa(4), aosoa(8)]


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
def test_index_matches_flat_memory_order(lay):
    """paper formula INDEX(c, s) == flat offset of pack()'s row-major data."""
    ncomp, nsites = 3, 24
    can = np.arange(ncomp * nsites, dtype=np.float32).reshape(ncomp, nsites)
    phys = np.asarray(lay.pack(jnp.asarray(can))).ravel()
    for c in range(ncomp):
        for s in range(nsites):
            assert phys[lay.flat_index(c, s, ncomp, nsites)] == can[c, s]


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
def test_pack_unpack_roundtrip(lay):
    ncomp, nsites = 5, 32
    can = np.random.default_rng(1).normal(size=(ncomp, nsites)).astype(np.float32)
    out = np.asarray(lay.unpack(lay.pack(jnp.asarray(can))))
    np.testing.assert_array_equal(out, can)


@given(
    ncomp=st.integers(1, 8),
    nblk=st.integers(1, 8),
    sal=st.sampled_from([1, 2, 4, 8]),
)
@settings(max_examples=30, deadline=None)
def test_aosoa_index_bijection(ncomp, nblk, sal):
    """INDEX is a bijection onto [0, ncomp*nsites) — no overlap, no holes."""
    lay = aosoa(sal)
    nsites = nblk * sal
    seen = set()
    for c in range(ncomp):
        for s in range(nsites):
            i = lay.flat_index(c, s, ncomp, nsites)
            assert 0 <= i < ncomp * nsites
            seen.add(i)
    assert len(seen) == ncomp * nsites


def test_parse_layout():
    assert parse_layout("aos") == AOS
    assert parse_layout("soa") == SOA
    assert parse_layout("aosoa32").sal == 32
    assert parse_layout("aosoa").sal == 128
    with pytest.raises(ValueError):
        parse_layout("zigzag")


def test_block_canonical_roundtrip():
    for lay in LAYOUTS:
        ncomp, vvl = 3, 16
        chunk = jnp.arange(ncomp * vvl, dtype=jnp.float32).reshape(ncomp, vvl)
        block = lay.canonical_to_block(chunk, ncomp, vvl)
        assert block.shape == lay.block_shape(ncomp, vvl)
        back = lay.block_to_canonical(block, ncomp, vvl)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(chunk))
