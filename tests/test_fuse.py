"""core.fuse launch graphs: fused == unfused == oracle, single-pallas_call
lowering (site-local, stencil and terminal-reduction stages), launch-cache
hits, halo-ring edge cases, and chain validation errors."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AOS, SOA, Field, LaunchGraph, TargetConfig, aosoa, fused_launch, launch,
    target_sum,
)
from repro.core import fuse

LAT = (4, 4, 8)  # 128 sites
LAYOUTS = [AOS, SOA, aosoa(32)]
ENGINES = ["jnp", "pallas"]


def _s1(v, *, a):
    return {"t": a * v["x"] + v["y"]}


def _s2(v):
    return {"u": v["t"] * v["t"] - v["x"]}


def _s3(v, *, b):
    return {"o": v["u"] + b * v["t"]}


def _mk(name, ncomp, lay, rng, lat=LAT):
    arr = rng.normal(size=(ncomp, *lat)).astype(np.float32)
    return arr, Field.from_numpy(name, arr, lat, lay)


def _oracle3(x, y):
    t = 2.0 * x + y
    u = t * t - x
    return u + 0.5 * t


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ENGINES)
def test_two_kernel_chain_matches_sequential_and_oracle(lay, engine, rng):
    x, fx = _mk("x", 3, lay, rng)
    y, fy = _mk("y", 3, lay, rng)
    cfg = TargetConfig(engine, vvl=64)
    g = (LaunchGraph("chain2")
         .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=2.0))
         .add(_s2, {"t": "t", "x": "x"}, {"u": 3}))
    fused = g.launch({"x": fx, "y": fy}, config=cfg)["u"].to_numpy()
    # sequential-unfused through the plain launch machinery, same engine
    t = launch(_s1, {"x": fx, "y": fy}, {"t": 3}, config=cfg,
               params=dict(a=2.0))["t"]
    seq = launch(_s2, {"t": t, "x": fx}, {"u": 3}, config=cfg)["u"].to_numpy()
    oracle = (2.0 * x + y) ** 2 - x
    np.testing.assert_allclose(fused, seq, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fused, oracle, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ENGINES)
def test_three_kernel_chain_matches_oracle(lay, engine, rng):
    x, fx = _mk("x", 3, lay, rng)
    y, fy = _mk("y", 3, lay, rng)
    cfg = TargetConfig(engine, vvl=64)
    out = fused_launch(
        [(_s1, {"x": "x", "y": "y"}, {"t": 3}, dict(a=2.0)),
         (_s2, {"t": "t", "x": "x"}, {"u": 3}),
         (_s3, {"u": "u", "t": "t"}, {"o": 3}, dict(b=0.5), {"o": "final"})],
        {"x": fx, "y": fy},
        config=cfg,
        outputs=("final",),
        name="chain3",
    )["final"].to_numpy()
    np.testing.assert_allclose(out, _oracle3(x, y), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("engine", ENGINES)
def test_runtime_scalars(engine, rng):
    x, fx = _mk("x", 3, SOA, rng)
    y, fy = _mk("y", 3, SOA, rng)
    g = LaunchGraph("sc").add(
        lambda v: {"o": v["y"] + v["a"] * v["x"]},
        {"x": "x", "y": "y", "a": "a"}, {"o": 3})
    out = g.launch({"x": fx, "y": fy}, scalars={"a": 0.75},
                   config=TargetConfig(engine, vvl=128))["o"].to_numpy()
    np.testing.assert_allclose(out, y + 0.75 * x, rtol=1e-5, atol=1e-6)


def test_launch_cache_hit_on_second_call(rng):
    _, fx = _mk("x", 3, SOA, rng)
    _, fy = _mk("y", 3, SOA, rng)
    cfg = TargetConfig("pallas", vvl=128)
    fuse.clear_cache()
    fuse.reset_stats()

    def run():
        g = (LaunchGraph("cache_probe")
             .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=2.0))
             .add(_s2, {"t": "t", "x": "x"}, {"u": 3}))
        return g.launch({"x": fx, "y": fy}, config=cfg)

    run()
    s = fuse.stats()
    assert s["traces"] == 1 and s["cache_misses"] == 1, s
    run()  # graph rebuilt from the same bodies -> structural key -> cache hit
    s = fuse.stats()
    assert s["traces"] == 1, f"fused launch re-traced on second call: {s}"
    assert s["cache_hits"] == 1, s


def test_ludwig_lc_chain_is_one_pallas_call(rng):
    """Acceptance probe: the fused 3-kernel Ludwig chain (molecular field ->
    BE rhs -> Q update) lowers to exactly ONE pallas_call and matches the
    unfused jnp oracle to 1e-5."""
    from repro.apps.ludwig import LudwigConfig
    from repro.apps.ludwig.driver import (
        _be_rhs_body, _mol_field_body, _q_update_body, lc_chain_graph,
    )

    cfg = LudwigConfig(lattice=LAT)
    q, fq = _mk("q", 5, SOA, rng)
    lapq, flapq = _mk("lapq", 5, SOA, rng)
    w, fw = _mk("w", 9, SOA, rng)
    adv, fadv = _mk("adv", 5, SOA, rng)
    q, lapq, w, adv = (0.01 * a for a in (q, lapq, w, adv))
    fq, flapq, fw, fadv = (
        f.with_canonical(0.01 * f.canonical()) for f in (fq, flapq, fw, fadv))
    ins = {"q": fq, "lapq": flapq, "w": fw, "adv": fadv}

    fuse.clear_cache()
    fuse.reset_stats()
    graph = lc_chain_graph(cfg)
    got = graph.launch(ins, config=TargetConfig("pallas", vvl=64),
                       outputs=("q_new",))["q_new"].to_numpy()
    s = fuse.stats()
    assert s["pallas_calls"] == 1, f"chain lowered to {s['pallas_calls']} pallas_calls"
    assert s["traces"] == 1, s

    # unfused jnp oracle: one plain launch per kernel
    jcfg = TargetConfig("jnp")
    h = launch(_mol_field_body, {"q": fq, "lapq": flapq}, {"h": 5}, config=jcfg,
               params=dict(a0=cfg.a0, gamma=cfg.gamma, kappa=cfg.kappa))["h"]
    rhs = launch(_be_rhs_body, {"q": fq, "h": h, "w": fw}, {"rhs": 5},
                 config=jcfg, params=dict(gamma_rot=cfg.gamma_rot, xi=cfg.xi))["rhs"]
    want = launch(_q_update_body, {"q": fq, "rhs": rhs, "adv": fadv}, {"q": 5},
                  config=jcfg, params=dict(dt=cfg.dt))["q"].to_numpy()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    # second launch: cache hit, no re-trace, still one pallas_call total
    graph.launch(ins, config=TargetConfig("pallas", vvl=64), outputs=("q_new",))
    s = fuse.stats()
    assert s["traces"] == 1 and s["cache_hits"] == 1 and s["pallas_calls"] == 1, s


def test_nsites_mismatch_raises(rng):
    _, fx = _mk("x", 3, SOA, rng)
    f_small = Field.zeros("y", 3, (4, 4, 4))
    g = LaunchGraph("mm").add(_s1, {"x": "x", "y": "y"}, {"t": 3},
                              params=dict(a=1.0))
    with pytest.raises(ValueError, match="share nsites"):
        g.launch({"x": fx, "y": f_small}, config=TargetConfig("jnp"))


def test_missing_input_raises(rng):
    _, fx = _mk("x", 3, SOA, rng)
    g = LaunchGraph("miss").add(_s1, {"x": "x", "y": "y"}, {"t": 3},
                                params=dict(a=1.0))
    with pytest.raises(ValueError, match="produced by no earlier stage"):
        g.launch({"x": fx}, config=TargetConfig("jnp"))


def test_duplicate_output_needs_rename():
    g = LaunchGraph("dup").add(_s1, {"x": "x", "y": "y"}, {"t": 3})
    with pytest.raises(ValueError, match="rename"):
        g.add(_s1, {"x": "t", "y": "y"}, {"t": 3})


def test_traced_param_rejected(rng):
    g = LaunchGraph("tp")
    import jax

    def try_add(a):
        g.add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=a))
        return jnp.zeros(())

    with pytest.raises(TypeError, match="scalars"):
        jax.make_jaxpr(try_add)(jnp.float32(2.0))


def test_auto_vvl_on_nondividing_nsites(rng):
    lat = (5, 5, 4)  # 100 sites: 128 does not divide
    arr, fx = _mk("x", 3, SOA, rng, lat=lat)
    g = LaunchGraph("av").add(lambda v: {"o": 3.0 * v["x"]}, {"x": "x"}, {"o": 3})
    out = g.launch({"x": fx}, config=TargetConfig("pallas", vvl=128))["o"]
    np.testing.assert_allclose(out.to_numpy(), 3.0 * arr, rtol=1e-6)
    # plain launch auto-vvl as well (seed raised here)
    out2 = launch(lambda v: {"o": 3.0 * v["x"]}, {"x": fx}, {"o": 3},
                  config=TargetConfig("pallas", vvl=128))["o"]
    np.testing.assert_allclose(out2.to_numpy(), 3.0 * arr, rtol=1e-6)


def test_bytes_moved_model():
    g = (LaunchGraph("bm")
         .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=2.0))
         .add(_s2, {"t": "t", "x": "x"}, {"u": 3}))
    bm = g.bytes_moved({"x": 3, "y": 3}, nsites=100, outputs=("u",))
    # unfused: s1 reads x,y writes t (9); s2 reads t,x writes u (9) -> 18 comps
    # fused: reads x,y once (6) + writes u (3) -> 9 comps
    assert bm["unfused"] == 18 * 100 * 4
    assert bm["fused"] == 9 * 100 * 4
    assert bm["fused"] < bm["unfused"]


def test_bytes_moved_counts_unfused_reduction_read():
    g = (LaunchGraph("bmr")
         .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=2.0))
         .add_reduce("t", op="sum", name="tsum"))
    bm = g.bytes_moved({"x": 3, "y": 3}, nsites=100, outputs=("tsum",))
    # unfused: s1 reads x,y (6) writes t (3); the separate reduction pass
    # re-reads t (3) -> 12 comps.  fused: x,y read once, tsum is O(ncomp).
    assert bm["unfused"] == 12 * 100 * 4
    assert bm["fused"] == 6 * 100 * 4


# -- stencil stages + terminal reductions --------------------------------------

def _scale_body(v, *, a):
    return {"y": a * v["x"]}


def _lap1d_body(v, gather, *, c):
    """width-1 stencil along the leading lattice dim."""
    return {"z": c * v["y"] + gather("y", (1, 0, 0)) + gather("y", (-1, 0, 0))}


def _shift2_body(v, gather):
    """width-2 stencil: y(r - 2 e_x) + y(r + 2 e_y)."""
    return {"z": gather("y", (2, 0, 0)) + gather("y", (0, -2, 0))}


def _lap_oracle(y, c):
    return c * y + np.roll(y, 1, axis=1) + np.roll(y, -1, axis=1)


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ENGINES)
def test_stencil_stage_after_map_matches_oracle(lay, engine, rng):
    """Site-local -> stencil -> terminal reduction, one launch: the map
    stage recomputes on halo sites so the stencil gathers its output."""
    lat = (6, 4, 8)
    x, fx = _mk("x", 3, lay, rng, lat=lat)
    g = (LaunchGraph("map_stencil_sum")
         .add(_scale_body, {"x": "x"}, {"y": 3}, params=dict(a=2.0))
         .add_stencil(_lap1d_body, {"y": "y"}, {"z": 3}, width=1,
                      params=dict(c=-2.0))
         .add_reduce("z", op="sum", name="ztot"))
    assert g.halo_widths(("z", "ztot")) == {"x": 1}
    fuse.reset_stats()
    out = g.launch({"x": fx}, config=TargetConfig(engine, vvl=64),
                   outputs=("z", "ztot"))
    want = _lap_oracle(2.0 * x, -2.0)
    np.testing.assert_allclose(out["z"].to_numpy(), want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out["ztot"]),
                               want.reshape(3, -1).sum(1), atol=1e-4)
    s = fuse.stats()
    assert engine == "jnp" or s["pallas_calls"] == 1, s


@pytest.mark.parametrize("engine", ENGINES)
def test_stencil_halo_width_greater_than_one(engine, rng):
    """width=2 stencil: periodic halo pads by 2 and gathers reach 2 deep."""
    lat = (8, 6, 4)
    x, fx = _mk("x", 2, SOA, rng, lat=lat)
    g = (LaunchGraph("w2")
         .add(_scale_body, {"x": "x"}, {"y": 2}, params=dict(a=1.0))
         .add_stencil(_shift2_body, {"y": "y"}, {"z": 2}, width=2))
    assert g.halo_widths(("z",)) == {"x": 2}
    out = g.launch({"x": fx}, config=TargetConfig(engine, vvl=64))["z"]
    want = np.roll(x, 2, axis=1) + np.roll(x, -2, axis=2)
    np.testing.assert_allclose(out.to_numpy(), want, rtol=1e-6)


@pytest.mark.parametrize("engine", ENGINES)
def test_chained_stencils_consume_ring_per_stage(engine, rng):
    """Two chained width-1 stencils need a ring-2 external halo, and the
    intermediate's valid ring shrinks by one per stage."""
    lat = (4, 4, 4)
    x, fx = _mk("x", 1, SOA, rng, lat=lat)
    g = (LaunchGraph("chain_stencil")
         .add_stencil(_lap1d_body, {"y": "x"}, {"z": 1}, width=1,
                      params=dict(c=0.0), rename={"z": "z1"})
         .add_stencil(_lap1d_body, {"y": "z1"}, {"z": 1}, width=1,
                      params=dict(c=0.0)))
    assert g.halo_widths(("z",)) == {"x": 2}
    out = g.launch({"x": fx}, config=TargetConfig(engine, vvl=16))["z"]
    want = _lap_oracle(_lap_oracle(x, 0.0), 0.0)
    np.testing.assert_allclose(out.to_numpy(), want, rtol=1e-5, atol=1e-5)


def test_stencil_vvl_not_dividing_interior_block(rng):
    """vvl smaller than / not dividing the inner-plane site count: the slab
    chooser falls back to single x-planes instead of raising."""
    lat = (5, 6, 7)   # X=5 prime, inner 42 sites; vvl=64 divides neither
    x, fx = _mk("x", 3, SOA, rng, lat=lat)
    g = (LaunchGraph("odd_slab")
         .add(_scale_body, {"x": "x"}, {"y": 3}, params=dict(a=3.0))
         .add_stencil(_lap1d_body, {"y": "y"}, {"z": 3}, width=1,
                      params=dict(c=1.0)))
    for vvl in (1, 64, 128, 4096):
        out = g.launch({"x": fx}, config=TargetConfig("pallas", vvl=vvl))["z"]
        np.testing.assert_allclose(out.to_numpy(), _lap_oracle(3.0 * x, 1.0),
                                   rtol=1e-5, atol=1e-5)


def test_fused_reduction_matches_target_sum_oracle(rng):
    """Fused terminal reduction == the standalone target_sum API on the
    materialized field == the fp64 numpy oracle (fp32 accumulation noise
    bounded against the fp64 reference)."""
    x, fx = _mk("x", 3, SOA, rng)
    y, fy = _mk("y", 3, SOA, rng)
    g = (LaunchGraph("red_oracle")
         .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=2.0))
         .add_reduce("t", op="sum", name="tsum")
         .add_reduce("t", op="max", name="tmax"))
    want64 = (2.0 * x.astype(np.float64) + y.astype(np.float64)).reshape(3, -1)
    for engine in ENGINES:
        cfg = TargetConfig(engine, vvl=64)
        out = g.launch({"x": fx, "y": fy}, config=cfg,
                       outputs=("t", "tsum", "tmax"))
        oracle = target_sum(out["t"], cfg)
        np.testing.assert_allclose(np.asarray(out["tsum"]), np.asarray(oracle),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out["tsum"]), want64.sum(1),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(out["tmax"]), want64.max(1),
                                   rtol=1e-6)


def test_stencil_after_reduce_raises():
    """A reduction changes the value shape (per-site -> per-component), so
    stencil (and site-local) stages cannot follow it."""
    g = (LaunchGraph("bad")
         .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=1.0))
         .add_reduce("t", op="sum"))
    with pytest.raises(ValueError, match="changes the value shape"):
        g.add_stencil(_lap1d_body, {"y": "t"}, {"z": 3}, width=1,
                      params=dict(c=0.0))
    with pytest.raises(ValueError, match="changes the value shape"):
        g.add(_s2, {"t": "t", "x": "x"}, {"u": 3})


def test_reduce_of_reduce_raises():
    g = (LaunchGraph("rr")
         .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=1.0))
         .add_reduce("t", op="sum"))
    with pytest.raises(ValueError, match="itself a reduction result"):
        g.add_reduce("t_sum", op="max")


def test_pre_halo_insufficient_ring_raises(rng):
    """halo='pre' with a Field too thin to carry the required ring (the
    derived interior would be empty): clear error naming the rings."""
    lat = (4, 4, 4)
    _, fx = _mk("x", 1, SOA, rng, lat=lat)
    g = (LaunchGraph("thin")
         .add_stencil(_lap1d_body, {"y": "x"}, {"z": 1}, width=1,
                      params=dict(c=0.0), rename={"z": "z1"})
         .add_stencil(_lap1d_body, {"y": "z1"}, {"z": 1}, width=1,
                      params=dict(c=0.0)))
    # graph needs ring 2 -> a (4,4,4) Field would have a 0-site interior
    with pytest.raises(ValueError, match="interior lattice"):
        g.launch({"x": fx}, config=TargetConfig("jnp"), halo="pre",
                 outputs=("z",))
    # and pre-halo mode on a stencil-free graph is rejected outright
    g2 = LaunchGraph("nostencil").add(_s1, {"x": "x", "y": "y"}, {"t": 3},
                                      params=dict(a=1.0))
    with pytest.raises(ValueError, match="stencil"):
        g2.launch({"x": fx, "y": fx}, config=TargetConfig("jnp"), halo="pre")


def test_gather_disp_exceeding_width_raises(rng):
    lat = (4, 4, 4)
    _, fx = _mk("x", 1, SOA, rng, lat=lat)

    def bad_body(v, gather):
        return {"z": gather("y", (2, 0, 0))}

    g = LaunchGraph("wide").add_stencil(bad_body, {"y": "x"}, {"z": 1},
                                        width=1)
    with pytest.raises(ValueError, match="exceeds stage width"):
        g.launch({"x": fx}, config=TargetConfig("jnp"))


# -- application acceptance probes ---------------------------------------------

def test_lb_collide_propagate_is_one_pallas_call(rng):
    """Acceptance probe: the fused LB collide->propagate step lowers to
    exactly ONE pallas_call and matches the unfused jnp oracle."""
    from repro.kernels.lb_collision import ref as lbref
    from repro.kernels.lb_propagation import ref as propref
    from repro.kernels.lb_propagation.ops import collide_propagate

    lat = (4, 4, 8)
    f0 = (1.0 + 0.1 * rng.normal(size=(19, *lat))).astype(np.float32)
    frc = (0.01 * rng.normal(size=(3, *lat))).astype(np.float32)
    d = Field.from_numpy("dist", f0, lat, SOA)
    g = Field.from_numpy("force", frc, lat, SOA)

    fuse.clear_cache()
    fuse.reset_stats()
    got = collide_propagate(d, g, tau=0.8,
                            config=TargetConfig("pallas", vvl=128)).to_numpy()
    s = fuse.stats()
    assert s["pallas_calls"] == 1, \
        f"LB step lowered to {s['pallas_calls']} pallas_calls"
    want = np.asarray(propref.propagate_ref(
        lbref.collide_ref(jnp.asarray(f0.reshape(19, -1)),
                          jnp.asarray(frc.reshape(3, -1)),
                          0.8).reshape(19, *lat)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)

    # second step: launch-cache hit, still one pallas_call total
    collide_propagate(d, g, tau=0.8, config=TargetConfig("pallas", vvl=128))
    s = fuse.stats()
    assert s["pallas_calls"] == 1 and s["cache_hits"] == 1, s


def test_milc_normal_op_is_one_pallas_call(rng):
    """Acceptance probe: dslash + axpy/g5 chain + <p, Ap> residual-norm-style
    reduction lower to ONE pallas_call, fused == unfused == oracle."""
    from repro.apps.milc import MilcConfig, init_problem
    from repro.apps.milc.cg import dot, make_fused_normal, make_wilson_op

    cfg = MilcConfig(lattice=(4, 4, 4, 4), kappa=0.1)
    u, b = init_problem(cfg, seed=0)
    jcfg = TargetConfig("jnp")
    _, _, apply_normal = make_wilson_op(u, cfg.kappa, jcfg)
    want_ap = apply_normal(b).to_numpy()
    want_pap = float(dot(b, apply_normal(b), jcfg))

    fuse.clear_cache()
    fuse.reset_stats()
    ap, pap = make_fused_normal(u, cfg.kappa,
                                TargetConfig("pallas", vvl=256))(b)
    s = fuse.stats()
    assert s["pallas_calls"] == 1, \
        f"normal op lowered to {s['pallas_calls']} pallas_calls"
    np.testing.assert_allclose(ap.to_numpy(), want_ap, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(pap), want_pap, rtol=2e-4)

    # jnp engine through the same graph is the fusion oracle
    ap_j, pap_j = make_fused_normal(u, cfg.kappa, jcfg)(b)
    np.testing.assert_allclose(ap_j.to_numpy(), want_ap, rtol=1e-5, atol=1e-6)


def test_cg_update_with_fused_residual_norm_is_one_pallas_call(rng):
    """Acceptance probe: the CG update chain ends in the residual-norm
    reduction inside the same single pallas_call."""
    from repro.apps.milc.cg import fused_cg_update

    lat4 = (4, 4, 4, 4)
    mk = lambda n: Field.from_numpy(
        n, rng.normal(size=(24, *lat4)).astype(np.float32), lat4, SOA)
    x, r, p, ap = mk("x"), mk("r"), mk("p"), mk("ap")

    fuse.clear_cache()
    fuse.reset_stats()
    cfg = TargetConfig("pallas", vvl=256)
    xn, rn, rr = fused_cg_update(x, r, p, ap, jnp.float32(0.3), cfg)
    s = fuse.stats()
    assert s["pallas_calls"] == 1, s
    want_r = r.to_numpy() - 0.3 * ap.to_numpy()
    np.testing.assert_allclose(xn.to_numpy(),
                               x.to_numpy() + 0.3 * p.to_numpy(),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(rn.to_numpy(), want_r, rtol=1e-5, atol=1e-6)
    want_rr = (want_r.astype(np.float64) ** 2).sum()
    np.testing.assert_allclose(float(jnp.sum(rr)), want_rr, rtol=1e-4)
