"""core.fuse launch graphs: fused == unfused == oracle, single-pallas_call
lowering, launch-cache hits, and chain validation errors."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AOS, SOA, Field, LaunchGraph, TargetConfig, aosoa, fused_launch, launch,
)
from repro.core import fuse

LAT = (4, 4, 8)  # 128 sites
LAYOUTS = [AOS, SOA, aosoa(32)]
ENGINES = ["jnp", "pallas"]


def _s1(v, *, a):
    return {"t": a * v["x"] + v["y"]}


def _s2(v):
    return {"u": v["t"] * v["t"] - v["x"]}


def _s3(v, *, b):
    return {"o": v["u"] + b * v["t"]}


def _mk(name, ncomp, lay, rng, lat=LAT):
    arr = rng.normal(size=(ncomp, *lat)).astype(np.float32)
    return arr, Field.from_numpy(name, arr, lat, lay)


def _oracle3(x, y):
    t = 2.0 * x + y
    u = t * t - x
    return u + 0.5 * t


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ENGINES)
def test_two_kernel_chain_matches_sequential_and_oracle(lay, engine, rng):
    x, fx = _mk("x", 3, lay, rng)
    y, fy = _mk("y", 3, lay, rng)
    cfg = TargetConfig(engine, vvl=64)
    g = (LaunchGraph("chain2")
         .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=2.0))
         .add(_s2, {"t": "t", "x": "x"}, {"u": 3}))
    fused = g.launch({"x": fx, "y": fy}, config=cfg)["u"].to_numpy()
    # sequential-unfused through the plain launch machinery, same engine
    t = launch(_s1, {"x": fx, "y": fy}, {"t": 3}, config=cfg,
               params=dict(a=2.0))["t"]
    seq = launch(_s2, {"t": t, "x": fx}, {"u": 3}, config=cfg)["u"].to_numpy()
    oracle = (2.0 * x + y) ** 2 - x
    np.testing.assert_allclose(fused, seq, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fused, oracle, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("lay", LAYOUTS, ids=lambda l: l.name)
@pytest.mark.parametrize("engine", ENGINES)
def test_three_kernel_chain_matches_oracle(lay, engine, rng):
    x, fx = _mk("x", 3, lay, rng)
    y, fy = _mk("y", 3, lay, rng)
    cfg = TargetConfig(engine, vvl=64)
    out = fused_launch(
        [(_s1, {"x": "x", "y": "y"}, {"t": 3}, dict(a=2.0)),
         (_s2, {"t": "t", "x": "x"}, {"u": 3}),
         (_s3, {"u": "u", "t": "t"}, {"o": 3}, dict(b=0.5), {"o": "final"})],
        {"x": fx, "y": fy},
        config=cfg,
        outputs=("final",),
        name="chain3",
    )["final"].to_numpy()
    np.testing.assert_allclose(out, _oracle3(x, y), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("engine", ENGINES)
def test_runtime_scalars(engine, rng):
    x, fx = _mk("x", 3, SOA, rng)
    y, fy = _mk("y", 3, SOA, rng)
    g = LaunchGraph("sc").add(
        lambda v: {"o": v["y"] + v["a"] * v["x"]},
        {"x": "x", "y": "y", "a": "a"}, {"o": 3})
    out = g.launch({"x": fx, "y": fy}, scalars={"a": 0.75},
                   config=TargetConfig(engine, vvl=128))["o"].to_numpy()
    np.testing.assert_allclose(out, y + 0.75 * x, rtol=1e-5, atol=1e-6)


def test_launch_cache_hit_on_second_call(rng):
    _, fx = _mk("x", 3, SOA, rng)
    _, fy = _mk("y", 3, SOA, rng)
    cfg = TargetConfig("pallas", vvl=128)
    fuse.clear_cache()
    fuse.reset_stats()

    def run():
        g = (LaunchGraph("cache_probe")
             .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=2.0))
             .add(_s2, {"t": "t", "x": "x"}, {"u": 3}))
        return g.launch({"x": fx, "y": fy}, config=cfg)

    run()
    s = fuse.stats()
    assert s["traces"] == 1 and s["cache_misses"] == 1, s
    run()  # graph rebuilt from the same bodies -> structural key -> cache hit
    s = fuse.stats()
    assert s["traces"] == 1, f"fused launch re-traced on second call: {s}"
    assert s["cache_hits"] == 1, s


def test_ludwig_lc_chain_is_one_pallas_call(rng):
    """Acceptance probe: the fused 3-kernel Ludwig chain (molecular field ->
    BE rhs -> Q update) lowers to exactly ONE pallas_call and matches the
    unfused jnp oracle to 1e-5."""
    from repro.apps.ludwig import LudwigConfig
    from repro.apps.ludwig.driver import (
        _be_rhs_body, _mol_field_body, _q_update_body, lc_chain_graph,
    )

    cfg = LudwigConfig(lattice=LAT)
    q, fq = _mk("q", 5, SOA, rng)
    lapq, flapq = _mk("lapq", 5, SOA, rng)
    w, fw = _mk("w", 9, SOA, rng)
    adv, fadv = _mk("adv", 5, SOA, rng)
    q, lapq, w, adv = (0.01 * a for a in (q, lapq, w, adv))
    fq, flapq, fw, fadv = (
        f.with_canonical(0.01 * f.canonical()) for f in (fq, flapq, fw, fadv))
    ins = {"q": fq, "lapq": flapq, "w": fw, "adv": fadv}

    fuse.clear_cache()
    fuse.reset_stats()
    graph = lc_chain_graph(cfg)
    got = graph.launch(ins, config=TargetConfig("pallas", vvl=64),
                       outputs=("q_new",))["q_new"].to_numpy()
    s = fuse.stats()
    assert s["pallas_calls"] == 1, f"chain lowered to {s['pallas_calls']} pallas_calls"
    assert s["traces"] == 1, s

    # unfused jnp oracle: one plain launch per kernel
    jcfg = TargetConfig("jnp")
    h = launch(_mol_field_body, {"q": fq, "lapq": flapq}, {"h": 5}, config=jcfg,
               params=dict(a0=cfg.a0, gamma=cfg.gamma, kappa=cfg.kappa))["h"]
    rhs = launch(_be_rhs_body, {"q": fq, "h": h, "w": fw}, {"rhs": 5},
                 config=jcfg, params=dict(gamma_rot=cfg.gamma_rot, xi=cfg.xi))["rhs"]
    want = launch(_q_update_body, {"q": fq, "rhs": rhs, "adv": fadv}, {"q": 5},
                  config=jcfg, params=dict(dt=cfg.dt))["q"].to_numpy()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    # second launch: cache hit, no re-trace, still one pallas_call total
    graph.launch(ins, config=TargetConfig("pallas", vvl=64), outputs=("q_new",))
    s = fuse.stats()
    assert s["traces"] == 1 and s["cache_hits"] == 1 and s["pallas_calls"] == 1, s


def test_nsites_mismatch_raises(rng):
    _, fx = _mk("x", 3, SOA, rng)
    f_small = Field.zeros("y", 3, (4, 4, 4))
    g = LaunchGraph("mm").add(_s1, {"x": "x", "y": "y"}, {"t": 3},
                              params=dict(a=1.0))
    with pytest.raises(ValueError, match="share nsites"):
        g.launch({"x": fx, "y": f_small}, config=TargetConfig("jnp"))


def test_missing_input_raises(rng):
    _, fx = _mk("x", 3, SOA, rng)
    g = LaunchGraph("miss").add(_s1, {"x": "x", "y": "y"}, {"t": 3},
                                params=dict(a=1.0))
    with pytest.raises(ValueError, match="produced by no earlier stage"):
        g.launch({"x": fx}, config=TargetConfig("jnp"))


def test_duplicate_output_needs_rename():
    g = LaunchGraph("dup").add(_s1, {"x": "x", "y": "y"}, {"t": 3})
    with pytest.raises(ValueError, match="rename"):
        g.add(_s1, {"x": "t", "y": "y"}, {"t": 3})


def test_traced_param_rejected(rng):
    g = LaunchGraph("tp")
    import jax

    def try_add(a):
        g.add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=a))
        return jnp.zeros(())

    with pytest.raises(TypeError, match="scalars"):
        jax.make_jaxpr(try_add)(jnp.float32(2.0))


def test_auto_vvl_on_nondividing_nsites(rng):
    lat = (5, 5, 4)  # 100 sites: 128 does not divide
    arr, fx = _mk("x", 3, SOA, rng, lat=lat)
    g = LaunchGraph("av").add(lambda v: {"o": 3.0 * v["x"]}, {"x": "x"}, {"o": 3})
    out = g.launch({"x": fx}, config=TargetConfig("pallas", vvl=128))["o"]
    np.testing.assert_allclose(out.to_numpy(), 3.0 * arr, rtol=1e-6)
    # plain launch auto-vvl as well (seed raised here)
    out2 = launch(lambda v: {"o": 3.0 * v["x"]}, {"x": fx}, {"o": 3},
                  config=TargetConfig("pallas", vvl=128))["o"]
    np.testing.assert_allclose(out2.to_numpy(), 3.0 * arr, rtol=1e-6)


def test_bytes_moved_model():
    g = (LaunchGraph("bm")
         .add(_s1, {"x": "x", "y": "y"}, {"t": 3}, params=dict(a=2.0))
         .add(_s2, {"t": "t", "x": "x"}, {"u": 3}))
    bm = g.bytes_moved({"x": 3, "y": 3}, nsites=100, outputs=("u",))
    # unfused: s1 reads x,y writes t (9); s2 reads t,x writes u (9) -> 18 comps
    # fused: reads x,y once (6) + writes u (3) -> 9 comps
    assert bm["unfused"] == 18 * 100 * 4
    assert bm["fused"] == 9 * 100 * 4
    assert bm["fused"] < bm["unfused"]
